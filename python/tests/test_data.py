"""Synthetic dataset tests, including the cross-language golden vector
shared with `rust/tests/integration_runtime.rs`."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

#: Must match wsel::data::GOLDEN_VAL0_PREFIX on the Rust side.
GOLDEN_VAL0_PREFIX = [193, 255, 194, 0, 0, 0, 81, 115, 117, 210, 215, 146, 245, 255, 249, 90]


def test_cross_language_golden():
    img, cls = D.sample(7, 1, 0, 10)
    assert list(img.reshape(-1)[:16]) == GOLDEN_VAL0_PREFIX
    assert cls == 2


def test_deterministic():
    a, ca = D.sample(7, 0, 5, 10)
    b, cb = D.sample(7, 0, 5, 10)
    np.testing.assert_array_equal(a, b)
    assert ca == cb


@given(split=st.integers(0, 2), idx=st.integers(0, 10_000), ncls=st.sampled_from([10, 100]))
def test_sample_shape_and_range(split, idx, ncls):
    img, cls = D.sample(7, split, idx, ncls)
    assert img.shape == (32, 32, 3)
    assert img.dtype == np.uint8
    assert 0 <= cls < ncls


def test_splits_differ():
    a, _ = D.sample(7, 0, 3, 10)
    b, _ = D.sample(7, 1, 3, 10)
    assert not np.array_equal(a, b)


def test_batch_normalization():
    xs, ys = D.batch(7, 1, 0, 4, 10)
    assert xs.shape == (4, 32, 32, 3)
    assert xs.min() >= -1.0 and xs.max() <= 1.0
    assert ys.dtype == np.int32


def test_label_distribution_covers_classes():
    labels = [D.sample(7, 0, i, 10)[1] for i in range(400)]
    assert set(labels) == set(range(10))


def test_label_noise_rate_in_band():
    # With LABEL_NOISE_DEN = 16, ~6.25% of samples have label != image class.
    n, noisy = 1200, 0
    for i in range(n):
        h = D.mix2(7 ^ (0 * 0x9E3779B97F4A7C15 & D.M64), i)
        if (h >> 32) % D.LABEL_NOISE_DEN == 0:
            noisy += 1
    rate = noisy / n
    assert 0.03 < rate < 0.10, rate
