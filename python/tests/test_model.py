"""L2 model tests: spec construction, QAT semantics, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

settings.register_profile("ci", max_examples=8, deadline=None)
settings.load_profile("ci")


def dense_qc_args(spec):
    masks = [jnp.ones(p["shape"]) for p in spec["params"] if p["kind"] == "conv_w"]
    wsets = [jnp.full((M.KSET,), M.SET_SENTINEL) for _ in range(spec["n_conv"])]
    won = jnp.zeros((spec["n_conv"],))
    asc = jnp.ones((spec["n_q"],))
    return masks, wsets, won, asc


class TestSpecs:
    @pytest.mark.parametrize("name,n_conv,n_q", [
        ("lenet5", 2, 5),
        ("resnet20", 21, 22),
        ("resnet50lite", 31, 32),
    ])
    def test_spec_shapes(self, name, n_conv, n_q):
        spec = M.SPECS[name]()
        assert spec["n_conv"] == n_conv
        assert spec["n_q"] == n_q
        # conv_idx and q_idx are dense ranges.
        conv_idxs = set()
        for op in spec["ops"]:
            if op["op"] == "conv":
                conv_idxs.add(op["conv_idx"])
            if op["op"] == "add_saved" and op["proj"]:
                conv_idxs.add(op["proj"]["conv_idx"])
        assert conv_idxs == set(range(n_conv))

    def test_param_count_resnet20(self):
        spec = M.resnet20_spec()
        total = sum(int(np.prod(p["shape"])) for p in spec["params"])
        # Classic ResNet-20 ~0.27M params (plus biases, no BN).
        assert 0.25e6 < total < 0.31e6, total


class TestForward:
    @pytest.mark.parametrize("name", ["lenet5", "resnet20", "resnet50lite"])
    def test_logit_shapes(self, name):
        spec = M.SPECS[name]()
        p = M.init_params(spec, 0)
        masks, wsets, won, asc = dense_qc_args(spec)
        x = jnp.zeros((2, 32, 32, 3))
        logits = M.logits_batch(
            spec, p, masks, wsets, won, asc, jnp.float32(0.0), x, False
        )
        assert logits.shape == (2, spec["n_classes"])
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_quantization_changes_little(self):
        spec = M.lenet5_spec()
        p = M.init_params(spec, 1)
        masks, wsets, won, _ = dense_qc_args(spec)
        key = jax.random.PRNGKey(2)
        x = jax.random.uniform(key, (4, 32, 32, 3), jnp.float32, -1, 1)
        calib, _ = M.calib_batch(spec, p, x)
        asc = calib / 127.0
        lf = M.logits_batch(spec, p, masks, wsets, won, asc, jnp.float32(0.0), x, False)
        lq = M.logits_batch(spec, p, masks, wsets, won, asc, jnp.float32(1.0), x, False)
        scale = float(jnp.max(jnp.abs(lf))) + 1e-6
        assert float(jnp.max(jnp.abs(lf - lq))) < 0.2 * scale

    def test_wset_projection_reduces_distinct_codes(self):
        spec = M.lenet5_spec()
        p = M.init_params(spec, 3)
        masks, wsets, won, asc = dense_qc_args(spec)
        # Restrict conv0 to codes {-64, 0, 64}.
        t = np.full(M.KSET, M.SET_SENTINEL, np.float32)
        t[:3] = [-64.0, 0.0, 64.0]
        wsets = [jnp.array(t)] + wsets[1:]
        won = jnp.array([1.0, 0.0])
        w = p[0]
        s = jnp.max(jnp.abs(w)) / M.QMAX
        wq, _ = M._quant_weight(w, masks[0], wsets[0], won[0], False)
        codes = np.unique(np.round(np.asarray(wq / s)))
        assert set(codes.tolist()).issubset({-64.0, 0.0, 64.0})

    def test_pruning_mask_zeroes(self):
        spec = M.lenet5_spec()
        p = M.init_params(spec, 4)
        mask = np.ones(spec["params"][0]["shape"], np.float32)
        mask[0] = 0.0
        wq, _ = M._quant_weight(p[0], jnp.array(mask), None, None, False)
        assert float(jnp.max(jnp.abs(wq[0]))) == 0.0


class TestTraining:
    def test_loss_decreases(self):
        spec = M.lenet5_spec()
        p = M.init_params(spec, 5)
        mom = [jnp.zeros_like(q) for q in p]
        masks, wsets, won, asc = dense_qc_args(spec)
        key = jax.random.PRNGKey(6)
        x = jax.random.uniform(key, (16, 32, 32, 3), jnp.float32, -1, 1)
        y = jax.random.randint(key, (16,), 0, 10)
        step = jax.jit(
            lambda p, mom: M.train_step(
                spec, p, mom, masks, wsets, won, asc, jnp.float32(0.0),
                jnp.float32(0.05), x, y,
            )
        )
        losses = []
        for _ in range(30):
            p, mom, loss = step(p, mom)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, losses[::10]

    def test_gradients_respect_mask(self):
        spec = M.lenet5_spec()
        p = M.init_params(spec, 7)
        masks, wsets, won, asc = dense_qc_args(spec)
        mask0 = np.ones(spec["params"][0]["shape"], np.float32)
        mask0[1] = 0.0
        masks = [jnp.array(mask0)] + masks[1:]
        key = jax.random.PRNGKey(8)
        x = jax.random.uniform(key, (4, 32, 32, 3), jnp.float32, -1, 1)
        y = jax.random.randint(key, (4,), 0, 10)
        mom = [jnp.zeros_like(q) for q in p]
        p2, _, _ = M.train_step(
            spec, p, mom, masks, wsets, won, asc, jnp.float32(0.0),
            jnp.float32(0.1), x, y,
        )
        # Pruned filter's weights unchanged (zero gradient through mask).
        np.testing.assert_array_equal(np.asarray(p[0][1]), np.asarray(p2[0][1]))


class TestCalib:
    def test_calib_counts_and_positive(self):
        spec = M.resnet20_spec()
        p = M.init_params(spec, 9)
        x = jax.random.uniform(jax.random.PRNGKey(10), (2, 32, 32, 3), jnp.float32, -1, 1)
        maxes, logit_mean = M.calib_batch(spec, p, x)
        assert maxes.shape == (spec["n_q"],)
        assert bool(jnp.all(maxes > 0))
        assert bool(jnp.isfinite(logit_mean))
