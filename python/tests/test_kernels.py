"""L1 kernel correctness: Pallas vs pure-jnp oracles.

Hypothesis sweeps shapes and seeds; every kernel must match its oracle to
float tolerance.  This is the core correctness signal pinning the
systolic-tile schedule to plain matmul semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quantize import KSET, fake_quant, project_codes
from compile.kernels.systolic_matmul import matmul_systolic

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestSystolicMatmul:
    @given(
        m=st.integers(1, 150),
        k=st.integers(1, 150),
        n=st.integers(1, 80),
        seed=st.integers(0, 2**31),
    )
    def test_matches_oracle(self, m, k, n, seed):
        x = rand(seed, (m, k))
        w = rand(seed + 1, (k, n))
        got = matmul_systolic(x, w)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_exact_on_tile_multiples(self):
        x = rand(0, (128, 192))
        w = rand(1, (192, 128))
        got = matmul_systolic(x, w)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_integer_codes_exact(self):
        # int8-code operands must be bit-exact (the systolic mapping
        # carries integer partial sums).
        rng = np.random.default_rng(3)
        x = rng.integers(-7, 8, (70, 90)).astype(np.float32)
        w = rng.integers(-7, 8, (90, 17)).astype(np.float32)
        got = np.asarray(matmul_systolic(jnp.array(x), jnp.array(w)))
        want = x @ w
        np.testing.assert_array_equal(got, want)


class TestFakeQuant:
    @given(
        n=st.integers(1, 3000),
        scale=st.floats(1e-4, 1.0),
        seed=st.integers(0, 2**31),
    )
    def test_matches_oracle(self, n, scale, seed):
        x = rand(seed, (n,), scale=3.0)
        s = jnp.float32(scale)
        np.testing.assert_allclose(
            fake_quant(x, s), ref.fake_quant_ref(x, s), rtol=0, atol=1e-6
        )

    def test_zero_scale_passes_zero(self):
        x = rand(9, (64,))
        out = fake_quant(x, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(out), np.zeros(64, np.float32))

    def test_clips_to_127_steps(self):
        x = jnp.array([10.0, -10.0, 0.4, -0.4], jnp.float32)
        s = jnp.float32(0.01)
        out = np.asarray(fake_quant(x, s))
        np.testing.assert_allclose(out[:2], [1.27, -1.27], atol=1e-6)


class TestProjectCodes:
    @given(
        n=st.integers(1, 2000),
        k=st.integers(1, KSET),
        seed=st.integers(0, 2**31),
    )
    def test_matches_oracle(self, n, k, seed):
        rng = np.random.default_rng(seed)
        q = jnp.array(rng.integers(-127, 128, n).astype(np.float32))
        codes = np.sort(rng.choice(np.arange(-127, 128), size=k, replace=False))
        cset = np.full(KSET, ref.SET_SENTINEL, np.float32)
        cset[:k] = codes
        cset = jnp.array(cset)
        got = np.asarray(project_codes(q, cset))
        want = np.asarray(ref.project_codes_ref(q, cset))
        np.testing.assert_array_equal(got, want)
        assert set(np.unique(got)).issubset(set(codes.tolist()))

    def test_projection_is_nearest(self):
        cset = np.full(KSET, ref.SET_SENTINEL, np.float32)
        cset[:3] = [-100.0, 0.0, 100.0]
        q = jnp.array([-70.0, -30.0, 49.0, 51.0], jnp.float32)
        got = np.asarray(project_codes(q, jnp.array(cset)))
        np.testing.assert_array_equal(got, [-100.0, 0.0, 0.0, 100.0])


class TestConv2dRef:
    @given(
        seed=st.integers(0, 2**31),
        cin=st.integers(1, 4),
        cout=st.integers(1, 5),
        k=st.sampled_from([1, 3, 5]),
        stride=st.sampled_from([1, 2]),
    )
    def test_im2col_conv_matches_lax(self, seed, cin, cout, k, stride):
        pad = k // 2
        x = rand(seed, (2, 12, 12, cin))
        w = rand(seed + 7, (cout, cin, k, k))
        got = ref.conv2d_ref(x, w, stride, pad)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(pad, pad)] * 2,
            dimension_numbers=("NHWC", "OIHW", "NHWC"),
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_pallas_conv_path(self):
        # The conv path with the Pallas matmul plugged in.
        x = rand(11, (1, 8, 8, 3))
        w = rand(12, (4, 3, 3, 3))
        got = ref.conv2d_ref(x, w, 1, 1, matmul=matmul_systolic)
        want = ref.conv2d_ref(x, w, 1, 1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
