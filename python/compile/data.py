"""Synthetic CIFAR generator — integer-exact, mirrored bit-for-bit in Rust.

The offline image has no dataset downloads, so CIFAR-10/100 are replaced
by a *deterministic* synthetic task (see DESIGN.md S2): each class is a
procedural 32x32x3 template (gratings / checkers / rings with
class-dependent frequency, orientation and per-channel inversion),
perturbed by a random phase, a random +-3 pixel shift and uniform pixel
noise.  Everything is integer arithmetic driven by SplitMix64, so the
Rust `data` module generates the *identical* byte stream
(`rust/tests/integration_data.rs` pins this).

Sample addressing is random-access: sample ``k`` of split ``s`` derives
its own seed, so Rust and Python can both materialize any batch without
sharing state.
"""

from __future__ import annotations

import numpy as np

M64 = (1 << 64) - 1
#: Uniform pixel-noise amplitude (out of 128); tuned together with
#: LABEL_NOISE_DEN so LeNet-5 lands in the paper's ~79% band and
#: ResNet-20 in the ~92% band on 10 classes.
NOISE_AMP = 100
#: Background / foreground template intensities.
BG, FG = 30, 255
#: One in LABEL_NOISE_DEN labels is resampled uniformly (irreducible
#: error floor, as in real CIFAR label noise).
LABEL_NOISE_DEN = 16
#: Sub-prototypes per class: each image draws one of VARIANTS pattern
#: parameterizations hashed from (class, variant) — multi-modal classes
#: are what separates small-capacity nets (LeNet) from deep ones.
VARIANTS = 3
#: Side of the random mid-gray occlusion square.
OCC = 10


def splitmix64(state: int):
    """One SplitMix64 step -> (new_state, output).  Matches util::rng."""
    state = (state + 0x9E3779B97F4A7C15) & M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    z = z ^ (z >> 31)
    return state, z


def mix2(a: int, b: int) -> int:
    """Order-sensitive 2-word hash used for sample addressing."""
    s = (a ^ 0x6A09E667F3BCC909) & M64
    s, _ = splitmix64(s)
    s = (s ^ b) & M64
    s, z = splitmix64(s)
    return z


def isqrt(n: int) -> int:
    return int(np.floor(np.sqrt(float(n)))) if n < (1 << 52) else int(np.sqrt(n))


def proto_params(cls: int, var: int):
    """Hash (class, variant) -> pattern parameterization.

    Returns (fam, period, slope, chinv): pattern family 0..3, stripe/cell
    period 3..7, orientation slope 1..3, per-channel inversion bits.
    ``chinv`` keeps one bit tied to the class so color stays weakly
    class-informative across variants.
    """
    h = mix2(0xC0FFEE ^ cls, 0xBEEF00 ^ var)
    fam = int(h % 4)
    p = 3 + int((h >> 8) % 5)
    a = 1 + int((h >> 16) % 3)
    chinv = (int((h >> 24) & 6)) | (cls & 1)
    return fam, p, a, chinv


def template(fam: int, p: int, a: int, chinv: int, u: int, v: int, ch: int, phase: int) -> int:
    """Prototype intensity at (shifted) pixel (u, v), channel ch."""
    if fam == 0:
        t = FG if ((u * a + v + phase) // p) % 2 == 0 else BG
    elif fam == 1:
        t = FG if ((u * a - v + phase) % (2 * p)) < p else BG
    elif fam == 2:
        t = FG if (((u + phase) // p) + ((v + phase) // p)) % 2 == 0 else BG
    else:
        d2 = (u - 16) * (u - 16) + (v - 16) * (v - 16)
        t = FG if ((isqrt(d2) + phase) // p) % 2 == 0 else BG
    if (chinv >> ch) & 1:
        t = 255 - t
    return t


def gen_image(seed: int, cls: int) -> np.ndarray:
    """One (32, 32, 3) uint8 image for class ``cls``.

    Distortions (all integer, all from one SplitMix64 stream so the Rust
    mirror reproduces the exact bytes): +-3 px shift, random phase,
    contrast jitter in [96/128, 160/128], a random OCCxOCC mid-gray
    occlusion square, and uniform pixel noise of amplitude NOISE_AMP.
    """
    s = seed & M64
    s, r0 = splitmix64(s)
    dx = int(r0 % 7) - 3
    dy = int((r0 >> 8) % 7) - 3
    phase = int((r0 >> 16) % 17)
    contrast = 96 + int((r0 >> 24) % 65)  # 96..160 (of 128)
    occx = int((r0 >> 32) % (33 - OCC))
    occy = int((r0 >> 40) % (33 - OCC))
    var = int((r0 >> 48) % VARIANTS)
    fam, p_, a, chinv = proto_params(cls, var)
    img = np.zeros((32, 32, 3), dtype=np.uint8)
    for y in range(32):
        for x in range(32):
            s, r = splitmix64(s)
            u, v = x + dx, y + dy
            occluded = occx <= x < occx + OCC and occy <= y < occy + OCC
            for ch in range(3):
                if occluded:
                    t = 128
                else:
                    t = template(fam, p_, a, chinv, u, v, ch, phase)
                    t = 128 + (t - 128) * contrast // 128
                noise = (int((r >> (8 * ch)) & 0xFF) - 128) * NOISE_AMP // 128
                p = t + noise
                img[y, x, ch] = 0 if p < 0 else (255 if p > 255 else p)
    return img


def sample(global_seed: int, split: int, index: int, n_classes: int):
    """Random-access sample -> (uint8 image, int label).

    ``split``: 0 = train, 1 = val, 2 = test (domain-separated streams).
    """
    h = mix2(global_seed ^ (split * 0x9E3779B97F4A7C15 & M64), index)
    cls = int(h % n_classes)
    if int((h >> 32) % LABEL_NOISE_DEN) == 0:
        cls = int((h >> 40) % n_classes)  # noisy label; image keeps cls below
        img_cls = int(h % n_classes)
    else:
        img_cls = cls
    img_seed = mix2(h, 0xDA7A5E77)
    return gen_image(img_seed, img_cls), cls


def batch(global_seed: int, split: int, start: int, size: int, n_classes: int):
    """Batch [start, start+size) as (f32 NHWC in [-1, 1], int32 labels)."""
    xs = np.zeros((size, 32, 32, 3), dtype=np.float32)
    ys = np.zeros((size,), dtype=np.int32)
    for i in range(size):
        img, cls = sample(global_seed, split, start + i, n_classes)
        xs[i] = img.astype(np.float32) / 127.5 - 1.0
        ys[i] = cls
    return xs, ys
