"""Layer-1 Pallas kernels: symmetric int8 fake-quantization and
weight-set projection (the paper's weight *restriction* operator, S4.2).

Both kernels are elementwise over the tensor being quantized, with the
candidate-set table broadcast from SMEM-like residency (a single 32-wide
row per layer).  ``interpret=True`` everywhere for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: int8 symmetric quantization range: codes in [-QMAX, QMAX].
QMAX = 127
#: Maximum candidate-set cardinality (the paper's "safe initial set" size).
KSET = 32
#: Elementwise block length for the 1-D kernels.
BLOCK = 512


def _fake_quant_kernel(x_ref, s_ref, out_ref):
    s = s_ref[0]
    inv = jnp.where(s > 0.0, 1.0 / jnp.maximum(s, 1e-30), 0.0)
    q = jnp.clip(jnp.round(x_ref[...] * inv), -QMAX, QMAX)
    out_ref[...] = q * s


def _project_kernel(q_ref, set_ref, out_ref):
    # q_ref: (BLOCK,) integer codes as f32; set_ref: (KSET,) candidate
    # codes with invalid slots pre-filled with a huge sentinel so they
    # never win the argmin.
    q = q_ref[...]
    dist = jnp.abs(q[:, None] - set_ref[...][None, :])
    best = jnp.argmin(dist, axis=1)
    out_ref[...] = set_ref[...][best]


def _pad1(x: jax.Array, n: int) -> jax.Array:
    return jnp.pad(x, (0, n - x.shape[0]))


def _ceil_block(n: int) -> int:
    return ((n + BLOCK - 1) // BLOCK) * BLOCK


@functools.partial(jax.jit, static_argnames=("interpret",))
def fake_quant(x: jax.Array, scale: jax.Array, *, interpret: bool = True):
    """Symmetric int8 fake-quant: ``round(x/s) clipped to +-127, times s``.

    ``scale == 0`` is the pass-to-zero convention used for disabled
    quantization points (callers gate with ``quant_on`` instead).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    npad = _ceil_block(n)
    out = pl.pallas_call(
        _fake_quant_kernel,
        grid=(npad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(_pad1(flat, npad), scale.reshape(1).astype(jnp.float32))
    return out[:n].reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def project_codes(q: jax.Array, cset: jax.Array, *, interpret: bool = True):
    """Map each int8 code in ``q`` to the nearest code of candidate set
    ``cset`` (shape ``(KSET,)``; invalid slots must hold a huge sentinel).

    This is the restriction operator applied inside QAT once a layer's
    candidate set has been chosen (S4.2): every occurrence of a removed
    weight value is mapped to the nearest remaining value.
    """
    flat = q.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    npad = _ceil_block(n)
    out = pl.pallas_call(
        _project_kernel,
        grid=(npad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((KSET,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(_pad1(flat, npad), cset.reshape(KSET).astype(jnp.float32))
    return out[:n].reshape(q.shape)
