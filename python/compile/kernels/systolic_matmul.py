"""Layer-1 Pallas kernel: weight-stationary systolic-tile matmul.

The paper (S3.2) maps every convolution, after im2col, onto a 64x64
weight-stationary systolic array: the weight matrix is cut into 64x64
tiles that stay resident in the PE grid while activations stream
through.  This kernel expresses exactly that schedule in Pallas terms:

  * grid = (M/64, N/64, K/64) - the (i, j) axes walk output tiles, the
    k axis walks the 64-deep reduction, i.e. one systolic *tile pass*
    per k step;
  * ``BlockSpec((64, 64), ...)`` for the weight operand = the
    weight-stationary residency (one 64x64 weight tile per grid step,
    exactly what is loaded into the PE grid);
  * the accumulator block plays the role of the 22-bit partial-sum
    chain: partial sums from tile pass k are carried into pass k+1.

Hardware adaptation (see DESIGN.md SHardware-Adaptation): on a real TPU
this lowering targets the MXU with VMEM-resident 64x64 blocks; here we
lower with ``interpret=True`` because the CPU PJRT plugin cannot execute
Mosaic custom-calls.  Numerics are identical; TPU efficiency is
estimated statically in EXPERIMENTS.md SPerf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Systolic array dimension from the paper (64x64 weight-stationary PEs).
TILE = 64


def _mm_kernel(x_ref, w_ref, out_ref):
    """One (i, j, k) grid step: multiply a 64xK block into the PE grid.

    ``out_ref`` is revisited for every k (same (i, j) block), which gives
    us the running partial-sum accumulation of the systolic column chain.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def _ceil_to_tile(n: int) -> int:
    return ((n + TILE - 1) // TILE) * TILE


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_systolic(x: jax.Array, w: jax.Array, *, interpret: bool = True):
    """``x @ w`` scheduled as 64x64 weight-stationary systolic tiles.

    Arbitrary (M, K) x (K, N) float32 operands; internally padded to
    multiples of :data:`TILE` (zero padding is exact for matmul).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    mp, kp, np_ = _ceil_to_tile(m), _ceil_to_tile(k), _ceil_to_tile(n)
    xp = _pad_to(x.astype(jnp.float32), mp, kp)
    wp = _pad_to(w.astype(jnp.float32), kp, np_)

    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // TILE, np_ // TILE, kp // TILE),
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def tile_matmul_entry(x: jax.Array, w: jax.Array):
    """AOT entry point for the standalone systolic-tile artifact.

    The Rust ``systolic`` module loads this executable to cross-check its
    cycle-level tile simulation against the device kernel (same tile, same
    numbers).  Shapes are fixed at lowering time by ``aot.py``.
    """
    return (matmul_systolic(x, w),)
