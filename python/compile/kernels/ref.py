"""Pure-jnp oracles for every Layer-1 Pallas kernel.

These are the CORE correctness references: pytest (with hypothesis
shape/seed sweeps) asserts the Pallas kernels match them bit-for-bit
(modulo float accumulation order).  The Layer-2 model uses these same
functions on its training path (they lower to plain XLA dot/elementwise,
which is much faster under the CPU PJRT plugin than interpreted Pallas),
while the eval/tile artifacts use the Pallas kernels — pytest pins the
two paths together.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127
KSET = 32
#: Sentinel for invalid candidate-set slots (never wins a nearest search).
SET_SENTINEL = 1.0e9


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for :func:`..kernels.systolic_matmul.matmul_systolic`."""
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def fake_quant_ref(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Oracle for :func:`..kernels.quantize.fake_quant`."""
    s = jnp.asarray(scale, jnp.float32)
    inv = jnp.where(s > 0.0, 1.0 / jnp.maximum(s, 1e-30), 0.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -QMAX, QMAX)
    return q * s


def project_codes_ref(q: jax.Array, cset: jax.Array) -> jax.Array:
    """Oracle for :func:`..kernels.quantize.project_codes`."""
    qf = q.astype(jnp.float32)
    dist = jnp.abs(qf[..., None] - cset.reshape(-1).astype(jnp.float32))
    best = jnp.argmin(dist, axis=-1)
    return cset.reshape(-1)[best].astype(jnp.float32)


def im2col(x: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """NHWC ``x`` -> patch matrix of shape (N*Ho*Wo, k*k*C).

    Patch column order is (ky, kx, c) fastest-last, matching the Rust
    engine's ``model::infer`` layout exactly (cross-checked in tests).
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            sl = xp[:, ky : ky + stride * ho : stride, kx : kx + stride * wo : stride, :]
            cols.append(sl.reshape(n * ho * wo, c))
    return jnp.concatenate(cols, axis=1)


def conv2d_ref(
    x: jax.Array, w: jax.Array, stride: int, pad: int, matmul=matmul_ref
) -> jax.Array:
    """im2col convolution; ``w`` is OIHW, ``x``/output are NHWC.

    ``matmul`` is pluggable so the same conv path runs with either the
    jnp oracle or the Pallas systolic kernel.
    """
    n, h, hh, c = x.shape
    cout, cin, k, _ = w.shape
    assert c == cin
    cols = im2col(x, k, stride, pad)  # (N*Ho*Wo, k*k*cin)
    # Weight matrix rows must match the (ky, kx, c) patch order.
    wmat = jnp.transpose(w, (2, 3, 1, 0)).reshape(k * k * cin, cout)
    ho = (h + 2 * pad - k) // stride + 1
    wo = (hh + 2 * pad - k) // stride + 1
    y = matmul(cols, wmat)
    return y.reshape(n, ho, wo, cout)
