"""Layer-2: quantization-aware CNN models (LeNet-5, ResNet-20,
ResNet-50-lite) built on the Layer-1 kernels.

The models are described by a *spec*: a flat op list (convs, pools, fcs,
residual save/add) with every shape resolved at spec-build time.  The
same spec is serialized into ``manifest.json`` by ``aot.py`` and parsed
by the Rust ``model`` module, so the two engines are built from a single
source of truth.

QAT scheme (mirrored exactly by ``rust/src/quant``):
  * weights: symmetric int8, per-layer scale ``s_w = max|w*mask| / 127``
    recomputed from the float shadow weights every step;
  * activations: symmetric int8 with per-quant-point scales passed in
    (computed by a calibration pass), gated by a global ``quant_on``;
  * weight restriction (S4.2): integer codes projected onto the layer's
    candidate set (nearest remaining code), gated per layer;
  * pruning: elementwise masks on conv weights;
  * straight-through estimator for all quantization ops.

Training uses the jnp reference kernels (fast under CPU PJRT); the
eval/logits artifacts for LeNet-5 and the standalone tile artifact use
the Pallas systolic kernel — pytest asserts both paths agree.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.quantize import fake_quant, project_codes
from .kernels.systolic_matmul import matmul_systolic

QMAX = 127
KSET = 32
SET_SENTINEL = ref.SET_SENTINEL
MOMENTUM = 0.9

# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


class SpecBuilder:
    """Builds the op list while tracking activation shape and allocating
    parameter / conv / quant-point indices."""

    def __init__(self, name: str, n_classes: int):
        self.spec: Dict[str, Any] = {
            "name": name,
            "n_classes": n_classes,
            "input": [32, 32, 3],
            "ops": [],
            "params": [],
        }
        self.h, self.w, self.c = 32, 32, 3
        self.flat = None  # set after flatten/gap
        self.n_conv = 0
        self.n_q = 0
        self.saved: List[Any] = []

    def _param(self, name: str, shape: List[int], kind: str) -> int:
        self.spec["params"].append({"name": name, "shape": shape, "kind": kind})
        return len(self.spec["params"]) - 1

    def conv(self, cout: int, k: int, stride: int = 1, pad: int = 0, relu: bool = True):
        name = f"conv{self.n_conv}"
        wi = self._param(f"{name}.w", [cout, self.c, k, k], "conv_w")
        bi = self._param(f"{name}.b", [cout], "bias")
        ho = (self.h + 2 * pad - k) // stride + 1
        wo = (self.w + 2 * pad - k) // stride + 1
        self.spec["ops"].append(
            {
                "op": "conv",
                "name": name,
                "w": wi,
                "b": bi,
                "conv_idx": self.n_conv,
                "q_idx": self.n_q,
                "cin": self.c,
                "cout": cout,
                "k": k,
                "stride": stride,
                "pad": pad,
                "relu": relu,
                "hin": self.h,
                "win": self.w,
                "hout": ho,
                "wout": wo,
            }
        )
        self.n_conv += 1
        self.n_q += 1
        self.h, self.w, self.c = ho, wo, cout
        return self

    def maxpool2(self):
        self.spec["ops"].append({"op": "maxpool2"})
        self.h //= 2
        self.w //= 2
        return self

    def gap(self):
        self.spec["ops"].append({"op": "gap"})
        self.flat = self.c
        return self

    def flatten(self):
        self.spec["ops"].append({"op": "flatten"})
        self.flat = self.h * self.w * self.c
        return self

    def fc(self, out: int, relu: bool):
        assert self.flat is not None, "fc before flatten/gap"
        idx = sum(1 for o in self.spec["ops"] if o["op"] == "fc")
        name = f"fc{idx}"
        wi = self._param(f"{name}.w", [out, self.flat], "fc_w")
        bi = self._param(f"{name}.b", [out], "bias")
        self.spec["ops"].append(
            {
                "op": "fc",
                "name": name,
                "w": wi,
                "b": bi,
                "q_idx": self.n_q,
                "din": self.flat,
                "dout": out,
                "relu": relu,
            }
        )
        self.n_q += 1
        self.flat = out
        return self

    def save(self):
        self.spec["ops"].append({"op": "save"})
        self.saved.append((self.h, self.w, self.c))
        return self

    def add_saved(self, relu: bool = True, proj_stride: int = 0):
        """Residual add with the saved tensor; ``proj_stride > 0`` inserts a
        1x1 projection conv (its own mask / wset / quant point) on the skip."""
        sh, sw, sc = self.saved.pop()
        entry: Dict[str, Any] = {"op": "add_saved", "relu": relu, "proj": None}
        if proj_stride > 0:
            name = f"conv{self.n_conv}"
            wi = self._param(f"{name}.w", [self.c, sc, 1, 1], "conv_w")
            bi = self._param(f"{name}.b", [self.c], "bias")
            entry["proj"] = {
                "name": name,
                "w": wi,
                "b": bi,
                "conv_idx": self.n_conv,
                "q_idx": self.n_q,
                "cin": sc,
                "cout": self.c,
                "k": 1,
                "stride": proj_stride,
                "pad": 0,
                "relu": False,
                "hin": sh,
                "win": sw,
                "hout": self.h,
                "wout": self.w,
            }
            self.n_conv += 1
            self.n_q += 1
        else:
            assert (sh, sw, sc) == (self.h, self.w, self.c)
        self.spec["ops"].append(entry)
        return self

    def done(self) -> Dict[str, Any]:
        self.spec["n_conv"] = self.n_conv
        self.spec["n_q"] = self.n_q
        self.spec["kset"] = KSET
        return self.spec


def lenet5_spec() -> Dict[str, Any]:
    """LeNet-5 adapted to 32x32x3 inputs (the CIFAR variant of Table 1)."""
    b = SpecBuilder("lenet5", 10)
    b.conv(6, 5, 1, 2, relu=True).maxpool2()
    b.conv(16, 5, 1, 0, relu=True).maxpool2()
    b.flatten()
    b.fc(120, relu=True).fc(84, relu=True).fc(10, relu=False)
    return b.done()


def _basic_block(b: SpecBuilder, cout: int, stride: int):
    proj = stride != 1 or b.c != cout
    b.save()
    b.conv(cout, 3, stride, 1, relu=True)
    b.conv(cout, 3, 1, 1, relu=False)
    b.add_saved(relu=True, proj_stride=stride if proj else 0)


def resnet20_spec() -> Dict[str, Any]:
    """ResNet-20 for CIFAR-10: 3 stages x 3 basic blocks, 16/32/64 ch."""
    b = SpecBuilder("resnet20", 10)
    b.conv(16, 3, 1, 1, relu=True)
    for cout, stride0 in [(16, 1), (32, 2), (64, 2)]:
        for blk in range(3):
            _basic_block(b, cout, stride0 if blk == 0 else 1)
    b.gap()
    b.fc(10, relu=False)
    return b.done()


def _bottleneck(b: SpecBuilder, width: int, stride: int):
    cout = width * 4
    proj = stride != 1 or b.c != cout
    b.save()
    b.conv(width, 1, 1, 0, relu=True)
    b.conv(width, 3, stride, 1, relu=True)
    b.conv(cout, 1, 1, 0, relu=False)
    b.add_saved(relu=True, proj_stride=stride if proj else 0)


def resnet50lite_spec() -> Dict[str, Any]:
    """Bottleneck ResNet scaled for single-core CPU training (DESIGN.md S2
    substitution for ResNet-50 / CIFAR-100): 3 stages x 3 bottlenecks."""
    b = SpecBuilder("resnet50lite", 100)
    b.conv(16, 3, 1, 1, relu=True)
    for width, stride0 in [(16, 1), (32, 2), (64, 2)]:
        for blk in range(3):
            _bottleneck(b, width, stride0 if blk == 0 else 1)
    b.gap()
    b.fc(100, relu=False)
    return b.done()


SPECS = {
    "lenet5": lenet5_spec,
    "resnet20": resnet20_spec,
    "resnet50lite": resnet50lite_spec,
}

# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_params(spec: Dict[str, Any], seed: int) -> List[jax.Array]:
    """He-normal init; residual-branch-final convs scaled down (fixup-lite:
    the quantized mirror has no batch norm, so deep nets need tamed
    residual branches to train)."""
    key = jax.random.PRNGKey(seed)
    ops = spec["ops"]
    last_before_add = set()
    for i, op in enumerate(ops):
        if op["op"] == "add_saved":
            for j in range(i - 1, -1, -1):
                if ops[j]["op"] == "conv":
                    last_before_add.add(ops[j]["w"])
                    break
    params: List[jax.Array] = []
    for i, p in enumerate(spec["params"]):
        key, sub = jax.random.split(key)
        shape = tuple(p["shape"])
        if p["kind"] == "conv_w":
            fan_in = shape[1] * shape[2] * shape[3]
            scale = jnp.sqrt(2.0 / fan_in)
            if i in last_before_add:
                scale = scale * 0.2
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
        elif p["kind"] == "fc_w":
            params.append(
                jnp.sqrt(2.0 / shape[1]) * jax.random.normal(sub, shape, jnp.float32)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Forward pass with QAT
# ---------------------------------------------------------------------------


def _ste(x: jax.Array, qx: jax.Array) -> jax.Array:
    return x + jax.lax.stop_gradient(qx - x)


def _weight_scale(w_eff: jax.Array) -> jax.Array:
    s = jnp.max(jnp.abs(w_eff)) / QMAX
    return jax.lax.stop_gradient(jnp.maximum(s, 1e-12))


def _quant_weight(w, mask, wset_row, wset_on_l, use_pallas):
    """mask -> scale -> int8 codes -> (optional) candidate-set projection."""
    w_eff = w * mask if mask is not None else w
    s = _weight_scale(w_eff)
    q = jnp.clip(jnp.round(w_eff / s), -QMAX, QMAX)
    if wset_row is not None:
        proj = project_codes if use_pallas else ref.project_codes_ref
        qp = proj(q, wset_row)
        q = wset_on_l * qp + (1.0 - wset_on_l) * q
    return _ste(w_eff, q * s), s


def _quant_act(x, s_a, quant_on, use_pallas):
    fq = fake_quant if use_pallas else ref.fake_quant_ref
    xq = fq(x, s_a)
    return x + quant_on * jax.lax.stop_gradient(xq - x)


def _apply_conv(op, x, params, qc, stats):
    w = params[op["w"]]
    bvec = params[op["b"]]
    ci = op["conv_idx"]
    mask = qc["masks"][ci] if qc["masks"] is not None else None
    wrow = qc["wsets"][ci] if qc["wsets"] is not None else None
    won = qc["wset_on"][ci] if qc["wsets"] is not None else None
    use_pallas = qc["use_pallas"]
    stats.append(jnp.max(jnp.abs(x)))
    xq = _quant_act(x, qc["act_scales"][op["q_idx"]], qc["quant_on"], use_pallas)
    wq, _ = _quant_weight(w, mask, wrow, won, use_pallas)
    if use_pallas:
        # The systolic-tile schedule: im2col + 64x64 Pallas matmul (S3.2).
        y = ref.conv2d_ref(xq, wq, op["stride"], op["pad"], matmul=matmul_systolic)
    else:
        # Training path: identical math via XLA's fused convolution
        # (~4x faster than im2col+dot on the CPU plugin; equivalence is
        # pinned by pytest).
        y = jax.lax.conv_general_dilated(
            xq,
            wq,
            (op["stride"], op["stride"]),
            [(op["pad"], op["pad"])] * 2,
            dimension_numbers=("NHWC", "OIHW", "NHWC"),
        )
    y = y + bvec
    if op.get("relu"):
        y = jax.nn.relu(y)
    return y


def forward(spec, params, x, qc):
    """Run the network.  ``qc`` (quant config) keys:

    ``act_scales`` f32[n_q]; ``quant_on`` f32 scalar; ``masks`` list of
    conv-shaped arrays or None; ``wsets`` list of f32[KSET] code rows
    (invalid slots = SET_SENTINEL) or None; ``wset_on`` f32[n_conv];
    ``use_pallas`` static bool.

    Returns (logits, act_maxes): one max-|activation| per quant point, in
    q_idx order (traversal order == q_idx order by construction).
    """
    stats: List[jax.Array] = []
    saved: List[jax.Array] = []
    h = x
    for op in spec["ops"]:
        kind = op["op"]
        if kind == "conv":
            h = _apply_conv(op, h, params, qc, stats)
        elif kind == "maxpool2":
            n, hh, ww, c = h.shape
            h = h.reshape(n, hh // 2, 2, ww // 2, 2, c).max(axis=(2, 4))
        elif kind == "gap":
            h = h.mean(axis=(1, 2))
        elif kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif kind == "save":
            saved.append(h)
        elif kind == "add_saved":
            skip = saved.pop()
            if op["proj"] is not None:
                skip = _apply_conv(op["proj"], skip, params, qc, stats)
            h = h + skip
            if op.get("relu"):
                h = jax.nn.relu(h)
        elif kind == "fc":
            w = params[op["w"]]
            bvec = params[op["b"]]
            stats.append(jnp.max(jnp.abs(h)))
            hq = _quant_act(
                h, qc["act_scales"][op["q_idx"]], qc["quant_on"], qc["use_pallas"]
            )
            wq, _ = _quant_weight(w, None, None, None, qc["use_pallas"])
            mm = matmul_systolic if qc["use_pallas"] else ref.matmul_ref
            h = mm(hq, wq.T) + bvec
            if op.get("relu"):
                h = jax.nn.relu(h)
        else:  # pragma: no cover - specs are internally generated
            raise ValueError(f"unknown op {kind}")
    return h, jnp.stack(stats)


# ---------------------------------------------------------------------------
# Entry points lowered by aot.py
# ---------------------------------------------------------------------------


def make_qc(masks, wsets, wset_on, act_scales, quant_on, use_pallas):
    return {
        "masks": masks,
        "wsets": wsets,
        "wset_on": wset_on,
        "act_scales": act_scales,
        "quant_on": quant_on,
        "use_pallas": use_pallas,
    }


def _loss_fn(spec, params, x, y, qc):
    logits, _ = forward(spec, params, x, qc)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def train_step(spec, params, mom, masks, wsets, wset_on, act_scales, quant_on, lr, x, y):
    """One SGD+momentum QAT step.  Returns (params', mom', loss)."""
    qc = make_qc(masks, wsets, wset_on, act_scales, quant_on, False)
    loss, grads = jax.value_and_grad(lambda p: _loss_fn(spec, p, x, y, qc))(params)
    new_mom = [MOMENTUM * m + g for m, g in zip(mom, grads)]
    new_params = [p - lr * m for p, m in zip(params, new_mom)]
    return new_params, new_mom, loss


def eval_batch(spec, params, masks, wsets, wset_on, act_scales, quant_on, x, y, use_pallas):
    """Returns (n_correct as f32 scalar, mean loss)."""
    qc = make_qc(masks, wsets, wset_on, act_scales, quant_on, use_pallas)
    logits, _ = forward(spec, params, x, qc)
    pred = jnp.argmax(logits, axis=1)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return jnp.sum((pred == y).astype(jnp.float32)), nll


def logits_batch(spec, params, masks, wsets, wset_on, act_scales, quant_on, x, use_pallas):
    qc = make_qc(masks, wsets, wset_on, act_scales, quant_on, use_pallas)
    logits, _ = forward(spec, params, x, qc)
    return logits


def calib_batch(spec, params, x):
    """Float forward (quant off) returning per-quant-point max |activation|.

    The mean |logit| is returned too — not for calibration, but to keep
    the final classifier parameters live in the lowered HLO (XLA drops
    unused entry parameters, which would change the input arity the Rust
    runtime feeds).
    """
    qc = make_qc(
        None,
        None,
        jnp.ones((spec["n_conv"],), jnp.float32),
        jnp.zeros((spec["n_q"],), jnp.float32),
        jnp.float32(0.0),
        False,
    )
    logits, act_maxes = forward(spec, params, x, qc)
    return act_maxes, jnp.mean(jnp.abs(logits))
