"""AOT lowering driver: JAX entry points -> HLO *text* artifacts.

Run once via ``make artifacts``; Python is never on the request path.
For every model we emit:

  artifacts/<model>/train.hlo.txt    SGD+momentum QAT step   (batch 64)
  artifacts/<model>/eval.hlo.txt     n_correct + loss        (batch 128)
  artifacts/<model>/logits.hlo.txt   logits cross-check      (batch 8)
  artifacts/<model>/calib.hlo.txt    activation-range calib  (batch 64)
  artifacts/<model>/params.bin       initial parameters (f32 LE, concat)
  artifacts/<model>/manifest.json    spec + entry-point I/O layout

plus ``artifacts/tile_matmul.hlo.txt`` — the standalone Pallas
systolic-tile kernel the Rust `systolic` module cross-checks against.

HLO **text** (not ``HloModuleProto.serialize``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.systolic_matmul import tile_matmul_entry

BATCH_TRAIN = 32
BATCH_EVAL = 128
BATCH_LOGITS = 8
BATCH_CALIB = 64
#: Models whose *logits* artifact routes the matmul hot-spot through the
#: Pallas systolic kernel (the eval graph always uses the jnp reference
#: path: interpreted Pallas costs ~50 s of XLA-CPU compile time plus a
#: ~50x execution penalty, and eval sits in the §4 selection loop).  The
#: kernel's numerics are pinned three ways: pytest vs ref.py, the logits
#: artifact vs the Rust mirror engine, and the standalone tile artifact
#: vs the cycle-level systolic simulation.
PALLAS_LOGITS_MODELS = ("lenet5",)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _mask_specs(spec) -> List[jax.ShapeDtypeStruct]:
    return [
        _sds(p["shape"])
        for p in spec["params"]
        if p["kind"] == "conv_w"
    ]


def _wset_specs(spec) -> List[jax.ShapeDtypeStruct]:
    return [_sds([M.KSET]) for _ in range(spec["n_conv"])]


def build_entry_fns(spec) -> Dict[str, Any]:
    """Wrap model entry points as flat-positional functions of arrays,
    with matching example-argument spec lists, ready for jit().lower()."""
    n_p = len(spec["params"])
    n_c = spec["n_conv"]
    n_q = spec["n_q"]
    ncls = spec["n_classes"]
    p_specs = [_sds(p["shape"]) for p in spec["params"]]
    m_specs = _mask_specs(spec)
    w_specs = _wset_specs(spec)

    def unpack_common(args, i):
        params = list(args[i : i + n_p]); i += n_p
        masks = list(args[i : i + n_c]); i += n_c
        wsets = list(args[i : i + n_c]); i += n_c
        wset_on = args[i]; i += 1
        act_scales = args[i]; i += 1
        quant_on = args[i]; i += 1
        return params, masks, wsets, wset_on, act_scales, quant_on, i

    def train_fn(*args):
        i = 0
        params = list(args[i : i + n_p]); i += n_p
        mom = list(args[i : i + n_p]); i += n_p
        masks = list(args[i : i + n_c]); i += n_c
        wsets = list(args[i : i + n_c]); i += n_c
        wset_on = args[i]; i += 1
        act_scales = args[i]; i += 1
        quant_on = args[i]; i += 1
        lr = args[i]; i += 1
        x = args[i]; i += 1
        y = args[i]; i += 1
        assert i == len(args)
        p2, m2, loss = M.train_step(
            spec, params, mom, masks, wsets, wset_on, act_scales, quant_on, lr, x, y
        )
        return tuple(p2) + tuple(m2) + (loss,)

    use_pallas = spec["name"] in PALLAS_LOGITS_MODELS

    def eval_fn(*args):
        params, masks, wsets, wset_on, act_scales, quant_on, i = unpack_common(args, 0)
        x = args[i]; y = args[i + 1]
        assert i + 2 == len(args)
        return M.eval_batch(
            spec, params, masks, wsets, wset_on, act_scales, quant_on, x, y, False
        )

    def logits_fn(*args):
        params, masks, wsets, wset_on, act_scales, quant_on, i = unpack_common(args, 0)
        x = args[i]
        assert i + 1 == len(args)
        return (
            M.logits_batch(
                spec, params, masks, wsets, wset_on, act_scales, quant_on, x, use_pallas
            ),
        )

    def calib_fn(*args):
        params = list(args[:n_p])
        x = args[n_p]
        assert n_p + 1 == len(args)
        return M.calib_batch(spec, params, x)

    scalar = _sds([])
    common = (
        p_specs
        + m_specs
        + w_specs
        + [_sds([n_c]), _sds([n_q]), scalar]
    )
    img = lambda b: _sds([b, 32, 32, 3])
    lbl = lambda b: _sds([b], jnp.int32)
    return {
        "train": (
            train_fn,
            p_specs + p_specs + m_specs + w_specs
            + [_sds([n_c]), _sds([n_q]), scalar, scalar, img(BATCH_TRAIN), lbl(BATCH_TRAIN)],
        ),
        "eval": (eval_fn, common + [img(BATCH_EVAL), lbl(BATCH_EVAL)]),
        "logits": (logits_fn, common + [img(BATCH_LOGITS)]),
        "calib": (calib_fn, p_specs + [img(BATCH_CALIB)]),
    }


def lower_model(name: str, out_dir: str, seed: int) -> None:
    spec = M.SPECS[name]()
    model_dir = os.path.join(out_dir, name)
    os.makedirs(model_dir, exist_ok=True)

    params = M.init_params(spec, seed)
    blob = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
    blob.astype("<f4").tofile(os.path.join(model_dir, "params.bin"))

    entries = build_entry_fns(spec)
    entry_meta = {}
    for ename, (fn, arg_specs) in entries.items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{ename}.hlo.txt"
        with open(os.path.join(model_dir, fname), "w") as f:
            f.write(text)
        entry_meta[ename] = {
            "file": fname,
            "n_inputs": len(arg_specs),
            "input_shapes": [list(s.shape) for s in arg_specs],
            "input_dtypes": [str(s.dtype) for s in arg_specs],
        }
        print(f"  {name}/{fname}: {len(text)} chars, {len(arg_specs)} inputs")

    manifest = {
        "model": spec["name"],
        "n_classes": spec["n_classes"],
        "input": spec["input"],
        "ops": spec["ops"],
        "params": spec["params"],
        "n_conv": spec["n_conv"],
        "n_q": spec["n_q"],
        "kset": M.KSET,
        "qmax": M.QMAX,
        "set_sentinel": M.SET_SENTINEL,
        "momentum": M.MOMENTUM,
        "seed": seed,
        "batches": {
            "train": BATCH_TRAIN,
            "eval": BATCH_EVAL,
            "logits": BATCH_LOGITS,
            "calib": BATCH_CALIB,
        },
        "pallas_eval": spec["name"] in PALLAS_LOGITS_MODELS,
        "entries": entry_meta,
    }
    with open(os.path.join(model_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def lower_tile(out_dir: str) -> None:
    """Standalone systolic-tile kernel artifact: (128,192) @ (192,128),
    i.e. a 2x2x3 grid of 64x64 weight-stationary tile passes."""
    specs = (_sds([128, 192]), _sds([192, 128]))
    lowered = jax.jit(tile_matmul_entry).lower(*specs)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "tile_matmul.hlo.txt"), "w") as f:
        f.write(text)
    print(f"  tile_matmul.hlo.txt: {len(text)} chars")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models", default="lenet5,resnet20,resnet50lite", help="comma-separated"
    )
    ap.add_argument("--seed", type=int, default=20250710)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    lower_tile(args.out_dir)
    for name in args.models.split(","):
        print(f"lowering {name} ...")
        lower_model(name.strip(), args.out_dir, args.seed)
    print("AOT artifacts complete.")


if __name__ == "__main__":
    main()
