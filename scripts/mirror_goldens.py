#!/usr/bin/env python3
"""Independent mirror of the core energy-model arithmetic, used to
generate the committed golden snapshots under rust/tests/golden/.

The Rust test `golden_model.rs` computes the same quantities through the
production code path; this script re-derives them from the paper's
formulas with plain IEEE-754 doubles (Python floats), mirroring the
exact operation order of the Rust implementation.  The fixtures are
dyadic/integer-valued so both sides agree bit-for-bit.

Normally the snapshots are (re)blessed from the Rust side with
`WSEL_BLESS=1 cargo test -q --test golden_model`; this mirror exists so
the initial snapshots are *independent* of the implementation they pin,
and stays useful as a cross-check.
"""

import json
import os

SCALE = 2.0 ** -50
E_IDLE = SCALE / 2.0
GATED_IDLE_FRACTION = 0.15
TILE = 64
CYCLES_PER_PASS = 128
ACC_BITS = 22
MSB_BINS, HW_BINS = 10, 5

LAYERS = [(0, 256, 75, 6), (1, 196, 150, 16), (2, 64, 400, 32)]
SET_A = [-127, -64, -32, -16, -8, 0, 8, 16, 32, 64, 127]
SET_B = [-81, -27, -9, -3, 0, 3, 9, 27, 81]


def table(i):
    """e_per_cycle[i] = (1 + |code|) * 2^-50, code = i - 128."""
    return (1.0 + float(abs(i - 128))) * SCALE


def usage(layer_idx):
    u = [0] * 256
    for c in range(-127, 128):
        pos = 1 if c > 0 else 0
        u[c + 128] = (3 * abs(c) + pos + 5 * layer_idx) % 17
    return u


def project(codes, q):
    """Nearest member; ties resolve to the smaller member."""
    return min(codes, key=lambda c: (abs(q - c), c))


def projected_usage(u, codes):
    out = [0] * 256
    for i in range(256):
        cnt = u[i]
        if cnt == 0:
            continue
        code = i - 128
        code = max(-127, min(127, code))
        out[project(codes, code) + 128] += cnt
    return out


def energy_of_usage(m, k, n, u):
    cycles = float(-(-m // TILE) * CYCLES_PER_PASS)
    e = 0.0
    occupied = 0
    for i in range(256):
        cnt = u[i]
        if cnt == 0:
            continue
        occupied += cnt
        e += float(cnt) * table(i) * cycles
    k_pad = -(-k // TILE) * TILE
    n_pad = -(-n // TILE) * TILE
    padded = k_pad * n_pad - occupied
    return e + float(padded) * E_IDLE * GATED_IDLE_FRACTION * cycles


def network(per_layer):
    total = 0.0
    for _, e in per_layer:
        total += e
    return {"layers": [[i, e] for i, e in per_layer], "total": total}


def group_of(v):
    msb = v.bit_length()
    msb_bin = (msb * MSB_BINS) // (ACC_BITS + 1)
    hw = bin(v).count("1")
    hw_bin = (hw * HW_BINS) // (ACC_BITS + 1)
    return msb_bin * HW_BINS + hw_bin


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "golden")
    os.makedirs(out_dir, exist_ok=True)

    dense, set_a, set_b = [], [], []
    for idx, (ci, m, k, n) in enumerate(LAYERS):
        u = usage(idx)
        dense.append((ci, energy_of_usage(m, k, n, u)))
        set_a.append((ci, energy_of_usage(m, k, n, projected_usage(u, SET_A))))
        set_b.append((ci, energy_of_usage(m, k, n, projected_usage(u, SET_B))))

    def total(net):
        return net["total"]

    nd, na, nb = network(dense), network(set_a), network(set_b)
    model = {
        "dense": nd,
        "setA": na,
        "setB": nb,
        "saving_setA": 1.0 - total(na) / total(nd),
        "saving_setB": 1.0 - total(nb) / total(nd),
    }
    with open(os.path.join(out_dir, "network_energy_model.json"), "w") as f:
        json.dump(model, f)
        f.write("\n")

    proj = projected_usage(usage(1), SET_A)
    with open(os.path.join(out_dir, "projected_usage_setA_layer1.json"), "w") as f:
        json.dump(proj, f)
        f.write("\n")

    pats = [
        0, 1, 2, 3, 5, 255, 4096, 0x155555, 0x2AAAAA,
        1 << 20, 1 << 21, (1 << 21) + 1, (1 << 22) - 1, 0x3FFFFE, 0x200001,
    ]
    with open(os.path.join(out_dir, "transition_groups.json"), "w") as f:
        json.dump([group_of(p) for p in pats], f)
        f.write("\n")

    print("wrote goldens to", os.path.abspath(out_dir))


if __name__ == "__main__":
    main()
