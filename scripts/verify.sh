#!/usr/bin/env bash
# Tier-1 verification in one command (see ROADMAP.md):
#   cargo build --release && cargo test -q, plus fmt/clippy stages.
#
# Usage: scripts/verify.sh [--quick]
#   --quick  skip clippy, and additionally run the exact-vs-model
#            validation smoke check (release mode: the gate-level
#            tile-power engine vs the statistical energy model on a
#            synthetic capture) plus the block-sparse engine property
#            tests (release mode: prune-ratio/thread sweep vs the
#            scalar reference), the SIMD kernel dispatch suite (every
#            available backend vs scalar, bitwise, plus the forced-
#            backend engine/grad end-to-end identity) and the serving
#            smoke (batcher contract tests + `wsel serve-bench --quick`,
#            which self-checks the emitted report: parse + monotone
#            p50/p95/p99 per cell)
#
# Both modes end with a golden-drift gate: if `cargo test` bootstrapped
# or rewrote anything under rust/tests/golden/, verification fails so a
# never-committed golden pin can't silently pass CI.  (WSEL_BLESS=1
# skips the gate — blessing rewrites goldens on purpose.)
# Env:   WSEL_BLESS=1 scripts/verify.sh       # re-bless golden snapshots
#        WSEL_STRICT_FMT=1 scripts/verify.sh  # make fmt drift fatal
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=1 ;;
        *) echo "unknown argument: $arg" >&2; exit 2 ;;
    esac
done

# Preflight: every stage below needs cargo.  Fail loudly up front
# instead of dying stage-by-stage with a confusing "command not found"
# — environments without the toolchain (e.g. bare containers) cannot
# verify at all, and must not mistake a silent no-op for a green run.
if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: no cargo toolchain found on PATH" >&2
    echo "verify: install rustup/cargo (or run inside the rust_pallas toolchain image) and re-run" >&2
    exit 3
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Robustness stage (both modes, --quick included): panic isolation,
# checksummed checkpoint/resume, divergence rollback and corruption
# rejection — release mode so the kill/resume sweep stays fast.
echo "== fault-tolerance tests (robustness stage) =="
cargo test --release -q --test fault_tolerance

# Schedule-search stage (both modes): journal resume × trial budget ×
# min_share kill-anywhere sweeps for the legacy and successive-halving
# searches, plus the warm accuracy-cache zero-fine-tune contract —
# release mode for the same reason.
echo "== schedule-search tests (resume/halving/cache stage) =="
cargo test --release -q --test schedule_search

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        if [ "${WSEL_STRICT_FMT:-0}" = "1" ]; then
            echo "fmt drift (WSEL_STRICT_FMT=1): failing" >&2
            exit 1
        fi
        echo "fmt drift detected (advisory; set WSEL_STRICT_FMT=1 to gate)"
    fi
else
    echo "rustfmt not installed; skipping (soft-fail)"
fi

if [ "$QUICK" -eq 1 ]; then
    echo "== exact-vs-model validation smoke (--quick) =="
    cargo test --release -q --test exact_power quick_exact_vs_model
    echo "== block-sparse engine property tests (--quick) =="
    cargo test --release -q --test engine_parallel
    echo "== SIMD kernel dispatch property tests (--quick) =="
    # Dispatched-vs-scalar bit-equality sweeps plus the forced-backend
    # end-to-end engine/grad identity at several thread counts; release
    # mode so the SIMD paths run at their real codegen.
    cargo test --release -q --test kernels_simd
    echo "== serving smoke (--quick): registry + micro-batcher under load =="
    # Batcher determinism / hot-swap / error-path contract tests, then a
    # tiny sustained-load grid through the real CLI.  serve-bench writes
    # the report and re-loads it through validate_report (parse + p99 >=
    # p95 >= p50 per cell), so a torn or non-monotone report fails here.
    cargo test --release -q --test serving
    SERVE_OUT="$(mktemp -t wsel_serving_XXXX.json)"
    trap 'rm -f "$SERVE_OUT"' EXIT
    cargo run --release -q -- serve-bench --quick --out "$SERVE_OUT"
    echo "== cargo clippy skipped (--quick) =="
else
    echo "== cargo clippy -D warnings (soft-fail if unavailable) =="
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets -- -D warnings
    else
        echo "clippy not installed; skipping (soft-fail)"
    fi
fi

echo "== golden drift gate =="
if [ "${WSEL_BLESS:-0}" = "1" ]; then
    echo "WSEL_BLESS=1: golden drift gate skipped (re-blessing)"
else
    DRIFT="$(git status --porcelain -- rust/tests/golden)"
    if [ -n "$DRIFT" ]; then
        echo "golden files drifted or were bootstrapped but never committed:" >&2
        echo "$DRIFT" >&2
        echo "commit the new/updated goldens (or investigate the regression)" >&2
        exit 1
    fi
    echo "golden files clean"
fi

echo "verify: OK"
