#!/usr/bin/env bash
# Tier-1 verification in one command (see ROADMAP.md):
#   cargo build --release && cargo test -q, plus clippy when available.
#
# Usage: scripts/verify.sh [--quick]
#   --quick  additionally run the exact-vs-model validation smoke check
#            (release mode: the gate-level tile-power engine vs the
#            statistical energy model on a synthetic capture)
# Env:   WSEL_BLESS=1 scripts/verify.sh   # re-bless golden snapshots
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=1 ;;
        *) echo "unknown argument: $arg" >&2; exit 2 ;;
    esac
done

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "$QUICK" -eq 1 ]; then
    echo "== exact-vs-model validation smoke (--quick) =="
    cargo test --release -q --test exact_power quick_exact_vs_model
fi

echo "== cargo clippy (soft-fail if unavailable) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed; skipping (soft-fail)"
fi

echo "verify: OK"
