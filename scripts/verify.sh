#!/usr/bin/env bash
# Tier-1 verification in one command (see ROADMAP.md):
#   cargo build --release && cargo test -q, plus clippy when available.
#
# Usage: scripts/verify.sh
# Env:   WSEL_BLESS=1 scripts/verify.sh   # re-bless golden snapshots
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy (soft-fail if unavailable) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed; skipping (soft-fail)"
fi

echo "verify: OK"
