//! Minimal offline shim of the `anyhow` API surface this workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros and the
//! [`Context`] extension trait on `Result` and `Option`.
//!
//! Semantics match the real crate for everything we rely on: `?`
//! converts any `std::error::Error + Send + Sync + 'static` into
//! [`Error`], context strings prepend to the message, and the source
//! error is preserved for `{:?}` chains.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically-typed error with a human-readable message chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap with additional context (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error {
            msg: format!("{c}: {}", self.msg),
            source: self.source,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().map(|s| s as &dyn StdError);
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`
// (same as the real anyhow) so the blanket `From` below is coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::Error::msg(format!($($t)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("boom"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file:"));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_and_debug_chain() {
        let e: Error = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let chained = Error::from(io_err()).context("outer");
        let dbg = format!("{chained:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"));
        fn bails() -> Result<()> {
            bail!("no {}", "good");
        }
        assert!(bails().is_err());
    }
}
