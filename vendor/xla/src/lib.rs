//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The build image for this repo has no `xla_extension` native library,
//! so this crate provides the exact API surface `wsel::runtime` consumes
//! but reports the backend as unavailable at runtime.  Everything that
//! needs PJRT (artifact-gated tests, examples, the training CLI paths)
//! already skips gracefully when `artifacts/` is absent, and
//! `PjRtClient::cpu()` returning an error makes the failure mode
//! explicit if someone does point it at artifacts.

use std::path::Path;

/// Error type; formatted with `{:?}` by callers.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT/XLA native runtime not available in this build \
         (offline stub; install xla_extension and swap the vendored `xla` crate)"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for u8 {}
impl NativeType for i32 {}
impl NativeType for u32 {}
impl NativeType for i64 {}
impl NativeType for u64 {}

/// Host-side tensor value (stub: shape/data are not retained).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation built from an HLO proto (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: construction fails, making the missing native
/// backend explicit at the first point of use).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
