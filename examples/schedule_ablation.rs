//! Ablation driver (Table 3 / Table 4 logic at example scale): on a
//! trained LeNet-5, compare
//!   1. energy-prioritized layer-wise compression (ours),
//!   2. global/uniform compression at matched (ratio, K),
//!   3. naive lowest-energy-K selection,
//! reporting accuracy and energy saving for each.
//!
//!     cargo run --release --example schedule_ablation -- [--quick]

use anyhow::Result;
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::report::{pct, Table};
use wsel::schedule::{global_uniform, Config, ScheduleParams};
use wsel::selection::{naive_lowest_energy, CompressionState, LayerConfig};
use wsel::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &[]);
    let quick = args.flag("quick");
    let artifacts = std::path::Path::new("artifacts");
    let pp = if quick {
        PipelineParams::quick()
    } else {
        PipelineParams {
            float_steps: 2400,
            qat_steps: 800,
            ..Default::default()
        }
    };
    let ft = if quick { 10 } else { 60 };

    let mut p = Pipeline::new(artifacts, "lenet5", pp)?;
    let acc0 = p.train_baseline()?;
    p.profile()?;
    let base = p.base_energy.clone().unwrap();
    let trained = p.checkpoint();
    let n_conv = p.rt.spec.n_conv;

    let mut t = Table::new(
        "Schedule / selection ablation (LeNet-5)",
        &["method", "accuracy", "energy saving"],
    );
    t.row(&["origin (quantized)".into(), pct(acc0), "-".into()]);

    // 1. Ours: layer-wise energy-prioritized.
    let sp = ScheduleParams {
        fine_tune_steps: ft,
        ..Default::default()
    };
    let ours = p.compress(sp)?;
    let ours_e = p.compute_network_energy(&ours.state);
    t.row(&[
        "layer-wise (ours)".into(),
        pct(ours.final_accuracy),
        pct(base.saving_vs(&ours_e)),
    ]);

    // 2. Global uniform at matched aggressiveness (0.5, 16).
    p.restore(trained.clone());
    let layers: Vec<usize> = (0..n_conv).collect();
    let glob = global_uniform(
        &mut p,
        n_conv,
        &layers,
        Config {
            prune_ratio: 0.5,
            k_target: 16,
        },
        ft,
        false,
    );
    let glob_e = p.compute_network_energy(&glob.state);
    t.row(&[
        "global uniform (0.5, 16)".into(),
        pct(glob.final_accuracy),
        pct(base.saving_vs(&glob_e)),
    ]);

    // 3. Naive lowest-energy 16 codes everywhere.
    p.restore(trained);
    let le0 = p.layer_energy_model(0);
    let naive = naive_lowest_energy(&le0.table, 16);
    let naive_state = CompressionState {
        layers: (0..n_conv)
            .map(|_| LayerConfig {
                prune_ratio: 0.5,
                wset: Some(naive.clone()),
            })
            .collect(),
    };
    let (nacc, nsave) = p.evaluate_state(&naive_state, ft)?;
    t.row(&["naive top-16 energy".into(), pct(nacc), pct(nsave)]);

    println!("{}", t.render());
    println!(
        "expected shape (paper Tables 3-4): ours >= global accuracy at matched saving;\n\
         naive top-16 collapses accuracy despite competitive savings."
    );
    Ok(())
}
