//! END-TO-END DRIVER (the EXPERIMENTS.md headline run): train LeNet-5
//! with QAT on the synthetic-CIFAR-10 workload, profile every conv layer
//! through the gate-level MAC + systolic energy model, run the paper's
//! energy-prioritized layer-wise compression with co-optimized weight
//! selection, and report the Table-1 row (accuracy / energy saving /
//! selected weights) for the origin, PowerPruning-baseline, and Ours.
//!
//!     cargo run --release --example compress_lenet -- [--steps N] [--quick]
//!
//! Proves the full stack composes: L1 Pallas kernel numerics (validated
//! in the artifacts), L2 train/eval graphs — AOT-PJRT when artifacts
//! exist, the native batch-parallel backend otherwise, so the whole
//! Table-1 flow runs offline — L3 coordinator with gate-level energy
//! substrates.

use anyhow::Result;
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::report::{pct, Table};
use wsel::schedule::ScheduleParams;
use wsel::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["steps"]);
    let artifacts = std::path::Path::new("artifacts");
    let quick = args.flag("quick");

    let mut pp = if quick {
        PipelineParams::quick()
    } else {
        PipelineParams {
            float_steps: args.usize_or("steps", 2400),
            qat_steps: 800,
            ..Default::default()
        }
    };
    pp.val_batches = if quick { 1 } else { 4 };

    // ---- Ours: full pipeline -------------------------------------------
    let mut p = Pipeline::new(artifacts, "lenet5", pp.clone())?;
    println!("backend: {}", p.rt.backend_name());
    let acc0 = p.train_baseline()?;
    p.profile()?;
    let trained = p.checkpoint();

    let sp = ScheduleParams {
        fine_tune_steps: if quick { 10 } else { 80 },
        delta: 0.03,
        ..Default::default()
    };
    let res = p.compress(sp)?;
    let base = p.base_energy.clone().unwrap();
    let ours_energy = p.compute_network_energy(&res.state);
    let ours_saving = base.saving_vs(&ours_energy);
    let ours_k = res
        .state
        .layers
        .iter()
        .filter_map(|l| l.wset.as_ref().map(|s| s.len()))
        .max()
        .unwrap_or(256);

    // ---- PowerPruning baseline (global model, 32 weights, uniform) -----
    p.restore(trained.clone());
    let glob = wsel::energy::uniform_weight_energy(
        &mut p.maclib,
        &p.cap_model,
        p.pp.trace_len,
        p.pp.seed,
        p.pp.threads,
    );
    let pp_state =
        wsel::selection::powerpruning::powerpruning_state(p.rt.spec.n_conv, &glob, 32, 0.5);
    let (pp_acc, pp_saving) = p.evaluate_state(&pp_state, if quick { 10 } else { 80 })?;

    // ---- Table 1 row ----------------------------------------------------
    let mut t = Table::new(
        "Table 1 (LeNet-5 / synthetic-CIFAR-10)",
        &["method", "accuracy", "energy saving", "selected weights"],
    );
    t.row(&["origin".into(), pct(acc0), "-".into(), "256".into()]);
    t.row(&[
        "PowerPruning [15]".into(),
        pct(pp_acc),
        pct(pp_saving),
        "32".into(),
    ]);
    t.row(&[
        "Ours".into(),
        pct(res.final_accuracy),
        pct(ours_saving),
        ours_k.to_string(),
    ]);
    println!("{}", t.render());
    println!(
        "paper reference: origin 78.9% / PP 78.4%, 46.0%, 32 / Ours 77.8%, 53.3%, 16"
    );
    println!(
        "(cost: {} oracle evals, {} fine-tune steps)",
        p.eval_count, p.ft_steps_total
    );
    Ok(())
}
