//! Energy-model deep dive (Figures 1–3 in terminal form):
//!
//!  * per-weight MAC power under uniform vs layer-specific statistics;
//!  * power vs transition Hamming distance, and the MSB-pair structure
//!    that justifies the 10×5 grouping (§3.1.1);
//!  * activation transition heatmaps for the first two LeNet-5 convs
//!    (§3.1.2), showing why per-layer statistics matter;
//!  * the grouping stability ratio of the adopted uniform partition
//!    against the MSB-only / HW-only ablations.
//!
//!     cargo run --release --example energy_profile

use anyhow::Result;
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::energy::{transition_energy, uniform_weight_energy};
use wsel::gates::CapModel;
use wsel::report;
use wsel::systolic::MacLib;
use wsel::transitions::{stability_ratio, Grouping};
use wsel::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let cap = CapModel::default();
    let mut lib = MacLib::new();

    // ---- Fig. 1: average MAC power per weight value --------------------
    let table = uniform_weight_energy(&mut lib, &cap, 256, 99, 1);
    let picks: Vec<i32> = vec![-127, -96, -64, -32, -8, -1, 0, 1, 8, 32, 64, 96, 127];
    let labels: Vec<String> = picks.iter().map(|w| format!("w={w:>4}")).collect();
    let powers: Vec<f64> = picks
        .iter()
        .map(|&w| table.energy(w as i8) * cap.freq_hz)
        .collect();
    println!(
        "{}",
        report::bar_chart("Fig.1 — avg MAC power (W) per weight value", &labels, &powers, 48)
    );

    // ---- Fig. 2a: power vs Hamming distance of psum transition ---------
    let base = 0b01_0101_0101_0101_0101_0101u32 as i32;
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for hd in [0usize, 1, 2, 4, 8, 12, 16, 20] {
        let flip: u32 = (0..hd).map(|i| 1u32 << i).sum();
        let e = transition_energy(&mut lib, &cap, 37, 11, base, base ^ flip as i32, 128);
        xs.push(hd as f64);
        ys.push(e * cap.freq_hz);
    }
    println!("{}", report::series("Fig.2a — MAC power (W) vs psum transition HD", &xs, &ys));

    // ---- Fig. 2b: MSB-pair transition power (diagonal is cool) ---------
    let bins = 8;
    let mut hm = vec![0.0f64; bins * bins];
    for i in 0..bins {
        for j in 0..bins {
            let p1 = 1i32 << (2 + i * 2);
            let p2 = 1i32 << (2 + j * 2);
            hm[i * bins + j] =
                transition_energy(&mut lib, &cap, 37, 11, p1, p2, 64) * cap.freq_hz;
        }
    }
    println!(
        "{}",
        report::heatmap("Fig.2b — power across MSB-position pairs", &hm, bins)
    );

    // ---- Fig. 3: per-layer activation transition heatmaps --------------
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("lenet5/manifest.json").exists() {
        let mut p = Pipeline::new(artifacts, "lenet5", PipelineParams::quick())?;
        p.train_baseline()?;
        p.profile()?;
        for ci in 0..2 {
            let st = &p.stats[ci];
            println!(
                "{}",
                report::heatmap(
                    &format!(
                        "Fig.3 — LeNet-5 conv{} activation transitions (zero-frac {:.2})",
                        ci,
                        st.act.zero_fraction()
                    ),
                    &st.act.heatmap(24),
                    24
                )
            );
        }
    } else {
        eprintln!("(skipping Fig.3 — run `make artifacts` first)");
    }

    // ---- Grouping stability (justifies the 10×5 uniform partition) -----
    let mut rng = Xoshiro256::new(4);
    for grouping in [Grouping::MsbHamming, Grouping::MsbOnly, Grouping::HammingOnly] {
        let mut samples = Vec::new();
        for _ in 0..4000 {
            let v = (rng.next_u64() & 0x3F_FFFF) as u32;
            let flip = 1u32 << rng.below(22);
            let e = transition_energy(
                &mut lib,
                &cap,
                17,
                5,
                v as i32,
                (v ^ flip) as i32,
                16,
            );
            samples.push((grouping.group(v), e));
        }
        println!(
            "stability ratio ({grouping:?}): {:.2}",
            stability_ratio(&samples)
        );
    }
    Ok(())
}
