//! Quickstart: train + evaluate a model, estimate the energy of its
//! first conv layer on the 64×64 systolic array.
//!
//!     cargo run --release --example quickstart
//!
//! Runs fully offline: with AOT artifacts present (`make artifacts`)
//! the training drivers go through PJRT; without them the pure-Rust
//! [`wsel::runtime::native::NativeBackend`] takes over, so the
//! quickstart works in a fresh checkout.  Either way this touches each
//! layer of the stack once: the training/eval runtime, the int8 mirror
//! engine, the gate-level MAC model and the tile-level energy
//! composition.

use anyhow::Result;
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::report::pct;
use wsel::selection::CompressionState;

fn main() -> Result<()> {
    let artifacts = std::path::Path::new("artifacts");

    // 1. Load LeNet-5 (AOT artifacts when built, native otherwise) and
    //    give it a short training run (quick preset).
    let mut p = Pipeline::new(artifacts, "lenet5", PipelineParams::quick())?;
    println!("backend: {}", p.rt.backend_name());
    let acc0 = p.train_baseline()?;
    println!("quantized baseline accuracy: {acc0:.3}");

    // 2. Profile: per-layer stats -> per-weight MAC energy tables.
    p.profile()?;
    let base = p.base_energy.clone().unwrap();
    println!("total conv energy: {:.3e} J/image", base.total());
    for (ci, share) in base.shares() {
        println!("  conv{ci}: share {}", pct(share));
    }

    // 3. Per-weight MAC power spread (the Fig. 1 premise).
    let t = &p.tables[0];
    let f = p.cap_model.freq_hz;
    println!(
        "conv0 MAC power:  w=0 -> {:.2} µW   w=+3 -> {:.2} µW   w=-127 -> {:.2} µW",
        t.energy(0) * f * 1e6,
        t.energy(3) * f * 1e6,
        t.energy(-127) * f * 1e6
    );

    // 4. What would restricting conv0 to 32 values save?
    let state = CompressionState::dense(p.rt.spec.n_conv);
    let usage = {
        use wsel::schedule::LayerModeler;
        p.usage(0, &state)
    };
    let le = p.layer_energy_model(0);
    let set0 = wsel::selection::safe_initial_set(&usage, &le, 32);
    let e_full = le.energy_of_usage(&usage);
    let e_restricted = wsel::selection::set_energy(&le, &usage, &set0);
    println!(
        "conv0: full-range {:.3e} J -> 32-value set {:.3e} J ({} saving)",
        e_full,
        e_restricted,
        pct(1.0 - e_restricted / e_full)
    );
    Ok(())
}
