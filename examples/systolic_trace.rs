//! Cycle-level systolic-array walkthrough: take one real tile of a
//! LeNet-5 conv layer, run it through (a) the functional tile simulation,
//! (b) the exact gate-level power mode, and (c) the statistical energy
//! model — and show that (a) reproduces the matmul and (c) approximates
//! (b).  This is the validation loop behind §3.2's tile-based model.
//!
//!     cargo run --release --example systolic_trace

use anyhow::Result;
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::gates::CapModel;
use wsel::model::{CaptureBuffer, ParallelEngine, QuantConfig};
use wsel::systolic::{self, MacLib};

fn main() -> Result<()> {
    // Native backend takes over when no artifacts are built, so this
    // walkthrough runs offline too.
    let artifacts = std::path::Path::new("artifacts");
    let threads = wsel::util::threadpool::default_threads();
    let mut p = Pipeline::new(artifacts, "lenet5", PipelineParams::quick())?;
    println!("backend: {}", p.rt.backend_name());
    p.train_baseline()?;

    // Capture real operand streams for conv1 (the 16×5×5 layer) via the
    // blocked parallel executor + a materializing capture sink.
    let spec = p.rt.spec.clone();
    let qc = QuantConfig::quantized(&spec, p.rt.act_scales.clone());
    let eng = ParallelEngine::new(&spec, &p.rt.params, &qc, threads);
    let (xs, _) = wsel::data::batch(p.rt.data_seed, wsel::data::Split::Train, 0, 2, 10);
    let mut buf = CaptureBuffer::new();
    eng.forward(&xs, 2, &mut buf);
    let captures = buf.into_captures();
    let cap = captures
        .iter()
        .find(|c| c.conv_idx == 1)
        .expect("conv1 capture");
    println!(
        "conv1 matmul: M={} K={} N={} -> {} tile passes of 128 cycles",
        cap.m,
        cap.k,
        cap.n,
        systolic::n_tiles(cap.m, cap.k, cap.n)
    );

    // (a) Functional check: tiled systolic == direct matmul.
    let y = systolic::matmul_tiled(&cap.x_codes, &cap.w_codes, cap.m, cap.k, cap.n);
    let mut check = 0i64;
    for r in 0..cap.k {
        check += cap.x_codes[r] as i64 * cap.w_codes[r * cap.n] as i64;
    }
    assert_eq!(y[0] as i64, check, "systolic mapping must equal matmul");
    println!("functional: tile-pass accumulation reproduces Y[0,0] = {}", y[0]);

    // (b) Exact gate-level power of the first pass.
    let cm = CapModel::default();
    let mut lib = MacLib::new();
    lib.specialize_for(&cap.w_codes, threads);
    let pass = systolic::passes_of(cap.m, cap.k, cap.n)[0];
    let (e_exact, steps) =
        systolic::tile_power_exact(&cap.x_codes, &cap.w_codes, cap.k, cap.n, &pass, &lib, &cm);
    let p_exact = e_exact / steps as f64 * cm.freq_hz * 64.0; // per-PE -> array-of-64-rows scale
    println!(
        "exact gate-level: pass energy {e_exact:.3e} J over {steps} MAC-steps  (P_tile ~ {:.2} mW)",
        p_exact * 1e3
    );

    // (c) Statistical model on the same weights.
    p.profile()?;
    let le = p.layer_energy_model(1);
    let mut usage = [0u64; 256];
    for r in 0..pass.kh {
        for c in 0..pass.nw {
            let w = cap.w_codes[(pass.k0 + r) * cap.n + (pass.n0 + c)];
            usage[(w as i32 + 128) as usize] += 1;
        }
    }
    // Model energy for ONE pass over these positions.
    let mut e_model = 0.0;
    for (i, &cnt) in usage.iter().enumerate() {
        let code = (i as i32 - 128) as i8;
        e_model += cnt as f64 * le.table.energy(code) * 128.0;
    }
    let ratio = e_model / e_exact;
    println!(
        "statistical model: pass energy {e_model:.3e} J  (model/exact = {ratio:.2})"
    );
    assert!(
        (0.2..5.0).contains(&ratio),
        "model should track exact simulation within small constant factor"
    );
    println!("model tracks exact gate-level simulation ✓");

    // (d) Network scale: every pass of every captured conv layer through
    // the parallel levelized engine, column streams deduplicated.
    p.maclib.specialize_all(threads);
    let exact = systolic::network_power_exact(&captures, &p.maclib, &cm, threads);
    for l in &exact.layers {
        println!(
            "conv{}: exact {:.3e} J over {} MAC-steps ({} of {} column streams simulated)",
            l.conv_idx, l.energy_j, l.mac_steps, l.columns_unique, l.columns_total
        );
    }
    println!("network exact total: {:.3e} J", exact.total_j());
    Ok(())
}
