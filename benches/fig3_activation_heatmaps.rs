//! Figure 3 — activation-transition heatmaps of the first two LeNet-5
//! conv layers, demonstrating the layer-to-layer variability that makes
//! *global* activation models (prior work) biased.
//!
//! Asserts the paper's qualitative claims: the two layers' transition
//! distributions differ substantially, and the ReLU layer (conv1's
//! input comes after a ReLU+pool) is much sparser than the image input.

use wsel::bench::bench;
use wsel::bench::scenarios;
use wsel::report;

fn main() {
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("lenet5", 400, 100).expect("pipeline");

    let bins = 24;
    let mut heatmaps = Vec::new();
    for ci in 0..2 {
        let st = &p.stats[ci];
        let hm = st.act.heatmap(bins);
        println!(
            "{}",
            report::heatmap(
                &format!(
                    "Fig.3 — LeNet-5 conv{ci} activation transitions (zero-fraction {:.2})",
                    st.act.zero_fraction()
                ),
                &hm,
                bins
            )
        );
        heatmaps.push(hm);
    }

    // Quantify the layer-to-layer difference: total variation distance.
    let tv: f64 = heatmaps[0]
        .iter()
        .zip(&heatmaps[1])
        .map(|(a, b)| (a - b).abs())
        .sum::<f64>()
        / 2.0;
    let zf0 = p.stats[0].act.zero_fraction();
    let zf1 = p.stats[1].act.zero_fraction();
    println!("total-variation distance between conv0/conv1 transitions: {tv:.3}");
    println!("zero-transition mass: conv0 {zf0:.3}, conv1 {zf1:.3}");
    assert!(
        tv > 0.2,
        "per-layer distributions must differ materially (tv = {tv:.3})"
    );
    assert!(
        zf1 > zf0 + 0.1,
        "post-ReLU layer must be sparser: {zf0:.3} vs {zf1:.3}"
    );

    // Perf: stats collection throughput (captures via the parallel
    // executor + materializing sink).
    let spec = p.rt.spec.clone();
    let qc = wsel::model::QuantConfig::quantized(&spec, p.rt.act_scales.clone());
    let threads = wsel::util::threadpool::default_threads();
    let eng = wsel::model::ParallelEngine::new(&spec, &p.rt.params, &qc, threads);
    let (xs, _) = wsel::data::batch(7, wsel::data::Split::Train, 0, 4, 10);
    let mut buf = wsel::model::CaptureBuffer::new();
    eng.forward(&xs, 4, &mut buf);
    let cap0 = buf.into_captures().swap_remove(0);
    let mut rng = wsel::util::rng::Xoshiro256::new(5);
    let m = bench("fig3/collect_layer_stats_conv0", 1, 5, || {
        wsel::bench::black_box(wsel::stats::collect(&cap0, &mut rng));
    });
    m.report_throughput((cap0.m * cap0.k) as f64, "transitions");
}
