//! Figure 1 — average MAC power per weight value.
//!
//! Regenerates the paper's motivating figure: the per-weight switching
//! power of the weight-stationary MAC, measured on the gate-level model
//! under uniform random transitions (the paper's Fig. 1 setting).  The
//! expected *shape* — power grows with |w| and bit density, w = 0 is the
//! floor, substantial spread overall — is asserted, and the
//! characterization throughput is benchmarked.

use wsel::bench::{bench, black_box};
use wsel::energy::uniform_weight_energy;
use wsel::gates::CapModel;
use wsel::report;
use wsel::systolic::MacLib;

fn main() {
    let cap = CapModel::default();
    let mut lib = MacLib::new();
    let table = uniform_weight_energy(&mut lib, &cap, 512, 1, 1);

    // Full per-weight power series (the figure's data).
    let picks: Vec<i32> = (-127..=127).step_by(17).chain([127]).collect();
    let labels: Vec<String> = picks.iter().map(|w| format!("w={w:>4}")).collect();
    let powers: Vec<f64> = picks
        .iter()
        .map(|&w| table.energy(w as i8) * cap.freq_hz)
        .collect();
    println!(
        "{}",
        report::bar_chart(
            "Fig.1 — average MAC power (W) per weight value",
            &labels,
            &powers,
            40
        )
    );

    // Shape assertions (the paper's premise).
    let p0 = table.energy(0) * cap.freq_hz;
    let p127 = table.energy(127) * cap.freq_hz;
    let pneg = table.energy(-127) * cap.freq_hz;
    let lo = (-127i32..=127)
        .map(|w| table.energy(w as i8))
        .fold(f64::MAX, f64::min);
    let hi = (-127i32..=127)
        .map(|w| table.energy(w as i8))
        .fold(0.0f64, f64::max);
    println!("power(0)={p0:.3e} W  power(127)={p127:.3e} W  power(-127)={pneg:.3e} W");
    println!("spread: max/min = {:.2}x  (paper: 'substantial spread')", hi / lo);
    assert!(p127 > p0 * 1.5, "dense weights must cost more than 0");
    assert!(hi / lo > 2.0, "spread too flat to motivate weight selection");

    // Perf: characterization throughput (255 weights × trace).
    let m = bench("fig1/characterize_255_weights_trace256", 1, 3, || {
        let mut lib = MacLib::new();
        black_box(uniform_weight_energy(&mut lib, &cap, 256, 2, 1));
    });
    m.report_throughput(255.0 * 256.0, "MAC-cycles-simulated");
}
