//! Table 2 (scaled) — layer-wise energy savings on ResNet-20: the
//! energy-prioritized schedule processes the highest-ρ layers first and
//! compresses them most aggressively.
//!
//! Bench scale: short training (the table's content is the *schedule
//! behavior*, which depends on the energy model, not on converged
//! accuracy), top-6 layers only.

use wsel::bench::scenarios;
use wsel::report::{pct, Table};
use wsel::schedule::ScheduleParams;

fn main() {
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("resnet20", 250, 60).expect("pipeline");
    let base = p.base_energy.clone().unwrap();

    let sp = ScheduleParams {
        fine_tune_steps: 10,
        delta: 0.05,
        max_layers: Some(6),
        ..Default::default()
    };
    let res = p.compress(sp).expect("compress");

    let mut t = Table::new(
        "Table 2 (scaled: ResNet-20 layer-wise savings; paper rows: Block2 61.8%/21.1%, Block4 63.2%/23.7%, Block6 51.2%/7.6%, Block9 48.3%/3.9%)",
        &["layer", "share", "prune", "K", "layer saving"],
    );
    for oc in &res.outcomes {
        let (ratio, k) = oc
            .accepted
            .map(|c| (format!("{:.2}", c.prune_ratio), c.k_target.to_string()))
            .unwrap_or(("-".into(), "-".into()));
        t.row(&[
            format!("conv{}", oc.conv_idx),
            pct(oc.share),
            ratio,
            k,
            if oc.energy_before > 0.0 {
                pct(1.0 - oc.energy_after / oc.energy_before)
            } else {
                "-".into()
            },
        ]);
    }
    println!("{}", t.render());

    // Shape assertions: processing order follows energy share descending,
    // and processed layers actually saved energy.
    let shares: Vec<f64> = res.outcomes.iter().map(|o| o.share).collect();
    for w in shares.windows(2) {
        assert!(
            w[0] >= w[1] - 1e-12,
            "schedule must process descending energy shares: {shares:?}"
        );
    }
    let accepted = res.outcomes.iter().filter(|o| o.accepted.is_some()).count();
    assert!(accepted >= 3, "most top layers should accept a config");
    let total_after = p.compute_network_energy(&res.state);
    let saving = base.saving_vs(&total_after);
    println!("total saving from top-6 layers: {}", pct(saving));
    assert!(saving > 0.1, "top-layer compression must move total energy");
}
