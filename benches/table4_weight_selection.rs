//! Table 4 (scaled) — effectiveness of the weight-selection algorithm:
//! naive lowest-energy top-16 vs top-20 vs the optimized (greedy
//! backward elimination) 16-value selection.
//!
//! Paper shape: naive-16 collapses accuracy (59.6%) despite competitive
//! energy savings; the optimized 16-value sets retain near-baseline
//! accuracy at similar savings.

use wsel::bench::scenarios;
use wsel::report::{pct, Table};
use wsel::schedule::ScheduleParams;
use wsel::selection::{naive_lowest_energy, CompressionState, LayerConfig};

fn main() {
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("lenet5", 600, 150).expect("pipeline");
    let acc0 = p.acc0;
    let base = p.base_energy.clone().unwrap();
    let trained = p.checkpoint();
    let n_conv = p.rt.spec.n_conv;

    let mut t = Table::new(
        "Table 4 (scaled: LeNet-5; paper: naive-16 59.3%/59.6%, naive-20 57.5%/89.6%, optimized-16 58.6%/89.4%)",
        &["selection", "energy saving", "accuracy"],
    );

    let mut measured = Vec::new();
    for k in [16usize, 20] {
        p.restore(trained.clone());
        let le0 = p.layer_energy_model(0);
        let set = naive_lowest_energy(&le0.table, k);
        let state = CompressionState {
            layers: (0..n_conv)
                .map(|_| LayerConfig {
                    prune_ratio: 0.5,
                    wset: Some(set.clone()),
                })
                .collect(),
        };
        let (acc, saving) = p.evaluate_state(&state, 20).expect("naive");
        t.row(&[format!("naive top-{k}"), pct(saving), pct(acc)]);
        measured.push((format!("naive{k}"), saving, acc));
    }

    // Optimized: greedy elimination to 16 per layer via the schedule with
    // a fixed (0.5, 16) menu.
    p.restore(trained.clone());
    let sp = ScheduleParams {
        prune_ratios: vec![0.5],
        k_targets: vec![16],
        fine_tune_steps: 20,
        delta: 0.06,
        ..Default::default()
    };
    let res = p.compress(sp).expect("compress");
    let e = p.compute_network_energy(&res.state);
    let saving = base.saving_vs(&e);
    t.row(&[
        "optimized 16 (ours)".into(),
        pct(saving),
        pct(res.final_accuracy),
    ]);
    println!("{}", t.render());
    println!("baseline acc0 = {}", pct(acc0));

    // Paper-shape assertions.  Note (EXPERIMENTS.md Table 4): with STE
    // fine-tuning our naive sets partially recover on the synthetic
    // task, so the paper's *catastrophic* 30-pt gap shrinks to an
    // ordering — which must still hold strictly.
    let naive16_acc = measured[0].2;
    assert!(
        res.final_accuracy > naive16_acc,
        "optimized selection must beat naive-16 accuracy: {:.3} vs {naive16_acc:.3}",
        res.final_accuracy
    );
    assert!(
        res.final_accuracy >= acc0 - 0.06,
        "optimized 16-value selection stays near baseline"
    );
}
