//! Performance benches for the L3 hot paths (the §Perf deliverable):
//!
//!   * bit-parallel gate simulation throughput (gate-lane-evals/s),
//!   * weight-specialized MAC trace energy (the inner loop of E_ℓ(w)
//!     characterization),
//!   * exact tile power simulation,
//!   * int8 mirror-engine forward,
//!   * selection loop (greedy elimination, proxy mode),
//!   * PJRT eval-graph execution latency.
//!
//! Before/after numbers for the optimization pass are recorded in
//! EXPERIMENTS.md §Perf.

use wsel::bench::{bench, black_box, scenarios};
use wsel::gates::{CapModel, TraceSim};
use wsel::mac::build_mac;
use wsel::selection::CompressionState;
use wsel::systolic::{self, MacLib};
use wsel::util::rng::Xoshiro256;

fn main() {
    let cap = CapModel::default();

    // ---- gate sim throughput -------------------------------------------
    let mac = build_mac();
    let nl = &mac.netlist;
    let n_gates = nl.gate_count();
    let mut sim = TraceSim::new(nl);
    let words: Vec<u64> = (0..nl.inputs.len() as u64).map(|i| i * 0x9E37).collect();
    let m = bench("perf/gate_sim_chunk64_generic_mac", 10, 200, || {
        sim.run_chunk(black_box(nl), &words, 64);
    });
    m.report_throughput(n_gates as f64 * 64.0, "gate-lane-evals");

    // ---- per-weight trace energy ----------------------------------------
    let mut lib = MacLib::new();
    lib.get(37);
    let m = bench("perf/specialize_mac", 2, 50, || {
        black_box(wsel::mac::specialize_mac(&mac, black_box(91)));
    });
    m.report();

    let mut rng = Xoshiro256::new(1);
    let acts: Vec<i32> = (0..512).map(|_| rng.code()).collect();
    let psums: Vec<i32> = (0..512).map(|_| (rng.below(1 << 22) as i64 - (1 << 21)) as i32).collect();
    let m = bench("perf/weight_trace_energy_512", 2, 50, || {
        black_box(wsel::energy::transition_energy(
            &mut lib, &cap, 37, 11, psums[0], psums[1], 512,
        ));
    });
    m.report_throughput(512.0, "MAC-cycles");
    black_box((acts, psums));

    // ---- exact tile power -------------------------------------------------
    let mut rng = Xoshiro256::new(2);
    let (mm, kk, nn) = (64usize, 64usize, 64usize);
    let x: Vec<i8> = (0..mm * kk).map(|_| rng.code() as i8).collect();
    let w: Vec<i8> = (0..kk * nn).map(|_| rng.code() as i8).collect();
    let pass = systolic::passes_of(mm, kk, nn)[0];
    let m = bench("perf/tile_power_exact_64x64x64", 1, 5, || {
        let mut lib2 = MacLib::new();
        black_box(systolic::tile_power_exact(
            &x, &w, kk, nn, &pass, &mut lib2, &cap,
        ));
    });
    m.report_throughput((mm * kk * nn) as f64, "MAC-steps");
    // Warm-library variant (the pipeline's steady state).
    let m = bench("perf/tile_power_exact_warm_maclib", 1, 5, || {
        black_box(systolic::tile_power_exact(
            &x, &w, kk, nn, &pass, &mut lib, &cap,
        ));
    });
    m.report_throughput((mm * kk * nn) as f64, "MAC-steps");

    // ---- pipeline-dependent paths (need artifacts) ------------------------
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("lenet5", 120, 40).expect("pipeline");

    // int8 mirror engine forward.
    let spec = p.rt.spec.clone();
    let eng = wsel::model::Engine::new(&spec);
    let qc = wsel::model::QuantConfig::quantized(&spec, p.rt.act_scales.clone());
    let (xs, _) = wsel::data::batch(7, wsel::data::Split::Val, 0, 8, 10);
    let m = bench("perf/mirror_engine_forward_b8", 1, 10, || {
        black_box(eng.forward(&p.rt.params, &xs, 8, &qc, false));
    });
    m.report_throughput(8.0, "images");

    // Greedy elimination (proxy mode) on real stats.
    use wsel::schedule::LayerModeler;
    let dense = CompressionState::dense(spec.n_conv);
    let usage = p.usage(1, &dense);
    let le = p.layer_energy_model(1);
    let m = bench("perf/greedy_eliminate_32_to_16", 1, 20, || {
        let set0 = wsel::selection::safe_initial_set(&usage, &le, 32);
        let mut st = CompressionState::dense(spec.n_conv);
        struct Null;
        impl wsel::selection::AccuracyOracle for Null {
            fn accuracy(&mut self, _: &CompressionState) -> f64 {
                1.0
            }
            fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
        }
        let gp = wsel::selection::GreedyParams::default();
        black_box(wsel::selection::greedy_backward_eliminate(
            set0, &usage, &le, &mut Null, &mut st, 1, &gp,
        ));
    });
    m.report();

    // PJRT eval latency (the oracle's unit of cost).
    let m = bench("perf/pjrt_eval_batch128", 1, 5, || {
        black_box(
            p.rt.evaluate(&dense, true, wsel::data::Split::Val, 1)
                .expect("eval"),
        );
    });
    m.report_throughput(128.0, "images");

    // Data generation (feeds every train step).
    let m = bench("perf/datagen_batch32", 1, 10, || {
        black_box(wsel::data::batch(7, wsel::data::Split::Train, 0, 32, 10));
    });
    m.report_throughput(32.0, "images");
}
