//! Performance benches for the L3 hot paths (the §Perf deliverable):
//!
//!   * bit-parallel gate simulation throughput (gate-lane-evals/s),
//!   * weight-specialized MAC trace energy (the inner loop of E_ℓ(w)
//!     characterization),
//!   * exact tile power simulation,
//!   * the memoized + parallel [`EnergyEvaluator`] vs the direct
//!     sequential un-cached path (table1/table3-style workloads),
//!   * the table3 layer-wise schedule evaluation, before/after the
//!     evaluator refactor (asserts the ≥2× win at 4+ threads),
//!   * the [`TransitionCostCache`] first-order table vs a full
//!     re-characterization,
//!   * the dispatched SIMD microkernels (AVX2/SSE2 vs scalar): int8
//!     blocked GEMM at dense / 50% / 87.5% block sparsity, quantize,
//!     requant epilogue and the f32 training GEMM (bit-identity always
//!     asserted; >= 2x dense int8 GEMM gated on an AVX2 host; emits
//!     BENCH_kernels.json),
//!   * int8 mirror-engine forward,
//!   * native train-step and evaluate throughput, serial vs
//!     batch-parallel (the PR-4 accuracy-oracle hot path; asserts the
//!     ≥2× win at 4+ threads and bit-identical trained params),
//!   * selection loop (greedy elimination, proxy mode),
//!   * §4.3 schedule search on the built-in lenet5: exhaustive sweep
//!     vs successive halving vs a warm persistent accuracy cache
//!     (asserts halving pays <= 50% of the exhaustive fine-tune bill
//!     and the warm rerun pays zero; emits BENCH_schedule_search.json),
//!   * PJRT eval-graph execution latency.
//!
//! Speedup assertions are skipped when fewer than 4 hardware threads
//! are available, when `WSEL_THREADS` caps the pool below 4, or when
//! `WSEL_PERF_ASSERT=0` (low-core CI runners).
//! Before/after numbers for the optimization pass are recorded in
//! EXPERIMENTS.md §Perf.

use std::sync::Arc;
use wsel::bench::{bench, black_box, perf_asserts_enabled, scenarios};
use wsel::energy::cache::{EnergyEvaluator, EvalLayer, TransitionCostCache};
use wsel::energy::{LayerEnergy, NetworkEnergy, WeightEnergyTable};
use wsel::gates::{CapModel, TraceSim};
use wsel::mac::build_mac;
use wsel::quant::WeightSet;
use wsel::schedule::{energy_prioritized, LayerModeler, ScheduleParams};
use wsel::selection::{AccuracyOracle, CompressionState, LayerConfig};
use wsel::systolic::{self, MacLib};
use wsel::util::rng::Xoshiro256;
use wsel::util::threadpool::default_threads;

fn synth_table() -> WeightEnergyTable {
    wsel::testutil::linear_energy_table(1e-15)
}

/// Artifact-free conv stack for the forward before/after bench
/// (LeNet-ish depth at CIFAR input dims).
const FWD_BENCH_MANIFEST: &str = r#"{
  "model": "fwdbench", "n_classes": 10, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 16, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 32, "wout": 32},
    {"op": "maxpool2"},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 16, "cout": 32, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 16, "win": 16, "hout": 16, "wout": 16},
    {"op": "maxpool2"},
    {"op": "conv", "name": "conv2", "w": 4, "b": 5, "conv_idx": 2,
     "q_idx": 2, "cin": 32, "cout": 32, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 8, "win": 8, "hout": 8, "wout": 8},
    {"op": "gap"},
    {"op": "fc", "name": "fc0", "w": 6, "b": 7, "q_idx": 3,
     "din": 32, "dout": 10, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [16, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [16], "kind": "bias"},
    {"name": "conv1.w", "shape": [32, 16, 3, 3], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [32], "kind": "bias"},
    {"name": "conv2.w", "shape": [32, 32, 3, 3], "kind": "conv_w"},
    {"name": "conv2.b", "shape": [32], "kind": "bias"},
    {"name": "fc0.w", "shape": [10, 32], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [10], "kind": "bias"}
  ],
  "n_conv": 3, "n_q": 4, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 16, "eval": 32, "logits": 4, "calib": 8},
  "pallas_eval": false
}"#;

/// Synthetic conv layers with the given (M, K, N) im2col dims and
/// random float weights — stand-ins for the table1/table3 workloads
/// when no artifacts are built.
fn synth_layers(dims: &[(usize, usize, usize)], seed: u64) -> Vec<EvalLayer> {
    let mut rng = Xoshiro256::new(seed);
    dims.iter()
        .enumerate()
        .map(|(ci, &(m, k, n))| EvalLayer {
            le: LayerEnergy {
                conv_idx: ci,
                m,
                k,
                n,
                table: synth_table(),
            },
            weights: (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        })
        .collect()
}

/// Candidate-state menu the schedule sweep touches: a few prune ratios
/// crossed with a few restricted sets, cycled over `count` states.
fn synth_states(n_conv: usize, count: usize) -> Vec<CompressionState> {
    let sets = [
        None,
        Some(WeightSet::new(vec![
            -127, -64, -32, -16, -8, 0, 8, 16, 32, 64, 127,
        ])),
        Some(WeightSet::new(vec![-81, -27, -9, -3, 0, 3, 9, 27, 81])),
    ];
    let ratios = [0.0, 0.5, 0.7];
    (0..count)
        .map(|i| CompressionState {
            layers: (0..n_conv)
                .map(|l| LayerConfig {
                    prune_ratio: ratios[(i + l) % ratios.len()],
                    wset: sets[(i / ratios.len() + l) % sets.len()].clone(),
                })
                .collect(),
        })
        .collect()
}

/// Schedule host over a synthetic evaluator.  `cached = false` models
/// the pre-refactor pipeline: every usage histogram recomputed inline,
/// sequential network-energy walks, no evaluator for the schedule to
/// fan out against.
struct SynthHost {
    ev: Arc<EnergyEvaluator>,
    cached: bool,
}

impl LayerModeler for SynthHost {
    fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy {
        self.ev.layer_model(conv_idx).clone()
    }
    fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256] {
        let ratio = state.layers[conv_idx].prune_ratio;
        if self.cached {
            *self.ev.usage_for_conv(conv_idx, ratio)
        } else {
            EnergyEvaluator::compute_usage(&self.ev.layer_by_conv(conv_idx).weights, ratio)
        }
    }
    fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy {
        if self.cached {
            self.ev.eval(state)
        } else {
            self.ev.eval_direct(state)
        }
    }
    fn evaluator(&mut self) -> Option<Arc<EnergyEvaluator>> {
        if self.cached {
            Some(self.ev.clone())
        } else {
            None
        }
    }
}

impl AccuracyOracle for SynthHost {
    fn accuracy(&mut self, state: &CompressionState) -> f64 {
        // Deterministic response: mild penalty per compressed layer so
        // the sweep exercises several candidates before accepting.
        let mut acc = 0.99;
        for l in &state.layers {
            acc -= 0.004 * l.prune_ratio;
            if let Some(s) = &l.wset {
                acc -= 0.002 * (32.0 - s.len() as f64) / 16.0;
            }
        }
        acc
    }
    fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
}

fn main() {
    let cap = CapModel::default();

    // ---- gate sim throughput -------------------------------------------
    let mac = build_mac();
    let nl = &mac.netlist;
    let n_gates = nl.gate_count();
    let mut sim = TraceSim::new(nl);
    let words: Vec<u64> = (0..nl.inputs.len() as u64).map(|i| i * 0x9E37).collect();
    let m = bench("perf/gate_sim_chunk64_generic_mac", 10, 200, || {
        sim.run_chunk(black_box(nl), &words, 64);
    });
    m.report_throughput(n_gates as f64 * 64.0, "gate-lane-evals");

    // ---- per-weight trace energy ----------------------------------------
    let mut lib = MacLib::new();
    lib.get(37);
    let m = bench("perf/specialize_mac", 2, 50, || {
        black_box(wsel::mac::specialize_mac(&mac, black_box(91)));
    });
    m.report();

    let mut rng = Xoshiro256::new(1);
    let acts: Vec<i32> = (0..512).map(|_| rng.code()).collect();
    let psums: Vec<i32> = (0..512).map(|_| (rng.below(1 << 22) as i64 - (1 << 21)) as i32).collect();
    let m = bench("perf/weight_trace_energy_512", 2, 50, || {
        black_box(wsel::energy::transition_energy(
            &mut lib, &cap, 37, 11, psums[0], psums[1], 512,
        ));
    });
    m.report_throughput(512.0, "MAC-cycles");
    black_box((acts, psums));

    // ---- exact tile power: sequential reference vs parallel engine --------
    // Before: the historical single-threaded path (per-gate dispatch,
    // per-lane bit packing).  After: TilePowerEngine — column-parallel,
    // levelized SoA evaluation, transpose packing.  Same MacLib, warm.
    let threads = default_threads();
    let mut rng = Xoshiro256::new(2);
    let (mm, kk, nn) = (64usize, 64usize, 64usize);
    let x: Vec<i8> = (0..mm * kk).map(|_| rng.code() as i8).collect();
    let w: Vec<i8> = (0..kk * nn).map(|_| rng.code() as i8).collect();
    let pass = systolic::passes_of(mm, kk, nn)[0];
    lib.specialize_all(threads);
    let m_seq = bench("perf/tile_power_exact_seq_64x64x64", 1, 5, || {
        black_box(systolic::tile_power_exact(&x, &w, kk, nn, &pass, &lib, &cap));
    });
    m_seq.report_throughput((mm * kk * nn) as f64, "MAC-steps");
    let engine = systolic::TilePowerEngine::new(&lib, &cap);
    let m_eng = bench(
        &format!("perf/tile_power_engine_t{threads}_64x64x64"),
        1,
        5,
        || {
            black_box(engine.pass_power(&x, &w, kk, nn, &pass, threads));
        },
    );
    m_eng.report_throughput((mm * kk * nn) as f64, "MAC-steps");
    let tile_speedup = m_seq.median_ns as f64 / m_eng.median_ns.max(1) as f64;
    println!("      -> tile power engine speedup vs sequential: {tile_speedup:.1}x");
    // The engine must be exact, not just fast: bit-identical energy and
    // identical MAC-step counts vs the sequential reference.
    let (e_seq, s_seq) = systolic::tile_power_exact(&x, &w, kk, nn, &pass, &lib, &cap);
    let (e_eng, s_eng) = engine.pass_power(&x, &w, kk, nn, &pass, threads);
    assert_eq!(
        (e_seq.to_bits(), s_seq),
        (e_eng.to_bits(), s_eng),
        "engine must be bit-identical to the sequential reference"
    );
    // Acceptance gate: >= 2x tile-power throughput at 4+ threads
    // (skipped on low-core runners / WSEL_PERF_ASSERT=0).
    if perf_asserts_enabled() {
        assert!(
            tile_speedup >= 2.0,
            "tile power engine must be >= 2x at {threads} threads (got {tile_speedup:.2}x)"
        );
    } else {
        println!("      (tile speedup assertion skipped: <4 cores or WSEL_PERF_ASSERT=0)");
    }

    // ---- EnergyEvaluator: memoized+parallel vs direct ---------------------
    // Table-1-style workload (resnet20-ish conv stack, no artifacts
    // needed): many candidate states over the same frozen weights —
    // exactly the shape of the schedule's inner loop.
    let resnet_dims: Vec<(usize, usize, usize)> =
        (0..6).map(|_| (256usize, 576usize, 32usize)).collect();
    let ev_serial = EnergyEvaluator::new(synth_layers(&resnet_dims, 31), 1);
    let ev_par = EnergyEvaluator::new(synth_layers(&resnet_dims, 31), threads);
    let states = synth_states(resnet_dims.len(), 36);
    let m_direct = bench("perf/evaluator_direct_uncached_36states", 1, 3, || {
        for st in &states {
            black_box(ev_serial.eval_direct(st));
        }
    });
    m_direct.report_throughput(36.0, "state-evals");
    let m_cached = bench("perf/evaluator_cached_serial_36states", 1, 3, || {
        for st in &states {
            black_box(ev_serial.eval(st));
        }
    });
    m_cached.report_throughput(36.0, "state-evals");
    let m_cached_par = bench(
        &format!("perf/evaluator_cached_parallel_t{threads}_36states"),
        1,
        3,
        || {
            for st in &states {
                black_box(ev_par.eval(st));
            }
        },
    );
    m_cached_par.report_throughput(36.0, "state-evals");
    let speedup = m_direct.median_ns as f64 / m_cached_par.median_ns.max(1) as f64;
    println!("      -> evaluator cached+parallel speedup vs direct: {speedup:.1}x");
    if perf_asserts_enabled() {
        assert!(
            speedup >= 2.0,
            "memoized evaluator must be >= 2x the direct path (got {speedup:.2}x)"
        );
    } else {
        println!("      (evaluator speedup assertion skipped: <4 cores or WSEL_PERF_ASSERT=0)");
    }

    // ---- table3 layer-wise schedule evaluation: before/after --------------
    // The §4.3 sweep at table3's (ratio, K) menu over the synthetic
    // stack, fine-tune-free (the evaluation cost itself).  `before`
    // models the pre-refactor pipeline (inline usage recompute, serial);
    // `after` runs against the shared evaluator with parallel candidate
    // precompute.
    let n_conv = resnet_dims.len();
    let sp = ScheduleParams {
        prune_ratios: vec![0.7, 0.5, 0.3],
        k_targets: vec![16, 24, 32],
        fine_tune_steps: 0,
        delta: 0.004,
        acc0: 0.99,
        ..Default::default()
    };
    let mut sp_par = sp.clone();
    sp_par.greedy.threads = threads;
    let ev_sched = Arc::new(EnergyEvaluator::new(synth_layers(&resnet_dims, 31), 1));
    let ev_sched_par = Arc::new(EnergyEvaluator::new(synth_layers(&resnet_dims, 31), threads));
    let m_before = bench("perf/table3_schedule_eval_before", 1, 3, || {
        let mut host = SynthHost {
            ev: ev_sched.clone(),
            cached: false,
        };
        black_box(energy_prioritized(&mut host, n_conv, &sp));
    });
    m_before.report();
    let m_after = bench(
        &format!("perf/table3_schedule_eval_after_t{threads}"),
        1,
        3,
        || {
            ev_sched_par.clear_cache();
            let mut host = SynthHost {
                ev: ev_sched_par.clone(),
                cached: true,
            };
            black_box(energy_prioritized(&mut host, n_conv, &sp_par));
        },
    );
    m_after.report();
    let sched_speedup = m_before.median_ns as f64 / m_after.median_ns.max(1) as f64;
    println!("      -> table3 schedule evaluation speedup: {sched_speedup:.1}x");
    // Acceptance gate: >= 2x at 4+ threads.  (Cold cache every
    // iteration, so the win is structural, not warm-cache residue.)
    if perf_asserts_enabled() {
        assert!(
            sched_speedup >= 2.0,
            "schedule evaluation must be >= 2x at {threads} threads (got {sched_speedup:.2}x)"
        );
    } else {
        println!("      (speedup assertion skipped: <4 cores or WSEL_PERF_ASSERT=0)");
    }
    // Both hosts must agree on the chosen compression plan exactly.
    {
        let mut h_before = SynthHost {
            ev: ev_sched.clone(),
            cached: false,
        };
        let mut h_after = SynthHost {
            ev: ev_sched_par.clone(),
            cached: true,
        };
        let r_before = energy_prioritized(&mut h_before, n_conv, &sp);
        let r_after = energy_prioritized(&mut h_after, n_conv, &sp_par);
        assert_eq!(
            format!("{}", r_before.to_json()),
            format!("{}", r_after.to_json()),
            "cached/parallel schedule must match the direct schedule exactly"
        );
    }

    // ---- TransitionCostCache: first-order table vs re-characterization ----
    {
        let mut rng = Xoshiro256::new(5);
        let (sm, sk, sn) = (96usize, 64usize, 4usize);
        let capture = wsel::model::ConvCapture {
            conv_idx: 0,
            m: sm,
            k: sk,
            n: sn,
            x_codes: (0..sm * sk)
                .map(|_| if rng.below(2) == 0 { 0 } else { rng.code() as i8 })
                .collect(),
            w_codes: (0..sk * sn).map(|_| rng.code() as i8).collect(),
            s_act: 0.01,
            s_w: 0.01,
        };
        let st = wsel::stats::collect(&capture, &mut rng);
        let mut lib3 = MacLib::new();
        lib3.specialize_all(threads);
        let m_char = bench("perf/characterize_layer_trace256", 1, 3, || {
            black_box(wsel::energy::characterize_layer_shared(
                &st, &lib3, &cap, 256, 7, threads,
            ));
        });
        m_char.report();
        let tc = TransitionCostCache::new(&st, 7);
        let m_cold = bench("perf/transition_cache_table_cold", 0, 1, || {
            black_box(tc.approx_table(&st, &lib3, &cap, threads));
        });
        m_cold.report();
        let m_warm = bench("perf/transition_cache_table_warm", 1, 5, || {
            black_box(tc.approx_table(&st, &lib3, &cap, threads));
        });
        m_warm.report();
        println!(
            "      -> warm first-order table vs full characterization: {:.1}x",
            m_char.median_ns as f64 / m_warm.median_ns.max(1) as f64
        );
    }

    // ---- SIMD microkernels: scalar vs runtime-dispatched ------------------
    // The kernels::dispatch hot loops.  Every backend is bit-identical
    // to scalar by construction, so the equality asserts are
    // unconditional; the >= 2x dense int8 GEMM gate applies only when
    // the host resolved AVX2 (and perf asserts are on).  The sweep is
    // recorded as BENCH_kernels.json at the repo root and re-loaded
    // through the checksummed artifact layer to prove it validates.
    {
        use wsel::model::kernels::dispatch::{self, KernelKind};
        use wsel::model::kernels::{BlockedWeights, SB};
        use wsel::util::json::Json;

        let scalar_ops = dispatch::for_kind(KernelKind::Scalar).expect("scalar backend");
        let active = dispatch::active();
        println!(
            "bench perf/kernels: dispatched backend = {}",
            active.kind.name()
        );

        // (name, scalar ns, dispatched ns, speedup, dispatched GOP-or-elem/s)
        let mut rows: Vec<(String, u128, u128, f64, f64)> = Vec::new();
        let mut dense_speedup = 0.0f64;
        let mut rng = Xoshiro256::new(17);

        // int8 blocked GEMM at a conv-sized im2col shape, swept over
        // block sparsity so the skip, dense and partial-mask strip
        // paths all get exercised.
        let (gm, gk, gn) = (256usize, 1152usize, 128usize);
        let x: Vec<i8> = (0..gm * gk).map(|_| rng.code() as i8).collect();
        for &(label, kill) in &[("dense", 0usize), ("sparse50", 4), ("sparse87.5", 7)] {
            // Kill `kill` of every 8 SB x SB weight cells (deterministic
            // cell-index stripe over the K x N matrix).
            let ncells = gn.div_ceil(SB);
            let w: Vec<i8> = (0..gk * gn)
                .map(|i| {
                    let (r, c) = (i / gn, i % gn);
                    if ((r / SB) * ncells + c / SB) % 8 < kill {
                        0
                    } else {
                        rng.code() as i8
                    }
                })
                .collect();
            let wb = BlockedWeights::pack(&w, gk, gn);
            let mut acc_s = vec![0i32; gm * gn];
            let mut acc_d = vec![0i32; gm * gn];
            (scalar_ops.gemm_i8_blocked)(&x, &wb, gm, &mut acc_s);
            (active.gemm_i8_blocked)(&x, &wb, gm, &mut acc_d);
            assert_eq!(
                acc_s, acc_d,
                "{label}: dispatched i8 GEMM must be bit-identical to scalar"
            );
            // Dense-equivalent MAC work, so sparse rows show the
            // combined structural-skip + SIMD win on one scale.
            let ops = 2.0 * (gm * gk * gn) as f64;
            let m_s = bench(&format!("perf/kernels_i8_gemm_scalar_{label}"), 1, 5, || {
                (scalar_ops.gemm_i8_blocked)(black_box(&x), &wb, gm, &mut acc_s);
            });
            m_s.report_throughput(ops, "ops");
            let m_d = bench(
                &format!("perf/kernels_i8_gemm_{}_{label}", active.kind.name()),
                1,
                5,
                || {
                    (active.gemm_i8_blocked)(black_box(&x), &wb, gm, &mut acc_d);
                },
            );
            m_d.report_throughput(ops, "ops");
            let sp = m_s.median_ns as f64 / m_d.median_ns.max(1) as f64;
            let gops = ops / m_d.median_ns.max(1) as f64;
            println!("      -> {label}: {gops:.2} GOP/s dispatched, {sp:.2}x vs scalar");
            if kill == 0 {
                dense_speedup = sp;
            }
            rows.push((format!("i8_gemm_{label}"), m_s.median_ns, m_d.median_ns, sp, gops));
        }

        // Activation quantization (the per-layer forward epilogue feed).
        {
            let n_el = 1usize << 16;
            let src: Vec<f32> = (0..n_el).map(|_| rng.range_f32(-4.0, 4.0)).collect();
            let mut q_s = vec![0i8; n_el];
            let mut q_d = vec![0i8; n_el];
            (scalar_ops.quantize_i8)(&src, 0.031, &mut q_s);
            (active.quantize_i8)(&src, 0.031, &mut q_d);
            assert_eq!(q_s, q_d, "dispatched quantize must be bit-identical to scalar");
            let m_s = bench("perf/kernels_quantize_scalar_64k", 2, 20, || {
                (scalar_ops.quantize_i8)(black_box(&src), 0.031, &mut q_s);
            });
            m_s.report_throughput(n_el as f64, "elems");
            let m_d = bench(
                &format!("perf/kernels_quantize_{}_64k", active.kind.name()),
                2,
                20,
                || {
                    (active.quantize_i8)(black_box(&src), 0.031, &mut q_d);
                },
            );
            m_d.report_throughput(n_el as f64, "elems");
            let sp = m_s.median_ns as f64 / m_d.median_ns.max(1) as f64;
            println!("      -> quantize: {sp:.2}x vs scalar");
            rows.push((
                "quantize_64k".to_string(),
                m_s.median_ns,
                m_d.median_ns,
                sp,
                n_el as f64 / m_d.median_ns.max(1) as f64,
            ));
        }

        // Requantization epilogue (i32 accumulators -> f32 + bias + relu).
        {
            let (rm, rn) = (256usize, 128usize);
            let acc: Vec<i32> = (0..rm * rn)
                .map(|_| (rng.below(1 << 20) as i64 - (1 << 19)) as i32)
                .collect();
            let bias: Vec<f32> = (0..rn).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let mut o_s = vec![0f32; rm * rn];
            let mut o_d = vec![0f32; rm * rn];
            (scalar_ops.requant_bias_relu)(&acc, 6.1e-4, &bias, true, &mut o_s);
            (active.requant_bias_relu)(&acc, 6.1e-4, &bias, true, &mut o_d);
            assert_eq!(
                o_s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                o_d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "dispatched requant must be bit-identical to scalar"
            );
            let m_s = bench("perf/kernels_requant_scalar_256x128", 2, 20, || {
                (scalar_ops.requant_bias_relu)(black_box(&acc), 6.1e-4, &bias, true, &mut o_s);
            });
            m_s.report_throughput((rm * rn) as f64, "elems");
            let m_d = bench(
                &format!("perf/kernels_requant_{}_256x128", active.kind.name()),
                2,
                20,
                || {
                    (active.requant_bias_relu)(black_box(&acc), 6.1e-4, &bias, true, &mut o_d);
                },
            );
            m_d.report_throughput((rm * rn) as f64, "elems");
            let sp = m_s.median_ns as f64 / m_d.median_ns.max(1) as f64;
            println!("      -> requant: {sp:.2}x vs scalar");
            rows.push((
                "requant_256x128".to_string(),
                m_s.median_ns,
                m_d.median_ns,
                sp,
                (rm * rn) as f64 / m_d.median_ns.max(1) as f64,
            ));
        }

        // f32 training GEMM (the GradEngine forward/backward core).
        {
            let (fm, fk, fnn) = (96usize, 256usize, 128usize);
            let a: Vec<f32> = (0..fm * fk).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..fk * fnn).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let mut c_s = vec![0f32; fm * fnn];
            let mut c_d = vec![0f32; fm * fnn];
            (scalar_ops.gemm_f32)(&a, &b, fm, fk, fnn, &mut c_s);
            (active.gemm_f32)(&a, &b, fm, fk, fnn, &mut c_d);
            assert_eq!(
                c_s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                c_d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "dispatched f32 GEMM must be bit-identical to scalar"
            );
            let ops = 2.0 * (fm * fk * fnn) as f64;
            let m_s = bench("perf/kernels_f32_gemm_scalar_96x256x128", 1, 10, || {
                (scalar_ops.gemm_f32)(black_box(&a), &b, fm, fk, fnn, &mut c_s);
            });
            m_s.report_throughput(ops, "flops");
            let m_d = bench(
                &format!("perf/kernels_f32_gemm_{}_96x256x128", active.kind.name()),
                1,
                10,
                || {
                    (active.gemm_f32)(black_box(&a), &b, fm, fk, fnn, &mut c_d);
                },
            );
            m_d.report_throughput(ops, "flops");
            let sp = m_s.median_ns as f64 / m_d.median_ns.max(1) as f64;
            println!("      -> f32 gemm: {sp:.2}x vs scalar");
            rows.push((
                "f32_gemm_96x256x128".to_string(),
                m_s.median_ns,
                m_d.median_ns,
                sp,
                ops / m_d.median_ns.max(1) as f64,
            ));
        }

        // Acceptance gate: >= 2x dense int8 GEMM where AVX2 resolved.
        let avx2_host = dispatch::for_kind(KernelKind::Avx2).is_some();
        if perf_asserts_enabled() && avx2_host && active.kind == KernelKind::Avx2 {
            assert!(
                dense_speedup >= 2.0,
                "AVX2 dense int8 GEMM must be >= 2x scalar (got {dense_speedup:.2}x)"
            );
        } else {
            println!(
                "      (kernel >=2x gate skipped: no AVX2 backend active or WSEL_PERF_ASSERT=0)"
            );
        }

        let json = Json::obj(vec![
            ("bench", Json::str("kernels")),
            ("backend", Json::str(active.kind.name())),
            ("avx2_host", Json::num(if avx2_host { 1.0 } else { 0.0 })),
            (
                "rows",
                Json::arr(rows.iter().map(|(name, s_ns, d_ns, sp, rate)| {
                    Json::obj(vec![
                        ("name", Json::str(name)),
                        ("scalar_median_ns", Json::num(*s_ns as f64)),
                        ("dispatched_median_ns", Json::num(*d_ns as f64)),
                        ("speedup", Json::num(*sp)),
                        ("dispatched_rate", Json::num(*rate)),
                    ])
                })),
            ),
        ]);
        let path =
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_kernels.json");
        match wsel::util::artifact::write_json_atomic(&path, &json) {
            Ok(()) => {
                // Round-trip through the checksummed loader: a torn or
                // bit-rotted artifact must be rejected, a good one must
                // parse back to the same document.
                let back = wsel::util::artifact::load_json(&path)
                    .expect("re-load BENCH_kernels.json");
                assert_eq!(
                    back.to_string(),
                    json.to_string(),
                    "BENCH_kernels.json must round-trip losslessly"
                );
                println!("      wrote {} (validated on re-load)", path.display());
            }
            Err(e) => eprintln!("      could not write {}: {e}", path.display()),
        }
    }

    // ---- int8 forward: scalar reference vs blocked parallel executor ------
    // Artifact-free synthetic conv stack.  Before: the monolithic scalar
    // engine (per-call weight quantization, unblocked loops, single
    // thread).  After: ParallelEngine — IR-lowered plan with
    // pre-quantized blocked weight tiles, cache-blocked i32 GEMM,
    // per-image fan-out over the pool.  Must be bit-identical AND >= 2x
    // at 4+ threads.
    {
        let spec = wsel::model::ModelSpec::from_manifest_str(FWD_BENCH_MANIFEST)
            .expect("bench manifest");
        let p = wsel::model::Params::random(&spec, 3);
        let qc = wsel::model::QuantConfig::quantized(&spec, vec![0.02; spec.n_q]);
        let scalar = wsel::model::Engine::new(&spec);
        let mut rng = Xoshiro256::new(11);
        let batch = 8usize;
        let xs: Vec<f32> = (0..batch * 32 * 32 * 3)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect();
        let m_scalar = bench("perf/forward_scalar_b8", 1, 5, || {
            black_box(scalar.forward(&p.tensors, &xs, batch, &qc, false));
        });
        m_scalar.report_throughput(batch as f64, "images");
        let par = wsel::model::ParallelEngine::new(&spec, &p.tensors, &qc, threads);
        let m_par = bench(&format!("perf/forward_parallel_t{threads}_b8"), 1, 5, || {
            black_box(par.forward_plain(&xs, batch));
        });
        m_par.report_throughput(batch as f64, "images");
        let fwd_speedup = m_scalar.median_ns as f64 / m_par.median_ns.max(1) as f64;
        println!("      -> parallel forward speedup vs scalar: {fwd_speedup:.1}x");
        let want = scalar.forward(&p.tensors, &xs, batch, &qc, false);
        let got = par.forward_plain(&xs, batch);
        assert_eq!(
            want.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "parallel executor must be bit-identical to the scalar reference"
        );
        // Acceptance gate: >= 2x forward throughput at 4+ threads.
        if perf_asserts_enabled() {
            assert!(
                fwd_speedup >= 2.0,
                "parallel forward must be >= 2x at {threads} threads (got {fwd_speedup:.2}x)"
            );
        } else {
            println!("      (forward speedup assertion skipped: <4 cores or WSEL_PERF_ASSERT=0)");
        }
    }

    // ---- block-sparse forward: structural skip vs dense -------------------
    // Block-structured pruning (whole SB-aligned k-row blocks zeroed
    // across all output columns) on the same synthetic stack: the
    // pack-time occupancy index lets the GEMM skip empty SB×SB weight
    // blocks structurally, so forward wall-clock finally scales with
    // prune ratio.  Swept at {0, 50, 75, 87.5}% nominal block sparsity;
    // bit-identity vs the scalar reference is asserted at every level,
    // and the >= 1.5x gate applies wherever the measured block-empty
    // fraction reaches 70% (4+ threads only).  The sweep is recorded as
    // BENCH_sparse_forward.json at the repo root.
    {
        use wsel::model::kernels::SB;
        use wsel::util::json::Json;
        let spec = wsel::model::ModelSpec::from_manifest_str(FWD_BENCH_MANIFEST)
            .expect("bench manifest");
        let p = wsel::model::Params::random(&spec, 7);
        let scalar = wsel::model::Engine::new(&spec);
        let mut rng = Xoshiro256::new(13);
        let batch = 8usize;
        let xs: Vec<f32> = (0..batch * 32 * 32 * 3)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect();
        // Drop `num` of every `den` SB-aligned k-row blocks of a conv's
        // K×N matrix (rows are (ky, kx, ci) taps, zeroed for all cout).
        let mask_for = |cv: &wsel::model::ConvOp, num: usize, den: usize| -> Vec<f32> {
            let kk = cv.k * cv.k * cv.cin;
            let mut mask = vec![1.0f32; cv.cout * cv.cin * cv.k * cv.k];
            for r in 0..kk {
                if (r / SB) % den >= num {
                    continue; // kept block
                }
                let ci = r % cv.cin;
                let pos = r / cv.cin;
                let kx = pos % cv.k;
                let ky = pos / cv.k;
                for o in 0..cv.cout {
                    mask[((o * cv.cin + ci) * cv.k + ky) * cv.k + kx] = 0.0;
                }
            }
            mask
        };
        let mut dense_median = 0u128;
        let mut levels: Vec<(String, f64, u64, u64, u128, f64)> = Vec::new();
        let mut last_report: Vec<wsel::model::ConvSkip> = Vec::new();
        for &(label, num, den) in
            &[("0", 0usize, 8usize), ("50", 4, 8), ("75", 6, 8), ("87.5", 7, 8)]
        {
            let mut qc = wsel::model::QuantConfig::quantized(&spec, vec![0.02; spec.n_q]);
            for cv in spec.convs() {
                qc.masks[cv.conv_idx] = Some(mask_for(cv, num, den));
            }
            let eng = wsel::model::ParallelEngine::new(&spec, &p.tensors, &qc, threads);
            // Structural skip must never change a bit of the output.
            let want = scalar.forward(&p.tensors, &xs, batch, &qc, false);
            let got = eng.forward_plain(&xs, batch);
            assert_eq!(
                want.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "sparse forward must stay bit-identical at {label}% block sparsity"
            );
            let rep = eng.sparsity_report(batch);
            let blocks: u64 = rep.iter().map(|r| r.sparsity.blocks_total).sum();
            let empty: u64 = rep.iter().map(|r| r.sparsity.blocks_empty).sum();
            let empty_frac = empty as f64 / blocks.max(1) as f64;
            let m_l = bench(
                &format!("perf/forward_sparse_{label}pct_t{threads}_b8"),
                1,
                5,
                || {
                    black_box(eng.forward_plain(&xs, batch));
                },
            );
            m_l.report_throughput(batch as f64, "images");
            if num == 0 {
                dense_median = m_l.median_ns;
            }
            let sp = dense_median as f64 / m_l.median_ns.max(1) as f64;
            println!(
                "      -> {empty}/{blocks} blocks empty ({:.1}%), speedup vs dense {sp:.2}x",
                empty_frac * 100.0
            );
            if perf_asserts_enabled() && empty_frac >= 0.70 {
                assert!(
                    sp >= 1.5,
                    "block-sparse forward must be >= 1.5x dense at {:.1}% block \
                     sparsity on {threads} threads (got {sp:.2}x)",
                    empty_frac * 100.0
                );
            }
            levels.push((label.to_string(), empty_frac, empty, blocks, m_l.median_ns, sp));
            last_report = rep;
        }
        if !perf_asserts_enabled() {
            println!("      (sparse speedup assertions skipped: <4 cores or WSEL_PERF_ASSERT=0)");
        }
        // Per-conv skip accounting at the deepest sweep level.
        let tbl: Vec<(usize, u64, u64, u64, u64)> = last_report
            .iter()
            .map(|r| {
                (
                    r.conv_idx,
                    r.sparsity.blocks_total,
                    r.sparsity.blocks_empty,
                    r.macs_skipped,
                    r.macs_dense,
                )
            })
            .collect();
        println!("{}", wsel::report::sparsity_table(&tbl).render());
        let json = Json::obj(vec![
            ("bench", Json::str("sparse_forward_sweep")),
            ("threads", Json::num(threads as f64)),
            ("batch", Json::num(batch as f64)),
            (
                "levels",
                Json::arr(levels.iter().map(|(label, frac, empty, blocks, ns, sp)| {
                    Json::obj(vec![
                        ("nominal_pct", Json::str(label)),
                        ("empty_fraction", Json::num(*frac)),
                        ("blocks_empty", Json::num(*empty as f64)),
                        ("blocks_total", Json::num(*blocks as f64)),
                        ("median_ns", Json::num(*ns as f64)),
                        ("speedup_vs_dense", Json::num(*sp)),
                    ])
                })),
            ),
        ]);
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("BENCH_sparse_forward.json");
        // Checksummed + atomic: a bench killed mid-write can't leave a
        // torn JSON behind, and a bit-rotted file is rejected on load.
        match wsel::util::artifact::write_json_atomic(&path, &json) {
            Ok(()) => println!("      wrote {}", path.display()),
            Err(e) => eprintln!("      could not write {}: {e}", path.display()),
        }
    }

    // ---- native train/eval backend: serial vs batch-parallel --------------
    // The PR-4 deliverable: the accuracy oracle and the QAT train step
    // through runtime::native::NativeBackend.  Before: one worker
    // (the serial per-batch cost every schedule candidate used to pay).
    // After: data-parallel across the batch with deterministic
    // image-order gradient reduction.  Must be bit-identical AND >= 2x
    // at 4+ threads.
    {
        use wsel::runtime::LrSchedule;
        let spec = wsel::model::ModelSpec::from_manifest_str(FWD_BENCH_MANIFEST)
            .expect("bench manifest");
        let p0 = wsel::model::Params::random(&spec, 5);
        let dense = CompressionState::dense(spec.n_conv);
        let lr = LrSchedule {
            base: 0.002,
            decay_at: 1.0,
        };
        let ckpt_dir = std::env::temp_dir().join("wsel_perf_native");
        let mk_rt = |t: usize| {
            let mut rt = wsel::runtime::ModelRuntime::from_spec_native(
                spec.clone(),
                p0.tensors.clone(),
                ckpt_dir.clone(),
            );
            rt.threads = t;
            rt.act_scales = vec![0.02; spec.n_q];
            rt
        };
        let steps = 2usize;
        let bs_train = spec.batch_train;
        let mut rt1 = mk_rt(1);
        let m_t1 = bench("perf/native_train_steps_t1", 1, 5, || {
            black_box(rt1.train_steps(&dense, true, lr, steps).expect("train"));
        });
        m_t1.report_throughput((steps * bs_train) as f64, "image-steps");
        let mut rtn = mk_rt(threads);
        let m_tn = bench(&format!("perf/native_train_steps_t{threads}"), 1, 5, || {
            black_box(rtn.train_steps(&dense, true, lr, steps).expect("train"));
        });
        m_tn.report_throughput((steps * bs_train) as f64, "image-steps");
        let train_speedup = m_t1.median_ns as f64 / m_tn.median_ns.max(1) as f64;
        println!("      -> native train-step speedup vs serial: {train_speedup:.1}x");

        // Bit-identity: fresh runtimes, same step count, any thread
        // count -> bitwise-equal parameters and momentum effects.
        {
            let mut a = mk_rt(1);
            let mut b = mk_rt(threads.max(2));
            let la = a.train_steps(&dense, true, lr, 3).expect("train a");
            let lb = b.train_steps(&dense, true, lr, 3).expect("train b");
            assert_eq!(la.to_bits(), lb.to_bits(), "train loss must be bit-identical");
            for (ta, tb) in a.params.iter().zip(&b.params) {
                assert_eq!(
                    ta.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    tb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "trained params must be bit-identical across thread counts"
                );
            }
        }

        // Evaluate throughput (the oracle's unit of cost, now native).
        let bs_eval = spec.batch_eval;
        let mut e1 = mk_rt(1);
        let m_e1 = bench("perf/native_evaluate_t1", 1, 5, || {
            black_box(
                e1.evaluate(&dense, true, wsel::data::Split::Val, 1)
                    .expect("eval"),
            );
        });
        m_e1.report_throughput(bs_eval as f64, "images");
        let mut en = mk_rt(threads);
        let m_en = bench(&format!("perf/native_evaluate_t{threads}"), 1, 5, || {
            black_box(
                en.evaluate(&dense, true, wsel::data::Split::Val, 1)
                    .expect("eval"),
            );
        });
        m_en.report_throughput(bs_eval as f64, "images");
        let eval_speedup = m_e1.median_ns as f64 / m_en.median_ns.max(1) as f64;
        println!("      -> native evaluate speedup vs serial: {eval_speedup:.1}x");

        // Acceptance gate: >= 2x train and eval throughput at 4+
        // threads (skipped on low-core runners / WSEL_PERF_ASSERT=0).
        if perf_asserts_enabled() {
            assert!(
                train_speedup >= 2.0,
                "native train step must be >= 2x at {threads} threads (got {train_speedup:.2}x)"
            );
            assert!(
                eval_speedup >= 2.0,
                "native evaluate must be >= 2x at {threads} threads (got {eval_speedup:.2}x)"
            );
        } else {
            println!(
                "      (native train/eval speedup assertions skipped: <4 cores or WSEL_PERF_ASSERT=0)"
            );
        }
    }

    // ---- serving layer: micro-batching under sustained load ---------------
    // Plan registry + async micro-batcher over ParallelEngine.  First
    // the correctness gate — per-request logits bit-identical to the
    // single-image forward whatever wave packing the batcher picked —
    // then the sustained-load grid (dense + 87.5% block-sparse ×
    // Poisson rates × {batch1, batched}) emitted as BENCH_serving.json.
    // Perf gate: saturated batched throughput >= 2x batch1 at the same
    // thread count.
    {
        use wsel::serve::bench::{request_images, standard_registry, wave_logits};
        use wsel::serve::{BatchPolicy, ServeBenchCfg};

        let reg = standard_registry(threads, 0x5EED).expect("serving registry");
        let imgs = request_images(0x5EED, 16);
        for variant in ["dense", "sparse87"] {
            let v = reg.get(variant).expect("installed");
            let eng = &v.engine;
            let refs: Vec<Vec<u32>> = imgs
                .iter()
                .map(|x| {
                    eng.forward_plain(x, 1)
                        .logits
                        .iter()
                        .map(|v| v.to_bits())
                        .collect()
                })
                .collect();
            for policy in [
                BatchPolicy::batch1(),
                BatchPolicy {
                    max_batch: 8,
                    max_wait_us: 200,
                },
            ] {
                let outs = wave_logits(&reg, variant, &imgs, policy);
                for (i, r) in outs.iter().enumerate() {
                    let got: Vec<u32> = r
                        .as_ref()
                        .expect("serve reply")
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    assert_eq!(
                        refs[i],
                        got,
                        "{variant}: wave logits differ from single-image forward (img {i}, {})",
                        policy.label()
                    );
                }
            }
        }
        println!("bench perf/serving: per-request logits bit-identical across wave packings");

        // Sustained-load grid (quick preset keeps bench runtime sane;
        // the CLI's `wsel serve-bench` runs the full standard preset).
        let cfg = ServeBenchCfg::quick(threads);
        let (json, cells) = wsel::serve::run_serve_bench(&cfg).expect("serve bench");
        for c in &cells {
            println!(
                "bench perf/serving/{:8} rate={:>9} {:9} p50={:>10} p95={:>10} p99={:>10}  {:9.1} img/s  wave={:.2}",
                c.variant,
                c.rate_label(),
                c.policy.label(),
                wsel::bench::fmt_ns((c.p50_us * 1e3) as u128),
                wsel::bench::fmt_ns((c.p95_us * 1e3) as u128),
                wsel::bench::fmt_ns((c.p99_us * 1e3) as u128),
                c.images_per_s,
                c.mean_wave,
            );
        }
        let speedup = |variant: &str| {
            let sat = |b1: bool| {
                cells.iter().find(|c| {
                    c.variant == variant
                        && !c.rate.is_finite()
                        && (c.policy.max_batch == 1) == b1
                })
            };
            match (sat(true), sat(false)) {
                (Some(base), Some(batched)) if base.images_per_s > 0.0 => {
                    batched.images_per_s / base.images_per_s
                }
                _ => 0.0,
            }
        };
        let dense_speedup = speedup("dense");
        println!(
            "      -> saturated batched vs batch1 images/s: dense {dense_speedup:.2}x, sparse87 {:.2}x",
            speedup("sparse87")
        );
        if perf_asserts_enabled() {
            assert!(
                dense_speedup >= 2.0,
                "micro-batching must be >= 2x batch1 images/s when saturated at {threads} threads (got {dense_speedup:.2}x)"
            );
        } else {
            println!(
                "      (serving >=2x batching assertion skipped: <4 cores or WSEL_PERF_ASSERT=0)"
            );
        }
        let path =
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_serving.json");
        match wsel::util::artifact::write_json_atomic(&path, &json) {
            Ok(()) => println!("      wrote {}", path.display()),
            Err(e) => eprintln!("      could not write {}: {e}", path.display()),
        }
    }

    // ---- schedule search: exhaustive sweep vs successive halving ----------
    // The §4.3 oracle-efficiency deliverable, on the built-in lenet5
    // (native backend, no artifacts): one trained checkpoint, one
    // infeasible candidate menu (δ < 0 puts the accept threshold above
    // 1.0, so every trial is rejected — the worst-case regime the rung
    // pyramid is built for, and the only one with a deterministic
    // fine-tune bill).  The legacy exhaustive sweep pays the full
    // menu × fine_tune_steps per layer; --halving-rungs 4 pays the
    // rung pyramid.  Gates (4+ cores, WSEL_PERF_ASSERT!=0): halving
    // spends <= 50% of the exhaustive fine-tune steps, lands within
    // the paper's default accuracy budget (0.03) of the exhaustive
    // result, and a second run against the warm persistent accuracy
    // cache performs ZERO oracle fine-tunes.  Always asserted: the
    // warm-cache rerun is bit-identical to the first halving run.
    {
        use wsel::coordinator::{Pipeline, PipelineParams};
        use wsel::schedule::{energy_prioritized_with, AccCache};
        use wsel::util::json::Json;

        let spec = wsel::model::ModelSpec::builtin("lenet5").expect("builtin lenet5");
        let p0 = wsel::model::Params::random(&spec, 11);
        let dir = std::env::temp_dir().join("wsel_perf_schedule_search");
        let _ = std::fs::remove_dir_all(&dir);
        let rt = wsel::runtime::ModelRuntime::from_spec_native(
            spec.clone(),
            p0.tensors.clone(),
            dir.clone(),
        );
        let mut pp = PipelineParams::quick();
        pp.threads = threads;
        let mut p = Pipeline::from_runtime(rt, pp);
        p.train_baseline().expect("train baseline");
        p.profile().expect("profile");
        assert!(
            p.save_search_state("bench-sched-base"),
            "snapshot trained state"
        );

        let mut sp = ScheduleParams {
            prune_ratios: vec![0.95, 0.9, 0.85, 0.8],
            k_targets: vec![4, 6, 8],
            delta: -1.0,
            fine_tune_steps: 8,
            acc0: p.acc0,
            ..Default::default()
        };
        sp.greedy.threads = threads;
        let n_conv = spec.n_conv;

        assert!(p.load_search_state("bench-sched-base"));
        let (ft0, ev0) = (p.ft_steps_total, p.eval_count);
        let t0 = std::time::Instant::now();
        let ex = energy_prioritized_with(&mut p, n_conv, &sp, None, None)
            .expect("exhaustive search")
            .expect("no trial budget");
        let ex_ns = t0.elapsed().as_nanos();
        let (ex_ft, ex_ev) = (p.ft_steps_total - ft0, p.eval_count - ev0);
        println!(
            "bench perf/schedule_search_exhaustive   {:>10}  ft_steps={ex_ft:<4} evals={ex_ev}",
            wsel::bench::fmt_ns(ex_ns)
        );

        let mut sp_h = sp.clone();
        sp_h.halving_rungs = 4;
        sp_h.rung_frac = 0.1;
        let cache_path = dir.join("acc_cache.json");
        let mut cache = AccCache::at(cache_path.clone()).expect("accuracy cache");
        assert!(p.load_search_state("bench-sched-base"));
        let (ft1, ev1) = (p.ft_steps_total, p.eval_count);
        let t1 = std::time::Instant::now();
        let hv = energy_prioritized_with(&mut p, n_conv, &sp_h, None, Some(&mut cache))
            .expect("halving search")
            .expect("no trial budget");
        let hv_ns = t1.elapsed().as_nanos();
        let (hv_ft, hv_ev) = (p.ft_steps_total - ft1, p.eval_count - ev1);
        println!(
            "bench perf/schedule_search_halving      {:>10}  ft_steps={hv_ft:<4} evals={hv_ev}  ({} misses -> cache)",
            wsel::bench::fmt_ns(hv_ns),
            cache.misses
        );

        // Warm rerun: fresh cache handle over the same file, oracle
        // restored to the same trained checkpoint.
        let mut warm_cache = AccCache::at(cache_path.clone()).expect("warm cache");
        assert!(p.load_search_state("bench-sched-base"));
        let (ft2, ev2) = (p.ft_steps_total, p.eval_count);
        let t2 = std::time::Instant::now();
        let wm = energy_prioritized_with(&mut p, n_conv, &sp_h, None, Some(&mut warm_cache))
            .expect("warm search")
            .expect("no trial budget");
        let wm_ns = t2.elapsed().as_nanos();
        let (wm_ft, wm_ev) = (p.ft_steps_total - ft2, p.eval_count - ev2);
        println!(
            "bench perf/schedule_search_warm_cache   {:>10}  ft_steps={wm_ft:<4} evals={wm_ev}  ({} hits / {} misses)",
            wsel::bench::fmt_ns(wm_ns),
            warm_cache.hits,
            warm_cache.misses
        );
        assert_eq!(
            wm.to_json().to_string(),
            hv.to_json().to_string(),
            "warm-cache rerun must be bit-identical to the first halving run"
        );

        if perf_asserts_enabled() {
            assert!(
                2 * hv_ft <= ex_ft,
                "halving must spend <= 50% of the exhaustive fine-tune bill (got {hv_ft} vs {ex_ft})"
            );
            assert!(
                hv.final_accuracy >= ex.final_accuracy - 0.03,
                "halving accuracy must land within the paper's budget of the exhaustive \
                 result (got {:.4} vs {:.4})",
                hv.final_accuracy,
                ex.final_accuracy
            );
            assert_eq!(wm_ft, 0, "warm cache must eliminate every oracle fine-tune");
            assert_eq!(warm_cache.misses, 0, "warm cache must serve every trial");
            assert!(warm_cache.hits > 0);
        } else {
            println!(
                "      (schedule-search oracle-cost assertions skipped: <4 cores or WSEL_PERF_ASSERT=0)"
            );
        }

        let json = Json::obj(vec![
            ("bench", Json::str("schedule_search")),
            ("model", Json::str("lenet5")),
            ("n_conv", Json::num(n_conv as f64)),
            ("candidates_per_layer", Json::num(12.0)),
            ("fine_tune_steps", Json::num(sp.fine_tune_steps as f64)),
            ("halving_rungs", Json::num(sp_h.halving_rungs as f64)),
            ("rung_frac", Json::num(sp_h.rung_frac)),
            (
                "exhaustive",
                Json::obj(vec![
                    ("ft_steps", Json::num(ex_ft as f64)),
                    ("evals", Json::num(ex_ev as f64)),
                    ("median_ns", Json::num(ex_ns as f64)),
                    ("final_accuracy", Json::num(ex.final_accuracy)),
                ]),
            ),
            (
                "halving",
                Json::obj(vec![
                    ("ft_steps", Json::num(hv_ft as f64)),
                    ("evals", Json::num(hv_ev as f64)),
                    ("median_ns", Json::num(hv_ns as f64)),
                    ("final_accuracy", Json::num(hv.final_accuracy)),
                    ("ft_fraction_of_exhaustive", Json::num(hv_ft as f64 / ex_ft.max(1) as f64)),
                ]),
            ),
            (
                "warm_cache",
                Json::obj(vec![
                    ("ft_steps", Json::num(wm_ft as f64)),
                    ("evals", Json::num(wm_ev as f64)),
                    ("median_ns", Json::num(wm_ns as f64)),
                    ("cache_hits", Json::num(warm_cache.hits as f64)),
                    ("cache_misses", Json::num(warm_cache.misses as f64)),
                ]),
            ),
        ]);
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("BENCH_schedule_search.json");
        match wsel::util::artifact::write_json_atomic(&path, &json) {
            Ok(()) => println!("      wrote {}", path.display()),
            Err(e) => eprintln!("      could not write {}: {e}", path.display()),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- pipeline-dependent paths (need artifacts) ------------------------
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("lenet5", 120, 40).expect("pipeline");

    // int8 mirror engine forward.
    let spec = p.rt.spec.clone();
    let eng = wsel::model::Engine::new(&spec);
    let qc = wsel::model::QuantConfig::quantized(&spec, p.rt.act_scales.clone());
    let (xs, _) = wsel::data::batch(7, wsel::data::Split::Val, 0, 8, 10);
    let m = bench("perf/mirror_engine_forward_b8", 1, 10, || {
        black_box(eng.forward(&p.rt.params, &xs, 8, &qc, false));
    });
    m.report_throughput(8.0, "images");

    // Greedy elimination (proxy mode) on real stats.
    use wsel::schedule::LayerModeler;
    let dense = CompressionState::dense(spec.n_conv);
    let usage = p.usage(1, &dense);
    let le = p.layer_energy_model(1);
    let m = bench("perf/greedy_eliminate_32_to_16", 1, 20, || {
        let set0 = wsel::selection::safe_initial_set(&usage, &le, 32);
        let mut st = CompressionState::dense(spec.n_conv);
        struct Null;
        impl wsel::selection::AccuracyOracle for Null {
            fn accuracy(&mut self, _: &CompressionState) -> f64 {
                1.0
            }
            fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
        }
        let gp = wsel::selection::GreedyParams::default();
        black_box(wsel::selection::greedy_backward_eliminate(
            set0, &usage, &le, &mut Null, &mut st, 1, &gp,
        ));
    });
    m.report();

    // PJRT eval latency (the oracle's unit of cost).
    let m = bench("perf/pjrt_eval_batch128", 1, 5, || {
        black_box(
            p.rt.evaluate(&dense, true, wsel::data::Split::Val, 1)
                .expect("eval"),
        );
    });
    m.report_throughput(128.0, "images");

    // Data generation (feeds every train step).
    let m = bench("perf/datagen_batch32", 1, 10, || {
        black_box(wsel::data::batch(7, wsel::data::Split::Train, 0, 32, 10));
    });
    m.report_throughput(32.0, "images");
}
