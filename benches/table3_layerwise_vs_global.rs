//! Table 3 (scaled) — layer-wise vs global compression at matched
//! (prune ratio, K): the layer-wise strategy must achieve at least the
//! energy saving of the global one with better (or equal) accuracy,
//! especially at the aggressive K = 16 point where the paper reports the
//! global method collapsing (89.4% vs 82.0%).

use wsel::bench::scenarios;
use wsel::report::{pct, Table};
use wsel::schedule::{global_uniform, Config, ScheduleParams};

fn main() {
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    // LeNet-5 at bench scale: trains to usable accuracy in ~600 steps so
    // accuracy comparisons carry signal (resnet20 needs far longer).
    let mut p = scenarios::prepared("lenet5", 600, 150).expect("pipeline");
    let base = p.base_energy.clone().unwrap();
    let trained = p.checkpoint();
    let n_conv = p.rt.spec.n_conv;
    let layers: Vec<usize> = (0..n_conv).collect();

    let mut t = Table::new(
        "Table 3 (scaled: LeNet-5; paper @K16: global 50.1%/82.0% vs layer-wise 51.8%/89.4%)",
        &["method", "ratio", "K", "energy saving", "accuracy"],
    );

    let mut results = Vec::new();
    for (k, ratio) in [(32usize, 0.5f64), (16, 0.5)] {
        // Global.
        p.restore(trained.clone());
        let g = global_uniform(
            &mut p,
            n_conv,
            &layers,
            Config {
                prune_ratio: ratio,
                k_target: k,
            },
            20,
            false,
        );
        let ge = p.compute_network_energy(&g.state);
        let g_saving = base.saving_vs(&ge);
        t.row(&[
            "global".into(),
            format!("{ratio}"),
            k.to_string(),
            pct(g_saving),
            pct(g.final_accuracy),
        ]);

        // Layer-wise (ours), constrained to the same (ratio, K) menu.
        p.restore(trained.clone());
        let sp = ScheduleParams {
            prune_ratios: vec![ratio],
            k_targets: vec![k],
            fine_tune_steps: 20,
            delta: 0.06,
            ..Default::default()
        };
        let lw = p.compress(sp).expect("compress");
        let le = p.compute_network_energy(&lw.state);
        let l_saving = base.saving_vs(&le);
        t.row(&[
            "layer-wise".into(),
            format!("{ratio}"),
            k.to_string(),
            pct(l_saving),
            pct(lw.final_accuracy),
        ]);
        results.push((k, g_saving, g.final_accuracy, l_saving, lw.final_accuracy));
    }
    println!("{}", t.render());

    // Paper-shape assertion: at matched configs the layer-wise strategy
    // wins the energy-accuracy trade-off (sum of normalized advantages).
    for (k, gs, ga, ls, la) in results {
        let adv = (ls - gs) + (la - ga);
        println!("K={k}: layer-wise advantage (saving+acc) = {adv:+.3}");
        assert!(
            adv > -0.02,
            "layer-wise must not lose the combined trade-off at K={k}"
        );
    }
}
