//! Table 1 (scaled) — accuracy / energy saving / selected weights for
//! origin vs PowerPruning vs Ours, on LeNet-5 at bench scale.
//!
//! Full-scale numbers (all three models, long training) live in
//! EXPERIMENTS.md and come from `wsel compress` / the compress_lenet
//! example; this bench keeps the comparison runnable in minutes and
//! asserts the paper's orderings: Ours saves more energy than the
//! PowerPruning baseline at a smaller weight set, with comparable
//! accuracy.

use wsel::bench::scenarios;
use wsel::report::{pct, Table};
use wsel::schedule::ScheduleParams;

fn main() {
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("lenet5", 600, 150).expect("pipeline");
    let acc0 = p.acc0;
    let base = p.base_energy.clone().unwrap();
    let trained = p.checkpoint();

    // Ours.
    let sp = ScheduleParams {
        fine_tune_steps: 25,
        delta: 0.04,
        ..Default::default()
    };
    let ours = p.compress(sp).expect("compress");
    let ours_e = p.compute_network_energy(&ours.state);
    let ours_saving = base.saving_vs(&ours_e);
    let ours_k = ours
        .state
        .layers
        .iter()
        .filter_map(|l| l.wset.as_ref().map(|s| s.len()))
        .max()
        .unwrap_or(256);

    // PowerPruning baseline.
    p.restore(trained);
    let glob = wsel::energy::uniform_weight_energy(
        &mut p.maclib,
        &p.cap_model,
        256,
        9,
        1,
    );
    let pp_state =
        wsel::selection::powerpruning::powerpruning_state(p.rt.spec.n_conv, &glob, 32, 0.5);
    let (pp_acc, pp_saving) = p.evaluate_state(&pp_state, 25).expect("baseline");

    let mut t = Table::new(
        "Table 1 (scaled: LeNet-5 / synthetic-CIFAR-10)",
        &["method", "accuracy", "energy saving", "weights", "paper"],
    );
    t.row(&[
        "origin".into(),
        pct(acc0),
        "-".into(),
        "256".into(),
        "78.9% / - / 256".into(),
    ]);
    t.row(&[
        "PowerPruning".into(),
        pct(pp_acc),
        pct(pp_saving),
        "32".into(),
        "78.4% / 46.0% / 32".into(),
    ]);
    t.row(&[
        "Ours".into(),
        pct(ours.final_accuracy),
        pct(ours_saving),
        ours_k.to_string(),
        "77.8% / 53.3% / 16".into(),
    ]);
    println!("{}", t.render());

    // Paper-shape assertions.
    assert!(
        ours_saving > pp_saving,
        "ours must out-save the PowerPruning baseline: {ours_saving:.3} vs {pp_saving:.3}"
    );
    assert!(
        ours_k <= 16,
        "ours must reach the smaller (16-value) weight set"
    );
    assert!(
        ours.final_accuracy >= acc0 - 0.05,
        "accuracy must stay within budget: {acc0:.3} -> {:.3}",
        ours.final_accuracy
    );
}
