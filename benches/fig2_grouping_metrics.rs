//! Figure 2 — validation of the MSB × Hamming-distance grouping metrics.
//!
//! (a) MAC power grows ~monotonically with the Hamming distance of the
//!     partial-sum transition; (b) transitions between similar MSB
//!     positions are cheap (diagonal of the MSB-pair matrix), crossing to
//!     higher MSBs is expensive.  Both are asserted, and the grouping
//!     stability ratio of the adopted uniform 10×5 partition is compared
//!     against MSB-only / HW-only ablations.

use wsel::bench::bench;
use wsel::energy::transition_energy;
use wsel::gates::CapModel;
use wsel::report;
use wsel::systolic::MacLib;
use wsel::transitions::{stability_ratio, Grouping};
use wsel::util::rng::Xoshiro256;

fn main() {
    let cap = CapModel::default();
    let mut lib = MacLib::new();

    // ---- (a) power vs HD ------------------------------------------------
    let base = 0b01_0101_0101_0101_0101_0101u32 as i32;
    let hds = [0usize, 1, 2, 4, 8, 12, 16, 20];
    let mut powers = Vec::new();
    for &hd in &hds {
        let flip: u32 = (0..hd).map(|i| 1u32 << i).sum();
        let e = transition_energy(&mut lib, &cap, 37, 11, base, base ^ flip as i32, 128);
        powers.push(e * cap.freq_hz);
    }
    println!(
        "{}",
        report::series(
            "Fig.2a — MAC power (W) vs psum-transition Hamming distance",
            &hds.iter().map(|&h| h as f64).collect::<Vec<_>>(),
            &powers
        )
    );
    assert!(
        powers[hds.len() - 1] > powers[0],
        "HD20 must cost more than HD0"
    );
    // Approximate monotonicity: each doubling of HD should not reduce power
    // by more than noise.
    for w in powers.windows(2) {
        assert!(w[1] > w[0] * 0.9, "power vs HD strongly non-monotone: {powers:?}");
    }

    // ---- (b) MSB-pair matrix ---------------------------------------------
    let bins = 10;
    let mut hm = vec![0.0f64; bins * bins];
    let mut diag = 0.0;
    let mut offdiag_hi = 0.0;
    for i in 0..bins {
        for j in 0..bins {
            let p1 = 1i32 << (2 + i * 2);
            let p2 = 1i32 << (2 + j * 2);
            let p = transition_energy(&mut lib, &cap, 37, 11, p1, p2, 64) * cap.freq_hz;
            hm[i * bins + j] = p;
            if i == j {
                diag += p;
            } else if i.abs_diff(j) >= 5 {
                offdiag_hi += p;
            }
        }
    }
    println!(
        "{}",
        report::heatmap("Fig.2b — avg power across MSB-position pairs", &hm, bins)
    );
    let diag_mean = diag / bins as f64;
    let far_mean = offdiag_hi / (2.0 * (0..bins).map(|i| (bins - 5).saturating_sub(i).min(1)).sum::<usize>().max(1) as f64).max(1.0);
    println!("diagonal mean {diag_mean:.3e} W, far-off-diagonal mean {far_mean:.3e} W");
    assert!(
        far_mean > diag_mean,
        "distant-MSB transitions must exceed same-MSB transitions"
    );

    // ---- Grouping quality (stability ratio, paper §3.1.1) ----------------
    let mut rng = Xoshiro256::new(4);
    let mut sampled: Vec<(u32, f64)> = Vec::new();
    for _ in 0..3000 {
        let v = (rng.next_u64() & 0x3F_FFFF) as u32;
        let flip = 1u32 << rng.below(22);
        let e = transition_energy(&mut lib, &cap, 17, 5, v as i32, (v ^ flip) as i32, 16);
        sampled.push((v, e));
    }
    for grouping in [Grouping::MsbHamming, Grouping::MsbOnly, Grouping::HammingOnly] {
        let labeled: Vec<(usize, f64)> =
            sampled.iter().map(|&(v, e)| (grouping.group(v), e)).collect();
        println!(
            "stability ratio ({grouping:?}): {:.2}",
            stability_ratio(&labeled)
        );
    }

    // Perf: transition probe latency.
    let m = bench("fig2/transition_probe_64step", 2, 10, || {
        wsel::bench::black_box(transition_energy(&mut lib, &cap, 37, 11, base, base ^ 0xFF, 64));
    });
    m.report();
}
