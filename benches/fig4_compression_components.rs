//! Figure 4 — contribution of the compression components on a trained
//! model: pruning only, weight restriction only, and both combined.
//! The paper's claim: both contribute independently and compose to a
//! substantially larger reduction.

use wsel::bench::scenarios;
use wsel::report::{bar_chart, pct};
use wsel::selection::{safe_initial_set, CompressionState, LayerConfig};

fn main() {
    let Some(_) = scenarios::artifacts_dir() else {
        return;
    };
    let mut p = scenarios::prepared("lenet5", 400, 100).expect("pipeline");
    let n_conv = p.rt.spec.n_conv;
    let dense = CompressionState::dense(n_conv);
    let base = p.compute_network_energy(&dense);

    // Restriction-only: greedy-style 16-value set per layer (proxy path).
    let mut restricted = CompressionState::dense(n_conv);
    for ci in 0..n_conv {
        use wsel::schedule::LayerModeler;
        let usage = p.usage(ci, &dense);
        let le = p.layer_energy_model(ci);
        let set0 = safe_initial_set(&usage, &le, 32);
        // Proxy-only elimination to 16 (no oracle in this figure).
        let mut state_tmp = CompressionState::dense(n_conv);
        let gp = wsel::selection::GreedyParams {
            k_target: 16,
            check_every_removal: false,
            ..Default::default()
        };
        struct Null;
        impl wsel::selection::AccuracyOracle for Null {
            fn accuracy(&mut self, _: &CompressionState) -> f64 {
                1.0
            }
            fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
        }
        let (set, _) = wsel::selection::greedy_backward_eliminate(
            set0, &usage, &le, &mut Null, &mut state_tmp, ci, &gp,
        );
        restricted.layers[ci].wset = Some(set);
    }
    let e_restrict = p.compute_network_energy(&restricted);

    // Pruning-only (0.5 everywhere).
    let pruned = CompressionState {
        layers: (0..n_conv)
            .map(|_| LayerConfig {
                prune_ratio: 0.5,
                wset: None,
            })
            .collect(),
    };
    let e_prune = p.compute_network_energy(&pruned);

    // Combined.
    let mut combined = restricted.clone();
    for l in &mut combined.layers {
        l.prune_ratio = 0.5;
    }
    let e_comb = p.compute_network_energy(&combined);

    let labels = vec![
        "pruning only (0.5)".to_string(),
        "restriction only (K=16)".to_string(),
        "combined".to_string(),
    ];
    let savings = vec![
        base.saving_vs(&e_prune),
        base.saving_vs(&e_restrict),
        base.saving_vs(&e_comb),
    ];
    println!(
        "{}",
        bar_chart(
            "Fig.4 — energy saving by compression component (LeNet-5)",
            &labels,
            &savings,
            40
        )
    );
    println!(
        "pruning {} | restriction {} | combined {}",
        pct(savings[0]),
        pct(savings[1]),
        pct(savings[2])
    );
    assert!(savings[0] > 0.05, "pruning alone must save energy");
    assert!(savings[1] > 0.05, "restriction alone must save energy");
    assert!(
        savings[2] > savings[0].max(savings[1]) + 0.02,
        "components must compose: {savings:?}"
    );
}
