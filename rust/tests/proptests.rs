//! Cross-module property tests (testutil-based, no artifacts needed).

use wsel::gates::{CapModel, TraceSim};
use wsel::mac::unit::{decode_psum, mac_ref};
use wsel::mac::{build_mac, specialize_mac};
use wsel::quant::{magnitude_mask, quantize_restricted, WeightSet};
use wsel::systolic::{matmul_tiled, passes_of, simulate_tile};
use wsel::testutil::cases;
use wsel::transitions::{group_of, N_GROUPS};

/// Systolic tile schedule reproduces arbitrary-shape integer matmuls
/// (when products fit the 22-bit column accumulators).
#[test]
fn prop_systolic_matmul_equals_reference() {
    cases(25, 0xA11CE, |g| {
        let m = g.usize_in(1, 90);
        let k = g.usize_in(1, 90);
        let n = g.usize_in(1, 40);
        // Small codes: |acc| <= 90*8*8 << 2^21.
        let x: Vec<i8> = (0..m * k).map(|_| (g.rng.below(17) as i8) - 8).collect();
        let w: Vec<i8> = (0..k * n).map(|_| (g.rng.below(17) as i8) - 8).collect();
        let y = matmul_tiled(&x, &w, m, k, n);
        let mi = g.usize_in(0, m - 1);
        let ci = g.usize_in(0, n - 1);
        let mut acc = 0i64;
        for r in 0..k {
            acc += x[mi * k + r] as i64 * w[r * n + ci] as i64;
        }
        assert_eq!(y[mi * n + ci] as i64, acc);
    });
}

/// Tile passes partition the iteration space: accumulating per-pass
/// partials equals the one-shot result.
#[test]
fn prop_pass_accumulation_associative() {
    cases(10, 0xB0B, |g| {
        let m = g.usize_in(1, 70);
        let k = g.usize_in(65, 130); // force >= 2 k-tiles
        let n = g.usize_in(1, 70);
        let x: Vec<i8> = (0..m * k).map(|_| (g.rng.below(9) as i8) - 4).collect();
        let w: Vec<i8> = (0..k * n).map(|_| (g.rng.below(9) as i8) - 4).collect();
        let full = matmul_tiled(&x, &w, m, k, n);
        // Manual re-accumulation in a different pass order (m-major).
        let mut y = vec![0i32; m * n];
        let mut passes = passes_of(m, k, n);
        passes.reverse();
        let mut partial = vec![0i32; 64 * 64];
        for pass in passes {
            for mi in 0..pass.mh {
                for c in 0..pass.nw {
                    partial[mi * pass.nw + c] = y[(pass.m0 + mi) * n + (pass.n0 + c)];
                }
            }
            simulate_tile(&x, &w, k, n, &pass, &mut partial[..pass.mh * pass.nw]);
            for mi in 0..pass.mh {
                for c in 0..pass.nw {
                    y[(pass.m0 + mi) * n + (pass.n0 + c)] = partial[mi * pass.nw + c];
                }
            }
        }
        assert_eq!(y, full, "pass order must not change the result");
    });
}

/// Specialized MAC == generic MAC == software reference, on random
/// weights and streams.
#[test]
fn prop_mac_specialization_sound() {
    let generic = build_mac();
    cases(12, 0xC0DE, |g| {
        let w = g.rng.code();
        let spec = specialize_mac(&generic, w);
        let mut sim = TraceSim::new(&spec.netlist);
        for _ in 0..20 {
            let a = g.rng.code();
            let p = (g.rng.below(1 << 22) as i64 - (1 << 21)) as i32;
            let out = sim.eval_single(&spec.netlist, &spec.pack_step(a, p));
            assert_eq!(decode_psum(&out), mac_ref(a, w, p), "a={a} w={w} p={p}");
        }
    });
}

/// Gate-count of the specialized MAC is bounded by the generic MAC and
/// monotone-ish in weight bit count (structural sanity of const-prop).
#[test]
fn prop_specialization_shrinks() {
    let generic = build_mac();
    let g_full = generic.netlist.gate_count();
    cases(30, 0xDEAD, |g| {
        let w = g.rng.code();
        let spec = specialize_mac(&generic, w);
        assert!(spec.netlist.gate_count() < g_full);
        spec.netlist.validate().expect("valid");
    });
}

/// Pruning + restricted quantization: pruned fraction exact, all codes
/// in set, scale positive, projection idempotent under re-application.
#[test]
fn prop_quantize_restricted_invariants() {
    cases(40, 0xFEED, |g| {
        let n = g.usize_in(8, 600);
        let w = g.vec_f32(n, -2.0, 2.0);
        let ratio = g.usize_in(0, 9) as f64 / 10.0;
        let mask = magnitude_mask(&w, ratio);
        assert_eq!(
            mask.iter().filter(|&&m| m == 0.0).count(),
            (n as f64 * ratio).floor() as usize
        );
        let mut set = g.weight_set(24);
        if !set.contains(0) {
            let mut codes = set.codes().to_vec();
            codes.push(0);
            set = WeightSet::new(codes);
        }
        let (codes, s) = quantize_restricted(&w, Some(&mask), Some(&set));
        assert!(s > 0.0);
        for &c in &codes {
            assert!(set.contains(c as i32));
        }
        // Idempotence: projecting already-projected codes is identity.
        for &c in &codes {
            assert_eq!(set.project(c as i32), c as i32);
        }
    });
}

/// Grouping is total, stable, and respects the MSB/HW construction on
/// random patterns.
#[test]
fn prop_grouping_structure() {
    cases(100, 0x9009, |g| {
        let v = (g.rng.next_u64() & 0x3F_FFFF) as u32;
        let grp = group_of(v);
        assert!(grp < N_GROUPS);
        assert_eq!(grp, group_of(v), "stable");
        // Flipping a bit BELOW the msb never changes the MSB bin.
        let msb = 32 - v.leading_zeros();
        if msb > 1 {
            let flip = 1u32 << g.usize_in(0, (msb - 2) as usize);
            let grp2 = group_of(v | flip);
            assert_eq!(grp / 5, grp2 / 5, "msb bin must be invariant");
        }
    });
}

/// The toggle model is additive: concatenating two traces yields the sum
/// of their toggles plus the boundary transition.
#[test]
fn prop_toggle_additivity() {
    let mac = build_mac();
    cases(8, 0xADD, |g| {
        let steps: Vec<Vec<bool>> = (0..100)
            .map(|_| (0..mac.netlist.inputs.len()).map(|_| g.bool()).collect())
            .collect();
        let mut sim_whole = TraceSim::new(&mac.netlist);
        sim_whole.run_trace(&mac.netlist, &steps);
        let mut sim_parts = TraceSim::new(&mac.netlist);
        let cut = g.usize_in(1, 99);
        sim_parts.run_trace_continue(&mac.netlist, &steps[..cut]);
        sim_parts.run_trace_continue(&mac.netlist, &steps[cut..]);
        assert_eq!(sim_whole.toggles, sim_parts.toggles);
        assert_eq!(sim_whole.steps, 100);
    });
}

/// CapModel energy is monotone in toggles and zero-cycle traces report
/// zero energy.
#[test]
fn prop_power_model_sane() {
    let mac = build_mac();
    let cap = CapModel::default();
    let mut sim = TraceSim::new(&mac.netlist);
    let rep0 = cap.report(&mac.netlist, &sim);
    assert_eq!(rep0.cycles, 0);
    assert_eq!(rep0.energy_j, 0.0);
    cases(6, 0x50F7, |g| {
        let steps: Vec<Vec<bool>> = (0..64)
            .map(|_| (0..mac.netlist.inputs.len()).map(|_| g.bool()).collect())
            .collect();
        let mut s1 = TraceSim::new(&mac.netlist);
        s1.run_trace(&mac.netlist, &steps[..32]);
        let e1 = cap.report(&mac.netlist, &s1).energy_j;
        let mut s2 = TraceSim::new(&mac.netlist);
        s2.run_trace(&mac.netlist, &steps);
        let e2 = cap.report(&mac.netlist, &s2).energy_j;
        assert!(e2 >= e1, "longer trace cannot cost less: {e1} vs {e2}");
    });
}
