//! Fault-tolerance integration tests — fully offline, native backend:
//!
//! * kill-and-resume bit-identity: a run hard-killed at an arbitrary
//!   step and resumed by a fresh process is bit-identical (final
//!   params, eval accuracy) to an uninterrupted run, at 1/2/5 threads,
//! * checkpointing itself perturbs nothing: a checkpointed
//!   uninterrupted run matches the plain `train_steps` path bit for bit,
//! * divergence rollback: a scripted backend that goes NaN recovers via
//!   rollback + lr backoff under `ResumeOpts`, and still hard-errors on
//!   the historical plain path,
//! * corrupted checkpoints (bit flip, truncation) are rejected with an
//!   error naming the file and the reason — never silently adopted,
//! * worker panics surface as a structured `PoisonedBatch` error naming
//!   the poisoned indices instead of aborting the process.

use std::path::PathBuf;
use wsel::data::Split;
use wsel::model::{ModelSpec, Params};
use wsel::runtime::{Backend, LrSchedule, ModelRuntime, ResumeOpts, RtCtx};
use wsel::selection::CompressionState;
use wsel::util::threadpool::{parallel_map, try_parallel_map};

/// Miniature offline spec (same shape family as the native-backend
/// tests): conv → pool → residual conv → gap → fc, tiny batches.
const FT_TINY: &str = r#"{
  "model": "fttiny", "n_classes": 4, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 4, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 32, "wout": 32},
    {"op": "maxpool2"},
    {"op": "save"},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 4, "cout": 4, "k": 3, "stride": 1, "pad": 1,
     "relu": false, "hin": 16, "win": 16, "hout": 16, "wout": 16},
    {"op": "add_saved", "relu": true, "proj": null},
    {"op": "gap"},
    {"op": "fc", "name": "fc0", "w": 4, "b": 5, "q_idx": 2,
     "din": 4, "dout": 4, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [4, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [4], "kind": "bias"},
    {"name": "conv1.w", "shape": [4, 4, 3, 3], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [4], "kind": "bias"},
    {"name": "fc0.w", "shape": [4, 4], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [4], "kind": "bias"}
  ],
  "n_conv": 2, "n_q": 3, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 6, "eval": 8, "logits": 4, "calib": 4},
  "pallas_eval": false, "entries": {}
}"#;

fn tiny_spec() -> ModelSpec {
    ModelSpec::from_manifest_str(FT_TINY).expect("tiny manifest")
}

/// A fresh (wiped) scratch dir for one test scenario.  Unlike the
/// per-runtime helper in `native_backend.rs`, the dir is wiped ONCE per
/// scenario so a second runtime built on it sees the first one's
/// checkpoints — the "new process after a kill" model.
fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("wsel_ft_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A runtime in `dir` with deterministic initial params — calling this
/// twice with the same (seed, dir) models a process restart: identical
/// fresh state, shared checkpoint directory.
fn rt_in(dir: &PathBuf, seed: u64, threads: usize) -> ModelRuntime {
    let spec = tiny_spec();
    let params = Params::init_train(&spec, seed).tensors;
    let mut rt = ModelRuntime::from_spec_native(spec, params, dir.clone());
    rt.threads = threads;
    rt.act_scales = vec![0.05; 3];
    rt
}

fn bits_of(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.iter().map(|v| v.to_bits()).collect())
        .collect()
}

const LR: LrSchedule = LrSchedule {
    base: 0.02,
    decay_at: 0.75,
};
const STEPS: usize = 9;

/// The acceptance property: kill at ANY step, resume in a fresh
/// process, and the final params + eval accuracy are bit-identical to
/// an uninterrupted run — at every thread count.  Also pins that
/// checkpointing is a pure observer: the checkpointed uninterrupted run
/// equals the plain `train_steps` path bit for bit.
#[test]
fn kill_and_resume_is_bit_identical() {
    let dense = CompressionState::dense(2);
    for threads in [1usize, 2, 5] {
        // Plain path (no checkpointing at all).
        let dir = fresh_dir(&format!("plain{threads}"));
        let mut plain = rt_in(&dir, 3, threads);
        plain.train_steps(&dense, true, LR, STEPS).expect("plain");
        let want_bits = bits_of(&plain.params);
        let want_acc = plain
            .evaluate(&dense, true, Split::Val, 1)
            .expect("plain eval");

        // Checkpointed but uninterrupted.
        let dir = fresh_dir(&format!("ckpt{threads}"));
        let mut whole = rt_in(&dir, 3, threads);
        let prog = whole
            .train_steps_resumable(&dense, true, LR, STEPS, &ResumeOpts::every(2, "t"))
            .expect("checkpointed");
        assert!(prog.completed && !prog.resumed && prog.rollbacks == 0);
        assert_eq!(
            bits_of(&whole.params),
            want_bits,
            "checkpointing perturbed training at {threads} threads"
        );
        assert!(
            !whole.checkpoint_path("t").exists(),
            "checkpoint must be deleted on completion"
        );

        for kill_at in [1usize, 4, 7] {
            let dir = fresh_dir(&format!("kill{threads}_{kill_at}"));
            // Run 1: hard-killed after `kill_at` steps (no save on the
            // way out — exactly a SIGKILL mid-run).
            let mut victim = rt_in(&dir, 3, threads);
            let mut opts = ResumeOpts::every(2, "t");
            opts.max_steps_this_run = Some(kill_at);
            let prog = victim
                .train_steps_resumable(&dense, true, LR, STEPS, &opts)
                .expect("victim run");
            assert!(!prog.completed && prog.at_step == kill_at);

            // Run 2: fresh process, same dir — adopts the checkpoint
            // and recomputes the tail.
            let mut resumed = rt_in(&dir, 3, threads);
            let prog = resumed
                .train_steps_resumable(&dense, true, LR, STEPS, &ResumeOpts::every(2, "t"))
                .expect("resumed run");
            assert!(prog.completed && prog.resumed, "kill_at={kill_at}");
            assert_eq!(
                bits_of(&resumed.params),
                want_bits,
                "params diverged after kill at {kill_at} ({threads} threads)"
            );
            let acc = resumed
                .evaluate(&dense, true, Split::Val, 1)
                .expect("resumed eval");
            assert_eq!(
                acc.to_bits(),
                want_acc.to_bits(),
                "accuracy diverged after kill at {kill_at} ({threads} threads)"
            );
            assert!(!resumed.checkpoint_path("t").exists());
        }
    }
}

/// Scripted backend: deterministic param drift, and a NaN loss the
/// first time a late step runs at full learning rate — so a rollback
/// with lr backoff recovers, but the plain path cannot.
struct DivergingBackend;

impl Backend for DivergingBackend {
    fn name(&self) -> &'static str {
        "diverging-script"
    }

    fn train_step(
        &mut self,
        ctx: RtCtx<'_>,
        _state: &CompressionState,
        _quant_on: bool,
        step_lr: f32,
    ) -> anyhow::Result<f32> {
        let s = *ctx.steps_done;
        *ctx.steps_done += 1;
        ctx.params[0][0] += step_lr;
        if s >= 3 && step_lr > 0.5 {
            return Ok(f32::NAN);
        }
        Ok(1.0 / (s as f32 + 1.0))
    }

    fn evaluate(
        &mut self,
        _ctx: RtCtx<'_>,
        _state: &CompressionState,
        _quant_on: bool,
        _split: Split,
        _n_batches: usize,
    ) -> anyhow::Result<f64> {
        Ok(0.5)
    }

    fn logits(
        &mut self,
        _ctx: RtCtx<'_>,
        _state: &CompressionState,
        _quant_on: bool,
        _x: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        Ok(Vec::new())
    }

    fn calibrate(&mut self, _ctx: RtCtx<'_>, _n_batches: usize) -> anyhow::Result<Vec<f32>> {
        Ok(Vec::new())
    }
}

fn scripted_rt(dir: &PathBuf) -> ModelRuntime {
    let spec = tiny_spec();
    let params = Params::init_train(&spec, 17).tensors;
    ModelRuntime::with_backend(spec, params, dir.clone(), Box::new(DivergingBackend))
}

#[test]
fn divergence_rolls_back_with_lr_backoff() {
    let dense = CompressionState::dense(2);
    let hot = LrSchedule {
        base: 1.0,
        decay_at: 1.0,
    };
    // Plain path: the NaN at step 3 is a hard error.
    let dir = fresh_dir("div_plain");
    let err = scripted_rt(&dir)
        .train_steps(&dense, true, hot, 6)
        .expect_err("plain path must fail on divergence");
    assert!(format!("{err}").contains("diverged"), "got: {err}");

    // Resumable path: roll back to the step-2 checkpoint, retry at
    // lr × 0.1 (≤ 0.5 → finite), and complete with one rollback.
    let dir = fresh_dir("div_roll");
    let mut rt = scripted_rt(&dir);
    let mut opts = ResumeOpts::every(2, "d");
    opts.backoff = 0.1;
    let prog = rt
        .train_steps_resumable(&dense, true, hot, 6, &opts)
        .expect("rollback must recover");
    assert!(prog.completed, "run must complete after rollback");
    assert_eq!(prog.rollbacks, 1, "exactly one rollback expected");

    // Exhausted rollbacks are still a hard error (backoff 1.0 never
    // leaves the diverging regime).
    let dir = fresh_dir("div_exhaust");
    let mut rt = scripted_rt(&dir);
    let mut opts = ResumeOpts::every(2, "d");
    opts.backoff = 1.0;
    opts.max_rollbacks = 2;
    let err = rt
        .train_steps_resumable(&dense, true, hot, 6, &opts)
        .expect_err("non-recovering divergence must fail");
    let msg = format!("{err}");
    assert!(
        msg.contains("diverged") && msg.contains("2 rollback"),
        "got: {msg}"
    );
}

/// Corrupted checkpoints must be rejected loudly, naming the file and
/// the reason — adopting one silently would poison the whole run.
#[test]
fn corrupt_checkpoint_is_rejected_with_pinpointed_error() {
    let dense = CompressionState::dense(2);
    let dir = fresh_dir("corrupt");
    let mut victim = rt_in(&dir, 3, 2);
    let mut opts = ResumeOpts::every(1, "c");
    opts.max_steps_this_run = Some(3);
    victim
        .train_steps_resumable(&dense, true, LR, STEPS, &opts)
        .expect("victim run");
    let path = victim.checkpoint_path("c");
    assert!(path.exists());
    let pristine = std::fs::read(&path).unwrap();

    // Bit flip in the payload.
    let mut bytes = pristine.clone();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    let err = rt_in(&dir, 3, 2)
        .train_steps_resumable(&dense, true, LR, STEPS, &ResumeOpts::every(1, "c"))
        .expect_err("bit-flipped checkpoint must be rejected");
    let msg = format!("{err:?}");
    assert!(msg.contains("checksum mismatch"), "got: {msg}");
    assert!(msg.contains("ckpt.c.bin"), "error must name the file: {msg}");

    // Truncation.
    std::fs::write(&path, &pristine[..pristine.len() - 7]).unwrap();
    let err = rt_in(&dir, 3, 2)
        .train_steps_resumable(&dense, true, LR, STEPS, &ResumeOpts::every(1, "c"))
        .expect_err("truncated checkpoint must be rejected");
    let msg = format!("{err:?}");
    assert!(msg.contains("truncated"), "got: {msg}");
}

/// Worker panics are contained per item and reported as a structured
/// error naming the poisoned indices — the process survives.
#[test]
fn worker_panics_surface_as_structured_errors() {
    let err = try_parallel_map(8, 4, |i| {
        if i == 2 || i == 5 {
            panic!("injected fault on item {i}");
        }
        i * 10
    })
    .expect_err("poisoned batch must error");
    let idx: Vec<usize> = err.poisoned.iter().map(|(i, _)| *i).collect();
    assert_eq!(idx, vec![2, 5]);
    assert_eq!(err.n, 8);
    let msg = format!("{err}");
    assert!(
        msg.contains("2 of 8") && msg.contains("[2, 5]"),
        "got: {msg}"
    );
    assert!(msg.contains("injected fault"), "got: {msg}");

    // The panicking wrapper converts the same condition into one
    // structured panic (with the poisoned indices) instead of letting a
    // worker thread tear the process down.
    let caught = std::panic::catch_unwind(|| {
        parallel_map(8, 2, |i| {
            if i == 6 {
                panic!("late fault");
            }
            i
        })
    })
    .expect_err("wrapper must panic");
    let msg = caught
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_default();
    assert!(
        msg.contains("parallel_map") && msg.contains("[6]"),
        "got: {msg}"
    );
}
