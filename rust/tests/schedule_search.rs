//! Schedule-search integration tests — journal resume × trial budget ×
//! `min_share` interplay, plus the oracle-efficient successive-halving
//! mode:
//!
//! * legacy exhaustive search killed after ANY trial (adaptive budget
//!   sweep) resumes bit-identically, paying each fine-tune step exactly
//!   once across the two invocations,
//! * a trailing below-`min_share` layer is never searched — neither by
//!   the uninterrupted run nor by a resumed one landing past it,
//! * halving-rung searches replay bit-identically after a kill at any
//!   trial boundary, serving recorded trials from the journal-seeded
//!   accuracy cache,
//! * halving spends well under half the exhaustive oracle fine-tune
//!   bill on a hopeless candidate menu, and a second run against the
//!   persistent accuracy cache performs zero oracle fine-tunes.
//!
//! The synthetic host mirrors the in-crate schedule test double: three
//! layers with energy shares ~80/20/0.2 % (the third below the default
//! `min_share`), an accuracy response that drops with aggressiveness
//! and recovers slightly with fine-tuning, and a `HashMap` standing in
//! for the on-disk oracle snapshots (surviving "process death" via
//! `.clone()`).

use std::collections::HashMap;
use std::path::PathBuf;
use wsel::energy::{LayerEnergy, NetworkEnergy, WeightEnergyTable};
use wsel::schedule::{
    energy_prioritized, energy_prioritized_resumable, energy_prioritized_with, AccCache,
    LayerModeler, ScheduleParams, ScheduleResult, SearchJournal,
};
use wsel::selection::{AccuracyOracle, CompressionState};

fn table() -> WeightEnergyTable {
    let mut e = [0.0f64; 256];
    for i in 0..256 {
        let code = (i as i32 - 128).unsigned_abs() as f64;
        e[i] = (1.0 + code) * 1e-15;
    }
    WeightEnergyTable {
        e_per_cycle: e,
        e_idle: 1e-16,
    }
}

struct SynthHost {
    tuned: f64,
    /// Accuracy gained per fine-tune step (capped at 0.01 total).
    tune_rate: f64,
    snapshots: HashMap<String, f64>,
    ft_total: usize,
}

impl SynthHost {
    fn new(tune_rate: f64) -> Self {
        SynthHost {
            tuned: 0.0,
            tune_rate,
            snapshots: HashMap::new(),
            ft_total: 0,
        }
    }
}

impl LayerModeler for SynthHost {
    fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy {
        // Layer 2's dense share is ~0.16% — below the default
        // `min_share` of 0.5%, so the schedule must skip it.
        let m = [1024, 256, 2][conv_idx];
        LayerEnergy {
            conv_idx,
            m,
            k: 64,
            n: 64,
            table: table(),
        }
    }
    fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256] {
        let mut u = [0u64; 256];
        let pruned = (4096.0 * state.layers[conv_idx].prune_ratio) as u64;
        u[128] = pruned;
        let rest = 4096 - pruned;
        for c in 1..=64 {
            u[128 + c as usize] = rest / 128;
            u[128 - c as usize] = rest / 128;
        }
        u
    }
    fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy {
        let layers = (0..3)
            .map(|i| {
                let le = self.layer_energy(i);
                let usage = self.usage(i, state);
                let e = match &state.layers[i].wset {
                    Some(s) => wsel::selection::set_energy(&le, &usage, s),
                    None => le.energy_of_usage(&usage),
                };
                (i, e)
            })
            .collect();
        NetworkEnergy { layers }
    }
}

impl AccuracyOracle for SynthHost {
    fn accuracy(&mut self, state: &CompressionState) -> f64 {
        let mut acc = 0.95 + self.tuned;
        for l in &state.layers {
            acc -= 0.010 * l.prune_ratio;
            if let Some(s) = &l.wset {
                acc -= 0.004 * (32.0 - s.len() as f64) / 16.0;
            }
        }
        acc
    }
    fn fine_tune(&mut self, _: &CompressionState, steps: usize) {
        self.ft_total += steps;
        self.tuned = (self.tuned + self.tune_rate * steps as f64).min(0.01);
    }
    fn save_search_state(&mut self, tag: &str) -> bool {
        self.snapshots.insert(tag.to_string(), self.tuned);
        true
    }
    fn load_search_state(&mut self, tag: &str) -> bool {
        match self.snapshots.get(tag) {
            Some(&t) => {
                self.tuned = t;
                true
            }
            None => false,
        }
    }
    fn drop_search_state(&mut self, tag: &str) {
        self.snapshots.remove(tag);
    }
    fn ft_steps(&self) -> usize {
        self.ft_total
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("wsel_sched_it_{tag}_{}.json", std::process::id()))
}

/// Kill the search after `budget` trials, then resume without a budget;
/// assert the two-invocation result matches `want` bit for bit and the
/// fine-tune bill is paid exactly once.  Returns `false` when `budget`
/// already covers the whole search (sweep termination).
fn kill_and_resume(sp: &ScheduleParams, want: &ScheduleResult, ref_ft: usize, budget: usize) -> bool {
    let path = tmp(&format!("kill_r{}_b{budget}", sp.halving_rungs));
    let _ = std::fs::remove_file(&path);
    let mut h1 = SynthHost::new(1e-4);
    let mut j1 = SearchJournal::new(path.clone(), "t").with_budget(budget);
    let out = energy_prioritized_resumable(&mut h1, 3, sp, &mut j1).unwrap();
    if let Some(done) = out {
        // Budget covered the whole search: must equal the reference.
        assert_eq!(done.to_json().to_string(), want.to_json().to_string());
        assert!(!path.exists());
        return false;
    }
    assert!(path.exists(), "journal survives the aborted invocation");
    // Process death: only the journal file + oracle snapshots survive.
    let mut h2 = SynthHost {
        snapshots: h1.snapshots.clone(),
        ..SynthHost::new(1e-4)
    };
    let mut j2 = SearchJournal::new(path.clone(), "t");
    let got = energy_prioritized_resumable(&mut h2, 3, sp, &mut j2)
        .unwrap()
        .expect("resumed search runs to completion");
    assert_eq!(
        got.to_json().to_string(),
        want.to_json().to_string(),
        "kill after {budget} trials (rungs={})",
        sp.halving_rungs
    );
    assert!(
        got.outcomes.iter().all(|oc| oc.conv_idx != 2),
        "below-min_share layer must stay unsearched on resume"
    );
    assert_eq!(
        h1.ft_total + h2.ft_total,
        ref_ft,
        "kill after {budget}: every fine-tune step paid exactly once (rungs={})",
        sp.halving_rungs
    );
    assert!(!path.exists(), "journal deleted on completion");
    true
}

/// Mixed accept/reject menu: layer 0 accepts its 2nd candidate, layer 1
/// its 5th — plenty of mid-wave kill points for the budget sweep.
fn mixed_sp() -> ScheduleParams {
    ScheduleParams {
        acc0: 0.95,
        delta: 0.0095,
        fine_tune_steps: 10,
        ..Default::default()
    }
}

#[test]
fn legacy_search_killed_after_any_trial_resumes_bit_identically() {
    let sp = mixed_sp();
    let mut ref_host = SynthHost::new(1e-4);
    let want = energy_prioritized(&mut ref_host, 3, &sp);
    let mut swept = 0;
    for budget in 1..200 {
        swept = budget;
        if !kill_and_resume(&sp, &want, ref_host.ft_total, budget) {
            break;
        }
    }
    assert!(swept > 1, "search must span multiple trials");
    assert!(swept < 200, "budget sweep must terminate");
}

#[test]
fn halving_search_killed_after_any_trial_resumes_bit_identically() {
    let sp = ScheduleParams {
        halving_rungs: 3,
        ..mixed_sp()
    };
    let mut ref_host = SynthHost::new(1e-4);
    let want = energy_prioritized(&mut ref_host, 3, &sp);
    let mut swept = 0;
    for budget in 1..200 {
        swept = budget;
        if !kill_and_resume(&sp, &want, ref_host.ft_total, budget) {
            break;
        }
    }
    assert!(swept > 1, "search must span multiple trials");
    assert!(swept < 200, "budget sweep must terminate");
}

#[test]
fn below_min_share_trailing_layer_is_never_searched() {
    let sp = mixed_sp();
    let mut host = SynthHost::new(1e-4);
    let res = energy_prioritized(&mut host, 3, &sp);
    assert_eq!(res.outcomes.len(), 2, "layer 2 is below min_share");
    assert!(res.outcomes.iter().all(|oc| oc.conv_idx != 2));
    assert_eq!(res.state.layers[2].prune_ratio, 0.0);
    assert!(res.state.layers[2].wset.is_none());
    // Both processed layers accepted something and report a real
    // accuracy (the 0.0-sentinel regression).
    for oc in &res.outcomes {
        assert!(oc.accepted.is_some());
        assert!(oc.accuracy_after > 0.9);
    }
}

#[test]
fn halving_halves_the_oracle_bill_and_warm_cache_skips_it_entirely() {
    // Hopeless menu: with a near-zero tune rate and a tight delta no
    // candidate ever passes, so the exhaustive sweep pays the full
    // 9-candidate × 10-step bill per layer while halving's rung pyramid
    // (1+1+2+6 steps, half the field cut per rung) stops early.
    let sp_ex = ScheduleParams {
        acc0: 0.95,
        delta: 0.0005,
        fine_tune_steps: 10,
        ..Default::default()
    };
    let mut h_ex = SynthHost::new(1e-5);
    let ex = energy_prioritized(&mut h_ex, 3, &sp_ex);
    assert!(ex.outcomes.iter().all(|oc| oc.accepted.is_none()));

    let sp_h = ScheduleParams {
        halving_rungs: 4,
        rung_frac: 0.1,
        ..sp_ex.clone()
    };
    let cache_path = tmp("acc_cache");
    let _ = std::fs::remove_file(&cache_path);
    let mut c1 = AccCache::at(cache_path.clone()).unwrap();
    let mut h1 = SynthHost::new(1e-5);
    let r1 = energy_prioritized_with(&mut h1, 3, &sp_h, None, Some(&mut c1))
        .unwrap()
        .unwrap();
    assert!(r1.outcomes.iter().all(|oc| oc.accepted.is_none()));
    assert!(
        2 * h1.ft_total <= h_ex.ft_total,
        "halving must spend <= 50% of the exhaustive fine-tune bill \
         ({} vs {})",
        h1.ft_total,
        h_ex.ft_total
    );
    // All-reject keeps the warm-start base, so final accuracy can only
    // differ from the exhaustive run by its (unreverted) trial drift.
    assert!(
        r1.final_accuracy >= ex.final_accuracy - 0.003,
        "{} vs {}",
        r1.final_accuracy,
        ex.final_accuracy
    );

    // Second run against the warm persistent cache + surviving
    // snapshots: zero oracle fine-tunes, bit-identical result.
    let mut c2 = AccCache::at(cache_path.clone()).unwrap();
    assert!(!c2.is_empty(), "cache persisted");
    let mut h2 = SynthHost {
        snapshots: h1.snapshots.clone(),
        ..SynthHost::new(1e-5)
    };
    let r2 = energy_prioritized_with(&mut h2, 3, &sp_h, None, Some(&mut c2))
        .unwrap()
        .unwrap();
    assert_eq!(r2.to_json().to_string(), r1.to_json().to_string());
    assert_eq!(h2.ft_total, 0, "warm cache: zero oracle fine-tunes");
    assert_eq!(c2.misses, 0);
    assert!(c2.hits > 0);
    std::fs::remove_file(&cache_path).unwrap();
}
