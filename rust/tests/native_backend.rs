//! Native training/eval backend integration tests — all fully offline
//! (no artifacts, no PJRT):
//!
//! * bit-identity of `train_steps` across thread counts 1/2/5,
//! * a golden pin of a short native train + evaluate run,
//! * the acceptance flow: `Pipeline::train_baseline` → `profile` →
//!   `compress` end-to-end on the native backend,
//! * native `evaluate`/`logits` agreement with the scalar int8 mirror,
//! * `data_seed` / backend plumbing through `PipelineParams`.
//!
//! (Finite-difference checks for the backward kernels live in
//! `rust/src/model/grad.rs` unit tests.)

use std::path::PathBuf;
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::data::{self, Split};
use wsel::model::{Engine, ModelSpec, Params, QuantConfig};
use wsel::quant::WeightSet;
use wsel::runtime::{BackendChoice, LrSchedule, ModelRuntime};
use wsel::schedule::ScheduleParams;
use wsel::selection::{CompressionState, LayerConfig};
use wsel::testutil::golden;
use wsel::util::json::Json;

/// Miniature offline spec: every op kind on the native path, with
/// batch sizes small enough for debug-mode CI.
const NATIVE_TINY: &str = r#"{
  "model": "nativetiny", "n_classes": 4, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 4, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 32, "wout": 32},
    {"op": "maxpool2"},
    {"op": "save"},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 4, "cout": 4, "k": 3, "stride": 1, "pad": 1,
     "relu": false, "hin": 16, "win": 16, "hout": 16, "wout": 16},
    {"op": "add_saved", "relu": true, "proj": null},
    {"op": "gap"},
    {"op": "fc", "name": "fc0", "w": 4, "b": 5, "q_idx": 2,
     "din": 4, "dout": 4, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [4, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [4], "kind": "bias"},
    {"name": "conv1.w", "shape": [4, 4, 3, 3], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [4], "kind": "bias"},
    {"name": "fc0.w", "shape": [4, 4], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [4], "kind": "bias"}
  ],
  "n_conv": 2, "n_q": 3, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 6, "eval": 8, "logits": 4, "calib": 4},
  "pallas_eval": false, "entries": {}
}"#;

fn tiny_spec() -> ModelSpec {
    ModelSpec::from_manifest_str(NATIVE_TINY).expect("tiny manifest")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("wsel_native_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn native_rt(spec: &ModelSpec, seed: u64, threads: usize, tag: &str) -> ModelRuntime {
    let params = Params::init_train(spec, seed).tensors;
    let mut rt = ModelRuntime::from_spec_native(spec.clone(), params, tmp_dir(tag));
    rt.threads = threads;
    rt
}

fn bits_of(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Tentpole property: training is data-parallel yet bit-identical at
/// any thread count — masks, weight sets and quantized activations
/// included.
#[test]
fn train_steps_bit_identical_across_thread_counts() {
    let spec = tiny_spec();
    let state = CompressionState {
        layers: vec![
            LayerConfig {
                prune_ratio: 0.4,
                wset: None,
            },
            LayerConfig {
                prune_ratio: 0.0,
                wset: Some(WeightSet::new(vec![-96, -32, 0, 32, 96])),
            },
        ],
    };
    let lr = LrSchedule {
        base: 0.02,
        decay_at: 0.5,
    };
    let mut reference: Option<(u32, Vec<Vec<u32>>)> = None;
    for threads in [1usize, 2, 5] {
        let mut rt = native_rt(&spec, 3, threads, "bitid");
        rt.act_scales = vec![0.05; spec.n_q];
        let loss = rt.train_steps(&state, true, lr, 4).expect("train");
        assert!(loss.is_finite());
        let got = (loss.to_bits(), bits_of(&rt.params));
        match &reference {
            None => reference = Some(got),
            Some(want) => {
                assert_eq!(want.0, got.0, "loss differs at {threads} threads");
                assert_eq!(want.1, got.1, "params differ at {threads} threads");
            }
        }
    }
}

/// Pruned weights receive no gradient: with fresh (zero) momentum, one
/// step leaves every masked weight bit-unchanged.
#[test]
fn masked_weights_frozen_on_first_step() {
    let spec = tiny_spec();
    let mut rt = native_rt(&spec, 5, 2, "mask");
    rt.act_scales = vec![0.05; spec.n_q];
    let before = rt.params[0].clone();
    let state = CompressionState {
        layers: vec![
            LayerConfig {
                prune_ratio: 0.5,
                wset: None,
            },
            LayerConfig::default(),
        ],
    };
    let mask = rt.masks_for(&state)[0].clone();
    rt.train_steps(
        &state,
        true,
        LrSchedule {
            base: 0.05,
            decay_at: 1.0,
        },
        1,
    )
    .expect("train");
    let mut moved = 0usize;
    for ((b, a), m) in before.iter().zip(&rt.params[0]).zip(&mask) {
        if *m == 0.0 {
            assert_eq!(b.to_bits(), a.to_bits(), "masked weight moved");
        } else if b != a {
            moved += 1;
        }
    }
    assert!(moved > 0, "unmasked weights should train");
}

/// Native evaluate (quantized path) agrees exactly with accuracy
/// computed through the scalar int8 mirror on the same batches.
#[test]
fn evaluate_matches_scalar_mirror() {
    let spec = tiny_spec();
    let mut rt = native_rt(&spec, 7, 3, "evalmirror");
    rt.calibrate(1).expect("calibrate");
    let dense = CompressionState::dense(spec.n_conv);
    let acc = rt.evaluate(&dense, true, Split::Val, 2).expect("eval");

    let eng = Engine::new(&spec);
    let qc = QuantConfig::quantized(&spec, rt.act_scales.clone());
    let bs = spec.batch_eval;
    let mut correct = 0usize;
    for b in 0..2 {
        let (x, y) =
            data::batch(rt.data_seed, Split::Val, (b * bs) as u64, bs, spec.n_classes as u64);
        let fwd = eng.forward(&rt.params, &x, bs, &qc, false);
        correct += y
            .iter()
            .enumerate()
            .filter(|(i, &yi)| fwd.argmax(*i) == yi as usize)
            .count();
    }
    let want = correct as f64 / (2 * bs) as f64;
    assert_eq!(acc, want, "native evaluate vs scalar mirror accuracy");

    // Logits path: bit-identical to the scalar mirror too.
    let (x, _) = data::batch(rt.data_seed, Split::Val, 0, spec.batch_logits, 4);
    let got = rt.logits(&dense, true, &x).expect("logits");
    let fwd = eng.forward(&rt.params, &x, spec.batch_logits, &qc, false);
    assert_eq!(
        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        fwd.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
}

/// Golden pin of a short native train + evaluate run: float phase,
/// calibration, QAT phase, per-tensor parameter sums.  Bootstraps on
/// first run (`check_or_init`), then pins with a tolerance wide enough
/// for cross-host libm (exp/ln) drift but far below any real
/// regression.
///
/// NOTE: the pin only has teeth across checkouts once the bootstrapped
/// `rust/tests/golden/native_train_eval.json` is **committed** — this
/// PR was authored in a container without a Rust toolchain, so the
/// first toolchain-equipped run creates it; commit the file then.
#[test]
fn golden_native_train_eval() {
    let spec = tiny_spec();
    let mut rt = native_rt(&spec, 11, 2, "golden");
    let dense = CompressionState::dense(spec.n_conv);
    let loss_float = rt
        .train_steps(
            &dense,
            false,
            LrSchedule {
                base: 0.02,
                decay_at: 0.75,
            },
            5,
        )
        .expect("float train");
    rt.calibrate(1).expect("calibrate");
    let loss_qat = rt
        .train_steps(
            &dense,
            true,
            LrSchedule {
                base: 0.01,
                decay_at: 1.0,
            },
            3,
        )
        .expect("qat train");
    // Accuracy over one 8-image batch is quantized to multiples of 1/8
    // — a relative tolerance cannot absorb a one-image flip from
    // cross-host libm ulps, so it is range-checked here and kept OUT of
    // the snapshot; only continuous quantities are pinned.
    let acc = rt.evaluate(&dense, true, Split::Val, 1).expect("eval");
    assert!((0.0..=1.0).contains(&acc), "acc = {acc}");
    // Absolute sums: strictly positive and O(n·mean|w|), so the
    // relative-tolerance pin never degenerates near a cancelling zero.
    let sums: Vec<Json> = rt
        .params
        .iter()
        .map(|t| Json::num(t.iter().map(|&v| v.abs() as f64).sum::<f64>()))
        .collect();
    let j = Json::obj(vec![
        ("loss_float", Json::num(loss_float as f64)),
        ("loss_qat", Json::num(loss_qat as f64)),
        ("param_sums", Json::arr(sums)),
        (
            "scales",
            Json::arr(rt.act_scales.iter().map(|&s| Json::num(s as f64))),
        ),
    ]);
    golden::check_or_init_with_rtol("native_train_eval", &j, 1e-3);
}

/// The PR acceptance flow: train → profile → compress completes fully
/// offline on the native backend (PJRT stub untouched).
#[test]
fn native_pipeline_train_profile_compress() {
    let spec = tiny_spec();
    let pp = PipelineParams {
        float_steps: 6,
        qat_steps: 4,
        calib_batches: 1,
        val_batches: 1,
        trace_len: 48,
        stats_images: 2,
        threads: 2,
        ..Default::default()
    };
    let rt = native_rt(&spec, 13, pp.threads, "pipeline");
    assert_eq!(rt.backend_name(), "native");
    let mut p = Pipeline::from_runtime(rt, pp);
    let acc0 = p.train_baseline().expect("train_baseline");
    assert!((0.0..=1.0).contains(&acc0), "acc0 = {acc0}");
    let base = p.profile().expect("profile").clone();
    assert!(base.total() > 0.0, "base energy must be positive");
    let sp = ScheduleParams {
        prune_ratios: vec![0.5],
        k_targets: vec![16],
        fine_tune_steps: 2,
        delta: 0.9,
        max_layers: Some(1),
        ..Default::default()
    };
    let res = p.compress(sp).expect("compress");
    assert!((0.0..=1.0).contains(&res.final_accuracy));
    assert!(p.eval_count > 0, "the schedule must consult the oracle");
    let now = p.compute_network_energy(&res.state);
    assert!(now.total().is_finite() && now.total() > 0.0);
}

/// `data_seed` and backend choice plumb through `PipelineParams` (the
/// runtime's historical hard-coded 7 is only the default now), and the
/// native backend serves built-in specs with no artifacts present.
#[test]
fn pipeline_params_plumb_data_seed_and_backend() {
    let no_artifacts = tmp_dir("noartifacts");
    let pp = PipelineParams {
        data_seed: 123,
        backend: BackendChoice::Native,
        threads: 2,
        ..PipelineParams::quick()
    };
    let p = Pipeline::new(&no_artifacts, "lenet5", pp).expect("native pipeline");
    assert_eq!(p.rt.backend_name(), "native");
    assert_eq!(p.rt.data_seed, 123);
    assert_eq!(p.rt.threads, 2);
    assert_eq!(p.rt.spec.name, "lenet5");
    // Auto with no artifacts also lands on native.
    let pp2 = PipelineParams::quick();
    let p2 = Pipeline::new(&no_artifacts, "lenet5", pp2).expect("auto pipeline");
    assert_eq!(p2.rt.backend_name(), "native");
    assert_eq!(p2.rt.data_seed, ModelRuntime::DEFAULT_DATA_SEED);
    // Forcing AOT without artifacts is an error, not a fallback.
    assert!(ModelRuntime::auto(&no_artifacts, "lenet5", BackendChoice::Aot).is_err());
}
