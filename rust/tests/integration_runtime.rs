//! Integration: PJRT runtime ⇄ artifacts ⇄ Rust int8 mirror engine.
//!
//! Requires `make artifacts` to have produced `artifacts/` (the Makefile
//! test target guarantees this).  Tests are `#[ignore]`-free but skip
//! gracefully when artifacts are absent (e.g. plain `cargo test` in a
//! fresh checkout).

use std::path::{Path, PathBuf};
use wsel::data::{self, Split};
use wsel::model::{Engine, QuantConfig};
use wsel::runtime::{run_tile_kernel, LrSchedule, ModelRuntime};
use wsel::selection::CompressionState;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("lenet5/manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built");
        None
    }
}

/// The Pallas systolic-tile kernel must agree with the Rust tiled
/// systolic simulation (functional mapping check, §3.2).
#[test]
fn tile_kernel_matches_systolic_sim() {
    let Some(dir) = artifacts() else { return };
    // Integer-valued f32 operands so both sides are exact.
    let mut rng = wsel::util::rng::Xoshiro256::new(42);
    let x_codes: Vec<i8> = (0..128 * 192).map(|_| (rng.below(15) as i8) - 7).collect();
    let w_codes: Vec<i8> = (0..192 * 128).map(|_| (rng.below(15) as i8) - 7).collect();
    let x_f: Vec<f32> = x_codes.iter().map(|&c| c as f32).collect();
    let w_f: Vec<f32> = w_codes.iter().map(|&c| c as f32).collect();
    let got = run_tile_kernel(&dir, &x_f, &w_f).expect("tile kernel run");
    let want = wsel::systolic::matmul_tiled(&x_codes, &w_codes, 128, 192, 128);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (*g - *w as f32).abs() < 1e-3,
            "tile mismatch at {i}: pallas {g} vs systolic {w}"
        );
    }
}

/// HLO eval / logits agree with the Rust int8 mirror engine.
#[test]
fn runtime_agrees_with_mirror_engine() {
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, "lenet5").expect("load lenet5");
    let state = CompressionState::dense(rt.spec.n_conv);

    // A couple of QAT steps so params are not pure-init, then calibrate.
    rt.train_steps(&state, false, LrSchedule::default(), 3)
        .expect("train");
    rt.calibrate(2).expect("calibrate");

    // The PJRT-free native calibration (same data recipe through the
    // compiled float engine) must track the AOT `calib` graph closely —
    // both are float forwards over the same batches, differing only in
    // accumulation order.
    let aot_scales = rt.act_scales.clone();
    let native_scales = rt.calibrate_native(2, 2);
    assert_eq!(aot_scales.len(), native_scales.len());
    for (q, (a, n)) in aot_scales.iter().zip(&native_scales).enumerate() {
        assert!(
            (a - n).abs() <= 0.1 * a.abs().max(1e-6),
            "quant point {q}: aot scale {a} vs native {n}"
        );
    }
    // Restore the AOT scales so the logits cross-check below sees the
    // exact state the HLO graphs were calibrated with.
    rt.act_scales = aot_scales;

    let bs = rt.spec.batch_logits;
    let (xs, _ys) = data::batch(rt.data_seed, Split::Val, 0, bs, 10);
    let hlo_logits = rt.logits(&state, true, &xs).expect("logits");

    let spec = rt.spec.clone();
    let eng = Engine::new(&spec);
    let qc = QuantConfig::quantized(&spec, rt.act_scales.clone());
    let fwd = eng.forward(&rt.params, &xs, bs, &qc, false);

    assert_eq!(hlo_logits.len(), fwd.logits.len());
    // f32 vs i32 accumulation differ at rounding boundaries; demand tight
    // relative agreement and identical argmax per row.
    let ncls = spec.n_classes;
    for row in 0..bs {
        let h = &hlo_logits[row * ncls..(row + 1) * ncls];
        let m = &fwd.logits[row * ncls..(row + 1) * ncls];
        let scale = h.iter().fold(1.0f32, |s, &v| s.max(v.abs()));
        for (a, b) in h.iter().zip(m) {
            assert!(
                (a - b).abs() <= 0.05 * scale,
                "row {row}: hlo {a} vs mirror {b} (scale {scale})"
            );
        }
        // Lowest-index tie-break, matching `Forward::argmax`'s documented
        // contract (max_by would pick the *last* of exactly-equal maxima).
        let mut am_h = 0;
        for (i, v) in h.iter().enumerate().skip(1) {
            if *v > h[am_h] {
                am_h = i;
            }
        }
        assert_eq!(am_h, fwd.argmax(row), "argmax mismatch on row {row}");
    }
}

/// Training through the AOT graph reduces loss and produces finite,
/// improving accuracy.
#[test]
fn training_learns() {
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, "lenet5").expect("load");
    let state = CompressionState::dense(rt.spec.n_conv);
    let acc0 = rt.evaluate(&state, false, Split::Val, 1).expect("eval");
    rt.train_steps(
        &state,
        false,
        LrSchedule {
            base: 0.02,
            decay_at: 1.0,
        },
        250,
    )
    .expect("train");
    let acc1 = rt.evaluate(&state, false, Split::Val, 1).expect("eval");
    assert!(
        acc1 > acc0 + 0.15,
        "250 steps should lift accuracy well above chance: {acc0} -> {acc1}"
    );
}

/// Rust and Python dataset generators are bit-identical (golden check:
/// values generated by python/compile/data.py for seed 7 / split 1).
#[test]
fn data_cross_language_golden() {
    // Golden bytes produced by the Python generator (see
    // python/tests/test_data.py::test_export_golden which asserts the
    // same values from the Python side).
    let (img, cls) = data::sample(7, Split::Val, 0, 10);
    let golden_prefix: Vec<u8> = GOLDEN_IMG_PREFIX.to_vec();
    assert_eq!(&img[..16], &golden_prefix[..], "class {cls}");
}

/// First 16 bytes of sample(seed=7, split=Val, index=0, n_classes=10).
/// Updated together with python/tests/test_data.py (both sides assert
/// the same constant; a drift in either mirror breaks one of the two).
const GOLDEN_IMG_PREFIX: [u8; 16] = wsel::data::GOLDEN_VAL0_PREFIX;
