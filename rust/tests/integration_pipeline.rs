//! Integration: the full compression pipeline at smoke scale, plus
//! cross-module property tests that need the real artifacts.
//!
//! Energy numbers are pinned by the golden harness (`testutil::golden`):
//! the first run against a fresh artifact build bootstraps the
//! snapshots automatically; refresh intentional changes with
//! `WSEL_BLESS=1 cargo test -q --test integration_pipeline`.

use std::path::{Path, PathBuf};
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::schedule::ScheduleParams;
use wsel::selection::CompressionState;
use wsel::testutil::golden;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("lenet5/manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built");
        None
    }
}

fn quick_pipeline(dir: &Path) -> Pipeline {
    let mut pp = PipelineParams::quick();
    pp.float_steps = 60;
    pp.qat_steps = 20;
    Pipeline::new(dir, "lenet5", pp).expect("pipeline")
}

/// Train → profile → compress completes and produces a consistent
/// result: restricted sets within size budget, saving in (0, 1),
/// accuracy within the schedule's constraint of the measured acc0.
#[test]
fn pipeline_end_to_end_smoke() {
    let Some(dir) = artifacts() else { return };
    let mut p = quick_pipeline(&dir);
    p.train_baseline().expect("train");
    p.profile().expect("profile");
    let base = p.base_energy.clone().unwrap();
    assert!(base.total() > 0.0);
    let sp = ScheduleParams {
        prune_ratios: vec![0.5],
        k_targets: vec![16],
        fine_tune_steps: 5,
        delta: 0.10,
        ..Default::default()
    };
    let res = p.compress(sp).expect("compress");
    for l in &res.state.layers {
        if let Some(s) = &l.wset {
            assert!(s.len() <= 16, "set size {}", s.len());
            assert!(s.contains(0), "0 must stay (pruning anchor)");
        }
    }
    let now = p.compute_network_energy(&res.state);
    let saving = base.saving_vs(&now);
    assert!(
        (0.0..1.0).contains(&saving),
        "saving out of range: {saving}"
    );
    // If any layer was accepted, energy must strictly drop.
    if res.state.layers.iter().any(|l| l.wset.is_some()) {
        assert!(saving > 0.0);
    }
    // The evaluator's cached parallel path must equal the direct path
    // on the real pipeline, bit for bit.
    let direct = p.compute_network_energy_direct(&res.state);
    for ((i1, e1), (i2, e2)) in now.layers.iter().zip(&direct.layers) {
        assert_eq!(i1, i2);
        assert_eq!(e1.to_bits(), e2.to_bits(), "layer {i1}: {e1} vs {e2}");
    }
    // Pin the full schedule outcome (baseline bootstraps on the first
    // run against a fresh artifact build, then drift fails).
    golden::check_or_init("pipeline_lenet5_schedule", &res.to_json());
}

/// The energy model is deterministic given the seed: two pipelines over
/// the same checkpoint produce identical layer energies.
#[test]
fn energy_model_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mk = || {
        let mut p = quick_pipeline(&dir);
        p.train_baseline().expect("train");
        p.profile().expect("profile");
        p.base_energy.clone().unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.layers.len(), b.layers.len());
    for ((i1, e1), (i2, e2)) in a.layers.iter().zip(&b.layers) {
        assert_eq!(i1, i2);
        assert!(
            (e1 - e2).abs() < 1e-18 + 1e-9 * e1.abs(),
            "layer {i1}: {e1} vs {e2}"
        );
    }
    // Pin the baseline network energy so it cannot drift silently
    // across refactors (baseline bootstraps on the first run against a
    // fresh artifact build).
    golden::check_or_init("pipeline_lenet5_base_energy", &a.to_json());
}

/// Compression monotonicity: more pruning can only reduce modeled energy.
#[test]
fn pruning_monotone_in_energy_model() {
    let Some(dir) = artifacts() else { return };
    let mut p = quick_pipeline(&dir);
    p.train_baseline().expect("train");
    p.profile().expect("profile");
    let n = p.rt.spec.n_conv;
    let mut prev = f64::MAX;
    for ratio in [0.0, 0.3, 0.5, 0.7, 0.9] {
        let state = CompressionState {
            layers: (0..n)
                .map(|_| wsel::selection::LayerConfig {
                    prune_ratio: ratio,
                    wset: None,
                })
                .collect(),
        };
        let e = p.compute_network_energy(&state).total();
        assert!(
            e <= prev * (1.0 + 1e-9),
            "energy increased with pruning {ratio}: {e} > {prev}"
        );
        prev = e;
    }
}

/// The statistical layer-energy model must track the exact gate-level
/// tile simulation within a small constant factor (model validation,
/// DESIGN.md §5).
#[test]
fn model_mode_tracks_exact_tile_power() {
    let Some(dir) = artifacts() else { return };
    let mut p = quick_pipeline(&dir);
    p.train_baseline().expect("train");
    p.profile().expect("profile");

    let spec = p.rt.spec.clone();
    let eng = wsel::model::Engine::new(&spec);
    let qc = wsel::model::QuantConfig::quantized(&spec, p.rt.act_scales.clone());
    let (xs, _) = wsel::data::batch(p.rt.data_seed, wsel::data::Split::Train, 0, 2, 10);
    let fwd = eng.forward(&p.rt.params, &xs, 2, &qc, true);
    let cap = fwd
        .captures
        .iter()
        .find(|c| c.conv_idx == 1)
        .expect("conv1");

    let cm = p.cap_model;
    let mut lib = wsel::systolic::MacLib::new();
    lib.specialize_for(&cap.w_codes, p.pp.threads);
    let pass = wsel::systolic::passes_of(cap.m, cap.k, cap.n)[0];
    let (e_exact, _steps) = wsel::systolic::tile_power_exact(
        &cap.x_codes,
        &cap.w_codes,
        cap.k,
        cap.n,
        &pass,
        &lib,
        &cm,
    );
    // Model: same weight positions, per-cycle energies from the table.
    let le = p.layer_energy_model(1);
    let mut e_model = 0.0;
    for r in 0..pass.kh {
        for c in 0..pass.nw {
            let w = cap.w_codes[(pass.k0 + r) * cap.n + (pass.n0 + c)];
            e_model += le.table.energy(w) * pass.mh as f64;
        }
    }
    let ratio = e_model / e_exact;
    assert!(
        (0.3..3.0).contains(&ratio),
        "statistical model should track exact tile power: ratio {ratio:.3}"
    );
}

/// Network-scale ground truth over the quickstart model's captures:
/// `validate_exact` streams every pass of every conv layer through the
/// parallel tile-power engine and the per-layer exact energies must
/// (a) be positive, (b) track the statistical model within a small
/// constant factor, and (c) be bit-identical across thread counts.
#[test]
fn network_exact_power_quickstart() {
    let Some(dir) = artifacts() else { return };
    let mut p = quick_pipeline(&dir);
    p.train_baseline().expect("train");
    p.profile().expect("profile");

    let rep = p.validate_exact(2);
    assert_eq!(rep.layers.len(), p.rt.spec.n_conv);
    for l in &rep.layers {
        assert!(l.exact_j > 0.0, "conv{} exact energy", l.conv_idx);
        let ratio = l.ratio();
        assert!(
            (0.05..20.0).contains(&ratio),
            "conv{}: model/exact = {ratio:.3}",
            l.conv_idx
        );
    }

    // Thread-count invariance at the pipeline level.
    let mut p1 = quick_pipeline(&dir);
    p1.pp.threads = 1;
    p1.train_baseline().expect("train");
    p1.profile().expect("profile");
    let rep1 = p1.validate_exact(2);
    assert_eq!(rep.layers.len(), rep1.layers.len());
    for (a, b) in rep.layers.iter().zip(&rep1.layers) {
        assert_eq!(a.conv_idx, b.conv_idx);
        assert_eq!(
            a.exact_j.to_bits(),
            b.exact_j.to_bits(),
            "conv{} exact energy must not depend on thread count",
            a.conv_idx
        );
    }
}

/// Determinism of the whole compression decision: same seeds -> same
/// accepted configs and identical final weight sets.
#[test]
fn compression_deterministic() {
    let Some(dir) = artifacts() else { return };
    let run = || {
        let mut p = quick_pipeline(&dir);
        p.train_baseline().expect("train");
        p.profile().expect("profile");
        let sp = ScheduleParams {
            prune_ratios: vec![0.5],
            k_targets: vec![16],
            fine_tune_steps: 0,
            delta: 0.5,
            ..Default::default()
        };
        let res = p.compress(sp).expect("compress");
        res.state
            .layers
            .iter()
            .map(|l| l.wset.as_ref().map(|s| s.codes().to_vec()))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}
