//! The dispatched SIMD kernel backends vs the scalar reference.
//!
//! Every backend in `model::kernels::dispatch` claims bit-identity with
//! scalar by construction (exact i32 for the int8 GEMM, order-preserving
//! f32 reductions, a faithful round-half-away-from-zero emulation in the
//! vector quantizer).  These tests hold it to that claim:
//!
//! * property sweeps over randomized shapes with non-multiple remainders
//!   for the int8 GEMM (dense / checkerboard / all-zero / single-cell
//!   weight masks, zero activation rows), quantize (including exact
//!   .5-tie and signed-zero inputs), the requant epilogue, and all three
//!   f32 training GEMMs — each available backend vs scalar, compared
//!   bitwise;
//! * `KernelKind` parsing, `WSEL_KERNELS` resolution and `select`
//!   semantics (bad CLI value errors, bad env value degrades to auto);
//! * end-to-end: `ParallelEngine` forward and `GradEngine`
//!   forward/backward at threads {1, 2, 5} with the SIMD backend forced
//!   on vs off — logits, loss and every gradient tensor bitwise equal.
//!
//! Tests that touch process-global state (the active vtable, the env
//! var) serialize on a mutex; the pure property sweeps call backend
//! vtables directly and never touch the global.

use std::sync::Mutex;

use wsel::model::kernels::dispatch::{self, KernelKind};
use wsel::model::kernels::{BlockedWeights, SB};
use wsel::model::{Engine, GradEngine, ModelSpec, ParallelEngine, Params, QuantConfig};
use wsel::util::rng::Xoshiro256;

/// Serializes the tests that mutate the active vtable or `WSEL_KERNELS`.
static GLOBAL: Mutex<()> = Mutex::new(());

fn scalar_ops() -> &'static dispatch::KernelOps {
    dispatch::for_kind(KernelKind::Scalar).expect("scalar backend always exists")
}

/// Every SIMD backend this host can run (empty off x86-64 — the sweeps
/// then have nothing to compare and pass trivially).
fn simd_backends() -> Vec<&'static dispatch::KernelOps> {
    [KernelKind::Sse2, KernelKind::Avx2]
        .into_iter()
        .filter_map(dispatch::for_kind)
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Shapes chosen so m, k and n hit 1, sub-block (< SB), sub-panel
/// (< NB=64), exact-multiple and ragged-remainder cases.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (3, 5, 2),
    (7, 64, 64),
    (33, 70, 64),
    (16, 80, 200),
    (65, 257, 67),
    (129, 300, 65),
];

#[test]
fn gemm_i8_dispatch_matches_scalar() {
    let scalar = scalar_ops();
    let simd = simd_backends();
    let mut rng = Xoshiro256::new(0xD15C);
    for &(m, k, n) in SHAPES {
        let mut x: Vec<i8> = (0..m * k).map(|_| rng.code() as i8).collect();
        // All-zero activation rows exercise the strip's xv == 0 skip.
        for i in (0..m).step_by(3) {
            x[i * k..(i + 1) * k].fill(0);
        }
        let variants: Vec<(&str, Vec<i8>)> = vec![
            ("dense", (0..k * n).map(|_| rng.code() as i8).collect()),
            // Checkerboard of SB×SB cells: every occupancy row mixes
            // empty, full and (at the ragged right edge) partial masks.
            (
                "checkerboard",
                (0..k * n)
                    .map(|i| {
                        let (r, c) = (i / n, i % n);
                        if (r / SB + c / SB) % 2 == 0 {
                            0
                        } else {
                            rng.code() as i8
                        }
                    })
                    .collect(),
            ),
            ("zero", vec![0i8; k * n]),
            // A single occupied top-left cell: everything else is the
            // structural-skip path.
            ("single_cell", {
                let mut w = vec![0i8; k * n];
                for r in 0..k.min(SB) {
                    for c in 0..n.min(SB) {
                        w[r * n + c] = rng.code() as i8;
                    }
                }
                w
            }),
        ];
        for (label, w) in &variants {
            let wb = BlockedWeights::pack(w, k, n);
            let mut want = vec![0i32; m * n];
            (scalar.gemm_i8_blocked)(&x, &wb, m, &mut want);
            for ops in &simd {
                let mut got = vec![0i32; m * n];
                (ops.gemm_i8_blocked)(&x, &wb, m, &mut got);
                assert_eq!(
                    want,
                    got,
                    "{label} {m}x{k}x{n}: {} i8 GEMM diverges from scalar",
                    ops.kind.name()
                );
            }
        }
    }
}

#[test]
fn quantize_dispatch_matches_scalar() {
    let scalar = scalar_ops();
    let simd = simd_backends();
    let mut rng = Xoshiro256::new(7);
    let s = 0.031f32;
    // Exact .5 ties (round away from zero), signed zeros, clamp-range
    // magnitudes, and values just inside/outside the ±127 edge.
    let special = [
        0.5 * s,
        -0.5 * s,
        1.5 * s,
        -1.5 * s,
        2.5 * s,
        0.0,
        -0.0,
        100.0,
        -100.0,
        126.5 * s,
        127.4 * s,
        -127.5 * s,
    ];
    for len in [1usize, 3, 7, 8, 9, 15, 16, 31, 64, 257, 1000] {
        let mut src: Vec<f32> = (0..len).map(|_| rng.range_f32(-8.0, 8.0)).collect();
        for (i, v) in special.iter().enumerate() {
            if i < src.len() {
                src[i] = *v;
            }
        }
        let mut want = vec![0i8; len];
        (scalar.quantize_i8)(&src, s, &mut want);
        // The scalar backend is itself pinned to quant::quantize.
        for (i, &v) in src.iter().enumerate() {
            assert_eq!(want[i] as i32, wsel::quant::quantize(v, s), "ref at {i}");
        }
        for ops in &simd {
            let mut got = vec![0i8; len];
            (ops.quantize_i8)(&src, s, &mut got);
            assert_eq!(
                want,
                got,
                "len={len}: {} quantize diverges from scalar",
                ops.kind.name()
            );
        }
    }
}

#[test]
fn requant_dispatch_matches_scalar() {
    let scalar = scalar_ops();
    let simd = simd_backends();
    let mut rng = Xoshiro256::new(11);
    for &(m, n) in &[(1usize, 1usize), (3, 5), (4, 16), (5, 33), (7, 127), (2, 256)] {
        let acc: Vec<i32> = (0..m * n)
            .map(|_| (rng.below(1 << 22) as i64 - (1 << 21)) as i32)
            .collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        for relu in [false, true] {
            let mut want = vec![0f32; m * n];
            (scalar.requant_bias_relu)(&acc, 6.1e-4, &bias, relu, &mut want);
            for ops in &simd {
                let mut got = vec![0f32; m * n];
                (ops.requant_bias_relu)(&acc, 6.1e-4, &bias, relu, &mut got);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "{m}x{n} relu={relu}: {} requant diverges from scalar",
                    ops.kind.name()
                );
            }
        }
    }
}

#[test]
fn f32_gemms_dispatch_match_scalar() {
    let scalar = scalar_ops();
    let simd = simd_backends();
    let mut rng = Xoshiro256::new(13);
    for &(m, k, n) in SHAPES {
        // Sprinkle exact zeros so the zero-skip path runs on every
        // backend (it must not change a bit: the skipped term is ±0).
        let a: Vec<f32> = (0..m * k)
            .map(|i| {
                if i % 5 == 0 {
                    0.0
                } else {
                    rng.range_f32(-1.0, 1.0)
                }
            })
            .collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let y: Vec<f32> = (0..m * n)
            .map(|i| {
                if i % 7 == 0 {
                    0.0
                } else {
                    rng.range_f32(-1.0, 1.0)
                }
            })
            .collect();
        // (accessor, a-operand, b-operand, acc length) per contraction:
        //   gemm_f32:      acc(m×n) += A(m×k)·B(k×n)
        //   gemm_f32_xt_y: acc(k×n) += Aᵀ(k×m)·Y(m×n)
        //   gemm_f32_y_wt: acc(m×k) += Y(m×n)·Bᵀ(n×k)
        type Getter = fn(&dispatch::KernelOps) -> fn(&[f32], &[f32], usize, usize, usize, &mut [f32]);
        let cases: [(&str, Getter, &[f32], &[f32], usize); 3] = [
            ("gemm_f32", |o| o.gemm_f32, &a, &b, m * n),
            ("gemm_f32_xt_y", |o| o.gemm_f32_xt_y, &a, &y, k * n),
            ("gemm_f32_y_wt", |o| o.gemm_f32_y_wt, &y, &b, m * k),
        ];
        for (name, get, pa, pb, acc_len) in cases {
            let mut want = vec![0f32; acc_len];
            get(scalar)(pa, pb, m, k, n, &mut want);
            for ops in &simd {
                let mut got = vec![0f32; acc_len];
                get(ops)(pa, pb, m, k, n, &mut got);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "{name} {m}x{k}x{n}: {} diverges from scalar",
                    ops.kind.name()
                );
            }
        }
    }
}

#[test]
fn kind_parse_select_and_env() {
    let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    assert_eq!(KernelKind::parse("auto").unwrap(), None);
    assert_eq!(KernelKind::parse("scalar").unwrap(), Some(KernelKind::Scalar));
    assert_eq!(KernelKind::parse("sse2").unwrap(), Some(KernelKind::Sse2));
    assert_eq!(KernelKind::parse("avx2").unwrap(), Some(KernelKind::Avx2));
    assert!(KernelKind::parse("bogus").is_err());
    assert!(KernelKind::parse("AVX2").is_err(), "values are lowercase-only");

    // Scalar can always be forced; auto always resolves to something.
    let ops = dispatch::select(Some(KernelKind::Scalar)).expect("force scalar");
    assert_eq!(ops.kind, KernelKind::Scalar);
    assert_eq!(dispatch::active_kind(), KernelKind::Scalar);
    let best = dispatch::select(None).expect("auto select");
    assert_eq!(dispatch::active_kind(), best.kind);

    // Forcing a backend the host lacks must error, not silently degrade.
    for kind in [KernelKind::Sse2, KernelKind::Avx2] {
        if dispatch::for_kind(kind).is_none() {
            assert!(dispatch::select(Some(kind)).is_err());
        }
    }

    // Env resolution: valid values parse, garbage warns and means auto.
    std::env::set_var("WSEL_KERNELS", "scalar");
    assert_eq!(dispatch::resolve_env(), Some(KernelKind::Scalar));
    std::env::set_var("WSEL_KERNELS", "auto");
    assert_eq!(dispatch::resolve_env(), None);
    std::env::set_var("WSEL_KERNELS", "bogus");
    assert_eq!(dispatch::resolve_env(), None);
    std::env::remove_var("WSEL_KERNELS");
    assert_eq!(dispatch::resolve_env(), None);

    // The available list is scalar-first and consistent with for_kind.
    let avail = dispatch::available();
    assert_eq!(avail[0].kind, KernelKind::Scalar);
    for ops in &avail {
        assert!(dispatch::for_kind(ops.kind).is_some());
    }
    dispatch::select(None).expect("restore auto");
}

/// Two-conv tower at 32×32×3 (the GradEngine input shape) with cout
/// values off every block boundary, so the int8 and f32 paths both see
/// ragged remainders end to end.
const E2E_MANIFEST: &str = r#"{
  "model": "simd_e2e", "n_classes": 4, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 5, "k": 3, "stride": 2, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 16, "wout": 16},
    {"op": "maxpool2"},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 5, "cout": 9, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 8, "win": 8, "hout": 8, "wout": 8},
    {"op": "gap"},
    {"op": "fc", "name": "fc0", "w": 4, "b": 5, "q_idx": 2,
     "din": 9, "dout": 4, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [5, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [5], "kind": "bias"},
    {"name": "conv1.w", "shape": [9, 5, 3, 3], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [9], "kind": "bias"},
    {"name": "fc0.w", "shape": [4, 9], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [4], "kind": "bias"}
  ],
  "n_conv": 2, "n_q": 3, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 8, "eval": 8, "logits": 4, "calib": 8},
  "pallas_eval": false
}"#;

/// Forward logits, grad-forward logits, loss and all gradient tensors
/// at threads {1, 2, 5}, everything as bits.
fn e2e_fingerprint(
    spec: &ModelSpec,
    p: &Params,
    qc: &QuantConfig,
    x: &[f32],
    y: &[i32],
    batch: usize,
) -> Vec<(Vec<u32>, Vec<u32>, u32, Vec<u32>)> {
    [1usize, 2, 5]
        .iter()
        .map(|&threads| {
            let eng = ParallelEngine::new(spec, &p.tensors, qc, threads);
            let fwd = eng.forward_plain(x, batch);
            let ge = GradEngine::new(spec, &p.tensors, qc, true);
            let logits = ge.forward_batch(&p.tensors, x, batch, threads);
            let (loss, grads) = ge.batch_grad(&p.tensors, x, y, threads);
            let gbits: Vec<u32> = grads
                .iter()
                .flat_map(|g| g.iter().map(|v| v.to_bits()))
                .collect();
            (bits(&fwd.logits), bits(&logits), loss.to_bits(), gbits)
        })
        .collect()
}

#[test]
fn engine_and_grad_bit_identical_across_backends() {
    let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let spec = ModelSpec::from_manifest_str(E2E_MANIFEST).expect("manifest");
    let p = Params::random(&spec, 3);
    let batch = 2usize;
    let mut rng = Xoshiro256::new(0xE2E);
    let x: Vec<f32> = (0..batch * 32 * 32 * 3)
        .map(|_| rng.range_f32(-1.0, 1.0))
        .collect();
    let y: Vec<i32> = vec![1, 3];
    let scales = Engine::new(&spec).calibrate(&p.tensors, &[&x], batch);
    let qc = QuantConfig::quantized(&spec, scales);

    dispatch::select(Some(KernelKind::Scalar)).expect("force scalar");
    let want = e2e_fingerprint(&spec, &p, &qc, &x, &y, batch);

    for kind in [KernelKind::Sse2, KernelKind::Avx2] {
        if dispatch::for_kind(kind).is_none() {
            continue;
        }
        dispatch::select(Some(kind)).expect("force simd backend");
        let got = e2e_fingerprint(&spec, &p, &qc, &x, &y, batch);
        assert_eq!(
            want,
            got,
            "engine/grad outputs diverge between scalar and {}",
            kind.name()
        );
    }

    // And the auto-detected backend, whatever it is on this host.
    dispatch::select(None).expect("auto");
    let got = e2e_fingerprint(&spec, &p, &qc, &x, &y, batch);
    assert_eq!(
        want, got,
        "engine/grad outputs diverge between scalar and the auto backend"
    );
}
