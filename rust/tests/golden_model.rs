//! Artifact-free golden regression tests for the core energy model.
//!
//! The fixtures are chosen so every quantity is either integer-valued
//! or dyadic (power-of-two scaled), which makes the committed snapshots
//! reproducible bit-for-bit across platforms.  Regenerate with
//! `WSEL_BLESS=1 cargo test -q --test golden_model` after an
//! *intentional* model change (and say why in the commit).
//!
//! The snapshots pin:
//! * `energy_of_usage` / `set_energy` / `NetworkEnergy::saving_vs`
//!   (`network_energy_model.json`),
//! * weight-set projection of a usage histogram
//!   (`projected_usage_setA_layer1.json`),
//! * the MSB×Hamming group mapping (`transition_groups.json`).

use wsel::energy::{LayerEnergy, NetworkEnergy, WeightEnergyTable};
use wsel::quant::WeightSet;
use wsel::selection::{projected_usage, set_energy};
use wsel::testutil::golden;
use wsel::transitions::group::{group_of, to_bits};
use wsel::util::json::Json;

/// 2^-50 J/cycle quantum: keeps every table entry exactly representable.
fn scale() -> f64 {
    (2.0f64).powi(-50)
}

fn dyadic_table() -> WeightEnergyTable {
    // (1 + |code|) * 2^-50 with idle 2^-51 — every entry exactly
    // representable (mirrored by scripts/mirror_goldens.py).
    wsel::testutil::linear_energy_table(scale())
}

fn layer(conv_idx: usize, m: usize, k: usize, n: usize) -> LayerEnergy {
    LayerEnergy {
        conv_idx,
        m,
        k,
        n,
        table: dyadic_table(),
    }
}

/// LeNet-5-shaped conv dims (im2col matmuls at batch 1-ish scale).
fn layers() -> Vec<LayerEnergy> {
    vec![
        layer(0, 256, 75, 6),
        layer(1, 196, 150, 16),
        layer(2, 64, 400, 32),
    ]
}

/// Deterministic, integer-valued usage histogram per layer.
fn usage(layer_idx: usize) -> [u64; 256] {
    let mut u = [0u64; 256];
    for c in -127i32..=127 {
        let pos = u64::from(c > 0);
        u[(c + 128) as usize] = (3 * c.unsigned_abs() as u64 + pos + 5 * layer_idx as u64) % 17;
    }
    u
}

fn set_a() -> WeightSet {
    WeightSet::new(vec![-127, -64, -32, -16, -8, 0, 8, 16, 32, 64, 127])
}

fn set_b() -> WeightSet {
    WeightSet::new(vec![-81, -27, -9, -3, 0, 3, 9, 27, 81])
}

#[test]
fn golden_network_energy_model() {
    let ls = layers();
    let net = |f: &dyn Fn(usize, &LayerEnergy) -> f64| NetworkEnergy {
        layers: ls
            .iter()
            .enumerate()
            .map(|(i, le)| (le.conv_idx, f(i, le)))
            .collect(),
    };
    let dense = net(&|i, le| le.energy_of_usage(&usage(i)));
    let a = net(&|i, le| set_energy(le, &usage(i), &set_a()));
    let b = net(&|i, le| set_energy(le, &usage(i), &set_b()));
    let j = Json::obj(vec![
        ("dense", dense.to_json()),
        ("setA", a.to_json()),
        ("setB", b.to_json()),
        ("saving_setA", Json::num(dense.saving_vs(&a))),
        ("saving_setB", Json::num(dense.saving_vs(&b))),
    ]);
    golden::check("network_energy_model", &j);
}

#[test]
fn golden_projected_usage() {
    let pa = projected_usage(&usage(1), &set_a());
    let j = Json::arr(pa.iter().map(|&c| Json::num(c as f64)));
    golden::check("projected_usage_setA_layer1", &j);
    // Projection conserves mass regardless of the snapshot.
    assert_eq!(
        usage(1).iter().sum::<u64>(),
        pa.iter().sum::<u64>(),
        "projection must conserve weight count"
    );
}

#[test]
fn golden_transition_groups() {
    let pats: [u32; 15] = [
        0,
        1,
        2,
        3,
        5,
        255,
        4096,
        0x15_5555,
        0x2A_AAAA,
        1 << 20,
        1 << 21,
        (1 << 21) + 1,
        (1 << 22) - 1,
        0x3F_FFFE,
        0x20_0001,
    ];
    let j = Json::arr(pats.iter().map(|&p| Json::num(group_of(p) as f64)));
    golden::check("transition_groups", &j);
    // Signed wrap agrees with the raw patterns at the corners.
    assert_eq!(group_of(to_bits(-1)), group_of((1 << 22) - 1));
    assert_eq!(group_of(to_bits(0)), group_of(0));
}
