//! Serving-layer contract tests: per-request logits bit-identical to
//! single-image `ParallelEngine::forward` at any thread count, wave
//! packing and arrival order; registry hot-swap under concurrent load;
//! unknown-model and poisoned-wave error paths that degrade a request
//! or a wave — never the service.

use std::sync::Arc;

use wsel::model::spec::INPUT_ELEMS;
use wsel::model::{ModelSpec, ParallelEngine, Params, QuantConfig};
use wsel::serve::bench::wave_logits;
use wsel::serve::{BatchPolicy, MicroBatcher, ModelVariant, ServeError, SnapshotRegistry, Ticket};
use wsel::util::rng::Xoshiro256;

/// Small two-conv net (conv → pool → strided conv → gap → fc): fast
/// enough to serve hundreds of requests in a test, deep enough to
/// exercise quantized convs, pooling and the fc head.
const SERVE_MANIFEST: &str = r#"{
  "model": "serve_tiny", "n_classes": 4, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 4, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 32, "wout": 32},
    {"op": "maxpool2"},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 4, "cout": 6, "k": 3, "stride": 2, "pad": 1,
     "relu": true, "hin": 16, "win": 16, "hout": 8, "wout": 8},
    {"op": "gap"},
    {"op": "fc", "name": "fc0", "w": 4, "b": 5, "q_idx": 2,
     "din": 6, "dout": 4, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [4, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [4], "kind": "bias"},
    {"name": "conv1.w", "shape": [6, 4, 3, 3], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [6], "kind": "bias"},
    {"name": "fc0.w", "shape": [4, 6], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [4], "kind": "bias"}
  ],
  "n_conv": 2, "n_q": 3, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 8, "eval": 8, "logits": 4, "calib": 8},
  "pallas_eval": false
}"#;

fn spec() -> ModelSpec {
    ModelSpec::from_manifest_str(SERVE_MANIFEST).expect("serve manifest")
}

fn engine(spec: &ModelSpec, param_seed: u64, threads: usize) -> ParallelEngine {
    let p = Params::random(spec, param_seed);
    let qc = QuantConfig::quantized(spec, vec![0.02f32; spec.n_q]);
    ParallelEngine::new(spec, &p.tensors, &qc, threads)
}

fn images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            let mut rng = Xoshiro256::new(seed ^ ((i as u64) << 16));
            (0..INPUT_ELEMS).map(|_| rng.range_f32(-1.0, 1.0)).collect()
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Deterministic in-test shuffle of `0..n`.
fn permutation(n: usize, seed: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256::new(seed);
    for i in (1..n).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        idx.swap(i, j);
    }
    idx
}

/// The headline determinism contract: per-request logits through the
/// batcher are bit-identical to a single-image `forward_plain` —
/// regardless of engine thread count {1, 2, 5}, wave packing (batch=1,
/// partial waves, one full wave) and arrival order.
#[test]
fn per_request_logits_bit_identical_across_threads_packing_and_order() {
    let spec = spec();
    let imgs = images(12, 0xBEEF);
    for threads in [1usize, 2, 5] {
        let eng = engine(&spec, 42, threads);
        // Single-image references (the wave-free ground truth).
        let refs: Vec<Vec<u32>> = imgs
            .iter()
            .map(|x| bits(&eng.forward_plain(x, 1).logits))
            .collect();
        let reg = Arc::new(SnapshotRegistry::new());
        reg.install(ModelVariant::new("m", eng));
        let policies = [
            BatchPolicy::batch1(),
            BatchPolicy {
                max_batch: 5,
                max_wait_us: 50_000,
            },
            BatchPolicy {
                max_batch: 12,
                max_wait_us: 50_000,
            },
        ];
        for (pi, &policy) in policies.iter().enumerate() {
            for (oi, order) in [
                (0..imgs.len()).collect::<Vec<_>>(),
                (0..imgs.len()).rev().collect(),
                permutation(imgs.len(), 7 + pi as u64),
            ]
            .iter()
            .enumerate()
            {
                let submitted: Vec<Vec<f32>> =
                    order.iter().map(|&i| imgs[i].clone()).collect();
                let results = wave_logits(&reg, "m", &submitted, policy);
                for (k, &i) in order.iter().enumerate() {
                    let got = results[k]
                        .as_ref()
                        .unwrap_or_else(|e| panic!("request failed: {e}"));
                    assert_eq!(
                        refs[i],
                        bits(got),
                        "threads={threads} policy#{pi} order#{oi} img{i}"
                    );
                }
            }
        }
    }
}

#[test]
fn unknown_model_name_is_a_per_request_error() {
    let spec = spec();
    let reg = Arc::new(SnapshotRegistry::new());
    reg.install(ModelVariant::new("known", engine(&spec, 1, 2)));
    let b = MicroBatcher::new(Arc::clone(&reg), BatchPolicy::default());
    let pool = images(1, 3);
    let img = &pool[0];
    // Unknown name fails that request...
    let t = b.submit("nope", img);
    assert_eq!(
        t.wait().result,
        Err(ServeError::UnknownModel("nope".to_string()))
    );
    // ...while the service keeps serving the installed variant.
    let ok = b.submit("known", img);
    assert!(ok.wait().result.is_ok());
    // Eviction turns a known name into an unknown one for new requests.
    assert!(reg.evict("known").is_some());
    let gone = b.submit("known", img);
    assert_eq!(
        gone.wait().result,
        Err(ServeError::UnknownModel("known".to_string()))
    );
    b.shutdown();
}

/// Hot-swap under concurrent load: submitters hammer one name while the
/// main thread swaps the variant underneath them.  Every reply must be
/// a complete answer from exactly one of the two variants (old or new)
/// — never an error, never a torn mix.
#[test]
fn registry_hot_swap_under_load() {
    let spec = spec();
    let imgs = images(6, 0xCAFE);
    let eng_a = engine(&spec, 100, 2);
    let eng_b = engine(&spec, 200, 2);
    let refs_a: Vec<Vec<u32>> = imgs
        .iter()
        .map(|x| bits(&eng_a.forward_plain(x, 1).logits))
        .collect();
    let refs_b: Vec<Vec<u32>> = imgs
        .iter()
        .map(|x| bits(&eng_b.forward_plain(x, 1).logits))
        .collect();
    // A and B must actually disagree for the check to mean anything.
    assert_ne!(refs_a, refs_b);

    let reg = Arc::new(SnapshotRegistry::new());
    reg.install(ModelVariant::new("m", eng_a));
    let b = MicroBatcher::new(
        Arc::clone(&reg),
        BatchPolicy {
            max_batch: 4,
            max_wait_us: 100,
        },
    );
    const PER_THREAD: usize = 40;
    let replies: Vec<(usize, Result<Vec<f32>, ServeError>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..3usize {
            let h = b.handle();
            let imgs = &imgs;
            handles.push(s.spawn(move || {
                let mut out = Vec::with_capacity(PER_THREAD);
                for k in 0..PER_THREAD {
                    let i = (t + 3 * k) % imgs.len();
                    let ticket = h.submit("m", &imgs[i]);
                    out.push((i, ticket.wait().result));
                }
                out
            }));
        }
        // Swap mid-flight.
        std::thread::sleep(std::time::Duration::from_millis(2));
        reg.install(ModelVariant::new("m", eng_b));
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("submitter panicked"))
            .collect()
    });
    let mut from_a = 0usize;
    let mut from_b = 0usize;
    for (i, r) in &replies {
        let got = bits(r.as_ref().unwrap_or_else(|e| panic!("hot-swap broke a request: {e}")));
        if got == refs_a[*i] {
            from_a += 1;
        } else if got == refs_b[*i] {
            from_b += 1;
        } else {
            panic!("img{i}: logits match neither variant");
        }
    }
    assert_eq!(from_a + from_b, replies.len());
    // After the swap has completed, new requests must be served by the
    // new variant (timing decides how many in-flight ones were).
    let post = b.submit("m", &imgs[0]).wait().result.expect("post-swap request");
    assert_eq!(bits(&post), refs_b[0], "post-swap request served by old variant");
    b.shutdown();
}

/// A poisoned wave fails exactly its own requests; the dispatcher and
/// the following waves are untouched.
#[test]
fn poisoned_wave_degrades_wave_not_service() {
    let spec = spec();
    let reg = Arc::new(SnapshotRegistry::new());
    let v = reg.install(ModelVariant::new("m", engine(&spec, 5, 2)));
    let pool = images(1, 8);
    let img = &pool[0];

    // batch1 policy: one wave per request, so exactly one armed fault
    // fails exactly one request.
    let b = MicroBatcher::new(Arc::clone(&reg), BatchPolicy::batch1());
    v.inject_wave_faults(1);
    let bad = b.submit("m", img).wait().result;
    match bad {
        Err(ServeError::WavePoisoned(msg)) => {
            assert!(msg.contains("injected wave fault"), "{msg}");
        }
        other => panic!("want WavePoisoned, got {other:?}"),
    }
    let good = b.submit("m", img).wait().result;
    assert!(good.is_ok(), "service did not recover: {good:?}");
    let stats = b.shutdown();
    assert_eq!(stats.poisoned_waves, 1);
    assert_eq!(stats.requests, 2);

    // Coalescing policy: one armed fault fails the whole wave it lands
    // on (every co-traveler), then the next wave is healthy.
    let b = MicroBatcher::new(
        Arc::clone(&reg),
        BatchPolicy {
            max_batch: 4,
            max_wait_us: 100_000,
        },
    );
    v.inject_wave_faults(1);
    let tickets: Vec<Ticket> = (0..4).map(|_| b.submit("m", img)).collect();
    let results: Vec<_> = tickets.iter().map(|t| t.wait().result).collect();
    let poisoned = results
        .iter()
        .filter(|r| matches!(r, Err(ServeError::WavePoisoned(_))))
        .count();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    // Wave packing under timing jitter may split the burst, but the
    // armed fault must fail at least one request, nothing may fail for
    // any other reason, and once the fault is consumed requests succeed.
    assert!(poisoned >= 1, "no request saw the armed fault: {results:?}");
    assert_eq!(poisoned + ok, results.len(), "unexpected error kind: {results:?}");
    assert!(b.submit("m", img).wait().result.is_ok());
    b.shutdown();
}

/// End-to-end smoke of the sustained-load driver itself (tiny grid):
/// full report shape, no lost requests, monotone percentiles.
#[test]
fn serve_bench_smoke_grid() {
    let cfg = wsel::serve::ServeBenchCfg {
        rates: vec![4000.0],
        include_saturated: true,
        requests: 16,
        max_batch: 8,
        max_wait_us: 100,
        seed: 11,
        threads: 2,
    };
    let (json, cells) = wsel::serve::run_serve_bench(&cfg).unwrap();
    assert_eq!(cells.len(), 8); // 2 variants x 2 rates x 2 policies
    assert_eq!(wsel::serve::bench::validate_report(&json).unwrap(), 8);
    for c in &cells {
        assert_eq!(c.ok + c.errors, c.n);
        assert_eq!(c.errors, 0, "{c:?}");
    }
}
