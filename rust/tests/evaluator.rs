//! Property tests for the memoized, parallel [`EnergyEvaluator`]
//! (testutil-based, no artifacts needed):
//!
//! * the cached + parallel path is **bit-identical** to the direct
//!   sequential un-cached path, and
//! * results are independent of the thread count (1, 2, N).

use wsel::energy::cache::{EnergyEvaluator, EvalLayer};
use wsel::energy::{LayerEnergy, WeightEnergyTable};
use wsel::selection::{CompressionState, LayerConfig};
use wsel::testutil::{cases, Gen};

fn table_from(g: &mut Gen) -> WeightEnergyTable {
    wsel::testutil::linear_energy_table(g.f32_in(0.5, 2.0) as f64 * 1e-15)
}

fn layers_from(g: &mut Gen) -> Vec<EvalLayer> {
    let n_layers = g.usize_in(1, 4);
    (0..n_layers)
        .map(|ci| {
            let k = g.usize_in(8, 120);
            let n = g.usize_in(1, 24);
            EvalLayer {
                le: LayerEnergy {
                    conv_idx: ci,
                    m: g.usize_in(1, 200),
                    k,
                    n,
                    table: table_from(g),
                },
                weights: g.vec_f32(k * n, -2.0, 2.0),
            }
        })
        .collect()
}

fn state_from(g: &mut Gen, n_layers: usize) -> CompressionState {
    CompressionState {
        layers: (0..n_layers)
            .map(|_| LayerConfig {
                prune_ratio: [0.0, 0.3, 0.5, 0.7, 0.9][g.usize_in(0, 4)],
                wset: if g.bool() { Some(g.weight_set(24)) } else { None },
            })
            .collect(),
    }
}

fn assert_bitwise_eq(a: &wsel::energy::NetworkEnergy, b: &wsel::energy::NetworkEnergy, what: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{what}: layer count");
    for ((i1, e1), (i2, e2)) in a.layers.iter().zip(&b.layers) {
        assert_eq!(i1, i2, "{what}: layer order");
        assert_eq!(
            e1.to_bits(),
            e2.to_bits(),
            "{what}: layer {i1} energy {e1} vs {e2}"
        );
    }
}

/// Cached + parallel evaluation is bit-identical to the direct
/// sequential un-cached path, for arbitrary layers and states.
#[test]
fn prop_evaluator_bit_identical_to_direct() {
    cases(20, 0xE7A1, |g| {
        let layers = layers_from(g);
        let n = layers.len();
        let ev = EnergyEvaluator::new(layers, 4);
        for _ in 0..3 {
            let st = state_from(g, n);
            let cached = ev.eval(&st);
            let direct = ev.eval_direct(&st);
            assert_bitwise_eq(&cached, &direct, "cached vs direct");
        }
        // Re-evaluating a state with a warm cache changes nothing.
        let st = state_from(g, n);
        let first = ev.eval(&st);
        let again = ev.eval(&st);
        assert_bitwise_eq(&first, &again, "cold vs warm cache");
    });
}

/// `parallel_map` fan-out width never changes a bit of the result.
#[test]
fn prop_evaluator_thread_count_independent() {
    cases(15, 0x7EAD, |g| {
        let layers = layers_from(g);
        let n = layers.len();
        let ev1 = EnergyEvaluator::new(layers.clone(), 1);
        let ev2 = EnergyEvaluator::new(layers.clone(), 2);
        let ev7 = EnergyEvaluator::new(layers, 7);
        for _ in 0..3 {
            let st = state_from(g, n);
            let a = ev1.eval(&st);
            let b = ev2.eval(&st);
            let c = ev7.eval(&st);
            assert_bitwise_eq(&a, &b, "1 vs 2 threads");
            assert_bitwise_eq(&a, &c, "1 vs 7 threads");
        }
    });
}

/// The memo only ever holds one histogram per (layer, ratio), no matter
/// how many states share it, and clearing it does not change results.
#[test]
fn prop_usage_cache_is_sound() {
    cases(10, 0xCAC4E, |g| {
        let layers = layers_from(g);
        let n = layers.len();
        let ev = EnergyEvaluator::new(layers, 3);
        let mut distinct = std::collections::HashSet::new();
        let mut states = Vec::new();
        for _ in 0..4 {
            let st = state_from(g, n);
            for (ci, l) in st.layers.iter().enumerate() {
                distinct.insert((ci, l.prune_ratio.to_bits()));
            }
            states.push(st);
        }
        let before: Vec<_> = states.iter().map(|s| ev.eval(s)).collect();
        assert_eq!(ev.cached_usages(), distinct.len());
        ev.clear_cache();
        let after: Vec<_> = states.iter().map(|s| ev.eval(s)).collect();
        for (a, b) in before.iter().zip(&after) {
            assert_bitwise_eq(a, b, "pre vs post cache clear");
        }
    });
}
