//! The blocked parallel executor vs the retained scalar reference:
//! bit-identical logits, activation maxima and captures across
//! quantized / float / masked / weight-set configs, conv edge cases
//! (stride 2 with odd input, pad 0, 1×1 and even kernels, cout not a
//! multiple of the GEMM block) and thread counts — plus thread-count
//! invariance of the streaming stats sink.

use wsel::model::kernels::SB;
use wsel::model::{CaptureBuffer, ConvOp, Engine, ModelSpec, ParallelEngine, Params, QuantConfig};
use wsel::quant::{magnitude_mask, WeightSet};
use wsel::stats::StatsSink;

/// Edge-case conv tower: stride-2/pad-1, 1×1/pad-0, even kernel
/// producing an odd feature map, then stride-2/pad-0 on that odd input;
/// every cout is far from the 64-wide GEMM panel.
const EDGE_MANIFEST: &str = r#"{
  "model": "edges", "n_classes": 4, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 5, "k": 3, "stride": 2, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 16, "wout": 16},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 5, "cout": 7, "k": 1, "stride": 1, "pad": 0,
     "relu": false, "hin": 16, "win": 16, "hout": 16, "wout": 16},
    {"op": "conv", "name": "conv2", "w": 4, "b": 5, "conv_idx": 2,
     "q_idx": 2, "cin": 7, "cout": 6, "k": 2, "stride": 1, "pad": 0,
     "relu": true, "hin": 16, "win": 16, "hout": 15, "wout": 15},
    {"op": "conv", "name": "conv3", "w": 6, "b": 7, "conv_idx": 3,
     "q_idx": 3, "cin": 6, "cout": 9, "k": 3, "stride": 2, "pad": 0,
     "relu": true, "hin": 15, "win": 15, "hout": 7, "wout": 7},
    {"op": "flatten"},
    {"op": "fc", "name": "fc0", "w": 8, "b": 9, "q_idx": 4,
     "din": 441, "dout": 4, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [5, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [5], "kind": "bias"},
    {"name": "conv1.w", "shape": [7, 5, 1, 1], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [7], "kind": "bias"},
    {"name": "conv2.w", "shape": [6, 7, 2, 2], "kind": "conv_w"},
    {"name": "conv2.b", "shape": [6], "kind": "bias"},
    {"name": "conv3.w", "shape": [9, 6, 3, 3], "kind": "conv_w"},
    {"name": "conv3.b", "shape": [9], "kind": "bias"},
    {"name": "fc0.w", "shape": [4, 441], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [4], "kind": "bias"}
  ],
  "n_conv": 4, "n_q": 5, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 8, "eval": 8, "logits": 4, "calib": 8},
  "pallas_eval": false
}"#;

/// Residual block with a 1×1 projection conv on the skip path (the
/// executor's `AddSaved { proj }` branch, including its capture).
const RESIDUAL_MANIFEST: &str = r#"{
  "model": "residual", "n_classes": 4, "input": [32, 32, 3],
  "ops": [
    {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
     "q_idx": 0, "cin": 3, "cout": 8, "k": 3, "stride": 1, "pad": 1,
     "relu": true, "hin": 32, "win": 32, "hout": 32, "wout": 32},
    {"op": "save"},
    {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
     "q_idx": 1, "cin": 8, "cout": 8, "k": 3, "stride": 1, "pad": 1,
     "relu": false, "hin": 32, "win": 32, "hout": 32, "wout": 32},
    {"op": "add_saved", "relu": true,
     "proj": {"op": "conv", "name": "proj0", "w": 4, "b": 5, "conv_idx": 2,
              "q_idx": 2, "cin": 8, "cout": 8, "k": 1, "stride": 1, "pad": 0,
              "relu": false, "hin": 32, "win": 32, "hout": 32, "wout": 32}},
    {"op": "gap"},
    {"op": "fc", "name": "fc0", "w": 6, "b": 7, "q_idx": 3,
     "din": 8, "dout": 4, "relu": false}
  ],
  "params": [
    {"name": "conv0.w", "shape": [8, 3, 3, 3], "kind": "conv_w"},
    {"name": "conv0.b", "shape": [8], "kind": "bias"},
    {"name": "conv1.w", "shape": [8, 8, 3, 3], "kind": "conv_w"},
    {"name": "conv1.b", "shape": [8], "kind": "bias"},
    {"name": "proj0.w", "shape": [8, 8, 1, 1], "kind": "conv_w"},
    {"name": "proj0.b", "shape": [8], "kind": "bias"},
    {"name": "fc0.w", "shape": [4, 8], "kind": "fc_w"},
    {"name": "fc0.b", "shape": [4], "kind": "bias"}
  ],
  "n_conv": 3, "n_q": 4, "kset": 32, "qmax": 127, "seed": 1,
  "set_sentinel": 1e9, "momentum": 0.9,
  "batches": {"train": 8, "eval": 8, "logits": 4, "calib": 8},
  "pallas_eval": false
}"#;

fn input(batch: usize, seed: u64) -> Vec<f32> {
    let mut rng = wsel::util::rng::Xoshiro256::new(seed);
    (0..batch * 32 * 32 * 3)
        .map(|_| rng.range_f32(-1.0, 1.0))
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Scalar reference vs executor over every config family × thread
/// count; captures compared field-for-field when quantized.
fn check_all_configs(manifest: &str, seed: u64) {
    let spec = ModelSpec::from_manifest_str(manifest).expect("manifest");
    let p = Params::random(&spec, seed);
    let scalar = Engine::new(&spec);
    let batch = 3usize;
    let x = input(batch, seed ^ 0xA5A5);
    let scales = scalar.calibrate(&p.tensors, &[&x], batch);

    let mut configs: Vec<(&str, QuantConfig)> = vec![
        ("float", QuantConfig::float(&spec)),
        ("quant", QuantConfig::quantized(&spec, scales.clone())),
    ];
    let convs = spec.convs();
    let mut masked = QuantConfig::quantized(&spec, scales.clone());
    masked.masks[0] = Some(magnitude_mask(&p.tensors[convs[0].w], 0.5));
    configs.push(("masked", masked));
    let mut wset = QuantConfig::quantized(&spec, scales.clone());
    wset.wsets[1] = Some(WeightSet::new(vec![-64, -16, 0, 16, 64]));
    configs.push(("wset", wset));
    let mut both = QuantConfig::quantized(&spec, scales.clone());
    both.masks[1] = Some(magnitude_mask(&p.tensors[convs[1].w], 0.7));
    both.wsets[0] = Some(WeightSet::new(vec![-96, -32, -8, 0, 8, 32, 96]));
    configs.push(("masked+wset", both));

    for (name, qc) in &configs {
        let capture = qc.quant_on;
        let want = scalar.forward(&p.tensors, &x, batch, qc, capture);
        for threads in [1usize, 2, 5] {
            let eng = ParallelEngine::new(&spec, &p.tensors, qc, threads);
            let mut buf = CaptureBuffer::new();
            let got = eng.forward(&x, batch, &mut buf);
            assert_eq!(
                bits(&want.logits),
                bits(&got.logits),
                "{name}: logits diverge at {threads} threads"
            );
            assert_eq!(
                bits(&want.act_max),
                bits(&got.act_max),
                "{name}: act_max diverges at {threads} threads"
            );
            if capture {
                let caps = buf.into_captures();
                assert_eq!(caps.len(), want.captures.len(), "{name}: capture count");
                for (a, b) in want.captures.iter().zip(&caps) {
                    assert_eq!(a.conv_idx, b.conv_idx, "{name}");
                    assert_eq!((a.m, a.k, a.n), (b.m, b.k, b.n), "{name} conv{}", a.conv_idx);
                    assert_eq!(a.x_codes, b.x_codes, "{name} conv{} x", a.conv_idx);
                    assert_eq!(a.w_codes, b.w_codes, "{name} conv{} w", a.conv_idx);
                    assert_eq!(a.s_act.to_bits(), b.s_act.to_bits(), "{name}");
                    assert_eq!(a.s_w.to_bits(), b.s_w.to_bits(), "{name}");
                }
            }
        }
    }
}

#[test]
fn edge_case_convs_bit_identical() {
    check_all_configs(EDGE_MANIFEST, 1);
}

/// Block-sparse forward vs the dense scalar reference across magnitude
/// prune ratios {0, 0.5, 0.9} × thread counts {1, 2, 5} on both the
/// edge-shape and residual manifests: logits, act maxima and captures
/// must stay bit-identical with the structural skip active.
#[test]
fn prune_ratio_sweep_bit_identical() {
    for (mi, manifest) in [EDGE_MANIFEST, RESIDUAL_MANIFEST].iter().enumerate() {
        let spec = ModelSpec::from_manifest_str(manifest).expect("manifest");
        let p = Params::random(&spec, 7 + mi as u64);
        let scalar = Engine::new(&spec);
        let batch = 2usize;
        let x = input(batch, 77 + mi as u64);
        let scales = scalar.calibrate(&p.tensors, &[&x], batch);
        for ratio in [0.0f64, 0.5, 0.9] {
            let mut qc = QuantConfig::quantized(&spec, scales.clone());
            for cv in spec.convs() {
                qc.masks[cv.conv_idx] = Some(magnitude_mask(&p.tensors[cv.w], ratio));
            }
            let want = scalar.forward(&p.tensors, &x, batch, &qc, true);
            for threads in [1usize, 2, 5] {
                let eng = ParallelEngine::new(&spec, &p.tensors, &qc, threads);
                let mut buf = CaptureBuffer::new();
                let got = eng.forward(&x, batch, &mut buf);
                assert_eq!(
                    bits(&want.logits),
                    bits(&got.logits),
                    "ratio={ratio} threads={threads}: logits diverge"
                );
                assert_eq!(
                    bits(&want.act_max),
                    bits(&got.act_max),
                    "ratio={ratio} threads={threads}: act_max diverges"
                );
                let caps = buf.into_captures();
                assert_eq!(caps.len(), want.captures.len(), "ratio={ratio}");
                for (a, b) in want.captures.iter().zip(&caps) {
                    assert_eq!(a.x_codes, b.x_codes, "ratio={ratio} conv{}", a.conv_idx);
                    assert_eq!(a.w_codes, b.w_codes, "ratio={ratio} conv{}", a.conv_idx);
                }
            }
        }
    }
}

/// Mask that zeroes every other SB-aligned k-row block of a conv's K×N
/// code matrix (K rows are (ky, kx, ci) taps, zeroed across all cout
/// columns) — block-structured pruning the executor skips structurally.
fn block_row_mask(cv: &ConvOp) -> Vec<f32> {
    let kk = cv.k * cv.k * cv.cin;
    let mut mask = vec![1.0f32; cv.cout * cv.cin * cv.k * cv.k];
    for r in 0..kk {
        if (r / SB) % 2 == 1 {
            continue; // keep odd blocks
        }
        let ci = r % cv.cin;
        let pos = r / cv.cin;
        let kx = pos % cv.k;
        let ky = pos / cv.k;
        for o in 0..cv.cout {
            mask[((o * cv.cin + ci) * cv.k + ky) * cv.k + kx] = 0.0;
        }
    }
    mask
}

/// Block-structured masks actually produce empty SB×SB blocks (unlike
/// unstructured magnitude pruning), the engine's sparsity report counts
/// the skipped MACs, and the forward stays bit-identical to the dense
/// scalar reference at every thread count.
#[test]
fn block_structured_masks_skip_and_match() {
    let spec = ModelSpec::from_manifest_str(EDGE_MANIFEST).expect("manifest");
    let p = Params::random(&spec, 9);
    let scalar = Engine::new(&spec);
    let batch = 2usize;
    let x = input(batch, 99);
    let scales = scalar.calibrate(&p.tensors, &[&x], batch);
    let mut qc = QuantConfig::quantized(&spec, scales);
    for cv in spec.convs() {
        qc.masks[cv.conv_idx] = Some(block_row_mask(cv));
    }
    let want = scalar.forward(&p.tensors, &x, batch, &qc, false);
    for threads in [1usize, 2, 5] {
        let eng = ParallelEngine::new(&spec, &p.tensors, &qc, threads);
        let got = eng.forward_plain(&x, batch);
        assert_eq!(bits(&want.logits), bits(&got.logits), "threads={threads}");
        let report = eng.sparsity_report(batch);
        assert_eq!(report.len(), spec.n_conv);
        let empty: u64 = report.iter().map(|r| r.sparsity.blocks_empty).sum();
        assert!(empty > 0, "block-structured masks must yield empty blocks");
        let skipped: u64 = report.iter().map(|r| r.macs_skipped).sum();
        assert!(skipped > 0, "skipped MACs must be counted");
        for r in &report {
            assert!(r.macs_skipped <= r.macs_dense, "conv{}", r.conv_idx);
        }
    }
}

#[test]
fn residual_projection_bit_identical() {
    check_all_configs(RESIDUAL_MANIFEST, 2);
}

/// Streaming stats through the executor are thread-count invariant
/// (blocks arrive in deterministic order regardless of the pool).
#[test]
fn stats_sink_thread_invariant() {
    let spec = ModelSpec::from_manifest_str(EDGE_MANIFEST).expect("manifest");
    let p = Params::random(&spec, 5);
    let batch = 2usize;
    let x = input(batch, 55);
    let scales = Engine::new(&spec).calibrate(&p.tensors, &[&x], batch);
    let qc = QuantConfig::quantized(&spec, scales);

    let run = |threads: usize| {
        let eng = ParallelEngine::new(&spec, &p.tensors, &qc, threads);
        let mut sink = StatsSink::new(99);
        eng.forward(&x, batch, &mut sink);
        sink.into_stats()
    };
    let a = run(1);
    let b = run(5);
    assert_eq!(a.len(), spec.n_conv);
    assert_eq!(a.len(), b.len());
    for (sa, sb) in a.iter().zip(&b) {
        assert_eq!(sa.conv_idx, sb.conv_idx);
        assert_eq!((sa.m, sa.k, sa.n), (sb.m, sb.k, sb.n));
        assert_eq!(sa.act.counts, sb.act.counts);
        assert_eq!(sa.act.total, sb.act.total);
        assert_eq!(sa.psum.counts, sb.psum.counts);
        assert_eq!(sa.psum.total, sb.psum.total);
        assert_eq!(sa.weight_usage, sb.weight_usage);
        assert!(sa.act.total > 0);
    }
}
