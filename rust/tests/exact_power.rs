//! Exact tile-power engine tests: the parallel levelized engine vs the
//! sequential reference across random tiles, thread counts and ragged
//! edge passes, plus the `--quick` exact-vs-model smoke check wired into
//! `scripts/verify.sh`.

use wsel::gates::CapModel;
use wsel::model::ConvCapture;
use wsel::systolic::{self, network_power_exact, MacLib, TilePowerEngine};
use wsel::testutil::cases;
use wsel::util::rng::Xoshiro256;
use wsel::util::threadpool::default_threads;

fn rand_codes(len: usize, rng: &mut Xoshiro256, zero_one_in: u64) -> Vec<i8> {
    (0..len)
        .map(|_| {
            if rng.below(zero_one_in) == 0 {
                0
            } else {
                rng.code() as i8
            }
        })
        .collect()
}

/// Tentpole property: the column-parallel, levelized, deduplicated
/// engine is bit-identical to the sequential `tile_power_exact`
/// reference — same toggle-derived energy bits and the same MAC-step
/// counts — across random tiles, ragged edges (mh/kh/nw < 64) and
/// thread counts.
#[test]
fn prop_engine_bit_identical_to_sequential_reference() {
    let mut lib = MacLib::new();
    lib.specialize_all(default_threads());
    let cap = CapModel::default();
    let engine = TilePowerEngine::new(&lib, &cap);
    cases(5, 0x711E, |g| {
        let m = g.usize_in(1, 66);
        let k = g.usize_in(1, 66);
        let n = g.usize_in(1, 40);
        let mut rng = Xoshiro256::new(g.rng.next_u64());
        let x = rand_codes(m * k, &mut rng, 3);
        let w = rand_codes(k * n, &mut rng, 2);
        let passes = systolic::passes_of(m, k, n);
        let pass = passes[g.usize_in(0, passes.len() - 1)];
        let (e_ref, s_ref) = systolic::tile_power_exact(&x, &w, k, n, &pass, &lib, &cap);
        for threads in [1usize, 2, 5] {
            let (e, s) = engine.pass_power(&x, &w, k, n, &pass, threads);
            assert_eq!(s, s_ref, "steps at {threads} threads");
            assert_eq!(
                e.to_bits(),
                e_ref.to_bits(),
                "energy at {threads} threads: {e} vs {e_ref} (pass {pass:?})"
            );
        }
    });
}

/// The fully-ragged corner: a 1×1×1 trailing pass.
#[test]
fn ragged_trailing_pass_exact() {
    let (m, k, n) = (65usize, 65, 65);
    let mut rng = Xoshiro256::new(9);
    let x = rand_codes(m * k, &mut rng, 2);
    let w = rand_codes(k * n, &mut rng, 2);
    let mut lib = MacLib::new();
    lib.specialize_for(&w, default_threads());
    let cap = CapModel::default();
    let engine = TilePowerEngine::new(&lib, &cap);
    let passes = systolic::passes_of(m, k, n);
    let last = passes[passes.len() - 1];
    assert_eq!((last.mh, last.kh, last.nw), (1, 1, 1));
    let (e_ref, s_ref) = systolic::tile_power_exact(&x, &w, k, n, &last, &lib, &cap);
    let (e, s) = engine.pass_power(&x, &w, k, n, &last, 3);
    assert_eq!((e.to_bits(), s), (e_ref.to_bits(), s_ref));
    assert_eq!(s, 1, "1x1x1 pass is a single MAC step");
}

/// Exact-vs-model validation smoke over a synthetic capture: the
/// characterized statistical table must track the exact engine within a
/// small constant factor.  `scripts/verify.sh --quick` runs exactly
/// this test as the fast ground-truth regression check.
#[test]
fn quick_exact_vs_model() {
    let mut rng = Xoshiro256::new(41);
    let (m, k, n) = (96usize, 70, 6);
    let capture = ConvCapture {
        conv_idx: 0,
        m,
        k,
        n,
        x_codes: rand_codes(m * k, &mut rng, 2),
        w_codes: rand_codes(k * n, &mut rng, 4),
        s_act: 0.01,
        s_w: 0.01,
    };
    let stats = wsel::stats::collect(&capture, &mut rng);
    let threads = default_threads();
    let mut lib = MacLib::new();
    lib.specialize_all(threads);
    let cm = CapModel::default();
    let table = wsel::energy::characterize_layer_shared(&stats, &lib, &cm, 128, 9, threads);

    let exact = network_power_exact(std::slice::from_ref(&capture), &lib, &cm, threads);
    assert_eq!(exact.layers.len(), 1);
    assert!(exact.layers[0].energy_j > 0.0);
    assert!(exact.layers[0].columns_unique <= exact.layers[0].columns_total);

    let report = wsel::energy::validate_captures(
        std::slice::from_ref(&capture),
        std::slice::from_ref(&table),
        &exact,
    );
    assert_eq!(report.layers.len(), 1);
    let l = &report.layers[0];
    assert!(l.exact_j > 0.0 && l.model_j > 0.0);
    let ratio = l.ratio();
    assert!(
        (0.05..20.0).contains(&ratio),
        "statistical model should track the exact engine: model/exact = {ratio:.3}"
    );
}
