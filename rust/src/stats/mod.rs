//! Per-layer activation & partial-sum statistics (paper §3.1.2).
//!
//! Built from the int8 engine's conv operand streams: the im2col code
//! matrix X (M×K) *is* the set of operand streams the weight-stationary
//! array sees — column k of X is exactly the activation sequence
//! entering PE row `k mod 64`, and the within-tile prefix sums over rows
//! are the partial-sum chains.  Layer-specific histograms of both feed
//! the per-weight MAC characterization in [`crate::energy`].
//!
//! Collection is **streaming**: a [`SamplePlan`] is drawn up-front
//! (which im2col columns and which (k-tile, output-column) pairs are
//! traced), and a [`StatsBuilder`] buffers only those sampled columns as
//! X row blocks arrive — a strict subset of the M×K matrix once
//! `K ≥ 192` (where `col_stride = K/96 ≥ 2`); smaller layers sample
//! every column, so the bound bites exactly where im2col matrices are
//! large.  [`StatsSink`]
//! adapts this to the executor's
//! [`CaptureSink`](crate::model::CaptureSink) stream; [`collect`] is the
//! whole-capture convenience wrapper.  Results are invariant to how the
//! rows are blocked (property-tested below) and hence to the executor's
//! thread count.

use crate::model::{CaptureSink, ConvCapture, ConvHead};
use crate::transitions::{ActTransHist, PsumGroupHist};
use crate::util::rng::Xoshiro256;

/// Tile dimension of the systolic array (64×64 weight-stationary).
pub const TILE: usize = 64;

/// Statistics of one convolution layer.
#[derive(Clone)]
pub struct LayerStats {
    pub conv_idx: usize,
    pub act: ActTransHist,
    pub psum: PsumGroupHist,
    /// Weight-code usage histogram (index = code + 128), §4.2.1 input.
    pub weight_usage: [u64; 256],
    /// Matmul dims observed (per calibration batch).
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// Number of (k-tile, output-column) pairs sampled for psum statistics.
const PSUM_SAMPLES: usize = 6;
/// Within each sampled pair, psum streams are recorded at these PE rows.
const PSUM_ROWS: [usize; 4] = [8, 24, 40, 56];

/// Deterministic per-layer sampling plan, drawn before any stream data
/// is seen (all draws depend only on the layer dims and the shared
/// profiling rng, so streaming and whole-capture collection consume the
/// rng identically).
#[derive(Clone, Debug)]
pub struct SamplePlan {
    /// Every `col_stride`-th im2col column feeds the activation
    /// transition histogram.
    pub col_stride: usize,
    /// Sampled (k-tile, output-column) pairs for psum chains.
    pub psum: Vec<(usize, usize)>,
}

impl SamplePlan {
    pub fn draw(k: usize, n: usize, rng: &mut Xoshiro256) -> Self {
        let col_stride = (k / 96).max(1);
        let k_tiles = k.div_ceil(TILE);
        let psum = (0..PSUM_SAMPLES)
            .map(|_| {
                (
                    rng.below(k_tiles as u64) as usize,
                    rng.below(n as u64) as usize,
                )
            })
            .collect();
        Self { col_stride, psum }
    }
}

/// Streaming statistics accumulator for one conv layer: buffers only the
/// plan's sampled columns as X row blocks arrive.
pub struct StatsBuilder {
    conv_idx: usize,
    k: usize,
    n: usize,
    m_seen: usize,
    plan: SamplePlan,
    /// Sampled activation columns (one per plan column, growing by
    /// `rows` codes per block).
    act_cols: Vec<Vec<i8>>,
    /// Per psum sample: the X tile slice, row-major `m_seen`×`kh`.
    psum_x: Vec<Vec<i8>>,
    /// Per psum sample: the weight codes down the sampled column.
    psum_w: Vec<Vec<i8>>,
    weight_usage: [u64; 256],
}

impl StatsBuilder {
    pub fn new(conv_idx: usize, k: usize, n: usize, w_codes: &[i8], plan: SamplePlan) -> Self {
        assert_eq!(w_codes.len(), k * n);
        let mut weight_usage = [0u64; 256];
        for &w in w_codes {
            weight_usage[(w as i32 + 128) as usize] += 1;
        }
        let act_cols = (0..k).step_by(plan.col_stride).map(|_| Vec::new()).collect();
        let psum_w = plan
            .psum
            .iter()
            .map(|&(kt, c)| {
                let k0 = kt * TILE;
                let kh = (k - k0).min(TILE);
                (0..kh).map(|r| w_codes[(k0 + r) * n + c]).collect()
            })
            .collect();
        Self {
            conv_idx,
            k,
            n,
            m_seen: 0,
            act_cols,
            psum_x: vec![Vec::new(); PSUM_SAMPLES],
            psum_w,
            plan,
            weight_usage,
        }
    }

    /// Feed a block of X rows (`rows`×`k`, row-major).
    pub fn push_block(&mut self, x_codes: &[i8], rows: usize) {
        let k = self.k;
        assert_eq!(x_codes.len(), rows * k);
        for (slot, col) in self.act_cols.iter_mut().zip((0..k).step_by(self.plan.col_stride)) {
            slot.extend((0..rows).map(|r| x_codes[r * k + col]));
        }
        for (slot, &(kt, _c)) in self.psum_x.iter_mut().zip(&self.plan.psum) {
            let k0 = kt * TILE;
            let kh = (k - k0).min(TILE);
            for r in 0..rows {
                slot.extend_from_slice(&x_codes[r * k + k0..r * k + k0 + kh]);
            }
        }
        self.m_seen += rows;
    }

    /// Finalize into [`LayerStats`].  The recording order (activation
    /// columns in plan order, then psum samples in plan order) matches
    /// [`collect`] exactly, so blocked streaming is bit-identical to
    /// whole-capture collection.
    pub fn finish(&mut self, rng: &mut Xoshiro256) -> LayerStats {
        let mut act = ActTransHist::new();
        for col in &self.act_cols {
            act.record_stream(col);
        }

        let mut psum = PsumGroupHist::new();
        let m = self.m_seen;
        let mut acc = vec![0i32; m];
        for (tile, wcol) in self.psum_x.iter().zip(&self.psum_w) {
            let kh = wcol.len();
            acc.iter_mut().for_each(|v| *v = 0);
            for (r, &w) in wcol.iter().enumerate() {
                if PSUM_ROWS.contains(&r) {
                    psum.record_stream(&acc, rng);
                }
                let w = w as i32;
                if w != 0 {
                    for (mi, a) in acc.iter_mut().enumerate() {
                        let x = tile[mi * kh + r] as i32;
                        // 22-bit wrap matches the hardware accumulator.
                        *a = crate::mac::unit::mac_ref(x, w, *a);
                    }
                }
            }
            // Top-of-column stream too (what the next tile pass inherits).
            psum.record_stream(&acc, rng);
        }

        LayerStats {
            conv_idx: self.conv_idx,
            act,
            psum,
            weight_usage: self.weight_usage,
            m,
            k: self.k,
            n: self.n,
        }
    }
}

/// Collect layer statistics from a whole capture (draws the sample plan
/// from `rng`, then streams the capture as a single block).
pub fn collect(cap: &ConvCapture, rng: &mut Xoshiro256) -> LayerStats {
    let plan = SamplePlan::draw(cap.k, cap.n, rng);
    let mut b = StatsBuilder::new(cap.conv_idx, cap.k, cap.n, &cap.w_codes, plan);
    b.push_block(&cap.x_codes, cap.m);
    b.finish(rng)
}

/// [`CaptureSink`] adapter: one [`StatsBuilder`] per conv, sample plans
/// drawn from a single profiling rng in conv execution order (the order
/// `begin_conv` arrives), stats finalized in the same order on
/// `finish()` and then sorted by `conv_idx`.  Expects one forward pass
/// per sink (each conv announced once).
pub struct StatsSink {
    rng: Xoshiro256,
    builders: Vec<StatsBuilder>,
    pos_of: Vec<Option<usize>>,
    stats: Vec<LayerStats>,
}

impl StatsSink {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            builders: Vec::new(),
            pos_of: Vec::new(),
            stats: Vec::new(),
        }
    }

    /// Finalized per-layer stats, ascending `conv_idx` (empty until the
    /// forward's `finish()` ran).
    pub fn into_stats(self) -> Vec<LayerStats> {
        self.stats
    }
}

impl CaptureSink for StatsSink {
    fn begin_conv(&mut self, head: &ConvHead<'_>) {
        if self.pos_of.len() <= head.conv_idx {
            self.pos_of.resize(head.conv_idx + 1, None);
        }
        assert!(
            self.pos_of[head.conv_idx].is_none(),
            "conv{} announced twice (one forward per StatsSink)",
            head.conv_idx
        );
        let plan = SamplePlan::draw(head.k, head.n, &mut self.rng);
        self.pos_of[head.conv_idx] = Some(self.builders.len());
        self.builders.push(StatsBuilder::new(
            head.conv_idx,
            head.k,
            head.n,
            head.w_codes,
            plan,
        ));
    }

    fn x_block(&mut self, conv_idx: usize, rows: usize, x_codes: &[i8]) {
        let pos = self
            .pos_of
            .get(conv_idx)
            .copied()
            .flatten()
            .expect("x_block before begin_conv");
        self.builders[pos].push_block(x_codes, rows);
    }

    fn finish(&mut self) {
        let mut builders = std::mem::take(&mut self.builders);
        for b in builders.iter_mut() {
            self.stats.push(b.finish(&mut self.rng));
        }
        self.stats.sort_by_key(|s| s.conv_idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_capture(m: usize, k: usize, n: usize, seed: u64) -> ConvCapture {
        let mut rng = Xoshiro256::new(seed);
        ConvCapture {
            conv_idx: 0,
            m,
            k,
            n,
            x_codes: (0..m * k)
                .map(|_| if rng.below(3) == 0 { 0 } else { rng.code() as i8 })
                .collect(),
            w_codes: (0..k * n).map(|_| rng.code() as i8).collect(),
            s_act: 0.01,
            s_w: 0.005,
        }
    }

    fn assert_stats_eq(a: &LayerStats, b: &LayerStats) {
        assert_eq!(a.conv_idx, b.conv_idx);
        assert_eq!((a.m, a.k, a.n), (b.m, b.k, b.n));
        assert_eq!(a.act.counts, b.act.counts);
        assert_eq!(a.act.total, b.act.total);
        assert_eq!(a.psum.counts, b.psum.counts);
        assert_eq!(a.psum.total, b.psum.total);
        assert_eq!(a.weight_usage, b.weight_usage);
    }

    #[test]
    fn collect_populates_histograms() {
        let cap = fake_capture(100, 80, 8, 1);
        let mut rng = Xoshiro256::new(2);
        let st = collect(&cap, &mut rng);
        assert!(st.act.total > 0);
        assert!(st.psum.total > 0);
        let usage_total: u64 = st.weight_usage.iter().sum();
        assert_eq!(usage_total, (80 * 8) as u64);
    }

    #[test]
    fn relu_sparsity_visible() {
        // 2/3 random + 1/3 zeros in x -> zero_fraction near 1/3.
        let cap = fake_capture(200, 64, 4, 3);
        let mut rng = Xoshiro256::new(4);
        let st = collect(&cap, &mut rng);
        let zf = st.act.zero_fraction();
        assert!(zf > 0.2 && zf < 0.5, "zero fraction {zf}");
    }

    /// Streaming the same rows in arbitrary block partitions is
    /// bit-identical to whole-capture collection — the property that
    /// makes the executor's per-image tile stream equivalent to the
    /// scalar engine's monolithic capture.
    #[test]
    fn blocked_streaming_equals_whole_capture() {
        let cap = fake_capture(150, 130, 7, 7);
        let whole = collect(&cap, &mut Xoshiro256::new(77));

        for cuts in [vec![150usize], vec![1, 149], vec![37, 53, 60], vec![64, 64, 22]] {
            let mut rng = Xoshiro256::new(77);
            let plan = SamplePlan::draw(cap.k, cap.n, &mut rng);
            let mut b = StatsBuilder::new(cap.conv_idx, cap.k, cap.n, &cap.w_codes, plan);
            let mut r0 = 0usize;
            for rows in cuts {
                b.push_block(&cap.x_codes[r0 * cap.k..(r0 + rows) * cap.k], rows);
                r0 += rows;
            }
            assert_eq!(r0, cap.m);
            let st = b.finish(&mut rng);
            assert_stats_eq(&whole, &st);
        }
    }

    /// The sink path (plan drawn in `begin_conv`, blocks via `x_block`,
    /// finalize in `finish`) equals `collect` with the same seed.
    #[test]
    fn sink_equals_collect() {
        let cap = fake_capture(90, 100, 5, 9);
        let whole = collect(&cap, &mut Xoshiro256::new(41));

        let mut sink = StatsSink::new(41);
        sink.begin_conv(&ConvHead {
            conv_idx: cap.conv_idx,
            m_total: cap.m,
            k: cap.k,
            n: cap.n,
            w_codes: &cap.w_codes,
            s_act: cap.s_act,
            s_w: cap.s_w,
        });
        sink.x_block(cap.conv_idx, 40, &cap.x_codes[..40 * cap.k]);
        sink.x_block(cap.conv_idx, 50, &cap.x_codes[40 * cap.k..]);
        sink.finish();
        let stats = sink.into_stats();
        assert_eq!(stats.len(), 1);
        assert_stats_eq(&whole, &stats[0]);
    }
}
