//! Per-layer activation & partial-sum statistics (paper §3.1.2).
//!
//! Built from the int8 engine's [`ConvCapture`]s: the im2col code matrix
//! X (M×K) *is* the set of operand streams the weight-stationary array
//! sees — column k of X is exactly the activation sequence entering PE
//! row `k mod 64`, and the within-tile prefix sums over rows are the
//! partial-sum chains.  Layer-specific histograms of both feed the
//! per-weight MAC characterization in [`crate::energy`].

use crate::model::ConvCapture;
use crate::transitions::{ActTransHist, PsumGroupHist};
use crate::util::rng::Xoshiro256;

/// Tile dimension of the systolic array (64×64 weight-stationary).
pub const TILE: usize = 64;

/// Statistics of one convolution layer.
#[derive(Clone)]
pub struct LayerStats {
    pub conv_idx: usize,
    pub act: ActTransHist,
    pub psum: PsumGroupHist,
    /// Weight-code usage histogram (index = code + 128), §4.2.1 input.
    pub weight_usage: [u64; 256],
    /// Matmul dims observed (per calibration batch).
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// Number of (k-tile, output-column) pairs sampled for psum statistics.
const PSUM_SAMPLES: usize = 6;
/// Within each sampled pair, psum streams are recorded at these PE rows.
const PSUM_ROWS: [usize; 4] = [8, 24, 40, 56];

/// Collect layer statistics from a capture.
pub fn collect(cap: &ConvCapture, rng: &mut Xoshiro256) -> LayerStats {
    let mut act = ActTransHist::new();
    // Activation transitions: every im2col column is a PE operand stream.
    // For large layers, sample columns to bound cost.
    let col_stride = (cap.k / 96).max(1);
    let mut col = 0;
    let mut stream = Vec::with_capacity(cap.m);
    while col < cap.k {
        stream.clear();
        for m in 0..cap.m {
            stream.push(cap.x_codes[m * cap.k + col]);
        }
        act.record_stream(&stream);
        col += col_stride;
    }

    // Partial-sum streams: sample (k-tile, out-column) pairs, sweep the
    // 64 PE rows maintaining per-m accumulators, record at PSUM_ROWS.
    let mut psum = PsumGroupHist::new();
    let k_tiles = cap.k.div_ceil(TILE);
    let mut acc = vec![0i32; cap.m];
    for _ in 0..PSUM_SAMPLES {
        let kt = rng.below(k_tiles as u64) as usize;
        let c = rng.below(cap.n as u64) as usize;
        let k0 = kt * TILE;
        let kh = (cap.k - k0).min(TILE);
        acc.iter_mut().for_each(|v| *v = 0);
        for r in 0..kh {
            if PSUM_ROWS.contains(&r) {
                psum.record_stream(&acc, rng);
            }
            let w = cap.w_codes[(k0 + r) * cap.n + c] as i32;
            if w != 0 {
                for m in 0..cap.m {
                    let a = cap.x_codes[m * cap.k + (k0 + r)] as i32;
                    // 22-bit wrap matches the hardware accumulator.
                    acc[m] = crate::mac::unit::mac_ref(a, w, acc[m]);
                }
            }
        }
        // Top-of-column stream too (what the next tile pass inherits).
        psum.record_stream(&acc, rng);
    }

    let mut weight_usage = [0u64; 256];
    for &w in &cap.w_codes {
        weight_usage[(w as i32 + 128) as usize] += 1;
    }

    LayerStats {
        conv_idx: cap.conv_idx,
        act,
        psum,
        weight_usage,
        m: cap.m,
        k: cap.k,
        n: cap.n,
    }
}

/// Merge statistics from several captures of the same layer (multiple
/// calibration batches).
pub fn merge(mut stats: Vec<LayerStats>) -> LayerStats {
    assert!(!stats.is_empty());
    let mut base = stats.remove(0);
    for s in stats {
        assert_eq!(s.conv_idx, base.conv_idx);
        for i in 0..256 * 256 {
            base.act.counts[i] += s.act.counts[i];
        }
        base.act.total += s.act.total;
        for i in 0..base.psum.counts.len() {
            base.psum.counts[i] += s.psum.counts[i];
        }
        base.psum.total += s.psum.total;
        // weight usage identical across batches (same weights) — keep base.
    }
    base
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_capture(m: usize, k: usize, n: usize, seed: u64) -> ConvCapture {
        let mut rng = Xoshiro256::new(seed);
        ConvCapture {
            conv_idx: 0,
            m,
            k,
            n,
            x_codes: (0..m * k)
                .map(|_| if rng.below(3) == 0 { 0 } else { rng.code() as i8 })
                .collect(),
            w_codes: (0..k * n).map(|_| rng.code() as i8).collect(),
            s_act: 0.01,
            s_w: 0.005,
        }
    }

    #[test]
    fn collect_populates_histograms() {
        let cap = fake_capture(100, 80, 8, 1);
        let mut rng = Xoshiro256::new(2);
        let st = collect(&cap, &mut rng);
        assert!(st.act.total > 0);
        assert!(st.psum.total > 0);
        let usage_total: u64 = st.weight_usage.iter().sum();
        assert_eq!(usage_total, (80 * 8) as u64);
    }

    #[test]
    fn relu_sparsity_visible() {
        // 2/3 random + 1/3 zeros in x -> zero_fraction near 1/3.
        let cap = fake_capture(200, 64, 4, 3);
        let mut rng = Xoshiro256::new(4);
        let st = collect(&cap, &mut rng);
        let zf = st.act.zero_fraction();
        assert!(zf > 0.2 && zf < 0.5, "zero fraction {zf}");
    }

    #[test]
    fn merge_accumulates() {
        let cap = fake_capture(50, 64, 4, 5);
        let mut rng = Xoshiro256::new(6);
        let a = collect(&cap, &mut rng);
        let b = collect(&cap, &mut rng);
        let at = a.act.total;
        let m = merge(vec![a, b]);
        assert_eq!(m.act.total, at * 2);
    }
}
