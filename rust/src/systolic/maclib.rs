//! Cache of weight-specialized MAC netlists.
//!
//! Specializing the generic MAC for one of the 255 int8 codes costs a
//! const-prop pass (~1 ms); the library memoizes all of them so tile
//! simulation and per-weight characterization amortize the cost.

use crate::mac::{build_mac, specialize_mac, MacNetlist};

pub struct MacLib {
    generic: MacNetlist,
    /// Index = code + 128.
    cache: Vec<Option<MacNetlist>>,
}

impl Default for MacLib {
    fn default() -> Self {
        Self::new()
    }
}

impl MacLib {
    pub fn new() -> Self {
        Self {
            generic: build_mac(),
            cache: (0..256).map(|_| None).collect(),
        }
    }

    /// The generic (weight-as-input) MAC.
    pub fn generic(&self) -> &MacNetlist {
        &self.generic
    }

    /// Specialized netlist for a weight code.
    pub fn get(&mut self, weight: i8) -> &MacNetlist {
        let idx = (weight as i32 + 128) as usize;
        if self.cache[idx].is_none() {
            self.cache[idx] = Some(specialize_mac(&self.generic, weight as i32));
        }
        self.cache[idx].as_ref().unwrap()
    }

    /// Shared-reference lookup for pre-specialized codes (lets the
    /// characterization loop fan out over a `&MacLib`).
    pub fn get_cached(&self, weight: i8) -> Option<&MacNetlist> {
        self.cache[(weight as i32 + 128) as usize].as_ref()
    }

    /// Gate count per weight (area proxy; also a quick Fig. 1 sanity
    /// signal since switching scales with surviving logic).
    pub fn gate_count(&mut self, weight: i8) -> usize {
        self.get(weight).netlist.gate_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_and_reuses() {
        let mut lib = MacLib::new();
        let g1 = lib.get(5).netlist.gate_count();
        let g2 = lib.get(5).netlist.gate_count();
        assert_eq!(g1, g2);
    }

    #[test]
    fn sparse_codes_are_smaller() {
        let mut lib = MacLib::new();
        // |w| with few set bits -> fewer surviving gates than dense codes.
        let g1 = lib.gate_count(1);
        let g_dense = lib.gate_count(0b0101_0101u8 as i8 ^ 0); // 85
        assert!(g1 < g_dense, "g(1)={g1} g(85)={g_dense}");
    }
}
