//! Cache of weight-specialized MAC netlists.
//!
//! Specializing the generic MAC for one of the 255 int8 codes costs a
//! const-prop pass (~1 ms); the library memoizes all of them so tile
//! simulation and per-weight characterization amortize the cost.

use crate::mac::{build_mac, specialize_mac, MacNetlist};
use crate::util::threadpool::parallel_map;

pub struct MacLib {
    generic: MacNetlist,
    /// Index = code + 128.
    cache: Vec<Option<MacNetlist>>,
}

impl Default for MacLib {
    fn default() -> Self {
        Self::new()
    }
}

impl MacLib {
    pub fn new() -> Self {
        Self {
            generic: build_mac(),
            cache: (0..256).map(|_| None).collect(),
        }
    }

    /// The generic (weight-as-input) MAC.
    pub fn generic(&self) -> &MacNetlist {
        &self.generic
    }

    /// Specialized netlist for a weight code.
    pub fn get(&mut self, weight: i8) -> &MacNetlist {
        let idx = (weight as i32 + 128) as usize;
        if self.cache[idx].is_none() {
            self.cache[idx] = Some(specialize_mac(&self.generic, weight as i32));
        }
        self.cache[idx].as_ref().unwrap()
    }

    /// Shared-reference lookup for pre-specialized codes (lets the
    /// characterization loop fan out over a `&MacLib`).
    pub fn get_cached(&self, weight: i8) -> Option<&MacNetlist> {
        self.cache[(weight as i32 + 128) as usize].as_ref()
    }

    /// Specialize every code in `[-127, 127]` that is still missing,
    /// fanning the const-prop passes out over `threads` workers.  After
    /// this, the library can be shared immutably across threads
    /// ([`Self::get_cached`] never misses).
    pub fn specialize_all(&mut self, threads: usize) {
        let missing: Vec<i32> = (-127i32..=127)
            .filter(|&c| self.cache[(c + 128) as usize].is_none())
            .collect();
        self.build_missing(missing, threads);
    }

    /// Specialize exactly the codes appearing in `codes` (deduplicated)
    /// that are still missing — the cheap alternative to
    /// [`Self::specialize_all`] when only one tile's weights are needed
    /// before handing a `&MacLib` to the exact tile-power path.
    pub fn specialize_for(&mut self, codes: &[i8], threads: usize) {
        let mut missing: Vec<i32> = codes
            .iter()
            .map(|&c| c as i32)
            .filter(|&c| self.cache[(c + 128) as usize].is_none())
            .collect();
        missing.sort_unstable();
        missing.dedup();
        self.build_missing(missing, threads);
    }

    fn build_missing(&mut self, missing: Vec<i32>, threads: usize) {
        if missing.is_empty() {
            return;
        }
        let generic = &self.generic;
        let built = parallel_map(missing.len(), threads, |i| {
            specialize_mac(generic, missing[i])
        });
        for (c, nl) in missing.iter().zip(built) {
            self.cache[(c + 128) as usize] = Some(nl);
        }
    }

    /// Gate count per weight (area proxy; also a quick Fig. 1 sanity
    /// signal since switching scales with surviving logic).
    pub fn gate_count(&mut self, weight: i8) -> usize {
        self.get(weight).netlist.gate_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_and_reuses() {
        let mut lib = MacLib::new();
        let g1 = lib.get(5).netlist.gate_count();
        let g2 = lib.get(5).netlist.gate_count();
        assert_eq!(g1, g2);
    }

    #[test]
    fn specialize_all_fills_cache_and_matches_lazy() {
        let mut a = MacLib::new();
        a.specialize_all(4);
        for c in -127i32..=127 {
            assert!(a.get_cached(c as i8).is_some(), "code {c} missing");
        }
        // Idempotent and identical to the lazy path.
        a.specialize_all(2);
        let mut b = MacLib::new();
        for c in [-127i32, -1, 0, 1, 85, 127] {
            assert_eq!(
                a.get_cached(c as i8).unwrap().netlist.gate_count(),
                b.get(c as i8).netlist.gate_count(),
                "code {c}"
            );
        }
    }

    #[test]
    fn specialize_for_fills_only_requested() {
        let mut lib = MacLib::new();
        lib.specialize_for(&[3, -7, 3, 0], 2);
        for c in [3i8, -7, 0] {
            assert!(lib.get_cached(c).is_some(), "code {c} missing");
        }
        assert!(lib.get_cached(55).is_none());
    }

    #[test]
    fn sparse_codes_are_smaller() {
        let mut lib = MacLib::new();
        // |w| with few set bits -> fewer surviving gates than dense codes.
        let g1 = lib.gate_count(1);
        let g_dense = lib.gate_count(0b0101_0101u8 as i8 ^ 0); // 85
        assert!(g1 < g_dense, "g(1)={g1} g(85)={g_dense}");
    }
}
