//! Cycle-level 64×64 weight-stationary systolic array (paper §3.2).
//!
//! The layer matmul `Y(M×N) = X(M×K)·W(K×N)` is cut into tile *passes*:
//! a 64×64 weight tile stays resident while a 64-row block of X streams
//! through (128 cycles per pass: 64 fill + 64 drain).  This module
//! provides
//!
//! * the tile schedule ([`passes_of`]) — the paper's `N_ℓ`;
//! * a functional simulation ([`simulate_tile`]) that reproduces the
//!   matmul result from per-PE MAC steps (validating the mapping against
//!   the engine / the Pallas tile artifact);
//! * an **exact gate-level power mode** ([`tile_power_exact`]) that
//!   drives every PE's specialized MAC netlist with its real operand
//!   streams — the ground truth used to validate the statistical model
//!   of [`crate::energy`];
//! * the **network-scale parallel engine** ([`power`]):
//!   [`TilePowerEngine`] fans deduplicated column streams out over the
//!   thread pool through a levelized evaluation schedule, and
//!   [`network_power_exact`] streams every pass of every captured conv
//!   layer — same ground truth, whole-network scale.

pub mod maclib;
pub mod power;

use crate::gates::{CapModel, TraceSim};
use crate::mac::unit::mac_ref;
pub use maclib::MacLib;
pub use power::{network_power_exact, ExactLayerPower, ExactNetworkPower, PowerSink, TilePowerEngine};

/// Systolic array dimension.
pub const TILE: usize = 64;
/// Cycles per tile pass at clock f (64 fill + 64 drain), per the paper.
pub const CYCLES_PER_PASS: u64 = 128;

/// One tile pass: weight sub-block [k0..k0+kh) × [n0..n0+nw) against X
/// rows [m0..m0+mh).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pass {
    pub m0: usize,
    pub mh: usize,
    pub k0: usize,
    pub kh: usize,
    pub n0: usize,
    pub nw: usize,
}

/// All tile passes of an (M, K, N) matmul, k-major then n then m —
/// the order a weight-stationary scheduler loads tiles.
pub fn passes_of(m: usize, k: usize, n: usize) -> Vec<Pass> {
    let mut out = Vec::new();
    for n0 in (0..n).step_by(TILE) {
        for k0 in (0..k).step_by(TILE) {
            for m0 in (0..m).step_by(TILE) {
                out.push(Pass {
                    m0,
                    mh: (m - m0).min(TILE),
                    k0,
                    kh: (k - k0).min(TILE),
                    n0,
                    nw: (n - n0).min(TILE),
                });
            }
        }
    }
    out
}

/// The paper's `N_ℓ`: number of tile passes for a layer matmul.
pub fn n_tiles(m: usize, k: usize, n: usize) -> u64 {
    (m.div_ceil(TILE) * k.div_ceil(TILE) * n.div_ceil(TILE)) as u64
}

/// Functionally simulate one pass: accumulate `partial[mh × nw]` using
/// per-PE MAC steps with 22-bit accumulators (wrap included), exactly as
/// the hardware columns chain partial sums.
pub fn simulate_tile(
    x_codes: &[i8],
    w_codes: &[i8],
    k: usize,
    n: usize,
    pass: &Pass,
    partial: &mut [i32],
) {
    assert_eq!(partial.len(), pass.mh * pass.nw);
    for mi in 0..pass.mh {
        let xrow = &x_codes[(pass.m0 + mi) * k..];
        for c in 0..pass.nw {
            let mut acc = partial[mi * pass.nw + c];
            for r in 0..pass.kh {
                let a = xrow[pass.k0 + r] as i32;
                let w = w_codes[(pass.k0 + r) * n + (pass.n0 + c)] as i32;
                acc = mac_ref(a, w, acc);
            }
            partial[mi * pass.nw + c] = acc;
        }
    }
}

/// Full matmul through the tile schedule (returns M×N i32; values are
/// exact when K·127² fits 22 bits per column chain — callers validate
/// against the engine's wide accumulation).
pub fn matmul_tiled(x_codes: &[i8], w_codes: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut y = vec![0i32; m * n];
    let mut partial = vec![0i32; TILE * TILE];
    for pass in passes_of(m, k, n) {
        partial[..pass.mh * pass.nw]
            .iter_mut()
            .enumerate()
            .for_each(|(i, v)| {
                let mi = i / pass.nw;
                let c = i % pass.nw;
                *v = y[(pass.m0 + mi) * n + (pass.n0 + c)];
            });
        simulate_tile(x_codes, w_codes, k, n, &pass, &mut partial[..pass.mh * pass.nw]);
        for mi in 0..pass.mh {
            for c in 0..pass.nw {
                y[(pass.m0 + mi) * n + (pass.n0 + c)] = partial[mi * pass.nw + c];
            }
        }
    }
    y
}

/// Exact gate-level energy of one tile pass (J): every PE's specialized
/// netlist is driven with its true (activation, psum-in) streams.
///
/// This is the **sequential reference**: single-threaded, per-gate
/// topological evaluation, per-lane bit-plane packing.  The production
/// path is [`TilePowerEngine::pass_power`] — column-parallel, levelized,
/// deduplicated, and bit-identical to this function (property-tested in
/// `rust/tests/exact_power.rs`).
///
/// `lib` must already hold every weight code of the tile
/// ([`MacLib::specialize_all`] or [`MacLib::specialize_for`]); borrowing
/// it shared is what lets callers fan many passes out over one library.
///
/// Returns (energy_joules, simulated_mac_steps).
pub fn tile_power_exact(
    x_codes: &[i8],
    w_codes: &[i8],
    k: usize,
    n: usize,
    pass: &Pass,
    lib: &MacLib,
    cap: &CapModel,
) -> (f64, u64) {
    let mh = pass.mh;
    // Per-weight simulation state (power ctx + trace sim + word buffer)
    // is reused across the up-to-4096 PEs of the pass, and the power
    // report is folded ONCE per weight at the end (toggle counts are
    // additive across trace segments) — building/reporting per PE
    // dominated the profile before (EXPERIMENTS.md §Perf).  The state
    // lives in a fixed 256-slot array indexed by weight code (+128):
    // no hashing in the row loop, and the final fold walks ascending
    // codes so the f64 energy total is reproducible run-to-run (the
    // HashMap this replaces leaked its iteration order into the sum).
    let mut state: Vec<Option<(crate::gates::PowerCtx, TraceSim, Vec<u64>)>> =
        (0..256).map(|_| None).collect();
    // Column-major sweep: maintain psum-in streams incrementally.
    let mut psum_in = vec![0i32; mh];
    let mut act_stream = vec![0i32; mh];
    for c in 0..pass.nw {
        psum_in.iter_mut().for_each(|v| *v = 0);
        for r in 0..pass.kh {
            let w = w_codes[(pass.k0 + r) * n + (pass.n0 + c)];
            for mi in 0..mh {
                act_stream[mi] = x_codes[(pass.m0 + mi) * k + pass.k0 + r] as i32;
            }
            let mac = lib
                .get_cached(w)
                .expect("MacLib must be pre-specialized (specialize_all / specialize_for)");
            let (_ctx, sim, words) = state[(w as i32 + 128) as usize].get_or_insert_with(|| {
                let n_in = mac.netlist.inputs.len();
                (
                    cap.ctx(&mac.netlist),
                    TraceSim::new(&mac.netlist),
                    vec![0u64; n_in],
                )
            });
            sim.new_segment();
            // Pack the (a, psum) trace in 64-step chunks.
            let mut mi = 0;
            while mi < mh {
                let chunk = (mh - mi).min(64);
                words.iter_mut().for_each(|w| *w = 0);
                for lane in 0..chunk {
                    // Branchless bit-plane transpose of (a, psum_in).
                    let a = act_stream[mi + lane] as u32;
                    let p = psum_in[mi + lane] as u32;
                    for (bit, wslot) in words[..crate::mac::ACT_BITS].iter_mut().enumerate() {
                        *wslot |= (((a >> bit) & 1) as u64) << lane;
                    }
                    for (bit, wslot) in words[crate::mac::ACT_BITS..].iter_mut().enumerate() {
                        *wslot |= (((p >> bit) & 1) as u64) << lane;
                    }
                }
                sim.run_chunk(&mac.netlist, words, chunk as u32);
                mi += chunk;
            }
            // Update psum streams for the next row.
            if w != 0 {
                for mi in 0..mh {
                    psum_in[mi] = mac_ref(act_stream[mi], w as i32, psum_in[mi]);
                }
            }
        }
    }
    // Fold power once per distinct weight value, in ascending code order.
    let mut total = 0.0f64;
    let mut steps = 0u64;
    for (ctx, sim, _) in state.iter().flatten() {
        let rep = ctx.report(sim);
        total += rep.energy_j;
        steps += rep.cycles;
    }
    (total, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_codes(n: usize, seed: u64, sparsity: u64) -> Vec<i8> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                if rng.below(sparsity) == 0 {
                    0
                } else {
                    rng.code() as i8
                }
            })
            .collect()
    }

    #[test]
    fn schedule_covers_matrix() {
        let (m, k, n) = (130, 100, 70);
        let passes = passes_of(m, k, n);
        assert_eq!(passes.len() as u64, n_tiles(m, k, n));
        // Every (m, k, n) cell covered exactly once.
        let mut cover = vec![0u8; m * k * n];
        for p in &passes {
            for mi in p.m0..p.m0 + p.mh {
                for r in p.k0..p.k0 + p.kh {
                    for c in p.n0..p.n0 + p.nw {
                        cover[(mi * k + r) * n + c] += 1;
                    }
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1));
    }

    /// The tiled systolic simulation must reproduce the plain matmul
    /// (with small-K operands so 22-bit accumulators never wrap).
    #[test]
    fn tiled_matmul_matches_reference() {
        let (m, k, n) = (70, 90, 17);
        let x = rand_codes(m * k, 1, 3);
        let w = rand_codes(k * n, 2, 3);
        let y = matmul_tiled(&x, &w, m, k, n);
        for mi in 0..m {
            for c in 0..n {
                let mut acc = 0i64;
                for r in 0..k {
                    acc += x[mi * k + r] as i64 * w[r * n + c] as i64;
                }
                // Value must fit 22 bits for this test's dims.
                assert_eq!(y[mi * n + c] as i64, acc, "({mi},{c})");
            }
        }
    }

    #[test]
    fn exact_power_positive_and_weight_dependent() {
        let (m, k, n) = (64, 64, 2);
        let x = rand_codes(m * k, 3, 2);
        // Compare an all-zero weight tile against a dense one.
        let w_zero = vec![0i8; k * n];
        let w_dense = rand_codes(k * n, 4, 1000);
        let mut lib = MacLib::new();
        lib.specialize_for(&w_zero, 2);
        lib.specialize_for(&w_dense, 2);
        let cap = CapModel::default();
        let pass = passes_of(m, k, n)[0];
        let (e_zero, s1) = tile_power_exact(&x, &w_zero, k, n, &pass, &lib, &cap);
        let (e_dense, s2) = tile_power_exact(&x, &w_dense, k, n, &pass, &lib, &cap);
        assert_eq!(s1, s2);
        assert!(e_zero > 0.0, "idle power must include clock energy");
        assert!(
            e_dense > e_zero * 1.5,
            "dense tile {e_dense} should dwarf zero tile {e_zero}"
        );
    }
}
