//! Network-scale exact gate-level power: the parallel levelized
//! tile-power engine.
//!
//! [`tile_power_exact`](super::tile_power_exact) is the sequential
//! reference (one thread, per-gate dispatch, per-lane bit packing); this
//! module is the production path that turns exact power from a one-tile
//! debugging tool into a subsystem that covers whole networks:
//!
//! * **Column-parallel decomposition** — partial sums chain *within* a
//!   systolic column and never across columns, so every (pass, column)
//!   stream is independent.  Streams fan out over
//!   [`parallel_for_with`]: each worker owns per-weight scratch
//!   ([`TraceSim`]s in a fixed 256-slot table) reused across all the
//!   streams it claims.
//! * **Levelized SoA evaluation** — every weight-specialized MAC gets an
//!   [`EvalSchedule`] (kind-homogeneous runs in topological-level
//!   order), and operand packing goes lane-major through the
//!   Hacker's-Delight [`transpose64`] instead of per-lane bit loops.
//! * **Column-stream deduplication** — a column's input trace is fully
//!   determined by (X-block *content*, weight-column codes).  Identical
//!   streams across tile passes — repeated weight columns across
//!   n-tiles, and m-blocks whose activation content repeats (zero
//!   padding, duplicated rows) — are simulated once and accounted with
//!   an exact toggle multiplicity ([`TraceSim::set_multiplicity`]);
//!   toggle counting is linear, so this is lossless.
//!
//! **Determinism.** Per-node toggles are `u64` and additive; worker
//! results merge by exact integer addition; the energy fold walks weight
//! codes in ascending order through a fixed node-order summation
//! ([`PowerCtx::report_raw`]).  Consequences, property-tested in
//! `rust/tests/exact_power.rs`:
//!
//! * any single pass is **bit-identical** to the sequential
//!   [`tile_power_exact`](super::tile_power_exact) reference (identical
//!   merged toggles, identical fold);
//! * per-layer (multi-pass) energies are **bit-identical for any thread
//!   count**; against a *sequential per-pass sum* they agree to f64
//!   rounding of the fold order (toggles still match exactly — only the
//!   summation association differs, ~1 ulp).

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use super::{passes_of, MacLib, Pass};
use crate::energy::validate::StreamMeta;
use crate::energy::NetworkEnergy;
use crate::gates::{transpose64, CapModel, EvalSchedule, Netlist, PowerCtx, TraceSim};
use crate::mac::unit::mac_ref;
use crate::mac::{ACC_BITS, ACT_BITS};
use crate::model::{CaptureSink, ConvCapture, ConvHead};
use crate::util::threadpool::parallel_for_with;

/// One deduplicated unit of work: the (X-block, weight-column) stream of
/// one systolic column, standing for `mult` identical pass columns.
struct ColJob {
    m0: usize,
    mh: usize,
    k0: usize,
    /// Weight codes down the column (`kh` entries).
    wcol: Vec<i8>,
    mult: u64,
}

/// Per-weight shared context: the specialized netlist, its power fold
/// constants and its levelized evaluation schedule.
struct EngineSlot<'l> {
    nl: &'l Netlist,
    ctx: PowerCtx,
    sched: EvalSchedule,
    n_inputs: usize,
}

/// Per-worker scratch: one toggle-accumulating [`TraceSim`] per weight
/// code touched (index = code + 128) plus the 64-lane packing buffers.
struct Scratch {
    sims: Vec<Option<TraceSim>>,
    lanes: [u64; 64],
    psum: [i32; 64],
    acts: [i32; 64],
}

impl Scratch {
    fn new() -> Self {
        Self {
            sims: (0..256).map(|_| None).collect(),
            lanes: [0; 64],
            psum: [0; 64],
            acts: [0; 64],
        }
    }
}

/// Shared, read-only exact tile-power engine over a pre-specialized
/// [`MacLib`].  Build once, then evaluate any number of passes /
/// captures from any number of threads.
pub struct TilePowerEngine<'l> {
    /// Index = weight code + 128; populated for every code cached in the
    /// library at construction time.
    slots: Vec<Option<EngineSlot<'l>>>,
}

impl<'l> TilePowerEngine<'l> {
    /// Build per-weight power contexts and levelized schedules for every
    /// code cached in `lib` (run [`MacLib::specialize_all`] or
    /// [`MacLib::specialize_for`] first).
    pub fn new(lib: &'l MacLib, cap: &CapModel) -> Self {
        let slots = (0..256)
            .map(|idx| {
                let code = (idx as i32 - 128) as i8;
                lib.get_cached(code).map(|mac| {
                    let n_inputs = mac.netlist.inputs.len();
                    assert!(n_inputs <= 64, "column packing needs <= 64 input bits");
                    EngineSlot {
                        nl: &mac.netlist,
                        ctx: cap.ctx(&mac.netlist),
                        sched: EvalSchedule::new(&mac.netlist),
                        n_inputs,
                    }
                })
            })
            .collect();
        Self { slots }
    }

    fn slot(&self, w: i8) -> &EngineSlot<'l> {
        self.slots[(w as i32 + 128) as usize]
            .as_ref()
            .expect("weight code not specialized in MacLib (specialize_all / specialize_for)")
    }

    /// Deduplicated column jobs for a set of passes over one (X, W)
    /// operand pair.  A column's input stream is fully determined by
    /// (X-block *content*, weight-column codes), so the key is a
    /// canonical X-block id plus the column's weight codes: repeated
    /// weight columns dedup across n-tiles, and m-blocks with identical
    /// activation content (zero padding, repeated rows) dedup too.
    /// Jobs keep first-encounter order, so the job list itself is
    /// deterministic.  Returns (jobs, total columns).
    fn column_jobs(
        x_codes: &[i8],
        w_codes: &[i8],
        k: usize,
        n: usize,
        passes: &[Pass],
    ) -> (Vec<ColJob>, u64) {
        // Canonical id per (m0, k0) X-block: blocks with bit-identical
        // (mh, kh, codes) content share an id.
        let mut block_of: HashMap<(usize, usize), u32> = HashMap::new();
        let mut content_ids: HashMap<(usize, usize, Vec<i8>), u32> = HashMap::new();
        for pass in passes {
            let coord = (pass.m0, pass.k0);
            if block_of.contains_key(&coord) {
                continue;
            }
            let mut content = Vec::with_capacity(pass.mh * pass.kh);
            for mi in 0..pass.mh {
                for r in 0..pass.kh {
                    content.push(x_codes[(pass.m0 + mi) * k + pass.k0 + r]);
                }
            }
            let next_id = content_ids.len() as u32;
            let id = *content_ids
                .entry((pass.mh, pass.kh, content))
                .or_insert(next_id);
            block_of.insert(coord, id);
        }

        let mut jobs: Vec<ColJob> = Vec::new();
        let mut index: HashMap<(u32, Vec<i8>), usize> = HashMap::new();
        let mut total = 0u64;
        for pass in passes {
            let block = block_of[&(pass.m0, pass.k0)];
            for c in 0..pass.nw {
                let wcol: Vec<i8> = (0..pass.kh)
                    .map(|r| w_codes[(pass.k0 + r) * n + (pass.n0 + c)])
                    .collect();
                total += 1;
                match index.entry((block, wcol.clone())) {
                    Entry::Occupied(o) => jobs[*o.get()].mult += 1,
                    Entry::Vacant(v) => {
                        v.insert(jobs.len());
                        jobs.push(ColJob {
                            m0: pass.m0,
                            mh: pass.mh,
                            k0: pass.k0,
                            wcol,
                            mult: 1,
                        });
                    }
                }
            }
        }
        (jobs, total)
    }

    /// Simulate one column stream into the worker scratch: `kh` rows of
    /// `mh` trace steps each, psum-in maintained incrementally exactly
    /// like the hardware column chains partial sums.
    fn run_column(&self, x_codes: &[i8], k: usize, job: &ColJob, scratch: &mut Scratch) {
        let mh = job.mh;
        debug_assert!(mh >= 1 && mh <= 64);
        scratch.psum[..mh].fill(0);
        for (r, &w) in job.wcol.iter().enumerate() {
            let slot = self.slot(w);
            for (mi, a) in scratch.acts[..mh].iter_mut().enumerate() {
                *a = x_codes[(job.m0 + mi) * k + job.k0 + r] as i32;
            }
            // Lane-major packing, then one bit-matrix transpose into the
            // simulator's bit-plane words: lane word = [a0..a7, p0..p21].
            for lane in 0..mh {
                let a = (scratch.acts[lane] as u32 as u64) & 0xFF;
                let p = (scratch.psum[lane] as u32 as u64) & ((1u64 << ACC_BITS) - 1);
                scratch.lanes[lane] = a | (p << ACT_BITS);
            }
            scratch.lanes[mh..].fill(0);
            transpose64(&mut scratch.lanes);
            let sim = scratch.sims[(w as i32 + 128) as usize]
                .get_or_insert_with(|| TraceSim::new(slot.nl));
            sim.set_multiplicity(job.mult);
            sim.new_segment();
            sim.run_chunk_scheduled(&slot.sched, &scratch.lanes[..slot.n_inputs], mh as u32);
            // Psum stream for the next row (w = 0 leaves it unchanged).
            if w != 0 {
                for mi in 0..mh {
                    scratch.psum[mi] = mac_ref(scratch.acts[mi], w as i32, scratch.psum[mi]);
                }
            }
        }
    }

    /// Fan jobs out over the pool and fold deterministically: merge the
    /// workers' per-weight toggle accumulators with exact `u64` adds,
    /// then fold energies in ascending weight-code order.
    fn run_jobs(&self, x_codes: &[i8], k: usize, jobs: &[ColJob], threads: usize) -> (f64, u64) {
        let workers = parallel_for_with(jobs.len(), threads, Scratch::new, |scratch, i| {
            self.run_column(x_codes, k, &jobs[i], scratch)
        });
        let mut total = 0.0f64;
        let mut steps = 0u64;
        let mut merged: Vec<u64> = Vec::new();
        for idx in 0..256 {
            let mut merged_steps = 0u64;
            let mut any = false;
            for w in &workers {
                if let Some(sim) = &w.sims[idx] {
                    if !any {
                        merged.clear();
                        merged.resize(sim.toggles.len(), 0);
                        any = true;
                    }
                    for (m, &t) in merged.iter_mut().zip(&sim.toggles) {
                        *m += t;
                    }
                    merged_steps += sim.steps;
                }
            }
            if any {
                let slot = self.slots[idx].as_ref().expect("slot exists for simulated weight");
                let rep = slot.ctx.report_raw(&merged, merged_steps);
                total += rep.energy_j;
                steps += rep.cycles;
            }
        }
        (total, steps)
    }

    /// Exact energy of one tile pass — the parallel counterpart of
    /// [`tile_power_exact`](super::tile_power_exact), bit-identical to
    /// it for any `threads`.  Returns (energy_joules, mac_steps).
    pub fn pass_power(
        &self,
        x_codes: &[i8],
        w_codes: &[i8],
        k: usize,
        n: usize,
        pass: &Pass,
        threads: usize,
    ) -> (f64, u64) {
        let (jobs, _total) = Self::column_jobs(x_codes, w_codes, k, n, std::slice::from_ref(pass));
        self.run_jobs(x_codes, k, &jobs, threads)
    }

    /// Exact energy of a whole layer matmul: every pass of the (m, k, n)
    /// tile schedule, with column streams deduplicated across passes.
    /// Returns (energy_joules, mac_steps, columns_total, columns_unique).
    pub fn matmul_power(
        &self,
        x_codes: &[i8],
        w_codes: &[i8],
        m: usize,
        k: usize,
        n: usize,
        threads: usize,
    ) -> (f64, u64, u64, u64) {
        let passes = passes_of(m, k, n);
        let (jobs, total) = Self::column_jobs(x_codes, w_codes, k, n, &passes);
        let unique = jobs.len() as u64;
        let (e, steps) = self.run_jobs(x_codes, k, &jobs, threads);
        (e, steps, total, unique)
    }
}

/// Exact power of one conv layer's captured operand streams.
#[derive(Clone, Debug)]
pub struct ExactLayerPower {
    pub conv_idx: usize,
    /// Exact gate-level energy (J) over every pass of every capture.
    pub energy_j: f64,
    /// Simulated MAC trace steps (deduplicated streams counted at their
    /// multiplicity, i.e. the number the hardware would execute).
    pub mac_steps: u64,
    /// Column streams before deduplication.
    pub columns_total: u64,
    /// Column streams actually simulated.
    pub columns_unique: u64,
}

/// Whole-network exact gate-level power over captured operand streams.
#[derive(Clone, Debug, Default)]
pub struct ExactNetworkPower {
    /// One entry per conv layer, ascending `conv_idx`.
    pub layers: Vec<ExactLayerPower>,
}

impl ExactNetworkPower {
    pub fn total_j(&self) -> f64 {
        self.layers.iter().map(|l| l.energy_j).sum()
    }

    /// Per-layer energies in the shape the model-mode evaluator reports,
    /// for direct diffs against
    /// [`EnergyEvaluator`](crate::energy::cache::EnergyEvaluator)
    /// predictions.
    pub fn to_network_energy(&self) -> NetworkEnergy {
        NetworkEnergy {
            layers: self
                .layers
                .iter()
                .map(|l| (l.conv_idx, l.energy_j))
                .collect(),
        }
    }
}

/// Exact gate-level energy of every pass of every capture — the
/// network-scale ground truth (paper §3.2) the statistical model is
/// validated against.  Captures sharing a `conv_idx` (several images)
/// are accumulated into one layer entry.
///
/// `lib` must be pre-specialized for every weight code appearing in the
/// captures.  Per-layer energies are bit-identical for any `threads`.
pub fn network_power_exact(
    captures: &[ConvCapture],
    lib: &MacLib,
    cap: &CapModel,
    threads: usize,
) -> ExactNetworkPower {
    let engine = TilePowerEngine::new(lib, cap);
    let mut layers: Vec<ExactLayerPower> = Vec::new();
    for capture in captures {
        let (e, steps, total, unique) = engine.matmul_power(
            &capture.x_codes,
            &capture.w_codes,
            capture.m,
            capture.k,
            capture.n,
            threads,
        );
        if let Some(pos) = layers.iter().position(|l| l.conv_idx == capture.conv_idx) {
            let l = &mut layers[pos];
            l.energy_j += e;
            l.mac_steps += steps;
            l.columns_total += total;
            l.columns_unique += unique;
        } else {
            layers.push(ExactLayerPower {
                conv_idx: capture.conv_idx,
                energy_j: e,
                mac_steps: steps,
                columns_total: total,
                columns_unique: unique,
            });
        }
    }
    layers.sort_by_key(|l| l.conv_idx);
    ExactNetworkPower { layers }
}

/// [`CaptureSink`] adapter for the exact engine: every X row block (one
/// batch chunk of one conv layer) is tiled and simulated **on
/// arrival**, so exact network power is computed without ever
/// materializing a layer's full im2col matrix — the streaming
/// counterpart of [`network_power_exact`] over buffered captures.
///
/// Per-block tiling means m-blocks never span chunk boundaries, so
/// cross-chunk stream dedup is traded for bounded memory (weight-column
/// dedup across n-tiles — the dominant saving — still applies within
/// every block).  `mac_steps` equals the buffered path exactly (Σ mh is
/// partition-invariant); energies are exact for the chunked tile
/// schedule and, like the engine itself, bit-identical for any thread
/// count because blocks arrive in deterministic order.
pub struct PowerSink<'l> {
    engine: TilePowerEngine<'l>,
    threads: usize,
    heads: Vec<StreamMeta>,
    layers: Vec<ExactLayerPower>,
}

impl<'l> PowerSink<'l> {
    /// `lib` must be pre-specialized for every weight code the forward
    /// will stream ([`MacLib::specialize_all`]).
    pub fn new(lib: &'l MacLib, cap: &CapModel, threads: usize) -> Self {
        Self {
            engine: TilePowerEngine::new(lib, cap),
            threads,
            heads: Vec::new(),
            layers: Vec::new(),
        }
    }

    /// Per-conv operand metadata (dims + weight codes) — what the model
    /// side of an exact-vs-model validation needs, without activations.
    pub fn stream_meta(&self) -> &[StreamMeta] {
        &self.heads
    }

    /// Accumulated exact power, ascending `conv_idx` (call after the
    /// forward's `finish()`).
    pub fn into_power(self) -> ExactNetworkPower {
        self.into_parts().1
    }

    /// Both halves of a validation — the per-conv stream metadata (model
    /// side) and the exact power — without cloning the weight codes.
    pub fn into_parts(self) -> (Vec<StreamMeta>, ExactNetworkPower) {
        (
            self.heads,
            ExactNetworkPower {
                layers: self.layers,
            },
        )
    }
}

impl CaptureSink for PowerSink<'_> {
    fn begin_conv(&mut self, head: &ConvHead<'_>) {
        assert!(
            !self.heads.iter().any(|h| h.conv_idx == head.conv_idx),
            "conv{} announced twice (one forward per PowerSink)",
            head.conv_idx
        );
        self.heads.push(StreamMeta {
            conv_idx: head.conv_idx,
            m: head.m_total,
            k: head.k,
            n: head.n,
            w_codes: head.w_codes.to_vec(),
        });
        self.layers.push(ExactLayerPower {
            conv_idx: head.conv_idx,
            energy_j: 0.0,
            mac_steps: 0,
            columns_total: 0,
            columns_unique: 0,
        });
    }

    fn x_block(&mut self, conv_idx: usize, rows: usize, x_codes: &[i8]) {
        let head = self
            .heads
            .iter()
            .find(|h| h.conv_idx == conv_idx)
            .expect("x_block before begin_conv");
        let (e, steps, total, unique) =
            self.engine
                .matmul_power(x_codes, &head.w_codes, rows, head.k, head.n, self.threads);
        let l = self
            .layers
            .iter_mut()
            .find(|l| l.conv_idx == conv_idx)
            .expect("layer entry");
        l.energy_j += e;
        l.mac_steps += steps;
        l.columns_total += total;
        l.columns_unique += unique;
    }

    fn finish(&mut self) {
        self.layers.sort_by_key(|l| l.conv_idx);
        self.heads.sort_by_key(|h| h.conv_idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::tile_power_exact;
    use crate::util::rng::Xoshiro256;

    /// Small-alphabet random codes keep specialization cheap in tests.
    fn small_codes(len: usize, seed: u64) -> Vec<i8> {
        let mut rng = Xoshiro256::new(seed);
        (0..len).map(|_| (rng.below(7) as i8) - 3).collect()
    }

    #[test]
    fn engine_matches_sequential_reference_small() {
        let (m, k, n) = (21usize, 30, 11);
        let x = small_codes(m * k, 1);
        let w = small_codes(k * n, 2);
        let mut lib = MacLib::new();
        lib.specialize_for(&w, 2);
        let cap = CapModel::default();
        let engine = TilePowerEngine::new(&lib, &cap);
        let pass = passes_of(m, k, n)[0];
        let (e_ref, s_ref) = tile_power_exact(&x, &w, k, n, &pass, &lib, &cap);
        for threads in [1usize, 3] {
            let (e, s) = engine.pass_power(&x, &w, k, n, &pass, threads);
            assert_eq!(s, s_ref, "threads={threads}");
            assert_eq!(
                e.to_bits(),
                e_ref.to_bits(),
                "threads={threads}: {e} vs {e_ref}"
            );
        }
    }

    /// Duplicated weight columns collapse to few unique jobs and the
    /// multiplicity-weighted result equals the per-pass sum.
    #[test]
    fn dedup_is_exact() {
        let (m, k, n) = (70usize, 20, 67);
        let x = small_codes(m * k, 3);
        // Only 3 distinct weight columns, tiled across all of n.
        let pattern = [small_codes(k, 4), small_codes(k, 5), small_codes(k, 6)];
        let mut w = vec![0i8; k * n];
        for c in 0..n {
            for r in 0..k {
                w[r * n + c] = pattern[c % 3][r];
            }
        }
        let mut lib = MacLib::new();
        lib.specialize_for(&w, 2);
        let cap = CapModel::default();
        let engine = TilePowerEngine::new(&lib, &cap);
        let (e, steps, total, unique) = engine.matmul_power(&x, &w, m, k, n, 2);
        // 2 m-blocks x 1 k-block x 3 distinct columns = 6 unique jobs
        // standing for 2 * 67 = 134 column streams.
        assert_eq!(total, 134);
        assert_eq!(unique, 6);
        // Reference: sequential per-pass sum (no dedup).  Fold orders
        // differ across pass boundaries, so compare at f64 tolerance;
        // steps are integers and must match exactly.
        let mut e_ref = 0.0f64;
        let mut s_ref = 0u64;
        for pass in passes_of(m, k, n) {
            let (pe, ps) = tile_power_exact(&x, &w, k, n, &pass, &lib, &cap);
            e_ref += pe;
            s_ref += ps;
        }
        assert_eq!(steps, s_ref);
        assert!(
            (e - e_ref).abs() <= e_ref * 1e-12,
            "dedup drifted: {e} vs {e_ref}"
        );
    }

    /// m-blocks with identical activation content (here: every X row
    /// equal) collapse into one block id, so column streams dedup
    /// *across m-blocks* too.
    #[test]
    fn dedup_crosses_m_blocks_on_repeated_content() {
        let (m, k, n) = (128usize, 20, 67);
        let row = small_codes(k, 7);
        let mut x = vec![0i8; m * k];
        for mi in 0..m {
            x[mi * k..(mi + 1) * k].copy_from_slice(&row);
        }
        let pattern = [small_codes(k, 4), small_codes(k, 5), small_codes(k, 6)];
        let mut w = vec![0i8; k * n];
        for c in 0..n {
            for r in 0..k {
                w[r * n + c] = pattern[c % 3][r];
            }
        }
        let mut lib = MacLib::new();
        lib.specialize_for(&w, 2);
        let cap = CapModel::default();
        let engine = TilePowerEngine::new(&lib, &cap);
        let (e, steps, total, unique) = engine.matmul_power(&x, &w, m, k, n, 2);
        // Both 64-row m-blocks carry identical content -> one block id:
        // 3 distinct columns total, standing for 2 * 67 = 134 streams.
        assert_eq!(total, 134);
        assert_eq!(unique, 3);
        let mut e_ref = 0.0f64;
        let mut s_ref = 0u64;
        for pass in passes_of(m, k, n) {
            let (pe, ps) = tile_power_exact(&x, &w, k, n, &pass, &lib, &cap);
            e_ref += pe;
            s_ref += ps;
        }
        assert_eq!(steps, s_ref);
        assert!(
            (e - e_ref).abs() <= e_ref * 1e-12,
            "cross-m dedup drifted: {e} vs {e_ref}"
        );
    }

    /// The streaming sink (per-block tiling) equals the engine run on
    /// each block separately, and is thread-count invariant.
    #[test]
    fn power_sink_streams_blocks_thread_invariant() {
        let (k, n) = (20usize, 9);
        let w = small_codes(k * n, 30);
        let blocks = [small_codes(40 * k, 31), small_codes(25 * k, 32)];
        let mut lib = MacLib::new();
        lib.specialize_for(&w, 2);
        let cm = CapModel::default();
        let run = |threads: usize| {
            let mut sink = PowerSink::new(&lib, &cm, threads);
            sink.begin_conv(&ConvHead {
                conv_idx: 0,
                m_total: 65,
                k,
                n,
                w_codes: &w,
                s_act: 0.01,
                s_w: 0.01,
            });
            sink.x_block(0, 40, &blocks[0]);
            sink.x_block(0, 25, &blocks[1]);
            sink.finish();
            assert_eq!(sink.stream_meta().len(), 1);
            assert_eq!(sink.stream_meta()[0].m, 65);
            sink.into_power()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.layers.len(), 1);
        assert_eq!(
            a.layers[0].energy_j.to_bits(),
            b.layers[0].energy_j.to_bits()
        );
        assert_eq!(a.layers[0].mac_steps, b.layers[0].mac_steps);
        let engine = TilePowerEngine::new(&lib, &cm);
        let (e0, s0, ..) = engine.matmul_power(&blocks[0], &w, 40, k, n, 2);
        let (e1, s1, ..) = engine.matmul_power(&blocks[1], &w, 25, k, n, 2);
        assert_eq!(a.layers[0].mac_steps, s0 + s1);
        assert_eq!(a.layers[0].energy_j.to_bits(), (e0 + e1).to_bits());
    }

    #[test]
    fn network_power_thread_invariant_and_layer_merged() {
        let (m, k, n) = (40usize, 17, 9);
        // Two captures on the same conv index merge into one layer.
        let caps: Vec<ConvCapture> = (0..2)
            .map(|i| ConvCapture {
                conv_idx: 0,
                m,
                k,
                n,
                x_codes: small_codes(m * k, 10 + i),
                w_codes: small_codes(k * n, 20),
                s_act: 0.01,
                s_w: 0.01,
            })
            .collect();
        let mut lib = MacLib::new();
        lib.specialize_for(&caps[0].w_codes, 2);
        let cm = CapModel::default();
        let a = network_power_exact(&caps, &lib, &cm, 1);
        let b = network_power_exact(&caps, &lib, &cm, 4);
        assert_eq!(a.layers.len(), 1);
        assert_eq!(b.layers.len(), 1);
        assert_eq!(a.layers[0].energy_j.to_bits(), b.layers[0].energy_j.to_bits());
        assert_eq!(a.layers[0].mac_steps, b.layers[0].mac_steps);
        assert_eq!(a.layers[0].columns_unique, b.layers[0].columns_unique);
        assert!(a.total_j() > 0.0);
        assert_eq!(a.to_network_energy().layers[0].0, 0);
    }
}
