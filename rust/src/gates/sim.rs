//! Bit-parallel zero-delay logic simulation with toggle counting.
//!
//! 64 consecutive *time steps* of the input trace are packed into each
//! `u64` word (lane `t` = trace step `t`), so one pass of bitwise ops
//! evaluates 64 cycles of the whole netlist.  Toggle counting is then a
//! `popcount(v ^ (v << 1))` per node per word, with the previous word's
//! last lane carried across the boundary.
//!
//! Two evaluation paths produce bit-identical toggles:
//!
//! * [`TraceSim::run_chunk`] — the reference path: walk nodes in index
//!   (topological) order with one kind-dispatch per gate.
//! * [`TraceSim::run_chunk_scheduled`] — the levelized SoA fast path
//!   used by the exact tile-power engine: an [`EvalSchedule`] groups
//!   gates into kind-homogeneous runs ordered by topological level, so
//!   the inner loop is one branch per *run* instead of one per gate.
//!
//! [`transpose64`] (Hacker's Delight §7-3) converts lane-major operand
//! words into the simulator's bit-plane layout in ~6·64 ops, replacing
//! per-lane bit-extraction loops in hot packers.
//!
//! Zero-delay (functional) toggles ignore glitching; DESIGN.md §5 absorbs
//! the glitch factor into the capacitance constants, which is standard
//! practice for activity-based power estimation.

use super::netlist::{GateKind, Netlist};

/// In-place 64×64 bit-matrix transpose (Hacker's Delight §7-3, widened
/// to 64 lanes): `out[r]` bit `c` == `in[c]` bit `r`.  An involution.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    let mut m: u64 = 0xFFFF_FFFF_0000_0000;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            let t = (a[k] ^ (a[k + j] << j)) & m;
            a[k] ^= t;
            a[k + j] ^= t >> j;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        if j != 0 {
            m ^= m >> j;
        }
    }
}

/// Levelized, kind-grouped evaluation schedule for one netlist.
///
/// Gates are ordered by topological level (inputs/consts at level 0;
/// see [`Netlist::levels`]) and, within a level, by kind.  Any order
/// that respects levels is a valid evaluation order, so sorting by kind
/// creates long kind-homogeneous runs the simulator can execute with a
/// single dispatch each — the struct-of-arrays (`dst`/`a`/`b`) flat
/// buffers are walked run-by-run into the shared value vector.
///
/// Build once per netlist (the tile-power engine builds one per
/// weight-specialized MAC) and share read-only across threads.
#[derive(Clone, Debug)]
pub struct EvalSchedule {
    /// Kind-homogeneous runs: (gate kind, start, end) into the flat
    /// arrays below.  Executing runs in order evaluates every non-input
    /// node in a level-respecting order.
    runs: Vec<(u8, u32, u32)>,
    dst: Vec<u32>,
    a: Vec<u32>,
    b: Vec<u32>,
    /// Primary input node indices (testbench order), copied from the
    /// netlist so the scheduled path needs no netlist at run time.
    inputs: Vec<u32>,
    n_nodes: usize,
}

impl EvalSchedule {
    pub fn new(nl: &Netlist) -> Self {
        let levels = nl.levels();
        // Every non-input node, ordered by (level, kind, index).  The
        // order is globally topological: a gate's operands live at
        // strictly lower levels, hence strictly earlier in the order.
        let mut order: Vec<u32> = (0..nl.len() as u32)
            .filter(|&i| nl.kinds[i as usize] != GateKind::Input as u8)
            .collect();
        order.sort_by_key(|&i| (levels[i as usize], nl.kinds[i as usize], i));

        let mut runs: Vec<(u8, u32, u32)> = Vec::new();
        let mut dst = Vec::with_capacity(order.len());
        let mut a = Vec::with_capacity(order.len());
        let mut b = Vec::with_capacity(order.len());
        for &i in &order {
            let iu = i as usize;
            dst.push(i);
            a.push(nl.a[iu]);
            b.push(nl.b[iu]);
            let end = dst.len() as u32;
            let extend = matches!(runs.last(), Some(r) if r.0 == nl.kinds[iu]);
            if extend {
                runs.last_mut().expect("run exists").2 = end;
            } else {
                runs.push((nl.kinds[iu], end - 1, end));
            }
        }
        Self {
            runs,
            dst,
            a,
            b,
            inputs: nl.inputs.clone(),
            n_nodes: nl.len(),
        }
    }

    /// Primary input count (testbench word count per chunk).
    pub fn n_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Number of kind-homogeneous runs (observability / tests).
    pub fn n_runs(&self) -> usize {
        self.runs.len()
    }
}

/// Reusable simulation state (scratch buffers sized to one netlist).
pub struct TraceSim {
    /// Node value words for the current 64-step chunk.
    vals: Vec<u64>,
    /// Per-node toggle accumulators.
    pub toggles: Vec<u64>,
    /// Last lane of the previous chunk per node (for cross-chunk toggles).
    prev_bit: Vec<u8>,
    first_chunk: bool,
    /// Total trace steps simulated since the last `reset`.
    pub steps: u64,
    /// Toggle/step accounting multiplicity (see [`Self::set_multiplicity`]).
    mult: u64,
}

impl TraceSim {
    pub fn new(nl: &Netlist) -> Self {
        Self {
            vals: vec![0; nl.len()],
            toggles: vec![0; nl.len()],
            prev_bit: vec![0; nl.len()],
            first_chunk: true,
            steps: 0,
            mult: 1,
        }
    }

    pub fn reset(&mut self) {
        self.toggles.iter_mut().for_each(|t| *t = 0);
        self.first_chunk = true;
        self.steps = 0;
        self.mult = 1;
    }

    /// Start a new independent trace *segment* while keeping accumulated
    /// toggle counts: the transition from the previous segment's last
    /// step to the new segment's first step is NOT counted.  Lets hot
    /// loops (exact tile power) accumulate many per-PE traces into one
    /// sim and fold the power report once at the end.
    pub fn new_segment(&mut self) {
        self.first_chunk = true;
    }

    /// Accounting multiplicity for subsequent chunks: toggle counts and
    /// steps are scaled by `m`.  Toggle counting is linear in identical
    /// trace segments, so a deduplicated segment simulated once and
    /// accounted `m` times is *exact*, not approximate — this is what
    /// lets the tile-power engine collapse repeated column streams.
    pub fn set_multiplicity(&mut self, m: u64) {
        assert!(m >= 1, "multiplicity must be >= 1");
        self.mult = m;
    }

    /// Compute node values for one chunk without touching toggle state.
    fn eval_values(&mut self, nl: &Netlist, input_words: &[u64]) {
        assert_eq!(input_words.len(), nl.inputs.len());
        let vals = &mut self.vals;
        // Drive inputs.
        for (w, &node) in input_words.iter().zip(&nl.inputs) {
            vals[node as usize] = *w;
        }
        // Evaluate in topological order.
        let kinds = &nl.kinds;
        let aops = &nl.a;
        let bops = &nl.b;
        for i in 0..nl.len() {
            let k = kinds[i];
            if k == GateKind::Input as u8 {
                continue;
            }
            let va = vals[aops[i] as usize];
            vals[i] = match GateKind::from_u8(k) {
                GateKind::Const => {
                    if aops[i] != 0 {
                        !0u64
                    } else {
                        0u64
                    }
                }
                GateKind::Buf => va,
                GateKind::Not => !va,
                GateKind::And => va & vals[bops[i] as usize],
                GateKind::Or => va | vals[bops[i] as usize],
                GateKind::Nand => !(va & vals[bops[i] as usize]),
                GateKind::Nor => !(va | vals[bops[i] as usize]),
                GateKind::Xor => va ^ vals[bops[i] as usize],
                GateKind::Xnor => !(va ^ vals[bops[i] as usize]),
                GateKind::Input => unreachable!(),
            };
        }
    }

    /// Fold the current chunk's values into the toggle accumulators
    /// (shared by both evaluation paths, so they are bit-identical).
    fn account_toggles(&mut self, n_steps: u32) {
        let valid_mask: u64 = if n_steps == 64 {
            !0
        } else {
            (1u64 << n_steps) - 1
        };
        // Mask of transition positions t-1 -> t for t in 1..n_steps.
        let intra_mask = valid_mask & !1u64;
        let first = self.first_chunk;
        let mult = self.mult;
        for i in 0..self.vals.len() {
            let v = self.vals[i] & valid_mask;
            let shifted = v << 1;
            let mut trans = (v ^ shifted) & intra_mask;
            if !first {
                // Boundary transition: previous chunk's last step -> lane 0.
                let pb = self.prev_bit[i] as u64;
                trans |= (v ^ pb) & 1;
            }
            self.toggles[i] += trans.count_ones() as u64 * mult;
            self.prev_bit[i] = ((self.vals[i] >> (n_steps - 1)) & 1) as u8;
        }
        self.first_chunk = false;
        self.steps += n_steps as u64 * mult;
    }

    /// Evaluate one chunk of up to 64 trace steps.
    ///
    /// `input_words[i]` packs the time series of primary input `i`
    /// (testbench order): bit `t` = value at step `t`.  `n_steps` gives
    /// how many low lanes are valid.  Toggle counts (including the
    /// transition from the previous chunk's last step) are accumulated.
    pub fn run_chunk(&mut self, nl: &Netlist, input_words: &[u64], n_steps: u32) {
        assert!(n_steps >= 1 && n_steps <= 64);
        self.eval_values(nl, input_words);
        self.account_toggles(n_steps);
    }

    /// Evaluate one chunk through a levelized [`EvalSchedule`] — the
    /// struct-of-arrays fast path of the exact tile-power engine.
    /// Bit-identical in values, toggles and steps to [`Self::run_chunk`]
    /// on the schedule's netlist (property-tested below).
    pub fn run_chunk_scheduled(
        &mut self,
        sched: &EvalSchedule,
        input_words: &[u64],
        n_steps: u32,
    ) {
        assert!(n_steps >= 1 && n_steps <= 64);
        assert_eq!(input_words.len(), sched.inputs.len());
        assert_eq!(self.vals.len(), sched.n_nodes);
        let vals = &mut self.vals;
        for (w, &node) in input_words.iter().zip(&sched.inputs) {
            vals[node as usize] = *w;
        }
        let dst = &sched.dst;
        let aops = &sched.a;
        let bops = &sched.b;
        for &(kind, start, end) in &sched.runs {
            let (s, e) = (start as usize, end as usize);
            match GateKind::from_u8(kind) {
                GateKind::Const => {
                    for j in s..e {
                        vals[dst[j] as usize] = if aops[j] != 0 { !0u64 } else { 0u64 };
                    }
                }
                GateKind::Buf => {
                    for j in s..e {
                        vals[dst[j] as usize] = vals[aops[j] as usize];
                    }
                }
                GateKind::Not => {
                    for j in s..e {
                        vals[dst[j] as usize] = !vals[aops[j] as usize];
                    }
                }
                GateKind::And => {
                    for j in s..e {
                        vals[dst[j] as usize] = vals[aops[j] as usize] & vals[bops[j] as usize];
                    }
                }
                GateKind::Or => {
                    for j in s..e {
                        vals[dst[j] as usize] = vals[aops[j] as usize] | vals[bops[j] as usize];
                    }
                }
                GateKind::Nand => {
                    for j in s..e {
                        vals[dst[j] as usize] = !(vals[aops[j] as usize] & vals[bops[j] as usize]);
                    }
                }
                GateKind::Nor => {
                    for j in s..e {
                        vals[dst[j] as usize] = !(vals[aops[j] as usize] | vals[bops[j] as usize]);
                    }
                }
                GateKind::Xor => {
                    for j in s..e {
                        vals[dst[j] as usize] = vals[aops[j] as usize] ^ vals[bops[j] as usize];
                    }
                }
                GateKind::Xnor => {
                    for j in s..e {
                        vals[dst[j] as usize] = !(vals[aops[j] as usize] ^ vals[bops[j] as usize]);
                    }
                }
                GateKind::Input => unreachable!("inputs are never scheduled"),
            }
        }
        self.account_toggles(n_steps);
    }

    /// Run a full trace given per-step input bit vectors (LSB-first input
    /// order matching `nl.inputs`).  Convenience wrapper over `run_chunk`.
    pub fn run_trace(&mut self, nl: &Netlist, steps: &[Vec<bool>]) {
        let n_in = nl.inputs.len();
        // One packing buffer reused across chunks (hot loops used to
        // re-allocate it per 64-step chunk).
        let mut words = vec![0u64; n_in];
        let mut t = 0;
        while t < steps.len() {
            let chunk = (steps.len() - t).min(64);
            words.iter_mut().for_each(|w| *w = 0);
            for (lane, step) in steps[t..t + chunk].iter().enumerate() {
                assert_eq!(step.len(), n_in);
                for (i, &bit) in step.iter().enumerate() {
                    if bit {
                        words[i] |= 1u64 << lane;
                    }
                }
            }
            self.run_chunk(nl, &words, chunk as u32);
            t += chunk;
        }
    }

    /// Evaluate a single input vector and return output bit values — a
    /// purely functional probe.  Only the value scratch is written:
    /// toggle counts, step totals and the chunk-boundary carry survive,
    /// so probes can interleave with an ongoing toggle-counting trace
    /// (regression-tested below; this used to `reset()` and silently
    /// clobber accumulated toggle state).
    pub fn eval_single(&mut self, nl: &Netlist, inputs: &[bool]) -> Vec<bool> {
        let words: Vec<u64> = inputs.iter().map(|&b| if b { 1 } else { 0 }).collect();
        self.eval_values(nl, &words);
        nl.outputs
            .iter()
            .map(|&o| self.vals[o as usize] & 1 != 0)
            .collect()
    }

    /// Output values of the most recent chunk, lane `lane`.
    pub fn outputs_at(&self, nl: &Netlist, lane: u32) -> Vec<bool> {
        nl.outputs
            .iter()
            .map(|&o| (self.vals[o as usize] >> lane) & 1 != 0)
            .collect()
    }
}

/// Pack a little-endian integer into input-bit vectors (helper for word
/// testbenches).
pub fn word_bits(value: i64, width: usize) -> Vec<bool> {
    (0..width).map(|i| (value >> i) & 1 != 0).collect()
}

/// Inverse of `word_bits` for unsigned interpretation.
pub fn bits_word(bits: &[bool]) -> u64 {
    bits.iter()
        .enumerate()
        .map(|(i, &b)| (b as u64) << i)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::netlist::NetBuilder;

    /// xor of two inputs: toggle count equals hand-computed transitions.
    #[test]
    fn toggle_counting_exact() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.input();
        let z = b.xor(x, y);
        let nl = b.finish(vec![z], vec![]);
        let mut sim = TraceSim::new(&nl);
        // Trace: x = 0,1,1,0 ; y = 0,0,1,1  -> z = 0,1,0,1 (3 toggles).
        let steps: Vec<Vec<bool>> = vec![
            vec![false, false],
            vec![true, false],
            vec![true, true],
            vec![false, true],
        ];
        sim.run_trace(&nl, &steps);
        let zi = nl.outputs[0] as usize;
        assert_eq!(sim.toggles[zi], 3);
        // x toggles: 0->1->1->0 = 2 ; y toggles: 0->0->1->1 = 1.
        assert_eq!(sim.toggles[nl.inputs[0] as usize], 2);
        assert_eq!(sim.toggles[nl.inputs[1] as usize], 1);
    }

    /// Cross-chunk boundaries must not lose or invent toggles.
    #[test]
    fn chunk_boundary_toggles() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let nl = b.finish(vec![x], vec![]);
        // Alternating trace over 130 steps -> 129 toggles.
        let steps: Vec<Vec<bool>> = (0..130).map(|t| vec![t % 2 == 1]).collect();
        let mut sim = TraceSim::new(&nl);
        sim.run_trace(&nl, &steps);
        assert_eq!(sim.toggles[nl.inputs[0] as usize], 129);
        assert_eq!(sim.steps, 130);
    }

    /// Same trace in one chunk vs many chunks gives identical counts.
    #[test]
    fn chunking_invariance() {
        let mut b = NetBuilder::new();
        let xs = b.inputs(3);
        let t1 = b.and(xs[0], xs[1]);
        let t2 = b.xor(t1, xs[2]);
        let nl = b.finish(vec![t2], vec![]);
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let steps: Vec<Vec<bool>> = (0..200)
            .map(|_| (0..3).map(|_| rng.next_u64() & 1 != 0).collect())
            .collect();
        let mut sim_a = TraceSim::new(&nl);
        sim_a.run_trace(&nl, &steps);
        // Manual 7-step chunking.
        let mut sim_b = TraceSim::new(&nl);
        for chunk in steps.chunks(7) {
            sim_b.run_trace_continue(&nl, chunk);
        }
        assert_eq!(sim_a.toggles, sim_b.toggles);
    }

    /// The Hacker's-Delight transpose is a true (index, LSB-bit)
    /// transpose and an involution.
    #[test]
    fn transpose64_matches_naive() {
        let mut rng = crate::util::rng::Xoshiro256::new(77);
        for _ in 0..4 {
            let mut m = [0u64; 64];
            for w in m.iter_mut() {
                *w = rng.next_u64();
            }
            let mut t = m;
            transpose64(&mut t);
            for r in 0..64 {
                for c in 0..64 {
                    assert_eq!((t[r] >> c) & 1, (m[c] >> r) & 1, "({r},{c})");
                }
            }
            let mut back = t;
            transpose64(&mut back);
            assert_eq!(back, m);
        }
    }

    /// The levelized scheduled path is bit-identical to the topological
    /// reference path: same values, toggles and steps, on a real MAC
    /// netlist over randomly-chunked random traces.
    #[test]
    fn scheduled_path_bit_identical() {
        let mac = crate::mac::build_mac();
        let spec = crate::mac::specialize_mac(&mac, 91);
        for nl in [&mac.netlist, &spec.netlist] {
            let sched = EvalSchedule::new(nl);
            assert!(sched.n_runs() > 0);
            assert_eq!(sched.n_inputs(), nl.inputs.len());
            let mut rng = crate::util::rng::Xoshiro256::new(123);
            let mut sim_ref = TraceSim::new(nl);
            let mut sim_lvl = TraceSim::new(nl);
            let mut words = vec![0u64; nl.inputs.len()];
            for round in 0..12 {
                for w in words.iter_mut() {
                    *w = rng.next_u64();
                }
                let n_steps = 1 + (rng.below(64) as u32);
                if round == 6 {
                    // Segment boundaries must behave identically too.
                    sim_ref.new_segment();
                    sim_lvl.new_segment();
                }
                sim_ref.run_chunk(nl, &words, n_steps);
                sim_lvl.run_chunk_scheduled(&sched, &words, n_steps);
                assert_eq!(
                    sim_ref.outputs_at(nl, n_steps - 1),
                    sim_lvl.outputs_at(nl, n_steps - 1),
                    "round {round}"
                );
            }
            assert_eq!(sim_ref.toggles, sim_lvl.toggles);
            assert_eq!(sim_ref.steps, sim_lvl.steps);
        }
    }

    /// Multiplicity-weighted accounting is exact: one segment at
    /// multiplicity 2 equals the same segment simulated twice.
    #[test]
    fn multiplicity_scales_toggles_exactly() {
        let mac = crate::mac::build_mac();
        let nl = &mac.netlist;
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let steps: Vec<Vec<bool>> = (0..90)
            .map(|_| (0..nl.inputs.len()).map(|_| rng.next_u64() & 1 != 0).collect())
            .collect();
        let mut sim_twice = TraceSim::new(nl);
        sim_twice.run_trace_continue(nl, &steps);
        sim_twice.new_segment();
        sim_twice.run_trace_continue(nl, &steps);
        let mut sim_mult = TraceSim::new(nl);
        sim_mult.set_multiplicity(2);
        sim_mult.run_trace_continue(nl, &steps);
        assert_eq!(sim_twice.toggles, sim_mult.toggles);
        assert_eq!(sim_twice.steps, sim_mult.steps);
    }

    /// `eval_single` is a pure functional probe: interleaving it with an
    /// ongoing trace leaves toggle accounting untouched (it used to
    /// `reset()`, losing all accumulated state).
    #[test]
    fn eval_single_preserves_toggle_state() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.not(x);
        let nl = b.finish(vec![y], vec![]);
        let steps: Vec<Vec<bool>> = (0..10).map(|t| vec![t % 2 == 1]).collect();
        let mut sim_plain = TraceSim::new(&nl);
        sim_plain.run_trace(&nl, &steps);

        let mut sim_probed = TraceSim::new(&nl);
        sim_probed.run_trace_continue(&nl, &steps[..5]);
        let out = sim_probed.eval_single(&nl, &[true]);
        assert!(!out[0], "probe itself must still be functionally correct");
        sim_probed.run_trace_continue(&nl, &steps[5..]);

        assert_eq!(sim_plain.toggles, sim_probed.toggles);
        assert_eq!(sim_plain.steps, sim_probed.steps);
    }
}

impl TraceSim {
    /// Like `run_trace` but without the implicit fresh-start semantics —
    /// simply continues from the current state (used by chunked feeders).
    pub fn run_trace_continue(&mut self, nl: &Netlist, steps: &[Vec<bool>]) {
        self.run_trace(nl, steps);
    }
}
