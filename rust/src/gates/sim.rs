//! Bit-parallel zero-delay logic simulation with toggle counting.
//!
//! 64 consecutive *time steps* of the input trace are packed into each
//! `u64` word (lane `t` = trace step `t`), so one pass of bitwise ops
//! evaluates 64 cycles of the whole netlist.  Toggle counting is then a
//! `popcount(v ^ (v << 1))` per node per word, with the previous word's
//! last lane carried across the boundary.
//!
//! Zero-delay (functional) toggles ignore glitching; DESIGN.md §5 absorbs
//! the glitch factor into the capacitance constants, which is standard
//! practice for activity-based power estimation.

use super::netlist::{GateKind, Netlist};

/// Reusable simulation state (scratch buffers sized to one netlist).
pub struct TraceSim {
    /// Node value words for the current 64-step chunk.
    vals: Vec<u64>,
    /// Per-node toggle accumulators.
    pub toggles: Vec<u64>,
    /// Last lane of the previous chunk per node (for cross-chunk toggles);
    /// u64::MAX means "no previous step yet".
    prev_bit: Vec<u8>,
    first_chunk: bool,
    /// Total trace steps simulated since the last `reset`.
    pub steps: u64,
}

impl TraceSim {
    pub fn new(nl: &Netlist) -> Self {
        Self {
            vals: vec![0; nl.len()],
            toggles: vec![0; nl.len()],
            prev_bit: vec![0; nl.len()],
            first_chunk: true,
            steps: 0,
        }
    }

    pub fn reset(&mut self) {
        self.toggles.iter_mut().for_each(|t| *t = 0);
        self.first_chunk = true;
        self.steps = 0;
    }

    /// Start a new independent trace *segment* while keeping accumulated
    /// toggle counts: the transition from the previous segment's last
    /// step to the new segment's first step is NOT counted.  Lets hot
    /// loops (exact tile power) accumulate many per-PE traces into one
    /// sim and fold the power report once at the end.
    pub fn new_segment(&mut self) {
        self.first_chunk = true;
    }

    /// Evaluate one chunk of up to 64 trace steps.
    ///
    /// `input_words[i]` packs the time series of primary input `i`
    /// (testbench order): bit `t` = value at step `t`.  `n_steps` gives
    /// how many low lanes are valid.  Toggle counts (including the
    /// transition from the previous chunk's last step) are accumulated.
    pub fn run_chunk(&mut self, nl: &Netlist, input_words: &[u64], n_steps: u32) {
        assert_eq!(input_words.len(), nl.inputs.len());
        assert!(n_steps >= 1 && n_steps <= 64);
        let vals = &mut self.vals;
        // Drive inputs.
        for (w, &node) in input_words.iter().zip(&nl.inputs) {
            vals[node as usize] = *w;
        }
        // Evaluate in topological order.
        let kinds = &nl.kinds;
        let aops = &nl.a;
        let bops = &nl.b;
        for i in 0..nl.len() {
            let k = kinds[i];
            if k == GateKind::Input as u8 {
                continue;
            }
            let va = vals[aops[i] as usize];
            vals[i] = match GateKind::from_u8(k) {
                GateKind::Const => {
                    if aops[i] != 0 {
                        !0u64
                    } else {
                        0u64
                    }
                }
                GateKind::Buf => va,
                GateKind::Not => !va,
                GateKind::And => va & vals[bops[i] as usize],
                GateKind::Or => va | vals[bops[i] as usize],
                GateKind::Nand => !(va & vals[bops[i] as usize]),
                GateKind::Nor => !(va | vals[bops[i] as usize]),
                GateKind::Xor => va ^ vals[bops[i] as usize],
                GateKind::Xnor => !(va ^ vals[bops[i] as usize]),
                GateKind::Input => unreachable!(),
            };
        }
        // Toggle accounting.
        let valid_mask: u64 = if n_steps == 64 {
            !0
        } else {
            (1u64 << n_steps) - 1
        };
        // Mask of transition positions t-1 -> t for t in 1..n_steps.
        let intra_mask = valid_mask & !1u64;
        for i in 0..nl.len() {
            let v = vals[i] & valid_mask;
            let shifted = v << 1;
            let mut trans = (v ^ shifted) & intra_mask;
            if !self.first_chunk {
                // Boundary transition: previous chunk's last step -> lane 0.
                let pb = self.prev_bit[i] as u64;
                trans |= (v ^ pb) & 1;
            }
            self.toggles[i] += trans.count_ones() as u64;
            self.prev_bit[i] = ((vals[i] >> (n_steps - 1)) & 1) as u8;
        }
        self.first_chunk = false;
        self.steps += n_steps as u64;
    }

    /// Run a full trace given per-step input bit vectors (LSB-first input
    /// order matching `nl.inputs`).  Convenience wrapper over `run_chunk`.
    pub fn run_trace(&mut self, nl: &Netlist, steps: &[Vec<bool>]) {
        let n_in = nl.inputs.len();
        let mut t = 0;
        while t < steps.len() {
            let chunk = (steps.len() - t).min(64);
            let mut words = vec![0u64; n_in];
            for (lane, step) in steps[t..t + chunk].iter().enumerate() {
                assert_eq!(step.len(), n_in);
                for (i, &bit) in step.iter().enumerate() {
                    if bit {
                        words[i] |= 1u64 << lane;
                    }
                }
            }
            self.run_chunk(nl, &words, chunk as u32);
            t += chunk;
        }
    }

    /// Evaluate a single input vector and return output bit values
    /// (functional check; does not disturb toggle state semantics because
    /// it resets first).
    pub fn eval_single(&mut self, nl: &Netlist, inputs: &[bool]) -> Vec<bool> {
        self.reset();
        let words: Vec<u64> = inputs.iter().map(|&b| if b { 1 } else { 0 }).collect();
        self.run_chunk(nl, &words, 1);
        nl.outputs
            .iter()
            .map(|&o| self.vals[o as usize] & 1 != 0)
            .collect()
    }

    /// Output values of the most recent chunk, lane `lane`.
    pub fn outputs_at(&self, nl: &Netlist, lane: u32) -> Vec<bool> {
        nl.outputs
            .iter()
            .map(|&o| (self.vals[o as usize] >> lane) & 1 != 0)
            .collect()
    }
}

/// Pack a little-endian integer into input-bit vectors (helper for word
/// testbenches).
pub fn word_bits(value: i64, width: usize) -> Vec<bool> {
    (0..width).map(|i| (value >> i) & 1 != 0).collect()
}

/// Inverse of `word_bits` for unsigned interpretation.
pub fn bits_word(bits: &[bool]) -> u64 {
    bits.iter()
        .enumerate()
        .map(|(i, &b)| (b as u64) << i)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::netlist::NetBuilder;

    /// xor of two inputs: toggle count equals hand-computed transitions.
    #[test]
    fn toggle_counting_exact() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.input();
        let z = b.xor(x, y);
        let nl = b.finish(vec![z], vec![]);
        let mut sim = TraceSim::new(&nl);
        // Trace: x = 0,1,1,0 ; y = 0,0,1,1  -> z = 0,1,0,1 (3 toggles).
        let steps: Vec<Vec<bool>> = vec![
            vec![false, false],
            vec![true, false],
            vec![true, true],
            vec![false, true],
        ];
        sim.run_trace(&nl, &steps);
        let zi = nl.outputs[0] as usize;
        assert_eq!(sim.toggles[zi], 3);
        // x toggles: 0->1->1->0 = 2 ; y toggles: 0->0->1->1 = 1.
        assert_eq!(sim.toggles[nl.inputs[0] as usize], 2);
        assert_eq!(sim.toggles[nl.inputs[1] as usize], 1);
    }

    /// Cross-chunk boundaries must not lose or invent toggles.
    #[test]
    fn chunk_boundary_toggles() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let nl = b.finish(vec![x], vec![]);
        // Alternating trace over 130 steps -> 129 toggles.
        let steps: Vec<Vec<bool>> = (0..130).map(|t| vec![t % 2 == 1]).collect();
        let mut sim = TraceSim::new(&nl);
        sim.run_trace(&nl, &steps);
        assert_eq!(sim.toggles[nl.inputs[0] as usize], 129);
        assert_eq!(sim.steps, 130);
    }

    /// Same trace in one chunk vs many chunks gives identical counts.
    #[test]
    fn chunking_invariance() {
        let mut b = NetBuilder::new();
        let xs = b.inputs(3);
        let t1 = b.and(xs[0], xs[1]);
        let t2 = b.xor(t1, xs[2]);
        let nl = b.finish(vec![t2], vec![]);
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let steps: Vec<Vec<bool>> = (0..200)
            .map(|_| (0..3).map(|_| rng.next_u64() & 1 != 0).collect())
            .collect();
        let mut sim_a = TraceSim::new(&nl);
        sim_a.run_trace(&nl, &steps);
        // Manual 7-step chunking.
        let mut sim_b = TraceSim::new(&nl);
        for chunk in steps.chunks(7) {
            sim_b.run_trace_continue(&nl, chunk);
        }
        assert_eq!(sim_a.toggles, sim_b.toggles);
    }
}

impl TraceSim {
    /// Like `run_trace` but without the implicit fresh-start semantics —
    /// simply continues from the current state (used by chunked feeders).
    pub fn run_trace_continue(&mut self, nl: &Netlist, steps: &[Vec<bool>]) {
        self.run_trace(nl, steps);
    }
}
