//! Toggle counts -> dynamic energy.
//!
//! NanGate-15nm-inspired switched-capacitance model (DESIGN.md §5): each
//! node's effective capacitance is an intrinsic output + wire term plus a
//! per-fanin-pin term scaled by fanout; flip-flop D-pins get FF input
//! capacitance; a constant per-cycle clock-tree energy covers the
//! register clock load (weight-independent by construction, exactly as in
//! the paper where only switching differences matter).
//!
//! `E_dyn = Σ_nodes ½ · C_node · V² · toggles(node)  +  cycles · E_clk`

use super::netlist::{GateKind, Netlist};
use super::sim::TraceSim;

/// Capacitance / voltage model.  Defaults approximate a 15 nm low-Vt
/// standard-cell library at nominal corner.
#[derive(Clone, Copy, Debug)]
pub struct CapModel {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Intrinsic output + local wire capacitance per gate (fF).
    pub c_out_ff: f64,
    /// Input-pin capacitance per fanout (fF).
    pub c_pin_ff: f64,
    /// Flip-flop D-pin capacitance (fF).
    pub c_ffpin_ff: f64,
    /// Clock-tree + register internal energy per cycle for the whole cell
    /// under model (fJ / cycle).
    pub e_clk_fj: f64,
    /// Clock frequency (Hz) for power conversion.
    pub freq_hz: f64,
}

impl Default for CapModel {
    fn default() -> Self {
        Self {
            vdd: 0.8,
            c_out_ff: 0.12,
            c_pin_ff: 0.05,
            c_ffpin_ff: 0.10,
            // Fine-grained gated clock tree (low-power 15 nm flows): the
            // per-MAC clock floor must stay well below active switching
            // or pruning/selection gains are artificially capped — the
            // paper's 46-63 % per-layer savings imply exactly that.
            e_clk_fj: 0.35,
            freq_hz: 5.0e9, // paper evaluates at 5 GHz
        }
    }
}

/// Energy/power accounting for one simulated trace.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PowerReport {
    /// Total dynamic energy (J).
    pub energy_j: f64,
    /// Combinational share (J).
    pub comb_j: f64,
    /// Sequential (FF data + clock) share (J).
    pub seq_j: f64,
    /// Trace length in cycles.
    pub cycles: u64,
}

impl PowerReport {
    /// Average power over the trace at the model's clock frequency (W).
    pub fn avg_power_w(&self, model: &CapModel) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.energy_j * model.freq_hz / self.cycles as f64
    }

    /// Energy per cycle (J).
    pub fn energy_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.energy_j / self.cycles as f64
        }
    }
}

/// Precomputed per-netlist power context: node capacitances and the
/// flip-flop membership mask.  Building this once per netlist (instead
/// of per trace) is the difference between O(PEs × nodes) setup and
/// O(weights × nodes) in the exact tile simulator — see EXPERIMENTS.md
/// §Perf.
#[derive(Clone, Debug)]
pub struct PowerCtx {
    caps_j: Vec<f64>, // 0.5 * C * V^2 per node, in joules/toggle
    is_ff: Vec<bool>,
    e_clk_j: f64,
}

impl PowerCtx {
    /// Fold a finished simulation into a [`PowerReport`].
    pub fn report(&self, sim: &TraceSim) -> PowerReport {
        self.report_raw(&sim.toggles, sim.steps)
    }

    /// Fold raw per-node toggle counts (e.g. merged across workers by
    /// the parallel tile-power engine) into a [`PowerReport`].  The
    /// node-order summation is fixed, so identical toggle vectors give
    /// bit-identical energies.
    pub fn report_raw(&self, toggles: &[u64], steps: u64) -> PowerReport {
        debug_assert_eq!(self.caps_j.len(), toggles.len());
        let mut comb = 0.0f64;
        let mut seq = 0.0f64;
        for i in 0..self.caps_j.len() {
            let e = self.caps_j[i] * toggles[i] as f64;
            if self.is_ff[i] {
                seq += e;
            } else {
                comb += e;
            }
        }
        let clk = steps as f64 * self.e_clk_j;
        PowerReport {
            energy_j: comb + seq + clk,
            comb_j: comb,
            seq_j: seq + clk,
            cycles: steps,
        }
    }
}

impl CapModel {
    /// Build the reusable per-netlist power context.
    pub fn ctx(&self, nl: &Netlist) -> PowerCtx {
        let caps = self.node_caps(nl);
        let v2 = self.vdd * self.vdd;
        let mut is_ff = vec![false; nl.len()];
        for &n in &nl.ff_nodes {
            is_ff[n as usize] = true;
        }
        PowerCtx {
            caps_j: caps.iter().map(|c| 0.5 * c * 1e-15 * v2).collect(),
            is_ff,
            e_clk_j: self.e_clk_fj * 1e-15,
        }
    }

    /// Effective switched capacitance of each node (fF), given fanouts.
    pub fn node_caps(&self, nl: &Netlist) -> Vec<f64> {
        let fo = nl.fanouts();
        let mut caps: Vec<f64> = (0..nl.len())
            .map(|i| {
                let k = GateKind::from_u8(nl.kinds[i]);
                if k == GateKind::Const {
                    0.0 // constants never toggle
                } else {
                    self.c_out_ff + self.c_pin_ff * fo[i] as f64
                }
            })
            .collect();
        for &n in &nl.ff_nodes {
            caps[n as usize] += self.c_ffpin_ff;
        }
        caps
    }

    /// Fold a finished simulation into a [`PowerReport`] (convenience
    /// one-shot path; hot loops should reuse [`CapModel::ctx`]).
    pub fn report(&self, nl: &Netlist, sim: &TraceSim) -> PowerReport {
        self.ctx(nl).report(sim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::netlist::NetBuilder;

    fn toggle_net() -> Netlist {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.not(x);
        b.finish(vec![y], vec![y])
    }

    #[test]
    fn energy_scales_with_toggles() {
        let nl = toggle_net();
        let model = CapModel::default();
        let mut sim = TraceSim::new(&nl);
        let alternating: Vec<Vec<bool>> = (0..100).map(|t| vec![t % 2 == 1]).collect();
        sim.run_trace(&nl, &alternating);
        let busy = model.report(&nl, &sim);

        let mut sim2 = TraceSim::new(&nl);
        let idle: Vec<Vec<bool>> = (0..100).map(|_| vec![false]).collect();
        sim2.run_trace(&nl, &idle);
        let quiet = model.report(&nl, &sim2);

        assert!(busy.energy_j > quiet.energy_j);
        // Idle trace still pays the clock tree.
        assert!(quiet.seq_j > 0.0);
        assert_eq!(quiet.comb_j, 0.0);
        assert_eq!(busy.cycles, 100);
    }

    #[test]
    fn power_conversion() {
        let nl = toggle_net();
        let model = CapModel::default();
        let mut sim = TraceSim::new(&nl);
        sim.run_trace(&nl, &[vec![false], vec![true]]);
        let rep = model.report(&nl, &sim);
        let p = rep.avg_power_w(&model);
        assert!(p > 0.0 && p.is_finite());
        // E/cycle * f == avg power by definition.
        assert!((rep.energy_per_cycle() * model.freq_hz - p).abs() / p < 1e-12);
    }

    #[test]
    fn const_nodes_cost_nothing() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let one = b.constant(true);
        let y = b.and(x, one); // folds to x; const node remains
        let nl = b.finish(vec![y], vec![]);
        let model = CapModel::default();
        let caps = model.node_caps(&nl);
        // Const node index 1 has zero cap.
        assert_eq!(caps[1], 0.0);
    }
}
