//! Netlist IR: a topologically-ordered DAG of 1/2-input gates.
//!
//! Struct-of-arrays layout (`kinds` / `a` / `b`) keeps the simulator's
//! inner loop branch-light and cache-friendly — this is the hottest data
//! structure in the whole energy model.

/// Gate kinds.  `Input` nodes are driven by the testbench; `Const` nodes
/// carry a fixed logic level (0 or 1 encoded in operand `a`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum GateKind {
    Input = 0,
    Const = 1,
    Buf = 2,
    Not = 3,
    And = 4,
    Or = 5,
    Nand = 6,
    Nor = 7,
    Xor = 8,
    Xnor = 9,
}

impl GateKind {
    pub fn from_u8(v: u8) -> GateKind {
        match v {
            0 => GateKind::Input,
            1 => GateKind::Const,
            2 => GateKind::Buf,
            3 => GateKind::Not,
            4 => GateKind::And,
            5 => GateKind::Or,
            6 => GateKind::Nand,
            7 => GateKind::Nor,
            8 => GateKind::Xor,
            9 => GateKind::Xnor,
            _ => panic!("bad gate kind {v}"),
        }
    }

    pub fn is_binary(self) -> bool {
        matches!(
            self,
            GateKind::And
                | GateKind::Or
                | GateKind::Nand
                | GateKind::Nor
                | GateKind::Xor
                | GateKind::Xnor
        )
    }
}

/// A signal: an index into the netlist's node array.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Sig(pub u32);

/// Topologically-ordered gate network.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub kinds: Vec<u8>,
    pub a: Vec<u32>,
    pub b: Vec<u32>,
    /// Node indices of the primary inputs, in testbench order.
    pub inputs: Vec<u32>,
    /// Node indices of the primary outputs, in order.
    pub outputs: Vec<u32>,
    /// Node indices whose toggles get flip-flop (not gate) capacitance —
    /// i.e. signals that feed sequential elements (register D pins).
    pub ff_nodes: Vec<u32>,
}

impl Netlist {
    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Count of non-input, non-const logic gates (reported as "area").
    pub fn gate_count(&self) -> usize {
        self.kinds
            .iter()
            .filter(|&&k| k != GateKind::Input as u8 && k != GateKind::Const as u8)
            .count()
    }

    /// Topological level of every node: inputs and constants at level 0,
    /// every gate one past its deepest operand.  Nodes of one level are
    /// mutually independent, which is what lets the levelized evaluation
    /// schedule ([`crate::gates::EvalSchedule`]) regroup gates by kind.
    pub fn levels(&self) -> Vec<u32> {
        let mut lv = vec![0u32; self.len()];
        for i in 0..self.len() {
            lv[i] = match GateKind::from_u8(self.kinds[i]) {
                GateKind::Input | GateKind::Const => 0,
                GateKind::Buf | GateKind::Not => lv[self.a[i] as usize] + 1,
                _ => lv[self.a[i] as usize].max(lv[self.b[i] as usize]) + 1,
            };
        }
        lv
    }

    /// Fanout of every node (number of gate operand references).
    pub fn fanouts(&self) -> Vec<u32> {
        let mut fo = vec![0u32; self.len()];
        for i in 0..self.len() {
            let k = GateKind::from_u8(self.kinds[i]);
            match k {
                GateKind::Input | GateKind::Const => {}
                GateKind::Buf | GateKind::Not => fo[self.a[i] as usize] += 1,
                _ => {
                    fo[self.a[i] as usize] += 1;
                    fo[self.b[i] as usize] += 1;
                }
            }
        }
        fo
    }

    /// Verify topological order and operand bounds (debug aid; used by
    /// property tests).
    pub fn validate(&self) -> Result<(), String> {
        for i in 0..self.len() {
            let k = GateKind::from_u8(self.kinds[i]);
            match k {
                GateKind::Input | GateKind::Const => {}
                GateKind::Buf | GateKind::Not => {
                    if self.a[i] as usize >= i {
                        return Err(format!("node {i}: operand a not topo-ordered"));
                    }
                }
                _ => {
                    if self.a[i] as usize >= i || self.b[i] as usize >= i {
                        return Err(format!("node {i}: operands not topo-ordered"));
                    }
                }
            }
        }
        for &o in self.outputs.iter().chain(&self.inputs).chain(&self.ff_nodes) {
            if o as usize >= self.len() {
                return Err(format!("dangling node reference {o}"));
            }
        }
        Ok(())
    }
}

/// Builder with constant-folding and structural-hash-free peepholes.
/// Operand signals must already exist, which guarantees topological order
/// by construction.
pub struct NetBuilder {
    kinds: Vec<u8>,
    a: Vec<u32>,
    b: Vec<u32>,
    inputs: Vec<u32>,
    zero: Option<Sig>,
    one: Option<Sig>,
}

impl Default for NetBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl NetBuilder {
    pub fn new() -> Self {
        Self {
            kinds: Vec::new(),
            a: Vec::new(),
            b: Vec::new(),
            inputs: Vec::new(),
            zero: None,
            one: None,
        }
    }

    fn push(&mut self, k: GateKind, a: u32, b: u32) -> Sig {
        self.kinds.push(k as u8);
        self.a.push(a);
        self.b.push(b);
        Sig(self.kinds.len() as u32 - 1)
    }

    pub fn input(&mut self) -> Sig {
        let s = self.push(GateKind::Input, 0, 0);
        self.inputs.push(s.0);
        s
    }

    /// `n` fresh inputs (LSB first, the convention for all word builders).
    pub fn inputs(&mut self, n: usize) -> Vec<Sig> {
        (0..n).map(|_| self.input()).collect()
    }

    pub fn constant(&mut self, v: bool) -> Sig {
        let cache = if v { &mut self.one } else { &mut self.zero };
        if let Some(s) = *cache {
            return s;
        }
        let s = Sig(self.kinds.len() as u32);
        self.kinds.push(GateKind::Const as u8);
        self.a.push(v as u32);
        self.b.push(0);
        if v {
            self.one = Some(s);
        } else {
            self.zero = Some(s);
        }
        s
    }

    fn const_of(&self, s: Sig) -> Option<bool> {
        if self.kinds[s.0 as usize] == GateKind::Const as u8 {
            Some(self.a[s.0 as usize] != 0)
        } else {
            None
        }
    }

    pub fn not(&mut self, x: Sig) -> Sig {
        match self.const_of(x) {
            Some(v) => self.constant(!v),
            None => self.push(GateKind::Not, x.0, 0),
        }
    }

    pub fn and(&mut self, x: Sig, y: Sig) -> Sig {
        match (self.const_of(x), self.const_of(y)) {
            (Some(false), _) | (_, Some(false)) => self.constant(false),
            (Some(true), _) => y,
            (_, Some(true)) => x,
            _ if x == y => x,
            _ => self.push(GateKind::And, x.0, y.0),
        }
    }

    pub fn or(&mut self, x: Sig, y: Sig) -> Sig {
        match (self.const_of(x), self.const_of(y)) {
            (Some(true), _) | (_, Some(true)) => self.constant(true),
            (Some(false), _) => y,
            (_, Some(false)) => x,
            _ if x == y => x,
            _ => self.push(GateKind::Or, x.0, y.0),
        }
    }

    pub fn xor(&mut self, x: Sig, y: Sig) -> Sig {
        match (self.const_of(x), self.const_of(y)) {
            (Some(false), _) => y,
            (_, Some(false)) => x,
            (Some(true), _) => self.not(y),
            (_, Some(true)) => self.not(x),
            _ if x == y => self.constant(false),
            _ => self.push(GateKind::Xor, x.0, y.0),
        }
    }

    pub fn nand(&mut self, x: Sig, y: Sig) -> Sig {
        let t = self.and(x, y);
        self.not(t)
    }

    pub fn nor(&mut self, x: Sig, y: Sig) -> Sig {
        let t = self.or(x, y);
        self.not(t)
    }

    pub fn xnor(&mut self, x: Sig, y: Sig) -> Sig {
        let t = self.xor(x, y);
        self.not(t)
    }

    pub fn mux(&mut self, sel: Sig, t: Sig, f: Sig) -> Sig {
        // sel ? t : f  ==  (sel & t) | (!sel & f)
        let ns = self.not(sel);
        let x = self.and(sel, t);
        let y = self.and(ns, f);
        self.or(x, y)
    }

    /// Full adder: returns (sum, carry).
    pub fn full_adder(&mut self, x: Sig, y: Sig, c: Sig) -> (Sig, Sig) {
        let xy = self.xor(x, y);
        let sum = self.xor(xy, c);
        let t1 = self.and(xy, c);
        let t2 = self.and(x, y);
        let carry = self.or(t1, t2);
        (sum, carry)
    }

    /// Ripple-carry add of two little-endian words of equal width, with
    /// carry-in; result truncated to the input width (wrap-around), which
    /// matches a fixed-width hardware accumulator.
    pub fn add_words(&mut self, xs: &[Sig], ys: &[Sig], mut carry: Sig) -> Vec<Sig> {
        assert_eq!(xs.len(), ys.len());
        let mut out = Vec::with_capacity(xs.len());
        for (&x, &y) in xs.iter().zip(ys) {
            let (s, c) = self.full_adder(x, y, carry);
            out.push(s);
            carry = c;
        }
        out
    }

    pub fn finish(self, outputs: Vec<Sig>, ff_nodes: Vec<Sig>) -> Netlist {
        let nl = Netlist {
            kinds: self.kinds,
            a: self.a,
            b: self.b,
            inputs: self.inputs,
            outputs: outputs.into_iter().map(|s| s.0).collect(),
            ff_nodes: ff_nodes.into_iter().map(|s| s.0).collect(),
        };
        debug_assert!(nl.validate().is_ok());
        nl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::sim::TraceSim;

    #[test]
    fn const_folding() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let zero = b.constant(false);
        let one = b.constant(true);
        assert_eq!(b.and(x, zero), zero);
        assert_eq!(b.and(x, one), x);
        assert_eq!(b.xor(x, x), zero);
        assert_eq!(b.or(x, one), one);
    }

    #[test]
    fn full_adder_truth_table() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.input();
        let c = b.input();
        let (s, co) = b.full_adder(x, y, c);
        let nl = b.finish(vec![s, co], vec![]);
        let mut sim = TraceSim::new(&nl);
        for bits in 0..8u32 {
            let ins = [bits & 1 != 0, bits & 2 != 0, bits & 4 != 0];
            let out = sim.eval_single(&nl, &ins);
            let total = ins.iter().filter(|&&v| v).count() as u32;
            assert_eq!(out[0], total & 1 != 0, "sum for {bits:03b}");
            assert_eq!(out[1], total >= 2, "carry for {bits:03b}");
        }
    }

    #[test]
    fn adder_wraps() {
        let mut b = NetBuilder::new();
        let xs = b.inputs(4);
        let ys = b.inputs(4);
        let c0 = b.constant(false);
        let sum = b.add_words(&xs, &ys, c0);
        let nl = b.finish(sum, vec![]);
        let mut sim = TraceSim::new(&nl);
        for x in 0..16u32 {
            for y in 0..16u32 {
                let mut ins = [false; 8];
                for i in 0..4 {
                    ins[i] = (x >> i) & 1 != 0;
                    ins[4 + i] = (y >> i) & 1 != 0;
                }
                let out = sim.eval_single(&nl, &ins);
                let got = out
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| (v as u32) << i)
                    .sum::<u32>();
                assert_eq!(got, (x + y) & 0xF);
            }
        }
    }

    #[test]
    fn levels_respect_structure() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.input();
        let c = b.input();
        let (s, co) = b.full_adder(x, y, c);
        let nl = b.finish(vec![s, co], vec![]);
        let lv = nl.levels();
        // Inputs at level 0; every gate strictly above its operands.
        for &i in &nl.inputs {
            assert_eq!(lv[i as usize], 0);
        }
        for i in 0..nl.len() {
            match GateKind::from_u8(nl.kinds[i]) {
                GateKind::Input | GateKind::Const => {}
                GateKind::Buf | GateKind::Not => {
                    assert!(lv[i] > lv[nl.a[i] as usize]);
                }
                _ => {
                    assert!(lv[i] > lv[nl.a[i] as usize]);
                    assert!(lv[i] > lv[nl.b[i] as usize]);
                }
            }
        }
        // full adder: sum = xor(xor(x,y), c) sits at level 2.
        assert_eq!(lv[nl.outputs[0] as usize], 2);
    }

    #[test]
    fn validate_catches_unordered() {
        let nl = Netlist {
            kinds: vec![GateKind::Buf as u8],
            a: vec![5],
            b: vec![0],
            inputs: vec![],
            outputs: vec![],
            ff_nodes: vec![],
        };
        assert!(nl.validate().is_err());
    }
}
