//! Gate-level netlist simulation substrate.
//!
//! Stands in for the paper's Modelsim + Synopsys DC power flow (DESIGN.md
//! §2): structural netlists of 2-input gates, a **bit-parallel** (64
//! simulation lanes per machine word) zero-delay logic simulator with
//! per-node toggle counting, a NanGate-15nm-inspired capacitance model
//! turning toggles into joules, and a constant-propagation specializer
//! that folds the stationary weight bits into the netlist — which is
//! precisely where weight-dependent MAC power (paper Fig. 1) comes from.

pub mod netlist;
pub mod optimize;
pub mod power;
pub mod sim;

pub use netlist::{GateKind, NetBuilder, Netlist, Sig};
pub use optimize::const_prop;
pub use power::{CapModel, PowerCtx, PowerReport};
pub use sim::{transpose64, EvalSchedule, TraceSim};
