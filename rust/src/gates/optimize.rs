//! Netlist specialization: constant propagation + dead-code elimination.
//!
//! The systolic array is weight-stationary: during a tile pass the weight
//! register bits are constants.  Folding them into the MAC netlist yields
//! a per-weight-value specialized circuit — smaller for sparse bit
//! patterns, with different switching structure per weight.  This is the
//! structural mechanism behind the paper's weight-dependent MAC power
//! (Fig. 1) and behind pruning's energy savings (w = 0 collapses the
//! whole multiplier).

use super::netlist::{GateKind, NetBuilder, Netlist, Sig};

/// Rebuild `nl` with the listed primary inputs fixed to constants.
///
/// `fixed[i] = (input_position, value)` refers to positions in
/// `nl.inputs`.  The surviving inputs keep their relative order.  Gates
/// made redundant are folded away by the builder's peepholes; nodes no
/// longer reachable from outputs/FF taps are dropped.
pub fn const_prop(nl: &Netlist, fixed: &[(usize, bool)]) -> Netlist {
    let mut fixed_map: Vec<Option<bool>> = vec![None; nl.inputs.len()];
    for &(pos, v) in fixed {
        fixed_map[pos] = Some(v);
    }

    let mut b = NetBuilder::new();
    // Map from old node index to new signal.
    let mut map: Vec<Option<Sig>> = vec![None; nl.len()];

    // Pre-create surviving inputs in original relative order.
    for (pos, &node) in nl.inputs.iter().enumerate() {
        let sig = match fixed_map[pos] {
            Some(v) => b.constant(v),
            None => b.input(),
        };
        map[node as usize] = Some(sig);
    }

    for i in 0..nl.len() {
        if map[i].is_some() {
            continue; // input already mapped
        }
        let k = GateKind::from_u8(nl.kinds[i]);
        let sig = match k {
            GateKind::Input => unreachable!("inputs pre-mapped"),
            GateKind::Const => b.constant(nl.a[i] != 0),
            GateKind::Buf => {
                let a = map[nl.a[i] as usize].expect("topo order");
                a
            }
            GateKind::Not => {
                let a = map[nl.a[i] as usize].expect("topo order");
                b.not(a)
            }
            _ => {
                let a = map[nl.a[i] as usize].expect("topo order");
                let bb = map[nl.b[i] as usize].expect("topo order");
                match k {
                    GateKind::And => b.and(a, bb),
                    GateKind::Or => b.or(a, bb),
                    GateKind::Nand => b.nand(a, bb),
                    GateKind::Nor => b.nor(a, bb),
                    GateKind::Xor => b.xor(a, bb),
                    GateKind::Xnor => b.xnor(a, bb),
                    _ => unreachable!(),
                }
            }
        };
        map[i] = Some(sig);
    }

    let outputs: Vec<Sig> = nl
        .outputs
        .iter()
        .map(|&o| map[o as usize].unwrap())
        .collect();
    let ffs: Vec<Sig> = nl
        .ff_nodes
        .iter()
        .map(|&o| map[o as usize].unwrap())
        .collect();
    let dense = b.finish(outputs, ffs);
    dce(&dense)
}

/// Drop nodes not reachable (backwards) from outputs, FF taps, or inputs.
pub fn dce(nl: &Netlist) -> Netlist {
    let mut live = vec![false; nl.len()];
    let mut stack: Vec<u32> = nl
        .outputs
        .iter()
        .chain(&nl.ff_nodes)
        .copied()
        .collect();
    while let Some(n) = stack.pop() {
        let i = n as usize;
        if live[i] {
            continue;
        }
        live[i] = true;
        match GateKind::from_u8(nl.kinds[i]) {
            GateKind::Input | GateKind::Const => {}
            GateKind::Buf | GateKind::Not => stack.push(nl.a[i]),
            _ => {
                stack.push(nl.a[i]);
                stack.push(nl.b[i]);
            }
        }
    }
    // Inputs always survive so the testbench interface is stable.
    for &n in &nl.inputs {
        live[n as usize] = true;
    }

    let mut remap: Vec<u32> = vec![u32::MAX; nl.len()];
    let mut kinds = Vec::new();
    let mut a = Vec::new();
    let mut bv = Vec::new();
    for i in 0..nl.len() {
        if !live[i] {
            continue;
        }
        remap[i] = kinds.len() as u32;
        kinds.push(nl.kinds[i]);
        let k = GateKind::from_u8(nl.kinds[i]);
        match k {
            GateKind::Input => {
                a.push(0);
                bv.push(0);
            }
            GateKind::Const => {
                a.push(nl.a[i]);
                bv.push(0);
            }
            GateKind::Buf | GateKind::Not => {
                a.push(remap[nl.a[i] as usize]);
                bv.push(0);
            }
            _ => {
                a.push(remap[nl.a[i] as usize]);
                bv.push(remap[nl.b[i] as usize]);
            }
        }
    }
    let out = Netlist {
        kinds,
        a,
        b: bv,
        inputs: nl.inputs.iter().map(|&n| remap[n as usize]).collect(),
        outputs: nl.outputs.iter().map(|&n| remap[n as usize]).collect(),
        ff_nodes: nl.ff_nodes.iter().map(|&n| remap[n as usize]).collect(),
    };
    debug_assert!(out.validate().is_ok());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::netlist::NetBuilder;
    use crate::gates::sim::TraceSim;

    /// (x & s) | (y & !s) specialized on s matches the chosen branch.
    #[test]
    fn specialize_mux() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.input();
        let s = b.input();
        let m = b.mux(s, x, y);
        let nl = b.finish(vec![m], vec![]);

        for sval in [false, true] {
            let spec = const_prop(&nl, &[(2, sval)]);
            // s is folded away: inputs shrink to {x, y}, logic to a wire.
            assert_eq!(spec.inputs.len(), 2);
            assert!(spec.gate_count() <= 1, "gates: {}", spec.gate_count());
            let mut sim = TraceSim::new(&spec);
            for (xv, yv) in [(false, false), (true, false), (false, true), (true, true)] {
                let out = sim.eval_single(&spec, &[xv, yv]);
                assert_eq!(out[0], if sval { xv } else { yv });
            }
        }
    }

    /// Exhaustive functional equivalence after random specialization.
    #[test]
    fn const_prop_preserves_function() {
        let mut b = NetBuilder::new();
        let ins = b.inputs(6);
        let t1 = b.xor(ins[0], ins[1]);
        let t2 = b.and(t1, ins[2]);
        let t3 = b.or(t2, ins[3]);
        let t4 = b.nand(t3, ins[4]);
        let t5 = b.xnor(t4, ins[5]);
        let nl = b.finish(vec![t3, t5], vec![]);

        let fixed = [(1usize, true), (4usize, false)];
        let spec = const_prop(&nl, &fixed);
        assert_eq!(spec.inputs.len(), 4);
        let mut sim_full = TraceSim::new(&nl);
        let mut sim_spec = TraceSim::new(&spec);
        for bits in 0..64u32 {
            let mut ins_full: Vec<bool> = (0..6).map(|i| (bits >> i) & 1 != 0).collect();
            for &(pos, v) in &fixed {
                ins_full[pos] = v;
            }
            // Surviving inputs keep their relative order.
            let ins_spec: Vec<bool> = ins_full
                .iter()
                .enumerate()
                .filter(|(i, _)| !fixed.iter().any(|&(p, _)| p == *i))
                .map(|(_, &v)| v)
                .collect();
            let expect = sim_full.eval_single(&nl, &ins_full);
            let got = sim_spec.eval_single(&spec, &ins_spec);
            assert_eq!(expect, got, "bits {bits:06b}");
        }
    }

    #[test]
    fn dce_drops_dead_logic() {
        let mut b = NetBuilder::new();
        let x = b.input();
        let y = b.input();
        let _dead = b.and(x, y);
        let live = b.xor(x, y);
        let nl = b.finish(vec![live], vec![]);
        let cleaned = dce(&nl);
        assert_eq!(cleaned.gate_count(), 1);
        assert_eq!(cleaned.inputs.len(), 2);
    }
}
