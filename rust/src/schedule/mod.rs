//! Layer-wise compression schedules (paper §4.3).
//!
//! [`energy_prioritized`] is the paper's method: rank layers by energy
//! share ρ_ℓ, process in descending order, and per layer pick the most
//! aggressive (prune-ratio, K) configuration that keeps global validation
//! accuracy above `Acc₀ − δ`.  [`global_uniform`] is the ablation
//! baseline (Table 3): the same configuration applied to every layer at
//! once, layer-agnostically.

use crate::energy::cache::EnergyEvaluator;
use crate::energy::{LayerEnergy, NetworkEnergy};
use crate::quant::WeightSet;
use crate::selection::{
    greedy_backward_eliminate, safe_initial_set, AccuracyOracle, CompressionState, GreedyParams,
    LayerConfig,
};
use crate::util::threadpool::parallel_map;
use anyhow::{anyhow, Result};
use std::cmp::Ordering;
use std::sync::Arc;

pub mod acc_cache;
pub mod journal;
pub use acc_cache::AccCache;
pub use journal::{SearchJournal, TrialRecord};

/// A candidate per-layer configuration of the §4.3 sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Config {
    pub prune_ratio: f64,
    pub k_target: usize,
}

/// Schedule hyper-parameters.
#[derive(Clone, Debug)]
pub struct ScheduleParams {
    /// Candidate pruning ratios, most aggressive first (paper: 0.7, 0.5, 0.3).
    pub prune_ratios: Vec<f64>,
    /// Candidate set sizes, most aggressive first (paper: 16, 24, 32).
    pub k_targets: Vec<usize>,
    /// Accuracy budget δ.
    pub delta: f64,
    /// Baseline accuracy Acc₀.
    pub acc0: f64,
    /// Fine-tune steps after applying each candidate config.
    pub fine_tune_steps: usize,
    /// Only process the top-`max_layers` energy layers (None = all); the
    /// remaining layers stay uncompressed, mirroring the paper's focus on
    /// the dominant blocks (Table 2).
    pub max_layers: Option<usize>,
    /// Minimum energy share ρ_ℓ for a layer to be worth compressing.
    pub min_share: f64,
    /// Successive-halving rungs for the oracle-efficient search
    /// (`--halving-rungs`): `0` = the legacy exhaustive sweep (every
    /// candidate pays the full fine-tune budget, and rejected trials'
    /// fine-tune drift carries into later candidates); `1` =
    /// warm-started single rung (every candidate fine-tunes from the
    /// shared accepted-path snapshot at full budget, with accuracy
    /// caching); `>= 2` = true successive halving (rung budgets double
    /// from `rung_frac × fine_tune_steps`, only the top half survives
    /// each rung).  Ignored when `fine_tune_steps == 0`, when the
    /// greedy elimination consults the oracle per removal, or when the
    /// oracle cannot snapshot state.
    pub halving_rungs: usize,
    /// First-rung fraction of `fine_tune_steps` (`--rung-frac`).
    pub rung_frac: f64,
    pub greedy: GreedyParams,
}

impl Default for ScheduleParams {
    fn default() -> Self {
        Self {
            prune_ratios: vec![0.7, 0.5, 0.3],
            k_targets: vec![16, 24, 32],
            delta: 0.03,
            acc0: 1.0,
            fine_tune_steps: 50,
            max_layers: None,
            min_share: 0.005,
            halving_rungs: 0,
            rung_frac: 0.25,
            greedy: GreedyParams::default(),
        }
    }
}

/// Per-layer outcome for reporting (Table 2 rows).
#[derive(Clone, Debug)]
pub struct LayerOutcome {
    pub conv_idx: usize,
    pub share: f64,
    pub accepted: Option<Config>,
    pub energy_before: f64,
    pub energy_after: f64,
    pub accuracy_after: f64,
}

/// Schedule result.
#[derive(Clone, Debug)]
pub struct ScheduleResult {
    pub state: CompressionState,
    pub outcomes: Vec<LayerOutcome>,
    pub final_accuracy: f64,
}

impl ScheduleResult {
    /// Machine-readable form for the golden-file regression harness
    /// (see `testutil::golden`): the accepted per-layer configuration,
    /// every outcome row, and the final accuracy.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let state = Json::arr(self.state.layers.iter().map(|l| {
            Json::obj(vec![
                ("prune_ratio", Json::num(l.prune_ratio)),
                (
                    "wset",
                    match &l.wset {
                        Some(s) => Json::arr(
                            s.codes().iter().map(|&c| Json::num(c as f64)),
                        ),
                        None => Json::Null,
                    },
                ),
            ])
        }));
        let outcomes = Json::arr(self.outcomes.iter().map(|oc| {
            Json::obj(vec![
                ("conv_idx", Json::num(oc.conv_idx as f64)),
                ("share", Json::num(oc.share)),
                (
                    "accepted",
                    match oc.accepted {
                        Some(c) => Json::obj(vec![
                            ("prune_ratio", Json::num(c.prune_ratio)),
                            ("k_target", Json::num(c.k_target as f64)),
                        ]),
                        None => Json::Null,
                    },
                ),
                ("energy_before", Json::num(oc.energy_before)),
                ("energy_after", Json::num(oc.energy_after)),
                ("accuracy_after", Json::num(oc.accuracy_after)),
            ])
        }));
        Json::obj(vec![
            ("state", state),
            ("outcomes", outcomes),
            ("final_accuracy", Json::num(self.final_accuracy)),
        ])
    }
}

/// Callback bundle the schedule needs from the coordinator: per-layer
/// energy models and usage histograms that *reflect the current state*
/// (pruning changes usage), recomputed on demand.
pub trait LayerModeler {
    /// Energy model of layer `conv_idx`.
    fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy;
    /// Weight-code usage of the layer under `state` (mask applied,
    /// quantized, *not* yet set-restricted).
    fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256];
    /// Current per-layer energies under `state` (for ρ_ℓ and reporting).
    fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy;
    /// Shared memoized evaluator, when the host provides one — lets the
    /// schedule precompute a layer's candidate weight sets in parallel
    /// (only used when the search is oracle-free, i.e. no fine-tuning
    /// between candidates and no per-removal accuracy checks).
    fn evaluator(&mut self) -> Option<Arc<EnergyEvaluator>> {
        None
    }
}

/// Oracle stand-in for oracle-free candidate precomputation (the greedy
/// elimination never consults it when `check_every_removal` is off).
struct NeverConsulted;

impl AccuracyOracle for NeverConsulted {
    fn accuracy(&mut self, _: &CompressionState) -> f64 {
        unreachable!("oracle-free candidate precompute must not evaluate accuracy")
    }
    fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
}

/// Build the restricted set for one `(prune_ratio, k_target)` candidate
/// of `conv_idx` from a usage histogram (the §4.2 procedure, proxy
/// mode).  Pure given its inputs, which is what makes the parallel
/// precompute below bit-identical to the sequential sweep.
fn candidate_set(
    usage: &[u64; 256],
    le: &LayerEnergy,
    n_conv: usize,
    conv_idx: usize,
    cfg: Config,
    sp: &ScheduleParams,
) -> WeightSet {
    let set0 = safe_initial_set(usage, le, sp.greedy.k_init);
    let gp = GreedyParams {
        k_target: cfg.k_target,
        acc0: sp.acc0,
        delta: sp.delta,
        threads: 1, // already inside a layer-level fan-out
        ..sp.greedy.clone()
    };
    let mut tmp = CompressionState::dense(n_conv);
    let (set, _trace) = greedy_backward_eliminate(
        set0,
        usage,
        le,
        &mut NeverConsulted,
        &mut tmp,
        conv_idx,
        &gp,
    );
    set
}

/// §4.3 — energy-prioritized layer-wise compression.
///
/// `host` provides both the energy models (`LayerModeler`) and the
/// accuracy oracle — the coordinator's pipeline implements both.
pub fn energy_prioritized<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
) -> ScheduleResult {
    run_schedule(host, n_conv, sp, None, None)
        .expect("journal-free schedule search is infallible")
        .expect("journal-free schedule search has no trial budget")
}

/// [`energy_prioritized`] with a persistent per-candidate journal:
/// every trial is recorded (atomically, under a checksummed header)
/// before the next begins, so a search killed mid-way resumes from the
/// exact candidate it died on instead of repaying every fine-tune step
/// before it.  Returns `Ok(None)` when the journal's per-invocation
/// trial budget is exhausted — call again with a journal at the same
/// path to continue.
///
/// With fine-tuning enabled the oracle's state is snapshotted (via
/// [`AccuracyOracle::save_search_state`]) after each trial; the journal
/// and the snapshot are written in sequence, so a kill landing between
/// the two writes costs the resumed run at most one trial's fine-tune
/// drift — every completed write boundary resumes exactly.
pub fn energy_prioritized_resumable<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
    journal: &mut SearchJournal,
) -> Result<Option<ScheduleResult>> {
    run_schedule(host, n_conv, sp, Some(journal), None)
}

/// Full-control entry point: optional journal (resumable search) and
/// optional persistent accuracy cache shared across searches.  Without
/// a cache, the oracle-efficient mode still runs against a session-only
/// cache (seeded from the journal's recorded trials on resume).
pub fn energy_prioritized_with<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
    journal: Option<&mut SearchJournal>,
    cache: Option<&mut AccCache>,
) -> Result<Option<ScheduleResult>> {
    run_schedule(host, n_conv, sp, journal, cache)
}

/// Per-rung fine-tune *increments* for the successive-halving search:
/// cumulative budgets double from `frac × total` and the last rung tops
/// up to exactly `total`; rungs whose increment rounds to zero collapse
/// away, so the returned increments always sum to `total`.
fn rung_schedule(total: usize, rungs: usize, frac: f64) -> Vec<usize> {
    if rungs <= 1 || total == 0 {
        return vec![total];
    }
    let frac = if frac > 0.0 && frac < 1.0 {
        frac
    } else {
        1.0 / rungs as f64
    };
    let mut steps = Vec::new();
    let mut prev = 0usize;
    for r in 0..rungs {
        let cum = if r + 1 == rungs {
            total
        } else {
            let scale = (1u64 << r.min(62)) as f64;
            ((total as f64 * frac * scale).round() as usize).clamp(1, total)
        };
        if cum > prev {
            steps.push(cum - prev);
            prev = cum;
        }
    }
    steps
}

fn run_schedule<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
    mut journal: Option<&mut SearchJournal>,
    cache: Option<&mut AccCache>,
) -> Result<Option<ScheduleResult>> {
    // Key identifying the search parameters — a journal written under
    // different parameters must not be resumed.
    let meta_key = format!(
        "v2;n_conv={n_conv};ratios={:?};ks={:?};ft={};delta={};acc0={};maxl={:?};min_share={};rungs={};rfrac={}",
        sp.prune_ratios,
        sp.k_targets,
        sp.fine_tune_steps,
        sp.delta,
        sp.acc0,
        sp.max_layers,
        sp.min_share,
        sp.halving_rungs,
        sp.rung_frac
    );
    // Oracle-efficient mode: warm-started, rung-budgeted, cached trials.
    // It needs real fine-tuning (with `ft == 0` the legacy sweep is
    // already oracle-free) and a greedy elimination that never consults
    // the oracle mid-build, so every candidate set stays a pure
    // function of the shared base parameters.
    let mut halving =
        sp.halving_rungs >= 1 && sp.fine_tune_steps > 0 && !sp.greedy.check_every_removal;
    // Cache keys fold in the rung geometry: an early-accepted layer may
    // carry a partial fine-tune budget, so identical configs reached
    // under different rung schedules are *not* interchangeable.
    let key_ctx = if halving {
        format!(
            "{}|rungs={};rfrac={}",
            host.search_context(),
            sp.halving_rungs,
            sp.rung_frac
        )
    } else {
        String::new()
    };
    let mut session_cache = AccCache::ephemeral();
    let cache: &mut AccCache = match cache {
        Some(c) => c,
        None => &mut session_cache,
    };
    let mut state = CompressionState::dense(n_conv);
    let mut outcomes: Vec<LayerOutcome> = Vec::new();
    // (order position, candidate index) to resume at; None = fresh.
    let mut resume_at: Option<(usize, usize)> = None;
    // Frozen processing order: (conv_idx, energy_before, share).
    let mut order_rows: Vec<(usize, f64, f64)> = Vec::new();

    if let Some(j) = journal.as_deref_mut() {
        if j.try_load(&meta_key)? {
            // With fine-tuning, the journal's accuracy numbers are only
            // meaningful if the oracle restores the fine-tuned state
            // that produced them.  Halving journals restore the
            // accepted-path base from its content-addressed snapshot
            // (the rolling `j.tag` snapshot holds rejected-trial drift,
            // which warm-starting exists to avoid); legacy journals use
            // the rolling tag.
            let oracle_ok = if sp.fine_tune_steps == 0 {
                true
            } else if halving {
                let tag = match j.trials.iter().rev().find(|t| t.accepted) {
                    Some(t) => acc_cache::acc_tag(&t.key),
                    None => acc_cache::acc_tag(&acc_cache::path_key(
                        &key_ctx,
                        sp.fine_tune_steps,
                        &state,
                    )),
                };
                host.load_search_state(&tag)
            } else {
                host.load_search_state(&j.tag)
            };
            if oracle_ok {
                order_rows = j.order.clone();
                outcomes = j.outcomes.clone();
                for t in &j.trials {
                    if t.accepted {
                        state.layers[t.conv_idx] = LayerConfig {
                            prune_ratio: t.prune_ratio,
                            wset: Some(WeightSet::new(t.wset.clone())),
                        };
                    }
                    // Seed the (session or persistent) accuracy cache so
                    // a replayed layer serves its recorded trials from
                    // cache instead of re-paying the oracle.
                    if !t.key.is_empty() {
                        cache.put(&t.key, t.accuracy)?;
                    }
                }
                let n_cands = sp.prune_ratios.len() * sp.k_targets.len();
                if let Some(t) = j.trials.last().filter(|_| !halving) {
                    let layer_done = t.accepted || t.cand_idx + 1 >= n_cands;
                    if layer_done && !outcomes.iter().any(|oc| oc.conv_idx == t.conv_idx) {
                        // Kill landed between the trial write and the
                        // outcome write: reconstruct the row from the
                        // recorded trial + rebuilt state.
                        let (_, e_before, share) =
                            *order_rows.get(t.order_pos).ok_or_else(|| {
                                anyhow!(
                                    "schedule journal {}: trial references order position {} out of range",
                                    j.path().display(),
                                    t.order_pos
                                )
                            })?;
                        let after = host.network_energy(&state);
                        let e_after = after
                            .layers
                            .iter()
                            .find(|(i, _)| *i == t.conv_idx)
                            .map(|(_, e)| *e)
                            .unwrap_or(e_before);
                        outcomes.push(LayerOutcome {
                            conv_idx: t.conv_idx,
                            share,
                            accepted: t.accepted.then(|| Config {
                                prune_ratio: t.prune_ratio,
                                k_target: t.k_target,
                            }),
                            energy_before: e_before,
                            energy_after: e_after,
                            // Rejected layers report the best accuracy
                            // any of their trials reached, not a fake
                            // 0.0 (same rule as the live path below).
                            accuracy_after: j
                                .trials
                                .iter()
                                .filter(|x| x.conv_idx == t.conv_idx)
                                .map(|x| x.accuracy)
                                .fold(f64::NEG_INFINITY, f64::max),
                        });
                        j.outcomes = outcomes.clone();
                        j.save()?;
                    }
                }
                resume_at = Some(if halving {
                    // A halving layer is complete iff its outcome row
                    // exists; an interrupted layer replays from rung 0,
                    // served by the journal-seeded accuracy cache.
                    match j.trials.last() {
                        Some(t) if outcomes.iter().any(|oc| oc.conv_idx == t.conv_idx) => {
                            (t.order_pos + 1, 0)
                        }
                        Some(t) => (t.order_pos, 0),
                        None => (0, 0),
                    }
                } else {
                    match j.trials.last() {
                        Some(t) if t.accepted || t.cand_idx + 1 >= n_cands => (t.order_pos + 1, 0),
                        Some(t) => (t.order_pos, t.cand_idx + 1),
                        None => (0, 0),
                    }
                });
                let (p, c) = resume_at.unwrap();
                crate::info!(
                    "schedule: resuming journal {} at layer position {p}, candidate {c} ({} recorded trials)",
                    j.path().display(),
                    j.trials.len()
                );
            } else {
                crate::info!(
                    "schedule journal {}: no oracle snapshot for tag `{}`; restarting search",
                    j.path().display(),
                    j.tag
                );
            }
        }
    }

    if resume_at.is_none() {
        // Fresh start: derive and FREEZE the processing order.  Params
        // drift during fine-tuning, so re-deriving the order on resume
        // could disagree with the interrupted run.
        let base = host.network_energy(&state);
        let shares = base.shares();
        let mut order = base.descending();
        if let Some(maxl) = sp.max_layers {
            order.truncate(maxl);
        }
        order_rows = order
            .into_iter()
            .map(|(conv_idx, e)| {
                let share = shares
                    .iter()
                    .find(|(i, _)| *i == conv_idx)
                    .map(|(_, s)| *s)
                    .unwrap_or(0.0);
                (conv_idx, e, share)
            })
            .collect();
        if let Some(j) = journal.as_deref_mut() {
            j.start(&meta_key, order_rows.clone());
            j.save()?;
            // Halving keeps content-addressed snapshots instead of the
            // rolling per-trial tag (saved below once per acceptance).
            if sp.fine_tune_steps > 0 && !halving && !host.save_search_state(&j.tag) {
                crate::info!(
                    "schedule journal: oracle cannot snapshot state; an interrupted \
                     fine-tuning search will restart from scratch on resume"
                );
            }
        }
    }

    // Warm-start base for the oracle-efficient mode: the accepted-path
    // snapshot every trial fine-tunes from.  A resumed search derives
    // the tag from the last accepted trial (already restored above); a
    // fresh search snapshots the oracle's current (trained) state now.
    // An oracle that cannot snapshot falls back to the legacy sweep.
    let mut base_tag = String::new();
    if halving {
        let last_key = journal
            .as_deref()
            .and_then(|j| j.trials.iter().rev().find(|t| t.accepted))
            .map(|t| t.key.clone());
        base_tag = match last_key {
            Some(k) if !k.is_empty() => acc_cache::acc_tag(&k),
            _ => {
                let tag = acc_cache::acc_tag(&acc_cache::path_key(
                    &key_ctx,
                    sp.fine_tune_steps,
                    &state,
                ));
                if !host.save_search_state(&tag) {
                    crate::info!(
                        "schedule: oracle cannot snapshot state; successive-halving \
                         warm-start disabled, falling back to the exhaustive sweep"
                    );
                    halving = false;
                }
                tag
            }
        };
    }
    // Every content-addressed snapshot this run creates (cleanup below).
    let mut spawned_tags: Vec<String> = Vec::new();
    if halving {
        spawned_tags.push(base_tag.clone());
    }

    let (start_pos, start_cand) = resume_at.unwrap_or((0, 0));
    let mut budget = journal.as_deref().and_then(|j| j.budget);
    for (pos, &(conv_idx, e_before, share)) in order_rows.iter().enumerate() {
        if pos < start_pos || share < sp.min_share {
            continue;
        }
        let le = host.layer_energy(conv_idx);
        let mut accepted: Option<Config> = None;
        // Rejected layers report the best accuracy any of their trials
        // reached, not a fake 0.0; a resumed layer folds in the
        // accuracies already recorded for this position.
        let mut best_acc = f64::NEG_INFINITY;
        if let Some(j) = journal.as_deref() {
            for t in j.trials.iter().filter(|t| t.order_pos == pos) {
                best_acc = best_acc.max(t.accuracy);
            }
        }
        // Candidate configs, most aggressive first.
        let candidates: Vec<Config> = sp
            .prune_ratios
            .iter()
            .flat_map(|&prune_ratio| {
                sp.k_targets.iter().map(move |&k_target| Config {
                    prune_ratio,
                    k_target,
                })
            })
            .collect();
        if halving {
            match run_layer_halving(
                host,
                n_conv,
                sp,
                &key_ctx,
                &mut base_tag,
                &mut state,
                pos,
                conv_idx,
                &le,
                &candidates,
                cache,
                &mut journal,
                &mut budget,
                &mut spawned_tags,
            )? {
                Some((acc_cfg, layer_best)) => {
                    accepted = acc_cfg;
                    best_acc = best_acc.max(layer_best);
                }
                None => return Ok(None),
            }
            let after = host.network_energy(&state);
            let e_after = after
                .layers
                .iter()
                .find(|(i, _)| *i == conv_idx)
                .map(|(_, e)| *e)
                .unwrap_or(e_before);
            let oc = LayerOutcome {
                conv_idx,
                share,
                accepted,
                energy_before: e_before,
                energy_after: e_after,
                accuracy_after: if best_acc.is_finite() { best_acc } else { 0.0 },
            };
            if let Some(j) = journal.as_deref_mut() {
                j.outcomes.push(oc.clone());
                j.save()?;
            }
            outcomes.push(oc);
            continue;
        }
        // ---- Legacy exhaustive sweep (the pre-halving behavior, kept
        // bit-identical so existing goldens and journals stay valid) ----
        // When no fine-tuning happens between candidates and the greedy
        // elimination never consults the oracle, every candidate's
        // restricted set is a pure function of the frozen parameters —
        // build them in parallel *waves* of `threads` against the shared
        // evaluator, one wave ahead of consumption.  The wave (rather
        // than all-at-once) bound keeps the common first-candidate-
        // accepted case at one elimination of wall-clock instead of
        // eagerly paying for the whole menu.  (With fine-tuning, params
        // drift between candidates, so sets are built inline, in order.)
        let oracle_free = sp.fine_tune_steps == 0 && !sp.greedy.check_every_removal;
        let evaluator = if oracle_free { host.evaluator() } else { None };
        let mut precomputed: Vec<Option<WeightSet>> = vec![None; candidates.len()];
        let first_cand = if pos == start_pos { start_cand } else { 0 };
        for ci_cand in first_cand..candidates.len() {
            let cfg = candidates[ci_cand];
            if budget == Some(0) {
                // This invocation's trial budget is exhausted; the
                // journal already points at exactly this candidate.
                return Ok(None);
            }
            let mut trial = state.clone();
            trial.layers[conv_idx] = LayerConfig {
                prune_ratio: cfg.prune_ratio,
                wset: None,
            };
            // The restricted set for this (ratio, K): precomputed, or
            // built inline against the live oracle/params.
            let set = match &evaluator {
                Some(ev) => {
                    if precomputed[ci_cand].is_none() {
                        let threads = sp.greedy.threads.max(1);
                        let wave_end = (ci_cand + threads).min(candidates.len());
                        let wave = &candidates[ci_cand..wave_end];
                        // Pre-warm the wave's distinct prune ratios (one
                        // usage computation each, in parallel) so the
                        // candidate fan-out below hits the memo instead
                        // of racing duplicate magnitude-sorts for
                        // candidates that share a ratio.
                        let mut ratios: Vec<f64> = Vec::new();
                        for c in wave {
                            if !ratios.iter().any(|r| r.to_bits() == c.prune_ratio.to_bits()) {
                                ratios.push(c.prune_ratio);
                            }
                        }
                        let ratios_ref = &ratios;
                        parallel_map(ratios.len(), threads, |j| {
                            ev.usage_for_conv(conv_idx, ratios_ref[j]);
                        });
                        let le_ref = &le;
                        let sets = parallel_map(wave.len(), threads, |j| {
                            let cfg = wave[j];
                            let usage = ev.usage_for_conv(conv_idx, cfg.prune_ratio);
                            candidate_set(&usage, le_ref, n_conv, conv_idx, cfg, sp)
                        });
                        for (j, s) in sets.into_iter().enumerate() {
                            precomputed[ci_cand + j] = Some(s);
                        }
                    }
                    precomputed[ci_cand].clone().expect("wave fill")
                }
                None => {
                    let usage = host.usage(conv_idx, &trial);
                    let set0 = safe_initial_set(&usage, &le, sp.greedy.k_init);
                    let gp = GreedyParams {
                        k_target: cfg.k_target,
                        acc0: sp.acc0,
                        delta: sp.delta,
                        ..sp.greedy.clone()
                    };
                    let (set, _trace) = greedy_backward_eliminate(
                        set0,
                        &usage,
                        &le,
                        host,
                        &mut trial,
                        conv_idx,
                        &gp,
                    );
                    set
                }
            };
            let set_codes = journal.is_some().then(|| set.codes().to_vec());
            trial.layers[conv_idx].wset = Some(set);
            // Short fine-tune then global accuracy check (§4.3 step 3).
            host.fine_tune(&trial, sp.fine_tune_steps);
            let acc = host.accuracy(&trial);
            best_acc = best_acc.max(acc);
            let ok = acc >= sp.acc0 - sp.delta;
            if ok {
                state = trial;
                accepted = Some(cfg);
            }
            if let Some(j) = journal.as_deref_mut() {
                j.trials.push(TrialRecord {
                    order_pos: pos,
                    conv_idx,
                    cand_idx: ci_cand,
                    rung: 0,
                    prune_ratio: cfg.prune_ratio,
                    k_target: cfg.k_target,
                    accepted: ok,
                    accuracy: acc,
                    wset: set_codes.unwrap_or_default(),
                    key: String::new(),
                });
                j.save()?;
                // Snapshot the oracle right after its state moved, so a
                // resume replays this trial's effects exactly.
                if sp.fine_tune_steps > 0 {
                    host.save_search_state(&j.tag);
                }
            }
            if let Some(b) = budget.as_mut() {
                *b -= 1;
            }
            if ok {
                break;
            }
        }
        let after = host.network_energy(&state);
        let e_after = after
            .layers
            .iter()
            .find(|(i, _)| *i == conv_idx)
            .map(|(_, e)| *e)
            .unwrap_or(e_before);
        let oc = LayerOutcome {
            conv_idx,
            share,
            accepted,
            energy_before: e_before,
            energy_after: e_after,
            accuracy_after: if best_acc.is_finite() { best_acc } else { 0.0 },
        };
        if let Some(j) = journal.as_deref_mut() {
            j.outcomes.push(oc.clone());
            j.save()?;
        }
        outcomes.push(oc);
    }
    let final_accuracy = host.accuracy(&state);
    if halving && cache.path().is_none() {
        // Session-only cache: its entries die with this call, so the
        // content-addressed snapshots backing them can never be hit
        // again — drop them instead of littering the oracle's storage.
        // (With a persistent cache they stay: a warm second run needs
        // them to serve hits without any oracle work.)
        for t in &spawned_tags {
            host.drop_search_state(t);
        }
    }
    if let Some(j) = journal.as_deref_mut() {
        j.finish();
    }
    Ok(Some(ScheduleResult {
        state,
        outcomes,
        final_accuracy,
    }))
}

/// One layer of the oracle-efficient (§4.3 + successive-halving)
/// search.  Every candidate warm-starts from the shared accepted-path
/// snapshot (`base_tag`) — never from another trial's drifted params —
/// fine-tunes in doubling rung budgets, and only the top half survives
/// each rung.  Acceptance keeps the exhaustive sweep's rule (the most
/// aggressive passing candidate wins): in the final rung the first
/// passing survivor in menu order is accepted, and in earlier rungs a
/// candidate may early-accept only when no more-aggressive candidate
/// is still alive to outrank it.
///
/// Trial accuracies are served from / recorded into `cache`, keyed by
/// `(context, target layer, cumulative steps, trial state)`; the
/// fine-tuned oracle state is snapshotted under the content-addressed
/// [`acc_cache::acc_tag`], so a cache hit whose snapshot still loads
/// costs zero oracle work, and a hit whose snapshot is gone safely
/// degrades to a recompute.
///
/// Returns `Ok(None)` when the journal trial budget runs out, else
/// `Ok(Some((accepted config, best trial accuracy)))`.
#[allow(clippy::too_many_arguments)]
fn run_layer_halving<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
    ctx: &str,
    base_tag: &mut String,
    state: &mut CompressionState,
    pos: usize,
    conv_idx: usize,
    le: &LayerEnergy,
    candidates: &[Config],
    cache: &mut AccCache,
    journal: &mut Option<&mut SearchJournal>,
    budget: &mut Option<usize>,
    spawned_tags: &mut Vec<String>,
) -> Result<Option<(Option<Config>, f64)>> {
    // Candidate restricted sets: with warm-starting, every set is a
    // pure function of the shared base parameters (no trial has
    // fine-tuned the oracle yet), so the whole menu can be built up
    // front — in parallel when the host exposes its memoized evaluator.
    let sets: Vec<WeightSet> = match host.evaluator() {
        Some(ev) => {
            let threads = sp.greedy.threads.max(1);
            let mut ratios: Vec<f64> = Vec::new();
            for c in candidates {
                if !ratios.iter().any(|r| r.to_bits() == c.prune_ratio.to_bits()) {
                    ratios.push(c.prune_ratio);
                }
            }
            let ratios_ref = &ratios;
            parallel_map(ratios.len(), threads, |j| {
                ev.usage_for_conv(conv_idx, ratios_ref[j]);
            });
            parallel_map(candidates.len(), threads, |j| {
                let cfg = candidates[j];
                let usage = ev.usage_for_conv(conv_idx, cfg.prune_ratio);
                candidate_set(&usage, le, n_conv, conv_idx, cfg, sp)
            })
        }
        None => candidates
            .iter()
            .map(|&cfg| {
                let mut trial = state.clone();
                trial.layers[conv_idx] = LayerConfig {
                    prune_ratio: cfg.prune_ratio,
                    wset: None,
                };
                let usage = host.usage(conv_idx, &trial);
                candidate_set(&usage, le, n_conv, conv_idx, cfg, sp)
            })
            .collect(),
    };
    // Full trial states (accepted path + this layer's candidate).
    let trials: Vec<CompressionState> = sets
        .iter()
        .enumerate()
        .map(|(ci, set)| {
            let mut t = state.clone();
            t.layers[conv_idx] = LayerConfig {
                prune_ratio: candidates[ci].prune_ratio,
                wset: Some(set.clone()),
            };
            t
        })
        .collect();

    let rung_steps = rung_schedule(sp.fine_tune_steps, sp.halving_rungs, sp.rung_frac);
    let n_rungs = rung_steps.len();
    let mut alive: Vec<usize> = (0..candidates.len()).collect();
    // Per-candidate key of its latest completed rung (warm-start chain).
    let mut keys: Vec<String> = vec![String::new(); candidates.len()];
    let mut cum = 0usize;
    let mut best_acc = f64::NEG_INFINITY;
    let mut chosen: Option<(usize, f64, String)> = None;
    'rungs: for (r, &steps_r) in rung_steps.iter().enumerate() {
        let is_last = r + 1 == n_rungs;
        cum += steps_r;
        let mut scored: Vec<(usize, f64)> = Vec::with_capacity(alive.len());
        for (ai, &ci) in alive.iter().enumerate() {
            if *budget == Some(0) {
                // This invocation's trial budget is exhausted; the
                // recorded trials replay from the cache on resume.
                return Ok(None);
            }
            let key = acc_cache::trial_key(ctx, sp.fine_tune_steps, conv_idx, cum, &trials[ci]);
            let tag = acc_cache::acc_tag(&key);
            let acc = match cache.get(&key) {
                // A hit only counts when the fine-tuned state behind it
                // is still restorable — the oracle must end every trial
                // holding the trial's state either way.
                Some(a) if host.load_search_state(&tag) => {
                    cache.hits += 1;
                    a
                }
                _ => {
                    cache.misses += 1;
                    let prev = if r == 0 {
                        base_tag.clone()
                    } else {
                        acc_cache::acc_tag(&keys[ci])
                    };
                    if !host.load_search_state(&prev) {
                        return Err(anyhow!(
                            "schedule halving search lost oracle snapshot `{prev}` \
                             (layer {conv_idx}, rung {r}); delete the journal/cache to restart"
                        ));
                    }
                    host.fine_tune(&trials[ci], steps_r);
                    let a = host.accuracy(&trials[ci]);
                    if !host.save_search_state(&tag) {
                        return Err(anyhow!(
                            "schedule halving search could not snapshot oracle state under `{tag}`"
                        ));
                    }
                    spawned_tags.push(tag.clone());
                    cache.put(&key, a)?;
                    a
                }
            };
            keys[ci] = key.clone();
            best_acc = best_acc.max(acc);
            if let Some(j) = journal.as_deref_mut() {
                // Replayed trials (resume) are already recorded.
                let dup = j
                    .trials
                    .iter()
                    .any(|t| t.order_pos == pos && t.cand_idx == ci && t.rung == r);
                if !dup {
                    j.trials.push(TrialRecord {
                        order_pos: pos,
                        conv_idx,
                        cand_idx: ci,
                        rung: r,
                        prune_ratio: candidates[ci].prune_ratio,
                        k_target: candidates[ci].k_target,
                        accepted: false,
                        accuracy: acc,
                        wset: sets[ci].codes().to_vec(),
                        key: key.clone(),
                    });
                    j.save()?;
                }
            }
            if let Some(b) = budget.as_mut() {
                *b -= 1;
            }
            scored.push((ci, acc));
            // Early acceptance: candidates run most-aggressive-first,
            // so a passing candidate wins as soon as no more-aggressive
            // candidate is still alive to outrank it — in the final
            // rung that is the first passing survivor, in earlier rungs
            // only the front of the alive list (which then keeps its
            // partial fine-tune budget: passing at reduced budget is a
            // stronger signal, and the saved steps are the point).
            if acc >= sp.acc0 - sp.delta && (is_last || ai == 0) {
                chosen = Some((ci, acc, key));
                break 'rungs;
            }
        }
        if is_last {
            break;
        }
        // Keep the top half by rung accuracy (ties favor the more
        // aggressive candidate), restored to menu order for the next
        // rung.
        scored.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        let keep = (scored.len() + 1) / 2;
        let mut kept: Vec<usize> = scored[..keep].iter().map(|&(ci, _)| ci).collect();
        kept.sort_unstable();
        alive = kept;
    }

    match chosen {
        Some((ci, acc, key)) => {
            let tag = acc_cache::acc_tag(&key);
            // The oracle already holds this trial's state (it was the
            // last one processed); make the invariant explicit anyway.
            if !host.load_search_state(&tag) {
                return Err(anyhow!(
                    "schedule halving search lost accepted snapshot `{tag}`"
                ));
            }
            *state = trials[ci].clone();
            *base_tag = tag;
            if let Some(j) = journal.as_deref_mut() {
                let mut dirty = false;
                for t in j.trials.iter_mut() {
                    if t.order_pos == pos && t.cand_idx == ci && t.key == key && !t.accepted {
                        t.accepted = true;
                        dirty = true;
                    }
                }
                if dirty {
                    j.save()?;
                }
            }
            Ok(Some((Some(candidates[ci]), acc)))
        }
        None => {
            // Every candidate failed: restore the shared base so the
            // rejected trials' fine-tune drift cannot leak into later
            // layers (the warm-start guarantee).
            if !host.load_search_state(base_tag) {
                return Err(anyhow!(
                    "schedule halving search lost base snapshot `{base_tag}`"
                ));
            }
            Ok(Some((None, if best_acc.is_finite() { best_acc } else { 0.0 })))
        }
    }
}

/// Table 3 baseline: one (ratio, K) configuration applied uniformly to
/// the given layers (or all), with a single global set per layer built
/// *without* the energy-prioritized ordering or per-layer search.
pub fn global_uniform<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    layers: &[usize],
    cfg: Config,
    fine_tune_steps: usize,
    naive_global_set: bool,
) -> ScheduleResult {
    let mut state = CompressionState::dense(n_conv);
    if layers.is_empty() {
        // Nothing to compress: the uniform schedule over zero layers is
        // the dense network (indexing `layers[0]` below used to panic).
        let final_accuracy = host.accuracy(&state);
        return ScheduleResult {
            state,
            outcomes: Vec::new(),
            final_accuracy,
        };
    }
    // Global usage / energy pooled across target layers.
    let mut pooled_usage = [0u64; 256];
    for &l in layers {
        let mut trial = state.clone();
        trial.layers[l].prune_ratio = cfg.prune_ratio;
        let u = host.usage(l, &trial);
        for i in 0..256 {
            pooled_usage[i] += u[i];
        }
    }
    let le0 = host.layer_energy(layers[0]);
    let set = if naive_global_set {
        crate::selection::naive_lowest_energy(&le0.table, cfg.k_target)
    } else {
        // Global variant of the selection: initial set + elimination on
        // pooled statistics, applied identically everywhere.
        let set0 = safe_initial_set(&pooled_usage, &le0, 32);
        let mut tmp_state = CompressionState::dense(n_conv);
        let gp = GreedyParams {
            k_target: cfg.k_target,
            check_every_removal: false,
            ..Default::default()
        };
        let (s, _) = greedy_backward_eliminate(
            set0,
            &pooled_usage,
            &le0,
            host,
            &mut tmp_state,
            layers[0],
            &gp,
        );
        s
    };
    for &l in layers {
        state.layers[l] = LayerConfig {
            prune_ratio: cfg.prune_ratio,
            wset: Some(set.clone()),
        };
    }
    host.fine_tune(&state, fine_tune_steps);
    let final_accuracy = host.accuracy(&state);
    let outcomes = Vec::new();
    ScheduleResult {
        state,
        outcomes,
        final_accuracy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::WeightEnergyTable;

    fn table() -> WeightEnergyTable {
        let mut e = [0.0f64; 256];
        for i in 0..256 {
            let code = (i as i32 - 128).unsigned_abs() as f64;
            e[i] = (1.0 + code) * 1e-15;
        }
        WeightEnergyTable {
            e_per_cycle: e,
            e_idle: 1e-16,
        }
    }

    /// Combined host: 3 layers with energy shares ~60/30/10 %, and an
    /// accuracy response that drops with aggressiveness but recovers a
    /// little with fine-tuning.  `snapshots` stands in for the on-disk
    /// oracle states the coordinator persists for resumable and
    /// warm-started searches (tag → tuned level), surviving simulated
    /// process death via `.clone()`.
    struct FakeHost {
        tuned: f64,
        snapshots: std::collections::HashMap<String, f64>,
        ft_total: usize,
        evals: usize,
    }

    impl FakeHost {
        fn new() -> Self {
            FakeHost {
                tuned: 0.0,
                snapshots: std::collections::HashMap::new(),
                ft_total: 0,
                evals: 0,
            }
        }
    }

    impl LayerModeler for FakeHost {
        fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy {
            let m = [192, 96, 32][conv_idx];
            LayerEnergy {
                conv_idx,
                m,
                k: 64,
                n: 64,
                table: table(),
            }
        }
        fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256] {
            let mut u = [0u64; 256];
            let pruned = (4096.0 * state.layers[conv_idx].prune_ratio) as u64;
            u[128] = pruned;
            let rest = 4096 - pruned;
            for c in 1..=64 {
                u[128 + c as usize] = rest / 128;
                u[128 - c as usize] = rest / 128;
            }
            u
        }
        fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy {
            let layers = (0..3)
                .map(|i| {
                    let le = self.layer_energy(i);
                    let usage = self.usage(i, state);
                    let e = match &state.layers[i].wset {
                        Some(s) => crate::selection::set_energy(&le, &usage, s),
                        None => le.energy_of_usage(&usage),
                    };
                    (i, e)
                })
                .collect();
            NetworkEnergy { layers }
        }
    }

    impl AccuracyOracle for FakeHost {
        fn accuracy(&mut self, state: &CompressionState) -> f64 {
            self.evals += 1;
            let mut acc = 0.95 + self.tuned;
            for l in &state.layers {
                acc -= 0.010 * l.prune_ratio;
                if let Some(s) = &l.wset {
                    acc -= 0.004 * (32.0 - s.len() as f64) / 16.0;
                }
            }
            acc
        }
        fn fine_tune(&mut self, _: &CompressionState, steps: usize) {
            self.ft_total += steps;
            self.tuned = (self.tuned + 1e-4 * steps as f64).min(0.01);
        }
        fn save_search_state(&mut self, tag: &str) -> bool {
            self.snapshots.insert(tag.to_string(), self.tuned);
            true
        }
        fn load_search_state(&mut self, tag: &str) -> bool {
            match self.snapshots.get(tag) {
                Some(&t) => {
                    self.tuned = t;
                    true
                }
                None => false,
            }
        }
        fn drop_search_state(&mut self, tag: &str) {
            self.snapshots.remove(tag);
        }
        fn ft_steps(&self) -> usize {
            self.ft_total
        }
        fn eval_count(&self) -> usize {
            self.evals
        }
    }

    #[test]
    fn processes_high_energy_layers_first_and_compresses() {
        let mut host = FakeHost::new();
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            ..Default::default()
        };
        let res = energy_prioritized(&mut host, 3, &sp);
        // Layer 0 (share 60%) processed first.
        assert_eq!(res.outcomes[0].conv_idx, 0);
        assert!(res.outcomes.iter().all(|oc| oc.accepted.is_some()));
        let top = res.outcomes[0].accepted.unwrap();
        assert_eq!(top.prune_ratio, 0.7);
        assert_eq!(top.k_target, 16);
        assert!(res.outcomes[0].energy_after < res.outcomes[0].energy_before);
    }

    #[test]
    fn tight_budget_yields_conservative_configs() {
        let mut host = FakeHost::new();
        let sp = ScheduleParams {
            acc0: 0.96,
            delta: 0.012, // very tight
            fine_tune_steps: 0,
            ..Default::default()
        };
        let res = energy_prioritized(&mut host, 3, &sp);
        let all_max = res
            .outcomes
            .iter()
            .all(|oc| matches!(oc.accepted, Some(c) if c.prune_ratio == 0.7 && c.k_target == 16));
        assert!(!all_max, "tight budget cannot accept max aggression everywhere");
    }

    #[test]
    fn journaled_search_resumes_where_it_died() {
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            ..Default::default()
        };
        // Uninterrupted reference run.
        let mut ref_host = FakeHost::new();
        let want = energy_prioritized(&mut ref_host, 3, &sp);

        let path = std::env::temp_dir()
            .join(format!("wsel_sched_journal_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Same search with a 2-trial budget: the third layer's trial
        // never runs — the "kill" model of a mid-search death.
        let mut h1 = FakeHost::new();
        let mut j1 = SearchJournal::new(path.clone(), "t").with_budget(2);
        let out = energy_prioritized_resumable(&mut h1, 3, &sp, &mut j1).unwrap();
        assert!(out.is_none(), "2-trial budget must exhaust before completion");
        assert!(path.exists(), "journal survives the aborted invocation");

        // "Process death": fresh host; only the journal file and the
        // (simulated on-disk) oracle snapshots survive.
        let mut h2 = FakeHost {
            snapshots: h1.snapshots.clone(),
            ..FakeHost::new()
        };
        let mut j2 = SearchJournal::new(path.clone(), "t");
        let got = energy_prioritized_resumable(&mut h2, 3, &sp, &mut j2)
            .unwrap()
            .expect("resumed search runs to completion");
        assert_eq!(got.to_json().to_string(), want.to_json().to_string());
        assert!(!path.exists(), "journal is deleted on completion");
    }

    #[test]
    fn global_uniform_applies_same_config() {
        let mut host = FakeHost::new();
        let res = global_uniform(
            &mut host,
            3,
            &[0, 1, 2],
            Config {
                prune_ratio: 0.5,
                k_target: 16,
            },
            5,
            false,
        );
        let s0 = res.state.layers[0].wset.clone().unwrap();
        for l in &res.state.layers {
            assert_eq!(l.prune_ratio, 0.5);
            assert_eq!(l.wset.as_ref().unwrap().codes(), s0.codes());
        }
    }

    #[test]
    fn global_uniform_empty_layer_list_returns_dense_state() {
        let mut host = FakeHost::new();
        let res = global_uniform(
            &mut host,
            3,
            &[],
            Config {
                prune_ratio: 0.5,
                k_target: 16,
            },
            5,
            false,
        );
        assert!(res
            .state
            .layers
            .iter()
            .all(|l| l.prune_ratio == 0.0 && l.wset.is_none()));
        assert!(res.outcomes.is_empty());
        assert!(res.final_accuracy > 0.9);
    }

    #[test]
    fn rejected_layer_reports_best_attempted_accuracy() {
        let mut host = FakeHost::new();
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 1e-4, // impossible budget: every candidate rejected
            fine_tune_steps: 0,
            ..Default::default()
        };
        let res = energy_prioritized(&mut host, 3, &sp);
        assert!(res.outcomes.iter().all(|oc| oc.accepted.is_none()));
        for oc in &res.outcomes {
            assert!(
                oc.accuracy_after > 0.9,
                "rejected layer must report its best attempted accuracy, not a \
                 0.0 sentinel; got {}",
                oc.accuracy_after
            );
        }
        // The JSON view (what goldens pin) carries the same values.
        let json = res.to_json().to_string();
        assert!(json.contains("accuracy_after"), "{json}");
    }

    #[test]
    fn rung_schedule_covers_budget_and_collapses_degenerate_rungs() {
        assert_eq!(rung_schedule(10, 3, 0.25), vec![3, 2, 5]);
        assert_eq!(rung_schedule(10, 1, 0.25), vec![10]);
        assert_eq!(rung_schedule(10, 0, 0.25), vec![10]);
        assert_eq!(rung_schedule(0, 3, 0.25), vec![0]);
        assert_eq!(rung_schedule(2, 4, 0.25), vec![1, 1]);
        // Out-of-range frac falls back to 1/rungs.
        assert_eq!(rung_schedule(100, 2, 0.0), vec![50, 50]);
        for (total, rungs) in [(7usize, 3usize), (50, 4), (1, 5), (13, 2)] {
            let rs = rung_schedule(total, rungs, 0.25);
            assert_eq!(rs.iter().sum::<usize>(), total, "{total}/{rungs}: {rs:?}");
            assert!(rs.iter().all(|&s| s > 0), "{total}/{rungs}: {rs:?}");
        }
    }

    #[test]
    fn warm_single_rung_matches_exhaustive_when_first_candidate_passes() {
        // With a generous budget the first (most aggressive) candidate
        // passes everywhere, so the warm-started path and the legacy
        // drift path see identical oracle states trial by trial: the
        // results and the fine-tune bill must agree bit for bit.
        let sp_ex = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            ..Default::default()
        };
        let mut h_ex = FakeHost::new();
        let want = energy_prioritized(&mut h_ex, 3, &sp_ex);
        let sp_h = ScheduleParams {
            halving_rungs: 1,
            ..sp_ex.clone()
        };
        let mut h_h = FakeHost::new();
        let got = energy_prioritized(&mut h_h, 3, &sp_h);
        assert_eq!(got.to_json().to_string(), want.to_json().to_string());
        assert_eq!(h_h.ft_total, h_ex.ft_total);
    }

    #[test]
    fn halving_early_accepts_most_aggressive_at_reduced_budget() {
        // Generous budget + 2 rungs: the most aggressive candidate
        // already passes at the first rung's partial fine-tune (3 of 10
        // steps), so each layer costs 3 steps instead of the exhaustive
        // sweep's 10.
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            halving_rungs: 2,
            ..Default::default()
        };
        let mut h = FakeHost::new();
        let res = energy_prioritized(&mut h, 3, &sp);
        assert!(res
            .outcomes
            .iter()
            .all(|oc| matches!(oc.accepted, Some(c) if c.prune_ratio == 0.7 && c.k_target == 16)));
        assert_eq!(h.ft_total, 9, "3 layers x 3 warm-started steps");
        assert!(res.final_accuracy >= sp.acc0 - sp.delta);
    }

    #[test]
    fn halving_prunes_hopeless_candidates_and_restores_base_on_reject() {
        // Impossible budget: every candidate fails at every rung.  Each
        // layer pays 9 trials x 3 steps at rung 0, keeps the top 5 for
        // rung 1 (7 steps each) = 62 steps — the exhaustive sweep would
        // pay 9 x 10 = 90.  All layers rejected, so the state stays
        // dense and the reported accuracy is the best attempt.
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.0005,
            fine_tune_steps: 10,
            halving_rungs: 2,
            ..Default::default()
        };
        let mut h = FakeHost::new();
        let res = energy_prioritized(&mut h, 3, &sp);
        assert!(res.outcomes.iter().all(|oc| oc.accepted.is_none()));
        assert!(res
            .state
            .layers
            .iter()
            .all(|l| l.prune_ratio == 0.0 && l.wset.is_none()));
        assert_eq!(h.ft_total, 3 * 62, "halving trims the hopeless menu");
        for oc in &res.outcomes {
            assert!(oc.accuracy_after > 0.9, "best attempt, not 0.0 sentinel");
        }
        // Reject-all restores the warm-start base: no drift leaks.
        assert_eq!(res.final_accuracy.to_bits(), {
            let mut probe = FakeHost::new();
            probe.accuracy(&CompressionState::dense(3)).to_bits()
        });
    }

    #[test]
    fn halving_journal_resume_replays_bit_identically() {
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.0005, // all-reject: maximum trials, maximum rungs
            fine_tune_steps: 10,
            halving_rungs: 2,
            ..Default::default()
        };
        let mut ref_host = FakeHost::new();
        let want = energy_prioritized(&mut ref_host, 3, &sp);
        // (9 rung-0 + 5 rung-1) trials x 3 layers.
        let total_trials = 42;
        for kill_after in [1usize, 5, 13, 14, 20, 41] {
            let path = std::env::temp_dir().join(format!(
                "wsel_halving_journal_{}_{kill_after}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            let mut h1 = FakeHost::new();
            let mut j1 = SearchJournal::new(path.clone(), "t").with_budget(kill_after);
            let out = energy_prioritized_resumable(&mut h1, 3, &sp, &mut j1).unwrap();
            assert!(out.is_none(), "budget {kill_after} of {total_trials} must exhaust");
            // Process death: only the journal + snapshots survive.
            let mut h2 = FakeHost {
                snapshots: h1.snapshots.clone(),
                ..FakeHost::new()
            };
            let mut j2 = SearchJournal::new(path.clone(), "t");
            let got = energy_prioritized_resumable(&mut h2, 3, &sp, &mut j2)
                .unwrap()
                .expect("resumed search runs to completion");
            assert_eq!(
                got.to_json().to_string(),
                want.to_json().to_string(),
                "kill at {kill_after}"
            );
            // Recorded trials replay as cache hits: the two invocations
            // together pay exactly the uninterrupted fine-tune bill.
            assert_eq!(
                h1.ft_total + h2.ft_total,
                ref_host.ft_total,
                "kill at {kill_after}"
            );
            assert!(!path.exists(), "journal deleted on completion");
        }
    }

    #[test]
    fn persistent_cache_second_run_pays_zero_oracle_fine_tunes() {
        let cache_path = std::env::temp_dir().join(format!(
            "wsel_sched_acc_cache_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&cache_path);
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            halving_rungs: 2,
            ..Default::default()
        };
        let mut c1 = AccCache::at(cache_path.clone()).unwrap();
        let mut h1 = FakeHost::new();
        let r1 = energy_prioritized_with(&mut h1, 3, &sp, None, Some(&mut c1))
            .unwrap()
            .unwrap();
        assert!(h1.ft_total > 0);
        assert_eq!(c1.hits, 0);
        // Second search against the warm cache + surviving snapshots.
        let mut c2 = AccCache::at(cache_path.clone()).unwrap();
        assert!(!c2.is_empty(), "cache persisted to disk");
        let mut h2 = FakeHost {
            snapshots: h1.snapshots.clone(),
            ..FakeHost::new()
        };
        let r2 = energy_prioritized_with(&mut h2, 3, &sp, None, Some(&mut c2))
            .unwrap()
            .unwrap();
        assert_eq!(r2.to_json().to_string(), r1.to_json().to_string());
        assert_eq!(h2.ft_total, 0, "warm cache: zero oracle fine-tunes");
        assert_eq!(c2.misses, 0);
        assert!(c2.hits > 0);
        std::fs::remove_file(&cache_path).unwrap();
    }
}
