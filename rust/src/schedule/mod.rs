//! Layer-wise compression schedules (paper §4.3).
//!
//! [`energy_prioritized`] is the paper's method: rank layers by energy
//! share ρ_ℓ, process in descending order, and per layer pick the most
//! aggressive (prune-ratio, K) configuration that keeps global validation
//! accuracy above `Acc₀ − δ`.  [`global_uniform`] is the ablation
//! baseline (Table 3): the same configuration applied to every layer at
//! once, layer-agnostically.

use crate::energy::cache::EnergyEvaluator;
use crate::energy::{LayerEnergy, NetworkEnergy};
use crate::quant::WeightSet;
use crate::selection::{
    greedy_backward_eliminate, safe_initial_set, AccuracyOracle, CompressionState, GreedyParams,
    LayerConfig,
};
use crate::util::threadpool::parallel_map;
use anyhow::{anyhow, Result};
use std::sync::Arc;

pub mod journal;
pub use journal::{SearchJournal, TrialRecord};

/// A candidate per-layer configuration of the §4.3 sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Config {
    pub prune_ratio: f64,
    pub k_target: usize,
}

/// Schedule hyper-parameters.
#[derive(Clone, Debug)]
pub struct ScheduleParams {
    /// Candidate pruning ratios, most aggressive first (paper: 0.7, 0.5, 0.3).
    pub prune_ratios: Vec<f64>,
    /// Candidate set sizes, most aggressive first (paper: 16, 24, 32).
    pub k_targets: Vec<usize>,
    /// Accuracy budget δ.
    pub delta: f64,
    /// Baseline accuracy Acc₀.
    pub acc0: f64,
    /// Fine-tune steps after applying each candidate config.
    pub fine_tune_steps: usize,
    /// Only process the top-`max_layers` energy layers (None = all); the
    /// remaining layers stay uncompressed, mirroring the paper's focus on
    /// the dominant blocks (Table 2).
    pub max_layers: Option<usize>,
    /// Minimum energy share ρ_ℓ for a layer to be worth compressing.
    pub min_share: f64,
    pub greedy: GreedyParams,
}

impl Default for ScheduleParams {
    fn default() -> Self {
        Self {
            prune_ratios: vec![0.7, 0.5, 0.3],
            k_targets: vec![16, 24, 32],
            delta: 0.03,
            acc0: 1.0,
            fine_tune_steps: 50,
            max_layers: None,
            min_share: 0.005,
            greedy: GreedyParams::default(),
        }
    }
}

/// Per-layer outcome for reporting (Table 2 rows).
#[derive(Clone, Debug)]
pub struct LayerOutcome {
    pub conv_idx: usize,
    pub share: f64,
    pub accepted: Option<Config>,
    pub energy_before: f64,
    pub energy_after: f64,
    pub accuracy_after: f64,
}

/// Schedule result.
#[derive(Clone, Debug)]
pub struct ScheduleResult {
    pub state: CompressionState,
    pub outcomes: Vec<LayerOutcome>,
    pub final_accuracy: f64,
}

impl ScheduleResult {
    /// Machine-readable form for the golden-file regression harness
    /// (see `testutil::golden`): the accepted per-layer configuration,
    /// every outcome row, and the final accuracy.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let state = Json::arr(self.state.layers.iter().map(|l| {
            Json::obj(vec![
                ("prune_ratio", Json::num(l.prune_ratio)),
                (
                    "wset",
                    match &l.wset {
                        Some(s) => Json::arr(
                            s.codes().iter().map(|&c| Json::num(c as f64)),
                        ),
                        None => Json::Null,
                    },
                ),
            ])
        }));
        let outcomes = Json::arr(self.outcomes.iter().map(|oc| {
            Json::obj(vec![
                ("conv_idx", Json::num(oc.conv_idx as f64)),
                ("share", Json::num(oc.share)),
                (
                    "accepted",
                    match oc.accepted {
                        Some(c) => Json::obj(vec![
                            ("prune_ratio", Json::num(c.prune_ratio)),
                            ("k_target", Json::num(c.k_target as f64)),
                        ]),
                        None => Json::Null,
                    },
                ),
                ("energy_before", Json::num(oc.energy_before)),
                ("energy_after", Json::num(oc.energy_after)),
                ("accuracy_after", Json::num(oc.accuracy_after)),
            ])
        }));
        Json::obj(vec![
            ("state", state),
            ("outcomes", outcomes),
            ("final_accuracy", Json::num(self.final_accuracy)),
        ])
    }
}

/// Callback bundle the schedule needs from the coordinator: per-layer
/// energy models and usage histograms that *reflect the current state*
/// (pruning changes usage), recomputed on demand.
pub trait LayerModeler {
    /// Energy model of layer `conv_idx`.
    fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy;
    /// Weight-code usage of the layer under `state` (mask applied,
    /// quantized, *not* yet set-restricted).
    fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256];
    /// Current per-layer energies under `state` (for ρ_ℓ and reporting).
    fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy;
    /// Shared memoized evaluator, when the host provides one — lets the
    /// schedule precompute a layer's candidate weight sets in parallel
    /// (only used when the search is oracle-free, i.e. no fine-tuning
    /// between candidates and no per-removal accuracy checks).
    fn evaluator(&mut self) -> Option<Arc<EnergyEvaluator>> {
        None
    }
}

/// Oracle stand-in for oracle-free candidate precomputation (the greedy
/// elimination never consults it when `check_every_removal` is off).
struct NeverConsulted;

impl AccuracyOracle for NeverConsulted {
    fn accuracy(&mut self, _: &CompressionState) -> f64 {
        unreachable!("oracle-free candidate precompute must not evaluate accuracy")
    }
    fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
}

/// Build the restricted set for one `(prune_ratio, k_target)` candidate
/// of `conv_idx` from a usage histogram (the §4.2 procedure, proxy
/// mode).  Pure given its inputs, which is what makes the parallel
/// precompute below bit-identical to the sequential sweep.
fn candidate_set(
    usage: &[u64; 256],
    le: &LayerEnergy,
    n_conv: usize,
    conv_idx: usize,
    cfg: Config,
    sp: &ScheduleParams,
) -> WeightSet {
    let set0 = safe_initial_set(usage, le, sp.greedy.k_init);
    let gp = GreedyParams {
        k_target: cfg.k_target,
        acc0: sp.acc0,
        delta: sp.delta,
        threads: 1, // already inside a layer-level fan-out
        ..sp.greedy.clone()
    };
    let mut tmp = CompressionState::dense(n_conv);
    let (set, _trace) = greedy_backward_eliminate(
        set0,
        usage,
        le,
        &mut NeverConsulted,
        &mut tmp,
        conv_idx,
        &gp,
    );
    set
}

/// §4.3 — energy-prioritized layer-wise compression.
///
/// `host` provides both the energy models (`LayerModeler`) and the
/// accuracy oracle — the coordinator's pipeline implements both.
pub fn energy_prioritized<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
) -> ScheduleResult {
    run_schedule(host, n_conv, sp, None)
        .expect("journal-free schedule search is infallible")
        .expect("journal-free schedule search has no trial budget")
}

/// [`energy_prioritized`] with a persistent per-candidate journal:
/// every trial is recorded (atomically, under a checksummed header)
/// before the next begins, so a search killed mid-way resumes from the
/// exact candidate it died on instead of repaying every fine-tune step
/// before it.  Returns `Ok(None)` when the journal's per-invocation
/// trial budget is exhausted — call again with a journal at the same
/// path to continue.
///
/// With fine-tuning enabled the oracle's state is snapshotted (via
/// [`AccuracyOracle::save_search_state`]) after each trial; the journal
/// and the snapshot are written in sequence, so a kill landing between
/// the two writes costs the resumed run at most one trial's fine-tune
/// drift — every completed write boundary resumes exactly.
pub fn energy_prioritized_resumable<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
    journal: &mut SearchJournal,
) -> Result<Option<ScheduleResult>> {
    run_schedule(host, n_conv, sp, Some(journal))
}

fn run_schedule<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    sp: &ScheduleParams,
    mut journal: Option<&mut SearchJournal>,
) -> Result<Option<ScheduleResult>> {
    // Key identifying the search parameters — a journal written under
    // different parameters must not be resumed.
    let meta_key = format!(
        "v1;n_conv={n_conv};ratios={:?};ks={:?};ft={};delta={};acc0={};maxl={:?};min_share={}",
        sp.prune_ratios,
        sp.k_targets,
        sp.fine_tune_steps,
        sp.delta,
        sp.acc0,
        sp.max_layers,
        sp.min_share
    );
    let mut state = CompressionState::dense(n_conv);
    let mut outcomes: Vec<LayerOutcome> = Vec::new();
    // (order position, candidate index) to resume at; None = fresh.
    let mut resume_at: Option<(usize, usize)> = None;
    // Frozen processing order: (conv_idx, energy_before, share).
    let mut order_rows: Vec<(usize, f64, f64)> = Vec::new();

    if let Some(j) = journal.as_deref_mut() {
        if j.try_load(&meta_key)? {
            // With fine-tuning, the journal's accuracy numbers are only
            // meaningful if the oracle restores the fine-tuned state
            // that produced them.
            let oracle_ok = sp.fine_tune_steps == 0 || host.load_search_state(&j.tag);
            if oracle_ok {
                order_rows = j.order.clone();
                outcomes = j.outcomes.clone();
                for t in &j.trials {
                    if t.accepted {
                        state.layers[t.conv_idx] = LayerConfig {
                            prune_ratio: t.prune_ratio,
                            wset: Some(WeightSet::new(t.wset.clone())),
                        };
                    }
                }
                let n_cands = sp.prune_ratios.len() * sp.k_targets.len();
                if let Some(t) = j.trials.last() {
                    let layer_done = t.accepted || t.cand_idx + 1 >= n_cands;
                    if layer_done && !outcomes.iter().any(|oc| oc.conv_idx == t.conv_idx) {
                        // Kill landed between the trial write and the
                        // outcome write: reconstruct the row from the
                        // recorded trial + rebuilt state.
                        let (_, e_before, share) =
                            *order_rows.get(t.order_pos).ok_or_else(|| {
                                anyhow!(
                                    "schedule journal {}: trial references order position {} out of range",
                                    j.path().display(),
                                    t.order_pos
                                )
                            })?;
                        let after = host.network_energy(&state);
                        let e_after = after
                            .layers
                            .iter()
                            .find(|(i, _)| *i == t.conv_idx)
                            .map(|(_, e)| *e)
                            .unwrap_or(e_before);
                        outcomes.push(LayerOutcome {
                            conv_idx: t.conv_idx,
                            share,
                            accepted: t.accepted.then(|| Config {
                                prune_ratio: t.prune_ratio,
                                k_target: t.k_target,
                            }),
                            energy_before: e_before,
                            energy_after: e_after,
                            accuracy_after: if t.accepted { t.accuracy } else { 0.0 },
                        });
                        j.outcomes = outcomes.clone();
                        j.save()?;
                    }
                }
                resume_at = Some(match j.trials.last() {
                    Some(t) if t.accepted || t.cand_idx + 1 >= n_cands => (t.order_pos + 1, 0),
                    Some(t) => (t.order_pos, t.cand_idx + 1),
                    None => (0, 0),
                });
                let (p, c) = resume_at.unwrap();
                crate::info!(
                    "schedule: resuming journal {} at layer position {p}, candidate {c} ({} recorded trials)",
                    j.path().display(),
                    j.trials.len()
                );
            } else {
                crate::info!(
                    "schedule journal {}: no oracle snapshot for tag `{}`; restarting search",
                    j.path().display(),
                    j.tag
                );
            }
        }
    }

    if resume_at.is_none() {
        // Fresh start: derive and FREEZE the processing order.  Params
        // drift during fine-tuning, so re-deriving the order on resume
        // could disagree with the interrupted run.
        let base = host.network_energy(&state);
        let shares = base.shares();
        let mut order = base.descending();
        if let Some(maxl) = sp.max_layers {
            order.truncate(maxl);
        }
        order_rows = order
            .into_iter()
            .map(|(conv_idx, e)| {
                let share = shares
                    .iter()
                    .find(|(i, _)| *i == conv_idx)
                    .map(|(_, s)| *s)
                    .unwrap_or(0.0);
                (conv_idx, e, share)
            })
            .collect();
        if let Some(j) = journal.as_deref_mut() {
            j.start(&meta_key, order_rows.clone());
            j.save()?;
            if sp.fine_tune_steps > 0 && !host.save_search_state(&j.tag) {
                crate::info!(
                    "schedule journal: oracle cannot snapshot state; an interrupted \
                     fine-tuning search will restart from scratch on resume"
                );
            }
        }
    }

    let (start_pos, start_cand) = resume_at.unwrap_or((0, 0));
    let mut budget = journal.as_deref().and_then(|j| j.budget);
    for (pos, &(conv_idx, e_before, share)) in order_rows.iter().enumerate() {
        if pos < start_pos || share < sp.min_share {
            continue;
        }
        let le = host.layer_energy(conv_idx);
        let mut accepted: Option<Config> = None;
        let mut acc_after = 0.0;
        // Candidate configs, most aggressive first.
        let candidates: Vec<Config> = sp
            .prune_ratios
            .iter()
            .flat_map(|&prune_ratio| {
                sp.k_targets.iter().map(move |&k_target| Config {
                    prune_ratio,
                    k_target,
                })
            })
            .collect();
        // When no fine-tuning happens between candidates and the greedy
        // elimination never consults the oracle, every candidate's
        // restricted set is a pure function of the frozen parameters —
        // build them in parallel *waves* of `threads` against the shared
        // evaluator, one wave ahead of consumption.  The wave (rather
        // than all-at-once) bound keeps the common first-candidate-
        // accepted case at one elimination of wall-clock instead of
        // eagerly paying for the whole menu.  (With fine-tuning, params
        // drift between candidates, so sets are built inline, in order.)
        let oracle_free = sp.fine_tune_steps == 0 && !sp.greedy.check_every_removal;
        let evaluator = if oracle_free { host.evaluator() } else { None };
        let mut precomputed: Vec<Option<WeightSet>> = vec![None; candidates.len()];
        let first_cand = if pos == start_pos { start_cand } else { 0 };
        for ci_cand in first_cand..candidates.len() {
            let cfg = candidates[ci_cand];
            if budget == Some(0) {
                // This invocation's trial budget is exhausted; the
                // journal already points at exactly this candidate.
                return Ok(None);
            }
            let mut trial = state.clone();
            trial.layers[conv_idx] = LayerConfig {
                prune_ratio: cfg.prune_ratio,
                wset: None,
            };
            // The restricted set for this (ratio, K): precomputed, or
            // built inline against the live oracle/params.
            let set = match &evaluator {
                Some(ev) => {
                    if precomputed[ci_cand].is_none() {
                        let threads = sp.greedy.threads.max(1);
                        let wave_end = (ci_cand + threads).min(candidates.len());
                        let wave = &candidates[ci_cand..wave_end];
                        // Pre-warm the wave's distinct prune ratios (one
                        // usage computation each, in parallel) so the
                        // candidate fan-out below hits the memo instead
                        // of racing duplicate magnitude-sorts for
                        // candidates that share a ratio.
                        let mut ratios: Vec<f64> = Vec::new();
                        for c in wave {
                            if !ratios.iter().any(|r| r.to_bits() == c.prune_ratio.to_bits()) {
                                ratios.push(c.prune_ratio);
                            }
                        }
                        let ratios_ref = &ratios;
                        parallel_map(ratios.len(), threads, |j| {
                            ev.usage_for_conv(conv_idx, ratios_ref[j]);
                        });
                        let le_ref = &le;
                        let sets = parallel_map(wave.len(), threads, |j| {
                            let cfg = wave[j];
                            let usage = ev.usage_for_conv(conv_idx, cfg.prune_ratio);
                            candidate_set(&usage, le_ref, n_conv, conv_idx, cfg, sp)
                        });
                        for (j, s) in sets.into_iter().enumerate() {
                            precomputed[ci_cand + j] = Some(s);
                        }
                    }
                    precomputed[ci_cand].clone().expect("wave fill")
                }
                None => {
                    let usage = host.usage(conv_idx, &trial);
                    let set0 = safe_initial_set(&usage, &le, sp.greedy.k_init);
                    let gp = GreedyParams {
                        k_target: cfg.k_target,
                        acc0: sp.acc0,
                        delta: sp.delta,
                        ..sp.greedy.clone()
                    };
                    let (set, _trace) = greedy_backward_eliminate(
                        set0,
                        &usage,
                        &le,
                        host,
                        &mut trial,
                        conv_idx,
                        &gp,
                    );
                    set
                }
            };
            let set_codes = journal.is_some().then(|| set.codes().to_vec());
            trial.layers[conv_idx].wset = Some(set);
            // Short fine-tune then global accuracy check (§4.3 step 3).
            host.fine_tune(&trial, sp.fine_tune_steps);
            let acc = host.accuracy(&trial);
            let ok = acc >= sp.acc0 - sp.delta;
            if ok {
                state = trial;
                accepted = Some(cfg);
                acc_after = acc;
            }
            if let Some(j) = journal.as_deref_mut() {
                j.trials.push(TrialRecord {
                    order_pos: pos,
                    conv_idx,
                    cand_idx: ci_cand,
                    prune_ratio: cfg.prune_ratio,
                    k_target: cfg.k_target,
                    accepted: ok,
                    accuracy: acc,
                    wset: set_codes.unwrap_or_default(),
                });
                j.save()?;
                // Snapshot the oracle right after its state moved, so a
                // resume replays this trial's effects exactly.
                if sp.fine_tune_steps > 0 {
                    host.save_search_state(&j.tag);
                }
            }
            if let Some(b) = budget.as_mut() {
                *b -= 1;
            }
            if ok {
                break;
            }
        }
        let after = host.network_energy(&state);
        let e_after = after
            .layers
            .iter()
            .find(|(i, _)| *i == conv_idx)
            .map(|(_, e)| *e)
            .unwrap_or(e_before);
        let oc = LayerOutcome {
            conv_idx,
            share,
            accepted,
            energy_before: e_before,
            energy_after: e_after,
            accuracy_after: acc_after,
        };
        if let Some(j) = journal.as_deref_mut() {
            j.outcomes.push(oc.clone());
            j.save()?;
        }
        outcomes.push(oc);
    }
    let final_accuracy = host.accuracy(&state);
    if let Some(j) = journal.as_deref_mut() {
        j.finish();
    }
    Ok(Some(ScheduleResult {
        state,
        outcomes,
        final_accuracy,
    }))
}

/// Table 3 baseline: one (ratio, K) configuration applied uniformly to
/// the given layers (or all), with a single global set per layer built
/// *without* the energy-prioritized ordering or per-layer search.
pub fn global_uniform<H: LayerModeler + AccuracyOracle>(
    host: &mut H,
    n_conv: usize,
    layers: &[usize],
    cfg: Config,
    fine_tune_steps: usize,
    naive_global_set: bool,
) -> ScheduleResult {
    let mut state = CompressionState::dense(n_conv);
    // Global usage / energy pooled across target layers.
    let mut pooled_usage = [0u64; 256];
    for &l in layers {
        let mut trial = state.clone();
        trial.layers[l].prune_ratio = cfg.prune_ratio;
        let u = host.usage(l, &trial);
        for i in 0..256 {
            pooled_usage[i] += u[i];
        }
    }
    let le0 = host.layer_energy(layers[0]);
    let set = if naive_global_set {
        crate::selection::naive_lowest_energy(&le0.table, cfg.k_target)
    } else {
        // Global variant of the selection: initial set + elimination on
        // pooled statistics, applied identically everywhere.
        let set0 = safe_initial_set(&pooled_usage, &le0, 32);
        let mut tmp_state = CompressionState::dense(n_conv);
        let gp = GreedyParams {
            k_target: cfg.k_target,
            check_every_removal: false,
            ..Default::default()
        };
        let (s, _) = greedy_backward_eliminate(
            set0,
            &pooled_usage,
            &le0,
            host,
            &mut tmp_state,
            layers[0],
            &gp,
        );
        s
    };
    for &l in layers {
        state.layers[l] = LayerConfig {
            prune_ratio: cfg.prune_ratio,
            wset: Some(set.clone()),
        };
    }
    host.fine_tune(&state, fine_tune_steps);
    let final_accuracy = host.accuracy(&state);
    let outcomes = Vec::new();
    ScheduleResult {
        state,
        outcomes,
        final_accuracy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::WeightEnergyTable;

    fn table() -> WeightEnergyTable {
        let mut e = [0.0f64; 256];
        for i in 0..256 {
            let code = (i as i32 - 128).unsigned_abs() as f64;
            e[i] = (1.0 + code) * 1e-15;
        }
        WeightEnergyTable {
            e_per_cycle: e,
            e_idle: 1e-16,
        }
    }

    /// Combined host: 3 layers with energy shares ~60/30/10 %, and an
    /// accuracy response that drops with aggressiveness but recovers a
    /// little with fine-tuning.  `snapshot` stands in for the on-disk
    /// oracle state the coordinator persists for resumable searches.
    struct FakeHost {
        tuned: f64,
        snapshot: Option<f64>,
    }

    impl FakeHost {
        fn new() -> Self {
            FakeHost {
                tuned: 0.0,
                snapshot: None,
            }
        }
    }

    impl LayerModeler for FakeHost {
        fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy {
            let m = [192, 96, 32][conv_idx];
            LayerEnergy {
                conv_idx,
                m,
                k: 64,
                n: 64,
                table: table(),
            }
        }
        fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256] {
            let mut u = [0u64; 256];
            let pruned = (4096.0 * state.layers[conv_idx].prune_ratio) as u64;
            u[128] = pruned;
            let rest = 4096 - pruned;
            for c in 1..=64 {
                u[128 + c as usize] = rest / 128;
                u[128 - c as usize] = rest / 128;
            }
            u
        }
        fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy {
            let layers = (0..3)
                .map(|i| {
                    let le = self.layer_energy(i);
                    let usage = self.usage(i, state);
                    let e = match &state.layers[i].wset {
                        Some(s) => crate::selection::set_energy(&le, &usage, s),
                        None => le.energy_of_usage(&usage),
                    };
                    (i, e)
                })
                .collect();
            NetworkEnergy { layers }
        }
    }

    impl AccuracyOracle for FakeHost {
        fn accuracy(&mut self, state: &CompressionState) -> f64 {
            let mut acc = 0.95 + self.tuned;
            for l in &state.layers {
                acc -= 0.010 * l.prune_ratio;
                if let Some(s) = &l.wset {
                    acc -= 0.004 * (32.0 - s.len() as f64) / 16.0;
                }
            }
            acc
        }
        fn fine_tune(&mut self, _: &CompressionState, steps: usize) {
            self.tuned = (self.tuned + 1e-4 * steps as f64).min(0.01);
        }
        fn save_search_state(&mut self, _tag: &str) -> bool {
            self.snapshot = Some(self.tuned);
            true
        }
        fn load_search_state(&mut self, _tag: &str) -> bool {
            match self.snapshot {
                Some(t) => {
                    self.tuned = t;
                    true
                }
                None => false,
            }
        }
    }

    #[test]
    fn processes_high_energy_layers_first_and_compresses() {
        let mut host = FakeHost::new();
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            ..Default::default()
        };
        let res = energy_prioritized(&mut host, 3, &sp);
        // Layer 0 (share 60%) processed first.
        assert_eq!(res.outcomes[0].conv_idx, 0);
        assert!(res.outcomes.iter().all(|oc| oc.accepted.is_some()));
        let top = res.outcomes[0].accepted.unwrap();
        assert_eq!(top.prune_ratio, 0.7);
        assert_eq!(top.k_target, 16);
        assert!(res.outcomes[0].energy_after < res.outcomes[0].energy_before);
    }

    #[test]
    fn tight_budget_yields_conservative_configs() {
        let mut host = FakeHost::new();
        let sp = ScheduleParams {
            acc0: 0.96,
            delta: 0.012, // very tight
            fine_tune_steps: 0,
            ..Default::default()
        };
        let res = energy_prioritized(&mut host, 3, &sp);
        let all_max = res
            .outcomes
            .iter()
            .all(|oc| matches!(oc.accepted, Some(c) if c.prune_ratio == 0.7 && c.k_target == 16));
        assert!(!all_max, "tight budget cannot accept max aggression everywhere");
    }

    #[test]
    fn journaled_search_resumes_where_it_died() {
        let sp = ScheduleParams {
            acc0: 0.95,
            delta: 0.05,
            fine_tune_steps: 10,
            ..Default::default()
        };
        // Uninterrupted reference run.
        let mut ref_host = FakeHost::new();
        let want = energy_prioritized(&mut ref_host, 3, &sp);

        let path = std::env::temp_dir()
            .join(format!("wsel_sched_journal_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Same search with a 2-trial budget: the third layer's trial
        // never runs — the "kill" model of a mid-search death.
        let mut h1 = FakeHost::new();
        let mut j1 = SearchJournal::new(path.clone(), "t").with_budget(2);
        let out = energy_prioritized_resumable(&mut h1, 3, &sp, &mut j1).unwrap();
        assert!(out.is_none(), "2-trial budget must exhaust before completion");
        assert!(path.exists(), "journal survives the aborted invocation");

        // "Process death": fresh host; only the journal file and the
        // (simulated on-disk) oracle snapshot survive.
        let mut h2 = FakeHost {
            tuned: 0.0,
            snapshot: h1.snapshot,
        };
        let mut j2 = SearchJournal::new(path.clone(), "t");
        let got = energy_prioritized_resumable(&mut h2, 3, &sp, &mut j2)
            .unwrap()
            .expect("resumed search runs to completion");
        assert_eq!(got.to_json().to_string(), want.to_json().to_string());
        assert!(!path.exists(), "journal is deleted on completion");
    }

    #[test]
    fn global_uniform_applies_same_config() {
        let mut host = FakeHost::new();
        let res = global_uniform(
            &mut host,
            3,
            &[0, 1, 2],
            Config {
                prune_ratio: 0.5,
                k_target: 16,
            },
            5,
            false,
        );
        let s0 = res.state.layers[0].wset.clone().unwrap();
        for l in &res.state.layers {
            assert_eq!(l.prune_ratio, 0.5);
            assert_eq!(l.wset.as_ref().unwrap().codes(), s0.codes());
        }
    }
}
