//! Persistent accuracy cache for the oracle-efficient §4.3 search.
//!
//! Every warm-started trial of the successive-halving schedule search
//! is a pure function of `(search context, trial compression state,
//! cumulative fine-tune steps)` — the candidate fine-tunes from the
//! shared accepted-path snapshot, never from another trial's drifted
//! params.  That makes its measured accuracy cacheable: [`AccCache`]
//! stores `key hash → accuracy` (checksummed artifact JSON via
//! [`crate::util::artifact`]), so repeated searches and `--resume` runs
//! skip the oracle entirely on hits.
//!
//! A cache hit only *fully* replaces the oracle call when the trial's
//! fine-tuned state snapshot (saved under the content-addressed tag
//! [`acc_tag`]) is still loadable — the search re-validates that at hit
//! time, so a cache that outlives its snapshots degrades to a miss
//! instead of silently continuing from the wrong parameters.

use crate::selection::CompressionState;
use crate::util::artifact;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// FNV-1a 64-bit — the cache's stable, dependency-free key hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Canonical digest of a compression state: per layer, the prune-ratio
/// bits and the restricted set's codes.  Two states digest equal iff
/// they are config-identical, which (under a fixed search context) is
/// what makes warm-started trial accuracies reusable.
pub fn state_digest(state: &CompressionState) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for l in &state.layers {
        let _ = write!(s, "{:x}:", l.prune_ratio.to_bits());
        if let Some(w) = &l.wset {
            for &c in w.codes() {
                let _ = write!(s, "{c},");
            }
        }
        s.push(';');
    }
    s
}

/// Hex cache key for one warm-started trial: context + fine-tune recipe
/// + target layer + cumulative fine-tune steps + full trial state.
pub fn trial_key(
    ctx: &str,
    fine_tune_steps: usize,
    conv_idx: usize,
    cum_steps: usize,
    trial: &CompressionState,
) -> String {
    let s = format!(
        "{ctx}|ft={fine_tune_steps}|conv={conv_idx}|steps={cum_steps}|{}",
        state_digest(trial)
    );
    format!("{:016x}", fnv1a64(s.as_bytes()))
}

/// Hex key of the accepted-path base state (the shared warm-start
/// point): context + fine-tune recipe + accepted state, no candidate.
pub fn path_key(ctx: &str, fine_tune_steps: usize, state: &CompressionState) -> String {
    let s = format!("{ctx}|ft={fine_tune_steps}|path|{}", state_digest(state));
    format!("{:016x}", fnv1a64(s.as_bytes()))
}

/// Oracle snapshot tag for a cache key — content-addressed, so a second
/// search (or a resumed one) recomputes the same tag and finds the
/// fine-tuned state on disk.
pub fn acc_tag(key_hex: &str) -> String {
    format!("acc-{key_hex}")
}

/// The persistent (or session-only) accuracy cache.
pub struct AccCache {
    path: Option<PathBuf>,
    entries: BTreeMap<String, f64>,
    /// Hits/misses served this session (cost accounting for benches).
    pub hits: usize,
    pub misses: usize,
}

impl AccCache {
    /// In-memory cache for a single search invocation (always used when
    /// the caller does not pass one — journal resume seeds it from the
    /// recorded trials).
    pub fn ephemeral() -> Self {
        Self {
            path: None,
            entries: BTreeMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Open (or create) a persistent cache at `path`.  A corrupt file
    /// is an error naming the path — never silently consumed.
    pub fn at(path: PathBuf) -> Result<Self> {
        let mut c = Self {
            path: Some(path.clone()),
            entries: BTreeMap::new(),
            hits: 0,
            misses: 0,
        };
        if path.exists() {
            let json = artifact::load_json(&path)
                .with_context(|| format!("accuracy cache {}", path.display()))?;
            let bad = || anyhow!("accuracy cache {}: malformed entries", path.display());
            let entries = json.get("entries").ok_or_else(bad)?;
            match entries {
                Json::Obj(m) => {
                    for (k, v) in m {
                        c.entries
                            .insert(k.clone(), v.as_f64().ok_or_else(bad)?);
                    }
                }
                _ => return Err(bad()),
            }
        }
        Ok(c)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Look up a trial accuracy by hex key (does not touch hit/miss
    /// counters — the search does, after snapshot revalidation).
    pub fn get(&self, key_hex: &str) -> Option<f64> {
        self.entries.get(key_hex).copied()
    }

    /// Record a trial accuracy; persistent caches are rewritten
    /// atomically on every put, so a killed search loses at most the
    /// in-flight entry.
    pub fn put(&mut self, key_hex: &str, acc: f64) -> Result<()> {
        self.entries.insert(key_hex.to_string(), acc);
        self.save()
    }

    fn save(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let entries = Json::Obj(
            self.entries
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v)))
                .collect(),
        );
        let json = Json::obj(vec![("version", Json::num(1.0)), ("entries", entries)]);
        artifact::write_json_atomic(path, &json)
            .with_context(|| format!("writing accuracy cache {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::WeightSet;
    use crate::selection::LayerConfig;

    #[test]
    fn digest_distinguishes_configs() {
        let mut a = CompressionState::dense(2);
        let b = a.clone();
        a.layers[1] = LayerConfig {
            prune_ratio: 0.5,
            wset: Some(WeightSet::new(vec![-3, 0, 3])),
        };
        assert_ne!(state_digest(&a), state_digest(&b));
        assert_ne!(
            trial_key("ctx", 10, 1, 5, &a),
            trial_key("ctx", 10, 1, 5, &b)
        );
        // Same config, different cumulative budget → different key.
        assert_ne!(
            trial_key("ctx", 10, 1, 5, &a),
            trial_key("ctx", 10, 1, 10, &a)
        );
        // Different context → different key.
        assert_ne!(
            trial_key("x", 10, 1, 5, &a),
            trial_key("y", 10, 1, 5, &a)
        );
    }

    #[test]
    fn persistent_roundtrip_and_corruption() {
        let path = std::env::temp_dir()
            .join(format!("wsel_acc_cache_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut c = AccCache::at(path.clone()).unwrap();
        assert!(c.is_empty());
        c.put("00ff", 0.912345).unwrap();
        c.put("01aa", 0.5).unwrap();
        let c2 = AccCache::at(path.clone()).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get("00ff").unwrap().to_bits(), 0.912345f64.to_bits());
        assert_eq!(c2.get("missing"), None);
        // Corruption is surfaced with the path, not consumed.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:?}", AccCache::at(path.clone()).unwrap_err());
        assert!(err.contains(&path.display().to_string()), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn ephemeral_never_writes() {
        let mut c = AccCache::ephemeral();
        c.put("aa", 1.0).unwrap();
        assert_eq!(c.get("aa"), Some(1.0));
        assert!(c.path().is_none());
    }
}
