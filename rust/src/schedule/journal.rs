//! Persistent per-candidate journal for the §4.3 schedule search.
//!
//! Every candidate `(prune ratio, K)` trial of
//! [`super::energy_prioritized_resumable`] is appended here and the
//! journal is rewritten atomically (checksummed artifact), so a search
//! killed mid-way resumes from the exact candidate it died on instead of
//! repaying every fine-tune step before it.  The journal records:
//!
//! * the **frozen processing order** (conv_idx, energy-before, share per
//!   layer) captured at the original start — params drift during
//!   fine-tuning, so re-deriving the order on resume could diverge from
//!   the interrupted run;
//! * one [`TrialRecord`] per evaluated candidate (accepted or not, with
//!   the restricted set's codes, so accepted layers rebuild exactly);
//! * the completed [`LayerOutcome`] rows, replayed verbatim on resume.
//!
//! Oracle state (the fine-tuned params behind the accuracy numbers) is
//! persisted through [`crate::selection::AccuracyOracle`]'s
//! `save_search_state`/`load_search_state` hooks, keyed by the journal's
//! `tag` — the coordinator pipeline backs them with runtime state
//! snapshots.

use super::LayerOutcome;
use crate::util::artifact;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One evaluated schedule candidate.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    /// Position in the frozen energy-descending processing order.
    pub order_pos: usize,
    pub conv_idx: usize,
    /// Index into the layer's (ratio × K) candidate menu.
    pub cand_idx: usize,
    /// Successive-halving rung this trial ran at (0 in the legacy
    /// exhaustive mode — every candidate gets the full budget at once).
    pub rung: usize,
    pub prune_ratio: f64,
    pub k_target: usize,
    pub accepted: bool,
    /// Global accuracy measured for this trial.
    pub accuracy: f64,
    /// Codes of the trial's restricted weight set.
    pub wset: Vec<i32>,
    /// Hex accuracy-cache key of this trial (empty in legacy mode).
    /// Resume seeds the session cache from it, and
    /// [`crate::schedule::acc_cache::acc_tag`] of it names the oracle
    /// snapshot holding the trial's fine-tuned state.
    pub key: String,
}

/// On-disk journal of a resumable schedule search.
pub struct SearchJournal {
    path: PathBuf,
    /// Tag under which the oracle snapshots its state (see module docs).
    pub tag: String,
    /// Max candidate trials to run in THIS invocation (`None` =
    /// unlimited).  Exhausting it makes the search return `None` with
    /// the journal positioned to resume — the kill model of the
    /// resume tests, and a bounded-work knob for long searches.
    pub budget: Option<usize>,
    /// Frozen processing order: `(conv_idx, energy_before, share)`.
    pub order: Vec<(usize, f64, f64)>,
    pub trials: Vec<TrialRecord>,
    /// Outcome rows of layers completed in earlier invocations.
    pub outcomes: Vec<LayerOutcome>,
    meta_key: String,
}

impl SearchJournal {
    pub fn new(path: PathBuf, tag: &str) -> Self {
        Self {
            path,
            tag: tag.to_string(),
            budget: None,
            order: Vec::new(),
            trials: Vec::new(),
            outcomes: Vec::new(),
            meta_key: String::new(),
        }
    }

    /// Limit this invocation to `budget` candidate trials.
    pub fn with_budget(mut self, budget: usize) -> Self {
        self.budget = Some(budget);
        self
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Begin a fresh search: record the meta key + frozen order, drop
    /// any stale trial state.
    pub(crate) fn start(&mut self, meta_key: &str, order: Vec<(usize, f64, f64)>) {
        self.meta_key = meta_key.to_string();
        self.order = order;
        self.trials.clear();
        self.outcomes.clear();
    }

    /// Load an existing journal if it matches `meta_key` (same search
    /// parameters).  `Ok(false)` when absent or for different
    /// parameters; `Err` (path + reason) when the file is corrupt or
    /// structurally invalid — never silently consumed.
    pub(crate) fn try_load(&mut self, meta_key: &str) -> Result<bool> {
        self.meta_key = meta_key.to_string();
        if !self.path.exists() {
            return Ok(false);
        }
        let json = artifact::load_json(&self.path)
            .with_context(|| format!("schedule journal {}", self.path.display()))?;
        if json.get("meta").and_then(Json::as_str) != Some(meta_key) {
            crate::info!(
                "schedule journal {}: different search parameters; starting fresh",
                self.path.display()
            );
            return Ok(false);
        }
        let what = format!("schedule journal {}", self.path.display());
        let bad = |field: &str| anyhow!("{what}: missing or malformed `{field}`");

        let order = json.get("order").and_then(Json::as_arr).ok_or_else(|| bad("order"))?;
        self.order = order
            .iter()
            .map(|row| {
                let r = row.as_arr().filter(|r| r.len() == 3).ok_or_else(|| bad("order row"))?;
                Ok((
                    r[0].as_usize().ok_or_else(|| bad("order conv_idx"))?,
                    r[1].as_f64().ok_or_else(|| bad("order energy"))?,
                    r[2].as_f64().ok_or_else(|| bad("order share"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;

        let trials = json.get("trials").and_then(Json::as_arr).ok_or_else(|| bad("trials"))?;
        self.trials = trials
            .iter()
            .map(|t| {
                let codes = t
                    .get("wset")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| bad("trial wset"))?
                    .iter()
                    .map(|c| c.as_f64().map(|v| v as i32).ok_or_else(|| bad("trial wset code")))
                    .collect::<Result<Vec<i32>>>()?;
                Ok(TrialRecord {
                    order_pos: t.get("order_pos").and_then(Json::as_usize).ok_or_else(|| bad("trial order_pos"))?,
                    conv_idx: t.get("conv_idx").and_then(Json::as_usize).ok_or_else(|| bad("trial conv_idx"))?,
                    cand_idx: t.get("cand_idx").and_then(Json::as_usize).ok_or_else(|| bad("trial cand_idx"))?,
                    rung: t.get("rung").and_then(Json::as_usize).ok_or_else(|| bad("trial rung"))?,
                    prune_ratio: t.get("prune_ratio").and_then(Json::as_f64).ok_or_else(|| bad("trial prune_ratio"))?,
                    k_target: t.get("k_target").and_then(Json::as_usize).ok_or_else(|| bad("trial k_target"))?,
                    accepted: t.get("accepted").and_then(Json::as_bool).ok_or_else(|| bad("trial accepted"))?,
                    accuracy: t.get("accuracy").and_then(Json::as_f64).ok_or_else(|| bad("trial accuracy"))?,
                    wset: codes,
                    key: t.get("key").and_then(Json::as_str).unwrap_or("").to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let outcomes =
            json.get("outcomes").and_then(Json::as_arr).ok_or_else(|| bad("outcomes"))?;
        self.outcomes = outcomes
            .iter()
            .map(|oc| {
                let accepted = match oc.get("accepted") {
                    Some(Json::Null) | None => None,
                    Some(c) => Some(super::Config {
                        prune_ratio: c.get("prune_ratio").and_then(Json::as_f64).ok_or_else(|| bad("outcome prune_ratio"))?,
                        k_target: c.get("k_target").and_then(Json::as_usize).ok_or_else(|| bad("outcome k_target"))?,
                    }),
                };
                Ok(LayerOutcome {
                    conv_idx: oc.get("conv_idx").and_then(Json::as_usize).ok_or_else(|| bad("outcome conv_idx"))?,
                    share: oc.get("share").and_then(Json::as_f64).ok_or_else(|| bad("outcome share"))?,
                    accepted,
                    energy_before: oc.get("energy_before").and_then(Json::as_f64).ok_or_else(|| bad("outcome energy_before"))?,
                    energy_after: oc.get("energy_after").and_then(Json::as_f64).ok_or_else(|| bad("outcome energy_after"))?,
                    accuracy_after: oc.get("accuracy_after").and_then(Json::as_f64).ok_or_else(|| bad("outcome accuracy_after"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(true)
    }

    /// Atomically rewrite the journal file.
    pub(crate) fn save(&self) -> Result<()> {
        artifact::write_json_atomic(&self.path, &self.to_json())
            .with_context(|| format!("writing schedule journal {}", self.path.display()))
    }

    /// The search completed: the journal is no longer needed.
    pub(crate) fn finish(&self) {
        let _ = std::fs::remove_file(&self.path);
    }

    fn to_json(&self) -> Json {
        let order = Json::arr(self.order.iter().map(|&(ci, e, s)| {
            Json::arr(vec![Json::num(ci as f64), Json::num(e), Json::num(s)])
        }));
        let trials = Json::arr(self.trials.iter().map(|t| {
            Json::obj(vec![
                ("order_pos", Json::num(t.order_pos as f64)),
                ("conv_idx", Json::num(t.conv_idx as f64)),
                ("cand_idx", Json::num(t.cand_idx as f64)),
                ("rung", Json::num(t.rung as f64)),
                ("prune_ratio", Json::num(t.prune_ratio)),
                ("k_target", Json::num(t.k_target as f64)),
                ("accepted", Json::Bool(t.accepted)),
                ("accuracy", Json::num(t.accuracy)),
                ("wset", Json::arr(t.wset.iter().map(|&c| Json::num(c as f64)))),
                ("key", Json::str(&t.key)),
            ])
        }));
        let outcomes = Json::arr(self.outcomes.iter().map(|oc| {
            Json::obj(vec![
                ("conv_idx", Json::num(oc.conv_idx as f64)),
                ("share", Json::num(oc.share)),
                (
                    "accepted",
                    match oc.accepted {
                        Some(c) => Json::obj(vec![
                            ("prune_ratio", Json::num(c.prune_ratio)),
                            ("k_target", Json::num(c.k_target as f64)),
                        ]),
                        None => Json::Null,
                    },
                ),
                ("energy_before", Json::num(oc.energy_before)),
                ("energy_after", Json::num(oc.energy_after)),
                ("accuracy_after", Json::num(oc.accuracy_after)),
            ])
        }));
        Json::obj(vec![
            ("meta", Json::str(&self.meta_key)),
            ("order", order),
            ("trials", trials),
            ("outcomes", outcomes),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wsel_journal_{tag}_{}.json", std::process::id()))
    }

    fn sample() -> SearchJournal {
        let mut j = SearchJournal::new(tmp("roundtrip"), "t");
        j.start("key1", vec![(0, 2.0e-9, 0.6), (2, 1.0e-9, 0.4)]);
        j.trials.push(TrialRecord {
            order_pos: 0,
            conv_idx: 0,
            cand_idx: 1,
            rung: 2,
            prune_ratio: 0.5,
            k_target: 24,
            accepted: true,
            accuracy: 0.94321,
            wset: vec![-96, -32, 0, 32, 96],
            key: "00deadbeef00f00d".to_string(),
        });
        j.outcomes.push(LayerOutcome {
            conv_idx: 0,
            share: 0.6,
            accepted: Some(super::super::Config {
                prune_ratio: 0.5,
                k_target: 24,
            }),
            energy_before: 2.0e-9,
            energy_after: 1.5e-9,
            accuracy_after: 0.94321,
        });
        j
    }

    #[test]
    fn roundtrips_exactly() {
        let j = sample();
        j.save().unwrap();
        let mut k = SearchJournal::new(j.path().to_path_buf(), "t");
        assert!(k.try_load("key1").unwrap());
        assert_eq!(k.order, j.order);
        assert_eq!(k.trials.len(), 1);
        let (a, b) = (&k.trials[0], &j.trials[0]);
        assert_eq!((a.order_pos, a.conv_idx, a.cand_idx), (0, 0, 1));
        assert_eq!((a.rung, a.key.as_str()), (2, "00deadbeef00f00d"));
        assert_eq!(a.wset, b.wset);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(k.outcomes.len(), 1);
        assert_eq!(
            k.outcomes[0].energy_after.to_bits(),
            j.outcomes[0].energy_after.to_bits()
        );
        j.finish();
        assert!(!j.path().exists());
    }

    #[test]
    fn meta_mismatch_starts_fresh() {
        let j = sample();
        let path = tmp("meta");
        let mut j2 = SearchJournal::new(path.clone(), "t");
        j2.start("key1", j.order.clone());
        j2.save().unwrap();
        let mut k = SearchJournal::new(path.clone(), "t");
        assert!(!k.try_load("other-key").unwrap());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_journal_is_rejected_with_path() {
        let j = sample();
        let path = tmp("corrupt");
        let mut j2 = SearchJournal::new(path.clone(), "t");
        j2.start("key1", j.order.clone());
        j2.trials = j.trials.clone();
        j2.save().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        let mut k = SearchJournal::new(path.clone(), "t");
        let err = format!("{:?}", k.try_load("key1").unwrap_err());
        assert!(err.contains("checksum mismatch") || err.contains("parse"), "{err}");
        assert!(err.contains(&path.display().to_string()), "{err}");
        std::fs::remove_file(&path).unwrap();
    }
}
