//! End-to-end compression pipeline (the L3 coordinator).
//!
//! Owns the training/eval runtime (AOT-PJRT or the native
//! batch-parallel backend, selected by `PipelineParams::backend`), the
//! int8 mirror engine, the gate-level energy substrate and the
//! compression algorithms, and drives the paper's full flow: QAT
//! training → calibration → per-layer statistics → per-weight energy
//! characterization → energy-prioritized layer-wise compression →
//! reporting.  It implements [`LayerModeler`] + [`AccuracyOracle`] so
//! the §4 algorithms run against the real system — offline and
//! multi-threaded on the native backend.

use crate::data::Split;
use crate::energy::cache::{EnergyEvaluator, EvalLayer};
use crate::energy::{characterize_layer_shared, LayerEnergy, NetworkEnergy, WeightEnergyTable};
use crate::gates::CapModel;
use crate::model::{CaptureSink, ParallelEngine, QuantConfig};
use crate::quant;
use crate::runtime::{BackendChoice, LrSchedule, ModelRuntime, ResumeOpts};
use crate::schedule::{
    energy_prioritized_with, AccCache, ScheduleParams, ScheduleResult, SearchJournal,
};
use crate::selection::{AccuracyOracle, CompressionState};
use crate::stats::{LayerStats, StatsSink};
use crate::systolic::MacLib;
use crate::util::threadpool::parallel_map;
use anyhow::Result;
use std::cell::RefCell;
use std::sync::Arc;

/// Pipeline hyper-parameters (scaled presets below).
#[derive(Clone, Debug)]
pub struct PipelineParams {
    /// Float pre-training steps.
    pub float_steps: usize,
    /// QAT steps after calibration.
    pub qat_steps: usize,
    pub lr: LrSchedule,
    /// Calibration batches (stats + act scales).
    pub calib_batches: usize,
    /// Validation batches per accuracy measurement.
    pub val_batches: usize,
    /// Synthetic trace length for per-weight characterization.
    pub trace_len: usize,
    /// Images used for capture-based statistics.
    pub stats_images: usize,
    pub threads: usize,
    pub seed: u64,
    /// Dataset seed shared by every driver (train/eval/calib batches);
    /// `--data-seed` on the CLI.  Historically hard-coded to 7 inside
    /// the runtime.
    pub data_seed: u64,
    /// Which training/eval backend to run (AOT-PJRT, native, or pick
    /// automatically); `--backend` on the CLI.
    pub backend: BackendChoice,
    /// Checkpoint training every N steps (0 = off) and resume
    /// interrupted phases from the last checkpoint; also arms the
    /// bounded divergence rollback (see
    /// [`crate::runtime::ResumeOpts`]).  `--ckpt-every` on the CLI.
    pub ckpt_every: usize,
    /// Kernel backend to force (`--kernels` on the CLI; `None` = auto
    /// detection / `WSEL_KERNELS`).  All backends are bit-identical, so
    /// this only changes speed, never results.
    pub kernels: Option<crate::model::KernelKind>,
}

impl Default for PipelineParams {
    fn default() -> Self {
        Self {
            float_steps: 1500,
            qat_steps: 600,
            lr: LrSchedule::default(),
            calib_batches: 2,
            val_batches: 4,
            trace_len: 512,
            stats_images: 8,
            threads: crate::util::threadpool::default_threads(),
            seed: 20250710,
            data_seed: ModelRuntime::DEFAULT_DATA_SEED,
            backend: BackendChoice::Auto,
            ckpt_every: 0,
            kernels: None,
        }
    }
}

impl PipelineParams {
    /// Small preset for benches / smoke tests.
    pub fn quick() -> Self {
        Self {
            float_steps: 120,
            qat_steps: 40,
            calib_batches: 1,
            val_batches: 1,
            trace_len: 128,
            stats_images: 2,
            ..Default::default()
        }
    }
}

/// The end-to-end pipeline.
pub struct Pipeline {
    pub rt: ModelRuntime,
    pub pp: PipelineParams,
    pub cap_model: CapModel,
    pub maclib: MacLib,
    /// Per-conv statistics (after `profile`).
    pub stats: Vec<LayerStats>,
    /// Per-conv energy tables (after `profile`).
    pub tables: Vec<WeightEnergyTable>,
    /// Baseline (uncompressed, quantized) accuracy.
    pub acc0: f64,
    /// Baseline network energy.
    pub base_energy: Option<NetworkEnergy>,
    pub eval_count: usize,
    pub ft_steps_total: usize,
    /// Bumped whenever `rt.params` or the energy tables change; tags the
    /// memoized evaluator so stale snapshots are never served.
    params_epoch: u64,
    /// Lazily built [`EnergyEvaluator`] for the current epoch.
    eval_cache: RefCell<Option<(u64, Arc<EnergyEvaluator>)>>,
}

impl Pipeline {
    pub fn new(artifacts_dir: &std::path::Path, model: &str, pp: PipelineParams) -> Result<Self> {
        let rt = ModelRuntime::auto(artifacts_dir, model, pp.backend)?;
        crate::info!("{model}: {} backend", rt.backend_name());
        Ok(Self::from_runtime(rt, pp))
    }

    /// Assemble a pipeline around an already-constructed runtime (tests
    /// and synthetic workloads use this with
    /// [`ModelRuntime::from_spec_native`]).  Applies the pipeline's
    /// `data_seed` and `threads` to the runtime.
    pub fn from_runtime(mut rt: ModelRuntime, pp: PipelineParams) -> Self {
        rt.data_seed = pp.data_seed;
        rt.threads = pp.threads;
        match crate::model::kernels::dispatch::select(pp.kernels) {
            Ok(ops) => crate::info!("kernels: {} backend", ops.kind.name()),
            // Bit-identical fallback: an unavailable forced backend only
            // changes speed, so degrade with a warning instead of
            // failing the whole pipeline here (the CLI flag validates
            // up front and does fail fast).
            Err(e) => crate::warnlog!("{e}; keeping current kernel backend"),
        }
        Self {
            rt,
            pp,
            cap_model: CapModel::default(),
            maclib: MacLib::new(),
            stats: Vec::new(),
            tables: Vec::new(),
            acc0: 0.0,
            base_energy: None,
            eval_count: 0,
            ft_steps_total: 0,
            params_epoch: 0,
            eval_cache: RefCell::new(None),
        }
    }

    /// Bridge from offline compression to the serving layer: compile
    /// the pipeline's *current* parameters + activation scales under
    /// `state` into a named [`crate::serve::ModelVariant`], ready for
    /// [`crate::serve::SnapshotRegistry::install`].  Uses the same
    /// `QuantConfig` recipe as the native backend (shared mask recipe +
    /// the state's weight sets), so the variant the schedule just
    /// accepted is bit-for-bit the variant that gets served.
    pub fn serving_variant(
        &self,
        name: &str,
        state: &CompressionState,
    ) -> crate::serve::ModelVariant {
        crate::serve::ModelVariant::compile(
            name,
            &self.rt.spec,
            &self.rt.params,
            &self.rt.act_scales,
            state,
            self.pp.threads,
        )
    }

    /// Invalidate the memoized energy evaluator.  Called internally
    /// after every parameter/table mutation; call it yourself if you
    /// mutate `rt.params` directly.
    pub fn touch_params(&mut self) {
        self.params_epoch += 1;
    }

    /// Run one training phase, with checkpoint/resume + divergence
    /// rollback when `ckpt_every` is armed (the plain historical loop
    /// otherwise — bit for bit).
    fn train_phase(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        lr: LrSchedule,
        steps: usize,
        tag: &str,
    ) -> Result<f32> {
        if self.pp.ckpt_every == 0 {
            return self.rt.train_steps(state, quant_on, lr, steps);
        }
        let opts = ResumeOpts::every(self.pp.ckpt_every, tag);
        let prog = self.rt.train_steps_resumable(state, quant_on, lr, steps, &opts)?;
        Ok(prog.loss)
    }

    /// Phase 1+2: float pre-training, activation calibration, QAT.
    /// Stores the quantized baseline accuracy `acc0`.
    pub fn train_baseline(&mut self) -> Result<f64> {
        let dense = CompressionState::dense(self.rt.spec.n_conv);
        let tag = format!("trained-f{}-q{}", self.pp.float_steps, self.pp.qat_steps);
        if self.rt.load_params(&tag)? {
            crate::info!("{}: loaded cached trained params", self.rt.spec.name);
            self.rt.calibrate(self.pp.calib_batches)?;
        } else {
            // Phase-boundary snapshot: a kill during QAT must not repay
            // the (much longer) float phase, whose periodic checkpoint
            // is deleted when the phase completes.
            let float_done = format!("float-done-{tag}");
            if self.pp.ckpt_every > 0 && self.rt.load_state_snapshot(&float_done)? {
                crate::info!(
                    "{}: resumed at QAT phase (float phase + calibration restored)",
                    self.rt.spec.name
                );
            } else {
                crate::info!(
                    "{}: float pre-training {} steps",
                    self.rt.spec.name,
                    self.pp.float_steps
                );
                let float_tag = format!("float-{tag}");
                let loss =
                    self.train_phase(&dense, false, self.pp.lr, self.pp.float_steps, &float_tag)?;
                crate::info!("float loss {loss:.4}; calibrating");
                self.rt.calibrate(self.pp.calib_batches)?;
                if self.pp.ckpt_every > 0 {
                    self.rt.save_state_snapshot(&float_done)?;
                }
            }
            let qat_lr = LrSchedule {
                base: self.pp.lr.base / 2.0,
                decay_at: 0.5,
            };
            let qat_tag = format!("qat-{tag}");
            let loss = self.train_phase(&dense, true, qat_lr, self.pp.qat_steps, &qat_tag)?;
            crate::info!("qat loss {loss:.4}");
            self.rt.save_params(&tag)?;
            let _ = std::fs::remove_file(self.rt.checkpoint_path(&float_done));
        }
        self.touch_params();
        self.acc0 = self
            .rt
            .evaluate(&dense, true, Split::Val, self.pp.val_batches)?;
        crate::info!("{}: quantized baseline acc0 = {:.4}", self.rt.spec.name, self.acc0);
        Ok(self.acc0)
    }

    /// Stream real operand tiles for `images` training inputs into
    /// `sink` — the single recipe (seed, split, batch offset, quantized
    /// parallel forward) shared by [`Self::profile`] and
    /// [`Self::validate_exact`], so the model tables and the exact
    /// ground truth always see the same streams.
    fn capture_streams(
        &self,
        images: usize,
        sink: &mut dyn CaptureSink,
    ) -> Result<crate::model::infer::Forward> {
        let spec = &self.rt.spec;
        let qc = QuantConfig::quantized(spec, self.rt.act_scales.clone());
        let eng = ParallelEngine::new(spec, &self.rt.params, &qc, self.pp.threads);
        let (xs, _ys) =
            crate::data::batch(self.rt.data_seed, Split::Train, 0, images, spec.n_classes as u64);
        // Worker panics surface as a structured PoisonedBatch error
        // (poisoned image indices named) instead of aborting the
        // pipeline.
        Ok(eng.try_forward(&xs, images, sink)?)
    }

    /// Phase 3: per-layer statistics + per-weight energy tables + base
    /// network energy (paper §3).  Statistics are collected *streaming*
    /// ([`StatsSink`]): only the sampled operand columns are buffered,
    /// never a conv's full im2col matrix.
    pub fn profile(&mut self) -> Result<&NetworkEnergy> {
        let spec = self.rt.spec.clone();
        let bs = self.pp.stats_images;
        crate::info!("{}: capturing operand streams ({} images)", spec.name, bs);
        let mut sink = StatsSink::new(self.pp.seed);
        self.capture_streams(bs, &mut sink)?;
        self.stats = sink.into_stats();
        assert_eq!(self.stats.len(), spec.n_conv, "conv layer missing capture");

        crate::info!("{}: characterizing E_l(w) for {} layers", spec.name, spec.n_conv);
        // Fan out across conv layers against one shared pre-specialized
        // MacLib; the per-layer traces only depend on (stats, seed), so
        // the tables are bit-identical to the sequential path.  Thread
        // budget is split between the layer level and the per-code level
        // inside each characterization.
        self.maclib.specialize_all(self.pp.threads);
        let n_layers = self.stats.len();
        let outer = self.pp.threads.clamp(1, n_layers.max(1));
        let inner = (self.pp.threads / outer).max(1);
        let stats_ref = &self.stats;
        let lib_ref = &self.maclib;
        let cap_ref = &self.cap_model;
        let (trace_len, seed) = (self.pp.trace_len, self.pp.seed);
        self.tables = parallel_map(n_layers, outer, |i| {
            let st = &stats_ref[i];
            characterize_layer_shared(
                st,
                lib_ref,
                cap_ref,
                trace_len,
                seed ^ st.conv_idx as u64,
                inner,
            )
        });
        // Tables changed: any memoized evaluator is stale.
        self.touch_params();
        let dense = CompressionState::dense(spec.n_conv);
        let ne = self.compute_network_energy(&dense);
        self.base_energy = Some(ne);
        Ok(self.base_energy.as_ref().unwrap())
    }

    /// Network-scale exact-vs-model validation (paper §3.2): stream
    /// real operand tiles for `images` inputs through the exact
    /// gate-level [`crate::systolic::PowerSink`] — each tile simulated
    /// on arrival, no full im2col copies retained — and diff per-layer
    /// exact energy against the statistical model's prediction on the
    /// same streams.  Requires [`Self::profile`] (the model tables).
    ///
    /// Per-layer exact energies are bit-identical for any thread count;
    /// the returned report is what experiment drivers log next to the
    /// model-mode [`EnergyEvaluator`] numbers.
    pub fn validate_exact(&mut self, images: usize) -> crate::energy::ValidationReport {
        assert!(!self.tables.is_empty(), "profile() before validate_exact()");
        self.maclib.specialize_all(self.pp.threads);
        let mut sink =
            crate::systolic::PowerSink::new(&self.maclib, &self.cap_model, self.pp.threads);
        self.capture_streams(images, &mut sink)
            .expect("capture streams");
        let (metas, exact) = sink.into_parts();
        crate::energy::validate_streams(&metas, &self.tables, &exact)
    }

    /// Build a fresh [`EnergyEvaluator`] snapshotting the current energy
    /// tables and float weights.  Requires [`Self::profile`] to have run.
    fn build_evaluator(&self) -> EnergyEvaluator {
        assert!(!self.tables.is_empty(), "profile() before energy evaluation");
        let convs = self.rt.spec.convs();
        let layers = (0..self.rt.spec.n_conv)
            .map(|ci| {
                let c = convs.iter().find(|c| c.conv_idx == ci).expect("conv idx");
                EvalLayer {
                    le: self.layer_energy_model(ci),
                    weights: self.rt.params[c.w].clone(),
                }
            })
            .collect();
        EnergyEvaluator::new(layers, self.pp.threads)
    }

    /// The memoized evaluator for the *current* parameters/tables.
    /// Rebuilt automatically whenever the params epoch moves (training,
    /// fine-tuning, restore, re-profile).
    pub fn evaluator(&self) -> Arc<EnergyEvaluator> {
        let mut slot = self.eval_cache.borrow_mut();
        if let Some((epoch, ev)) = slot.as_ref() {
            if *epoch == self.params_epoch {
                return ev.clone();
            }
        }
        let ev = Arc::new(self.build_evaluator());
        *slot = Some((self.params_epoch, ev.clone()));
        ev
    }

    /// Per-image canonical energy model for one conv layer.
    pub fn layer_energy_model(&self, conv_idx: usize) -> LayerEnergy {
        let convs = self.rt.spec.convs();
        let c = convs
            .iter()
            .find(|c| c.conv_idx == conv_idx)
            .expect("conv idx");
        let (m, k, n) = c.matmul_dims(1);
        LayerEnergy {
            conv_idx,
            m,
            k,
            n,
            table: self.tables[conv_idx].clone(),
        }
    }

    /// Weight-code usage of a layer under `state` (mask applied, no set
    /// restriction — the schedule restricts separately).  Direct
    /// (uncached) computation from the live params; the hot paths go
    /// through [`Self::evaluator`] instead.
    fn usage_of(&self, conv_idx: usize, state: &CompressionState) -> [u64; 256] {
        let convs = self.rt.spec.convs();
        let c = convs
            .iter()
            .find(|c| c.conv_idx == conv_idx)
            .expect("conv idx");
        let w = &self.rt.params[c.w];
        let ratio = state.layers[conv_idx].prune_ratio;
        let mask = if ratio > 0.0 {
            Some(quant::magnitude_mask(w, ratio))
        } else {
            None
        };
        let (codes, _s) = quant::quantize_restricted(w, mask.as_deref(), None);
        let mut usage = [0u64; 256];
        for &c in &codes {
            usage[(c as i32 + 128) as usize] += 1;
        }
        usage
    }

    /// Network energy under `state` (model mode): memoized + parallel
    /// through the shared [`EnergyEvaluator`].
    pub fn compute_network_energy(&self, state: &CompressionState) -> NetworkEnergy {
        self.evaluator().eval(state)
    }

    /// The historical sequential, uncached path (reference for property
    /// tests and before/after benches; bit-identical to
    /// [`Self::compute_network_energy`]).
    pub fn compute_network_energy_direct(&self, state: &CompressionState) -> NetworkEnergy {
        let layers = (0..self.rt.spec.n_conv)
            .map(|ci| {
                let le = self.layer_energy_model(ci);
                let usage = self.usage_of(ci, state);
                let e = match &state.layers[ci].wset {
                    Some(s) => crate::selection::set_energy(&le, &usage, s),
                    None => le.energy_of_usage(&usage),
                };
                (ci, e)
            })
            .collect();
        NetworkEnergy { layers }
    }

    /// Phase 4: the §4.3 schedule.
    pub fn compress(&mut self, sp: ScheduleParams) -> Result<ScheduleResult> {
        Ok(self
            .compress_opts(sp, None, None)?
            .expect("no trial budget set: search runs to completion"))
    }

    /// [`Self::compress`] with a persistent per-candidate journal at
    /// `journal_path`: an interrupted search resumes from the exact
    /// candidate it died on (oracle params restored from the runtime's
    /// state snapshots).  `--resume` on the CLI.
    pub fn compress_resumable(
        &mut self,
        sp: ScheduleParams,
        journal_path: &std::path::Path,
    ) -> Result<ScheduleResult> {
        Ok(self
            .compress_opts(sp, Some(journal_path), None)?
            .expect("no trial budget set: search runs to completion"))
    }

    /// Full-control compression entry point: optional resumable journal
    /// (`--resume`) and optional persistent accuracy cache
    /// (`--acc-cache`) for the oracle-efficient successive-halving
    /// search.  Returns `Ok(None)` only when the journal carries a
    /// per-invocation trial budget and it is exhausted.
    pub fn compress_opts(
        &mut self,
        mut sp: ScheduleParams,
        journal_path: Option<&std::path::Path>,
        cache_path: Option<&std::path::Path>,
    ) -> Result<Option<ScheduleResult>> {
        assert!(!self.tables.is_empty(), "profile() before compress()");
        sp.acc0 = self.acc0;
        if sp.greedy.threads == 0 {
            sp.greedy.threads = self.pp.threads;
        }
        let n_conv = self.rt.spec.n_conv;
        let mut journal =
            journal_path.map(|p| SearchJournal::new(p.to_path_buf(), "schedule-search"));
        let mut cache = match cache_path {
            Some(p) => Some(AccCache::at(p.to_path_buf())?),
            None => None,
        };
        let res = energy_prioritized_with(self, n_conv, &sp, journal.as_mut(), cache.as_mut())?;
        if let Some(c) = &cache {
            crate::info!(
                "schedule accuracy cache {}: {} entries ({} hits / {} misses this run)",
                c.path().expect("persistent").display(),
                c.len(),
                c.hits,
                c.misses
            );
        }
        Ok(res)
    }

    /// Evaluate an arbitrary state: fine-tune then accuracy + energy
    /// saving vs the profiled baseline (for baseline methods).
    pub fn evaluate_state(
        &mut self,
        state: &CompressionState,
        fine_tune_steps: usize,
    ) -> Result<(f64, f64)> {
        if fine_tune_steps > 0 {
            self.fine_tune(state, fine_tune_steps);
        }
        let acc = self.accuracy(state);
        let base = self
            .base_energy
            .clone()
            .unwrap_or_else(|| self.compute_network_energy(&CompressionState::dense(self.rt.spec.n_conv)));
        let now = self.compute_network_energy(state);
        Ok((acc, base.saving_vs(&now)))
    }

    /// Snapshot current parameters so destructive experiments (naive
    /// baselines) can restore them.
    pub fn checkpoint(&self) -> Vec<Vec<f32>> {
        self.rt.params.clone()
    }

    pub fn restore(&mut self, params: Vec<Vec<f32>>) {
        self.rt.params = params;
        self.touch_params();
    }
}

impl crate::schedule::LayerModeler for Pipeline {
    fn layer_energy(&mut self, conv_idx: usize) -> LayerEnergy {
        self.layer_energy_model(conv_idx)
    }

    fn usage(&mut self, conv_idx: usize, state: &CompressionState) -> [u64; 256] {
        *self
            .evaluator()
            .usage_for_conv(conv_idx, state.layers[conv_idx].prune_ratio)
    }

    fn network_energy(&mut self, state: &CompressionState) -> NetworkEnergy {
        self.compute_network_energy(state)
    }

    fn evaluator(&mut self) -> Option<Arc<EnergyEvaluator>> {
        Some(Pipeline::evaluator(self))
    }
}

impl AccuracyOracle for Pipeline {
    fn accuracy(&mut self, state: &CompressionState) -> f64 {
        self.eval_count += 1;
        self.rt
            .evaluate(state, true, Split::Val, self.pp.val_batches)
            .expect("eval")
    }

    fn fine_tune(&mut self, state: &CompressionState, steps: usize) {
        if steps == 0 {
            return;
        }
        self.ft_steps_total += steps;
        let lr = LrSchedule {
            base: self.pp.lr.base / 4.0,
            decay_at: 1.0,
        };
        self.rt
            .train_steps(state, true, lr, steps)
            .expect("fine-tune");
        self.touch_params();
    }

    fn eval_count(&self) -> usize {
        self.eval_count
    }

    /// Back the resumable schedule search's oracle persistence with the
    /// runtime's checksummed state snapshots (params + momentum +
    /// act_scales + data cursor).
    fn save_search_state(&mut self, tag: &str) -> bool {
        match self.rt.save_state_snapshot(tag) {
            Ok(()) => true,
            Err(e) => {
                crate::warnlog!("oracle snapshot `{tag}` failed: {e}");
                false
            }
        }
    }

    fn load_search_state(&mut self, tag: &str) -> bool {
        match self.rt.load_state_snapshot(tag) {
            Ok(found) => {
                if found {
                    self.touch_params();
                }
                found
            }
            Err(e) => {
                crate::warnlog!("oracle snapshot `{tag}` rejected: {e}");
                false
            }
        }
    }

    fn drop_search_state(&mut self, tag: &str) {
        self.rt.drop_state_snapshot(tag);
    }

    /// Identity of everything the oracle's accuracy numbers depend on
    /// besides the compression state: model spec, data recipe,
    /// evaluation size, fine-tune learning rate, and a digest of the
    /// starting parameters + activation scales.  Keys the persistent
    /// accuracy cache, so entries warmed under one trained checkpoint
    /// are never served against another.
    fn search_context(&mut self) -> String {
        let mut bytes: Vec<u8> = Vec::new();
        for t in &self.rt.params {
            for &v in t {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        for &s in &self.rt.act_scales {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        format!(
            "{}|seed={}|val={}|lr={}|params={:016x}",
            self.rt.spec.name,
            self.rt.data_seed,
            self.pp.val_batches,
            self.pp.lr.base,
            crate::schedule::acc_cache::fnv1a64(&bytes)
        )
    }

    fn ft_steps(&self) -> usize {
        self.ft_steps_total
    }
}
