//! Blocked parallel executor for the int8 mirror engine.
//!
//! [`ParallelEngine`] compiles a [`Plan`](super::ir::Plan) once and fans
//! independent batch images out over [`crate::util::threadpool`]: each
//! worker owns one [`Scratch`] (preallocated activation buffers, im2col
//! and accumulator tiles) reused across every image it claims (for
//! capturing forwards, across every image of the current wave).  Images
//! are computed independently with exact i32 conv accumulation, so
//! logits, activation maxima and captured operand streams are
//! **bit-identical to the scalar reference in [`super::infer`] at any
//! thread count** (property-pinned in `rust/tests/engine_parallel.rs`).
//!
//! The scalar engine's `capture: bool` flag is replaced by the
//! [`CaptureSink`] trait: consumers receive each conv's weight panel
//! once plus per-image im2col row blocks as streams, delivered on the
//! caller's thread in deterministic (image, conv) order.  Sinks that
//! only need samples or running aggregates ([`crate::stats::StatsSink`],
//! [`crate::systolic::PowerSink`]) never materialize a layer's full
//! im2col matrix; [`CaptureBuffer`] reconstructs classic
//! [`ConvCapture`]s for consumers that do need whole operand matrices.

use super::infer::{ConvCapture, Forward, QuantConfig};
use super::ir::{ConvStep, ConvWeights, FcStep, FcWeights, Plan, StepKind};
use super::kernels;
use super::spec::{ModelSpec, INPUT_ELEMS as IMG_ELEMS};
use crate::util::threadpool::{try_parallel_for_with, PoisonedBatch};

/// Streaming consumer of conv operand tiles.
///
/// Per forward pass the executor calls [`begin_conv`](Self::begin_conv)
/// once per quantized conv (in execution order, before any block), then
/// [`x_block`](Self::x_block) once per (image, conv) in ascending batch
/// order, then [`finish`](Self::finish).  All calls happen on the
/// caller's thread in an order independent of the executor's thread
/// count, so sink state needs no synchronization and deterministic sinks
/// stay deterministic.
pub trait CaptureSink {
    /// Whether the executor should materialize X tile blocks at all
    /// (`false` skips the per-image copies entirely).
    fn wants_tiles(&self) -> bool {
        true
    }
    /// A conv's operand-pair metadata + pre-quantized weight panel.
    fn begin_conv(&mut self, head: &ConvHead<'_>);
    /// Pack-time block sparsity of the conv announced by the preceding
    /// [`begin_conv`](Self::begin_conv) call: which share of its SB×SB
    /// weight blocks the GEMM skips structurally.  Defaulted so sinks
    /// that don't track skip counts need no change.
    fn conv_sparsity(&mut self, _conv_idx: usize, _s: &kernels::BlockSparsity) {}
    /// One block of im2col rows (`rows`×`k`, row-major) of conv
    /// `conv_idx`'s X matrix.
    fn x_block(&mut self, conv_idx: usize, rows: usize, x_codes: &[i8]);
    /// All blocks delivered (Σ rows == `m_total` per conv).
    fn finish(&mut self);
}

/// Metadata + weight panel of one conv's im2col matmul
/// `Y(M×N) = X(M×K)·W(K×N)`.
pub struct ConvHead<'a> {
    pub conv_idx: usize,
    /// Total X rows this forward will stream (batch × hout × wout).
    pub m_total: usize,
    pub k: usize,
    pub n: usize,
    /// K×N row-major weight codes.
    pub w_codes: &'a [i8],
    pub s_act: f32,
    pub s_w: f32,
}

/// Sink that captures nothing (the old `capture: false`).
pub struct NullSink;

impl CaptureSink for NullSink {
    fn wants_tiles(&self) -> bool {
        false
    }
    fn begin_conv(&mut self, _head: &ConvHead<'_>) {}
    fn x_block(&mut self, _conv_idx: usize, _rows: usize, _x_codes: &[i8]) {}
    fn finish(&mut self) {}
}

/// Sink that materializes classic [`ConvCapture`]s (one per conv, in
/// execution order, X rows in batch order) — bit-identical to what the
/// scalar reference's `capture: true` path produced.
#[derive(Default)]
pub struct CaptureBuffer {
    captures: Vec<ConvCapture>,
    pos_of: Vec<Option<usize>>,
}

impl CaptureBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn captures(&self) -> &[ConvCapture] {
        &self.captures
    }

    pub fn into_captures(self) -> Vec<ConvCapture> {
        self.captures
    }
}

impl CaptureSink for CaptureBuffer {
    fn begin_conv(&mut self, head: &ConvHead<'_>) {
        if self.pos_of.len() <= head.conv_idx {
            self.pos_of.resize(head.conv_idx + 1, None);
        }
        assert!(
            self.pos_of[head.conv_idx].is_none(),
            "conv{} announced twice (one forward per CaptureBuffer)",
            head.conv_idx
        );
        self.pos_of[head.conv_idx] = Some(self.captures.len());
        self.captures.push(ConvCapture {
            conv_idx: head.conv_idx,
            m: head.m_total,
            k: head.k,
            n: head.n,
            x_codes: Vec::with_capacity(head.m_total * head.k),
            w_codes: head.w_codes.to_vec(),
            s_act: head.s_act,
            s_w: head.s_w,
        });
    }

    fn x_block(&mut self, conv_idx: usize, _rows: usize, x_codes: &[i8]) {
        let pos = self
            .pos_of
            .get(conv_idx)
            .copied()
            .flatten()
            .expect("x_block before begin_conv");
        self.captures[pos].x_codes.extend_from_slice(x_codes);
    }

    fn finish(&mut self) {
        for c in &self.captures {
            debug_assert_eq!(c.x_codes.len(), c.m * c.k, "conv{} capture short", c.conv_idx);
        }
    }
}

/// Per-worker execution scratch: every buffer sized once from the
/// plan's maxima and reused across all images the worker claims.  The
/// kernel operands (`xq`, `cols`, `acc`) live in 64-byte-aligned
/// [`kernels::AVec`] buffers so the SIMD microkernels see cache-line
/// aligned tiles.
struct Scratch {
    cur: Vec<f32>,
    tmp: Vec<f32>,
    saved: Vec<Vec<f32>>,
    xq: kernels::AVec<i8>,
    cols: kernels::AVec<i8>,
    acc: kernels::AVec<i32>,
}

impl Scratch {
    fn new(plan: &Plan) -> Self {
        Self {
            cur: Vec::with_capacity(plan.max_tensor),
            tmp: Vec::with_capacity(plan.max_tensor.max(plan.max_acc)),
            saved: (0..plan.save_depth)
                .map(|_| Vec::with_capacity(plan.max_tensor))
                .collect(),
            xq: kernels::AVec::with_capacity(plan.max_qin),
            cols: kernels::AVec::with_capacity(plan.max_cols),
            acc: kernels::AVec::with_capacity(plan.max_acc),
        }
    }
}

/// One image's outputs (logits + per-quant-point maxima + operand
/// blocks when capturing).
struct ImgOut {
    logits: Vec<f32>,
    act_max: Vec<f32>,
    blocks: Vec<ConvBlock>,
}

struct ConvBlock {
    conv_idx: usize,
    rows: usize,
    x: Vec<i8>,
}

#[allow(clippy::too_many_arguments)]
fn run_conv(
    plan: &Plan,
    cs: &ConvStep,
    input: &[f32],
    act_max: &mut [f32],
    xq: &mut kernels::AVec<i8>,
    cols: &mut kernels::AVec<i8>,
    acc: &mut kernels::AVec<i32>,
    out: &mut Vec<f32>,
    capture: bool,
    blocks: &mut Vec<ConvBlock>,
) {
    let cv = &cs.op;
    let amax = kernels::abs_max(input);
    act_max[cv.q_idx] = act_max[cv.q_idx].max(amax);
    match &cs.weights {
        ConvWeights::Quant { wb, s_w, .. } => {
            let s_a = plan.act_scales[cv.q_idx];
            kernels::quantize_into(input, s_a, xq);
            kernels::im2col_i8(xq, 1, cv.hin, cv.win, cv.cin, cv, cols);
            let m_img = cv.hout * cv.wout;
            acc.clear();
            acc.resize(m_img * cv.cout, 0);
            kernels::gemm_i8_blocked(cols, wb, m_img, acc);
            let ss = s_a * *s_w;
            kernels::requant_bias_relu(acc, ss, &cs.bias, cv.relu, out);
            if capture {
                blocks.push(ConvBlock {
                    conv_idx: cv.conv_idx,
                    rows: m_img,
                    x: cols.to_vec(),
                });
            }
        }
        ConvWeights::Float(wf) => {
            kernels::conv_f32_direct(cv, input, 1, wf, &cs.bias, out);
            if cv.relu {
                out.iter_mut().for_each(|v| *v = v.max(0.0));
            }
        }
    }
}

fn run_fc(
    plan: &Plan,
    fs: &FcStep,
    input: &[f32],
    act_max: &mut [f32],
    xq: &mut kernels::AVec<i8>,
    out: &mut Vec<f32>,
) {
    let fc = &fs.op;
    let amax = kernels::abs_max(input);
    act_max[fc.q_idx] = act_max[fc.q_idx].max(amax);
    match &fs.weights {
        FcWeights::Quant { wq, s_w } => {
            let s_a = plan.act_scales[fc.q_idx];
            kernels::quantize_into(input, s_a, xq);
            let ss = s_a * *s_w;
            kernels::fc_i8(xq, 1, fc.din, fc.dout, wq, ss, &fs.bias, fc.relu, out);
        }
        FcWeights::Float(w) => {
            kernels::fc_f32(input, 1, fc.din, fc.dout, w, &fs.bias, fc.relu, out);
        }
    }
}

/// Interpret the plan over one image.
fn run_image(plan: &Plan, x: &[f32], scratch: &mut Scratch, capture: bool) -> ImgOut {
    let mut act_max = vec![0.0f32; plan.n_q];
    let mut blocks = Vec::new();
    let Scratch {
        cur,
        tmp,
        saved,
        xq,
        cols,
        acc,
    } = scratch;
    cur.clear();
    cur.extend_from_slice(x);
    let mut depth = 0usize;
    for step in &plan.steps {
        let sh = step.shape;
        match &step.kind {
            StepKind::Conv(cs) => {
                run_conv(plan, cs, cur, &mut act_max, xq, cols, acc, tmp, capture, &mut blocks);
                std::mem::swap(cur, tmp);
            }
            StepKind::MaxPool2 => {
                kernels::maxpool2(cur, 1, sh.h, sh.w, sh.c, tmp);
                std::mem::swap(cur, tmp);
            }
            StepKind::Gap => {
                kernels::gap(cur, 1, sh.h, sh.w, sh.c, tmp);
                std::mem::swap(cur, tmp);
            }
            StepKind::Flatten => {} // shape bookkeeping only
            StepKind::Save => {
                let slot = &mut saved[depth];
                slot.clear();
                slot.extend_from_slice(cur);
                depth += 1;
            }
            StepKind::AddSaved { relu, proj } => {
                depth -= 1;
                if let Some(ps) = proj {
                    run_conv(
                        plan, ps, &saved[depth], &mut act_max, xq, cols, acc, tmp, capture,
                        &mut blocks,
                    );
                    for (a, &b) in cur.iter_mut().zip(tmp.iter()) {
                        *a += b;
                    }
                } else {
                    for (a, &b) in cur.iter_mut().zip(saved[depth].iter()) {
                        *a += b;
                    }
                }
                if *relu {
                    cur.iter_mut().for_each(|v| *v = v.max(0.0));
                }
            }
            StepKind::Fc(fs) => {
                run_fc(plan, fs, cur, &mut act_max, xq, tmp);
                std::mem::swap(cur, tmp);
            }
        }
    }
    ImgOut {
        logits: cur.clone(),
        act_max,
        blocks,
    }
}

/// Per-conv structural-skip summary for one `batch`-image forward:
/// the pack-time block sparsity plus the MAC counts it translates to.
#[derive(Clone, Copy, Debug)]
pub struct ConvSkip {
    pub conv_idx: usize,
    pub sparsity: kernels::BlockSparsity,
    /// MACs the structural skip removes (`m` im2col rows ×
    /// `elems_skipped` weight positions).
    pub macs_skipped: u64,
    /// Dense MAC count (`m · k · n`) of the same forward.
    pub macs_dense: u64,
}

/// The parallel inference engine: a compiled [`Plan`] plus a worker
/// budget.
pub struct ParallelEngine {
    pub plan: Plan,
    pub threads: usize,
}

impl ParallelEngine {
    /// Compile `spec` + params under `qc` (weight quantization and
    /// panel packing happen here, once).
    pub fn new(spec: &ModelSpec, params: &[Vec<f32>], qc: &QuantConfig, threads: usize) -> Self {
        Self {
            plan: Plan::compile(spec, params, qc),
            threads: threads.max(1),
        }
    }

    fn announce(&self, cs: &ConvStep, batch: usize, sink: &mut dyn CaptureSink) {
        if let ConvWeights::Quant { wq, wb, s_w } = &cs.weights {
            let cv = &cs.op;
            let (m, kk, nn) = cv.matmul_dims(batch);
            sink.begin_conv(&ConvHead {
                conv_idx: cv.conv_idx,
                m_total: m,
                k: kk,
                n: nn,
                w_codes: wq,
                s_act: self.plan.act_scales[cv.q_idx],
                s_w: *s_w,
            });
            sink.conv_sparsity(cv.conv_idx, &wb.sparsity());
        }
    }

    /// Forward a batch (`x`: NHWC f32), streaming conv operand tiles
    /// into `sink`.  Bit-identical to the scalar reference for any
    /// `threads`.
    ///
    /// Unlike the scalar engine, operand captures live in the **sink**,
    /// not the return value: the returned [`Forward`]'s `captures` field
    /// is always empty (use [`CaptureBuffer`] to materialize classic
    /// captures).
    pub fn forward(&self, x: &[f32], batch: usize, sink: &mut dyn CaptureSink) -> Forward {
        self.try_forward(x, batch, sink)
            .unwrap_or_else(|e| panic!("forward: {e}"))
    }

    /// [`Self::forward`] with worker-panic isolation: a panic inside any
    /// per-image worker is caught and reported as a structured
    /// [`PoisonedBatch`] naming the poisoned image indices, instead of
    /// aborting the process.
    pub fn try_forward(
        &self,
        x: &[f32],
        batch: usize,
        sink: &mut dyn CaptureSink,
    ) -> Result<Forward, PoisonedBatch> {
        assert_eq!(x.len(), batch * IMG_ELEMS);
        let plan = &self.plan;
        let capturing = plan.quant_on && sink.wants_tiles();
        if capturing {
            for step in &plan.steps {
                match &step.kind {
                    StepKind::Conv(cs) => self.announce(cs, batch, sink),
                    StepKind::AddSaved { proj: Some(cs), .. } => self.announce(cs, batch, sink),
                    _ => {}
                }
            }
        }
        let ncls = plan.n_classes;
        let mut logits = vec![0.0f32; batch * ncls];
        let mut act_max = vec![0.0f32; plan.n_q];
        // Capturing forwards run in waves so sink consumption (and hence
        // peak tile memory) stays bounded by the wave, not the batch —
        // the deliberate trade: per-wave worker spawn + scratch build is
        // a handful of `with_capacity` mallocs amortized over 4·threads
        // full image forwards, bought for an O(wave) tile footprint.
        // Plain forwards produce no tiles, so the whole batch is one
        // wave: workers spawn once and each worker's scratch is built
        // once and reused across every image it claims.
        let wave = if capturing {
            self.threads * 4
        } else {
            batch.max(1)
        };
        let mut img0 = 0usize;
        while img0 < batch {
            let count = wave.min(batch - img0);
            let worker_outs = try_parallel_for_with(
                count,
                self.threads,
                || (Scratch::new(plan), Vec::new()),
                |state: &mut (Scratch, Vec<(usize, ImgOut)>), i| {
                    let (scratch, outs) = state;
                    let x_img = &x[(img0 + i) * IMG_ELEMS..(img0 + i + 1) * IMG_ELEMS];
                    outs.push((i, run_image(plan, x_img, scratch, capturing)));
                },
            )?;
            let mut flat: Vec<(usize, ImgOut)> =
                worker_outs.into_iter().flat_map(|(_s, outs)| outs).collect();
            flat.sort_by_key(|(i, _)| *i);
            for (i, out) in flat {
                logits[(img0 + i) * ncls..(img0 + i + 1) * ncls].copy_from_slice(&out.logits);
                for (m, &v) in act_max.iter_mut().zip(&out.act_max) {
                    *m = m.max(v);
                }
                for b in &out.blocks {
                    sink.x_block(b.conv_idx, b.rows, &b.x);
                }
            }
            img0 += count;
        }
        sink.finish();
        Ok(Forward {
            logits,
            batch,
            act_max,
            captures: Vec::new(),
        })
    }

    /// Forward without captures.
    pub fn forward_plain(&self, x: &[f32], batch: usize) -> Forward {
        self.forward(x, batch, &mut NullSink)
    }

    /// [`Self::forward_plain`] with worker-panic isolation.
    pub fn try_forward_plain(&self, x: &[f32], batch: usize) -> Result<Forward, PoisonedBatch> {
        self.try_forward(x, batch, &mut NullSink)
    }

    /// Serving entry point: forward a **wave** of independently owned
    /// single images (as coalesced by [`crate::serve`]'s micro-batcher),
    /// returning each request's logits separately instead of one packed
    /// `batch × n_classes` buffer.  Each image runs through the same
    /// `run_image` interpreter as the batch path, images are independent,
    /// and conv accumulation is exact i32 — so every returned logit
    /// vector is bit-identical to a single-image [`Self::forward_plain`]
    /// of the same input at any thread count and any wave packing
    /// (pinned in `rust/tests/serving.rs`).  A worker panic poisons the
    /// wave, not the process.
    pub fn forward_wave(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f32>>, PoisonedBatch> {
        for x in imgs {
            assert_eq!(x.len(), IMG_ELEMS);
        }
        let plan = &self.plan;
        let worker_outs = try_parallel_for_with(
            imgs.len(),
            self.threads,
            || (Scratch::new(plan), Vec::new()),
            |state: &mut (Scratch, Vec<(usize, Vec<f32>)>), i| {
                let (scratch, outs) = state;
                outs.push((i, run_image(plan, imgs[i], scratch, false).logits));
            },
        )?;
        let mut out = vec![Vec::new(); imgs.len()];
        for (_scratch, outs) in worker_outs {
            for (i, logits) in outs {
                out[i] = logits;
            }
        }
        Ok(out)
    }

    /// Structural-skip summary per quantized conv for a `batch`-image
    /// forward, in conv-index order.  Empty on float plans.
    pub fn sparsity_report(&self, batch: usize) -> Vec<ConvSkip> {
        let mut out: Vec<ConvSkip> = Vec::new();
        let mut push = |cs: &ConvStep| {
            if let ConvWeights::Quant { wb, .. } = &cs.weights {
                let (m, kk, nn) = cs.op.matmul_dims(batch);
                let s = wb.sparsity();
                out.push(ConvSkip {
                    conv_idx: cs.op.conv_idx,
                    sparsity: s,
                    macs_skipped: m as u64 * s.elems_skipped,
                    macs_dense: (m * kk * nn) as u64,
                });
            }
        };
        for step in &self.plan.steps {
            match &step.kind {
                StepKind::Conv(cs) => push(cs),
                StepKind::AddSaved { proj: Some(cs), .. } => push(cs),
                _ => {}
            }
        }
        out.sort_by_key(|c| c.conv_idx);
        out
    }

    /// Calibrate activation scales over float batches: one forward
    /// scratch per worker is reused across the *entire* batch loop, and
    /// per-image maxima merge by `max` (order-insensitive), so the
    /// result is bit-identical to the scalar reference at any thread
    /// count.  Requires a float plan.
    pub fn calibrate(&self, xs: &[&[f32]], batch: usize) -> Vec<f32> {
        self.try_calibrate(xs, batch)
            .unwrap_or_else(|e| panic!("calibrate: {e}"))
    }

    /// [`Self::calibrate`] with worker-panic isolation (see
    /// [`Self::try_forward`]).
    pub fn try_calibrate(
        &self,
        xs: &[&[f32]],
        batch: usize,
    ) -> Result<Vec<f32>, PoisonedBatch> {
        let plan = &self.plan;
        assert!(!plan.quant_on, "calibration runs the float plan");
        for x in xs {
            assert_eq!(x.len(), batch * IMG_ELEMS);
        }
        let total = xs.len() * batch;
        let states = try_parallel_for_with(
            total,
            self.threads,
            || (Scratch::new(plan), vec![0.0f32; plan.n_q]),
            |state: &mut (Scratch, Vec<f32>), idx| {
                let (scratch, maxes) = state;
                let (bi, ii) = (idx / batch, idx % batch);
                let x_img = &xs[bi][ii * IMG_ELEMS..(ii + 1) * IMG_ELEMS];
                let out = run_image(plan, x_img, scratch, false);
                for (m, &v) in maxes.iter_mut().zip(&out.act_max) {
                    *m = m.max(v);
                }
            },
        )?;
        let mut maxes = vec![0.0f32; plan.n_q];
        for (_scratch, wm) in &states {
            for (m, &v) in maxes.iter_mut().zip(wm) {
                *m = m.max(v);
            }
        }
        Ok(maxes
            .iter()
            .map(|&m| (m / crate::quant::QMAX as f32).max(1e-9))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::infer::Engine;
    use super::super::spec::tests_support::tiny_spec;
    use super::*;
    use crate::model::Params;

    fn input(batch: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..batch * IMG_ELEMS)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect()
    }

    #[test]
    fn float_logits_bit_identical_to_scalar() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 11);
        let x = input(3, 12);
        let qc = QuantConfig::float(&spec);
        let want = Engine::new(&spec).forward(&p.tensors, &x, 3, &qc, false);
        for threads in [1usize, 2, 5] {
            let eng = ParallelEngine::new(&spec, &p.tensors, &qc, threads);
            let got = eng.forward_plain(&x, 3);
            assert_eq!(
                want.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
            assert_eq!(
                want.act_max.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.act_max.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn quant_captures_bit_identical_to_scalar() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 13);
        let x = input(2, 14);
        let scalar = Engine::new(&spec);
        let scales = scalar.calibrate(&p.tensors, &[&x], 2);
        let qc = QuantConfig::quantized(&spec, scales);
        let want = scalar.forward(&p.tensors, &x, 2, &qc, true);
        let eng = ParallelEngine::new(&spec, &p.tensors, &qc, 3);
        let mut sink = CaptureBuffer::new();
        let got = eng.forward(&x, 2, &mut sink);
        assert_eq!(
            want.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let caps = sink.into_captures();
        assert_eq!(caps.len(), want.captures.len());
        for (a, b) in want.captures.iter().zip(&caps) {
            assert_eq!(a.conv_idx, b.conv_idx);
            assert_eq!((a.m, a.k, a.n), (b.m, b.k, b.n));
            assert_eq!(a.x_codes, b.x_codes);
            assert_eq!(a.w_codes, b.w_codes);
            assert_eq!(a.s_act.to_bits(), b.s_act.to_bits());
            assert_eq!(a.s_w.to_bits(), b.s_w.to_bits());
        }
    }

    /// The executor announces pack-time block sparsity alongside each
    /// conv head, and `sparsity_report` agrees with what sinks saw.
    #[test]
    fn sparsity_reaches_sinks_and_report() {
        struct SpySink {
            seen: Vec<(usize, kernels::BlockSparsity)>,
        }
        impl CaptureSink for SpySink {
            fn wants_tiles(&self) -> bool {
                true
            }
            fn begin_conv(&mut self, _head: &ConvHead<'_>) {}
            fn conv_sparsity(&mut self, conv_idx: usize, s: &kernels::BlockSparsity) {
                self.seen.push((conv_idx, *s));
            }
            fn x_block(&mut self, _conv_idx: usize, _rows: usize, _x: &[i8]) {}
            fn finish(&mut self) {}
        }
        let spec = tiny_spec();
        let p = Params::random(&spec, 21);
        let x = input(2, 22);
        let scales = Engine::new(&spec).calibrate(&p.tensors, &[&x], 2);
        let qc = QuantConfig::quantized(&spec, scales);
        let eng = ParallelEngine::new(&spec, &p.tensors, &qc, 2);
        let mut sink = SpySink { seen: Vec::new() };
        eng.forward(&x, 2, &mut sink);
        let report = eng.sparsity_report(2);
        assert_eq!(sink.seen.len(), report.len());
        assert_eq!(report.len(), spec.n_conv);
        let mut seen = sink.seen;
        seen.sort_by_key(|&(i, _)| i);
        for ((i, s), r) in seen.iter().zip(&report) {
            assert_eq!(*i, r.conv_idx);
            assert_eq!(*s, r.sparsity);
            assert!(r.macs_skipped <= r.macs_dense);
        }
        // Float plans pack no panels, so there is nothing to skip.
        let feng = ParallelEngine::new(&spec, &p.tensors, &QuantConfig::float(&spec), 2);
        assert!(feng.sparsity_report(2).is_empty());
    }

    #[test]
    fn calibrate_matches_scalar_reference() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 15);
        let x0 = input(2, 16);
        let x1 = input(2, 17);
        // Scalar reference: float forwards, fold maxima, scale by QMAX —
        // the historical `Engine::calibrate` recipe, inlined so the
        // delegating production path is checked against an independent
        // computation.
        let scalar = Engine::new(&spec);
        let qc = QuantConfig::float(&spec);
        let mut fold = vec![0.0f32; spec.n_q];
        for x in [&x0, &x1] {
            let f = scalar.forward(&p.tensors, x, 2, &qc, false);
            for (m, &v) in fold.iter_mut().zip(&f.act_max) {
                *m = m.max(v);
            }
        }
        let want: Vec<f32> = fold
            .iter()
            .map(|&m| (m / crate::quant::QMAX as f32).max(1e-9))
            .collect();
        let eng = ParallelEngine::new(&spec, &p.tensors, &QuantConfig::float(&spec), 4);
        let got = eng.calibrate(&[&x0, &x1], 2);
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
