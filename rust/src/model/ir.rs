//! IR lowering: compile a [`ModelSpec`] + parameter snapshot +
//! [`QuantConfig`] into an executable [`Plan`] once, ahead of any
//! forward pass.
//!
//! The scalar reference re-derives everything per call (weight
//! quantization, OIHW→K×N reorder, output allocation); the plan does it
//! exactly once per `(params, CompressionState)` snapshot:
//!
//! * per-conv weights are pre-quantized under the config's mask/set and
//!   packed into the blocked panel layout the GEMM kernel consumes
//!   ([`super::kernels::BlockedWeights`]);
//! * the op list is lowered to [`Step`]s carrying their input shapes, so
//!   the executor does no shape inference at run time;
//! * maximum per-image buffer sizes are computed so executor scratch is
//!   allocated once per worker and reused across the whole batch loop
//!   (the kernel-operand buffers are 64-byte-aligned
//!   [`super::kernels::AVec`]s, matching the aligned panel layout the
//!   SIMD microkernels expect).
//!
//! Lowering checks the same structural invariants the scalar forward
//! asserts (shape chaining, save/add balance), failing fast at compile
//! time instead of mid-batch.

use super::infer::QuantConfig;
use super::kernels::{BlockSparsity, BlockedWeights};
use super::spec::{ConvOp, FcOp, ModelSpec, Op, INPUT_C, INPUT_H, INPUT_W};
use crate::quant;

/// Tensor shape per image at a step boundary (NHWC, or flattened with
/// `h = w = 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub flat: bool,
}

impl Shape {
    pub(crate) fn numel(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Pre-lowered conv weights (one of the two execution modes).
pub(crate) enum ConvWeights {
    /// Quantized: K×N codes (capture/reference layout), the blocked
    /// panel packing for the GEMM kernel, and the weight scale.
    Quant {
        wq: Vec<i8>,
        wb: BlockedWeights,
        s_w: f32,
    },
    /// Float (calibration): raw OIHW tensor for the direct-conv kernel.
    Float(Vec<f32>),
}

pub(crate) struct ConvStep {
    pub op: ConvOp,
    pub weights: ConvWeights,
    pub bias: Vec<f32>,
}

pub(crate) enum FcWeights {
    Quant { wq: Vec<i8>, s_w: f32 },
    Float(Vec<f32>),
}

pub(crate) struct FcStep {
    pub op: FcOp,
    pub weights: FcWeights,
    pub bias: Vec<f32>,
}

pub(crate) enum StepKind {
    Conv(Box<ConvStep>),
    MaxPool2,
    Gap,
    Flatten,
    Save,
    AddSaved {
        relu: bool,
        proj: Option<Box<ConvStep>>,
    },
    Fc(Box<FcStep>),
}

pub(crate) struct Step {
    pub kind: StepKind,
    /// Shape of the tensor *entering* this step, per image.
    pub shape: Shape,
}

/// Executable plan: lowered steps plus scratch-sizing metadata.
pub struct Plan {
    pub quant_on: bool,
    pub act_scales: Vec<f32>,
    pub n_q: usize,
    /// Logit width (the final flattened dimension).
    pub n_classes: usize,
    pub(crate) steps: Vec<Step>,
    /// Largest per-image f32 tensor any step produces or consumes.
    pub(crate) max_tensor: usize,
    /// Largest per-image im2col code matrix.
    pub(crate) max_cols: usize,
    /// Largest per-image conv accumulator tile.
    pub(crate) max_acc: usize,
    /// Largest per-image tensor that gets quantized to codes.
    pub(crate) max_qin: usize,
    /// Deepest save/add nesting.
    pub(crate) save_depth: usize,
}

fn lower_conv(cv: &ConvOp, params: &[Vec<f32>], qc: &QuantConfig) -> ConvStep {
    let wt = &params[cv.w];
    let bias = params[cv.b].clone();
    let weights = if qc.quant_on {
        let mask = qc.masks[cv.conv_idx].as_deref();
        let set = qc.wsets[cv.conv_idx].as_ref();
        let (w_oihw, s_w) = quant::quantize_restricted(wt, mask, set);
        // OIHW codes -> K×N ((ky, kx, ci) rows, cout columns), matching
        // the scalar reference and the capture layout.
        let kk = cv.k * cv.k * cv.cin;
        let nn = cv.cout;
        let mut wq = vec![0i8; kk * nn];
        for o in 0..cv.cout {
            for ci in 0..cv.cin {
                for ky in 0..cv.k {
                    for kx in 0..cv.k {
                        let src = ((o * cv.cin + ci) * cv.k + ky) * cv.k + kx;
                        let row = (ky * cv.k + kx) * cv.cin + ci;
                        wq[row * nn + o] = w_oihw[src];
                    }
                }
            }
        }
        let wb = BlockedWeights::pack(&wq, kk, nn);
        // The SIMD strip kernels rely on pack's layout contract: panels
        // start cache-line aligned (aligned base + 64-byte-multiple
        // panel stride).  Cheap pointer check, compiled out of release.
        debug_assert!(wb.panels_aligned(), "{}: unaligned weight panels", cv.name);
        ConvWeights::Quant { wq, wb, s_w }
    } else {
        ConvWeights::Float(wt.clone())
    };
    ConvStep {
        op: cv.clone(),
        weights,
        bias,
    }
}

fn lower_fc(fc: &FcOp, params: &[Vec<f32>], qc: &QuantConfig) -> FcStep {
    let wt = &params[fc.w];
    let bias = params[fc.b].clone();
    let weights = if qc.quant_on {
        let (wq, s_w) = quant::quantize_restricted(wt, None, None);
        FcWeights::Quant { wq, s_w }
    } else {
        FcWeights::Float(wt.clone())
    };
    FcStep {
        op: fc.clone(),
        weights,
        bias,
    }
}

/// Track a conv through shape lowering: validate the input shape,
/// update scratch maxima, return the output shape.
fn conv_shape(
    cv: &ConvOp,
    sh: Shape,
    max_cols: &mut usize,
    max_acc: &mut usize,
    max_qin: &mut usize,
) -> Shape {
    assert!(!sh.flat, "{}: conv expects NHWC input", cv.name);
    assert_eq!(sh.c, cv.cin, "{}: cin mismatch", cv.name);
    assert_eq!((sh.h, sh.w), (cv.hin, cv.win), "{}: spatial mismatch", cv.name);
    let m_img = cv.hout * cv.wout;
    let kk = cv.k * cv.k * cv.cin;
    *max_cols = (*max_cols).max(m_img * kk);
    *max_acc = (*max_acc).max(m_img * cv.cout);
    *max_qin = (*max_qin).max(sh.numel());
    Shape {
        h: cv.hout,
        w: cv.wout,
        c: cv.cout,
        flat: false,
    }
}

impl Plan {
    /// Lower `spec` against a parameter snapshot and quantization
    /// config.  All weight quantization/packing happens here, once.
    pub fn compile(spec: &ModelSpec, params: &[Vec<f32>], qc: &QuantConfig) -> Plan {
        assert_eq!(qc.act_scales.len(), spec.n_q);
        assert_eq!(qc.masks.len(), spec.n_conv);
        assert_eq!(qc.wsets.len(), spec.n_conv);
        let mut steps = Vec::with_capacity(spec.ops.len());
        let mut sh = Shape {
            h: INPUT_H,
            w: INPUT_W,
            c: INPUT_C,
            flat: false,
        };
        let mut saved: Vec<Shape> = Vec::new();
        let mut max_tensor = sh.numel();
        let mut max_cols = 0usize;
        let mut max_acc = 0usize;
        let mut max_qin = 0usize;
        let mut save_depth = 0usize;
        for op in &spec.ops {
            let in_shape = sh;
            let kind = match op {
                Op::Conv(cv) => {
                    sh = conv_shape(cv, sh, &mut max_cols, &mut max_acc, &mut max_qin);
                    StepKind::Conv(Box::new(lower_conv(cv, params, qc)))
                }
                Op::MaxPool2 => {
                    assert!(!sh.flat, "maxpool expects NHWC input");
                    // Fail fast here instead of mid-batch: the 2×2/stride-2
                    // kernel (like the scalar reference) assumes even dims.
                    assert!(
                        sh.h % 2 == 0 && sh.w % 2 == 0,
                        "maxpool2 requires even dims, got {}x{}",
                        sh.h,
                        sh.w
                    );
                    sh = Shape {
                        h: sh.h / 2,
                        w: sh.w / 2,
                        c: sh.c,
                        flat: false,
                    };
                    StepKind::MaxPool2
                }
                Op::Gap => {
                    assert!(!sh.flat, "gap expects NHWC input");
                    sh = Shape {
                        h: 1,
                        w: 1,
                        c: sh.c,
                        flat: true,
                    };
                    StepKind::Gap
                }
                Op::Flatten => {
                    sh = Shape {
                        h: 1,
                        w: 1,
                        c: sh.numel(),
                        flat: true,
                    };
                    StepKind::Flatten
                }
                Op::Save => {
                    saved.push(sh);
                    save_depth = save_depth.max(saved.len());
                    StepKind::Save
                }
                Op::AddSaved { relu, proj } => {
                    let skip = saved.pop().expect("unbalanced save/add");
                    let proj_step = proj.as_ref().map(|p| {
                        let after = conv_shape(p, skip, &mut max_cols, &mut max_acc, &mut max_qin);
                        assert_eq!(after.numel(), sh.numel(), "{}: skip shape mismatch", p.name);
                        max_tensor = max_tensor.max(after.numel());
                        Box::new(lower_conv(p, params, qc))
                    });
                    if proj_step.is_none() {
                        assert_eq!(skip.numel(), sh.numel(), "skip shape mismatch");
                    }
                    StepKind::AddSaved {
                        relu: *relu,
                        proj: proj_step,
                    }
                }
                Op::Fc(fc) => {
                    assert!(sh.flat, "{}: fc expects flattened input", fc.name);
                    assert_eq!(sh.c, fc.din, "{}: din mismatch", fc.name);
                    sh = Shape {
                        h: 1,
                        w: 1,
                        c: fc.dout,
                        flat: true,
                    };
                    StepKind::Fc(Box::new(lower_fc(fc, params, qc)))
                }
            };
            max_tensor = max_tensor.max(sh.numel());
            steps.push(Step {
                kind,
                shape: in_shape,
            });
        }
        assert!(saved.is_empty(), "unbalanced save/add");
        Plan {
            quant_on: qc.quant_on,
            act_scales: qc.act_scales.clone(),
            n_q: spec.n_q,
            n_classes: sh.numel(),
            steps,
            max_tensor,
            max_cols,
            max_acc,
            max_qin,
            save_depth,
        }
    }

    /// Pack-time block sparsity of every quantized conv, as
    /// `(conv_idx, summary)` sorted by conv index.  Empty on float
    /// plans (no packed panels exist).
    pub fn conv_sparsity(&self) -> Vec<(usize, BlockSparsity)> {
        let mut out: Vec<(usize, BlockSparsity)> = Vec::new();
        let mut push = |cs: &ConvStep| {
            if let ConvWeights::Quant { wb, .. } = &cs.weights {
                out.push((cs.op.conv_idx, wb.sparsity()));
            }
        };
        for step in &self.steps {
            match &step.kind {
                StepKind::Conv(cs) => push(cs),
                StepKind::AddSaved { proj: Some(cs), .. } => push(cs),
                _ => {}
            }
        }
        out.sort_by_key(|&(idx, _)| idx);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::tests_support::tiny_spec;
    use super::*;
    use crate::model::Params;

    #[test]
    fn compiles_tiny_spec_shapes() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 1);
        let plan = Plan::compile(&spec, &p.tensors, &QuantConfig::float(&spec));
        assert_eq!(plan.steps.len(), spec.ops.len());
        assert_eq!(plan.n_classes, 4);
        assert_eq!(plan.save_depth, 1);
        assert!(!plan.quant_on);
        // conv0: 32*32 rows × 27 cols is the largest im2col.
        assert_eq!(plan.max_cols, 32 * 32 * 27);
        assert!(plan.max_tensor >= 32 * 32 * 4);
    }

    #[test]
    fn quant_plan_prepacks_weights() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 2);
        let qc = QuantConfig::quantized(&spec, vec![0.01; spec.n_q]);
        let plan = Plan::compile(&spec, &p.tensors, &qc);
        assert!(plan.quant_on);
        let StepKind::Conv(cs) = &plan.steps[0].kind else {
            panic!("step 0 must be a conv");
        };
        let ConvWeights::Quant { wq, wb, s_w } = &cs.weights else {
            panic!("quant plan must prequantize");
        };
        assert_eq!(wq.len(), 27 * 4);
        assert_eq!((wb.k, wb.n), (27, 4));
        assert!(*s_w > 0.0);
    }

    /// `conv_sparsity` covers every quantized conv (including residual
    /// projections) in conv-index order, and is empty on float plans.
    #[test]
    fn plan_reports_block_sparsity() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 3);
        let fplan = Plan::compile(&spec, &p.tensors, &QuantConfig::float(&spec));
        assert!(fplan.conv_sparsity().is_empty());
        let qc = QuantConfig::quantized(&spec, vec![0.01; spec.n_q]);
        let plan = Plan::compile(&spec, &p.tensors, &qc);
        let sp = plan.conv_sparsity();
        assert_eq!(sp.len(), spec.n_conv);
        for (i, (idx, s)) in sp.iter().enumerate() {
            assert_eq!(*idx, i);
            assert!(s.blocks_total > 0);
            assert!(s.blocks_empty <= s.blocks_total);
        }
    }
}
