//! int8 mirror inference engine.
//!
//! Bit-level mirror of the QAT forward in `python/compile/model.py`:
//! the same symmetric int8 scheme, the same im2col layout ((ky, kx, c)
//! patch order), the same candidate-set projection — but with exact i32
//! accumulation instead of f32.  Logit agreement with the AOT `logits`
//! graph is pinned by `tests/integration_runtime.rs`.
//!
//! Besides logits, the engine captures per-conv im2col code matrices
//! (`ConvCapture`), which are exactly the operand streams the 64×64
//! weight-stationary systolic array consumes — the raw material for the
//! layer statistics (§3.1.2) and tile power simulation (§3.2).

use super::spec::{ConvOp, ModelSpec, Op};
use crate::quant::{self, WeightSet};

/// Quantization configuration for a forward pass.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// Per-quant-point activation scales (len `n_q`); ignored when
    /// `quant_on` is false.
    pub act_scales: Vec<f32>,
    pub quant_on: bool,
    /// Per-conv pruning masks (None = dense).
    pub masks: Vec<Option<Vec<f32>>>,
    /// Per-conv restricted weight sets (None = unrestricted).
    pub wsets: Vec<Option<WeightSet>>,
}

impl QuantConfig {
    pub fn float(spec: &ModelSpec) -> Self {
        Self {
            act_scales: vec![1.0; spec.n_q],
            quant_on: false,
            masks: vec![None; spec.n_conv],
            wsets: vec![None; spec.n_conv],
        }
    }

    pub fn quantized(spec: &ModelSpec, act_scales: Vec<f32>) -> Self {
        assert_eq!(act_scales.len(), spec.n_q);
        Self {
            act_scales,
            quant_on: true,
            masks: vec![None; spec.n_conv],
            wsets: vec![None; spec.n_conv],
        }
    }
}

/// Captured operands of one conv layer's im2col matmul
/// `Y(M×N) = X(M×K) · W(K×N)` in int8 code space.
#[derive(Clone)]
pub struct ConvCapture {
    pub conv_idx: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Row-major M×K activation codes.
    pub x_codes: Vec<i8>,
    /// Row-major K×N weight codes.
    pub w_codes: Vec<i8>,
    pub s_act: f32,
    pub s_w: f32,
}

/// Inference engine bound to a spec.
pub struct Engine<'s> {
    pub spec: &'s ModelSpec,
}

/// Forward output: logits plus optional captures / activation maxima.
pub struct Forward {
    pub logits: Vec<f32>, // batch × n_classes, row major
    pub batch: usize,
    /// Max |activation| per quant point (calibration support).
    pub act_max: Vec<f32>,
    /// Captures per conv (present when requested).
    pub captures: Vec<ConvCapture>,
}

impl Forward {
    /// Index of the largest logit in `row`.  Ties break
    /// **deterministically to the lowest index** (strict `>` never
    /// replaces an equal earlier maximum), so accuracy numbers are
    /// reproducible across engines and thread counts even when
    /// quantized logits collide exactly.
    pub fn argmax(&self, row: usize) -> usize {
        let ncls = self.logits.len() / self.batch;
        let r = &self.logits[row * ncls..(row + 1) * ncls];
        let mut best = 0;
        for i in 1..ncls {
            if r[i] > r[best] {
                best = i;
            }
        }
        best
    }

    pub fn accuracy(&self, labels: &[i32]) -> f64 {
        assert_eq!(labels.len(), self.batch);
        let correct = labels
            .iter()
            .enumerate()
            .filter(|(i, &y)| self.argmax(*i) == y as usize)
            .count();
        correct as f64 / self.batch as f64
    }
}

/// A tensor traveling through the network (NHWC) or flattened (N×D).
#[derive(Clone)]
struct Tensor {
    data: Vec<f32>,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    flat: bool,
}

impl Tensor {
    fn nhwc(data: Vec<f32>, n: usize, h: usize, w: usize, c: usize) -> Self {
        assert_eq!(data.len(), n * h * w * c);
        Tensor {
            data,
            n,
            h,
            w,
            c,
            flat: false,
        }
    }
    fn flat(data: Vec<f32>, n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d);
        Tensor {
            data,
            n,
            h: 1,
            w: 1,
            c: d,
            flat: true,
        }
    }
}

impl<'s> Engine<'s> {
    pub fn new(spec: &'s ModelSpec) -> Self {
        Self { spec }
    }

    /// Run a forward pass over a batch (`x`: NHWC f32 in [-1, 1]).
    /// `capture` collects im2col operands for every conv layer.
    pub fn forward(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        batch: usize,
        qc: &QuantConfig,
        capture: bool,
    ) -> Forward {
        let spec = self.spec;
        use super::spec::{INPUT_C, INPUT_ELEMS, INPUT_H, INPUT_W};
        assert_eq!(x.len(), batch * INPUT_ELEMS);
        let mut cur = Tensor::nhwc(x.to_vec(), batch, INPUT_H, INPUT_W, INPUT_C);
        let mut saved: Vec<Tensor> = Vec::new();
        let mut act_max = vec![0.0f32; spec.n_q];
        let mut captures = Vec::new();

        for op in &spec.ops {
            match op {
                Op::Conv(cv) => {
                    cur = self.conv(
                        cv, &cur, params, qc, capture, &mut act_max, &mut captures,
                    );
                }
                Op::MaxPool2 => {
                    let (n, h, w, c) = (cur.n, cur.h, cur.w, cur.c);
                    let (ho, wo) = (h / 2, w / 2);
                    let mut out = vec![f32::NEG_INFINITY; n * ho * wo * c];
                    for b in 0..n {
                        for y in 0..h {
                            for xx in 0..w {
                                let src = &cur.data[((b * h + y) * w + xx) * c..][..c];
                                let dst_idx = ((b * ho + y / 2) * wo + xx / 2) * c;
                                for ch in 0..c {
                                    let d = &mut out[dst_idx + ch];
                                    if src[ch] > *d {
                                        *d = src[ch];
                                    }
                                }
                            }
                        }
                    }
                    cur = Tensor::nhwc(out, n, ho, wo, c);
                }
                Op::Gap => {
                    let (n, h, w, c) = (cur.n, cur.h, cur.w, cur.c);
                    let mut out = vec![0.0f32; n * c];
                    for b in 0..n {
                        for y in 0..h {
                            for xx in 0..w {
                                let src = &cur.data[((b * h + y) * w + xx) * c..][..c];
                                for ch in 0..c {
                                    out[b * c + ch] += src[ch];
                                }
                            }
                        }
                    }
                    let inv = 1.0 / (h * w) as f32;
                    out.iter_mut().for_each(|v| *v *= inv);
                    cur = Tensor::flat(out, n, c);
                }
                Op::Flatten => {
                    let d = cur.h * cur.w * cur.c;
                    let n = cur.n;
                    cur = Tensor::flat(std::mem::take(&mut cur.data), n, d);
                }
                Op::Save => saved.push(cur.clone()),
                Op::AddSaved { relu, proj } => {
                    let mut skip = saved.pop().expect("unbalanced save/add");
                    if let Some(p) = proj {
                        skip = self.conv(
                            p, &skip, params, qc, capture, &mut act_max, &mut captures,
                        );
                    }
                    assert_eq!(skip.data.len(), cur.data.len());
                    for (a, &b) in cur.data.iter_mut().zip(&skip.data) {
                        *a += b;
                    }
                    if *relu {
                        cur.data.iter_mut().for_each(|v| *v = v.max(0.0));
                    }
                }
                Op::Fc(fc) => {
                    assert!(cur.flat, "fc expects flattened input");
                    let n = cur.n;
                    let din = fc.din;
                    let dout = fc.dout;
                    assert_eq!(cur.c, din);
                    let amax = cur.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    act_max[fc.q_idx] = act_max[fc.q_idx].max(amax);
                    let wt = &params[fc.w];
                    let bt = &params[fc.b];
                    let mut out = vec![0.0f32; n * dout];
                    if qc.quant_on {
                        let s_a = qc.act_scales[fc.q_idx];
                        let (wq, s_w) = quant::quantize_restricted(wt, None, None);
                        let xq: Vec<i8> = cur
                            .data
                            .iter()
                            .map(|&v| quant::quantize(v, s_a) as i8)
                            .collect();
                        for b in 0..n {
                            for o in 0..dout {
                                let mut acc = 0i32;
                                let wrow = &wq[o * din..(o + 1) * din];
                                let xrow = &xq[b * din..(b + 1) * din];
                                for i in 0..din {
                                    acc += xrow[i] as i32 * wrow[i] as i32;
                                }
                                out[b * dout + o] = s_a * s_w * acc as f32 + bt[o];
                            }
                        }
                    } else {
                        for b in 0..n {
                            for o in 0..dout {
                                let mut acc = 0.0f32;
                                let wrow = &wt[o * din..(o + 1) * din];
                                let xrow = &cur.data[b * din..(b + 1) * din];
                                for i in 0..din {
                                    acc += xrow[i] * wrow[i];
                                }
                                out[b * dout + o] = acc + bt[o];
                            }
                        }
                    }
                    if fc.relu {
                        out.iter_mut().for_each(|v| *v = v.max(0.0));
                    }
                    cur = Tensor::flat(out, n, dout);
                }
            }
        }
        Forward {
            logits: cur.data,
            batch,
            act_max,
            captures,
        }
    }

    /// im2col of an NHWC tensor of quantized codes; (ky, kx, c) patch
    /// column order matching `ref.im2col` on the JAX side.
    fn im2col_codes(t: &[i8], n: usize, h: usize, w: usize, c: usize, cv: &ConvOp) -> Vec<i8> {
        let (ho, wo, k, s, p) = (cv.hout, cv.wout, cv.k, cv.stride, cv.pad as isize);
        let m = n * ho * wo;
        let kk = k * k * c;
        let mut out = vec![0i8; m * kk];
        for b in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = (b * ho + oy) * wo + ox;
                    let base = row * kk;
                    for ky in 0..k {
                        let iy = (oy * s) as isize + ky as isize - p;
                        for kx in 0..k {
                            let ix = (ox * s) as isize + kx as isize - p;
                            let col0 = (ky * k + kx) * c;
                            if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                continue; // zero padding
                            }
                            let src = ((b * h + iy as usize) * w + ix as usize) * c;
                            out[base + col0..base + col0 + c]
                                .copy_from_slice(&t[src..src + c]);
                        }
                    }
                }
            }
        }
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        cv: &ConvOp,
        cur: &Tensor,
        params: &[Vec<f32>],
        qc: &QuantConfig,
        capture: bool,
        act_max: &mut [f32],
        captures: &mut Vec<ConvCapture>,
    ) -> Tensor {
        let (n, h, w, c) = (cur.n, cur.h, cur.w, cur.c);
        assert_eq!(c, cv.cin, "{}: cin mismatch", cv.name);
        assert_eq!((h, w), (cv.hin, cv.win), "{}: spatial mismatch", cv.name);
        let amax = cur.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        act_max[cv.q_idx] = act_max[cv.q_idx].max(amax);

        let wt = &params[cv.w];
        let bt = &params[cv.b];
        let (m, kk, nn) = cv.matmul_dims(n);
        let mut out = vec![0.0f32; m * nn];

        if qc.quant_on {
            let s_a = qc.act_scales[cv.q_idx];
            let mask = qc.masks[cv.conv_idx].as_deref();
            let set = qc.wsets[cv.conv_idx].as_ref();
            let (w_oihw, s_w) = quant::quantize_restricted(wt, mask, set);
            // Reorder OIHW codes -> K×N ((ky,kx,ci) rows, cout cols).
            let mut w_codes = vec![0i8; kk * nn];
            for o in 0..cv.cout {
                for ci in 0..cv.cin {
                    for ky in 0..cv.k {
                        for kx in 0..cv.k {
                            let src = ((o * cv.cin + ci) * cv.k + ky) * cv.k + kx;
                            let row = (ky * cv.k + kx) * cv.cin + ci;
                            w_codes[row * nn + o] = w_oihw[src];
                        }
                    }
                }
            }
            let x_nhwc: Vec<i8> = cur
                .data
                .iter()
                .map(|&v| quant::quantize(v, s_a) as i8)
                .collect();
            let x_codes = Self::im2col_codes(&x_nhwc, n, h, w, c, cv);
            // Integer matmul with exact i32 accumulation (the i32 sum —
            // not an f32-accumulated approximation of it — so the result
            // is independent of summation order and the blocked parallel
            // executor can be pinned bit-identical against it).
            let mut acc = vec![0i32; m * nn];
            for r in 0..m {
                let xrow = &x_codes[r * kk..(r + 1) * kk];
                let arow = &mut acc[r * nn..(r + 1) * nn];
                for (i, &xc) in xrow.iter().enumerate() {
                    if xc == 0 {
                        continue;
                    }
                    let wrow = &w_codes[i * nn..(i + 1) * nn];
                    let xv = xc as i32;
                    for (a, &wc) in arow.iter_mut().zip(wrow) {
                        *a += xv * wc as i32;
                    }
                }
            }
            let ss = s_a * s_w;
            for r in 0..m {
                for o in 0..nn {
                    out[r * nn + o] = acc[r * nn + o] as f32 * ss + bt[o];
                }
            }
            if capture {
                captures.push(ConvCapture {
                    conv_idx: cv.conv_idx,
                    m,
                    k: kk,
                    n: nn,
                    x_codes,
                    w_codes,
                    s_act: s_a,
                    s_w,
                });
            }
        } else {
            // Float path (calibration): direct convolution.
            let (k, s, p) = (cv.k, cv.stride, cv.pad as isize);
            for b in 0..n {
                for oy in 0..cv.hout {
                    for ox in 0..cv.wout {
                        let row = (b * cv.hout + oy) * cv.wout + ox;
                        let orow = &mut out[row * nn..(row + 1) * nn];
                        for ky in 0..k {
                            let iy = (oy * s) as isize + ky as isize - p;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox * s) as isize + kx as isize - p;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let src = ((b * h + iy as usize) * w + ix as usize) * c;
                                for ci in 0..c {
                                    let xv = cur.data[src + ci];
                                    if xv == 0.0 {
                                        continue;
                                    }
                                    for o in 0..nn {
                                        orow[o] += xv
                                            * wt[((o * c + ci) * k + ky) * k + kx];
                                    }
                                }
                            }
                        }
                        for o in 0..nn {
                            orow[o] += bt[o];
                        }
                    }
                }
            }
        }
        if cv.relu {
            out.iter_mut().for_each(|v| *v = v.max(0.0));
        }
        Tensor::nhwc(out, n, cv.hout, cv.wout, cv.cout)
    }

    /// Calibrate activation scales: float forward over `batches`, scale =
    /// max|act| / 127 per quant point (what the AOT `calib` graph returns,
    /// reproduced natively).
    ///
    /// Delegates to the compiled executor
    /// ([`super::engine::ParallelEngine::calibrate`]), which builds one
    /// forward scratch and reuses it across the whole batch loop instead
    /// of re-allocating every tensor per image; bit-identical to the
    /// historical per-forward fold (max-merge of per-image maxima).
    pub fn calibrate(&self, params: &[Vec<f32>], xs: &[&[f32]], batch: usize) -> Vec<f32> {
        let qc = QuantConfig::float(self.spec);
        super::engine::ParallelEngine::new(self.spec, params, &qc, 1).calibrate(xs, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::tests_support::tiny_spec;
    use super::*;
    use crate::model::Params;

    fn input(batch: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..batch * 32 * 32 * 3)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect()
    }

    #[test]
    fn argmax_breaks_ties_to_lowest_index() {
        // Row 0: duplicate maxima at 1 and 3 -> must pick 1.
        // Row 1: all equal -> must pick 0.
        let f = Forward {
            logits: vec![0.5, 2.0, -1.0, 2.0, 7.0, 7.0, 7.0, 7.0],
            batch: 2,
            act_max: vec![],
            captures: vec![],
        };
        assert_eq!(f.argmax(0), 1);
        assert_eq!(f.argmax(1), 0);
        assert_eq!(f.accuracy(&[1, 0]), 1.0);
    }

    #[test]
    fn float_forward_shapes() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 1);
        let eng = Engine::new(&spec);
        let f = eng.forward(&p.tensors, &input(2, 7), 2, &QuantConfig::float(&spec), false);
        assert_eq!(f.logits.len(), 2 * 4);
        assert_eq!(f.act_max.len(), 3);
        assert!(f.act_max.iter().all(|&m| m > 0.0));
    }

    #[test]
    fn quantized_close_to_float() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 2);
        let eng = Engine::new(&spec);
        let x = input(2, 8);
        let scales = eng.calibrate(&p.tensors, &[&x], 2);
        let ff = eng.forward(&p.tensors, &x, 2, &QuantConfig::float(&spec), false);
        let fq = eng.forward(
            &p.tensors,
            &x,
            2,
            &QuantConfig::quantized(&spec, scales),
            false,
        );
        let max_logit = ff.logits.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (a, b) in ff.logits.iter().zip(&fq.logits) {
            assert!(
                (a - b).abs() < 0.15 * max_logit.max(1.0),
                "float {a} vs quant {b}"
            );
        }
    }

    #[test]
    fn captures_match_dims_and_feed_matmul() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 3);
        let eng = Engine::new(&spec);
        let x = input(1, 9);
        let scales = eng.calibrate(&p.tensors, &[&x], 1);
        let f = eng.forward(
            &p.tensors,
            &x,
            1,
            &QuantConfig::quantized(&spec, scales),
            true,
        );
        assert_eq!(f.captures.len(), 2);
        let c0 = &f.captures[0];
        assert_eq!((c0.m, c0.k, c0.n), (32 * 32, 27, 4));
        assert_eq!(c0.x_codes.len(), c0.m * c0.k);
        assert_eq!(c0.w_codes.len(), c0.k * c0.n);
    }

    #[test]
    fn pruning_mask_zeroes_outputs() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 4);
        let eng = Engine::new(&spec);
        let x = input(1, 10);
        let scales = eng.calibrate(&p.tensors, &[&x], 1);
        let mut qc = QuantConfig::quantized(&spec, scales);
        // Prune everything in conv0 -> its capture weight codes all zero.
        qc.masks[0] = Some(vec![0.0; spec.params[0].numel()]);
        let f = eng.forward(&p.tensors, &x, 1, &qc, true);
        assert!(f.captures[0].w_codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn weight_set_restricts_codes() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 5);
        let eng = Engine::new(&spec);
        let x = input(1, 11);
        let scales = eng.calibrate(&p.tensors, &[&x], 1);
        let mut qc = QuantConfig::quantized(&spec, scales);
        let set = crate::quant::WeightSet::new(vec![-64, 0, 64]);
        qc.wsets[0] = Some(set.clone());
        let f = eng.forward(&p.tensors, &x, 1, &qc, true);
        assert!(f.captures[0]
            .w_codes
            .iter()
            .all(|&c| set.contains(c as i32)));
    }
}
