//! Parameter storage: the flat little-endian f32 blob written by
//! `aot.py` (`params.bin`), addressed through the manifest's param list.

use super::spec::ModelSpec;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Model parameters as one tensor per `ParamSpec`, in manifest order.
#[derive(Clone, Debug)]
pub struct Params {
    pub tensors: Vec<Vec<f32>>,
}

impl Params {
    /// Load `params.bin` (concatenated f32 LE in param order).
    pub fn load(spec: &ModelSpec, path: &Path) -> Result<Params> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let expect = spec.n_param_elems() * 4;
        if bytes.len() != expect {
            bail!(
                "params.bin size {} != expected {} ({} elems)",
                bytes.len(),
                expect,
                spec.n_param_elems()
            );
        }
        let mut tensors = Vec::with_capacity(spec.params.len());
        let mut off = 0usize;
        for p in &spec.params {
            let n = p.numel();
            let mut t = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[off + i * 4..off + i * 4 + 4];
                t.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n * 4;
            tensors.push(t);
        }
        Ok(Params { tensors })
    }

    /// Save back to the same blob format (checkpoints of trained /
    /// compressed models).
    pub fn save(&self, spec: &ModelSpec, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(spec.n_param_elems() * 4);
        for (t, p) in self.tensors.iter().zip(&spec.params) {
            assert_eq!(t.len(), p.numel(), "tensor/spec mismatch for {}", p.name);
            for &v in t {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Deterministic random params for tests (He-like scaling).
    pub fn random(spec: &ModelSpec, seed: u64) -> Params {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(seed);
        let tensors = spec
            .params
            .iter()
            .map(|p| {
                let fan_in = match p.kind {
                    super::spec::ParamKind::ConvW => {
                        p.shape[1] * p.shape[2] * p.shape[3]
                    }
                    super::spec::ParamKind::FcW => p.shape[1],
                    super::spec::ParamKind::Bias => 1,
                };
                let scale = if matches!(p.kind, super::spec::ParamKind::Bias) {
                    0.0
                } else {
                    (2.0 / fan_in as f32).sqrt()
                };
                (0..p.numel())
                    .map(|_| {
                        // Approximate normal via sum of uniforms (CLT).
                        let u: f32 = (0..4).map(|_| rng.range_f32(-0.5, 0.5)).sum();
                        scale * u
                    })
                    .collect()
            })
            .collect();
        Params { tensors }
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::tests_support::tiny_spec;
    use super::*;

    #[test]
    fn roundtrip_blob() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 3);
        let dir = std::env::temp_dir().join("wsel_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        p.save(&spec, &path).unwrap();
        let q = Params::load(&spec, &path).unwrap();
        assert_eq!(p.tensors, q.tensors);
    }

    #[test]
    fn load_rejects_wrong_size() {
        let spec = tiny_spec();
        let dir = std::env::temp_dir().join("wsel_params_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 12]).unwrap();
        assert!(Params::load(&spec, &path).is_err());
    }
}
