//! Parameter storage: the flat little-endian f32 blob written by
//! `aot.py` (`params.bin`), addressed through the manifest's param list.

use super::spec::ModelSpec;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Model parameters as one tensor per `ParamSpec`, in manifest order.
#[derive(Clone, Debug)]
pub struct Params {
    pub tensors: Vec<Vec<f32>>,
}

impl Params {
    /// Load `params.bin` (concatenated f32 LE in param order).  Blobs
    /// saved by [`Params::save`] carry the checksummed artifact header
    /// (corruption fails here with path + reason); headerless blobs
    /// written by `aot.py` load as legacy payloads.
    pub fn load(spec: &ModelSpec, path: &Path) -> Result<Params> {
        let bytes = crate::util::artifact::load(path)
            .with_context(|| format!("loading params {}", path.display()))?;
        let expect = spec.n_param_elems() * 4;
        if bytes.len() != expect {
            bail!(
                "params.bin size {} != expected {} ({} elems)",
                bytes.len(),
                expect,
                spec.n_param_elems()
            );
        }
        let mut tensors = Vec::with_capacity(spec.params.len());
        let mut off = 0usize;
        for p in &spec.params {
            let n = p.numel();
            let mut t = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[off + i * 4..off + i * 4 + 4];
                t.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n * 4;
            tensors.push(t);
        }
        Ok(Params { tensors })
    }

    /// Save back to the same blob format (checkpoints of trained /
    /// compressed models), atomically and under a checksummed header.
    pub fn save(&self, spec: &ModelSpec, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(spec.n_param_elems() * 4);
        for (t, p) in self.tensors.iter().zip(&spec.params) {
            assert_eq!(t.len(), p.numel(), "tensor/spec mismatch for {}", p.name);
            for &v in t {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        crate::util::artifact::write_atomic(path, &bytes)
            .with_context(|| format!("writing params {}", path.display()))?;
        Ok(())
    }

    /// Training initialization for the native backend, mirroring
    /// `python/compile/model.py::init_params`: He-normal weights, zero
    /// biases, and fixup-lite 0.2× scaling of the final conv in each
    /// residual branch (the quantized mirror has no batch norm, so deep
    /// nets need tamed residual branches to train).  The PRNG differs
    /// from JAX's, so the draws are not bit-equal to `params.bin` — the
    /// distribution and structure are.
    pub fn init_train(spec: &ModelSpec, seed: u64) -> Params {
        use super::spec::Op;
        use crate::util::rng::Xoshiro256;
        // Weight tensors of the conv immediately preceding each
        // residual add (same backward scan as the Python side).
        let mut last_before_add = std::collections::HashSet::new();
        for (i, op) in spec.ops.iter().enumerate() {
            if matches!(op, Op::AddSaved { .. }) {
                for j in (0..i).rev() {
                    if let Op::Conv(c) = &spec.ops[j] {
                        last_before_add.insert(c.w);
                        break;
                    }
                }
            }
        }
        let mut rng = Xoshiro256::new(seed);
        let tensors = spec
            .params
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let (fan_in, is_bias) = match p.kind {
                    super::spec::ParamKind::ConvW => {
                        (p.shape[1] * p.shape[2] * p.shape[3], false)
                    }
                    super::spec::ParamKind::FcW => (p.shape[1], false),
                    super::spec::ParamKind::Bias => (1, true),
                };
                if is_bias {
                    return vec![0.0f32; p.numel()];
                }
                let mut scale = (2.0 / fan_in as f32).sqrt();
                if last_before_add.contains(&i) {
                    scale *= 0.2;
                }
                (0..p.numel())
                    .map(|_| {
                        // Unit-variance normal approximation: sum of 12
                        // U(-0.5, 0.5) draws (Irwin–Hall).
                        let u: f32 = (0..12).map(|_| rng.range_f32(-0.5, 0.5)).sum();
                        scale * u
                    })
                    .collect()
            })
            .collect();
        Params { tensors }
    }

    /// Deterministic random params for tests (He-like scaling).
    pub fn random(spec: &ModelSpec, seed: u64) -> Params {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(seed);
        let tensors = spec
            .params
            .iter()
            .map(|p| {
                let fan_in = match p.kind {
                    super::spec::ParamKind::ConvW => {
                        p.shape[1] * p.shape[2] * p.shape[3]
                    }
                    super::spec::ParamKind::FcW => p.shape[1],
                    super::spec::ParamKind::Bias => 1,
                };
                let scale = if matches!(p.kind, super::spec::ParamKind::Bias) {
                    0.0
                } else {
                    (2.0 / fan_in as f32).sqrt()
                };
                (0..p.numel())
                    .map(|_| {
                        // Approximate normal via sum of uniforms (CLT).
                        let u: f32 = (0..4).map(|_| rng.range_f32(-0.5, 0.5)).sum();
                        scale * u
                    })
                    .collect()
            })
            .collect();
        Params { tensors }
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::tests_support::tiny_spec;
    use super::*;

    #[test]
    fn roundtrip_blob() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 3);
        let dir = std::env::temp_dir().join("wsel_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        p.save(&spec, &path).unwrap();
        let q = Params::load(&spec, &path).unwrap();
        assert_eq!(p.tensors, q.tensors);
    }

    #[test]
    fn init_train_structure() {
        let spec = super::super::spec::ModelSpec::builtin("resnet20").unwrap();
        let p = Params::init_train(&spec, 3);
        assert_eq!(p.tensors.len(), spec.params.len());
        let mut damped = 0usize;
        for (t, ps) in p.tensors.iter().zip(&spec.params) {
            assert_eq!(t.len(), ps.numel());
            match ps.kind {
                super::super::spec::ParamKind::Bias => {
                    assert!(t.iter().all(|&v| v == 0.0));
                }
                super::super::spec::ParamKind::ConvW => {
                    let fan_in: usize = ps.shape[1] * ps.shape[2] * ps.shape[3];
                    let std =
                        (t.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / t.len() as f64)
                            .sqrt();
                    let he = (2.0 / fan_in as f64).sqrt();
                    // Either full He scale or the 0.2× fixup-lite branch.
                    if std < 0.5 * he {
                        damped += 1;
                        assert!((std - 0.2 * he).abs() < 0.1 * he, "{}: std {std}", ps.name);
                    } else {
                        assert!((std - he).abs() < 0.35 * he, "{}: std {std}", ps.name);
                    }
                }
                _ => {}
            }
        }
        // One damped conv per residual block.
        assert_eq!(damped, 9);
        // Deterministic.
        let q = Params::init_train(&spec, 3);
        assert_eq!(p.tensors, q.tensors);
    }

    #[test]
    fn load_rejects_wrong_size() {
        let spec = tiny_spec();
        let dir = std::env::temp_dir().join("wsel_params_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 12]).unwrap();
        assert!(Params::load(&spec, &path).is_err());
    }

    #[test]
    fn load_rejects_bit_flipped_blob() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 5);
        let dir = std::env::temp_dir().join("wsel_params_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        p.save(&spec, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 1;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:?}", Params::load(&spec, &path).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("c.bin"), "{err}");
    }
}
