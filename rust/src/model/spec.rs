//! Model specification, parsed from `artifacts/<model>/manifest.json`.
//!
//! The manifest is written by `python/compile/aot.py` from the very spec
//! the JAX graphs were lowered from, so shapes, parameter order, conv and
//! quant-point indices here are *definitionally* consistent with the HLO
//! artifacts.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Fixed network input dims (NHWC) — the synthetic-CIFAR workload every
/// manifest targets.  Single source of truth for the engines' input
/// slicing and the IR's shape chain.
pub const INPUT_H: usize = 32;
pub const INPUT_W: usize = 32;
pub const INPUT_C: usize = 3;
/// Elements of one input image.
pub const INPUT_ELEMS: usize = INPUT_H * INPUT_W * INPUT_C;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    ConvW,
    FcW,
    Bias,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Convolution op (also used for residual projection convs).
#[derive(Clone, Debug)]
pub struct ConvOp {
    pub name: String,
    pub w: usize,
    pub b: usize,
    pub conv_idx: usize,
    pub q_idx: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub hin: usize,
    pub win: usize,
    pub hout: usize,
    pub wout: usize,
}

impl ConvOp {
    /// im2col matrix dims for batch `n`: (M, K, N) of Y(M×N) = X(M×K)·W(K×N).
    pub fn matmul_dims(&self, n: usize) -> (usize, usize, usize) {
        (
            n * self.hout * self.wout,
            self.k * self.k * self.cin,
            self.cout,
        )
    }

    /// MAC count for batch `n`.
    pub fn macs(&self, n: usize) -> u64 {
        let (m, k, nn) = self.matmul_dims(n);
        m as u64 * k as u64 * nn as u64
    }
}

#[derive(Clone, Debug)]
pub struct FcOp {
    pub name: String,
    pub w: usize,
    pub b: usize,
    pub q_idx: usize,
    pub din: usize,
    pub dout: usize,
    pub relu: bool,
}

#[derive(Clone, Debug)]
pub enum Op {
    Conv(ConvOp),
    MaxPool2,
    Gap,
    Flatten,
    Save,
    AddSaved { relu: bool, proj: Option<ConvOp> },
    Fc(FcOp),
}

/// Entry-point metadata (input arity used for runtime sanity checks).
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub file: String,
    pub n_inputs: usize,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub n_classes: usize,
    pub ops: Vec<Op>,
    pub params: Vec<ParamSpec>,
    pub n_conv: usize,
    pub n_q: usize,
    pub kset: usize,
    pub seed: u64,
    /// SGD momentum coefficient baked into the AOT `train` graph; the
    /// native backend reads it from here so both backends train with
    /// the same recipe.
    pub momentum: f32,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub batch_logits: usize,
    pub batch_calib: usize,
    pub pallas_eval: bool,
    pub entries: Vec<(String, EntryMeta)>,
}

fn parse_conv(op: &Json) -> Result<ConvOp> {
    Ok(ConvOp {
        name: op.req_str("name").to_string(),
        w: op.req_usize("w"),
        b: op.req_usize("b"),
        conv_idx: op.req_usize("conv_idx"),
        q_idx: op.req_usize("q_idx"),
        cin: op.req_usize("cin"),
        cout: op.req_usize("cout"),
        k: op.req_usize("k"),
        stride: op.req_usize("stride"),
        pad: op.req_usize("pad"),
        relu: op.get("relu").and_then(Json::as_bool).unwrap_or(false),
        hin: op.req_usize("hin"),
        win: op.req_usize("win"),
        hout: op.req_usize("hout"),
        wout: op.req_usize("wout"),
    })
}

impl ModelSpec {
    pub fn from_manifest_str(text: &str) -> Result<ModelSpec> {
        let j = Json::parse(text).context("manifest.json parse")?;
        let mut params = Vec::new();
        for p in j.req_arr("params") {
            let kind = match p.req_str("kind") {
                "conv_w" => ParamKind::ConvW,
                "fc_w" => ParamKind::FcW,
                "bias" => ParamKind::Bias,
                other => bail!("unknown param kind {other}"),
            };
            params.push(ParamSpec {
                name: p.req_str("name").to_string(),
                shape: p
                    .req_arr("shape")
                    .iter()
                    .map(|s| s.as_usize().unwrap())
                    .collect(),
                kind,
            });
        }
        let mut ops = Vec::new();
        for op in j.req_arr("ops") {
            let kind = op.req_str("op");
            ops.push(match kind {
                "conv" => Op::Conv(parse_conv(op)?),
                "maxpool2" => Op::MaxPool2,
                "gap" => Op::Gap,
                "flatten" => Op::Flatten,
                "save" => Op::Save,
                "add_saved" => Op::AddSaved {
                    relu: op.get("relu").and_then(Json::as_bool).unwrap_or(false),
                    proj: match op.get("proj") {
                        Some(Json::Null) | None => None,
                        Some(p) => Some(parse_conv(p)?),
                    },
                },
                "fc" => Op::Fc(FcOp {
                    name: op.req_str("name").to_string(),
                    w: op.req_usize("w"),
                    b: op.req_usize("b"),
                    q_idx: op.req_usize("q_idx"),
                    din: op.req_usize("din"),
                    dout: op.req_usize("dout"),
                    relu: op.get("relu").and_then(Json::as_bool).unwrap_or(false),
                }),
                other => bail!("unknown op {other}"),
            });
        }
        let batches = j.get("batches").context("batches")?;
        let mut entries = Vec::new();
        if let Some(Json::Obj(m)) = j.get("entries") {
            for (name, e) in m {
                entries.push((
                    name.clone(),
                    EntryMeta {
                        file: e.req_str("file").to_string(),
                        n_inputs: e.req_usize("n_inputs"),
                    },
                ));
            }
        }
        let spec = ModelSpec {
            name: j.req_str("model").to_string(),
            n_classes: j.req_usize("n_classes"),
            ops,
            params,
            n_conv: j.req_usize("n_conv"),
            n_q: j.req_usize("n_q"),
            kset: j.req_usize("kset"),
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            momentum: j.get("momentum").and_then(Json::as_f64).unwrap_or(0.9) as f32,
            batch_train: batches.req_usize("train"),
            batch_eval: batches.req_usize("eval"),
            batch_logits: batches.req_usize("logits"),
            batch_calib: batches.req_usize("calib"),
            pallas_eval: j.get("pallas_eval").and_then(Json::as_bool).unwrap_or(false),
            entries,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_manifest_file(path: &std::path::Path) -> Result<ModelSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_manifest_str(&text)
    }

    /// Structural consistency checks (shape chaining, index ranges).
    pub fn validate(&self) -> Result<()> {
        let mut conv_seen = vec![false; self.n_conv];
        let mut q_seen = vec![false; self.n_q];
        fn check_conv(
            spec: &ModelSpec,
            conv_seen: &mut [bool],
            q_seen: &mut [bool],
            c: &ConvOp,
        ) -> Result<()> {
            if c.w >= spec.params.len() || c.b >= spec.params.len() {
                bail!("{}: param index out of range", c.name);
            }
            let ws = &spec.params[c.w];
            if ws.shape != vec![c.cout, c.cin, c.k, c.k] {
                bail!("{}: weight shape mismatch {:?}", c.name, ws.shape);
            }
            if c.conv_idx >= spec.n_conv || c.q_idx >= spec.n_q {
                bail!("{}: conv/q index out of range", c.name);
            }
            conv_seen[c.conv_idx] = true;
            q_seen[c.q_idx] = true;
            let ho = (c.hin + 2 * c.pad - c.k) / c.stride + 1;
            if ho != c.hout {
                bail!("{}: hout mismatch", c.name);
            }
            Ok(())
        }
        for op in &self.ops {
            match op {
                Op::Conv(c) => check_conv(self, &mut conv_seen, &mut q_seen, c)?,
                Op::AddSaved { proj: Some(c), .. } => {
                    check_conv(self, &mut conv_seen, &mut q_seen, c)?
                }
                Op::Fc(f) => {
                    q_seen[f.q_idx] = true;
                    if self.params[f.w].shape != vec![f.dout, f.din] {
                        bail!("{}: fc shape mismatch", f.name);
                    }
                }
                _ => {}
            }
        }
        if !conv_seen.iter().all(|&s| s) {
            bail!("not all conv indices used");
        }
        if !q_seen.iter().all(|&s| s) {
            bail!("not all quant points used");
        }
        Ok(())
    }

    /// Total parameter element count.
    pub fn n_param_elems(&self) -> usize {
        self.params.iter().map(ParamSpec::numel).sum()
    }

    /// Conv ops in `conv_idx` order (projection convs included).
    pub fn convs(&self) -> Vec<&ConvOp> {
        let mut out: Vec<&ConvOp> = Vec::with_capacity(self.n_conv);
        for op in &self.ops {
            match op {
                Op::Conv(c) => out.push(c),
                Op::AddSaved { proj: Some(c), .. } => out.push(c),
                _ => {}
            }
        }
        out.sort_by_key(|c| c.conv_idx);
        out
    }

    /// Param indices of conv weights in conv_idx order.
    pub fn conv_weight_params(&self) -> Vec<usize> {
        self.convs().iter().map(|c| c.w).collect()
    }

    /// Human-readable layer label (e.g. for Table 2 rows).
    pub fn conv_label(&self, conv_idx: usize) -> String {
        format!("conv{conv_idx}")
    }

    /// Built-in model specs — the same three architectures
    /// `python/compile/model.py` lowers (LeNet-5, ResNet-20,
    /// ResNet-50-lite), constructed natively so the training/eval
    /// backend runs with **no artifacts at all**.  Shapes, indices and
    /// batch sizes match the AOT manifests exactly (batch sizes are the
    /// ones `aot.py` lowers: train 64, eval 128, logits 8, calib 64).
    pub fn builtin(name: &str) -> Result<ModelSpec> {
        let spec = match name {
            "lenet5" => {
                let mut b = BuiltinBuilder::new("lenet5", 10);
                b.conv(6, 5, 1, 2, true).maxpool2();
                b.conv(16, 5, 1, 0, true).maxpool2();
                b.flatten();
                b.fc(120, true).fc(84, true).fc(10, false);
                b.done()
            }
            "resnet20" => {
                let mut b = BuiltinBuilder::new("resnet20", 10);
                b.conv(16, 3, 1, 1, true);
                for (cout, stride0) in [(16usize, 1usize), (32, 2), (64, 2)] {
                    for blk in 0..3 {
                        b.basic_block(cout, if blk == 0 { stride0 } else { 1 });
                    }
                }
                b.gap().fc(10, false);
                b.done()
            }
            "resnet50lite" => {
                let mut b = BuiltinBuilder::new("resnet50lite", 100);
                b.conv(16, 3, 1, 1, true);
                for (width, stride0) in [(16usize, 1usize), (32, 2), (64, 2)] {
                    for blk in 0..3 {
                        b.bottleneck(width, if blk == 0 { stride0 } else { 1 });
                    }
                }
                b.gap().fc(100, false);
                b.done()
            }
            other => bail!("no built-in spec for `{other}` (lenet5 | resnet20 | resnet50lite)"),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Builder mirroring `python/compile/model.py::SpecBuilder`: tracks the
/// activation shape and allocates parameter / conv / quant-point
/// indices in traversal order.
struct BuiltinBuilder {
    name: String,
    n_classes: usize,
    ops: Vec<Op>,
    params: Vec<ParamSpec>,
    h: usize,
    w: usize,
    c: usize,
    flat: Option<usize>,
    n_conv: usize,
    n_q: usize,
    saved: Vec<(usize, usize, usize)>,
}

impl BuiltinBuilder {
    fn new(name: &str, n_classes: usize) -> Self {
        Self {
            name: name.to_string(),
            n_classes,
            ops: Vec::new(),
            params: Vec::new(),
            h: INPUT_H,
            w: INPUT_W,
            c: INPUT_C,
            flat: None,
            n_conv: 0,
            n_q: 0,
            saved: Vec::new(),
        }
    }

    fn param(&mut self, name: String, shape: Vec<usize>, kind: ParamKind) -> usize {
        self.params.push(ParamSpec { name, shape, kind });
        self.params.len() - 1
    }

    fn make_conv(
        &mut self,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        relu: bool,
        hin: usize,
        win: usize,
        cin: usize,
    ) -> ConvOp {
        let name = format!("conv{}", self.n_conv);
        let w = self.param(format!("{name}.w"), vec![cout, cin, k, k], ParamKind::ConvW);
        let b = self.param(format!("{name}.b"), vec![cout], ParamKind::Bias);
        let hout = (hin + 2 * pad - k) / stride + 1;
        let wout = (win + 2 * pad - k) / stride + 1;
        let op = ConvOp {
            name,
            w,
            b,
            conv_idx: self.n_conv,
            q_idx: self.n_q,
            cin,
            cout,
            k,
            stride,
            pad,
            relu,
            hin,
            win,
            hout,
            wout,
        };
        self.n_conv += 1;
        self.n_q += 1;
        op
    }

    fn conv(&mut self, cout: usize, k: usize, stride: usize, pad: usize, relu: bool) -> &mut Self {
        let (h, w, c) = (self.h, self.w, self.c);
        let op = self.make_conv(cout, k, stride, pad, relu, h, w, c);
        self.h = op.hout;
        self.w = op.wout;
        self.c = op.cout;
        self.ops.push(Op::Conv(op));
        self
    }

    fn maxpool2(&mut self) -> &mut Self {
        self.ops.push(Op::MaxPool2);
        self.h /= 2;
        self.w /= 2;
        self
    }

    fn gap(&mut self) -> &mut Self {
        self.ops.push(Op::Gap);
        self.flat = Some(self.c);
        self
    }

    fn flatten(&mut self) -> &mut Self {
        self.ops.push(Op::Flatten);
        self.flat = Some(self.h * self.w * self.c);
        self
    }

    fn fc(&mut self, out: usize, relu: bool) -> &mut Self {
        let din = self.flat.expect("fc before flatten/gap");
        let idx = self
            .ops
            .iter()
            .filter(|o| matches!(o, Op::Fc(_)))
            .count();
        let name = format!("fc{idx}");
        let w = self.param(format!("{name}.w"), vec![out, din], ParamKind::FcW);
        let b = self.param(format!("{name}.b"), vec![out], ParamKind::Bias);
        self.ops.push(Op::Fc(FcOp {
            name,
            w,
            b,
            q_idx: self.n_q,
            din,
            dout: out,
            relu,
        }));
        self.n_q += 1;
        self.flat = Some(out);
        self
    }

    fn save(&mut self) -> &mut Self {
        self.ops.push(Op::Save);
        self.saved.push((self.h, self.w, self.c));
        self
    }

    /// Residual add; `proj_stride > 0` inserts a 1×1 projection conv on
    /// the skip path (its own conv/quant indices).
    fn add_saved(&mut self, relu: bool, proj_stride: usize) -> &mut Self {
        let (sh, sw, sc) = self.saved.pop().expect("unbalanced save/add");
        let proj = if proj_stride > 0 {
            let mut op = self.make_conv(self.c, 1, proj_stride, 0, false, sh, sw, sc);
            op.hout = self.h;
            op.wout = self.w;
            assert_eq!((op.hin + 2 * op.pad - op.k) / op.stride + 1, self.h);
            Some(op)
        } else {
            assert_eq!((sh, sw, sc), (self.h, self.w, self.c));
            None
        };
        self.ops.push(Op::AddSaved { relu, proj });
        self
    }

    fn basic_block(&mut self, cout: usize, stride: usize) {
        let proj = stride != 1 || self.c != cout;
        self.save();
        self.conv(cout, 3, stride, 1, true);
        self.conv(cout, 3, 1, 1, false);
        self.add_saved(true, if proj { stride } else { 0 });
    }

    fn bottleneck(&mut self, width: usize, stride: usize) {
        let cout = width * 4;
        let proj = stride != 1 || self.c != cout;
        self.save();
        self.conv(width, 1, 1, 0, true);
        self.conv(width, 3, stride, 1, true);
        self.conv(cout, 1, 1, 0, false);
        self.add_saved(true, if proj { stride } else { 0 });
    }

    fn done(self) -> ModelSpec {
        ModelSpec {
            name: self.name,
            n_classes: self.n_classes,
            ops: self.ops,
            params: self.params,
            n_conv: self.n_conv,
            n_q: self.n_q,
            kset: crate::quant::KSET,
            seed: 20250710,
            momentum: 0.9,
            batch_train: 64,
            batch_eval: 128,
            batch_logits: 8,
            batch_calib: 64,
            pallas_eval: false,
            entries: Vec::new(),
        }
    }
}

/// Test support: a miniature spec exercising every op kind (shared by
/// unit tests across modules).
#[cfg(test)]
pub(crate) mod tests_support {
    use super::ModelSpec;

    pub(crate) fn tiny_spec() -> ModelSpec {
        ModelSpec::from_manifest_str(super::tests::TINY_MANIFEST).unwrap()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A miniature hand-written manifest exercising every op kind.
    pub(crate) const TINY_MANIFEST: &str = r#"{
      "model": "tiny", "n_classes": 4, "input": [32, 32, 3],
      "ops": [
        {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
         "q_idx": 0, "cin": 3, "cout": 4, "k": 3, "stride": 1, "pad": 1,
         "relu": true, "hin": 32, "win": 32, "hout": 32, "wout": 32},
        {"op": "maxpool2"},
        {"op": "save"},
        {"op": "conv", "name": "conv1", "w": 2, "b": 3, "conv_idx": 1,
         "q_idx": 1, "cin": 4, "cout": 4, "k": 3, "stride": 1, "pad": 1,
         "relu": false, "hin": 16, "win": 16, "hout": 16, "wout": 16},
        {"op": "add_saved", "relu": true, "proj": null},
        {"op": "gap"},
        {"op": "fc", "name": "fc0", "w": 4, "b": 5, "q_idx": 2,
         "din": 4, "dout": 4, "relu": false}
      ],
      "params": [
        {"name": "conv0.w", "shape": [4, 3, 3, 3], "kind": "conv_w"},
        {"name": "conv0.b", "shape": [4], "kind": "bias"},
        {"name": "conv1.w", "shape": [4, 4, 3, 3], "kind": "conv_w"},
        {"name": "conv1.b", "shape": [4], "kind": "bias"},
        {"name": "fc0.w", "shape": [4, 4], "kind": "fc_w"},
        {"name": "fc0.b", "shape": [4], "kind": "bias"}
      ],
      "n_conv": 2, "n_q": 3, "kset": 32, "qmax": 127, "seed": 1,
      "set_sentinel": 1e9, "momentum": 0.9,
      "batches": {"train": 8, "eval": 8, "logits": 4, "calib": 8},
      "pallas_eval": false,
      "entries": {"eval": {"file": "eval.hlo.txt", "n_inputs": 10,
                           "input_shapes": [], "input_dtypes": []}}
    }"#;

    #[test]
    fn parses_tiny() {
        let spec = ModelSpec::from_manifest_str(TINY_MANIFEST).unwrap();
        assert_eq!(spec.name, "tiny");
        assert_eq!(spec.ops.len(), 7);
        assert_eq!(spec.n_conv, 2);
        assert_eq!(spec.convs().len(), 2);
        assert_eq!(spec.n_param_elems(), 4 * 3 * 9 + 4 + 4 * 4 * 9 + 4 + 16 + 4);
        assert_eq!(spec.entries.len(), 1);
    }

    #[test]
    fn validate_rejects_bad_shape() {
        let broken = TINY_MANIFEST.replace(
            r#""shape": [4, 3, 3, 3]"#,
            r#""shape": [4, 3, 3, 2]"#,
        );
        assert!(ModelSpec::from_manifest_str(&broken).is_err());
    }

    #[test]
    fn builtin_specs_validate() {
        let lenet = ModelSpec::builtin("lenet5").unwrap();
        assert_eq!(lenet.n_conv, 2);
        assert_eq!(lenet.n_q, 5);
        assert_eq!(lenet.n_classes, 10);
        assert_eq!(lenet.batch_train, 64);
        // fc0 input: 32 →(k5,p2) 32 →pool 16 →(k5,p0) 12 →pool 6, so 16
        // channels over 6×6.
        let fc0 = lenet
            .ops
            .iter()
            .find_map(|o| match o {
                Op::Fc(f) if f.name == "fc0" => Some(f),
                _ => None,
            })
            .unwrap();
        assert_eq!(fc0.din, 16 * 6 * 6);

        let r20 = ModelSpec::builtin("resnet20").unwrap();
        // 1 stem + 18 block convs + 2 downsample projections.
        assert_eq!(r20.n_conv, 21);
        assert_eq!(r20.n_q, 22);
        assert_eq!(r20.convs().len(), 21);

        let r50 = ModelSpec::builtin("resnet50lite").unwrap();
        // 1 stem + 27 bottleneck convs + 3 projections.
        assert_eq!(r50.n_conv, 31);
        assert_eq!(r50.n_classes, 100);

        assert!(ModelSpec::builtin("vgg").is_err());
    }

    #[test]
    fn conv_macs() {
        let spec = ModelSpec::from_manifest_str(TINY_MANIFEST).unwrap();
        let convs = spec.convs();
        let (m, k, n) = convs[0].matmul_dims(2);
        assert_eq!((m, k, n), (2 * 32 * 32, 27, 4));
        assert_eq!(convs[0].macs(1), (32 * 32 * 27 * 4) as u64);
    }
}
