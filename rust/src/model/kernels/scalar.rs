//! Scalar reference backend.
//!
//! Every function here is the portable ground truth the SIMD backends are
//! pinned bit-identical to (see `rust/tests/kernels_simd.rs`).  The int8
//! strip walk and the f32 accumulation order are the contract; keep any
//! change here mirrored in [`super::simd`].

use crate::quant;

use super::f32core::{self, AView};
use super::{occupied_subblocks, NB, SB};

/// Scalar k-strip microkernel for `gemm_i8_blocked`: walk one activation
/// row against one panel strip, honoring the per-sub-block occupancy masks.
///
/// `xrow` is the activation slice for this strip (`kh` codes), `prows` the
/// matching panel rows (`kh * NB` bytes), `occ_rows` the strip's occupancy
/// masks (one per SB rows), `arow` the `width` output accumulators.
pub(crate) fn strip_scalar(xrow: &[i8], prows: &[i8], occ_rows: &[u8], width: usize, arow: &mut [i32]) {
    let kh = xrow.len();
    let nsb = width.div_ceil(SB);
    let full: u8 = if nsb == 8 { 0xFF } else { ((1u16 << nsb) - 1) as u8 };
    let mut r = 0usize;
    while r < kh {
        let kb = r / SB;
        let rend = kh.min((kb + 1) * SB);
        let mask = occ_rows[kb];
        if mask == 0 {
            // Structurally empty: skip the whole sub-block row group.
            r = rend;
            continue;
        }
        if mask == full {
            // Dense: every sub-block occupied, stream the full row.
            for dk in r..rend {
                let xv = xrow[dk];
                if xv == 0 {
                    continue;
                }
                let xi = xv as i32;
                let wrow = &prows[dk * NB..dk * NB + width];
                for (a, &wv) in arow.iter_mut().zip(wrow.iter()) {
                    *a += xi * wv as i32;
                }
            }
        } else {
            // Partial: visit only occupied sub-blocks.  The span list is
            // hoisted out of the dk loop — one bit-scan per occupancy row,
            // not one per activation row.
            let (spans, cnt) = occupied_subblocks(mask, width);
            for dk in r..rend {
                let xv = xrow[dk];
                if xv == 0 {
                    continue;
                }
                let xi = xv as i32;
                let wbase = dk * NB;
                for &(c0, cend) in &spans[..cnt] {
                    for c in c0..cend {
                        arow[c] += xi * prows[wbase + c] as i32;
                    }
                }
            }
        }
        r = rend;
    }
}

/// Quantize `src` into pre-sized `dst` with `quant::quantize` semantics
/// (round half away from zero, clamp to ±127).
pub(crate) fn quantize_i8(src: &[f32], s: f32, dst: &mut [i8]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &v) in dst.iter_mut().zip(src.iter()) {
        *d = quant::quantize(v, s) as i8;
    }
}

/// Requantize + bias + optional ReLU epilogue: `out = acc as f32 * ss +
/// bias`, row-wise over `bias.len()`-wide rows.
pub(crate) fn requant_bias_relu(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut [f32]) {
    let n = bias.len();
    debug_assert_eq!(acc.len(), out.len());
    debug_assert_eq!(acc.len() % n.max(1), 0);
    for (orow, arow) in out.chunks_exact_mut(n).zip(acc.chunks_exact(n)) {
        for ((o, &a), &b) in orow.iter_mut().zip(arow.iter()).zip(bias.iter()) {
            let v = a as f32 * ss + b;
            *o = if relu { v.max(0.0) } else { v };
        }
    }
}

#[inline(always)]
fn axpy_scalar(s: f32, b: &[f32], a: &mut [f32]) {
    for (av, &bv) in a.iter_mut().zip(b.iter()) {
        *av += s * bv;
    }
}

/// `acc[m x n] += x[m x k] * w[k x n]`.
pub(crate) fn gemm_f32(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
    f32core::gemm_core(AView::RowMajor(x), w, m, k, n, acc, axpy_scalar);
}

/// `acc[k x n] += x^T[k x m] * y[m x n]` (x stored m x k).
pub(crate) fn gemm_f32_xt_y(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
    f32core::gemm_core(AView::Transposed(x), y, k, m, n, acc, axpy_scalar);
}

/// `acc[m x k] += y[m x n] * w^T[n x k]` (w stored k x n).
pub(crate) fn gemm_f32_y_wt(y: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
    f32core::with_wt(w, k, n, |wt| {
        f32core::gemm_core(AView::RowMajor(y), wt, m, n, k, acc, axpy_scalar);
    });
}
