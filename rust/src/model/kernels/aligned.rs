//! 64-byte-aligned growable buffers for kernel operands.
//!
//! `Vec<T>` only guarantees `align_of::<T>()` alignment, so an i8 im2col
//! buffer or a packed weight panel can start at any byte address.  The SIMD
//! microkernels in [`super::simd`] tolerate unaligned operands (they use
//! unaligned loads), but cache-line-aligned panels keep every 64-wide panel
//! row within a predictable pair of lines and let future aligned-load
//! variants land without another layout migration.  [`AVec`] is the small
//! `Vec` subset the engine scratch and weight packer actually use, backed by
//! a [`ALIGN`]-byte-aligned allocation.

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Alignment (bytes) of every kernel operand buffer: one x86-64 cache line,
/// and at least the widest vector the SIMD layer uses (32-byte AVX2).
pub const ALIGN: usize = 64;

/// A `Vec`-like growable buffer whose backing allocation is always
/// [`ALIGN`]-byte aligned.  Derefs to `[T]`, so read-side call sites are
/// unchanged; only the handful of producers (pack / im2col / quantize) talk
/// to the growth API.
pub struct AVec<T: Copy> {
    ptr: NonNull<T>,
    len: usize,
    cap: usize,
}

// SAFETY: AVec owns its allocation exclusively, exactly like Vec<T>.
unsafe impl<T: Copy + Send> Send for AVec<T> {}
unsafe impl<T: Copy + Sync> Sync for AVec<T> {}

impl<T: Copy> AVec<T> {
    pub const fn new() -> Self {
        Self {
            ptr: NonNull::dangling(),
            len: 0,
            cap: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        let mut v = Self::new();
        v.reserve_total(cap);
        v
    }

    fn layout(cap: usize) -> Layout {
        let size = cap
            .checked_mul(std::mem::size_of::<T>())
            .expect("AVec capacity overflow");
        Layout::from_size_align(size, ALIGN.max(std::mem::align_of::<T>()))
            .expect("AVec layout")
    }

    /// Grow the backing allocation to at least `want` elements (no-op if
    /// already large enough).  Amortized doubling, like `Vec`.
    fn reserve_total(&mut self, want: usize) {
        assert!(std::mem::size_of::<T>() > 0, "AVec does not support ZSTs");
        if want <= self.cap {
            return;
        }
        let new_cap = want.max(self.cap * 2).max(ALIGN / std::mem::size_of::<T>()).max(8);
        let layout = Self::layout(new_cap);
        // SAFETY: layout has non-zero size (new_cap >= 8, T is not a ZST).
        let raw = unsafe { alloc(layout) } as *mut T;
        let Some(new_ptr) = NonNull::new(raw) else {
            handle_alloc_error(layout);
        };
        if self.cap > 0 {
            // SAFETY: both regions are valid for `len` elements and disjoint
            // (fresh allocation).
            unsafe {
                std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), new_ptr.as_ptr(), self.len);
                dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap));
            }
        }
        self.ptr = new_ptr;
        self.cap = new_cap;
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Set the length to `new_len`, filling any new tail elements with
    /// `value` (truncates if shrinking), like `Vec::resize`.
    pub fn resize(&mut self, new_len: usize, value: T) {
        self.reserve_total(new_len);
        if new_len > self.len {
            // SAFETY: capacity >= new_len, elements are Copy.
            unsafe {
                let base = self.ptr.as_ptr();
                for i in self.len..new_len {
                    base.add(i).write(value);
                }
            }
        }
        self.len = new_len;
    }

    pub fn extend_from_slice(&mut self, src: &[T]) {
        let new_len = self.len + src.len();
        self.reserve_total(new_len);
        // SAFETY: capacity >= new_len; src cannot alias our fresh tail.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.as_ptr().add(self.len), src.len());
        }
        self.len = new_len;
    }

    pub fn push(&mut self, value: T) {
        self.reserve_total(self.len + 1);
        // SAFETY: capacity > len.
        unsafe { self.ptr.as_ptr().add(self.len).write(value) };
        self.len += 1;
    }

    pub fn to_vec(&self) -> Vec<T> {
        self[..].to_vec()
    }
}

impl<T: Copy> Deref for AVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // SAFETY: ptr is valid for len elements (dangling only when len == 0).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> DerefMut for AVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: as above, plus &mut self gives exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> Clone for AVec<T> {
    fn clone(&self) -> Self {
        let mut v = Self::with_capacity(self.len);
        v.extend_from_slice(self);
        v
    }
}

impl<T: Copy> Default for AVec<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy> Drop for AVec<T> {
    fn drop(&mut self) {
        if self.cap > 0 {
            // SAFETY: allocation came from `alloc` with this exact layout.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
        }
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for AVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self[..].fmt(f)
    }
}
