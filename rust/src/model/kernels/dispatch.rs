//! Runtime kernel dispatch: detect once, call through a vtable forever.
//!
//! The hot kernels exist in up to three backends (scalar, SSE2, AVX2 —
//! see [`super::scalar`] / [`super::simd`]).  A [`KernelOps`] vtable per
//! backend is selected once — auto-detection, the `WSEL_KERNELS` env var
//! (`scalar|sse2|avx2|auto`), or the `--kernels` CLI flag via
//! [`select`] — and cached in an atomic pointer; after that every
//! dispatched call is one indirect call with zero per-call feature
//! checks.  All backends are bit-identical, so swapping them (even
//! mid-process, as the property tests do) can never change results.
//!
//! On non-x86-64 targets the SIMD accessors return `None` and everything
//! resolves to scalar; no `cfg` appears outside `super::simd`.

use std::sync::atomic::{AtomicPtr, Ordering};

use super::{scalar, simd, BlockedWeights};

/// The selectable kernel backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Scalar,
    Sse2,
    Avx2,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Sse2 => "sse2",
            KernelKind::Avx2 => "avx2",
        }
    }

    /// Parse a `WSEL_KERNELS` / `--kernels` value; `"auto"` means "let
    /// detection pick" and maps to `None`.
    pub fn parse(s: &str) -> anyhow::Result<Option<KernelKind>> {
        match s {
            "auto" => Ok(None),
            "scalar" => Ok(Some(KernelKind::Scalar)),
            "sse2" => Ok(Some(KernelKind::Sse2)),
            "avx2" => Ok(Some(KernelKind::Avx2)),
            other => anyhow::bail!(
                "unknown kernel backend {other:?} (expected scalar|sse2|avx2|auto)"
            ),
        }
    }
}

/// One backend's implementations of the dispatched kernels.  Plain
/// function pointers: resolved once, branch-predicted perfectly after.
pub struct KernelOps {
    pub kind: KernelKind,
    pub gemm_i8_blocked: fn(&[i8], &BlockedWeights, usize, &mut [i32]),
    pub quantize_i8: fn(&[f32], f32, &mut [i8]),
    pub requant_bias_relu: fn(&[i32], f32, &[f32], bool, &mut [f32]),
    pub gemm_f32: fn(&[f32], &[f32], usize, usize, usize, &mut [f32]),
    pub gemm_f32_xt_y: fn(&[f32], &[f32], usize, usize, usize, &mut [f32]),
    pub gemm_f32_y_wt: fn(&[f32], &[f32], usize, usize, usize, &mut [f32]),
}

static SCALAR_OPS: KernelOps = KernelOps {
    kind: KernelKind::Scalar,
    gemm_i8_blocked: gemm_i8_scalar,
    quantize_i8: scalar::quantize_i8,
    requant_bias_relu: scalar::requant_bias_relu,
    gemm_f32: scalar::gemm_f32,
    gemm_f32_xt_y: scalar::gemm_f32_xt_y,
    gemm_f32_y_wt: scalar::gemm_f32_y_wt,
};

fn gemm_i8_scalar(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
    super::gemm_i8_outer(x, w, m, acc, scalar::strip_scalar);
}

/// The table for a specific backend, or `None` when this host can't run
/// it (SSE2/AVX2 off x86-64, AVX2 without hardware support).
pub fn for_kind(kind: KernelKind) -> Option<&'static KernelOps> {
    match kind {
        KernelKind::Scalar => Some(&SCALAR_OPS),
        KernelKind::Sse2 => simd::sse2_ops(),
        KernelKind::Avx2 => simd::avx2_ops(),
    }
}

/// Every backend this host can run, scalar first.
pub fn available() -> Vec<&'static KernelOps> {
    let mut v = vec![&SCALAR_OPS];
    v.extend(simd::sse2_ops());
    v.extend(simd::avx2_ops());
    v
}

/// The best backend runtime detection finds: AVX2 > SSE2 > scalar.
pub fn detect_best() -> &'static KernelOps {
    simd::avx2_ops()
        .or_else(simd::sse2_ops)
        .unwrap_or(&SCALAR_OPS)
}

/// The `WSEL_KERNELS` override, if set and valid.  Invalid values warn
/// and fall back to auto (`None`) rather than failing a run whose
/// environment leaked a bad value; the CLI flag, in contrast, errors.
pub fn resolve_env() -> Option<KernelKind> {
    let raw = std::env::var("WSEL_KERNELS").ok()?;
    match KernelKind::parse(&raw) {
        Ok(sel) => sel,
        Err(e) => {
            crate::warnlog!("WSEL_KERNELS: {e}; using auto detection");
            None
        }
    }
}

/// The active vtable pointer.  Null until first resolution; always
/// points at one of the `'static` tables after.  An `AtomicPtr` rather
/// than a `OnceLock` so [`select`] can re-point it mid-process — the
/// property tests A/B backends in one process, and the CLI applies
/// `--kernels` after startup.
static ACTIVE: AtomicPtr<KernelOps> = AtomicPtr::new(std::ptr::null_mut());

/// The active kernel table.  First use resolves `WSEL_KERNELS` (an
/// env-forced backend that's unavailable on this host warns and degrades
/// to detection) or auto-detects, then caches.
pub fn active() -> &'static KernelOps {
    let p = ACTIVE.load(Ordering::Acquire);
    if !p.is_null() {
        // SAFETY: ACTIVE only ever holds pointers to 'static tables.
        return unsafe { &*p };
    }
    let ops = match resolve_env() {
        Some(kind) => for_kind(kind).unwrap_or_else(|| {
            crate::warnlog!(
                "WSEL_KERNELS={} unavailable on this host; using auto detection",
                kind.name()
            );
            detect_best()
        }),
        None => detect_best(),
    };
    ACTIVE.store(ops as *const KernelOps as *mut KernelOps, Ordering::Release);
    ops
}

/// Kind of the currently active backend (resolving it if needed).
pub fn active_kind() -> KernelKind {
    active().kind
}

/// Force the active backend (`None` = auto-detect best).  Errors if the
/// requested backend can't run on this host — callers surface that
/// rather than silently computing on a different backend than asked.
pub fn select(kind: Option<KernelKind>) -> anyhow::Result<&'static KernelOps> {
    let ops = match kind {
        None => detect_best(),
        Some(kind) => for_kind(kind).ok_or_else(|| {
            anyhow::anyhow!("kernel backend `{}` unavailable on this host", kind.name())
        })?,
    };
    ACTIVE.store(ops as *const KernelOps as *mut KernelOps, Ordering::Release);
    Ok(ops)
}
