//! Kernel layer of the int8 inference engine: cache-blocked
//! i32-accumulating GEMM, im2col, requantization and the float/pool/fc
//! kernels the executor composes.
//!
//! Every kernel is **bit-compatible** with the scalar reference in
//! [`super::infer`]: the quantized path accumulates exact i32 (so any
//! blocking order yields identical sums) and the float kernels walk the
//! reduction in the same element order as the reference loops, so the
//! f32 rounding sequence is identical.  `rust/tests/engine_parallel.rs`
//! pins this bit-for-bit.
//!
//! # Module layout
//!
//! The hot kernels (`gemm_i8_blocked`, `quantize_into`,
//! `requant_bias_relu`, the three f32 training GEMMs) are **dispatched**:
//! the public functions here forward through a runtime-selected vtable
//! ([`dispatch`]) to either the portable [`scalar`] backend or the
//! x86-64 [`simd`] backends (AVX2/SSE2).  All backends are bit-identical
//! by construction and pinned so by `rust/tests/kernels_simd.rs`; the
//! backend is chosen once (auto-detect, `WSEL_KERNELS`, or `--kernels`)
//! and every caller inherits it.  The remaining kernels (im2col, pool,
//! fc, direct conv) are memory-bound or cold and stay scalar here.
//!
//! - [`dispatch`] — kernel kinds, runtime detection, the active vtable;
//! - [`scalar`] — portable reference backend;
//! - [`simd`] — AVX2/SSE2 backends (compiles to nothing off x86-64);
//! - [`f32core`] — the one f32 GEMM loop nest all variants share;
//! - [`aligned`] — 64-byte-aligned buffers ([`AVec`]) for panels and
//!   engine scratch.

use super::spec::ConvOp;
use crate::quant;

pub mod aligned;
pub mod dispatch;
mod f32core;
mod scalar;
mod simd;

pub use aligned::{AVec, ALIGN};

/// Column-panel width of the blocked weight layout (one GEMM tile of
/// output columns).
pub const NB: usize = 64;
/// Rows of X per GEMM macro-block.
pub const MB: usize = 32;
/// K-panel depth per GEMM macro-block.
pub const KB: usize = 256;
/// Side of the block-sparse occupancy grid: SB×SB weight blocks (8-wide
/// column sub-blocks × 8 k-rows, BSR-style).  NB and KB are multiples of
/// SB, so panel sub-blocks align with the global 8×8 grid over K×N.
/// SB is also the i32 vector width of one AVX2 register, so a full
/// sub-block is exactly one SIMD accumulator lane group.
pub const SB: usize = 8;

/// Block-sparsity summary of a packed weight matrix, counted over the
/// real K×N extent only (panel padding excluded).  `elems_skipped` is
/// the number of real weight positions inside all-zero SB×SB blocks —
/// the per-output-row MAC count the structural skip removes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockSparsity {
    pub blocks_total: u64,
    pub blocks_empty: u64,
    pub elems_skipped: u64,
}

impl BlockSparsity {
    /// Fraction of SB×SB blocks that are entirely zero.
    pub fn empty_fraction(&self) -> f64 {
        if self.blocks_total == 0 {
            0.0
        } else {
            self.blocks_empty as f64 / self.blocks_total as f64
        }
    }
}

/// Per-panel SB×SB occupancy masks plus the real-extent sparsity
/// summary.  Masks are panel-major: `occ[p * kblocks + kb]` bit `b` set
/// iff the block covering columns `p*NB + b*SB ..` and k-rows
/// `kb*SB ..` has any nonzero code.  Padding bits stay 0.
fn occupancy_of(w_kxn: &[i8], k: usize, n: usize) -> (Vec<u8>, BlockSparsity) {
    let panels = n.div_ceil(NB);
    let kblocks = k.div_ceil(SB);
    let mut occ = vec![0u8; panels * kblocks];
    let mut s = BlockSparsity::default();
    for p in 0..panels {
        let j0 = p * NB;
        let width = NB.min(n - j0);
        let nsb = width.div_ceil(SB);
        for kb in 0..kblocks {
            let r0 = kb * SB;
            let rend = k.min(r0 + SB);
            let mut mask = 0u8;
            for b in 0..nsb {
                let c0 = j0 + b * SB;
                let cend = n.min(c0 + SB);
                let occupied = (r0..rend)
                    .any(|r| w_kxn[r * n + c0..r * n + cend].iter().any(|&v| v != 0));
                s.blocks_total += 1;
                if occupied {
                    mask |= 1 << b;
                } else {
                    s.blocks_empty += 1;
                    s.elems_skipped += ((rend - r0) * (cend - c0)) as u64;
                }
            }
            occ[p * kblocks + kb] = mask;
        }
    }
    (occ, s)
}

/// Block-sparsity summary of a raw K×N code matrix on the global SB×SB
/// grid (same grid the packed panels use, since `NB % SB == 0`).
pub fn block_sparsity_of(w_kxn: &[i8], k: usize, n: usize) -> BlockSparsity {
    assert_eq!(w_kxn.len(), k * n);
    occupancy_of(w_kxn, k, n).1
}

/// Expand an occupancy mask into its occupied `(c0, cend)` column spans
/// within a `width`-wide panel row, hoisted once per occupancy row so
/// neither the scalar nor the SIMD strip re-scans the bits per
/// activation row.  At most `NB / SB` spans.
#[inline]
pub(crate) fn occupied_subblocks(mask: u8, width: usize) -> ([(usize, usize); NB / SB], usize) {
    let mut spans = [(0usize, 0usize); NB / SB];
    let mut cnt = 0usize;
    let mut mbits = mask;
    while mbits != 0 {
        let b = mbits.trailing_zeros() as usize;
        mbits &= mbits - 1;
        let c0 = b * SB;
        spans[cnt] = (c0, width.min(c0 + SB));
        cnt += 1;
    }
    (spans, cnt)
}

/// Pre-quantized conv weights packed into column panels: `ceil(n/NB)`
/// panels, each `k`×`NB` row-major with tail columns zero-padded, so the
/// GEMM inner loop reads one contiguous stripe per (row, panel).  Pack
/// time also records a per-panel SB×SB block occupancy index so the
/// GEMM can skip all-zero weight blocks structurally.  Panels live in an
/// [`AVec`], and since each panel is `k * NB` bytes (a multiple of
/// [`ALIGN`]), every panel starts cache-line aligned.
#[derive(Clone)]
pub struct BlockedWeights {
    pub k: usize,
    pub n: usize,
    data: AVec<i8>,
    /// Panel-major occupancy masks, `panels * kblocks` entries.
    occ: Vec<u8>,
    /// `k.div_ceil(SB)` — rows of the occupancy grid.
    kblocks: usize,
    sparsity: BlockSparsity,
}

impl BlockedWeights {
    /// Pack a K×N row-major code matrix into column panels.
    pub fn pack(w_kxn: &[i8], k: usize, n: usize) -> Self {
        assert_eq!(w_kxn.len(), k * n);
        let panels = n.div_ceil(NB);
        let mut data = AVec::new();
        data.resize(panels * k * NB, 0i8);
        for p in 0..panels {
            let j0 = p * NB;
            let width = NB.min(n - j0);
            for r in 0..k {
                let dst = p * k * NB + r * NB;
                data[dst..dst + width].copy_from_slice(&w_kxn[r * n + j0..r * n + j0 + width]);
            }
        }
        let (occ, sparsity) = occupancy_of(w_kxn, k, n);
        let kblocks = k.div_ceil(SB);
        debug_assert_eq!(data.as_ptr() as usize % ALIGN, 0);
        Self { k, n, data, occ, kblocks, sparsity }
    }

    fn panel(&self, p: usize) -> &[i8] {
        &self.data[p * self.k * NB..(p + 1) * self.k * NB]
    }

    fn panel_occ(&self, p: usize) -> &[u8] {
        &self.occ[p * self.kblocks..(p + 1) * self.kblocks]
    }

    /// Real-extent block-sparsity summary recorded at pack time.
    pub fn sparsity(&self) -> BlockSparsity {
        self.sparsity
    }

    /// Whether every panel starts [`ALIGN`]-byte aligned (always true by
    /// construction: the base allocation is aligned and the panel stride
    /// `k * NB` bytes is a multiple of NB = ALIGN).
    pub fn panels_aligned(&self) -> bool {
        self.data.as_ptr() as usize % ALIGN == 0 && (self.k * NB) % ALIGN == 0
    }
}

/// The shared outer blocking of `gemm_i8_blocked`: panels → MB row
/// blocks → KB k-strips, handing each (activation row × panel strip) to
/// a backend microkernel.  `strip(xrow, prows, occ_rows, width, arow)`
/// accumulates `kh` activation codes against `kh` panel rows into
/// `width` i32 outputs, honoring the strip's occupancy masks (one per
/// SB k-rows; KB is a multiple of SB so strips start on occupancy-row
/// boundaries).
pub(crate) fn gemm_i8_outer(
    x: &[i8],
    w: &BlockedWeights,
    m: usize,
    acc: &mut [i32],
    mut strip: impl FnMut(&[i8], &[i8], &[u8], usize, &mut [i32]),
) {
    let (k, n) = (w.k, w.n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(acc.len(), m * n);
    let panels = n.div_ceil(NB);
    for p in 0..panels {
        let j0 = p * NB;
        let width = NB.min(n - j0);
        let panel = w.panel(p);
        let occ = w.panel_occ(p);
        for i0 in (0..m).step_by(MB) {
            let ih = MB.min(m - i0);
            for k0 in (0..k).step_by(KB) {
                let kh = KB.min(k - k0);
                let prows = &panel[k0 * NB..(k0 + kh) * NB];
                let occ_rows = &occ[k0 / SB..(k0 + kh).div_ceil(SB)];
                for i in i0..i0 + ih {
                    let xrow = &x[i * k + k0..i * k + k0 + kh];
                    let arow = &mut acc[i * n + j0..i * n + j0 + width];
                    strip(xrow, prows, occ_rows, width, arow);
                }
            }
        }
    }
}

/// `acc(m×n) += X(m×k) · W(k×n)` with exact i32 accumulation, blocked
/// over (column panel, M, K).  Zero activations are skipped (post-ReLU
/// code streams are sparse), and all-zero SB×SB weight blocks are
/// skipped *structurally* via the pack-time occupancy index — no
/// per-element zero tests on the weight side.  Skipped blocks contribute
/// exactly zero to the i32 sums, so the result is bit-identical to the
/// dense walk — and exact i32 also makes every dispatched backend
/// bit-identical regardless of vector width.  Caller zeroes `acc`.
pub fn gemm_i8_blocked(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
    (dispatch::active().gemm_i8_blocked)(x, w, m, acc)
}

/// Quantize a float tensor to int8 codes into a reused buffer
/// (dispatched; all backends reproduce `quant::quantize` bit-exactly for
/// finite inputs).
pub fn quantize_into(src: &[f32], s: f32, dst: &mut AVec<i8>) {
    dst.clear();
    dst.resize(src.len(), 0);
    (dispatch::active().quantize_i8)(src, s, dst)
}

/// im2col of an NHWC code tensor into a reused buffer; (ky, kx, c) patch
/// column order, matching the scalar reference and `ref.im2col` on the
/// JAX side.  Out-of-bounds taps stay zero (the buffer is zero-filled).
pub fn im2col_i8(
    t: &[i8],
    n_imgs: usize,
    h: usize,
    w: usize,
    c: usize,
    cv: &ConvOp,
    out: &mut AVec<i8>,
) {
    let (ho, wo, k, s, p) = (cv.hout, cv.wout, cv.k, cv.stride, cv.pad as isize);
    let m = n_imgs * ho * wo;
    let kk = k * k * c;
    out.clear();
    out.resize(m * kk, 0);
    for b in 0..n_imgs {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (b * ho + oy) * wo + ox;
                let base = row * kk;
                for ky in 0..k {
                    let iy = (oy * s) as isize + ky as isize - p;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s) as isize + kx as isize - p;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let col0 = (ky * k + kx) * c;
                        let src = ((b * h + iy as usize) * w + ix as usize) * c;
                        out[base + col0..base + col0 + c].copy_from_slice(&t[src..src + c]);
                    }
                }
            }
        }
    }
}

/// Requantize an i32 accumulator tile: `out = acc·ss + bias`, optional
/// ReLU.  `ss` must be the pre-multiplied `s_act · s_w` so the f32
/// expression matches the scalar reference exactly (dispatched; the
/// vector backends compute the identical mul-then-add per element).
pub fn requant_bias_relu(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut Vec<f32>) {
    debug_assert_eq!(acc.len() % bias.len(), 0);
    out.clear();
    out.resize(acc.len(), 0.0);
    (dispatch::active().requant_bias_relu)(acc, ss, bias, relu, out)
}

/// Float direct convolution (calibration path), bit-identical in
/// accumulation order to the scalar reference: (oy, ox) outer, then
/// (ky, kx, ci) taps with zero-skip, bias added last, ReLU applied by
/// the caller over the whole tensor.  `w_oihw` is the raw OIHW tensor.
pub fn conv_f32_direct(
    cv: &ConvOp,
    input: &[f32],
    n_imgs: usize,
    w_oihw: &[f32],
    bias: &[f32],
    out: &mut Vec<f32>,
) {
    let (h, w, c) = (cv.hin, cv.win, cv.cin);
    debug_assert_eq!(input.len(), n_imgs * h * w * c);
    let nn = cv.cout;
    let m = n_imgs * cv.hout * cv.wout;
    out.clear();
    out.resize(m * nn, 0.0);
    let (k, s, p) = (cv.k, cv.stride, cv.pad as isize);
    for b in 0..n_imgs {
        for oy in 0..cv.hout {
            for ox in 0..cv.wout {
                let row = (b * cv.hout + oy) * cv.wout + ox;
                let orow = &mut out[row * nn..(row + 1) * nn];
                for ky in 0..k {
                    let iy = (oy * s) as isize + ky as isize - p;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s) as isize + kx as isize - p;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * w + ix as usize) * c;
                        for ci in 0..c {
                            let xv = input[src + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            for (o, ov) in orow.iter_mut().enumerate() {
                                *ov += xv * w_oihw[((o * c + ci) * k + ky) * k + kx];
                            }
                        }
                    }
                }
                for (ov, bv) in orow.iter_mut().zip(bias) {
                    *ov += bv;
                }
            }
        }
    }
}

/// 2×2 max-pool (stride 2), scalar-reference scan order.
pub fn maxpool2(input: &[f32], n_imgs: usize, h: usize, w: usize, c: usize, out: &mut Vec<f32>) {
    let (ho, wo) = (h / 2, w / 2);
    out.clear();
    out.resize(n_imgs * ho * wo * c, f32::NEG_INFINITY);
    for b in 0..n_imgs {
        for y in 0..h {
            for xx in 0..w {
                let src = &input[((b * h + y) * w + xx) * c..][..c];
                let dst_idx = ((b * ho + y / 2) * wo + xx / 2) * c;
                for (ch, &sv) in src.iter().enumerate() {
                    let d = &mut out[dst_idx + ch];
                    if sv > *d {
                        *d = sv;
                    }
                }
            }
        }
    }
}

/// Global average pool, scalar-reference accumulation order.
pub fn gap(input: &[f32], n_imgs: usize, h: usize, w: usize, c: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(n_imgs * c, 0.0);
    for b in 0..n_imgs {
        for y in 0..h {
            for xx in 0..w {
                let src = &input[((b * h + y) * w + xx) * c..][..c];
                for (ch, &sv) in src.iter().enumerate() {
                    out[b * c + ch] += sv;
                }
            }
        }
    }
    let inv = 1.0 / (h * w) as f32;
    out.iter_mut().for_each(|v| *v *= inv);
}

/// Float fully-connected layer, scalar-reference dot order.
#[allow(clippy::too_many_arguments)]
pub fn fc_f32(
    input: &[f32],
    n_imgs: usize,
    din: usize,
    dout: usize,
    w: &[f32],
    bias: &[f32],
    relu: bool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(n_imgs * dout);
    for b in 0..n_imgs {
        let xrow = &input[b * din..(b + 1) * din];
        for o in 0..dout {
            let wrow = &w[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += xv * wv;
            }
            let v = acc + bias[o];
            out.push(if relu { v.max(0.0) } else { v });
        }
    }
}

/// Quantized fully-connected layer: int8 codes, exact i32 dot, then the
/// scalar reference's requant expression.
#[allow(clippy::too_many_arguments)]
pub fn fc_i8(
    xq: &[i8],
    n_imgs: usize,
    din: usize,
    dout: usize,
    wq: &[i8],
    ss: f32,
    bias: &[f32],
    relu: bool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(n_imgs * dout);
    for b in 0..n_imgs {
        let xrow = &xq[b * din..(b + 1) * din];
        for o in 0..dout {
            let wrow = &wq[o * din..(o + 1) * din];
            let mut acc = 0i32;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += *xv as i32 * *wv as i32;
            }
            let v = ss * acc as f32 + bias[o];
            out.push(if relu { v.max(0.0) } else { v });
        }
    }
}

/// Max |v| of a tensor (activation-scale calibration support).
pub fn abs_max(t: &[f32]) -> f32 {
    t.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

// ---------------------------------------------------------------------------
// f32 GEMM / im2col primitives for the training engine
// (`super::grad`): fixed reduction orders so per-image results are
// deterministic regardless of thread count.
// ---------------------------------------------------------------------------

/// im2col of an NHWC f32 tensor into a reused buffer; (ky, kx, c) patch
/// column order, matching [`im2col_i8`].  Out-of-bounds taps stay zero.
pub fn im2col_f32(
    t: &[f32],
    n_imgs: usize,
    h: usize,
    w: usize,
    c: usize,
    cv: &ConvOp,
    out: &mut AVec<f32>,
) {
    let (ho, wo, k, s, p) = (cv.hout, cv.wout, cv.k, cv.stride, cv.pad as isize);
    let m = n_imgs * ho * wo;
    let kk = k * k * c;
    out.clear();
    out.resize(m * kk, 0.0);
    for b in 0..n_imgs {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (b * ho + oy) * wo + ox;
                let base = row * kk;
                for ky in 0..k {
                    let iy = (oy * s) as isize + ky as isize - p;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s) as isize + kx as isize - p;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let col0 = (ky * k + kx) * c;
                        let src = ((b * h + iy as usize) * w + ix as usize) * c;
                        out[base + col0..base + col0 + c].copy_from_slice(&t[src..src + c]);
                    }
                }
            }
        }
    }
}

/// Transpose of [`im2col_f32`]: scatter-add patch-matrix values back to
/// the NHWC tensor (`dx += col2im(cols)`).  Overlapping patches sum,
/// which is exactly the conv input-gradient composition.  Caller zeroes
/// `dx`.
pub fn col2im_f32_add(
    cols: &[f32],
    n_imgs: usize,
    h: usize,
    w: usize,
    c: usize,
    cv: &ConvOp,
    dx: &mut [f32],
) {
    let (ho, wo, k, s, p) = (cv.hout, cv.wout, cv.k, cv.stride, cv.pad as isize);
    let kk = k * k * c;
    debug_assert_eq!(cols.len(), n_imgs * ho * wo * kk);
    debug_assert_eq!(dx.len(), n_imgs * h * w * c);
    for b in 0..n_imgs {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (b * ho + oy) * wo + ox;
                let base = row * kk;
                for ky in 0..k {
                    let iy = (oy * s) as isize + ky as isize - p;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s) as isize + kx as isize - p;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let col0 = (ky * k + kx) * c;
                        let dst = ((b * h + iy as usize) * w + ix as usize) * c;
                        for ci in 0..c {
                            dx[dst + ci] += cols[base + col0 + ci];
                        }
                    }
                }
            }
        }
    }
}

/// `acc(m×n) += X(m×k) · W(k×n)` in f32 with zero-skip on X (post-ReLU
/// activations are sparse).  Reduction walks k in ascending order per
/// row, so the rounding sequence is fixed — and the dispatched vector
/// backends preserve exactly that per-output-element order (see
/// [`f32core`]), so results are bit-identical across backends.
pub fn gemm_f32(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(acc.len(), m * n);
    (dispatch::active().gemm_f32)(x, w, m, k, n, acc)
}

/// `acc(k×n) += Xᵀ(k×m) · Y(m×n)` — the weight-gradient contraction
/// `dW = colsᵀ · dY` with X in m×k row-major (dispatched,
/// order-preserving).
pub fn gemm_f32_xt_y(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(acc.len(), k * n);
    (dispatch::active().gemm_f32_xt_y)(x, y, m, k, n, acc)
}

/// `acc(m×k) += Y(m×n) · Wᵀ(n×k)` with W in k×n row-major — the conv
/// input-gradient contraction `dCols = dY · Wᵀ` (dispatched,
/// order-preserving; `acc` must be zeroed by the caller, which the grad
/// engine does).
pub fn gemm_f32_y_wt(y: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(acc.len(), m * k);
    (dispatch::active().gemm_f32_y_wt)(y, w, m, k, n, acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(len: usize, seed: u64) -> Vec<i8> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..len)
            .map(|_| {
                if rng.below(3) == 0 {
                    0
                } else {
                    rng.code() as i8
                }
            })
            .collect()
    }

    /// Blocked GEMM equals the naive triple loop exactly, across shapes
    /// that exercise partial panels / partial M and K blocks.
    #[test]
    fn gemm_matches_naive() {
        for (si, &(m, k, n)) in [(3usize, 5usize, 2usize), (33, 70, 64), (65, 257, 67), (1, 1, 1)]
            .iter()
            .enumerate()
        {
            let x = codes(m * k, si as u64 + 1);
            let w = codes(k * n, si as u64 + 100);
            let wb = BlockedWeights::pack(&w, k, n);
            let mut acc = vec![0i32; m * n];
            gemm_i8_blocked(&x, &wb, m, &mut acc);
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0i32;
                    for r in 0..k {
                        want += x[i * k + r] as i32 * w[r * n + j] as i32;
                    }
                    assert_eq!(acc[i * n + j], want, "({m},{k},{n}) at ({i},{j})");
                }
            }
        }
    }

    /// Zero out whole SB×SB blocks (block-structured pruning) and check
    /// the structural-skip GEMM still equals the naive triple loop, and
    /// that pack-time occupancy actually reports the empty blocks.
    #[test]
    fn gemm_block_sparse_matches_naive() {
        for (si, &(m, k, n)) in [(3usize, 5usize, 2usize), (33, 70, 64), (65, 257, 67), (9, 16, 8)]
            .iter()
            .enumerate()
        {
            let x = codes(m * k, si as u64 + 11);
            let mut w = codes(k * n, si as u64 + 200);
            // Kill every other block on the SB×SB grid (checkerboard),
            // so masks exercise empty, partial and (where the grid is
            // 1 wide) full rows.
            for kb in 0..k.div_ceil(SB) {
                for jb in 0..n.div_ceil(SB) {
                    if (kb + jb) % 2 == 0 {
                        for r in kb * SB..k.min((kb + 1) * SB) {
                            for j in jb * SB..n.min((jb + 1) * SB) {
                                w[r * n + j] = 0;
                            }
                        }
                    }
                }
            }
            let wb = BlockedWeights::pack(&w, k, n);
            let s = wb.sparsity();
            assert_eq!(s.blocks_total, (k.div_ceil(SB) * n.div_ceil(SB)) as u64);
            assert!(s.blocks_empty > 0, "({m},{k},{n}): no empty blocks seen");
            let mut acc = vec![0i32; m * n];
            gemm_i8_blocked(&x, &wb, m, &mut acc);
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0i32;
                    for r in 0..k {
                        want += x[i * k + r] as i32 * w[r * n + j] as i32;
                    }
                    assert_eq!(acc[i * n + j], want, "({m},{k},{n}) at ({i},{j})");
                }
            }
        }
    }

    /// Occupancy accounting on a hand-built matrix: exactly one nonzero
    /// block, real-extent element counts for the skipped remainder.
    #[test]
    fn block_sparsity_counts_real_extent() {
        // 10×12 → grid 2×2 k-blocks × ... : k.div_ceil(8)=2, n.div_ceil(8)=2.
        let (k, n) = (10usize, 12usize);
        let mut w = vec![0i8; k * n];
        w[0] = 5; // block (kb=0, jb=0) occupied
        let s = block_sparsity_of(&w, k, n);
        assert_eq!(s.blocks_total, 4);
        assert_eq!(s.blocks_empty, 3);
        // (kb0,jb1): 8 rows × 4 cols; (kb1,jb0): 2 × 8; (kb1,jb1): 2 × 4.
        assert_eq!(s.elems_skipped, 8 * 4 + 2 * 8 + 2 * 4);
        assert!((s.empty_fraction() - 0.75).abs() < 1e-12);
        // Fully dense matrix: nothing skipped.
        let d = block_sparsity_of(&vec![1i8; k * n], k, n);
        assert_eq!(d.blocks_empty, 0);
        assert_eq!(d.elems_skipped, 0);
        // Fully zero matrix: everything skipped, real extent only.
        let z = block_sparsity_of(&vec![0i8; k * n], k, n);
        assert_eq!(z.blocks_empty, z.blocks_total);
        assert_eq!(z.elems_skipped, (k * n) as u64);
    }

    #[test]
    fn pack_roundtrips_tail_panel() {
        let (k, n) = (3usize, NB + 5);
        let w = codes(k * n, 9);
        let wb = BlockedWeights::pack(&w, k, n);
        assert!(wb.panels_aligned());
        // Read back through the panel accessor.
        for r in 0..k {
            for j in 0..n {
                let p = j / NB;
                assert_eq!(wb.panel(p)[r * NB + j % NB], w[r * n + j]);
            }
        }
    }

    #[test]
    fn occupied_subblocks_spans() {
        // Bits 0 and 2 set, width 20: spans (0,8) and (16,20) — the tail
        // sub-block is clipped to the real width.
        let (spans, cnt) = occupied_subblocks(0b101, 20);
        assert_eq!(cnt, 2);
        assert_eq!(spans[0], (0, 8));
        assert_eq!(spans[1], (16, 20));
        let (_, c0) = occupied_subblocks(0, 64);
        assert_eq!(c0, 0);
        let (full, c8) = occupied_subblocks(0xFF, 64);
        assert_eq!(c8, 8);
        assert_eq!(full[7], (56, 64));
    }

    #[test]
    fn avec_alignment_and_growth() {
        let mut v: AVec<i8> = AVec::new();
        assert_eq!(v.len(), 0);
        v.resize(5, 7);
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
        assert_eq!(&v[..], &[7, 7, 7, 7, 7]);
        v.extend_from_slice(&[1, 2, 3]);
        assert_eq!(v.len(), 8);
        // Grow past the first allocation: contents survive, still aligned.
        v.resize(10_000, 0);
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
        assert_eq!(&v[..8], &[7, 7, 7, 7, 7, 1, 2, 3]);
        let c = v.clone();
        assert_eq!(&c[..], &v[..]);
        assert_eq!(c.as_ptr() as usize % ALIGN, 0);
        let mut f: AVec<f32> = AVec::with_capacity(3);
        f.push(1.5);
        f.extend_from_slice(&[2.5, 3.5, 4.5]);
        assert_eq!(f.to_vec(), vec![1.5, 2.5, 3.5, 4.5]);
        f.clear();
        assert!(f.is_empty());
    }

    fn vals(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..len)
            .map(|_| {
                if rng.below(4) == 0 {
                    0.0
                } else {
                    rng.range_f32(-1.0, 1.0)
                }
            })
            .collect()
    }

    #[test]
    fn gemm_f32_matches_naive() {
        for (si, &(m, k, n)) in [(3usize, 5usize, 2usize), (17, 9, 13), (1, 1, 1)]
            .iter()
            .enumerate()
        {
            let x = vals(m * k, si as u64 + 1);
            let w = vals(k * n, si as u64 + 50);
            let mut acc = vec![0.0f32; m * n];
            gemm_f32(&x, &w, m, k, n, &mut acc);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|r| x[i * k + r] * w[r * n + j]).sum();
                    assert!((acc[i * n + j] - want).abs() < 1e-5, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn gemm_transposed_contractions() {
        let (m, k, n) = (7usize, 5usize, 4usize);
        let x = vals(m * k, 3);
        let y = vals(m * n, 4);
        // dW = Xᵀ·Y.
        let mut dw = vec![0.0f32; k * n];
        gemm_f32_xt_y(&x, &y, m, k, n, &mut dw);
        for r in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| x[i * k + r] * y[i * n + j]).sum();
                assert!((dw[r * n + j] - want).abs() < 1e-5);
            }
        }
        // dX = Y·Wᵀ.
        let w = vals(k * n, 5);
        let mut dx = vec![0.0f32; m * k];
        gemm_f32_y_wt(&y, &w, m, k, n, &mut dx);
        for i in 0..m {
            for r in 0..k {
                let want: f32 = (0..n).map(|j| y[i * n + j] * w[r * n + j]).sum();
                assert!((dx[i * k + r] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn im2col_f32_matches_i8_layout() {
        // Integer-valued floats so both paths are exact.
        let cv = ConvOp {
            name: "c".into(),
            w: 0,
            b: 1,
            conv_idx: 0,
            q_idx: 0,
            cin: 2,
            cout: 3,
            k: 3,
            stride: 2,
            pad: 1,
            relu: false,
            hin: 5,
            win: 5,
            hout: 3,
            wout: 3,
        };
        let ci8 = codes(2 * 5 * 5 * 2, 7);
        let cf: Vec<f32> = ci8.iter().map(|&v| v as f32).collect();
        let mut oi = AVec::new();
        let mut of = AVec::new();
        im2col_i8(&ci8, 2, 5, 5, 2, &cv, &mut oi);
        im2col_f32(&cf, 2, 5, 5, 2, &cv, &mut of);
        assert_eq!(oi.len(), of.len());
        for (a, b) in oi.iter().zip(of.iter()) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn col2im_is_im2col_transpose() {
        // <im2col(x), g> == <x, col2im(g)> for random x, g — the adjoint
        // identity the conv backward relies on.
        let cv = ConvOp {
            name: "c".into(),
            w: 0,
            b: 1,
            conv_idx: 0,
            q_idx: 0,
            cin: 3,
            cout: 2,
            k: 3,
            stride: 1,
            pad: 1,
            relu: false,
            hin: 4,
            win: 4,
            hout: 4,
            wout: 4,
        };
        let x = vals(4 * 4 * 3, 8);
        let m = cv.hout * cv.wout;
        let kk = cv.k * cv.k * cv.cin;
        let g = vals(m * kk, 9);
        let mut cols = AVec::new();
        im2col_f32(&x, 1, 4, 4, 3, &cv, &mut cols);
        let lhs: f64 = cols.iter().zip(&g).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let mut back = vec![0.0f32; x.len()];
        col2im_f32_add(&g, 1, 4, 4, 3, &cv, &mut back);
        let rhs: f64 = x.iter().zip(&back).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn requant_expression() {
        let acc = vec![3i32, -2, 0, 7];
        let bias = vec![0.5f32, -0.25];
        let mut out = Vec::new();
        requant_bias_relu(&acc, 0.125, &bias, false, &mut out);
        assert_eq!(out, vec![3.0 * 0.125 + 0.5, -2.0 * 0.125 - 0.25, 0.5, 7.0 * 0.125 - 0.25]);
        requant_bias_relu(&acc, 0.125, &bias, true, &mut out);
        assert!(out.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn quantize_into_matches_scalar_reference() {
        let s = 0.03125f32;
        let src: Vec<f32> = (0..133)
            .map(|i| (i as f32 - 66.0) * 0.07)
            .chain([4.0 * s, -7.0 * s, 0.5 * s, -0.5 * s, 1.5 * s, 0.0, -0.0, 100.0, -100.0])
            .collect();
        let mut dst = AVec::new();
        quantize_into(&src, s, &mut dst);
        assert_eq!(dst.len(), src.len());
        for (i, (&d, &v)) in dst.iter().zip(src.iter()).enumerate() {
            assert_eq!(d, quant::quantize(v, s) as i8, "elem {i} ({v})");
        }
    }
}
