//! x86-64 SIMD backends (AVX2 and SSE2) for the dispatched kernels.
//!
//! Everything target-specific lives behind `cfg(target_arch = "x86_64")`
//! inside this file; other architectures compile only the `*_ops()`
//! accessors below, which return `None` so [`super::dispatch`] falls back
//! to scalar.  No `cfg` leaks outside this module.
//!
//! # Bit-identity contracts (vs [`super::scalar`])
//!
//! - **int8 GEMM**: products and accumulators are exact i32, so any
//!   blocking or lane order gives the same result.  The AVX2 strip holds
//!   one 256-bit register of 8 i32 accumulators per full SB=8 sub-block
//!   across the whole k-strip; SSE2 widens i8 -> i16 (products bounded by
//!   128^2 = 16384, exact in i16) and then i16 -> i32 before
//!   memory-accumulating in 4-lane halves.
//! - **f32 GEMMs**: the shared [`super::f32core`] loop nest fixes the
//!   per-output-element accumulation order; the SIMD axpy only widens
//!   across output columns (independent accumulator chains) and uses
//!   separate multiply + add — never FMA, which rounds once where
//!   mul-then-add rounds twice.
//! - **quantize**: `(v / s).round()` with round-half-away-from-zero is
//!   emulated exactly: `t = v / s` (vector divide, not a reciprocal
//!   approximation), truncate via `cvttps` (after clamping `t` to ±1e9 so
//!   the i32 conversion cannot wrap; anything that large clamps to ±127
//!   regardless), then add `copysign(1, t)` when `|t - trunc(t)| >= 0.5`.
//!   The naive `trunc(t + 0.5)` is *not* equivalent: for `t` just below
//!   0.5 (e.g. `0.5 - 2^-25`), `t + 0.5` rounds up to exactly 1.0 and
//!   truncates to 1, where `round` gives 0.  The frac comparison has no
//!   such double-rounding.  Caveat: non-finite inputs diverge (scalar
//!   sends NaN to 0, the vector path to ±127); engine activations are
//!   finite by construction.
//! - **requant**: `acc as f32 * ss + bias` elementwise in lanes, with
//!   `max_ps(v, 0)` for ReLU.  `v` can never be `-0.0` or NaN here
//!   (`ss > 0` by the 1e-12 floor in `quant::weight_scale`, exact
//!   cancellation yields `+0.0`), so `max_ps` matches `f32::max`.

use super::dispatch::KernelOps;

#[cfg(target_arch = "x86_64")]
pub(crate) fn sse2_ops() -> Option<&'static KernelOps> {
    // SSE2 is part of the x86-64 baseline: always available.
    Some(&x86::SSE2_OPS)
}

#[cfg(target_arch = "x86_64")]
pub(crate) fn avx2_ops() -> Option<&'static KernelOps> {
    if is_x86_feature_detected!("avx2") {
        Some(&x86::AVX2_OPS)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn sse2_ops() -> Option<&'static KernelOps> {
    None
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn avx2_ops() -> Option<&'static KernelOps> {
    None
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use crate::model::kernels::dispatch::{KernelKind, KernelOps};
    use crate::model::kernels::f32core::{self, AView};
    use crate::model::kernels::{gemm_i8_outer, occupied_subblocks, BlockedWeights, NB, SB};
    use crate::quant;

    /// Pre-clamp bound for the quantize truncation: exactly representable
    /// in f32, far above the ±127.5 clamp threshold, and small enough that
    /// `cvttps` can never wrap to `i32::MIN` and flip the sign.
    const BIG: f32 = 1.0e9;

    pub(crate) static SSE2_OPS: KernelOps = KernelOps {
        kind: KernelKind::Sse2,
        gemm_i8_blocked: gemm_i8_sse2,
        quantize_i8: quantize_i8_sse2,
        requant_bias_relu: requant_sse2,
        gemm_f32: gemm_f32_sse2,
        gemm_f32_xt_y: gemm_f32_xt_y_sse2,
        gemm_f32_y_wt: gemm_f32_y_wt_sse2,
    };

    pub(crate) static AVX2_OPS: KernelOps = KernelOps {
        kind: KernelKind::Avx2,
        gemm_i8_blocked: gemm_i8_avx2,
        quantize_i8: quantize_i8_avx2,
        requant_bias_relu: requant_avx2,
        gemm_f32: gemm_f32_avx2,
        gemm_f32_xt_y: gemm_f32_xt_y_avx2,
        gemm_f32_y_wt: gemm_f32_y_wt_avx2,
    };

    // ---------------------------------------------------------------- int8

    fn gemm_i8_sse2(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
        // SAFETY: SSE2 is unconditionally available on x86-64.
        unsafe { gemm_i8_sse2_inner(x, w, m, acc) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn gemm_i8_sse2_inner(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
        gemm_i8_outer(x, w, m, acc, |xrow, prows, occ_rows, width, arow| {
            // SAFETY: sse2 is enabled on this code path by the caller.
            unsafe { strip_sse2(xrow, prows, occ_rows, width, arow) }
        });
    }

    fn gemm_i8_avx2(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
        // SAFETY: this entry is only installed in the vtable after runtime
        // AVX2 detection (dispatch::avx2_ops).
        unsafe { gemm_i8_avx2_inner(x, w, m, acc) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gemm_i8_avx2_inner(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
        gemm_i8_outer(x, w, m, acc, |xrow, prows, occ_rows, width, arow| {
            // SAFETY: avx2 is enabled on this code path by the caller.
            unsafe { strip_avx2(xrow, prows, occ_rows, width, arow) }
        });
    }

    /// Multiply-accumulate one SB=8 sub-block: widen 8 weights i8 -> i32,
    /// multiply by the splatted activation, add into the i32 accumulator
    /// register.  Exact: every product fits i32.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn mac8_avx2(acc: __m256i, w: *const i8, xs: __m256i) -> __m256i {
        let w8 = _mm_loadl_epi64(w as *const __m128i);
        _mm256_add_epi32(acc, _mm256_mullo_epi32(_mm256_cvtepi8_epi32(w8), xs))
    }

    /// SSE2 sub-block MAC: no `cvtepi8_epi32`/`mullo_epi32` below SSE4.1,
    /// so widen i8 -> i16 by sign-unpacking, multiply in i16 (|x|,|w| <=
    /// 128 keeps products <= 16384, exact), widen products to i32 and
    /// memory-accumulate the two 4-lane halves.
    #[target_feature(enable = "sse2")]
    #[inline]
    unsafe fn mac8_sse2(acc: *mut i32, w: *const i8, xs: __m128i) {
        let zero = _mm_setzero_si128();
        let w8 = _mm_loadl_epi64(w as *const __m128i);
        let w16 = _mm_unpacklo_epi8(w8, _mm_cmpgt_epi8(zero, w8));
        let p16 = _mm_mullo_epi16(w16, xs);
        let psign = _mm_cmpgt_epi16(zero, p16);
        let lo = _mm_unpacklo_epi16(p16, psign);
        let hi = _mm_unpackhi_epi16(p16, psign);
        let a0 = _mm_loadu_si128(acc as *const __m128i);
        let a1 = _mm_loadu_si128(acc.add(4) as *const __m128i);
        _mm_storeu_si128(acc as *mut __m128i, _mm_add_epi32(a0, lo));
        _mm_storeu_si128(acc.add(4) as *mut __m128i, _mm_add_epi32(a1, hi));
    }

    /// AVX2 k-strip: one 8-lane i32 accumulator register per full SB=8
    /// sub-block, loaded from `arow` once per strip and stored back once.
    /// Tail columns (width % 8) and ragged partial spans accumulate in
    /// memory; the register-held and memory-held column sets are disjoint.
    #[target_feature(enable = "avx2")]
    unsafe fn strip_avx2(xrow: &[i8], prows: &[i8], occ_rows: &[u8], width: usize, arow: &mut [i32]) {
        let kh = xrow.len();
        let nsb = width.div_ceil(SB);
        let full: u8 = if nsb == 8 { 0xFF } else { ((1u16 << nsb) - 1) as u8 };
        let nfull = width / SB;
        let tail0 = nfull * SB;
        let ap = arow.as_mut_ptr();
        let mut accv = [_mm256_setzero_si256(); NB / SB];
        for (bsub, av) in accv.iter_mut().enumerate().take(nfull) {
            *av = _mm256_loadu_si256(ap.add(bsub * SB) as *const __m256i);
        }
        let mut r = 0usize;
        while r < kh {
            let kb = r / SB;
            let rend = kh.min((kb + 1) * SB);
            let mask = occ_rows[kb];
            if mask == 0 {
                r = rend;
                continue;
            }
            if mask == full {
                for dk in r..rend {
                    let xv = xrow[dk];
                    if xv == 0 {
                        continue;
                    }
                    let xs = _mm256_set1_epi32(xv as i32);
                    let wrow = prows.as_ptr().add(dk * NB);
                    for (bsub, av) in accv.iter_mut().enumerate().take(nfull) {
                        *av = mac8_avx2(*av, wrow.add(bsub * SB), xs);
                    }
                    if tail0 < width {
                        let xi = xv as i32;
                        for c in tail0..width {
                            *ap.add(c) += xi * *wrow.add(c) as i32;
                        }
                    }
                }
            } else {
                let (spans, cnt) = occupied_subblocks(mask, width);
                for dk in r..rend {
                    let xv = xrow[dk];
                    if xv == 0 {
                        continue;
                    }
                    let xs = _mm256_set1_epi32(xv as i32);
                    let wrow = prows.as_ptr().add(dk * NB);
                    for &(c0, cend) in &spans[..cnt] {
                        if cend - c0 == SB {
                            let av = &mut accv[c0 / SB];
                            *av = mac8_avx2(*av, wrow.add(c0), xs);
                        } else {
                            let xi = xv as i32;
                            for c in c0..cend {
                                *ap.add(c) += xi * *wrow.add(c) as i32;
                            }
                        }
                    }
                }
            }
            r = rend;
        }
        for (bsub, av) in accv.iter().enumerate().take(nfull) {
            _mm256_storeu_si256(ap.add(bsub * SB) as *mut __m256i, *av);
        }
    }

    /// SSE2 k-strip: same walk as scalar/AVX2 but memory-accumulating each
    /// SB=8 sub-block as two 4-lane i32 halves.
    #[target_feature(enable = "sse2")]
    unsafe fn strip_sse2(xrow: &[i8], prows: &[i8], occ_rows: &[u8], width: usize, arow: &mut [i32]) {
        let kh = xrow.len();
        let nsb = width.div_ceil(SB);
        let full: u8 = if nsb == 8 { 0xFF } else { ((1u16 << nsb) - 1) as u8 };
        let nfull = width / SB;
        let tail0 = nfull * SB;
        let ap = arow.as_mut_ptr();
        let mut r = 0usize;
        while r < kh {
            let kb = r / SB;
            let rend = kh.min((kb + 1) * SB);
            let mask = occ_rows[kb];
            if mask == 0 {
                r = rend;
                continue;
            }
            if mask == full {
                for dk in r..rend {
                    let xv = xrow[dk];
                    if xv == 0 {
                        continue;
                    }
                    let xs = _mm_set1_epi16(xv as i16);
                    let wrow = prows.as_ptr().add(dk * NB);
                    for bsub in 0..nfull {
                        mac8_sse2(ap.add(bsub * SB), wrow.add(bsub * SB), xs);
                    }
                    if tail0 < width {
                        let xi = xv as i32;
                        for c in tail0..width {
                            *ap.add(c) += xi * *wrow.add(c) as i32;
                        }
                    }
                }
            } else {
                let (spans, cnt) = occupied_subblocks(mask, width);
                for dk in r..rend {
                    let xv = xrow[dk];
                    if xv == 0 {
                        continue;
                    }
                    let xs = _mm_set1_epi16(xv as i16);
                    let wrow = prows.as_ptr().add(dk * NB);
                    for &(c0, cend) in &spans[..cnt] {
                        if cend - c0 == SB {
                            mac8_sse2(ap.add(c0), wrow.add(c0), xs);
                        } else {
                            let xi = xv as i32;
                            for c in c0..cend {
                                *ap.add(c) += xi * *wrow.add(c) as i32;
                            }
                        }
                    }
                }
            }
            r = rend;
        }
    }

    // ------------------------------------------------------------ quantize

    fn quantize_i8_sse2(src: &[f32], s: f32, dst: &mut [i8]) {
        // SAFETY: SSE2 baseline.
        unsafe { quantize_i8_sse2_inner(src, s, dst) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn quantize_i8_sse2_inner(src: &[f32], s: f32, dst: &mut [i8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len();
        let sv = _mm_set1_ps(s);
        let big = _mm_set1_ps(BIG);
        let nbig = _mm_set1_ps(-BIG);
        let half = _mm_set1_ps(0.5);
        let one = _mm_set1_ps(1.0);
        let msign = _mm_set1_ps(-0.0);
        let qmax = _mm_set1_ps(quant::QMAX as f32);
        let qmin = _mm_set1_ps(-(quant::QMAX as f32));
        let mut out = [0i32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            let t = _mm_div_ps(_mm_loadu_ps(src.as_ptr().add(i)), sv);
            // Clamp before cvtt so |t| >= 2^31 cannot wrap to i32::MIN.
            let tc = _mm_max_ps(_mm_min_ps(t, big), nbig);
            let rt = _mm_cvtepi32_ps(_mm_cvttps_epi32(tc));
            // round-half-away-from-zero: bump |rt| when |frac| >= 0.5.
            let frac = _mm_sub_ps(tc, rt);
            let absf = _mm_andnot_ps(msign, frac);
            let bump = _mm_and_ps(_mm_cmpge_ps(absf, half), one);
            let signed_bump = _mm_or_ps(bump, _mm_and_ps(msign, tc));
            let q = _mm_add_ps(rt, signed_bump);
            let c = _mm_min_ps(_mm_max_ps(q, qmin), qmax);
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, _mm_cvtps_epi32(c));
            for lane in 0..4 {
                dst[i + lane] = out[lane] as i8;
            }
            i += 4;
        }
        while i < n {
            dst[i] = quant::quantize(src[i], s) as i8;
            i += 1;
        }
    }

    fn quantize_i8_avx2(src: &[f32], s: f32, dst: &mut [i8]) {
        // SAFETY: installed only after runtime AVX2 detection.
        unsafe { quantize_i8_avx2_inner(src, s, dst) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn quantize_i8_avx2_inner(src: &[f32], s: f32, dst: &mut [i8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len();
        let sv = _mm256_set1_ps(s);
        let big = _mm256_set1_ps(BIG);
        let nbig = _mm256_set1_ps(-BIG);
        let half = _mm256_set1_ps(0.5);
        let one = _mm256_set1_ps(1.0);
        let msign = _mm256_set1_ps(-0.0);
        let qmax = _mm256_set1_ps(quant::QMAX as f32);
        let qmin = _mm256_set1_ps(-(quant::QMAX as f32));
        let mut out = [0i32; 8];
        let mut i = 0usize;
        while i + 8 <= n {
            let t = _mm256_div_ps(_mm256_loadu_ps(src.as_ptr().add(i)), sv);
            let tc = _mm256_max_ps(_mm256_min_ps(t, big), nbig);
            let rt = _mm256_cvtepi32_ps(_mm256_cvttps_epi32(tc));
            let frac = _mm256_sub_ps(tc, rt);
            let absf = _mm256_andnot_ps(msign, frac);
            let bump = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(absf, half), one);
            let signed_bump = _mm256_or_ps(bump, _mm256_and_ps(msign, tc));
            let q = _mm256_add_ps(rt, signed_bump);
            let c = _mm256_min_ps(_mm256_max_ps(q, qmin), qmax);
            _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, _mm256_cvtps_epi32(c));
            for lane in 0..8 {
                dst[i + lane] = out[lane] as i8;
            }
            i += 8;
        }
        while i < n {
            dst[i] = quant::quantize(src[i], s) as i8;
            i += 1;
        }
    }

    // ------------------------------------------------------------- requant

    fn requant_sse2(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut [f32]) {
        // SAFETY: SSE2 baseline.
        unsafe { requant_sse2_inner(acc, ss, bias, relu, out) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn requant_sse2_inner(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut [f32]) {
        let n = bias.len();
        debug_assert_eq!(acc.len(), out.len());
        let ssv = _mm_set1_ps(ss);
        let zero = _mm_setzero_ps();
        for (orow, arow) in out.chunks_exact_mut(n).zip(acc.chunks_exact(n)) {
            let op = orow.as_mut_ptr();
            let apr = arow.as_ptr();
            let bp = bias.as_ptr();
            let mut j = 0usize;
            while j + 4 <= n {
                let av = _mm_cvtepi32_ps(_mm_loadu_si128(apr.add(j) as *const __m128i));
                let mut v = _mm_add_ps(_mm_mul_ps(av, ssv), _mm_loadu_ps(bp.add(j)));
                if relu {
                    v = _mm_max_ps(v, zero);
                }
                _mm_storeu_ps(op.add(j), v);
                j += 4;
            }
            while j < n {
                let v = arow[j] as f32 * ss + bias[j];
                orow[j] = if relu { v.max(0.0) } else { v };
                j += 1;
            }
        }
    }

    fn requant_avx2(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut [f32]) {
        // SAFETY: installed only after runtime AVX2 detection.
        unsafe { requant_avx2_inner(acc, ss, bias, relu, out) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn requant_avx2_inner(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut [f32]) {
        let n = bias.len();
        debug_assert_eq!(acc.len(), out.len());
        let ssv = _mm256_set1_ps(ss);
        let zero = _mm256_setzero_ps();
        for (orow, arow) in out.chunks_exact_mut(n).zip(acc.chunks_exact(n)) {
            let op = orow.as_mut_ptr();
            let apr = arow.as_ptr();
            let bp = bias.as_ptr();
            let mut j = 0usize;
            while j + 8 <= n {
                let av = _mm256_cvtepi32_ps(_mm256_loadu_si256(apr.add(j) as *const __m256i));
                let mut v = _mm256_add_ps(_mm256_mul_ps(av, ssv), _mm256_loadu_ps(bp.add(j)));
                if relu {
                    v = _mm256_max_ps(v, zero);
                }
                _mm256_storeu_ps(op.add(j), v);
                j += 8;
            }
            while j < n {
                let v = arow[j] as f32 * ss + bias[j];
                orow[j] = if relu { v.max(0.0) } else { v };
                j += 1;
            }
        }
    }

    // ----------------------------------------------------------- f32 gemms

    /// `a[j] += s * b[j]` vectorized across output columns: 4x8 unrolled
    /// main loop (the "register blocking across n"), then 8-wide, then a
    /// scalar tail.  Separate mul + add per element, same as scalar.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn axpy_avx2(s: f32, b: &[f32], a: &mut [f32]) {
        debug_assert_eq!(b.len(), a.len());
        let n = a.len();
        let bp = b.as_ptr();
        let ap = a.as_mut_ptr();
        let sv = _mm256_set1_ps(s);
        let mut j = 0usize;
        while j + 32 <= n {
            let a0 = _mm256_add_ps(_mm256_loadu_ps(ap.add(j)), _mm256_mul_ps(sv, _mm256_loadu_ps(bp.add(j))));
            let a1 = _mm256_add_ps(_mm256_loadu_ps(ap.add(j + 8)), _mm256_mul_ps(sv, _mm256_loadu_ps(bp.add(j + 8))));
            let a2 = _mm256_add_ps(_mm256_loadu_ps(ap.add(j + 16)), _mm256_mul_ps(sv, _mm256_loadu_ps(bp.add(j + 16))));
            let a3 = _mm256_add_ps(_mm256_loadu_ps(ap.add(j + 24)), _mm256_mul_ps(sv, _mm256_loadu_ps(bp.add(j + 24))));
            _mm256_storeu_ps(ap.add(j), a0);
            _mm256_storeu_ps(ap.add(j + 8), a1);
            _mm256_storeu_ps(ap.add(j + 16), a2);
            _mm256_storeu_ps(ap.add(j + 24), a3);
            j += 32;
        }
        while j + 8 <= n {
            let av = _mm256_add_ps(_mm256_loadu_ps(ap.add(j)), _mm256_mul_ps(sv, _mm256_loadu_ps(bp.add(j))));
            _mm256_storeu_ps(ap.add(j), av);
            j += 8;
        }
        while j < n {
            *ap.add(j) += s * *bp.add(j);
            j += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    #[inline]
    unsafe fn axpy_sse2(s: f32, b: &[f32], a: &mut [f32]) {
        debug_assert_eq!(b.len(), a.len());
        let n = a.len();
        let bp = b.as_ptr();
        let ap = a.as_mut_ptr();
        let sv = _mm_set1_ps(s);
        let mut j = 0usize;
        while j + 16 <= n {
            let a0 = _mm_add_ps(_mm_loadu_ps(ap.add(j)), _mm_mul_ps(sv, _mm_loadu_ps(bp.add(j))));
            let a1 = _mm_add_ps(_mm_loadu_ps(ap.add(j + 4)), _mm_mul_ps(sv, _mm_loadu_ps(bp.add(j + 4))));
            let a2 = _mm_add_ps(_mm_loadu_ps(ap.add(j + 8)), _mm_mul_ps(sv, _mm_loadu_ps(bp.add(j + 8))));
            let a3 = _mm_add_ps(_mm_loadu_ps(ap.add(j + 12)), _mm_mul_ps(sv, _mm_loadu_ps(bp.add(j + 12))));
            _mm_storeu_ps(ap.add(j), a0);
            _mm_storeu_ps(ap.add(j + 4), a1);
            _mm_storeu_ps(ap.add(j + 8), a2);
            _mm_storeu_ps(ap.add(j + 12), a3);
            j += 16;
        }
        while j + 4 <= n {
            let av = _mm_add_ps(_mm_loadu_ps(ap.add(j)), _mm_mul_ps(sv, _mm_loadu_ps(bp.add(j))));
            _mm_storeu_ps(ap.add(j), av);
            j += 4;
        }
        while j < n {
            *ap.add(j) += s * *bp.add(j);
            j += 1;
        }
    }

    fn gemm_f32_avx2(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        // SAFETY: installed only after runtime AVX2 detection.
        unsafe { gemm_f32_avx2_inner(x, w, m, k, n, acc) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gemm_f32_avx2_inner(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        f32core::gemm_core(AView::RowMajor(x), w, m, k, n, acc, |s, b, a| {
            // SAFETY: avx2 enabled on this path.
            unsafe { axpy_avx2(s, b, a) }
        });
    }

    fn gemm_f32_xt_y_avx2(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        // SAFETY: installed only after runtime AVX2 detection.
        unsafe { gemm_f32_xt_y_avx2_inner(x, y, m, k, n, acc) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gemm_f32_xt_y_avx2_inner(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        f32core::gemm_core(AView::Transposed(x), y, k, m, n, acc, |s, b, a| {
            // SAFETY: avx2 enabled on this path.
            unsafe { axpy_avx2(s, b, a) }
        });
    }

    fn gemm_f32_y_wt_avx2(y: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        // SAFETY: installed only after runtime AVX2 detection.
        unsafe { gemm_f32_y_wt_avx2_inner(y, w, m, k, n, acc) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gemm_f32_y_wt_avx2_inner(y: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        f32core::with_wt(w, k, n, |wt| {
            f32core::gemm_core(AView::RowMajor(y), wt, m, n, k, acc, |s, b, a| {
                // SAFETY: avx2 enabled on this path.
                unsafe { axpy_avx2(s, b, a) }
            });
        });
    }

    fn gemm_f32_sse2(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        // SAFETY: SSE2 baseline.
        unsafe { gemm_f32_sse2_inner(x, w, m, k, n, acc) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn gemm_f32_sse2_inner(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        f32core::gemm_core(AView::RowMajor(x), w, m, k, n, acc, |s, b, a| {
            // SAFETY: sse2 enabled on this path.
            unsafe { axpy_sse2(s, b, a) }
        });
    }

    fn gemm_f32_xt_y_sse2(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        // SAFETY: SSE2 baseline.
        unsafe { gemm_f32_xt_y_sse2_inner(x, y, m, k, n, acc) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn gemm_f32_xt_y_sse2_inner(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        f32core::gemm_core(AView::Transposed(x), y, k, m, n, acc, |s, b, a| {
            // SAFETY: sse2 enabled on this path.
            unsafe { axpy_sse2(s, b, a) }
        });
    }

    fn gemm_f32_y_wt_sse2(y: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        // SAFETY: SSE2 baseline.
        unsafe { gemm_f32_y_wt_sse2_inner(y, w, m, k, n, acc) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn gemm_f32_y_wt_sse2_inner(y: &[f32], w: &[f32], m: usize, k: usize, n: usize, acc: &mut [f32]) {
        f32core::with_wt(w, k, n, |wt| {
            f32core::gemm_core(AView::RowMajor(y), wt, m, n, k, acc, |s, b, a| {
                // SAFETY: sse2 enabled on this path.
                unsafe { axpy_sse2(s, b, a) }
            });
        });
    }
}
