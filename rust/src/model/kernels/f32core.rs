//! One f32 GEMM loop nest shared by every variant and backend.
//!
//! The three training GEMMs (`gemm_f32`, `gemm_f32_xt_y`, `gemm_f32_y_wt`)
//! are all "C[rows x width] += A[rows x red] * B[red x width]" after
//! choosing how A is viewed ([`AView`]) and, for the `y_wt` case,
//! materializing a transposed copy of W (thread-local scratch, see
//! [`with_wt`]).  The core fixes the accumulation order so that backends
//! only choose *how an axpy row is executed*, never *in what order partial
//! sums land*:
//!
//! - per output row `o`, the reduction index `t` ascends `0..red`;
//! - each step does `acc_row += a(o,t) * b_row(t)` via the caller's axpy;
//! - an axpy may be vectorized across the `width` axis (output columns are
//!   independent accumulators — lanes never mix), but must compute each
//!   element as `acc[j] + s * b[j]` with one multiply and one add.
//!
//! That makes every backend bit-identical to the scalar reference: the
//! per-output-element chain of f32 adds is the same sequence of operations
//! in the same order.  (No FMA anywhere: a fused multiply-add rounds once
//! where `mul` + `add` round twice, which would change bits.)
//!
//! The `a(o,t) == 0.0` skip is order-preserving too: skipping a term means
//! not executing `acc[j] += 0.0 * b[j]`.  For finite `b` that term is
//! `acc[j] += ±0.0`, and since every accumulator chain starts at a caller
//! zeroed (+0.0) buffer, partial sums are never -0.0, so adding ±0.0 is a
//! bit-level no-op.

use std::cell::RefCell;

/// How the A operand of `C += A * B` is stored.
#[derive(Clone, Copy)]
pub(crate) enum AView<'a> {
    /// `a[o * red + t]`: A is rows x red, row-major.
    RowMajor(&'a [f32]),
    /// `a[t * rows + o]`: A is red x rows, row-major (we walk its transpose).
    Transposed(&'a [f32]),
}

/// The shared loop nest.  `axpy(s, brow, arow)` must perform
/// `arow[j] += s * brow[j]` for all j (any vector width, no FMA).
#[inline(always)]
pub(crate) fn gemm_core(
    a: AView,
    b: &[f32],
    rows: usize,
    red: usize,
    width: usize,
    acc: &mut [f32],
    mut axpy: impl FnMut(f32, &[f32], &mut [f32]),
) {
    debug_assert_eq!(b.len(), red * width);
    debug_assert_eq!(acc.len(), rows * width);
    for o in 0..rows {
        let arow = &mut acc[o * width..(o + 1) * width];
        match a {
            AView::RowMajor(av) => {
                let r = &av[o * red..(o + 1) * red];
                for (t, &s) in r.iter().enumerate() {
                    if s == 0.0 {
                        continue;
                    }
                    axpy(s, &b[t * width..(t + 1) * width], arow);
                }
            }
            AView::Transposed(av) => {
                for t in 0..red {
                    let s = av[t * rows + o];
                    if s == 0.0 {
                        continue;
                    }
                    axpy(s, &b[t * width..(t + 1) * width], arow);
                }
            }
        }
    }
}

thread_local! {
    /// Scratch for the transposed-W copy `gemm_f32_y_wt` needs so its B
    /// operand is row-major like the others.  Per worker thread: the grad
    /// engine calls in from pool workers concurrently.
    static WT_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Materialize `wt[n x k]` = transpose of `w[k x n]` into thread-local
/// scratch and hand it to `f`.
#[inline]
pub(crate) fn with_wt<R>(w: &[f32], k: usize, n: usize, f: impl FnOnce(&[f32]) -> R) -> R {
    debug_assert_eq!(w.len(), k * n);
    WT_SCRATCH.with(|cell| {
        let mut wt = cell.borrow_mut();
        wt.clear();
        wt.resize(n * k, 0.0);
        for r in 0..k {
            let wrow = &w[r * n..(r + 1) * n];
            for (j, &wv) in wrow.iter().enumerate() {
                wt[j * k + r] = wv;
            }
        }
        f(&wt)
    })
}
