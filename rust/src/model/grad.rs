//! Reverse-mode training engine: the native mirror of the AOT `train`
//! HLO graph.
//!
//! Forward semantics match `python/compile/model.py` exactly:
//!
//! * weights are **always** fake-quantized (mask → per-tensor scale →
//!   int8 round/clip → optional candidate-set projection → dequantize),
//!   even when `quant_on` is false — that is what `_quant_weight` does
//!   in the JAX graph;
//! * activations are fake-quantized per quant point only when
//!   `quant_on` is set;
//! * convolutions and fc layers compute in f32 over the fake-quant
//!   values (the training path uses XLA's float convolution, not the
//!   int8 mirror), so this engine reproduces the AOT training numerics
//!   up to float summation order.
//!
//! Backward applies the straight-through estimator: every fake-quant
//! (weights and activations) has identity gradient, with the pruning
//! mask as the only weight-gradient filter (`w_eff = w ⊙ mask` is the
//! sole differentiable path through `_quant_weight`).  ReLU kinks use
//! the `x > 0` convention (JAX's `relu` JVP), max-pool routes to the
//! first maximum in forward scan order, and the loss is the batch-mean
//! softmax cross-entropy.
//!
//! Parallelism: images are independent, so [`GradEngine::batch_grad`]
//! fans them out over [`crate::util::threadpool`] and reduces per-image
//! gradients **in ascending image order** on the caller's thread —
//! results are bit-identical at any thread count (pinned in
//! `rust/tests/native_backend.rs`).  Finite-difference checks for every
//! backward kernel live in this module's tests (with weight fake-quant
//! disabled, since a rounding staircase has no meaningful FD slope —
//! the `fake_quant_weights: false` switch exists for exactly that).

use super::infer::QuantConfig;
use super::kernels;
use super::spec::{ConvOp, ModelSpec, Op, INPUT_C, INPUT_ELEMS, INPUT_H, INPUT_W};
use crate::quant;
use crate::util::threadpool::parallel_for_with;

/// Fake-quantize one value at scale `s` (symmetric int8, JAX
/// `fake_quant_ref` semantics: non-positive scale maps everything to 0).
#[inline]
fn fq(v: f32, s: f32) -> f32 {
    if s > 0.0 {
        quant::dequantize(quant::quantize(v, s), s)
    } else {
        0.0
    }
}

/// Per-image tensor shape at a step boundary.
#[derive(Clone, Copy, Debug)]
struct Sh {
    h: usize,
    w: usize,
    c: usize,
    flat: bool,
}

impl Sh {
    fn numel(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Pre-lowered conv weights for one training step: fake-quant values in
/// the K×N im2col layout plus the OIHW pruning mask (the STE gradient
/// filter).
struct ConvW {
    /// kk×nn fake-quant weight *values* (codes·scale), row-major.
    wkn: Vec<f32>,
    /// OIHW 0/1 mask; empty = dense.
    mask: Vec<f32>,
}

/// Fake-quant fc weights (dout×din values, no mask).
struct FcW {
    wvals: Vec<f32>,
}

/// Per-image tape entry: the step's output plus (when activations are
/// quantized) the fake-quant input values the matmul actually consumed.
#[derive(Default)]
struct TapeEntry {
    out: Vec<f32>,
    qin: Vec<f32>,
    proj_out: Vec<f32>,
    proj_qin: Vec<f32>,
}

/// Reused per-image scratch (one per worker).  The im2col patch matrix
/// feeds the dispatched f32 GEMMs (which may run SIMD; see
/// `kernels::dispatch` — every backend preserves the scalar
/// accumulation order, so gradients stay bit-identical at any thread
/// count on any backend), so it lives in a 64-byte-aligned buffer.
#[derive(Default)]
struct GradScratch {
    cols: kernels::AVec<f32>,
    dcols: Vec<f32>,
    dwkn: Vec<f32>,
    qbuf: Vec<f32>,
}

/// One image's backward product.
struct ImgGrad {
    loss: f32,
    grads: Vec<Vec<f32>>,
}

/// The compiled training engine: spec + one fake-quant weight snapshot.
/// Rebuild per step (weight quantization tracks the float shadow
/// weights, exactly like the AOT graph recomputes it every step).
pub struct GradEngine<'s> {
    spec: &'s ModelSpec,
    quant_on: bool,
    act_scales: Vec<f32>,
    convs: Vec<ConvW>,
    fcs: Vec<FcW>,
    /// Input shape of each op.
    shapes: Vec<Sh>,
    /// For each `AddSaved` op index, the matching `Save` op index.
    pairs: Vec<usize>,
}

impl<'s> GradEngine<'s> {
    /// Lower `params` under `qc`.  `fake_quant_weights` is true on every
    /// production path; tests disable it so the loss is differentiable
    /// and finite differences can validate the backward kernels.
    pub fn new(
        spec: &'s ModelSpec,
        params: &[Vec<f32>],
        qc: &QuantConfig,
        fake_quant_weights: bool,
    ) -> Self {
        assert_eq!(qc.act_scales.len(), spec.n_q);
        assert_eq!(qc.masks.len(), spec.n_conv);
        assert_eq!(qc.wsets.len(), spec.n_conv);
        // Conv weights in conv_idx order.
        let convs = spec
            .convs()
            .iter()
            .map(|cv| {
                let wt = &params[cv.w];
                let mask = qc.masks[cv.conv_idx].clone().unwrap_or_default();
                let m_opt = if mask.is_empty() {
                    None
                } else {
                    Some(mask.as_slice())
                };
                let w_oihw: Vec<f32> = if fake_quant_weights {
                    let (codes, s) =
                        quant::quantize_restricted(wt, m_opt, qc.wsets[cv.conv_idx].as_ref());
                    codes.iter().map(|&c| c as f32 * s).collect()
                } else {
                    match m_opt {
                        Some(m) => wt.iter().zip(m).map(|(&v, &mv)| v * mv).collect(),
                        None => wt.clone(),
                    }
                };
                // OIHW -> K×N ((ky, kx, ci) rows, cout columns).
                let kk = cv.k * cv.k * cv.cin;
                let nn = cv.cout;
                let mut wkn = vec![0.0f32; kk * nn];
                for o in 0..cv.cout {
                    for ci in 0..cv.cin {
                        for ky in 0..cv.k {
                            for kx in 0..cv.k {
                                let src = ((o * cv.cin + ci) * cv.k + ky) * cv.k + kx;
                                let row = (ky * cv.k + kx) * cv.cin + ci;
                                wkn[row * nn + o] = w_oihw[src];
                            }
                        }
                    }
                }
                ConvW { wkn, mask }
            })
            .collect();
        let fcs = spec
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::Fc(fc) => {
                    let wt = &params[fc.w];
                    let wvals = if fake_quant_weights {
                        let (codes, s) = quant::quantize_restricted(wt, None, None);
                        codes.iter().map(|&c| c as f32 * s).collect()
                    } else {
                        wt.clone()
                    };
                    Some(FcW { wvals })
                }
                _ => None,
            })
            .collect();
        let (shapes, pairs) = Self::lower_shapes(spec);
        Self {
            spec,
            quant_on: qc.quant_on,
            act_scales: qc.act_scales.clone(),
            convs,
            fcs,
            shapes,
            pairs,
        }
    }

    /// Input shape of every op plus the Save index matching each
    /// AddSaved (mirrors the IR lowering's structural checks).
    fn lower_shapes(spec: &ModelSpec) -> (Vec<Sh>, Vec<usize>) {
        let mut sh = Sh {
            h: INPUT_H,
            w: INPUT_W,
            c: INPUT_C,
            flat: false,
        };
        let mut shapes = Vec::with_capacity(spec.ops.len());
        let mut pairs = vec![usize::MAX; spec.ops.len()];
        let mut saved: Vec<(usize, Sh)> = Vec::new();
        for (i, op) in spec.ops.iter().enumerate() {
            shapes.push(sh);
            match op {
                Op::Conv(cv) => {
                    assert_eq!((sh.h, sh.w, sh.c), (cv.hin, cv.win, cv.cin));
                    sh = Sh {
                        h: cv.hout,
                        w: cv.wout,
                        c: cv.cout,
                        flat: false,
                    };
                }
                Op::MaxPool2 => {
                    assert!(sh.h % 2 == 0 && sh.w % 2 == 0, "maxpool2 needs even dims");
                    sh.h /= 2;
                    sh.w /= 2;
                }
                Op::Gap => {
                    sh = Sh {
                        h: 1,
                        w: 1,
                        c: sh.c,
                        flat: true,
                    };
                }
                Op::Flatten => {
                    sh = Sh {
                        h: 1,
                        w: 1,
                        c: sh.numel(),
                        flat: true,
                    };
                }
                Op::Save => saved.push((i, sh)),
                Op::AddSaved { proj, .. } => {
                    let (j, ssh) = saved.pop().expect("unbalanced save/add");
                    pairs[i] = j;
                    if let Some(p) = proj {
                        assert_eq!((ssh.h, ssh.w, ssh.c), (p.hin, p.win, p.cin));
                        assert_eq!((p.hout, p.wout, p.cout), (sh.h, sh.w, sh.c));
                    } else {
                        assert_eq!(ssh.numel(), sh.numel(), "skip shape mismatch");
                    }
                }
                Op::Fc(fc) => {
                    assert!(sh.flat, "fc expects flattened input");
                    assert_eq!(sh.c, fc.din);
                    sh = Sh {
                        h: 1,
                        w: 1,
                        c: fc.dout,
                        flat: true,
                    };
                }
            }
        }
        assert!(saved.is_empty(), "unbalanced save/add");
        (shapes, pairs)
    }

    /// Fake-quantize `src` at quant point `q_idx` into `dst`; returns
    /// whether quantization was applied (false ⇒ caller uses `src`).
    fn quant_act(&self, src: &[f32], q_idx: usize, dst: &mut Vec<f32>) -> bool {
        if !self.quant_on {
            return false;
        }
        let s = self.act_scales[q_idx];
        dst.clear();
        dst.extend(src.iter().map(|&v| fq(v, s)));
        true
    }

    /// Conv forward over one image: fake-quant input (when quantizing),
    /// im2col, f32 GEMM, bias, ReLU.  Returns (output, stored quantized
    /// input — empty when the raw input was used or `keep_qin` is off;
    /// tape-less forwards keep the buffer in the scratch for reuse).
    fn conv_fwd(
        &self,
        cv: &ConvOp,
        input: &[f32],
        params: &[Vec<f32>],
        scratch: &mut GradScratch,
        keep_qin: bool,
    ) -> (Vec<f32>, Vec<f32>) {
        let cw = &self.convs[cv.conv_idx];
        let used_q = self.quant_act(input, cv.q_idx, &mut scratch.qbuf);
        let x_used: &[f32] = if used_q { &scratch.qbuf } else { input };
        kernels::im2col_f32(x_used, 1, cv.hin, cv.win, cv.cin, cv, &mut scratch.cols);
        let m = cv.hout * cv.wout;
        let kk = cv.k * cv.k * cv.cin;
        let nn = cv.cout;
        let mut out = vec![0.0f32; m * nn];
        kernels::gemm_f32(&scratch.cols, &cw.wkn, m, kk, nn, &mut out);
        let bias = &params[cv.b];
        for row in out.chunks_exact_mut(nn) {
            for (v, &bv) in row.iter_mut().zip(bias) {
                *v += bv;
            }
        }
        if cv.relu {
            out.iter_mut().for_each(|v| *v = v.max(0.0));
        }
        let qin = if used_q && keep_qin {
            std::mem::take(&mut scratch.qbuf)
        } else {
            Vec::new()
        };
        (out, qin)
    }

    /// Conv backward over one image.  `dy` is the gradient at the conv
    /// *output* (post-ReLU); `input`/`qin` are the tensors the forward
    /// consumed; accumulates into `gw`/`gb` (param-shaped) and returns
    /// the input gradient (STE: activation fake-quant is identity).
    #[allow(clippy::too_many_arguments)]
    fn conv_bwd(
        &self,
        cv: &ConvOp,
        input: &[f32],
        qin: &[f32],
        out: &[f32],
        mut dy: Vec<f32>,
        gw: &mut [f32],
        gb: &mut [f32],
        scratch: &mut GradScratch,
    ) -> Vec<f32> {
        let cw = &self.convs[cv.conv_idx];
        let m = cv.hout * cv.wout;
        let kk = cv.k * cv.k * cv.cin;
        let nn = cv.cout;
        if cv.relu {
            for (d, &o) in dy.iter_mut().zip(out) {
                if o <= 0.0 {
                    *d = 0.0;
                }
            }
        }
        // Bias gradient: column sums of dY.
        for row in dy.chunks_exact(nn) {
            for (g, &d) in gb.iter_mut().zip(row) {
                *g += d;
            }
        }
        // Weight gradient: dWkn = colsᵀ·dY, remapped to OIHW under the
        // pruning mask (the STE path through w_eff = w ⊙ mask).
        let x_used: &[f32] = if qin.is_empty() { input } else { qin };
        kernels::im2col_f32(x_used, 1, cv.hin, cv.win, cv.cin, cv, &mut scratch.cols);
        scratch.dwkn.clear();
        scratch.dwkn.resize(kk * nn, 0.0);
        kernels::gemm_f32_xt_y(&scratch.cols, &dy, m, kk, nn, &mut scratch.dwkn);
        let dense = cw.mask.is_empty();
        for o in 0..cv.cout {
            for ci in 0..cv.cin {
                for ky in 0..cv.k {
                    for kx in 0..cv.k {
                        let dst = ((o * cv.cin + ci) * cv.k + ky) * cv.k + kx;
                        let row = (ky * cv.k + kx) * cv.cin + ci;
                        let g = scratch.dwkn[row * nn + o];
                        gw[dst] += if dense { g } else { g * cw.mask[dst] };
                    }
                }
            }
        }
        // Input gradient: dCols = dY·Wᵀ, scattered back by col2im.
        scratch.dcols.clear();
        scratch.dcols.resize(m * kk, 0.0);
        kernels::gemm_f32_y_wt(&dy, &cw.wkn, m, kk, nn, &mut scratch.dcols);
        let mut dx = vec![0.0f32; cv.hin * cv.win * cv.cin];
        kernels::col2im_f32_add(&scratch.dcols, 1, cv.hin, cv.win, cv.cin, cv, &mut dx);
        dx
    }

    /// Forward one image, recording the tape when `tape` is given.
    /// Returns the logits.
    fn forward_image(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        scratch: &mut GradScratch,
        mut tape: Option<&mut Vec<TapeEntry>>,
    ) -> Vec<f32> {
        assert_eq!(x.len(), INPUT_ELEMS);
        let mut cur: Vec<f32> = x.to_vec();
        let mut saved: Vec<Vec<f32>> = Vec::new();
        let mut fc_pos = 0usize;
        for (i, op) in self.spec.ops.iter().enumerate() {
            let sh = self.shapes[i];
            let mut entry = TapeEntry::default();
            match op {
                Op::Conv(cv) => {
                    let (out, qin) = self.conv_fwd(cv, &cur, params, scratch, tape.is_some());
                    entry.qin = qin;
                    cur = out;
                }
                Op::MaxPool2 => {
                    let mut out = Vec::new();
                    kernels::maxpool2(&cur, 1, sh.h, sh.w, sh.c, &mut out);
                    cur = out;
                }
                Op::Gap => {
                    let mut out = Vec::new();
                    kernels::gap(&cur, 1, sh.h, sh.w, sh.c, &mut out);
                    cur = out;
                }
                Op::Flatten => {}
                Op::Save => saved.push(cur.clone()),
                Op::AddSaved { relu, proj } => {
                    let skip = saved.pop().expect("unbalanced save/add");
                    let skip = if let Some(p) = proj {
                        let (out, qin) = self.conv_fwd(p, &skip, params, scratch, tape.is_some());
                        if tape.is_some() {
                            entry.proj_qin = qin;
                            entry.proj_out = out.clone();
                        }
                        out
                    } else {
                        skip
                    };
                    for (a, &b) in cur.iter_mut().zip(&skip) {
                        *a += b;
                    }
                    if *relu {
                        cur.iter_mut().for_each(|v| *v = v.max(0.0));
                    }
                }
                Op::Fc(fc) => {
                    let used_q = self.quant_act(&cur, fc.q_idx, &mut scratch.qbuf);
                    let x_used: &[f32] = if used_q { &scratch.qbuf } else { &cur };
                    let fw = &self.fcs[fc_pos];
                    let bias = &params[fc.b];
                    let mut out = vec![0.0f32; fc.dout];
                    for (o, ov) in out.iter_mut().enumerate() {
                        let wrow = &fw.wvals[o * fc.din..(o + 1) * fc.din];
                        let mut acc = 0.0f32;
                        for (xv, wv) in x_used.iter().zip(wrow) {
                            acc += xv * wv;
                        }
                        *ov = acc + bias[o];
                        if fc.relu {
                            *ov = ov.max(0.0);
                        }
                    }
                    if used_q && tape.is_some() {
                        entry.qin = std::mem::take(&mut scratch.qbuf);
                    }
                    fc_pos += 1;
                    cur = out;
                }
            }
            if let Some(t) = tape.as_mut() {
                entry.out = cur.clone();
                t.push(entry);
            }
        }
        cur
    }

    /// Softmax cross-entropy of one image: (nll, dlogits).
    fn xent(logits: &[f32], label: i32) -> (f32, Vec<f32>) {
        let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let sum: f32 = logits.iter().map(|&v| (v - max).exp()).sum();
        let lse = max + sum.ln();
        let y = label as usize;
        assert!(y < logits.len(), "label {label} out of range");
        let loss = lse - logits[y];
        let mut d: Vec<f32> = logits.iter().map(|&v| (v - lse).exp()).collect();
        d[y] -= 1.0;
        (loss, d)
    }

    /// Forward + backward for one image; returns the per-image NLL and
    /// param-shaped gradients of that NLL (unscaled — the caller
    /// divides the fixed-order sum by the batch size).
    fn image_grad(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        label: i32,
        scratch: &mut GradScratch,
    ) -> ImgGrad {
        let mut tape: Vec<TapeEntry> = Vec::with_capacity(self.spec.ops.len());
        let logits = self.forward_image(params, x, scratch, Some(&mut tape));
        let (loss, dlogits) = Self::xent(&logits, label);
        let mut grads: Vec<Vec<f32>> = self
            .spec
            .params
            .iter()
            .map(|p| vec![0.0f32; p.numel()])
            .collect();
        let mut dcur = dlogits;
        // Pending skip gradients keyed by Save op index.
        let mut pending: Vec<Option<Vec<f32>>> = vec![None; self.spec.ops.len()];
        let mut fc_pos = self.fcs.len();
        for (i, op) in self.spec.ops.iter().enumerate().rev() {
            let sh = self.shapes[i];
            let input: &[f32] = if i == 0 { x } else { &tape[i - 1].out };
            match op {
                Op::Conv(cv) => {
                    let (gw, gb) = split_two(&mut grads, cv.w, cv.b);
                    dcur = self.conv_bwd(
                        cv,
                        input,
                        &tape[i].qin,
                        &tape[i].out,
                        dcur,
                        gw,
                        gb,
                        scratch,
                    );
                }
                Op::MaxPool2 => {
                    let (h, w, c) = (sh.h, sh.w, sh.c);
                    let (ho, wo) = (h / 2, w / 2);
                    let out = &tape[i].out;
                    let mut dx = vec![0.0f32; h * w * c];
                    for oy in 0..ho {
                        for ox in 0..wo {
                            for ch in 0..c {
                                let ov = out[(oy * wo + ox) * c + ch];
                                let d = dcur[(oy * wo + ox) * c + ch];
                                if d == 0.0 {
                                    continue;
                                }
                                // First maximum in forward scan order
                                // (y-major) receives the gradient.
                                'route: for dy_ in 0..2 {
                                    for dx_ in 0..2 {
                                        let iy = oy * 2 + dy_;
                                        let ix = ox * 2 + dx_;
                                        if input[(iy * w + ix) * c + ch] == ov {
                                            dx[(iy * w + ix) * c + ch] += d;
                                            break 'route;
                                        }
                                    }
                                }
                            }
                        }
                    }
                    dcur = dx;
                }
                Op::Gap => {
                    let (h, w, c) = (sh.h, sh.w, sh.c);
                    let inv = 1.0 / (h * w) as f32;
                    let mut dx = vec![0.0f32; h * w * c];
                    for pix in 0..h * w {
                        for ch in 0..c {
                            dx[pix * c + ch] = dcur[ch] * inv;
                        }
                    }
                    dcur = dx;
                }
                Op::Flatten => {}
                Op::Save => {
                    if let Some(dskip) = pending[i].take() {
                        for (a, b) in dcur.iter_mut().zip(dskip) {
                            *a += b;
                        }
                    }
                }
                Op::AddSaved { relu, proj } => {
                    if *relu {
                        for (d, &o) in dcur.iter_mut().zip(&tape[i].out) {
                            if o <= 0.0 {
                                *d = 0.0;
                            }
                        }
                    }
                    let save_idx = self.pairs[i];
                    let dskip = if let Some(p) = proj {
                        let saved_in: &[f32] = &tape[save_idx].out;
                        let (gw, gb) = split_two(&mut grads, p.w, p.b);
                        self.conv_bwd(
                            p,
                            saved_in,
                            &tape[i].proj_qin,
                            &tape[i].proj_out,
                            dcur.clone(),
                            gw,
                            gb,
                            scratch,
                        )
                    } else {
                        dcur.clone()
                    };
                    pending[save_idx] = Some(dskip);
                    // dcur continues unchanged to the main branch.
                }
                Op::Fc(fc) => {
                    fc_pos -= 1;
                    if fc.relu {
                        for (d, &o) in dcur.iter_mut().zip(&tape[i].out) {
                            if o <= 0.0 {
                                *d = 0.0;
                            }
                        }
                    }
                    let x_used: &[f32] = if tape[i].qin.is_empty() {
                        input
                    } else {
                        &tape[i].qin
                    };
                    let fw = &self.fcs[fc_pos];
                    let (gw, gb) = split_two(&mut grads, fc.w, fc.b);
                    let mut dx = vec![0.0f32; fc.din];
                    for (o, &d) in dcur.iter().enumerate() {
                        gb[o] += d;
                        if d == 0.0 {
                            continue;
                        }
                        let grow = &mut gw[o * fc.din..(o + 1) * fc.din];
                        let wrow = &fw.wvals[o * fc.din..(o + 1) * fc.din];
                        for j in 0..fc.din {
                            grow[j] += d * x_used[j];
                            dx[j] += d * wrow[j];
                        }
                    }
                    dcur = dx;
                }
            }
        }
        ImgGrad { loss, grads }
    }

    /// Logits for a batch (NHWC f32 input), data-parallel across images;
    /// bit-identical for any `threads`.
    pub fn forward_batch(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        batch: usize,
        threads: usize,
    ) -> Vec<f32> {
        assert_eq!(x.len(), batch * INPUT_ELEMS);
        let ncls = self.spec.n_classes;
        let outs = parallel_for_with(
            batch,
            threads,
            || (GradScratch::default(), Vec::new()),
            |state: &mut (GradScratch, Vec<(usize, Vec<f32>)>), i| {
                let (scratch, outs) = state;
                let xi = &x[i * INPUT_ELEMS..(i + 1) * INPUT_ELEMS];
                outs.push((i, self.forward_image(params, xi, scratch, None)));
            },
        );
        let mut logits = vec![0.0f32; batch * ncls];
        for (_s, imgs) in outs {
            for (i, l) in imgs {
                logits[i * ncls..(i + 1) * ncls].copy_from_slice(&l);
            }
        }
        logits
    }

    /// Mean loss and mean-loss gradients over a batch.  Per-image
    /// gradients are computed in parallel, then reduced in ascending
    /// image order and scaled by 1/batch, so the result is bit-identical
    /// at any thread count.
    pub fn batch_grad(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        threads: usize,
    ) -> (f32, Vec<Vec<f32>>) {
        let batch = y.len();
        assert_eq!(x.len(), batch * INPUT_ELEMS);
        let mut total: Vec<Vec<f32>> = self
            .spec
            .params
            .iter()
            .map(|p| vec![0.0f32; p.numel()])
            .collect();
        let mut loss_sum = 0.0f32;
        // Waves bound the resident per-image gradient memory to
        // O(threads · |params|) instead of O(batch · |params|).
        let wave = threads.max(1) * 4;
        let mut img0 = 0usize;
        while img0 < batch {
            let count = wave.min(batch - img0);
            let outs = parallel_for_with(
                count,
                threads,
                || (GradScratch::default(), Vec::new()),
                |state: &mut (GradScratch, Vec<(usize, ImgGrad)>), i| {
                    let (scratch, outs) = state;
                    let idx = img0 + i;
                    let xi = &x[idx * INPUT_ELEMS..(idx + 1) * INPUT_ELEMS];
                    outs.push((i, self.image_grad(params, xi, y[idx], scratch)));
                },
            );
            let mut flat: Vec<(usize, ImgGrad)> =
                outs.into_iter().flat_map(|(_s, v)| v).collect();
            flat.sort_by_key(|(i, _)| *i);
            for (_i, ig) in flat {
                loss_sum += ig.loss;
                for (t, g) in total.iter_mut().zip(&ig.grads) {
                    for (a, &b) in t.iter_mut().zip(g) {
                        *a += b;
                    }
                }
            }
            img0 += count;
        }
        let inv = 1.0 / batch as f32;
        for t in &mut total {
            t.iter_mut().for_each(|v| *v *= inv);
        }
        (loss_sum * inv, total)
    }
}

/// Two disjoint mutable tensor borrows out of the gradient list.
fn split_two(grads: &mut [Vec<f32>], a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = grads.split_at_mut(b);
        (lo[a].as_mut_slice(), hi[0].as_mut_slice())
    } else {
        let (lo, hi) = grads.split_at_mut(a);
        (hi[0].as_mut_slice(), lo[b].as_mut_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::tests_support::tiny_spec;
    use super::*;
    use crate::model::{ModelSpec, Params, QuantConfig};

    fn input(batch: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..batch * INPUT_ELEMS)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect()
    }

    /// Loss of a batch under float-mode weights (no fake-quant anywhere)
    /// — the differentiable function the FD checks probe.
    fn loss_of(spec: &ModelSpec, params: &[Vec<f32>], x: &[f32], y: &[i32]) -> f64 {
        let qc = QuantConfig::float(spec);
        let eng = GradEngine::new(spec, params, &qc, false);
        let mut scratch = GradScratch::default();
        let mut sum = 0.0f64;
        for (i, &yi) in y.iter().enumerate() {
            let logits =
                eng.forward_image(params, &x[i * INPUT_ELEMS..(i + 1) * INPUT_ELEMS], &mut scratch, None);
            let (l, _) = GradEngine::xent(&logits, yi);
            sum += l as f64;
        }
        sum / y.len() as f64
    }

    /// Central-difference gradient check on sampled parameter entries of
    /// the full differentiable network (conv, pool, residual add, gap,
    /// fc, cross-entropy — every backward kernel on the path).
    fn fd_check(spec: &ModelSpec, seed: u64) {
        let p = Params::random(spec, seed);
        let x = input(2, seed + 1);
        let y = vec![1i32, 3];
        let qc = QuantConfig::float(spec);
        let eng = GradEngine::new(spec, &p.tensors, &qc, false);
        let (_, grads) = eng.batch_grad(&p.tensors, &x, &y, 2);
        let mut rng = crate::util::rng::Xoshiro256::new(seed + 2);
        let eps = 1e-3f32;
        let mut checked = 0usize;
        for (ti, t) in p.tensors.iter().enumerate() {
            for _ in 0..8.min(t.len()) {
                let j = rng.below(t.len() as u64) as usize;
                let mut pp = p.tensors.clone();
                pp[ti][j] = t[j] + eps;
                let lp = loss_of(spec, &pp, &x, &y);
                pp[ti][j] = t[j] - eps;
                let lm = loss_of(spec, &pp, &x, &y);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grads[ti][j];
                let tol = 0.05 * fd.abs().max(an.abs()) + 2e-3;
                assert!(
                    (fd - an).abs() <= tol,
                    "param {ti}[{j}]: fd {fd} vs analytic {an} (seed {seed})"
                );
                checked += 1;
            }
        }
        assert!(checked > 20);
    }

    #[test]
    fn finite_difference_full_net() {
        // tiny_spec: conv+relu, maxpool, save/add (no proj), gap, fc.
        fd_check(&tiny_spec(), 41);
    }

    /// Residual projection conv on the skip path (the resnet downsample
    /// shape) — covers conv_bwd through AddSaved{proj}.
    const PROJ_MANIFEST: &str = r#"{
      "model": "projtest", "n_classes": 4, "input": [32, 32, 3],
      "ops": [
        {"op": "save"},
        {"op": "conv", "name": "conv0", "w": 0, "b": 1, "conv_idx": 0,
         "q_idx": 0, "cin": 3, "cout": 4, "k": 3, "stride": 2, "pad": 1,
         "relu": true, "hin": 32, "win": 32, "hout": 16, "wout": 16},
        {"op": "add_saved", "relu": true,
         "proj": {"op": "conv", "name": "conv1", "w": 2, "b": 3,
          "conv_idx": 1, "q_idx": 1, "cin": 3, "cout": 4, "k": 1,
          "stride": 2, "pad": 0, "relu": false,
          "hin": 32, "win": 32, "hout": 16, "wout": 16}},
        {"op": "gap"},
        {"op": "fc", "name": "fc0", "w": 4, "b": 5, "q_idx": 2,
         "din": 4, "dout": 4, "relu": false}
      ],
      "params": [
        {"name": "conv0.w", "shape": [4, 3, 3, 3], "kind": "conv_w"},
        {"name": "conv0.b", "shape": [4], "kind": "bias"},
        {"name": "conv1.w", "shape": [4, 3, 1, 1], "kind": "conv_w"},
        {"name": "conv1.b", "shape": [4], "kind": "bias"},
        {"name": "fc0.w", "shape": [4, 4], "kind": "fc_w"},
        {"name": "fc0.b", "shape": [4], "kind": "bias"}
      ],
      "n_conv": 2, "n_q": 3, "kset": 32, "qmax": 127, "seed": 1,
      "set_sentinel": 1e9, "momentum": 0.9,
      "batches": {"train": 4, "eval": 4, "logits": 2, "calib": 4},
      "pallas_eval": false, "entries": {}
    }"#;

    #[test]
    fn finite_difference_projection_skip() {
        let spec = ModelSpec::from_manifest_str(PROJ_MANIFEST).unwrap();
        fd_check(&spec, 57);
    }

    #[test]
    fn softmax_xent_gradient() {
        let logits = vec![0.3f32, -1.2, 2.0, 0.0];
        let (loss, d) = GradEngine::xent(&logits, 2);
        // Probabilities sum to 1 ⇒ gradient sums to 0.
        let s: f32 = d.iter().sum();
        assert!(s.abs() < 1e-5);
        assert!(loss > 0.0);
        // FD on each logit.
        let eps = 1e-3f32;
        for j in 0..4 {
            let mut lp = logits.clone();
            lp[j] += eps;
            let (a, _) = GradEngine::xent(&lp, 2);
            lp[j] -= 2.0 * eps;
            let (b, _) = GradEngine::xent(&lp, 2);
            let fd = (a - b) / (2.0 * eps);
            assert!((fd - d[j]).abs() < 1e-3, "logit {j}: {fd} vs {}", d[j]);
        }
    }

    #[test]
    fn pruned_weights_get_zero_gradient() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 5);
        let x = input(2, 6);
        let y = vec![0i32, 2];
        let mut qc = QuantConfig::quantized(&spec, vec![0.02; spec.n_q]);
        let mask = crate::quant::magnitude_mask(&p.tensors[0], 0.5);
        qc.masks[0] = Some(mask.clone());
        let eng = GradEngine::new(&spec, &p.tensors, &qc, true);
        let (_, grads) = eng.batch_grad(&p.tensors, &x, &y, 1);
        for (g, m) in grads[0].iter().zip(&mask) {
            if *m == 0.0 {
                assert_eq!(*g, 0.0, "masked weight received gradient");
            }
        }
        // Unmasked weights do receive gradient somewhere.
        assert!(grads[0].iter().any(|&g| g != 0.0));
    }

    #[test]
    fn batch_grad_bit_identical_across_threads() {
        for manifest in [None, Some(PROJ_MANIFEST)] {
            let spec = match manifest {
                None => tiny_spec(),
                Some(m) => ModelSpec::from_manifest_str(m).unwrap(),
            };
            let p = Params::random(&spec, 7);
            let x = input(5, 8);
            let y = vec![0i32, 1, 2, 3, 0];
            let mut qc = QuantConfig::quantized(&spec, vec![0.02; spec.n_q]);
            qc.masks[0] = Some(crate::quant::magnitude_mask(&p.tensors[0], 0.3));
            qc.wsets[1] = Some(crate::quant::WeightSet::new(vec![-64, -16, 0, 16, 64]));
            let eng = GradEngine::new(&spec, &p.tensors, &qc, true);
            let (l1, g1) = eng.batch_grad(&p.tensors, &x, &y, 1);
            for threads in [2usize, 5] {
                let (lt, gt) = eng.batch_grad(&p.tensors, &x, &y, threads);
                assert_eq!(l1.to_bits(), lt.to_bits(), "threads={threads}");
                for (a, b) in g1.iter().zip(&gt) {
                    assert_eq!(
                        a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_batch_bit_identical_across_threads() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 9);
        let x = input(4, 10);
        let qc = QuantConfig::quantized(&spec, vec![0.02; spec.n_q]);
        let eng = GradEngine::new(&spec, &p.tensors, &qc, true);
        let l1 = eng.forward_batch(&p.tensors, &x, 4, 1);
        for threads in [2usize, 5] {
            let lt = eng.forward_batch(&p.tensors, &x, 4, threads);
            assert_eq!(
                l1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                lt.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn training_descends_on_tiny_net() {
        // A few SGD steps on one fixed batch must reduce the loss — the
        // end-to-end sanity check that forward and backward agree.
        let spec = tiny_spec();
        let mut p = Params::random(&spec, 11).tensors;
        let x = input(4, 12);
        let y = vec![0i32, 1, 2, 3];
        let qc = QuantConfig::float(&spec);
        let first = {
            let eng = GradEngine::new(&spec, &p, &qc, true);
            eng.batch_grad(&p, &x, &y, 2).0
        };
        let mut last = first;
        for _ in 0..40 {
            let eng = GradEngine::new(&spec, &p, &qc, true);
            let (l, g) = eng.batch_grad(&p, &x, &y, 2);
            last = l;
            for (t, gt) in p.iter_mut().zip(&g) {
                for (v, &gv) in t.iter_mut().zip(gt) {
                    *v -= 0.1 * gv;
                }
            }
        }
        assert!(
            last < first * 0.95,
            "loss did not descend: {first} -> {last}"
        );
    }
}
