//! Network specs (parsed from the AOT `manifest.json` — single source of
//! truth shared with the JAX side) and the int8 mirror inference engine.
//!
//! The engine is layered (see `rust/README.md` §Inference engine):
//!
//! * [`ir`] — lowers a [`ModelSpec`] + parameter snapshot +
//!   [`QuantConfig`] into an executable [`ir::Plan`] with pre-quantized
//!   blocked i8 weight tiles and preallocated-buffer sizing;
//! * [`kernels`] — cache-blocked i32-accumulating GEMM/conv kernels,
//!   im2col, requantization, pools and fc, with the hot paths dispatched
//!   at runtime to AVX2/SSE2 backends ([`kernels::dispatch`],
//!   bit-identical to scalar by construction);
//! * [`engine`] — the batch-parallel executor ([`ParallelEngine`]) with
//!   streaming operand-tile delivery through [`CaptureSink`];
//! * [`infer`] — the original scalar engine, retained as the bit-exact
//!   test reference the executor is pinned against;
//! * [`grad`] — the reverse-mode training engine (fake-quant forward +
//!   STE backward, batch-parallel with deterministic reduction) backing
//!   [`crate::runtime::native::NativeBackend`].
//!
//! Captures (im2col code matrices per conv layer) feed the systolic
//! array simulator and the per-layer statistics of §3.1.2; accumulation
//! is exact i32 everywhere, so results are thread-count independent.

pub mod engine;
pub mod grad;
pub mod infer;
pub mod ir;
pub mod kernels;
pub mod params;
pub mod spec;

pub use engine::{CaptureBuffer, CaptureSink, ConvHead, ConvSkip, NullSink, ParallelEngine};
pub use grad::GradEngine;
pub use kernels::dispatch::KernelKind;
pub use kernels::{block_sparsity_of, BlockSparsity};
pub use infer::{ConvCapture, Engine, QuantConfig};
pub use params::Params;
pub use spec::{ConvOp, EntryMeta, FcOp, ModelSpec, Op, ParamKind, ParamSpec};
