//! Network specs (parsed from the AOT `manifest.json` — single source of
//! truth shared with the JAX side) and the int8 mirror inference engine.
//!
//! The engine reproduces the QAT forward of `python/compile/model.py`
//! with integer arithmetic: activations and weights quantize to int8
//! codes, convolutions run as im2col × integer matmul, accumulation is
//! exact i32.  Its captures (im2col code matrices per conv layer) feed
//! the systolic-array simulator and the per-layer statistics of §3.1.2.

pub mod infer;
pub mod params;
pub mod spec;

pub use infer::{ConvCapture, Engine, QuantConfig};
pub use params::Params;
pub use spec::{ConvOp, EntryMeta, FcOp, ModelSpec, Op, ParamKind, ParamSpec};
