//! Kernel layer of the int8 inference engine: cache-blocked
//! i32-accumulating GEMM, im2col, requantization and the float/pool/fc
//! kernels the executor composes.
//!
//! Every kernel is **bit-compatible** with the scalar reference in
//! [`super::infer`]: the quantized path accumulates exact i32 (so any
//! blocking order yields identical sums) and the float kernels walk the
//! reduction in the same element order as the reference loops, so the
//! f32 rounding sequence is identical.  `rust/tests/engine_parallel.rs`
//! pins this bit-for-bit.

use super::spec::ConvOp;
use crate::quant;

/// Column-panel width of the blocked weight layout (one GEMM tile of
/// output columns).
pub const NB: usize = 64;
/// Rows of X per GEMM macro-block.
pub const MB: usize = 32;
/// K-panel depth per GEMM macro-block.
pub const KB: usize = 256;

/// Pre-quantized conv weights packed into column panels: `ceil(n/NB)`
/// panels, each `k`×`NB` row-major with tail columns zero-padded, so the
/// GEMM inner loop reads one contiguous stripe per (row, panel).
#[derive(Clone)]
pub struct BlockedWeights {
    pub k: usize,
    pub n: usize,
    data: Vec<i8>,
}

impl BlockedWeights {
    /// Pack a K×N row-major code matrix into column panels.
    pub fn pack(w_kxn: &[i8], k: usize, n: usize) -> Self {
        assert_eq!(w_kxn.len(), k * n);
        let panels = n.div_ceil(NB);
        let mut data = vec![0i8; panels * k * NB];
        for p in 0..panels {
            let j0 = p * NB;
            let width = NB.min(n - j0);
            for r in 0..k {
                let dst = p * k * NB + r * NB;
                data[dst..dst + width].copy_from_slice(&w_kxn[r * n + j0..r * n + j0 + width]);
            }
        }
        Self { k, n, data }
    }

    fn panel(&self, p: usize) -> &[i8] {
        &self.data[p * self.k * NB..(p + 1) * self.k * NB]
    }
}

/// `acc(m×n) += X(m×k) · W(k×n)` with exact i32 accumulation, blocked
/// over (column panel, M, K).  Zero activations are skipped (post-ReLU
/// code streams are sparse).  Caller zeroes `acc`.
pub fn gemm_i8_blocked(x: &[i8], w: &BlockedWeights, m: usize, acc: &mut [i32]) {
    let (k, n) = (w.k, w.n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(acc.len(), m * n);
    let panels = n.div_ceil(NB);
    for p in 0..panels {
        let j0 = p * NB;
        let width = NB.min(n - j0);
        let panel = w.panel(p);
        for i0 in (0..m).step_by(MB) {
            let ih = MB.min(m - i0);
            for k0 in (0..k).step_by(KB) {
                let kh = KB.min(k - k0);
                for i in i0..i0 + ih {
                    let xrow = &x[i * k + k0..i * k + k0 + kh];
                    let arow = &mut acc[i * n + j0..i * n + j0 + width];
                    for (dk, &xv) in xrow.iter().enumerate() {
                        if xv == 0 {
                            continue;
                        }
                        let xi = xv as i32;
                        let wrow = &panel[(k0 + dk) * NB..(k0 + dk) * NB + width];
                        for (a, &wv) in arow.iter_mut().zip(wrow) {
                            *a += xi * wv as i32;
                        }
                    }
                }
            }
        }
    }
}

/// Quantize a float tensor to int8 codes into a reused buffer.
pub fn quantize_into(src: &[f32], s: f32, dst: &mut Vec<i8>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| quant::quantize(v, s) as i8));
}

/// im2col of an NHWC code tensor into a reused buffer; (ky, kx, c) patch
/// column order, matching the scalar reference and `ref.im2col` on the
/// JAX side.  Out-of-bounds taps stay zero (the buffer is zero-filled).
pub fn im2col_i8(
    t: &[i8],
    n_imgs: usize,
    h: usize,
    w: usize,
    c: usize,
    cv: &ConvOp,
    out: &mut Vec<i8>,
) {
    let (ho, wo, k, s, p) = (cv.hout, cv.wout, cv.k, cv.stride, cv.pad as isize);
    let m = n_imgs * ho * wo;
    let kk = k * k * c;
    out.clear();
    out.resize(m * kk, 0);
    for b in 0..n_imgs {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (b * ho + oy) * wo + ox;
                let base = row * kk;
                for ky in 0..k {
                    let iy = (oy * s) as isize + ky as isize - p;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s) as isize + kx as isize - p;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let col0 = (ky * k + kx) * c;
                        let src = ((b * h + iy as usize) * w + ix as usize) * c;
                        out[base + col0..base + col0 + c].copy_from_slice(&t[src..src + c]);
                    }
                }
            }
        }
    }
}

/// Requantize an i32 accumulator tile: `out = acc·ss + bias`, optional
/// ReLU.  `ss` must be the pre-multiplied `s_act · s_w` so the f32
/// expression matches the scalar reference exactly.
pub fn requant_bias_relu(acc: &[i32], ss: f32, bias: &[f32], relu: bool, out: &mut Vec<f32>) {
    let n = bias.len();
    debug_assert_eq!(acc.len() % n, 0);
    out.clear();
    out.reserve(acc.len());
    for arow in acc.chunks_exact(n) {
        for (a, b) in arow.iter().zip(bias) {
            let v = *a as f32 * ss + *b;
            out.push(if relu { v.max(0.0) } else { v });
        }
    }
}

/// Float direct convolution (calibration path), bit-identical in
/// accumulation order to the scalar reference: (oy, ox) outer, then
/// (ky, kx, ci) taps with zero-skip, bias added last, ReLU applied by
/// the caller over the whole tensor.  `w_oihw` is the raw OIHW tensor.
pub fn conv_f32_direct(
    cv: &ConvOp,
    input: &[f32],
    n_imgs: usize,
    w_oihw: &[f32],
    bias: &[f32],
    out: &mut Vec<f32>,
) {
    let (h, w, c) = (cv.hin, cv.win, cv.cin);
    debug_assert_eq!(input.len(), n_imgs * h * w * c);
    let nn = cv.cout;
    let m = n_imgs * cv.hout * cv.wout;
    out.clear();
    out.resize(m * nn, 0.0);
    let (k, s, p) = (cv.k, cv.stride, cv.pad as isize);
    for b in 0..n_imgs {
        for oy in 0..cv.hout {
            for ox in 0..cv.wout {
                let row = (b * cv.hout + oy) * cv.wout + ox;
                let orow = &mut out[row * nn..(row + 1) * nn];
                for ky in 0..k {
                    let iy = (oy * s) as isize + ky as isize - p;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s) as isize + kx as isize - p;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * w + ix as usize) * c;
                        for ci in 0..c {
                            let xv = input[src + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            for (o, ov) in orow.iter_mut().enumerate() {
                                *ov += xv * w_oihw[((o * c + ci) * k + ky) * k + kx];
                            }
                        }
                    }
                }
                for (ov, bv) in orow.iter_mut().zip(bias) {
                    *ov += bv;
                }
            }
        }
    }
}

/// 2×2 max-pool (stride 2), scalar-reference scan order.
pub fn maxpool2(input: &[f32], n_imgs: usize, h: usize, w: usize, c: usize, out: &mut Vec<f32>) {
    let (ho, wo) = (h / 2, w / 2);
    out.clear();
    out.resize(n_imgs * ho * wo * c, f32::NEG_INFINITY);
    for b in 0..n_imgs {
        for y in 0..h {
            for xx in 0..w {
                let src = &input[((b * h + y) * w + xx) * c..][..c];
                let dst_idx = ((b * ho + y / 2) * wo + xx / 2) * c;
                for (ch, &sv) in src.iter().enumerate() {
                    let d = &mut out[dst_idx + ch];
                    if sv > *d {
                        *d = sv;
                    }
                }
            }
        }
    }
}

/// Global average pool, scalar-reference accumulation order.
pub fn gap(input: &[f32], n_imgs: usize, h: usize, w: usize, c: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(n_imgs * c, 0.0);
    for b in 0..n_imgs {
        for y in 0..h {
            for xx in 0..w {
                let src = &input[((b * h + y) * w + xx) * c..][..c];
                for (ch, &sv) in src.iter().enumerate() {
                    out[b * c + ch] += sv;
                }
            }
        }
    }
    let inv = 1.0 / (h * w) as f32;
    out.iter_mut().for_each(|v| *v *= inv);
}

/// Float fully-connected layer, scalar-reference dot order.
#[allow(clippy::too_many_arguments)]
pub fn fc_f32(
    input: &[f32],
    n_imgs: usize,
    din: usize,
    dout: usize,
    w: &[f32],
    bias: &[f32],
    relu: bool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(n_imgs * dout);
    for b in 0..n_imgs {
        let xrow = &input[b * din..(b + 1) * din];
        for o in 0..dout {
            let wrow = &w[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += xv * wv;
            }
            let v = acc + bias[o];
            out.push(if relu { v.max(0.0) } else { v });
        }
    }
}

/// Quantized fully-connected layer: int8 codes, exact i32 dot, then the
/// scalar reference's requant expression.
#[allow(clippy::too_many_arguments)]
pub fn fc_i8(
    xq: &[i8],
    n_imgs: usize,
    din: usize,
    dout: usize,
    wq: &[i8],
    ss: f32,
    bias: &[f32],
    relu: bool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(n_imgs * dout);
    for b in 0..n_imgs {
        let xrow = &xq[b * din..(b + 1) * din];
        for o in 0..dout {
            let wrow = &wq[o * din..(o + 1) * din];
            let mut acc = 0i32;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += *xv as i32 * *wv as i32;
            }
            let v = ss * acc as f32 + bias[o];
            out.push(if relu { v.max(0.0) } else { v });
        }
    }
}

/// Max |v| of a tensor (activation-scale calibration support).
pub fn abs_max(t: &[f32]) -> f32 {
    t.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(len: usize, seed: u64) -> Vec<i8> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..len)
            .map(|_| {
                if rng.below(3) == 0 {
                    0
                } else {
                    rng.code() as i8
                }
            })
            .collect()
    }

    /// Blocked GEMM equals the naive triple loop exactly, across shapes
    /// that exercise partial panels / partial M and K blocks.
    #[test]
    fn gemm_matches_naive() {
        for (si, &(m, k, n)) in [(3usize, 5usize, 2usize), (33, 70, 64), (65, 257, 67), (1, 1, 1)]
            .iter()
            .enumerate()
        {
            let x = codes(m * k, si as u64 + 1);
            let w = codes(k * n, si as u64 + 100);
            let wb = BlockedWeights::pack(&w, k, n);
            let mut acc = vec![0i32; m * n];
            gemm_i8_blocked(&x, &wb, m, &mut acc);
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0i32;
                    for r in 0..k {
                        want += x[i * k + r] as i32 * w[r * n + j] as i32;
                    }
                    assert_eq!(acc[i * n + j], want, "({m},{k},{n}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn pack_roundtrips_tail_panel() {
        let (k, n) = (3usize, NB + 5);
        let w = codes(k * n, 9);
        let wb = BlockedWeights::pack(&w, k, n);
        // Read back through the panel accessor.
        for r in 0..k {
            for j in 0..n {
                let p = j / NB;
                assert_eq!(wb.panel(p)[r * NB + j % NB], w[r * n + j]);
            }
        }
    }

    #[test]
    fn requant_expression() {
        let acc = vec![3i32, -2, 0, 7];
        let bias = vec![0.5f32, -0.25];
        let mut out = Vec::new();
        requant_bias_relu(&acc, 0.125, &bias, false, &mut out);
        assert_eq!(out, vec![3.0 * 0.125 + 0.5, -2.0 * 0.125 - 0.25, 0.5, 7.0 * 0.125 - 0.25]);
        requant_bias_relu(&acc, 0.125, &bias, true, &mut out);
        assert!(out.iter().all(|&v| v >= 0.0));
    }
}
