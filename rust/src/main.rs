//! `wsel` — CLI for the layer-wise weight-selection reproduction.
//!
//! Subcommands:
//!   train      — QAT-train a model (float phase, calibration, QAT phase)
//!   profile    — per-layer energy profile + per-weight MAC power tables
//!   compress   — full §4 pipeline (train → profile → schedule → report)
//!   baseline   — PowerPruning / naive baselines on a trained model
//!   eval       — accuracy of the current (possibly compressed) params
//!   repro      — regenerate a paper table/figure (--table N | --fig N)
//!
//! Every run is deterministic given --seed.

use anyhow::{bail, Result};
use wsel::coordinator::{Pipeline, PipelineParams};
use wsel::data::Split;
use wsel::report::{pct, Table};
use wsel::runtime::LrSchedule;
use wsel::schedule::ScheduleParams;
use wsel::selection::{AccuracyOracle, CompressionState};
use wsel::util::cli::Args;

const USAGE: &str = "\
wsel <subcommand> [options]

subcommands:
  train      --model <m> [--float-steps N] [--qat-steps N] [--lr F]
  profile    --model <m> [--quick]
  compress   --model <m> [--delta F] [--max-layers N] [--ft-steps N]
             [--halving-rungs N] [--rung-frac F] [--acc-cache <path>]
             [--resume] [--quick]
             (--halving-rungs >= 1 enables the oracle-efficient search:
              candidates warm-start from the accepted-path snapshot and
              fine-tune in doubling rung budgets, top half surviving
              each rung; --acc-cache persists trial accuracies so
              repeated searches skip oracle calls, and implies at least
              one rung)
  baseline   --model <m> --method powerpruning|naive16|naive20 [--quick]
  eval       --model <m>
  faults     --model <m> [--flips 1,2,4,8] [--fault-seed S]
             [--fault-trials N] [--resume] [--quick]
             (SEU bit-flip resilience campaign, dense vs compressed)
  serve-bench [--rates 200,500,1000] [--requests N] [--max-batch N]
             [--max-wait-us N] [--bench-seed S] [--out <path>] [--quick]
             (sustained-load serving bench over the snapshot registry +
              micro-batcher: p50/p95/p99 latency + images/s per
              (variant, rate, policy) cell -> BENCH_serving.json)
  repro      --table 1|2|3|4 | --fig 1|2|3|4   (see benches/ for scaled runs)

common options:
  --artifacts <dir>   artifact directory (default: artifacts)
  --backend <b>       auto | aot | native (default auto: AOT when
                      artifacts exist, else the pure-Rust backend)
  --data-seed <u64>   dataset seed (default 7; --seed is an alias)
  --threads <n>       worker threads for parallel engines (default: autodetect)
  --ckpt-every <n>    checkpoint training every n steps (0 = off); an
                      interrupted run re-invoked with the same flags
                      resumes from the last checkpoint bit-identically
  --resume            resume an interrupted schedule search from the
                      journal in the artifact dir (compress / faults)
  --quick             small preset (smoke-scale)
  --kernels <k>       scalar | sse2 | avx2 | auto (default: auto; env WSEL_KERNELS)
models: lenet5 | resnet20 | resnet50lite";

fn params_from(args: &Args) -> Result<PipelineParams> {
    let mut pp = if args.flag("quick") {
        PipelineParams::quick()
    } else {
        PipelineParams::default()
    };
    pp.float_steps = args.usize_or("float-steps", pp.float_steps);
    pp.qat_steps = args.usize_or("qat-steps", pp.qat_steps);
    pp.lr = LrSchedule {
        base: args.f64_or("lr", pp.lr.base as f64) as f32,
        decay_at: 0.75,
    };
    pp.val_batches = args.usize_or("val-batches", pp.val_batches);
    pp.ckpt_every = args.usize_or("ckpt-every", pp.ckpt_every);
    pp.threads = args.threads_or(pp.threads);
    // `--seed` stays as an alias for the dataset seed; `--data-seed`
    // wins when both are given.
    pp.data_seed = args.u64_or("data-seed", args.u64_or("seed", pp.data_seed));
    pp.backend = wsel::runtime::BackendChoice::parse(args.opt_or("backend", "auto"))?;
    if let Some(ks) = args.opt("kernels") {
        pp.kernels = wsel::model::KernelKind::parse(ks)?;
    }
    Ok(pp)
}

fn pipeline(args: &Args) -> Result<Pipeline> {
    let dir = std::path::PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let model = args
        .opt("model")
        .ok_or_else(|| anyhow::anyhow!("--model required\n{USAGE}"))?;
    Pipeline::new(&dir, model, params_from(args)?)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut p = pipeline(args)?;
    let acc = p.train_baseline()?;
    println!(
        "model={} backend={} quantized-acc0={:.4}",
        p.rt.spec.name,
        p.rt.backend_name(),
        acc
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let mut p = pipeline(args)?;
    p.train_baseline()?;
    p.profile()?;
    let ne = p.base_energy.clone().unwrap();
    let mut t = Table::new(
        &format!("Per-layer energy profile: {}", p.rt.spec.name),
        &["layer", "M", "K", "N", "tiles", "energy (J/img)", "share"],
    );
    let shares = ne.shares();
    for (ci, e) in &ne.layers {
        let le = p.layer_energy_model(*ci);
        let share = shares.iter().find(|(i, _)| i == ci).unwrap().1;
        t.row(&[
            p.rt.spec.conv_label(*ci),
            le.m.to_string(),
            le.k.to_string(),
            le.n.to_string(),
            le.n_tiles().to_string(),
            format!("{e:.4e}"),
            pct(share),
        ]);
    }
    println!("{}", t.render());
    println!("total conv energy: {:.4e} J/image", ne.total());
    Ok(())
}

fn compress_params(args: &Args, acc_quick: bool) -> ScheduleParams {
    let mut sp = ScheduleParams {
        delta: args.f64_or("delta", 0.03),
        fine_tune_steps: args.usize_or("ft-steps", if acc_quick { 10 } else { 60 }),
        max_layers: args.opt("max-layers").map(|v| v.parse().unwrap()),
        halving_rungs: args.usize_or("halving-rungs", 0),
        rung_frac: args.f64_or("rung-frac", 0.25),
        ..Default::default()
    };
    if acc_quick {
        sp.prune_ratios = vec![0.7, 0.5];
        sp.k_targets = vec![16, 32];
    }
    sp
}

/// Run the schedule search — journaled (resumable across process death)
/// when `--resume` is given, plain otherwise.
fn run_search(
    p: &mut Pipeline,
    args: &Args,
    mut sp: ScheduleParams,
) -> Result<wsel::schedule::ScheduleResult> {
    let cache = args.opt("acc-cache").map(std::path::PathBuf::from);
    if cache.is_some() && sp.halving_rungs == 0 {
        // A persistent accuracy cache rides on the warm-started search
        // (content-addressed snapshots): imply a single rung.
        sp.halving_rungs = 1;
    }
    let journal = args
        .flag("resume")
        .then(|| p.rt.dir().join("schedule.journal.json"));
    let res = p.compress_opts(sp, journal.as_deref(), cache.as_deref())?;
    Ok(res.expect("no trial budget set: search runs to completion"))
}

fn cmd_compress(args: &Args) -> Result<()> {
    let mut p = pipeline(args)?;
    p.train_baseline()?;
    p.profile()?;
    let sp = compress_params(args, args.flag("quick"));
    let res = run_search(&mut p, args, sp)?;
    let base = p.base_energy.clone().unwrap();
    let now = p.compute_network_energy(&res.state);
    let saving = base.saving_vs(&now);

    let mut t = Table::new(
        &format!("Layer-wise compression: {}", p.rt.spec.name),
        &["layer", "share", "prune", "K", "layer saving"],
    );
    for oc in &res.outcomes {
        let (ratio, k) = oc
            .accepted
            .map(|c| (format!("{:.2}", c.prune_ratio), c.k_target.to_string()))
            .unwrap_or(("-".into(), "-".into()));
        let lsave = if oc.energy_before > 0.0 {
            pct(1.0 - oc.energy_after / oc.energy_before)
        } else {
            "-".into()
        };
        t.row(&[
            p.rt.spec.conv_label(oc.conv_idx),
            pct(oc.share),
            ratio,
            k,
            lsave,
        ]);
    }
    println!("{}", t.render());
    println!(
        "acc0={:.4}  final-acc={:.4}  total energy saving={}  (evals={}, ft-steps={})",
        p.acc0,
        res.final_accuracy,
        pct(saving),
        p.eval_count,
        p.ft_steps_total
    );
    p.rt.save_params("compressed")?;
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let mut p = pipeline(args)?;
    p.train_baseline()?;
    p.profile()?;
    let method = args.opt_or("method", "powerpruning").to_string();
    let n_conv = p.rt.spec.n_conv;
    // Global table (uniform transition model — what PowerPruning uses).
    let quick = args.flag("quick");
    let ft = args.usize_or("ft-steps", if quick { 10 } else { 60 });
    let state = match method.as_str() {
        "powerpruning" => {
            let glob = wsel::energy::uniform_weight_energy(
                &mut p.maclib,
                &p.cap_model,
                p.pp.trace_len,
                p.pp.seed,
                p.pp.threads,
            );
            wsel::selection::powerpruning::powerpruning_state(n_conv, &glob, 32, 0.5)
        }
        "naive16" | "naive20" => {
            let k = if method == "naive16" { 16 } else { 20 };
            let glob = wsel::energy::uniform_weight_energy(
                &mut p.maclib,
                &p.cap_model,
                p.pp.trace_len,
                p.pp.seed,
                p.pp.threads,
            );
            let set = wsel::selection::naive_lowest_energy(&glob, k);
            CompressionState {
                layers: (0..n_conv)
                    .map(|_| wsel::selection::LayerConfig {
                        prune_ratio: 0.5,
                        wset: Some(set.clone()),
                    })
                    .collect(),
            }
        }
        other => bail!("unknown method {other}"),
    };
    let (acc, saving) = p.evaluate_state(&state, ft)?;
    println!(
        "model={} method={} acc0={:.4} acc={:.4} energy-saving={}",
        p.rt.spec.name,
        method,
        p.acc0,
        acc,
        pct(saving)
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mut p = pipeline(args)?;
    // Use trained params if available, without re-training.
    if !p.rt.load_params("compressed")? {
        let tag = format!(
            "trained-f{}-q{}",
            p.pp.float_steps, p.pp.qat_steps
        );
        if !p.rt.load_params(&tag)? {
            bail!("no checkpoint ({tag}); run `wsel train` with matching steps first");
        }
    }
    p.rt.calibrate(p.pp.calib_batches)?;
    let state = CompressionState::dense(p.rt.spec.n_conv);
    let acc = p.accuracy(&state);
    println!("model={} val-acc={:.4}", p.rt.spec.name, acc);
    let test = p.rt.evaluate(&state, true, Split::Test, p.pp.val_batches)?;
    println!("model={} test-acc={:.4}", p.rt.spec.name, test);
    Ok(())
}

fn cmd_faults(args: &Args) -> Result<()> {
    let mut p = pipeline(args)?;
    p.train_baseline()?;
    p.profile()?;
    let sp = compress_params(args, args.flag("quick"));
    let res = run_search(&mut p, args, sp)?;
    let flip_counts: Vec<usize> = args
        .opt_or("flips", "1,2,4,8")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--flips expects a comma-separated list of integers, got `{s}`"))
        })
        .collect::<Result<_>>()?;
    let cfg = wsel::faults::CampaignCfg {
        seed: args.u64_or("fault-seed", 0xF117),
        flip_counts,
        val_batches: args.usize_or("val-batches", 2),
        trials: args.usize_or("fault-trials", 3),
    };
    let dense = CompressionState::dense(p.rt.spec.n_conv);
    let report = wsel::faults::resilience_campaign(
        &p,
        &[("dense", &dense), ("compressed", &res.state)],
        &cfg,
    );
    println!("{}", report.table().render());
    let out = p.rt.dir().join("BENCH_resilience.json");
    wsel::util::artifact::write_json_atomic(&out, &report.to_json())?;
    println!(
        "seed={:#x} trials={} -> {}",
        cfg.seed,
        cfg.trials,
        out.display()
    );
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    use wsel::serve::bench::{run_serve_bench, validate_report, ServeBenchCfg};
    let threads = args.threads_or(wsel::util::threadpool::default_threads());
    let mut cfg = if args.flag("quick") {
        ServeBenchCfg::quick(threads)
    } else {
        ServeBenchCfg::standard(threads)
    };
    cfg.rates = args.f64_list_or("rates", &cfg.rates.clone());
    cfg.requests = args.usize_or("requests", cfg.requests);
    cfg.max_batch = args.usize_or("max-batch", cfg.max_batch);
    cfg.max_wait_us = args.u64_or("max-wait-us", cfg.max_wait_us);
    cfg.seed = args.u64_or("bench-seed", cfg.seed);
    let (json, cells) = run_serve_bench(&cfg)?;
    let mut t = Table::new(
        &format!(
            "Sustained-load serving: lenet5, {} threads, {} req/cell",
            cfg.threads, cfg.requests
        ),
        &[
            "variant", "rate", "policy", "p50 µs", "p95 µs", "p99 µs", "images/s", "mean wave",
            "err",
        ],
    );
    for c in &cells {
        t.row(&[
            c.variant.clone(),
            c.rate_label(),
            c.policy.label(),
            format!("{:.0}", c.p50_us),
            format!("{:.0}", c.p95_us),
            format!("{:.0}", c.p99_us),
            format!("{:.1}", c.images_per_s),
            format!("{:.2}", c.mean_wave),
            c.errors.to_string(),
        ]);
    }
    println!("{}", t.render());
    let out = std::path::PathBuf::from(args.opt_or("out", "BENCH_serving.json"));
    wsel::util::artifact::write_json_atomic(&out, &json)?;
    // Smoke gate (verify.sh --quick): re-load what was just written and
    // re-check shape + p99 >= p95 >= p50 per cell, through the same
    // checksummed loader any consumer would use.
    let reloaded = wsel::util::artifact::load_json(&out)?;
    let n = validate_report(&reloaded)?;
    println!(
        "wrote {} ({n} cells); self-check OK (parse + monotone percentiles)",
        out.display()
    );
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    // Full-scale repro paths delegate to the same code the benches use,
    // at full parameters.  See benches/ for the scaled variants.
    if let Some(t) = args.opt("table") {
        match t {
            "1" => println!("Table 1: run `wsel compress --model <m>` for each model, and `wsel baseline --method powerpruning`.\nThe bench `table1_energy_savings` runs a scaled version end-to-end."),
            "2" => println!("Table 2: `wsel compress --model resnet20` prints per-layer rows; bench `table2_layerwise` is the scaled run."),
            "3" => println!("Table 3: bench `table3_layerwise_vs_global`."),
            "4" => println!("Table 4: bench `table4_weight_selection`."),
            other => bail!("unknown table {other}"),
        }
        return Ok(());
    }
    if let Some(f) = args.opt("fig") {
        match f {
            "1" => println!("Fig 1: bench `fig1_mac_power_per_weight` (full table printed)."),
            "2" => println!("Fig 2: bench `fig2_grouping_metrics`."),
            "3" => println!("Fig 3: bench `fig3_activation_heatmaps`."),
            "4" => println!("Fig 4: bench `fig4_compression_components`."),
            other => bail!("unknown figure {other}"),
        }
        return Ok(());
    }
    bail!("repro requires --table N or --fig N");
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        argv,
        &[
            "model",
            "artifacts",
            "backend",
            "seed",
            "data-seed",
            "threads",
            "float-steps",
            "qat-steps",
            "lr",
            "delta",
            "max-layers",
            "ft-steps",
            "halving-rungs",
            "rung-frac",
            "acc-cache",
            "val-batches",
            "method",
            "table",
            "fig",
            "ckpt-every",
            "flips",
            "fault-seed",
            "fault-trials",
            "rates",
            "requests",
            "max-batch",
            "max-wait-us",
            "bench-seed",
            "out",
            "kernels",
        ],
    );
    // Resolve the kernel backend once, up front, so every subcommand
    // (including ones that never build a `Pipeline`, e.g. `serve-bench`)
    // honors `--kernels`. A bad value is a CLI error, fail fast.
    if let Some(ks) = args.opt("kernels") {
        let kind = wsel::model::KernelKind::parse(ks)?;
        wsel::model::kernels::dispatch::select(kind)?;
    }
    let sub = args.positional.first().map(String::as_str).unwrap_or("");
    match sub {
        "train" => cmd_train(&args),
        "profile" => cmd_profile(&args),
        "compress" => cmd_compress(&args),
        "baseline" => cmd_baseline(&args),
        "eval" => cmd_eval(&args),
        "faults" => cmd_faults(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "repro" => cmd_repro(&args),
        "version" => {
            println!("wsel {}", wsel::version());
            Ok(())
        }
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}
