//! Deterministic fault injection: single-event-upset (SEU) bit flips in
//! quantized weight codes, and the resilience campaign that sweeps them.
//!
//! The SEU model flips individual bits of the stored int8 weight codes
//! of one conv layer *after* plan compilation — exactly what a particle
//! strike on an on-chip weight buffer does to an inference accelerator.
//! Injection is deterministic from a seed (distinct `(byte, bit)`
//! targets drawn from a seeded PRNG), so every campaign row is exactly
//! reproducible.
//!
//! [`resilience_campaign`] sweeps flip counts × conv layers over model
//! variants (dense vs compressed weight-set states) and reports, per
//! cell, the accuracy and modeled-energy deltas against the clean run —
//! the data behind the EXPERIMENTS.md resilience table.  Dense and
//! compressed variants share the same (post-compression) parameters, so
//! the comparison isolates the *representation*: whether restricting
//! weights to a small set changes how much damage a flipped bit does.

use crate::data::Split;
use crate::model::ir::{ConvWeights, Plan, StepKind};
use crate::model::kernels::BlockedWeights;
use crate::model::{ParallelEngine, QuantConfig};
use crate::selection::CompressionState;
use crate::util::json::Json;
use crate::util::rng::{mix2, Xoshiro256};

/// One injected bit flip.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlipRecord {
    pub conv_idx: usize,
    /// Byte position in the layer's K×N code matrix.
    pub pos: usize,
    /// Flipped bit (0 = LSB).
    pub bit: u8,
    pub before: i8,
    pub after: i8,
}

fn conv_steps(plan: &Plan) -> impl Iterator<Item = &crate::model::ir::ConvStep> {
    plan.steps.iter().filter_map(|step| match &step.kind {
        StepKind::Conv(cs) => Some(&**cs),
        StepKind::AddSaved { proj: Some(cs), .. } => Some(&**cs),
        _ => None,
    })
}

/// Conv indices of a plan that carry quantized (injectable) weights,
/// ascending.
pub fn injectable_convs(plan: &Plan) -> Vec<usize> {
    let mut out: Vec<usize> = conv_steps(plan)
        .filter(|cs| matches!(cs.weights, ConvWeights::Quant { .. }))
        .map(|cs| cs.op.conv_idx)
        .collect();
    out.sort_unstable();
    out
}

/// Copy of a layer's K×N weight codes (None when not quantized).
pub fn conv_codes(plan: &Plan, conv_idx: usize) -> Option<Vec<i8>> {
    conv_steps(plan)
        .find(|cs| cs.op.conv_idx == conv_idx)
        .and_then(|cs| match &cs.weights {
            ConvWeights::Quant { wq, .. } => Some(wq.clone()),
            ConvWeights::Float(_) => None,
        })
}

/// Flip `n_flips` distinct bits of `conv_idx`'s quantized weight codes
/// (SEU model), deterministically from `seed`, and repack the blocked
/// GEMM panels so the executed kernel sees the faulted weights.
/// Returns the flips applied — empty when the layer is absent or not
/// quantized.  `n_flips` is clamped to the layer's bit capacity.
pub fn inject_bit_flips(
    plan: &mut Plan,
    conv_idx: usize,
    n_flips: usize,
    seed: u64,
) -> Vec<FlipRecord> {
    let cs = plan.steps.iter_mut().find_map(|step| {
        let cs = match &mut step.kind {
            StepKind::Conv(cs) => cs,
            StepKind::AddSaved { proj: Some(cs), .. } => cs,
            _ => return None,
        };
        (cs.op.conv_idx == conv_idx).then_some(cs)
    });
    let Some(cs) = cs else {
        return Vec::new();
    };
    let kk = cs.op.k * cs.op.k * cs.op.cin;
    let nn = cs.op.cout;
    let ConvWeights::Quant { wq, wb, .. } = &mut cs.weights else {
        return Vec::new();
    };
    let n_bits = wq.len() * 8;
    let n_flips = n_flips.min(n_bits);
    let mut rng = Xoshiro256::new(mix2(seed, conv_idx as u64));
    let mut chosen: Vec<usize> = Vec::with_capacity(n_flips);
    let mut records = Vec::with_capacity(n_flips);
    while records.len() < n_flips {
        let target = rng.below(n_bits as u64) as usize;
        if chosen.contains(&target) {
            continue;
        }
        chosen.push(target);
        let (pos, bit) = (target / 8, (target % 8) as u8);
        let before = wq[pos];
        let after = (before as u8 ^ (1u8 << bit)) as i8;
        wq[pos] = after;
        records.push(FlipRecord {
            conv_idx,
            pos,
            bit,
            before,
            after,
        });
    }
    // The GEMM kernel reads the blocked panels, not `wq` — repack so
    // the fault is actually executed (and structural skip bookkeeping
    // stays consistent with the faulted codes).
    *wb = BlockedWeights::pack(wq, kk, nn);
    records
}

/// Campaign knobs.
#[derive(Clone, Debug)]
pub struct CampaignCfg {
    /// Base seed; every (variant, layer, flip-count, trial) cell derives
    /// its own injection seed from it.
    pub seed: u64,
    /// Flip counts to sweep per layer.
    pub flip_counts: Vec<usize>,
    /// Validation batches per accuracy measurement.
    pub val_batches: usize,
    /// Independent injections averaged per cell.
    pub trials: usize,
}

impl Default for CampaignCfg {
    fn default() -> Self {
        Self {
            seed: 0xF117,
            flip_counts: vec![1, 2, 4, 8],
            val_batches: 2,
            trials: 3,
        }
    }
}

/// One campaign cell: a (variant, layer, flip-count) aggregated over
/// `trials` independent injections.
#[derive(Clone, Debug)]
pub struct CampaignRow {
    pub variant: String,
    pub conv_idx: usize,
    pub n_flips: usize,
    pub acc_clean: f64,
    pub acc_mean: f64,
    pub acc_worst: f64,
    /// Modeled network energy per image, clean (J).
    pub energy_clean: f64,
    /// Mean modeled network energy per image under injection (J).
    pub energy_mean: f64,
}

/// Campaign output: rows in (variant, layer, flip-count) sweep order.
#[derive(Clone, Debug, Default)]
pub struct ResilienceReport {
    pub rows: Vec<CampaignRow>,
}

impl ResilienceReport {
    pub fn table(&self) -> crate::report::Table {
        let mut t = crate::report::Table::new(
            "SEU bit-flip resilience (accuracy / modeled energy vs clean)",
            &[
                "variant", "conv", "flips", "acc clean", "acc mean", "acc worst", "E clean (J/img)",
                "dE mean %",
            ],
        );
        for r in &self.rows {
            let de = if r.energy_clean > 0.0 {
                100.0 * (r.energy_mean - r.energy_clean) / r.energy_clean
            } else {
                0.0
            };
            t.row(&[
                r.variant.clone(),
                r.conv_idx.to_string(),
                r.n_flips.to_string(),
                format!("{:.4}", r.acc_clean),
                format!("{:.4}", r.acc_mean),
                format!("{:.4}", r.acc_worst),
                format!("{:.3e}", r.energy_clean),
                format!("{de:+.3}"),
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "rows",
            Json::arr(self.rows.iter().map(|r| {
                Json::obj(vec![
                    ("variant", Json::str(&r.variant)),
                    ("conv_idx", Json::num(r.conv_idx as f64)),
                    ("n_flips", Json::num(r.n_flips as f64)),
                    ("acc_clean", Json::num(r.acc_clean)),
                    ("acc_mean", Json::num(r.acc_mean)),
                    ("acc_worst", Json::num(r.acc_worst)),
                    ("energy_clean", Json::num(r.energy_clean)),
                    ("energy_mean", Json::num(r.energy_mean)),
                ])
            })),
        )])
    }
}

fn accuracy_of(
    eng: &ParallelEngine,
    batches: &[(Vec<f32>, Vec<i32>)],
) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for (x, y) in batches {
        let fwd = eng.forward_plain(x, y.len());
        correct += y
            .iter()
            .enumerate()
            .filter(|(i, &yi)| fwd.argmax(*i) == yi as usize)
            .count();
        total += y.len();
    }
    correct as f64 / total.max(1) as f64
}

/// Modeled per-image network energy of a plan from its *executed* codes
/// (mask + set restriction + any injected faults included).
fn plan_energy(p: &crate::coordinator::Pipeline, plan: &Plan) -> f64 {
    injectable_convs(plan)
        .into_iter()
        .map(|ci| {
            let codes = conv_codes(plan, ci).expect("quantized conv");
            p.layer_energy_model(ci).energy_of_codes(&codes)
        })
        .sum()
}

/// Sweep `cfg.flip_counts` × injectable conv layers over the given
/// model variants, measuring validation accuracy and modeled energy
/// under injection.  Requires a profiled pipeline (energy tables).
/// Every cell is deterministic from `cfg.seed`.
pub fn resilience_campaign(
    p: &crate::coordinator::Pipeline,
    variants: &[(&str, &CompressionState)],
    cfg: &CampaignCfg,
) -> ResilienceReport {
    let spec = &p.rt.spec;
    let bs = spec.batch_eval;
    let ncls = spec.n_classes as u64;
    let batches: Vec<(Vec<f32>, Vec<i32>)> = (0..cfg.val_batches.max(1))
        .map(|b| crate::data::batch(p.rt.data_seed, Split::Val, (b * bs) as u64, bs, ncls))
        .collect();
    let mut report = ResilienceReport::default();
    for &(name, state) in variants {
        let qc = QuantConfig {
            act_scales: p.rt.act_scales.clone(),
            quant_on: true,
            masks: crate::runtime::mask_options(spec, &p.rt.params, state),
            wsets: state.layers.iter().map(|l| l.wset.clone()).collect(),
        };
        let clean = ParallelEngine::new(spec, &p.rt.params, &qc, p.pp.threads);
        let acc_clean = accuracy_of(&clean, &batches);
        let energy_clean = plan_energy(p, &clean.plan);
        for conv_idx in injectable_convs(&clean.plan) {
            for &n_flips in &cfg.flip_counts {
                let mut accs = Vec::with_capacity(cfg.trials);
                let mut energies = Vec::with_capacity(cfg.trials);
                for trial in 0..cfg.trials.max(1) {
                    let mut eng = ParallelEngine::new(spec, &p.rt.params, &qc, p.pp.threads);
                    let cell = mix2(
                        cfg.seed,
                        mix2(conv_idx as u64, ((n_flips as u64) << 16) | trial as u64),
                    );
                    inject_bit_flips(&mut eng.plan, conv_idx, n_flips, cell);
                    accs.push(accuracy_of(&eng, &batches));
                    energies.push(plan_energy(p, &eng.plan));
                }
                let acc_mean = accs.iter().sum::<f64>() / accs.len() as f64;
                let acc_worst = accs.iter().cloned().fold(f64::INFINITY, f64::min);
                let energy_mean = energies.iter().sum::<f64>() / energies.len() as f64;
                report.rows.push(CampaignRow {
                    variant: name.to_string(),
                    conv_idx,
                    n_flips,
                    acc_clean,
                    acc_mean,
                    acc_worst,
                    energy_clean,
                    energy_mean,
                });
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::tests_support::tiny_spec;
    use crate::model::Params;

    fn engine(seed: u64) -> ParallelEngine {
        let spec = tiny_spec();
        let p = Params::random(&spec, seed);
        let qc = QuantConfig::quantized(&spec, vec![0.05; spec.n_q]);
        ParallelEngine::new(&spec, &p.tensors, &qc, 2)
    }

    fn logits_bits(eng: &ParallelEngine, x: &[f32], batch: usize) -> Vec<u32> {
        eng.forward_plain(x, batch)
            .logits
            .iter()
            .map(|v| v.to_bits())
            .collect()
    }

    fn val_input(batch: usize) -> Vec<f32> {
        crate::data::batch(7, Split::Val, 0, batch, 10).0
    }

    #[test]
    fn injection_is_deterministic_from_seed() {
        let x = val_input(2);
        let mut a = engine(3);
        let mut b = engine(3);
        let ci = injectable_convs(&a.plan)[0];
        let fa = inject_bit_flips(&mut a.plan, ci, 4, 0xF117);
        let fb = inject_bit_flips(&mut b.plan, ci, 4, 0xF117);
        assert_eq!(fa, fb);
        assert_eq!(logits_bits(&a, &x, 2), logits_bits(&b, &x, 2));
    }

    #[test]
    fn records_reconstruct_the_faulted_codes_exactly() {
        let mut eng = engine(5);
        let ci = injectable_convs(&eng.plan)[0];
        let before = conv_codes(&eng.plan, ci).unwrap();
        let flips = inject_bit_flips(&mut eng.plan, ci, 8, 42);
        assert_eq!(flips.len(), 8);
        // Distinct (pos, bit) targets.
        let mut targets: Vec<(usize, u8)> = flips.iter().map(|f| (f.pos, f.bit)).collect();
        targets.sort_unstable();
        targets.dedup();
        assert_eq!(targets.len(), 8);
        // Replaying the records over the clean codes reproduces the
        // faulted codes; each record flips exactly its named bit.
        let mut replay = before.clone();
        for f in &flips {
            assert_eq!((f.before as u8) ^ (f.after as u8), 1u8 << f.bit);
            replay[f.pos] = (replay[f.pos] as u8 ^ (1u8 << f.bit)) as i8;
        }
        assert_eq!(replay, conv_codes(&eng.plan, ci).unwrap());
    }

    #[test]
    fn zero_flips_is_bit_identical() {
        let x = val_input(2);
        let clean = engine(9);
        let mut faulted = engine(9);
        let ci = injectable_convs(&faulted.plan)[0];
        let flips = inject_bit_flips(&mut faulted.plan, ci, 0, 1);
        assert!(flips.is_empty());
        assert_eq!(logits_bits(&clean, &x, 2), logits_bits(&faulted, &x, 2));
    }

    #[test]
    fn repack_keeps_blocked_panels_consistent_with_codes() {
        let mut eng = engine(11);
        let ci = injectable_convs(&eng.plan)[0];
        inject_bit_flips(&mut eng.plan, ci, 16, 77);
        // conv_sparsity reads the repacked panels; their occupancy must
        // match what packing the faulted reference codes yields.
        let codes = conv_codes(&eng.plan, ci).unwrap();
        let cs = conv_steps(&eng.plan)
            .find(|cs| cs.op.conv_idx == ci)
            .unwrap();
        let (kk, nn) = (cs.op.k * cs.op.k * cs.op.cin, cs.op.cout);
        let want = crate::model::kernels::block_sparsity_of(&codes, kk, nn);
        let got = eng
            .plan
            .conv_sparsity()
            .into_iter()
            .find(|(i, _)| *i == ci)
            .unwrap()
            .1;
        assert_eq!(got, want);
    }

    #[test]
    fn missing_or_float_layers_yield_no_flips() {
        let spec = tiny_spec();
        let p = Params::random(&spec, 13);
        let qc = QuantConfig::float(&spec);
        let mut float_eng = ParallelEngine::new(&spec, &p.tensors, &qc, 1);
        assert!(injectable_convs(&float_eng.plan).is_empty());
        assert!(inject_bit_flips(&mut float_eng.plan, 0, 3, 1).is_empty());
        let mut quant_eng = engine(13);
        assert!(inject_bit_flips(&mut quant_eng.plan, 999, 3, 1).is_empty());
    }
}
