//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every `benches/*.rs` target (`harness = false` in Cargo.toml).
//! Reports min / median / mean over timed iterations after warmup, plus a
//! derived throughput line when the caller supplies an items count.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub min_ns: u128,
    pub median_ns: u128,
    pub mean_ns: u128,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "bench {:40} iters={:4}  min={:>12}  median={:>12}  mean={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        );
    }

    pub fn report_throughput(&self, items: f64, unit: &str) {
        self.report();
        let per_sec = items / (self.median_ns as f64 * 1e-9);
        println!("      -> {per_sec:.3e} {unit}/s (median)");
    }
}

pub fn fmt_ns(ns: u128) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<u128> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos());
    }
    samples.sort_unstable();
    let min_ns = *samples.first().unwrap_or(&0);
    let median_ns = samples.get(samples.len() / 2).copied().unwrap_or(0);
    let mean_ns = samples.iter().sum::<u128>() / samples.len().max(1) as u128;
    Measurement {
        name: name.to_string(),
        iters,
        min_ns,
        median_ns,
        mean_ns,
    }
}

/// Guard against dead-code elimination of benched values.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Nearest-rank percentile of an ascending-sorted sample (`q` in
/// `0..=1`): the smallest sample ≥ the q-fraction rank.  Used by the
/// serving bench for p50/p95/p99 latency; nearest-rank keeps every
/// reported value an actually observed latency (no interpolation), so
/// p99 ≥ p95 ≥ p50 holds structurally.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    let q = q.clamp(0.0, 1.0);
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1).min(sorted.len()) - 1]
}

/// Whether the ≥2× speedup assertions in `benches/perf_hotpaths.rs`
/// should be enforced: requires ≥ 4 hardware threads
/// (`std::thread::available_parallelism`) **and** a worker pool of ≥ 4
/// (`default_threads()`, which honors the `WSEL_THREADS` override the
/// benches actually run with), and can be force-disabled with
/// `WSEL_PERF_ASSERT=0` (low-core CI runners would otherwise flake —
/// the benches still run and report, they just don't gate).
pub fn perf_asserts_enabled() -> bool {
    if std::env::var("WSEL_PERF_ASSERT")
        .map(|v| v == "0")
        .unwrap_or(false)
    {
        return false;
    }
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    hw.min(crate::util::threadpool::default_threads()) >= 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 0.50), 50);
        assert_eq!(percentile(&s, 0.95), 95);
        assert_eq!(percentile(&s, 0.99), 99);
        assert_eq!(percentile(&s, 1.0), 100);
        assert_eq!(percentile(&s, 0.0), 1);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[42], 0.99), 42);
        // Monotone in q by construction.
        let p50 = percentile(&s, 0.5);
        let p95 = percentile(&s, 0.95);
        let p99 = percentile(&s, 0.99);
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn measures_something() {
        let m = bench("noop-ish", 1, 5, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(m.min_ns <= m.median_ns && m.median_ns <= m.mean_ns * 2);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_ns(12).ends_with("ns"));
        assert!(fmt_ns(12_000).ends_with("µs"));
        assert!(fmt_ns(12_000_000).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000).ends_with('s'));
    }
}

/// Shared scenario setup for the paper-table benches: a quick-preset
/// pipeline, trained + profiled, with `float_steps` overridable so each
/// bench balances runtime against signal.  Reuses step-tagged checkpoints
/// when present, so repeated `cargo bench` invocations skip training.
pub mod scenarios {
    use crate::coordinator::{Pipeline, PipelineParams};
    use anyhow::Result;
    use std::path::PathBuf;

    pub fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if p.join("lenet5/manifest.json").exists() {
            Some(p)
        } else {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }

    /// Quick pipeline, trained and profiled.
    pub fn prepared(model: &str, float_steps: usize, qat_steps: usize) -> Result<Pipeline> {
        let dir = artifacts_dir().expect("artifacts");
        let pp = PipelineParams {
            float_steps,
            qat_steps,
            calib_batches: 1,
            val_batches: 2,
            trace_len: 256,
            stats_images: 4,
            ..PipelineParams::default()
        };
        let mut p = Pipeline::new(&dir, model, pp)?;
        p.train_baseline()?;
        p.profile()?;
        Ok(p)
    }
}
