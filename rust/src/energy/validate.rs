//! Exact-vs-model validation plumbing (paper §3.2).
//!
//! The statistical layer model ([`LayerEnergy`]) predicts conv energy
//! from per-weight tables; the exact tile-power engine
//! ([`crate::systolic::network_power_exact`]) measures it gate-by-gate
//! on the same captured operand streams.  This module diffs the two per
//! layer, which is the network-scale version of the paper's model
//! validation (previously feasible only for cherry-picked single tiles).

use crate::energy::layer::LayerEnergy;
use crate::energy::macmodel::WeightEnergyTable;
use crate::model::ConvCapture;
use crate::systolic::ExactNetworkPower;
use crate::util::json::Json;

/// One conv layer's exact/model comparison.
#[derive(Clone, Debug)]
pub struct LayerValidation {
    pub conv_idx: usize,
    /// Exact gate-level energy (J) over the layer's captured streams.
    pub exact_j: f64,
    /// Model-mode prediction (J) on the same streams (same M, K, N and
    /// weight codes as each capture).
    pub model_j: f64,
    /// Model prediction (J) with the executor's structural skip
    /// accounted: zero weights inside all-zero SB×SB blocks are
    /// clock-gated instead of paying dense `E(0)` switching (see
    /// [`LayerEnergy::energy_of_codes_gated`]).  Equals `model_j` when
    /// the layer has no empty blocks.
    pub model_gated_j: f64,
}

impl LayerValidation {
    /// model / exact — the paper's validation tracks this within a small
    /// constant factor.
    pub fn ratio(&self) -> f64 {
        if self.exact_j > 0.0 {
            self.model_j / self.exact_j
        } else {
            f64::INFINITY
        }
    }

    /// Fractional energy saving the gated-MAC skip buys this layer
    /// (`1 − model_gated_j / model_j`; 0 for empty layers).
    pub fn gated_saving(&self) -> f64 {
        if self.model_j > 0.0 {
            1.0 - self.model_gated_j / self.model_j
        } else {
            0.0
        }
    }
}

/// Per-layer exact-vs-model report, ascending `conv_idx`.
#[derive(Clone, Debug, Default)]
pub struct ValidationReport {
    pub layers: Vec<LayerValidation>,
}

impl ValidationReport {
    /// Largest spread of model/exact ratios across layers (1.0 = the
    /// model mis-ranks nothing; the schedule only needs *relative*
    /// layer energies to order its work).
    pub fn ratio_spread(&self) -> f64 {
        let mut lo = f64::MAX;
        let mut hi = 0.0f64;
        for l in &self.layers {
            let r = l.ratio();
            lo = lo.min(r);
            hi = hi.max(r);
        }
        if self.layers.is_empty() || lo <= 0.0 {
            return f64::INFINITY;
        }
        hi / lo
    }

    /// Machine-readable form for reports / golden harness.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "layers",
            Json::arr(self.layers.iter().map(|l| {
                Json::obj(vec![
                    ("conv_idx", Json::num(l.conv_idx as f64)),
                    ("exact_j", Json::num(l.exact_j)),
                    ("model_j", Json::num(l.model_j)),
                    ("model_gated_j", Json::num(l.model_gated_j)),
                ])
            })),
        )])
    }
}

/// One conv layer's operand-pair metadata: what the model side of an
/// exact-vs-model validation needs (dims + weight codes), without any
/// activation copies.  Produced by the streaming
/// [`crate::systolic::PowerSink`].
#[derive(Clone, Debug)]
pub struct StreamMeta {
    pub conv_idx: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// K×N row-major weight codes.
    pub w_codes: Vec<i8>,
}

/// Diff an exact engine run against the model's prediction on the same
/// operand streams, described by per-conv [`StreamMeta`].  Entries
/// sharing a `conv_idx` accumulate into one layer, in order.
///
/// The `exact` side is whatever tile schedule produced it: buffered
/// captures through [`crate::systolic::network_power_exact`] (whole-M
/// packing, cross-pass stream dedup) or the streaming
/// [`crate::systolic::PowerSink`] (per-block tiling, dedup within each
/// block).  Both are exact gate-level energies of their respective
/// schedules, but they tile M differently, so their absolute joules are
/// not interchangeable — compare reports produced by the same path.
pub fn validate_streams(
    metas: &[StreamMeta],
    tables: &[WeightEnergyTable],
    exact: &ExactNetworkPower,
) -> ValidationReport {
    let mut layers: Vec<LayerValidation> = Vec::new();
    for meta in metas {
        let le = LayerEnergy {
            conv_idx: meta.conv_idx,
            m: meta.m,
            k: meta.k,
            n: meta.n,
            table: tables[meta.conv_idx].clone(),
        };
        let e = le.energy_of_codes(&meta.w_codes);
        // Gated prediction: whatever the executor skips structurally
        // (all-zero SB×SB blocks of this stream's weight matrix) is
        // clock-gated instead of paying dense E(0).
        let skipped = crate::model::kernels::block_sparsity_of(&meta.w_codes, meta.k, meta.n)
            .elems_skipped;
        let e_gated = le.energy_of_codes_gated(&meta.w_codes, skipped);
        if let Some(pos) = layers.iter().position(|l| l.conv_idx == meta.conv_idx) {
            layers[pos].model_j += e;
            layers[pos].model_gated_j += e_gated;
        } else {
            layers.push(LayerValidation {
                conv_idx: meta.conv_idx,
                exact_j: 0.0,
                model_j: e,
                model_gated_j: e_gated,
            });
        }
    }
    for l in &mut layers {
        if let Some(x) = exact.layers.iter().find(|x| x.conv_idx == l.conv_idx) {
            l.exact_j = x.energy_j;
        }
    }
    layers.sort_by_key(|l| l.conv_idx);
    ValidationReport { layers }
}

/// Diff an exact engine run against the model's prediction on the same
/// captures.  `tables` is indexed by `conv_idx` (the coordinator's
/// layout).  Captures sharing a `conv_idx` accumulate into one entry, in
/// capture order, mirroring [`crate::systolic::network_power_exact`].
pub fn validate_captures(
    captures: &[ConvCapture],
    tables: &[WeightEnergyTable],
    exact: &ExactNetworkPower,
) -> ValidationReport {
    let metas: Vec<StreamMeta> = captures
        .iter()
        .map(|cap| StreamMeta {
            conv_idx: cap.conv_idx,
            m: cap.m,
            k: cap.k,
            n: cap.n,
            w_codes: cap.w_codes.clone(),
        })
        .collect();
    validate_streams(&metas, tables, exact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::ExactLayerPower;

    fn table() -> WeightEnergyTable {
        crate::testutil::linear_energy_table(1e-15)
    }

    #[test]
    fn report_accumulates_and_sorts() {
        let caps: Vec<ConvCapture> = [1usize, 0, 1]
            .iter()
            .map(|&ci| ConvCapture {
                conv_idx: ci,
                m: 4,
                k: 3,
                n: 2,
                x_codes: vec![0i8; 12],
                w_codes: vec![5i8; 6],
                s_act: 1.0,
                s_w: 1.0,
            })
            .collect();
        let exact = ExactNetworkPower {
            layers: vec![
                ExactLayerPower {
                    conv_idx: 0,
                    energy_j: 1e-12,
                    mac_steps: 10,
                    columns_total: 2,
                    columns_unique: 1,
                },
                ExactLayerPower {
                    conv_idx: 1,
                    energy_j: 2e-12,
                    mac_steps: 20,
                    columns_total: 4,
                    columns_unique: 2,
                },
            ],
        };
        let rep = validate_captures(&caps, &[table(), table()], &exact);
        assert_eq!(rep.layers.len(), 2);
        assert_eq!(rep.layers[0].conv_idx, 0);
        assert_eq!(rep.layers[1].conv_idx, 1);
        // conv 1 had two captures: model energy doubles conv 0's.
        assert!((rep.layers[1].model_j / rep.layers[0].model_j - 2.0).abs() < 1e-12);
        assert_eq!(rep.layers[0].exact_j, 1e-12);
        assert!(rep.layers[0].ratio() > 0.0);
        assert!(rep.ratio_spread() >= 1.0);
        // All weights nonzero: nothing to skip, gated model == dense.
        for l in &rep.layers {
            assert_eq!(l.model_gated_j.to_bits(), l.model_j.to_bits());
            assert_eq!(l.gated_saving(), 0.0);
        }
        let js = format!("{}", rep.to_json());
        assert!(js.contains("exact_j"));
        assert!(js.contains("model_gated_j"));
    }

    /// A layer whose weights contain whole all-zero SB×SB blocks shows a
    /// gated-MAC energy delta in the validation report.
    #[test]
    fn gated_model_reflects_empty_blocks() {
        use crate::model::kernels::SB;
        let (k, n) = (2 * SB, SB);
        let mut w = vec![3i8; k * n];
        // Zero the second 8-row block entirely: one empty SB×SB block.
        for r in SB..k {
            for j in 0..n {
                w[r * n + j] = 0;
            }
        }
        let metas = vec![StreamMeta {
            conv_idx: 0,
            m: 4,
            k,
            n,
            w_codes: w,
        }];
        let exact = ExactNetworkPower { layers: vec![] };
        let rep = validate_streams(&metas, &[table()], &exact);
        let l = &rep.layers[0];
        assert!(
            l.model_gated_j < l.model_j,
            "structural skip must cheapen the model: {} vs {}",
            l.model_gated_j,
            l.model_j
        );
        assert!(l.gated_saving() > 0.0 && l.gated_saving() < 1.0);
    }
}
