//! Memoized, parallel energy-evaluation engine (the enabling refactor
//! for every schedule/selection hot loop).
//!
//! Two caches live here:
//!
//! * [`EnergyEvaluator`] — the model-mode network-energy engine.  Built
//!   once from the per-layer energy tables + float weight tensors, it
//!   memoizes the expensive per-(layer, prune-ratio) weight-code usage
//!   histograms (each one costs a magnitude sort + full re-quantization
//!   of the layer tensor) and evaluates all conv layers through
//!   [`parallel_map`].  `eval(state)` is **bit-identical** to the
//!   direct sequential path ([`EnergyEvaluator::eval_direct`], asserted
//!   by property tests): per-layer energies are computed by exactly the
//!   same f64 expression on exactly the same inputs and assembled in
//!   layer order, so neither memoization nor thread count can change a
//!   single bit of the result.
//!
//! * [`TransitionCostCache`] — a first-order (FODLAM-style) memo of
//!   gate-level MAC energies keyed by (weight code, MSB×Hamming
//!   partial-sum group pair), with group representatives drawn
//!   deterministically from the layer's empirical reservoirs (paper
//!   §3.1).  [`TransitionCostCache::approx_table`] composes the memo
//!   with the layer's group-pair transition distribution into a fast
//!   approximate `E_ℓ(w)` table — the cheap surrogate for
//!   [`characterize_layer`](crate::energy::characterize_layer) when a
//!   candidate sweep needs many re-characterizations.
//!
//! Cache keying: usage histograms key on `(conv_idx,
//! prune_ratio.to_bits())`; transition costs key on `(weight_code,
//! group_from * N_GROUPS + group_to)`.  Both caches are internally
//! locked so `parallel_map` workers share them safely; values are
//! deterministic, so a racing duplicate computation is harmless (first
//! insert wins, all candidates are equal).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::energy::layer::{LayerEnergy, NetworkEnergy};
use crate::energy::macmodel::{trace_energy, WeightEnergyTable};
use crate::gates::CapModel;
use crate::quant::{magnitude_mask, quantize_restricted};
use crate::selection::CompressionState;
use crate::stats::LayerStats;
use crate::systolic::MacLib;
use crate::transitions::group::N_GROUPS;
use crate::transitions::histogram::from_bits;
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::parallel_map;

/// One conv layer as the evaluator sees it: the energy model plus the
/// float weight tensor the usage histograms derive from.
#[derive(Clone)]
pub struct EvalLayer {
    pub le: LayerEnergy,
    /// Float weight tensor (pre-mask, pre-quantization).
    pub weights: Vec<f32>,
}

/// Memoized network-energy evaluator.  Build once (snapshot of tables +
/// weights), then `eval(state)` is cheap: usage histograms are computed
/// at most once per (layer, prune-ratio) and layers fan out across the
/// thread pool.
///
/// The snapshot semantics matter: if the underlying weights change
/// (fine-tuning, restore), build a fresh evaluator — the coordinator
/// does this automatically via its params epoch.
pub struct EnergyEvaluator {
    layers: Vec<EvalLayer>,
    threads: usize,
    usage_cache: Mutex<HashMap<(usize, u64), Arc<[u64; 256]>>>,
}

impl EnergyEvaluator {
    /// `layers` must be sorted by `conv_idx` (one entry per conv layer);
    /// `threads` is the fan-out width for [`eval`](Self::eval).
    pub fn new(layers: Vec<EvalLayer>, threads: usize) -> Self {
        debug_assert!(layers.windows(2).all(|w| w[0].le.conv_idx < w[1].le.conv_idx));
        Self {
            layers,
            threads: threads.max(1),
            usage_cache: Mutex::new(HashMap::new()),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, slot: usize) -> &EvalLayer {
        &self.layers[slot]
    }

    /// Change the fan-out width (cache is kept).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Number of memoized usage histograms (observability / tests).
    pub fn cached_usages(&self) -> usize {
        self.usage_cache.lock().unwrap().len()
    }

    /// Drop all memoized usage histograms (benchmark cold paths).
    pub fn clear_cache(&self) {
        self.usage_cache.lock().unwrap().clear();
    }

    /// The direct (uncached) usage computation — the exact mirror of the
    /// coordinator's historical inline path: magnitude-mask at `ratio`,
    /// re-quantize, histogram.
    pub fn compute_usage(weights: &[f32], ratio: f64) -> [u64; 256] {
        let mask = if ratio > 0.0 {
            Some(magnitude_mask(weights, ratio))
        } else {
            None
        };
        let (codes, _s) = quantize_restricted(weights, mask.as_deref(), None);
        let mut usage = [0u64; 256];
        for &c in &codes {
            usage[(c as i32 + 128) as usize] += 1;
        }
        usage
    }

    /// Memoized usage histogram of layer slot `slot` at `prune_ratio`.
    pub fn usage(&self, slot: usize, prune_ratio: f64) -> Arc<[u64; 256]> {
        let key = (self.layers[slot].le.conv_idx, prune_ratio.to_bits());
        if let Some(u) = self.usage_cache.lock().unwrap().get(&key) {
            return u.clone();
        }
        // Computed outside the lock: duplicates are deterministic and
        // the first insert wins.
        let u = Arc::new(Self::compute_usage(&self.layers[slot].weights, prune_ratio));
        self.usage_cache
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(u)
            .clone()
    }

    /// Memoized usage histogram addressed by `conv_idx`.
    pub fn usage_for_conv(&self, conv_idx: usize, prune_ratio: f64) -> Arc<[u64; 256]> {
        let slot = self
            .layers
            .iter()
            .position(|l| l.le.conv_idx == conv_idx)
            .expect("conv idx");
        self.usage(slot, prune_ratio)
    }

    /// Energy model of a layer (addressed by `conv_idx`).
    pub fn layer_model(&self, conv_idx: usize) -> &LayerEnergy {
        &self.layer_by_conv(conv_idx).le
    }

    /// Full layer entry (addressed by `conv_idx`).
    pub fn layer_by_conv(&self, conv_idx: usize) -> &EvalLayer {
        let slot = self
            .layers
            .iter()
            .position(|l| l.le.conv_idx == conv_idx)
            .expect("conv idx");
        &self.layers[slot]
    }

    /// Model-mode energy of layer slot `slot` under `state` (cached
    /// usage; identical math to the direct path).
    fn layer_energy(&self, slot: usize, state: &CompressionState) -> f64 {
        let l = &self.layers[slot];
        let lc = &state.layers[l.le.conv_idx];
        let usage = self.usage(slot, lc.prune_ratio);
        match &lc.wset {
            Some(s) => crate::selection::set_energy(&l.le, &usage, s),
            None => l.le.energy_of_usage(&usage),
        }
    }

    /// Network energy under `state`: layers fan out over the thread
    /// pool against the shared usage cache.  Bit-identical to
    /// [`eval_direct`](Self::eval_direct) for any thread count.
    pub fn eval(&self, state: &CompressionState) -> NetworkEnergy {
        let layers = parallel_map(self.layers.len(), self.threads, |i| {
            (self.layers[i].le.conv_idx, self.layer_energy(i, state))
        });
        NetworkEnergy { layers }
    }

    /// Reference path: sequential, no memoization — every usage
    /// histogram recomputed from the weight tensors.  This is what the
    /// coordinator did inline before the evaluator existed; property
    /// tests assert `eval == eval_direct` bit-for-bit.
    pub fn eval_direct(&self, state: &CompressionState) -> NetworkEnergy {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let lc = &state.layers[l.le.conv_idx];
                let usage = Self::compute_usage(&l.weights, lc.prune_ratio);
                let e = match &lc.wset {
                    Some(s) => crate::selection::set_energy(&l.le, &usage, s),
                    None => l.le.energy_of_usage(&usage),
                };
                (l.le.conv_idx, e)
            })
            .collect();
        NetworkEnergy { layers }
    }
}

/// Memo of gate-level MAC probe energies per (weight code, partial-sum
/// group pair), with representatives fixed per layer statistics.
///
/// A probe drives the weight-specialized MAC with a constant activation
/// (the mode of the layer's activation marginal) and an alternating
/// `rep[g_from] ⇄ rep[g_to]` partial-sum stream for
/// [`PROBE_STEPS`](Self::PROBE_STEPS) cycles — the Fig. 2 measurement,
/// memoized.  All draws are deterministic in the seed, so the cache is
/// reproducible.
pub struct TransitionCostCache {
    /// Representative 22-bit pattern per group (from the layer's
    /// reservoirs, synthetic members for unseen groups).
    reps: Vec<u32>,
    /// Constant activation code used by probes.
    act: i32,
    memo: Mutex<HashMap<(i8, u16), f64>>,
}

impl TransitionCostCache {
    /// Probe trace length per (code, group-pair) measurement.
    pub const PROBE_STEPS: usize = 64;

    /// Build the per-layer cache: pick one representative pattern per
    /// group and the modal activation, both deterministic in `seed`.
    pub fn new(stats: &LayerStats, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let reps: Vec<u32> = (0..N_GROUPS)
            .map(|g| stats.psum.representative(g, &mut rng))
            .collect();
        let marg = stats.act.from_marginal();
        let mut act = 0i32;
        let mut best = -1.0f64;
        for (i, &p) in marg.iter().enumerate() {
            if p > best {
                best = p;
                act = i as i32 - 128;
            }
        }
        Self {
            reps,
            act,
            memo: Mutex::new(HashMap::new()),
        }
    }

    /// Number of memoized (code, group-pair) probes.
    pub fn len(&self) -> usize {
        self.memo.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The gate-level probe itself (no memo access): alternate
    /// `rep[g_from] ⇄ rep[g_to]` under the modal activation.
    fn probe(&self, lib: &MacLib, cap: &CapModel, w: i8, g_from: usize, g_to: usize) -> f64 {
        let mac = lib.get_cached(w).expect("MacLib must be pre-specialized");
        let p1 = from_bits(self.reps[g_from]);
        let p2 = from_bits(self.reps[g_to]);
        let acts = vec![self.act; Self::PROBE_STEPS];
        let psums: Vec<i32> = (0..Self::PROBE_STEPS)
            .map(|i| if i % 2 == 0 { p1 } else { p2 })
            .collect();
        trace_energy(mac, &acts, &psums, cap)
    }

    /// Memoized per-cycle energy (J) of weight `w` under the
    /// `g_from → g_to` transition.  `lib` must be pre-specialized (see
    /// [`MacLib::specialize_all`]).
    pub fn cost(&self, lib: &MacLib, cap: &CapModel, w: i8, g_from: usize, g_to: usize) -> f64 {
        let key = (w, (g_from * N_GROUPS + g_to) as u16);
        if let Some(&e) = self.memo.lock().unwrap().get(&key) {
            return e;
        }
        let e = self.probe(lib, cap, w, g_from, g_to);
        *self.memo.lock().unwrap().entry(key).or_insert(e)
    }

    /// First-order approximate `E_ℓ(w)` table: the expectation of the
    /// memoized probe costs under the layer's empirical group-pair
    /// transition distribution.  Orders of magnitude cheaper than a full
    /// re-characterization once the memo is warm, and deterministic.
    pub fn approx_table(
        &self,
        stats: &LayerStats,
        lib: &MacLib,
        cap: &CapModel,
        threads: usize,
    ) -> WeightEnergyTable {
        // Non-zero group-pair probabilities in fixed (g_from, g_to) order.
        let total = stats.psum.total.max(1) as f64;
        let mut pairs: Vec<(usize, usize, f64)> = Vec::new();
        for gf in 0..N_GROUPS {
            for gt in 0..N_GROUPS {
                let c = stats.psum.counts[gf * N_GROUPS + gt];
                if c > 0 {
                    pairs.push((gf, gt, c as f64 / total));
                }
            }
        }
        // Fill the memo for every missing (code, pair) in one parallel
        // batch (the expensive gate-level probes), then do the weighted
        // sums against a single snapshot — one lock total on the warm
        // path instead of one per lookup.
        let missing: Vec<(i8, usize, usize)> = {
            let memo = self.memo.lock().unwrap();
            let mut v = Vec::new();
            for i in 0..255 {
                let code = (i as i32 - 127) as i8;
                for &(gf, gt, _) in &pairs {
                    if !memo.contains_key(&(code, (gf * N_GROUPS + gt) as u16)) {
                        v.push((code, gf, gt));
                    }
                }
            }
            v
        };
        if !missing.is_empty() {
            let missing_ref = &missing;
            let probed = parallel_map(missing.len(), threads, |i| {
                let (w, gf, gt) = missing_ref[i];
                self.probe(lib, cap, w, gf, gt)
            });
            let mut memo = self.memo.lock().unwrap();
            for (&(w, gf, gt), e) in missing.iter().zip(probed) {
                memo.entry((w, (gf * N_GROUPS + gt) as u16)).or_insert(e);
            }
        }
        let memo = self.memo.lock().unwrap();
        let energies: Vec<f64> = (0..255)
            .map(|i| {
                let code = (i as i32 - 127) as i8;
                let mut e = 0.0f64;
                for &(gf, gt, p) in &pairs {
                    e += p * memo[&(code, (gf * N_GROUPS + gt) as u16)];
                }
                e
            })
            .collect();
        drop(memo);
        let mut e_per_cycle = [0.0f64; 256];
        for (i, &e) in energies.iter().enumerate() {
            e_per_cycle[i + 1] = e; // code -127 at index 1
        }
        e_per_cycle[0] = e_per_cycle[1]; // -128 alias (never produced)

        // Idle matches characterize_layer's definition: w = 0 driven by
        // an all-zero stream.
        let zeros = vec![0i32; Self::PROBE_STEPS];
        let e_idle = trace_energy(
            lib.get_cached(0).expect("MacLib must be pre-specialized"),
            &zeros,
            &zeros,
            cap,
        );
        WeightEnergyTable { e_per_cycle, e_idle }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ConvCapture;
    use crate::quant::WeightSet;
    use crate::selection::LayerConfig;
    use crate::stats::collect;

    fn synth_table() -> WeightEnergyTable {
        crate::testutil::linear_energy_table(1e-15)
    }

    fn synth_evaluator(threads: usize) -> EnergyEvaluator {
        let mut rng = Xoshiro256::new(9);
        let layers = (0..3)
            .map(|ci| EvalLayer {
                le: LayerEnergy {
                    conv_idx: ci,
                    m: 64 * (ci + 1),
                    k: 75 + 25 * ci,
                    n: 8 << ci,
                    table: synth_table(),
                },
                weights: (0..(75 + 25 * ci) * (8 << ci))
                    .map(|_| rng.range_f32(-1.0, 1.0))
                    .collect(),
            })
            .collect();
        EnergyEvaluator::new(layers, threads)
    }

    fn states() -> Vec<CompressionState> {
        let set = WeightSet::new(vec![-127, -64, -16, -4, 0, 4, 16, 64, 127]);
        let dense = CompressionState::dense(3);
        let mut pruned = CompressionState::dense(3);
        for l in &mut pruned.layers {
            l.prune_ratio = 0.5;
        }
        let mut restricted = CompressionState::dense(3);
        restricted.layers[1] = LayerConfig {
            prune_ratio: 0.7,
            wset: Some(set),
        };
        vec![dense, pruned, restricted]
    }

    #[test]
    fn cached_parallel_matches_direct_bitwise() {
        let ev = synth_evaluator(4);
        for st in states() {
            let a = ev.eval(&st);
            let b = ev.eval_direct(&st);
            assert_eq!(a.layers.len(), b.layers.len());
            for ((i1, e1), (i2, e2)) in a.layers.iter().zip(&b.layers) {
                assert_eq!(i1, i2);
                assert_eq!(e1.to_bits(), e2.to_bits(), "layer {i1}: {e1} vs {e2}");
            }
        }
    }

    #[test]
    fn usage_is_memoized_per_layer_and_ratio() {
        let ev = synth_evaluator(2);
        assert_eq!(ev.cached_usages(), 0);
        let st = states().remove(1); // all layers at ratio 0.5
        ev.eval(&st);
        assert_eq!(ev.cached_usages(), 3);
        ev.eval(&st); // second eval hits the cache
        assert_eq!(ev.cached_usages(), 3);
        ev.clear_cache();
        assert_eq!(ev.cached_usages(), 0);
    }

    #[test]
    fn transition_cache_memoizes_and_orders_costs() {
        let mut rng = Xoshiro256::new(4);
        let (m, k, n) = (96, 64, 4);
        let cap = ConvCapture {
            conv_idx: 0,
            m,
            k,
            n,
            x_codes: (0..m * k)
                .map(|_| if rng.below(2) == 0 { 0 } else { rng.code() as i8 })
                .collect(),
            w_codes: (0..k * n).map(|_| rng.code() as i8).collect(),
            s_act: 0.01,
            s_w: 0.01,
        };
        let st = collect(&cap, &mut rng);
        let mut lib = MacLib::new();
        lib.specialize_all(1);
        let cm = CapModel::default();
        let tc = TransitionCostCache::new(&st, 11);
        let c1 = tc.cost(&lib, &cm, 17, 3, 7);
        let n1 = tc.len();
        let c2 = tc.cost(&lib, &cm, 17, 3, 7);
        assert_eq!(c1.to_bits(), c2.to_bits(), "memo must be stable");
        assert_eq!(tc.len(), n1, "second lookup must not grow the memo");

        let t = tc.approx_table(&st, &lib, &cm, 2);
        assert!(t.e_per_cycle[1..].iter().all(|&e| e > 0.0));
        // Fig. 1 shape: w = 0 is much cheaper than the heaviest code.
        assert!(t.energy(0) < t.energy(-127) * 0.9);
        // Deterministic across a rebuild with the same seed.
        let tc2 = TransitionCostCache::new(&st, 11);
        let t2 = tc2.approx_table(&st, &lib, &cm, 1);
        assert_eq!(t.e_per_cycle.to_vec(), t2.e_per_cycle.to_vec());
    }
}
