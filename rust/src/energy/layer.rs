//! Convolution-layer and network energy (paper §3.2).
//!
//! A layer's im2col matmul runs as `N_ℓ` tile passes of 128 cycles on the
//! 64×64 array.  In model mode the layer energy composes the per-weight
//! table with the weight-code usage:
//!
//! ```text
//! E_ℓ = Σ_positions E_ℓ(w_pos) · cycles_resident   + padding · E_idle
//! cycles_resident = ceil(M/64) · 128        (per weight position)
//! ```
//!
//! which is algebraically `N_ℓ · E_tile` with `E_tile = 2 P̄_tile T`,
//! `T = 64/f` (the paper's formulation), since every weight position of a
//! tile is live for all of the tile's passes.  Exact mode
//! ([`crate::systolic::tile_power_exact`]) validates this composition.

use super::macmodel::WeightEnergyTable;
use crate::systolic::{n_tiles, CYCLES_PER_PASS, TILE};

/// Residual clock-tree energy fraction for *padded* PE positions (tile
/// rows/columns beyond the layer's K×N).  Weight-stationary arrays
/// clock-gate columns/rows that carry no data (TPU-style); only a stub
/// of the clock tree keeps toggling.  Pruned (w = 0) positions inside
/// the layer are NOT gated — partial sums still chain through them — so
/// they pay the full `E(0)` like the paper's zero-weight MACs, *unless*
/// they sit in an all-zero SB×SB block the executor skips structurally:
/// those never enter the array and are clock-gated like padding (see
/// [`LayerEnergy::energy_of_usage_gated`]).
pub const GATED_IDLE_FRACTION: f64 = 0.15;

/// Energy accounting for one conv layer.
#[derive(Clone, Debug)]
pub struct LayerEnergy {
    pub conv_idx: usize,
    /// Matmul dims (per evaluated batch).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub table: WeightEnergyTable,
}

impl LayerEnergy {
    /// Tile passes (`N_ℓ`).
    pub fn n_tiles(&self) -> u64 {
        n_tiles(self.m, self.k, self.n)
    }

    /// Cycles each weight position stays resident across the layer.
    pub fn resident_cycles(&self) -> u64 {
        (self.m.div_ceil(TILE) as u64) * CYCLES_PER_PASS
    }

    /// Model-mode layer energy (J) for a weight-code usage histogram
    /// (index = code + 128; total must equal K·N).
    pub fn energy_of_usage(&self, usage: &[u64; 256]) -> f64 {
        let cycles = self.resident_cycles() as f64;
        let mut e = 0.0f64;
        let mut occupied = 0u64;
        for (i, &cnt) in usage.iter().enumerate() {
            if cnt == 0 {
                continue;
            }
            occupied += cnt;
            let code = (i as i32 - 128) as i8;
            e += cnt as f64 * self.table.energy(code) * cycles;
        }
        // Padding PEs in ragged tiles are clock-gated to a stub.
        let k_pad = self.k.div_ceil(TILE) * TILE;
        let n_pad = self.n.div_ceil(TILE) * TILE;
        let padded = (k_pad * n_pad) as u64 - occupied;
        e + padded as f64 * self.table.e_idle * GATED_IDLE_FRACTION * cycles
    }

    /// Energy from explicit weight codes (K×N row-major).
    pub fn energy_of_codes(&self, w_codes: &[i8]) -> f64 {
        assert_eq!(w_codes.len(), self.k * self.n);
        let mut usage = [0u64; 256];
        for &c in w_codes {
            usage[(c as i32 + 128) as usize] += 1;
        }
        self.energy_of_usage(&usage)
    }

    /// Gated-MAC variant of [`Self::energy_of_usage`]: `gated_zeros`
    /// zero-code positions sit inside all-zero SB×SB blocks the executor
    /// skips structurally, so they are clock-gated like tile padding
    /// (`e_idle · GATED_IDLE_FRACTION`) instead of paying the dense
    /// `E(0)` switching cost.  `gated_zeros` is clamped to the
    /// zero-code count; `gated_zeros == 0` is bit-identical to
    /// [`Self::energy_of_usage`] (the gated positions simply move from
    /// the occupied sum into the existing padding pool).
    pub fn energy_of_usage_gated(&self, usage: &[u64; 256], gated_zeros: u64) -> f64 {
        let gated = gated_zeros.min(usage[128]);
        let mut u = *usage;
        u[128] -= gated;
        // The removed zeros fall out of `occupied`, so energy_of_usage's
        // padding term picks them up at the gated idle rate — exactly
        // the association the golden-pinned dense expression uses.
        self.energy_of_usage(&u)
    }

    /// Gated-MAC variant of [`Self::energy_of_codes`]; see
    /// [`Self::energy_of_usage_gated`].
    pub fn energy_of_codes_gated(&self, w_codes: &[i8], gated_zeros: u64) -> f64 {
        assert_eq!(w_codes.len(), self.k * self.n);
        let mut usage = [0u64; 256];
        for &c in w_codes {
            usage[(c as i32 + 128) as usize] += 1;
        }
        self.energy_of_usage_gated(&usage, gated_zeros)
    }

    /// Average tile power (W) implied by the model — the paper's
    /// `P_tile` — at clock `f`.
    pub fn p_tile(&self, usage: &[u64; 256], freq_hz: f64) -> f64 {
        let e = self.energy_of_usage(usage);
        let total_cycles = self.n_tiles() as f64 * CYCLES_PER_PASS as f64;
        // Energy per array-cycle × f = average array power while this
        // layer runs.
        e / total_cycles * freq_hz
    }
}

/// Whole-network energy report (conv layers; fc energy is negligible on
/// the array and constant across methods, as in the paper).
#[derive(Clone, Debug, Default)]
pub struct NetworkEnergy {
    pub layers: Vec<(usize, f64)>, // (conv_idx, joules)
}

impl NetworkEnergy {
    pub fn total(&self) -> f64 {
        self.layers.iter().map(|(_, e)| e).sum()
    }

    /// Per-layer share ρ_ℓ (paper §4.3).
    pub fn shares(&self) -> Vec<(usize, f64)> {
        let t = self.total();
        self.layers
            .iter()
            .map(|&(i, e)| (i, if t > 0.0 { e / t } else { 0.0 }))
            .collect()
    }

    /// Layers sorted by descending energy (the processing order of the
    /// energy-prioritized schedule).
    pub fn descending(&self) -> Vec<(usize, f64)> {
        let mut v = self.layers.clone();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    /// Saving of `other` relative to `self` (fraction in [0, 1]).
    pub fn saving_vs(&self, compressed: &NetworkEnergy) -> f64 {
        let base = self.total();
        if base <= 0.0 {
            return 0.0;
        }
        1.0 - compressed.total() / base
    }

    /// Machine-readable form for reports and the golden-file regression
    /// harness (see `testutil::golden`): per-layer `[conv_idx, joules]`
    /// pairs plus the total.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "layers",
                Json::arr(self.layers.iter().map(|&(i, e)| {
                    Json::arr([Json::num(i as f64), Json::num(e)])
                })),
            ),
            ("total", Json::num(self.total())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(scale: f64) -> WeightEnergyTable {
        let mut e = [0.0f64; 256];
        for i in 0..256 {
            let code = (i as i32 - 128).unsigned_abs() as f64;
            e[i] = (1.0 + code) * 1e-15 * scale;
        }
        WeightEnergyTable {
            e_per_cycle: e,
            e_idle: 0.5e-15 * scale,
        }
    }

    fn layer(m: usize, k: usize, n: usize) -> LayerEnergy {
        LayerEnergy {
            conv_idx: 0,
            m,
            k,
            n,
            table: table(1.0),
        }
    }

    #[test]
    fn zero_codes_cost_less() {
        let le = layer(128, 64, 64);
        let dense = vec![100i8; 64 * 64];
        let sparse = vec![0i8; 64 * 64];
        assert!(le.energy_of_codes(&dense) > le.energy_of_codes(&sparse) * 10.0);
    }

    #[test]
    fn energy_scales_with_m_passes() {
        let a = layer(64, 64, 64);
        let b = layer(128, 64, 64);
        let codes = vec![10i8; 64 * 64];
        let ea = a.energy_of_codes(&codes);
        let eb = b.energy_of_codes(&codes);
        assert!((eb / ea - 2.0).abs() < 1e-9, "double M -> double passes");
    }

    #[test]
    fn padding_counted_at_idle() {
        // K=N=32 -> tile is 3/4 padding.
        let le = layer(64, 32, 32);
        let codes = vec![0i8; 32 * 32];
        let e = le.energy_of_codes(&codes);
        let cycles = le.resident_cycles() as f64;
        let expect = (32.0 * 32.0) * le.table.energy(0) * cycles
            + (4096.0 - 1024.0) * le.table.e_idle * GATED_IDLE_FRACTION * cycles;
        assert!((e - expect).abs() / expect < 1e-12);
    }

    /// Gated accounting: zero gated positions is bit-identical to the
    /// dense model; gating zeros strictly cheapens the layer by exactly
    /// `E(0) − e_idle·GATED_IDLE_FRACTION` per position-cycle; the count
    /// clamps to the zero-code population.
    #[test]
    fn gated_zeros_join_idle_pool() {
        let le = layer(64, 32, 32);
        let mut codes = vec![7i8; 32 * 32];
        for c in codes.iter_mut().take(200) {
            *c = 0;
        }
        let dense = le.energy_of_codes(&codes);
        assert_eq!(
            dense.to_bits(),
            le.energy_of_codes_gated(&codes, 0).to_bits(),
            "gated=0 must be bit-identical to the dense model"
        );
        let gated = le.energy_of_codes_gated(&codes, 150);
        let cycles = le.resident_cycles() as f64;
        let per_pos = le.table.energy(0) - le.table.e_idle * GATED_IDLE_FRACTION;
        let expect = dense - 150.0 * per_pos * cycles;
        assert!((gated - expect).abs() / expect < 1e-12);
        assert!(gated < dense);
        // Clamp: can't gate more zeros than exist.
        let all = le.energy_of_codes_gated(&codes, 10_000);
        let clamped = le.energy_of_codes_gated(&codes, 200);
        assert_eq!(all.to_bits(), clamped.to_bits());
    }

    #[test]
    fn network_shares_and_order() {
        let ne = NetworkEnergy {
            layers: vec![(0, 1.0), (1, 3.0), (2, 1.0)],
        };
        assert!((ne.total() - 5.0).abs() < 1e-12);
        assert_eq!(ne.descending()[0].0, 1);
        let shares = ne.shares();
        assert!((shares[1].1 - 0.6).abs() < 1e-12);
        let compressed = NetworkEnergy {
            layers: vec![(0, 0.5), (1, 1.5), (2, 0.5)],
        };
        assert!((ne.saving_vs(&compressed) - 0.5).abs() < 1e-12);
    }
}
