//! Energy models (paper §3): per-weight MAC energy under layer-specific
//! transition statistics, and the tile-level convolution-layer energy.

pub mod layer;
pub mod macmodel;

pub use layer::{LayerEnergy, NetworkEnergy};
pub use macmodel::{
    characterize_layer, transition_energy, uniform_weight_energy, WeightEnergyTable,
};
