//! Energy models (paper §3): per-weight MAC energy under layer-specific
//! transition statistics, the tile-level convolution-layer energy, and
//! the memoized parallel evaluation engine ([`cache`]) the compression
//! hot loops run against.

pub mod cache;
pub mod layer;
pub mod macmodel;

pub use cache::{EnergyEvaluator, EvalLayer, TransitionCostCache};
pub use layer::{LayerEnergy, NetworkEnergy};
pub use macmodel::{
    characterize_layer, characterize_layer_shared, transition_energy, uniform_weight_energy,
    WeightEnergyTable,
};
