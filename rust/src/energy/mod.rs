//! Energy models (paper §3): per-weight MAC energy under layer-specific
//! transition statistics, the tile-level convolution-layer energy, the
//! memoized parallel evaluation engine ([`cache`]) the compression hot
//! loops run against, and the exact-vs-model validation plumbing
//! ([`validate`]) that diffs the model against the gate-level tile-power
//! engine on captured operand streams.

pub mod cache;
pub mod layer;
pub mod macmodel;
pub mod validate;

pub use cache::{EnergyEvaluator, EvalLayer, TransitionCostCache};
pub use layer::{LayerEnergy, NetworkEnergy};
pub use macmodel::{
    characterize_layer, characterize_layer_shared, transition_energy, uniform_weight_energy,
    WeightEnergyTable,
};
pub use validate::{validate_captures, validate_streams, LayerValidation, StreamMeta, ValidationReport};
