//! Per-weight MAC energy characterization (paper §3.1).
//!
//! For every int8 weight code we drive the weight-specialized MAC netlist
//! with synthetic traces sampled from the layer's empirical activation
//! and (grouped) partial-sum transition distributions, and measure
//! average energy/cycle.  The result — a 256-entry `E_ℓ(w)` table per
//! layer — is what the weight-selection algorithm (§4.2) and the layer
//! energy model (§3.2) consume.

use crate::gates::{CapModel, TraceSim};
use crate::mac::{MacNetlist, ACC_BITS, ACT_BITS};
use crate::stats::LayerStats;
use crate::systolic::MacLib;
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::parallel_map;

/// Per-layer, per-weight-code energy table (J / cycle).
#[derive(Clone, Debug)]
pub struct WeightEnergyTable {
    /// Index = code + 128 (code −128 unused: QAT clamps to ±127).
    pub e_per_cycle: [f64; 256],
    /// Idle energy (w = 0, a = 0 stream): pure clock/register floor.
    pub e_idle: f64,
}

impl WeightEnergyTable {
    #[inline]
    pub fn energy(&self, code: i8) -> f64 {
        self.e_per_cycle[(code as i32 + 128) as usize]
    }

    /// Energy/cycle of a clock-gated PE position — tile padding or a
    /// weight inside a structurally-skipped all-zero SB×SB block.  Only
    /// a stub of the clock tree toggles, so this sits well below even
    /// the `w = 0` switching cost.
    #[inline]
    pub fn e_gated(&self) -> f64 {
        self.e_idle * super::layer::GATED_IDLE_FRACTION
    }
}

/// Drive one specialized MAC with an (activation, psum) step trace and
/// return energy per cycle (J).  Shared with [`crate::energy::cache`]'s
/// memoized transition probes.
pub(crate) fn trace_energy(
    mac: &MacNetlist,
    acts: &[i32],
    psums: &[i32],
    cap: &CapModel,
) -> f64 {
    debug_assert_eq!(acts.len(), psums.len());
    let mut sim = TraceSim::new(&mac.netlist);
    let n_in = mac.netlist.inputs.len();
    let mut words = vec![0u64; n_in];
    let mut i = 0;
    while i < acts.len() {
        let chunk = (acts.len() - i).min(64);
        words.iter_mut().for_each(|w| *w = 0);
        for lane in 0..chunk {
            let a = acts[i + lane];
            let p = psums[i + lane];
            for bit in 0..ACT_BITS {
                if (a >> bit) & 1 != 0 {
                    words[bit] |= 1 << lane;
                }
            }
            for bit in 0..ACC_BITS {
                if (p >> bit) & 1 != 0 {
                    words[ACT_BITS + bit] |= 1 << lane;
                }
            }
        }
        sim.run_chunk(&mac.netlist, &words, chunk as u32);
        i += chunk;
    }
    let rep = cap.report(&mac.netlist, &sim);
    rep.energy_per_cycle()
}

/// Characterize `E_ℓ(w)` for all codes from layer statistics.
///
/// `trace_len` controls the synthetic trace length per weight (the paper
/// samples until stable; 512 gives <2 % run-to-run spread in our tests).
pub fn characterize_layer(
    stats: &LayerStats,
    lib: &mut MacLib,
    cap: &CapModel,
    trace_len: usize,
    seed: u64,
    threads: usize,
) -> WeightEnergyTable {
    // Ensure all specializations exist before the parallel section.
    lib.specialize_all(threads);
    characterize_layer_shared(stats, lib, cap, trace_len, seed, threads)
}

/// [`characterize_layer`] against a pre-specialized, shared `MacLib` —
/// the form the coordinator fans out across conv layers (see
/// [`MacLib::specialize_all`]).  Bit-identical to the `&mut` variant:
/// the trace sampling and per-code measurements only depend on `stats`,
/// `seed` and `trace_len`.
pub fn characterize_layer_shared(
    stats: &LayerStats,
    lib: &MacLib,
    cap: &CapModel,
    trace_len: usize,
    seed: u64,
    threads: usize,
) -> WeightEnergyTable {
    // Pre-sample shared traces: the *same* activation/psum streams are
    // applied to every weight so the table isolates the weight effect
    // (matching the paper's fixed-trace per-weight measurements).
    let mut rng = Xoshiro256::new(seed);
    let acts = stats.act.sample_chain(trace_len, &mut rng);
    let psums = stats.psum.sample_chain(trace_len, &mut rng);

    let energies = parallel_map(255, threads, |i| {
        let code = i as i32 - 127;
        let mac = lib.get_cached(code as i8).expect("pre-specialized");
        trace_energy(mac, &acts, &psums, cap)
    });

    let mut e_per_cycle = [0.0f64; 256];
    for (i, &e) in energies.iter().enumerate() {
        e_per_cycle[i + 1] = e; // code -127 at index 1
    }
    e_per_cycle[0] = e_per_cycle[1]; // -128 alias (never produced)

    // Idle: w=0 with an all-zero stream.
    let zeros = vec![0i32; trace_len.min(128)];
    let e_idle = trace_energy(
        lib.get_cached(0).unwrap(),
        &zeros,
        &zeros,
        cap,
    );
    WeightEnergyTable { e_per_cycle, e_idle }
}

/// `E(w)` under *uniform random* transitions (no layer statistics) —
/// the global model prior work uses; also regenerates Fig. 1.
pub fn uniform_weight_energy(
    lib: &mut MacLib,
    cap: &CapModel,
    trace_len: usize,
    seed: u64,
    threads: usize,
) -> WeightEnergyTable {
    let mut rng = Xoshiro256::new(seed);
    let acts: Vec<i32> = (0..trace_len).map(|_| rng.code()).collect();
    let psums: Vec<i32> = (0..trace_len)
        .map(|_| (rng.below(1 << ACC_BITS) as i64 - (1 << (ACC_BITS - 1)) as i64) as i32)
        .collect();
    lib.specialize_all(threads);
    let lib_ref: &MacLib = lib;
    let energies = parallel_map(255, threads, |i| {
        let code = i as i32 - 127;
        let mac = lib_ref.get_cached(code as i8).unwrap();
        trace_energy(mac, &acts, &psums, cap)
    });
    let mut e_per_cycle = [0.0f64; 256];
    for (i, &e) in energies.iter().enumerate() {
        e_per_cycle[i + 1] = e;
    }
    e_per_cycle[0] = e_per_cycle[1];
    let zeros = vec![0i32; 128];
    let e_idle = trace_energy(lib.get_cached(0).unwrap(), &zeros, &zeros, cap);
    WeightEnergyTable { e_per_cycle, e_idle }
}

/// Energy of a single alternating psum transition (p1 ⇄ p2) under a fixed
/// weight and constant activation — the probe behind Fig. 2's
/// power-vs-HD and power-vs-MSB analyses.
pub fn transition_energy(
    lib: &mut MacLib,
    cap: &CapModel,
    weight: i8,
    act: i32,
    p1: i32,
    p2: i32,
    steps: usize,
) -> f64 {
    let mac = lib.get(weight);
    let acts = vec![act; steps];
    let psums: Vec<i32> = (0..steps).map(|i| if i % 2 == 0 { p1 } else { p2 }).collect();
    trace_energy(mac, &acts, &psums, cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ConvCapture;
    use crate::stats::collect;

    fn stats_fixture(seed: u64) -> LayerStats {
        let mut rng = Xoshiro256::new(seed);
        let (m, k, n) = (96, 64, 4);
        let cap = ConvCapture {
            conv_idx: 0,
            m,
            k,
            n,
            x_codes: (0..m * k)
                .map(|_| if rng.below(2) == 0 { 0 } else { rng.code() as i8 })
                .collect(),
            w_codes: (0..k * n).map(|_| rng.code() as i8).collect(),
            s_act: 0.01,
            s_w: 0.01,
        };
        collect(&cap, &mut rng)
    }

    #[test]
    fn table_shape_and_zero_is_cheap() {
        let st = stats_fixture(1);
        let mut lib = MacLib::new();
        let cap = CapModel::default();
        let t = characterize_layer(&st, &mut lib, &cap, 128, 7, 1);
        // Energy positive everywhere (clock floor).
        assert!(t.e_per_cycle[1..].iter().all(|&e| e > 0.0));
        // w = 0 cheapest-or-near-cheapest; much cheaper than w = -127.
        assert!(t.energy(0) < t.energy(-127) * 0.8);
        assert!(t.e_idle <= t.energy(0) + 1e-18);
        // A clock-gated (structurally-skipped) position is cheaper still.
        assert!(t.e_gated() < t.e_idle);
        assert!(t.e_gated() > 0.0);
    }

    #[test]
    fn spread_across_weights_exists() {
        // Fig. 1's premise: meaningful per-weight power variation.
        let st = stats_fixture(2);
        let mut lib = MacLib::new();
        let cap = CapModel::default();
        let t = characterize_layer(&st, &mut lib, &cap, 128, 8, 1);
        let lo = t.e_per_cycle[1..].iter().cloned().fold(f64::MAX, f64::min);
        let hi = t.e_per_cycle[1..].iter().cloned().fold(0.0f64, f64::max);
        assert!(hi / lo > 1.5, "spread {lo}..{hi} too flat");
    }

    #[test]
    fn hd_monotonicity_trend() {
        // Fig. 2a: transitions with larger Hamming distance cost more
        // (on average).  Compare HD=1 vs HD=16 starting from the same base.
        let mut lib = MacLib::new();
        let cap = CapModel::default();
        let base = 0b0101_0101_0101_0101_0101u32 as i32;
        let e_small = transition_energy(&mut lib, &cap, 17, 5, base, base ^ 1, 64);
        let e_large =
            transition_energy(&mut lib, &cap, 17, 5, base, base ^ 0xFFFF, 64);
        assert!(
            e_large > e_small,
            "HD16 {e_large} should exceed HD1 {e_small}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let st = stats_fixture(3);
        let capm = CapModel::default();
        let mut lib = MacLib::new();
        let a = characterize_layer(&st, &mut lib, &capm, 64, 9, 1);
        let b = characterize_layer(&st, &mut lib, &capm, 64, 9, 1);
        assert_eq!(a.e_per_cycle.to_vec(), b.e_per_cycle.to_vec());
    }
}
