//! # wsel — Layer-wise Weight Selection for Power-Efficient NN Acceleration
//!
//! Full-system reproduction of the paper's stack (see `DESIGN.md`):
//!
//! * **Energy modeling (§3)** — a gate-level MAC switching-power model
//!   ([`gates`], [`mac`]), the MSB × Hamming-weight partial-sum grouping
//!   ([`transitions`]), per-layer statistics ([`stats`]), a cycle-level
//!   64×64 weight-stationary systolic array ([`systolic`]) and the
//!   im2col/tile layer-energy model ([`energy`]).  The hot evaluation
//!   path is [`energy::cache::EnergyEvaluator`] — a memoized, parallel
//!   engine (built once per parameter snapshot, bit-identical to the
//!   direct path).  Its companion [`energy::cache::TransitionCostCache`]
//!   memoizes gate-level MAC probe energies per (weight code,
//!   MSB×Hamming group pair) and derives fast first-order `E_ℓ(w)`
//!   tables for candidate sweeps (benched in `perf_hotpaths`; not yet
//!   on the default pipeline path).
//! * **Compression (§4)** — int8 QAT utilities ([`quant`]), the
//!   energy–accuracy co-optimized weight selection ([`selection`]) and the
//!   energy-prioritized layer-wise schedule ([`schedule`]).
//! * **Execution** — AOT-compiled JAX/Pallas graphs run through PJRT
//!   ([`runtime`]); a bit-exact int8 mirror inference engine ([`model`])
//!   feeds the statistics and the systolic simulator; [`coordinator`]
//!   orchestrates the end-to-end pipeline; [`serve`] runs compiled
//!   plans as a long-running service (snapshot registry + async
//!   micro-batching + sustained-load bench); [`data`] generates the
//!   deterministic synthetic-CIFAR workload; [`report`] renders the
//!   paper's tables and figures.
//!
//! The offline toolchain ships no tokio/clap/serde/criterion/proptest, so
//! [`util`], [`testutil`] and [`bench`] provide the needed substrates
//! in-repo (thread pool, CLI, JSON, PRNG, property tests, golden-file
//! regression harness, micro-benches); `vendor/` carries minimal shims
//! for `anyhow` and the `xla` PJRT bindings.  See `rust/README.md` for
//! the evaluator architecture, cache keying and how to bless golden
//! snapshots.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod faults;
pub mod gates;
pub mod mac;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod selection;
pub mod serve;
pub mod stats;
pub mod systolic;
pub mod testutil;
pub mod transitions;
pub mod util;

/// Crate version string (kept in sync with `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
