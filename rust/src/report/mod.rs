//! Paper-style table / figure renderers.
//!
//! Every bench and the `wsel repro` subcommand print their measurements
//! through these helpers so the output lines up with the paper's tables
//! (paper value and measured value side by side).

use crate::util::json::Json;

/// A plain-text table with aligned columns.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Machine-readable form for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "headers",
                Json::arr(self.headers.iter().map(|h| Json::str(h))),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c)))),
                ),
            ),
        ])
    }
}

/// Percent formatting matching the paper ("58.6%").
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Per-layer structural-skip summary table for block-sparse execution:
/// rows of `(conv_idx, blocks_total, blocks_empty, macs_skipped,
/// macs_dense)`.  Takes plain numbers so any layer (engine reports,
/// benches, the CLI) can feed it without coupling `report` to the model
/// types.
pub fn sparsity_table(rows: &[(usize, u64, u64, u64, u64)]) -> Table {
    let mut t = Table::new(
        "Block-sparse structural skip",
        &["conv", "blocks", "empty", "empty%", "MACs skipped", "MAC%"],
    );
    for &(ci, total, empty, skipped, dense) in rows {
        let ef = if total > 0 { empty as f64 / total as f64 } else { 0.0 };
        let mf = if dense > 0 { skipped as f64 / dense as f64 } else { 0.0 };
        t.row(&[
            format!("conv{ci}"),
            total.to_string(),
            empty.to_string(),
            pct(ef),
            skipped.to_string(),
            pct(mf),
        ]);
    }
    t
}

/// An ASCII bar chart (figures in terminal form).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(f64::MIN, f64::max);
    let maxl = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = if maxv > 0.0 {
            ((v / maxv) * width as f64).round() as usize
        } else {
            0
        };
        out.push_str(&format!("{:w$}  {:10.4e}  {}\n", l, v, "#".repeat(n), w = maxl));
    }
    out
}

/// An ASCII heatmap (Fig. 2b / Fig. 3 in terminal form): row-major
/// `bins × bins` values rendered with a density ramp.
pub fn heatmap(title: &str, values: &[f64], bins: usize) -> String {
    assert_eq!(values.len(), bins * bins);
    const RAMP: &[u8] = b" .:-=+*#%@";
    let maxv = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-300);
    let mut out = format!("== {title} ==  (max={maxv:.3e})\n");
    for r in 0..bins {
        for c in 0..bins {
            // Log-ish scaling: sqrt emphasizes the low-mass structure.
            let x = (values[r * bins + c] / maxv).sqrt();
            let idx = ((x * (RAMP.len() - 1) as f64).round() as usize).min(RAMP.len() - 1);
            out.push(RAMP[idx] as char);
        }
        out.push('\n');
    }
    out
}

/// A simple series printer for line-style figures.
pub fn series(title: &str, xs: &[f64], ys: &[f64]) -> String {
    let mut out = format!("== {title} ==\nx\ty\n");
    for (x, y) in xs.iter().zip(ys) {
        out.push_str(&format!("{x:.4}\t{y:.6e}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a   bbbb"));
        let j = t.to_json().to_string();
        assert!(j.contains("\"rows\""));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.586), "58.6%");
    }

    #[test]
    fn sparsity_table_fractions() {
        let t = sparsity_table(&[(0, 8, 2, 128, 1024), (1, 4, 0, 0, 512)]);
        let s = t.render();
        assert!(s.contains("conv0"));
        assert!(s.contains("25.0%"));
        assert!(s.contains("12.5%"));
        assert!(s.contains("conv1"));
        assert!(s.contains("0.0%"));
    }

    #[test]
    fn chart_and_heatmap_shapes() {
        let s = bar_chart("B", &["x".into(), "yy".into()], &[1.0, 2.0], 10);
        assert_eq!(s.lines().count(), 3);
        let hm = heatmap("H", &vec![0.5; 16], 4);
        assert_eq!(hm.lines().count(), 5);
    }
}
