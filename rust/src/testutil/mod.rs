//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `cases(n, seed, |rng| ...)` runs a property over `n` generated cases
//! with deterministic seeding and reports the failing case index on
//! panic, which is what we actually use proptest for in this codebase.
//! Generators live on [`Gen`].

pub mod golden;

use crate::util::rng::Xoshiro256;

/// Shared synthetic energy-table fixture: energy grows linearly with
/// |code| (`(1 + |code|) * quantum`, idle at half the quantum) — the
/// Fig. 1 shape used by tests and benches.  Pass a dyadic quantum
/// (e.g. `2^-50`) when exact cross-platform arithmetic matters.
pub fn linear_energy_table(quantum: f64) -> crate::energy::WeightEnergyTable {
    let mut e = [0.0f64; 256];
    for (i, slot) in e.iter_mut().enumerate() {
        let code = (i as i32 - 128).unsigned_abs() as f64;
        *slot = (1.0 + code) * quantum;
    }
    crate::energy::WeightEnergyTable {
        e_per_cycle: e,
        e_idle: quantum * 0.5,
    }
}

/// Deterministic case runner.  On panic, re-raises with the case index
/// and per-case seed so the failure reproduces with `case_seed`.
pub fn cases<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(n: usize, seed: u64, prop: F) {
    for i in 0..n {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Xoshiro256::new(case_seed),
            };
            prop(&mut g);
        });
        if let Err(e) = result {
            eprintln!("property failed at case {i}/{n} (case_seed = {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Value generators over a deterministic PRNG.
pub struct Gen {
    pub rng: Xoshiro256,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn i8_code(&mut self) -> i8 {
        self.rng.code() as i8
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_codes(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.i8_code()).collect()
    }

    /// Non-empty sorted unique code set of size <= max_k.
    pub fn weight_set(&mut self, max_k: usize) -> crate::quant::WeightSet {
        let k = self.usize_in(1, max_k);
        let codes: Vec<i32> = (0..k).map(|_| self.rng.code()).collect();
        crate::quant::WeightSet::new(codes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases_deterministically() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        cases(5, 42, |g| {
            let v = g.usize_in(0, 1000);
            assert!(v <= 1000);
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 5);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        cases(10, 1, |g| {
            let v = g.usize_in(0, 0);
            assert!(v == 1, "always fails: v = {v}");
        });
    }

    #[test]
    fn generators_in_range() {
        cases(50, 7, |g| {
            let c = g.i8_code();
            assert!((-127..=127).contains(&(c as i32)));
            let f = g.f32_in(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&f));
            let s = g.weight_set(8);
            assert!(!s.is_empty() && s.len() <= 8);
        });
    }
}
