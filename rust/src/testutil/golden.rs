//! Golden-file regression harness.
//!
//! Snapshots live under `rust/tests/golden/*.json`.  A check parses the
//! stored JSON and compares it structurally against the actual value —
//! numbers with a tight relative tolerance (`1e-9` by default, far
//! below any legitimate modeling change), everything else exactly.
//!
//! Blessing: run with `WSEL_BLESS=1` to (re)write the snapshot instead
//! of comparing, e.g.
//!
//! ```text
//! WSEL_BLESS=1 cargo test -q --test golden_model
//! ```
//!
//! A missing golden file fails the check (that is the harness's whole
//! point: numbers cannot drift — or appear — silently); the failure
//! message says how to bless.
//!
//! Snapshots are written through [`crate::util::artifact`] (atomic
//! rename + checksummed header), so a kill mid-bless cannot leave a
//! half-written golden, and bit-rot in a blessed file is detected at
//! read time with a pinpointed error.  Goldens committed before the
//! artifact layer existed are headerless and load as legacy payloads.

use crate::util::artifact;
use crate::util::json::Json;
use std::path::PathBuf;

/// Default relative tolerance for numeric comparisons.
pub const DEFAULT_RTOL: f64 = 1e-9;

/// Directory holding the golden snapshots.
pub fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// True when bless mode is active (`WSEL_BLESS=1`).
pub fn blessing() -> bool {
    std::env::var("WSEL_BLESS").map(|v| v == "1").unwrap_or(false)
}

/// Compare `actual` against the stored snapshot `<name>.json`, or
/// rewrite the snapshot in bless mode.  Panics with a pinpointed path
/// on mismatch.
pub fn check(name: &str, actual: &Json) {
    check_with_rtol(name, actual, DEFAULT_RTOL)
}

/// Like [`check`], but a *missing* snapshot is written (with a loud
/// warning) instead of failing.  For artifact-gated tests whose
/// snapshots cannot ship with the repo (they depend on locally built
/// artifacts): the first run in a fresh artifact build bootstraps the
/// baseline, every later run pins against it.
pub fn check_or_init(name: &str, actual: &Json) {
    check_or_init_with_rtol(name, actual, DEFAULT_RTOL)
}

/// [`check_or_init`] with an explicit relative tolerance — for
/// snapshots of values that route through `libm` (`exp`/`ln` in a
/// training loss), whose last-ulp behavior may differ across hosts.
pub fn check_or_init_with_rtol(name: &str, actual: &Json, rtol: f64) {
    let path = golden_dir().join(format!("{name}.json"));
    if !blessing() && !path.exists() {
        artifact::write_json_atomic(&path, actual).expect("write golden");
        eprintln!(
            "BOOTSTRAPPED golden {} (first run in this environment); \
             subsequent runs will pin against it",
            path.display()
        );
        return;
    }
    check_with_rtol(name, actual, rtol)
}

/// [`check`] with an explicit relative tolerance (0.0 = exact).
pub fn check_with_rtol(name: &str, actual: &Json, rtol: f64) {
    let path = golden_dir().join(format!("{name}.json"));
    if blessing() {
        artifact::write_json_atomic(&path, actual).expect("write golden");
        eprintln!("BLESSED {}", path.display());
        return;
    }
    if !path.exists() {
        panic!(
            "golden snapshot {} missing; run with WSEL_BLESS=1 to create it",
            path.display()
        );
    }
    // artifact::load verifies the checksummed header on blessed files
    // (corruption fails here with path + reason) and passes committed
    // pre-artifact goldens through as legacy payloads.
    let payload = artifact::load(&path)
        .unwrap_or_else(|e| panic!("golden snapshot rejected: {e:?}"));
    let text = String::from_utf8(payload).unwrap_or_else(|_| {
        panic!("golden snapshot {} is not UTF-8", path.display())
    });
    let want = Json::parse(text.trim()).unwrap_or_else(|e| {
        panic!("golden snapshot {} unparsable: {e}", path.display())
    });
    if let Err(diff) = approx_eq(&want, actual, rtol, "$") {
        panic!(
            "golden mismatch vs {} at {}\n  (bless with WSEL_BLESS=1 after verifying the change is intended)",
            path.display(),
            diff
        );
    }
}

/// Structural comparison: numbers within `rtol` (relative, with a tiny
/// absolute floor for values near zero), everything else exact.
/// Returns `Err(description)` naming the first diverging path.
pub fn approx_eq(want: &Json, got: &Json, rtol: f64, path: &str) -> Result<(), String> {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = rtol * a.abs().max(b.abs()) + 1e-300;
            if (a - b).abs() <= tol || (a.is_nan() && b.is_nan()) {
                Ok(())
            } else {
                Err(format!("{path}: {a} != {b} (rtol {rtol})"))
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                return Err(format!("{path}: array len {} != {}", a.len(), b.len()));
            }
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                approx_eq(x, y, rtol, &format!("{path}[{i}]"))?;
            }
            Ok(())
        }
        (Json::Obj(a), Json::Obj(b)) => {
            if a.len() != b.len() {
                let ka: Vec<_> = a.keys().collect();
                let kb: Vec<_> = b.keys().collect();
                return Err(format!("{path}: keys {ka:?} != {kb:?}"));
            }
            for (k, x) in a {
                let y = b
                    .get(k)
                    .ok_or_else(|| format!("{path}: missing key {k:?}"))?;
                approx_eq(x, y, rtol, &format!("{path}.{k}"))?;
            }
            Ok(())
        }
        (a, b) => {
            if a == b {
                Ok(())
            } else {
                Err(format!("{path}: {a} != {b}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_tolerates_and_rejects() {
        let a = Json::parse(r#"{"x": [1.0, 2.0], "s": "hi"}"#).unwrap();
        let b = Json::parse(r#"{"x": [1.0000000000001, 2.0], "s": "hi"}"#).unwrap();
        assert!(approx_eq(&a, &b, 1e-9, "$").is_ok());
        let c = Json::parse(r#"{"x": [1.01, 2.0], "s": "hi"}"#).unwrap();
        let err = approx_eq(&a, &c, 1e-9, "$").unwrap_err();
        assert!(err.contains("$.x[0]"), "{err}");
        let d = Json::parse(r#"{"x": [1.0, 2.0], "s": "no"}"#).unwrap();
        assert!(approx_eq(&a, &d, 1e-9, "$").is_err());
    }

    #[test]
    fn exact_mode_is_strict() {
        let a = Json::Num(1.0);
        let b = Json::Num(1.0 + f64::EPSILON);
        assert!(approx_eq(&a, &b, 0.0, "$").is_err());
        assert!(approx_eq(&a, &a, 0.0, "$").is_ok());
    }

    #[test]
    fn golden_dir_is_under_tests() {
        assert!(golden_dir().ends_with("rust/tests/golden"));
    }
}
