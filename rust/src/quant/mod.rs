//! Symmetric int8 quantization, candidate-set projection and magnitude
//! pruning — the exact mirror of the Python QAT scheme in
//! `python/compile/model.py` (single source of truth for constants is the
//! artifact manifest; these must stay in lock-step or the runtime
//! cross-check test fails).

pub const QMAX: i32 = 127;
/// Maximum candidate-set cardinality (the "safe initial set" size, §4.2).
pub const KSET: usize = 32;
/// Sentinel used for invalid candidate slots in the padded set tables.
pub const SET_SENTINEL: f32 = 1.0e9;

/// Per-tensor symmetric scale: `max|w| / 127` (with epsilon floor).
pub fn weight_scale(w: &[f32]) -> f32 {
    let m = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    (m / QMAX as f32).max(1e-12)
}

/// Quantize a float to an int8 code under scale `s`.
#[inline]
pub fn quantize(v: f32, s: f32) -> i32 {
    let q = (v / s).round();
    q.clamp(-(QMAX as f32), QMAX as f32) as i32
}

/// Dequantize a code.
#[inline]
pub fn dequantize(q: i32, s: f32) -> f32 {
    q as f32 * s
}

/// Quantize a tensor to codes.
pub fn quantize_tensor(w: &[f32], s: f32) -> Vec<i8> {
    w.iter().map(|&v| quantize(v, s) as i8).collect()
}

/// A restricted weight-value set: sorted unique int8 codes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WeightSet {
    codes: Vec<i32>,
}

impl WeightSet {
    /// Build from arbitrary codes (sorted + deduped).  Panics if empty.
    pub fn new(mut codes: Vec<i32>) -> Self {
        assert!(!codes.is_empty(), "weight set cannot be empty");
        assert!(codes.iter().all(|&c| (-QMAX..=QMAX).contains(&c)));
        codes.sort_unstable();
        codes.dedup();
        Self { codes }
    }

    /// The full int8 code range (no restriction), cardinality 255.
    pub fn full() -> Self {
        Self {
            codes: (-QMAX..=QMAX).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    pub fn codes(&self) -> &[i32] {
        &self.codes
    }

    pub fn contains(&self, c: i32) -> bool {
        self.codes.binary_search(&c).is_ok()
    }

    /// Nearest member to code `q` (ties resolve to the smaller member,
    /// matching `argmin` over the ascending padded table on the JAX side).
    pub fn project(&self, q: i32) -> i32 {
        match self.codes.binary_search(&q) {
            Ok(_) => q,
            Err(pos) => {
                if pos == 0 {
                    self.codes[0]
                } else if pos == self.codes.len() {
                    self.codes[pos - 1]
                } else {
                    let lo = self.codes[pos - 1];
                    let hi = self.codes[pos];
                    if (q - lo) <= (hi - q) {
                        lo
                    } else {
                        hi
                    }
                }
            }
        }
    }

    /// Remove a code, returning a new set.  Panics if it would empty the
    /// set or the code is absent.
    pub fn without(&self, c: i32) -> Self {
        assert!(self.contains(c), "code {c} not in set");
        assert!(self.len() > 1, "cannot empty a weight set");
        Self {
            codes: self.codes.iter().copied().filter(|&x| x != c).collect(),
        }
    }

    /// Padded `[KSET]` f32 table (ascending codes then sentinels) in the
    /// layout the AOT graphs expect.
    pub fn padded_table(&self) -> [f32; KSET] {
        assert!(self.len() <= KSET, "set larger than table: {}", self.len());
        let mut t = [SET_SENTINEL; KSET];
        for (i, &c) in self.codes.iter().enumerate() {
            t[i] = c as f32;
        }
        t
    }
}

/// Magnitude pruning: zero-mask the `ratio` fraction of smallest-|w|
/// entries.  Returns a 0/1 mask of `w.len()`.
///
/// Ties at the threshold are broken by index order (deterministic), and
/// exactly `floor(ratio * n)` entries are pruned.
pub fn magnitude_mask(w: &[f32], ratio: f64) -> Vec<f32> {
    let n = w.len();
    let n_prune = ((n as f64) * ratio).floor() as usize;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        w[a].abs()
            .partial_cmp(&w[b].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![1.0f32; n];
    for &i in idx.iter().take(n_prune) {
        mask[i] = 0.0;
    }
    mask
}

/// Apply mask and quantize-project a weight tensor exactly as the QAT
/// forward does: `w_eff = w*mask; s = max|w_eff|/127; q = clip(round);
/// q' = project(q)`.  Returns (codes, scale).
pub fn quantize_restricted(
    w: &[f32],
    mask: Option<&[f32]>,
    set: Option<&WeightSet>,
) -> (Vec<i8>, f32) {
    let w_eff: Vec<f32> = match mask {
        Some(m) => w.iter().zip(m).map(|(&v, &mv)| v * mv).collect(),
        None => w.to_vec(),
    };
    let s = weight_scale(&w_eff);
    let codes: Vec<i8> = w_eff
        .iter()
        .map(|&v| {
            let q = quantize(v, s);
            match set {
                Some(cs) => cs.project(q) as i8,
                None => q as i8,
            }
        })
        .collect();
    (codes, s)
}

/// Histogram of code usage (|code| -> count), used by the joint
/// energy+usage score of the safe initial set (§4.2.1).
pub fn code_usage(codes: &[i8]) -> [u64; 256] {
    let mut h = [0u64; 256];
    for &c in codes {
        h[(c as i32 + 128) as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn quantize_roundtrip_within_step() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..200 {
            let s = rng.range_f32(1e-4, 0.1);
            let v = rng.range_f32(-10.0, 10.0);
            let q = quantize(v, s);
            let back = dequantize(q, s);
            let clipped = v.clamp(-(QMAX as f32) * s, QMAX as f32 * s);
            assert!(
                (back - clipped).abs() <= s * 0.5 + 1e-6,
                "v={v} s={s} q={q} back={back}"
            );
        }
    }

    #[test]
    fn projection_nearest_property() {
        // Property: projection returns a member minimizing |q - c|.
        let mut rng = Xoshiro256::new(2);
        for _ in 0..200 {
            let n = 1 + rng.below(20) as usize;
            let codes: Vec<i32> = (0..n).map(|_| rng.code()).collect();
            let set = WeightSet::new(codes);
            for _ in 0..50 {
                let q = rng.code();
                let p = set.project(q);
                assert!(set.contains(p));
                let best = set
                    .codes()
                    .iter()
                    .map(|&c| (q - c).abs())
                    .min()
                    .unwrap();
                assert_eq!((q - p).abs(), best);
            }
        }
    }

    #[test]
    fn projection_idempotent() {
        let set = WeightSet::new(vec![-100, -3, 0, 7, 90]);
        for q in -127..=127 {
            let p = set.project(q);
            assert_eq!(set.project(p), p);
        }
    }

    #[test]
    fn mask_prunes_exact_count_and_smallest() {
        let w = vec![0.5, -0.1, 0.9, 0.05, -0.7, 0.2];
        let mask = magnitude_mask(&w, 0.5);
        assert_eq!(mask.iter().filter(|&&m| m == 0.0).count(), 3);
        // The three smallest magnitudes are 0.05, 0.1, 0.2.
        assert_eq!(mask, vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn mask_ratio_extremes() {
        let w = vec![1.0, 2.0, 3.0];
        assert!(magnitude_mask(&w, 0.0).iter().all(|&m| m == 1.0));
        // ratio 1.0 prunes everything.
        assert!(magnitude_mask(&w, 1.0).iter().all(|&m| m == 0.0));
    }

    #[test]
    fn restricted_quantization_lands_in_set() {
        let mut rng = Xoshiro256::new(3);
        let w: Vec<f32> = (0..500).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mask = magnitude_mask(&w, 0.3);
        let set = WeightSet::new(vec![-90, -40, -10, 0, 10, 40, 90]);
        let (codes, s) = quantize_restricted(&w, Some(&mask), Some(&set));
        assert!(s > 0.0);
        for (&c, &m) in codes.iter().zip(&mask) {
            assert!(set.contains(c as i32));
            if m == 0.0 {
                // Pruned weights quantize to 0 and 0 is projected within
                // the set; with 0 in the set they stay 0.
                assert_eq!(c, 0);
            }
        }
    }

    #[test]
    fn padded_table_layout() {
        let set = WeightSet::new(vec![5, -5, 0]);
        let t = set.padded_table();
        assert_eq!(&t[..3], &[-5.0, 0.0, 5.0]);
        assert!(t[3..].iter().all(|&v| v == SET_SENTINEL));
    }

    #[test]
    fn usage_histogram_counts() {
        let codes: Vec<i8> = vec![0, 0, 5, -5, 5];
        let h = code_usage(&codes);
        assert_eq!(h[128], 2);
        assert_eq!(h[133], 2);
        assert_eq!(h[123], 1);
    }
}
