//! Long-running inference service over compiled [`Plan`]s.
//!
//! The IR/engine split compiles a plan once and executes it forever;
//! this module is the "forever" part — the first serving (rather than
//! batch-offline) surface of the crate:
//!
//! * [`registry`] — a [`SnapshotRegistry`] of named model variants
//!   (spec + params artifacts loaded through [`crate::util::artifact`],
//!   or compiled in-process from a [`CompressionState`]).  Each variant
//!   holds one compiled [`ParallelEngine`] behind an `Arc`: variants
//!   hot-install and evict by name while in-flight waves keep their own
//!   reference, so a swap never interrupts running work.
//! * [`batcher`] — a [`MicroBatcher`] that coalesces concurrent
//!   single-image requests into *waves* for
//!   [`ParallelEngine::forward_wave`] under a
//!   [`BatchPolicy`]`{ max_batch, max_wait_us }`, built on
//!   `std::sync::mpsc` + condvar tickets atop the existing scoped
//!   thread pool (no new dependencies).  Results are delivered
//!   per-request as `Result`, so a [`PoisonedBatch`] degrades the one
//!   wave that panicked — the service keeps serving.
//! * [`bench`] — a seeded sustained-load driver (Poisson arrivals,
//!   open-loop latency accounting) recording p50/p95/p99 latency and
//!   images/s per (variant, rate, policy) cell; `wsel serve-bench` and
//!   the `perf_hotpaths` serving stage both run it and emit
//!   `BENCH_serving.json` atomically.
//!
//! Determinism contract: images are independent and conv accumulation
//! is exact i32, so every request's logits are bit-identical to a
//! single-image [`ParallelEngine::forward_plain`] of the same input —
//! at any thread count, wave packing and arrival order (pinned in
//! `rust/tests/serving.rs`).
//!
//! [`Plan`]: crate::model::ir::Plan
//! [`ParallelEngine`]: crate::model::ParallelEngine
//! [`ParallelEngine::forward_wave`]: crate::model::ParallelEngine::forward_wave
//! [`CompressionState`]: crate::selection::CompressionState
//! [`PoisonedBatch`]: crate::util::threadpool::PoisonedBatch

pub mod batcher;
pub mod bench;
pub mod registry;

pub use batcher::{BatchPolicy, MicroBatcher, Reply, SubmitHandle, Ticket};
pub use bench::{run_serve_bench, CellResult, ServeBenchCfg};
pub use registry::{ModelVariant, SnapshotRegistry};

/// Per-request serving failure.  Every variant leaves the service
/// itself healthy: the next wave is unaffected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// No variant under that name is currently installed.
    UnknownModel(String),
    /// Submitted image had the wrong element count.
    BadInput { expected: usize, got: usize },
    /// A worker panicked inside this request's wave; the structured
    /// [`PoisonedBatch`](crate::util::threadpool::PoisonedBatch)
    /// message is carried verbatim.
    WavePoisoned(String),
    /// The batcher was shut down before this request ran.
    Shutdown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownModel(name) => write!(f, "unknown model variant `{name}`"),
            ServeError::BadInput { expected, got } => {
                write!(f, "bad input: expected {expected} elements, got {got}")
            }
            ServeError::WavePoisoned(msg) => write!(f, "wave poisoned: {msg}"),
            ServeError::Shutdown => write!(f, "batcher shut down"),
        }
    }
}

impl std::error::Error for ServeError {}
