//! Async dynamic micro-batcher: single-image requests in, engine waves
//! out.
//!
//! Requests flow over an `std::sync::mpsc` channel to one dispatcher
//! thread.  The dispatcher blocks for the first request of a wave, then
//! keeps the wave open until either `max_batch` requests have arrived
//! or `max_wait_us` has elapsed since the wave opened — the classic
//! dynamic-batching policy: `max_wait_us = 0` degrades to batch=1
//! serving, large values trade first-request latency for wave
//! occupancy.  Each closed wave is grouped by model name (arrival order
//! preserved within a group) and executed through
//! [`ModelVariant::run_wave`](super::registry::ModelVariant::run_wave),
//! which fans images out over the engine's scoped thread pool.
//!
//! Delivery is per-request: every [`Ticket`] is a one-shot
//! `Mutex<Option<..>> + Condvar` slot the dispatcher fills exactly
//! once.  A [`PoisonedBatch`](crate::util::threadpool::PoisonedBatch)
//! from one wave therefore fails that wave's requests with
//! [`ServeError::WavePoisoned`] and nothing else — the dispatcher loop
//! and every other wave keep running.  A request that can never run
//! (dropped channel, shutdown race) resolves to [`ServeError::Shutdown`]
//! rather than hanging its caller: the reply slot is filled on drop if
//! still empty.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::registry::{SnapshotRegistry, IMG_ELEMS};
use super::ServeError;

/// Wave-closing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum images per wave (≥ 1).
    pub max_batch: usize,
    /// How long a wave stays open for co-travelers after its first
    /// request arrives, in microseconds.
    pub max_wait_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait_us: 200,
        }
    }
}

impl BatchPolicy {
    /// No coalescing: every request is its own wave (the serving
    /// baseline the bench compares against).
    pub fn batch1() -> Self {
        Self {
            max_batch: 1,
            max_wait_us: 0,
        }
    }

    pub fn label(&self) -> String {
        if self.max_batch <= 1 {
            "batch1".to_string()
        } else {
            format!("b{}w{}us", self.max_batch, self.max_wait_us)
        }
    }
}

/// One-shot reply slot shared between a [`Ticket`] and the dispatcher.
struct TicketInner {
    slot: Mutex<Option<Reply>>,
    cv: Condvar,
}

/// The dispatcher's answer to one request.
#[derive(Clone, Debug)]
pub struct Reply {
    pub result: Result<Vec<f32>, ServeError>,
    /// When the reply was produced (wave completion) — recorded at fill
    /// time so latency accounting is independent of when the caller
    /// gets around to [`Ticket::wait`].
    pub done_at: Instant,
}

/// Caller's handle on one submitted request.
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    fn pair() -> (Ticket, Arc<TicketInner>) {
        let inner = Arc::new(TicketInner {
            slot: Mutex::new(None),
            cv: Condvar::new(),
        });
        (
            Ticket {
                inner: Arc::clone(&inner),
            },
            inner,
        )
    }

    fn resolved(result: Result<Vec<f32>, ServeError>) -> Ticket {
        let (t, inner) = Ticket::pair();
        fill(&inner, result);
        t
    }

    /// Block until the reply arrives.
    pub fn wait(&self) -> Reply {
        let mut slot = self.inner.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.as_ref() {
                return r.clone();
            }
            slot = self.inner.cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking probe.
    pub fn try_take(&self) -> Option<Reply> {
        self.inner.slot.lock().unwrap().clone()
    }
}

/// Fill a reply slot if still empty (first writer wins — makes the
/// drop-safety net below a no-op on already-answered requests).
fn fill(inner: &TicketInner, result: Result<Vec<f32>, ServeError>) {
    let mut slot = inner.slot.lock().unwrap();
    if slot.is_none() {
        *slot = Some(Reply {
            result,
            done_at: Instant::now(),
        });
        inner.cv.notify_all();
    }
}

struct Request {
    model: String,
    image: Vec<f32>,
    ticket: Arc<TicketInner>,
}

impl Drop for Request {
    fn drop(&mut self) {
        // Safety net: a request dropped without an answer (lost in a
        // shutdown race, dispatcher gone) must not hang its caller.
        fill(&self.ticket, Err(ServeError::Shutdown));
    }
}

enum Msg {
    Req(Request),
    /// Finish queued work, then exit the dispatch loop.
    Shutdown,
}

/// Cloneable submission endpoint (for concurrent submitter threads).
#[derive(Clone)]
pub struct SubmitHandle {
    tx: Sender<Msg>,
}

impl SubmitHandle {
    /// Submit one image for `model`.  Never blocks on the wave; input
    /// validation failures and a shut-down batcher resolve the ticket
    /// immediately.
    pub fn submit(&self, model: &str, image: &[f32]) -> Ticket {
        if image.len() != IMG_ELEMS {
            return Ticket::resolved(Err(ServeError::BadInput {
                expected: IMG_ELEMS,
                got: image.len(),
            }));
        }
        let (ticket, inner) = Ticket::pair();
        let req = Request {
            model: model.to_string(),
            image: image.to_vec(),
            ticket: inner,
        };
        // A send failure drops `req`, whose Drop resolves the ticket to
        // Shutdown.
        let _ = self.tx.send(Msg::Req(req));
        ticket
    }
}

/// Counters the dispatcher maintains (all monotonically increasing).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatcherStats {
    pub requests: u64,
    pub waves: u64,
    /// Σ wave sizes — `batched_images / waves` is the mean occupancy.
    pub batched_images: u64,
    pub poisoned_waves: u64,
    pub unknown_model: u64,
}

impl BatcherStats {
    pub fn mean_wave(&self) -> f64 {
        if self.waves == 0 {
            0.0
        } else {
            self.batched_images as f64 / self.waves as f64
        }
    }
}

/// The micro-batching service: one dispatcher thread draining a
/// request channel into engine waves.
pub struct MicroBatcher {
    handle: SubmitHandle,
    worker: Option<JoinHandle<BatcherStats>>,
}

impl MicroBatcher {
    /// Spawn the dispatcher over `registry` under `policy`.
    pub fn new(registry: Arc<SnapshotRegistry>, policy: BatchPolicy) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("wsel-serve-batcher".to_string())
            .spawn(move || dispatch(rx, registry, policy))
            .expect("spawn batcher dispatcher");
        Self {
            handle: SubmitHandle { tx },
            worker: Some(worker),
        }
    }

    /// A cloneable submission endpoint.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// Submit one image (see [`SubmitHandle::submit`]).
    pub fn submit(&self, model: &str, image: &[f32]) -> Ticket {
        self.handle.submit(model, image)
    }

    /// Finish all queued requests, stop the dispatcher and return its
    /// counters.  Outstanding [`SubmitHandle`]s stay valid but every
    /// later submission resolves to [`ServeError::Shutdown`].
    pub fn shutdown(mut self) -> BatcherStats {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> BatcherStats {
        let _ = self.handle.tx.send(Msg::Shutdown);
        match self.worker.take() {
            Some(w) => w.join().expect("batcher dispatcher panicked"),
            None => BatcherStats::default(),
        }
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        if self.worker.is_some() {
            self.shutdown_inner();
        }
    }
}

fn dispatch(rx: Receiver<Msg>, registry: Arc<SnapshotRegistry>, policy: BatchPolicy) -> BatcherStats {
    let max_batch = policy.max_batch.max(1);
    let mut stats = BatcherStats::default();
    loop {
        // Block for the wave's first request.
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => {
                drain_remaining(&rx, &registry, max_batch, &mut stats);
                return stats;
            }
        };
        let mut wave = vec![first];
        let deadline = Instant::now() + Duration::from_micros(policy.max_wait_us);
        let mut stop = false;
        while wave.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                // Past the deadline: take only what is already queued.
                match rx.try_recv() {
                    Ok(Msg::Req(r)) => wave.push(r),
                    Ok(Msg::Shutdown) | Err(TryRecvError::Disconnected) => {
                        stop = true;
                        break;
                    }
                    Err(TryRecvError::Empty) => break,
                }
            } else {
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Req(r)) => wave.push(r),
                    Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                        stop = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => {} // re-check at deadline
                }
            }
        }
        execute_wave(&registry, wave, &mut stats);
        if stop {
            drain_remaining(&rx, &registry, max_batch, &mut stats);
            return stats;
        }
    }
}

/// Shutdown path: execute whatever is still queued (in max_batch-sized
/// waves, no waiting), then return.  Requests that race past this drain
/// are answered by `Request::drop` once the receiver goes away.
fn drain_remaining(
    rx: &Receiver<Msg>,
    registry: &SnapshotRegistry,
    max_batch: usize,
    stats: &mut BatcherStats,
) {
    let mut wave: Vec<Request> = Vec::new();
    loop {
        match rx.try_recv() {
            Ok(Msg::Req(r)) => {
                wave.push(r);
                if wave.len() >= max_batch {
                    execute_wave(registry, std::mem::take(&mut wave), stats);
                }
            }
            Ok(Msg::Shutdown) => {}
            Err(_) => break,
        }
    }
    if !wave.is_empty() {
        execute_wave(registry, wave, stats);
    }
}

/// Run one closed wave: group by model (arrival order kept within each
/// group), execute each group, deliver per-request results.
fn execute_wave(registry: &SnapshotRegistry, wave: Vec<Request>, stats: &mut BatcherStats) {
    if wave.is_empty() {
        return;
    }
    stats.requests += wave.len() as u64;
    stats.waves += 1;
    stats.batched_images += wave.len() as u64;
    let mut groups: Vec<(String, Vec<Request>)> = Vec::new();
    for req in wave {
        match groups.iter_mut().find(|(m, _)| *m == req.model) {
            Some((_, g)) => g.push(req),
            None => groups.push((req.model.clone(), vec![req])),
        }
    }
    for (model, group) in groups {
        let Some(variant) = registry.get(&model) else {
            stats.unknown_model += group.len() as u64;
            for req in &group {
                fill(&req.ticket, Err(ServeError::UnknownModel(model.clone())));
            }
            continue;
        };
        let imgs: Vec<&[f32]> = group.iter().map(|r| r.image.as_slice()).collect();
        match variant.run_wave(&imgs) {
            Ok(outs) => {
                debug_assert_eq!(outs.len(), group.len());
                for (req, logits) in group.iter().zip(outs) {
                    fill(&req.ticket, Ok(logits));
                }
            }
            Err(pb) => {
                stats.poisoned_waves += 1;
                let msg = pb.to_string();
                for req in &group {
                    fill(&req.ticket, Err(ServeError::WavePoisoned(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::tests_support::tiny_spec;
    use crate::model::{ParallelEngine, Params, QuantConfig};
    use crate::serve::registry::ModelVariant;

    fn registry_with(name: &str, seed: u64) -> Arc<SnapshotRegistry> {
        let reg = Arc::new(SnapshotRegistry::new());
        let spec = tiny_spec();
        let p = Params::random(&spec, seed);
        let qc = QuantConfig::float(&spec);
        reg.install(ModelVariant::new(
            name,
            ParallelEngine::new(&spec, &p.tensors, &qc, 2),
        ));
        reg
    }

    fn image(seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        (0..IMG_ELEMS).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn bad_input_resolves_immediately() {
        let b = MicroBatcher::new(registry_with("m", 1), BatchPolicy::default());
        let t = b.submit("m", &[0.0f32; 7]);
        match t.wait().result {
            Err(ServeError::BadInput { expected, got }) => {
                assert_eq!(expected, IMG_ELEMS);
                assert_eq!(got, 7);
            }
            other => panic!("want BadInput, got {other:?}"),
        }
        // A malformed request never reaches the dispatcher.
        let stats = b.shutdown();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn submit_after_shutdown_resolves_shutdown() {
        let b = MicroBatcher::new(registry_with("m", 2), BatchPolicy::default());
        let h = b.handle();
        b.shutdown();
        let t = h.submit("m", &image(3));
        assert_eq!(t.wait().result, Err(ServeError::Shutdown));
    }

    #[test]
    fn queued_requests_survive_shutdown() {
        // Everything queued before shutdown() still gets a real answer.
        let b = MicroBatcher::new(
            registry_with("m", 4),
            BatchPolicy {
                max_batch: 4,
                max_wait_us: 0,
            },
        );
        let img = image(5);
        let tickets: Vec<Ticket> = (0..9).map(|_| b.submit("m", &img)).collect();
        let stats = b.shutdown();
        assert_eq!(stats.requests, 9);
        for t in &tickets {
            assert!(t.wait().result.is_ok());
        }
    }

    #[test]
    fn mean_occupancy_exceeds_one_under_burst() {
        // Submit a burst with a generous window: the dispatcher must
        // coalesce, not serve 8 single-image waves.
        let b = MicroBatcher::new(
            registry_with("m", 4),
            BatchPolicy {
                max_batch: 4,
                max_wait_us: 200_000,
            },
        );
        let img = image(5);
        let tickets: Vec<Ticket> = (0..8).map(|_| b.submit("m", &img)).collect();
        for t in &tickets {
            assert!(t.wait().result.is_ok());
        }
        let stats = b.shutdown();
        assert_eq!(stats.requests, 8);
        assert!(stats.waves >= 2, "waves={}", stats.waves);
        assert!(stats.waves < 8, "no coalescing happened: {}", stats.waves);
    }

    #[test]
    fn batch1_policy_means_one_wave_per_request() {
        let b = MicroBatcher::new(registry_with("m", 6), BatchPolicy::batch1());
        let img = image(7);
        let tickets: Vec<Ticket> = (0..5).map(|_| b.submit("m", &img)).collect();
        for t in &tickets {
            assert!(t.wait().result.is_ok());
        }
        let stats = b.shutdown();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.waves, 5);
        assert_eq!(stats.batched_images, 5);
    }
}
