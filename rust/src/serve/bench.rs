//! Seeded sustained-load serving bench: Poisson arrivals, open-loop
//! latency accounting, per-cell percentiles.
//!
//! One *cell* = `(variant, arrival rate, batch policy)`.  The driver
//! pre-draws exponential inter-arrival gaps from a seeded
//! [`Xoshiro256`], submits each request at its *scheduled* arrival time
//! and measures latency from that scheduled instant to wave completion
//! — the open-loop discipline, so a backed-up service shows its real
//! queueing delay instead of the coordinated-omission artifact a
//! closed submit-wait loop would produce.  `rate = ∞` ("saturated")
//! submits with zero gaps and measures peak images/s — that is the
//! cell pair the micro-batching ≥2× acceptance gate compares
//! (`max_batch ≥ 8` vs batch=1 at the same thread count).
//!
//! [`run_serve_bench`] drives the standard dense + ≥70%-block-sparse
//! lenet5 variant pair over a rate × policy grid and returns the
//! machine-readable report (`BENCH_serving.json` shape) plus the raw
//! cells; `wsel serve-bench` and the `perf_hotpaths` serving stage are
//! thin wrappers over it.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, MicroBatcher, Ticket};
use super::registry::{ModelVariant, SnapshotRegistry, IMG_ELEMS};
use super::ServeError;
use crate::bench::percentile;
use crate::model::kernels::SB;
use crate::model::{ConvOp, ModelSpec, ParallelEngine, Params, QuantConfig};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use anyhow::Result;

/// Grid for one [`run_serve_bench`] invocation.
#[derive(Clone, Debug)]
pub struct ServeBenchCfg {
    /// Finite Poisson arrival rates, requests/s.
    pub rates: Vec<f64>,
    /// Also run a zero-gap ("saturated") rate per (variant, policy) —
    /// the peak-throughput cell the ≥2× batching gate reads.
    pub include_saturated: bool,
    /// Requests per cell.
    pub requests: usize,
    /// Coalescing policy under test (compared against
    /// [`BatchPolicy::batch1`]).
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub seed: u64,
    pub threads: usize,
}

impl ServeBenchCfg {
    /// Full preset (CLI default).
    pub fn standard(threads: usize) -> Self {
        Self {
            rates: vec![200.0, 500.0, 1000.0],
            include_saturated: true,
            requests: 2000,
            max_batch: 8,
            max_wait_us: 200,
            seed: 0x5EED,
            threads,
        }
    }

    /// Smoke preset: small enough for `verify.sh --quick`, still ≥3
    /// rates × 2 variants so the emitted JSON has the full shape.
    pub fn quick(threads: usize) -> Self {
        Self {
            rates: vec![500.0, 2000.0],
            include_saturated: true,
            requests: 60,
            max_batch: 8,
            max_wait_us: 200,
            seed: 0x5EED,
            threads,
        }
    }
}

/// Measured result of one `(variant, rate, policy)` cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub variant: String,
    /// Requests/s; `f64::INFINITY` for the saturated cell.
    pub rate: f64,
    pub policy: BatchPolicy,
    pub n: usize,
    pub ok: usize,
    pub errors: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Completed images per wall-clock second (first scheduled arrival
    /// → last completion).
    pub images_per_s: f64,
    /// Mean images per executed wave.
    pub mean_wave: f64,
    pub elapsed_s: f64,
}

impl CellResult {
    pub fn rate_label(&self) -> String {
        if self.rate.is_finite() {
            format!("{:.0}/s", self.rate)
        } else {
            "saturated".to_string()
        }
    }
}

/// Deterministic request images: `n_distinct` seeded inputs cycled
/// round-robin, so logits are reproducible per request index.
pub fn request_images(seed: u64, n_distinct: usize) -> Vec<Vec<f32>> {
    (0..n_distinct.max(1))
        .map(|i| {
            let mut rng = Xoshiro256::new(seed ^ ((i as u64) << 32) ^ 0xA11CE);
            (0..IMG_ELEMS).map(|_| rng.range_f32(-1.0, 1.0)).collect()
        })
        .collect()
}

/// Zero `drop_num` of every `den` SB-aligned k-row blocks of a conv's
/// K×N weight matrix (rows are (ky, kx, ci) taps, zeroed across every
/// output channel) — pruning that lands exactly on the structural SB×SB
/// grid, the same recipe as the `perf_hotpaths` sparse-forward sweep.
pub fn block_structured_mask(cv: &ConvOp, drop_num: usize, den: usize) -> Vec<f32> {
    let kk = cv.k * cv.k * cv.cin;
    let mut mask = vec![1.0f32; cv.cout * cv.cin * cv.k * cv.k];
    for r in 0..kk {
        if (r / SB) % den >= drop_num {
            continue; // kept block
        }
        let ci = r % cv.cin;
        let pos = r / cv.cin;
        let kx = pos % cv.k;
        let ky = pos / cv.k;
        for o in 0..cv.cout {
            mask[((o * cv.cin + ci) * cv.k + ky) * cv.k + kx] = 0.0;
        }
    }
    mask
}

/// The standard serving variant pair: quantized dense lenet5 plus the
/// same params under 87.5% block-structured pruning (≥70% empty SB×SB
/// blocks, so the structural-skip GEMM path is what's being served).
/// Fixed activation scales keep setup artifact- and calibration-free;
/// determinism is unaffected (scales only pick the quantization grid).
pub fn standard_registry(threads: usize, seed: u64) -> Result<Arc<SnapshotRegistry>> {
    let spec = ModelSpec::builtin("lenet5")?;
    let params = Params::init_train(&spec, seed);
    let scales = vec![0.02f32; spec.n_q];
    let reg = Arc::new(SnapshotRegistry::new());

    let dense_qc = QuantConfig::quantized(&spec, scales.clone());
    reg.install(ModelVariant::new(
        "dense",
        ParallelEngine::new(&spec, &params.tensors, &dense_qc, threads),
    ));

    let mut sparse_qc = QuantConfig::quantized(&spec, scales);
    for cv in spec.convs() {
        sparse_qc.masks[cv.conv_idx] = Some(block_structured_mask(cv, 7, 8));
    }
    reg.install(ModelVariant::new(
        "sparse87",
        ParallelEngine::new(&spec, &params.tensors, &sparse_qc, threads),
    ));
    Ok(reg)
}

/// Run one sustained-load cell against an installed variant.
pub fn run_cell(
    registry: &Arc<SnapshotRegistry>,
    variant: &str,
    rate: f64,
    policy: BatchPolicy,
    requests: usize,
    seed: u64,
) -> CellResult {
    let images = request_images(seed, 16);
    let mut rng = Xoshiro256::new(seed ^ 0xD15BA7C4);
    // Pre-drawn exponential gaps (ns); zero gaps when saturated.
    let gaps: Vec<u64> = (0..requests)
        .map(|_| {
            if rate.is_finite() && rate > 0.0 {
                let u = rng.f64();
                ((-(1.0 - u).ln()) / rate * 1e9) as u64
            } else {
                0
            }
        })
        .collect();
    let batcher = MicroBatcher::new(Arc::clone(registry), policy);
    let start = Instant::now();
    let mut scheduled: Vec<Instant> = Vec::with_capacity(requests);
    let mut tickets: Vec<Ticket> = Vec::with_capacity(requests);
    let mut cum_ns = 0u64;
    for (i, gap) in gaps.iter().enumerate() {
        cum_ns += gap;
        let target = start + Duration::from_nanos(cum_ns);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        // Latency is measured from the *scheduled* arrival even when the
        // submit loop falls behind (open loop).
        scheduled.push(target);
        tickets.push(batcher.submit(variant, &images[i % images.len()]));
    }
    let mut lat_ns: Vec<u64> = Vec::with_capacity(requests);
    let mut errors = 0usize;
    let mut last_done = start;
    for (t, sched) in tickets.iter().zip(&scheduled) {
        let reply = t.wait();
        match reply.result {
            Ok(_) => {
                lat_ns.push(reply.done_at.saturating_duration_since(*sched).as_nanos() as u64);
                if reply.done_at > last_done {
                    last_done = reply.done_at;
                }
            }
            Err(_) => errors += 1,
        }
    }
    let stats = batcher.shutdown();
    lat_ns.sort_unstable();
    let elapsed_s = last_done.duration_since(start).as_secs_f64().max(1e-9);
    CellResult {
        variant: variant.to_string(),
        rate,
        policy,
        n: requests,
        ok: lat_ns.len(),
        errors,
        p50_us: percentile(&lat_ns, 0.50) as f64 / 1e3,
        p95_us: percentile(&lat_ns, 0.95) as f64 / 1e3,
        p99_us: percentile(&lat_ns, 0.99) as f64 / 1e3,
        images_per_s: lat_ns.len() as f64 / elapsed_s,
        mean_wave: stats.mean_wave(),
        elapsed_s,
    }
}

/// Structural self-check every cell must satisfy regardless of the
/// machine: nearest-rank percentiles are monotone and every completed
/// request was counted.
pub fn check_cell(c: &CellResult) {
    assert!(
        c.p99_us >= c.p95_us && c.p95_us >= c.p50_us,
        "percentiles must be monotone: {c:?}"
    );
    assert_eq!(c.ok + c.errors, c.n, "lost requests: {c:?}");
}

/// Drive the full grid: `{dense, sparse87}` × `{rates…, saturated}` ×
/// `{batch1, (max_batch, max_wait_us)}`.  Returns the
/// `BENCH_serving.json`-shaped report and the raw cells.
pub fn run_serve_bench(cfg: &ServeBenchCfg) -> Result<(Json, Vec<CellResult>)> {
    let reg = standard_registry(cfg.threads, cfg.seed)?;
    let policies = [
        BatchPolicy::batch1(),
        BatchPolicy {
            max_batch: cfg.max_batch.max(2),
            max_wait_us: cfg.max_wait_us,
        },
    ];
    let mut rates = cfg.rates.clone();
    if cfg.include_saturated {
        rates.push(f64::INFINITY);
    }
    let mut cells: Vec<CellResult> = Vec::new();
    for name in ["dense", "sparse87"] {
        for &rate in &rates {
            for &policy in &policies {
                let cell = run_cell(&reg, name, rate, policy, cfg.requests, cfg.seed);
                check_cell(&cell);
                cells.push(cell);
            }
        }
    }

    // Peak-throughput ratio per variant: saturated batched vs batch1.
    let saturated_speedup = |variant: &str| -> Option<f64> {
        let find = |b1: bool| {
            cells.iter().find(|c| {
                c.variant == variant
                    && !c.rate.is_finite()
                    && (c.policy.max_batch == 1) == b1
            })
        };
        let (base, batched) = (find(true)?, find(false)?);
        (base.images_per_s > 0.0).then(|| batched.images_per_s / base.images_per_s)
    };

    let variant_json = |name: &str| -> Json {
        let v = reg.get(name).expect("installed above");
        let rep = v.engine.sparsity_report(1);
        let blocks: u64 = rep.iter().map(|r| r.sparsity.blocks_total).sum();
        let empty: u64 = rep.iter().map(|r| r.sparsity.blocks_empty).sum();
        Json::obj(vec![
            ("name", Json::str(name)),
            ("blocks_total", Json::num(blocks as f64)),
            ("blocks_empty", Json::num(empty as f64)),
            (
                "empty_fraction",
                Json::num(empty as f64 / blocks.max(1) as f64),
            ),
            (
                "batched_speedup_vs_batch1",
                Json::num(saturated_speedup(name).unwrap_or(0.0)),
            ),
        ])
    };

    let json = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("model", Json::str("lenet5")),
        ("seed", Json::num(cfg.seed as f64)),
        ("threads", Json::num(cfg.threads as f64)),
        ("requests_per_cell", Json::num(cfg.requests as f64)),
        ("max_batch", Json::num(cfg.max_batch as f64)),
        ("max_wait_us", Json::num(cfg.max_wait_us as f64)),
        (
            "variants",
            Json::arr(["dense", "sparse87"].into_iter().map(variant_json)),
        ),
        (
            "cells",
            Json::arr(cells.iter().map(|c| {
                Json::obj(vec![
                    ("variant", Json::str(&c.variant)),
                    (
                        "rate_rps",
                        if c.rate.is_finite() {
                            Json::num(c.rate)
                        } else {
                            Json::num(0.0)
                        },
                    ),
                    ("saturated", Json::Bool(!c.rate.is_finite())),
                    ("policy", Json::str(&c.policy.label())),
                    ("max_batch", Json::num(c.policy.max_batch as f64)),
                    ("max_wait_us", Json::num(c.policy.max_wait_us as f64)),
                    ("n", Json::num(c.n as f64)),
                    ("ok", Json::num(c.ok as f64)),
                    ("errors", Json::num(c.errors as f64)),
                    ("p50_us", Json::num(c.p50_us)),
                    ("p95_us", Json::num(c.p95_us)),
                    ("p99_us", Json::num(c.p99_us)),
                    ("images_per_s", Json::num(c.images_per_s)),
                    ("mean_wave", Json::num(c.mean_wave)),
                    ("elapsed_s", Json::num(c.elapsed_s)),
                ])
            })),
        ),
    ]);
    Ok((json, cells))
}

/// Validate a loaded `BENCH_serving.json`: shape + the p99 ≥ p50
/// invariant per cell.  Returns the cell count.  This is the
/// `verify.sh --quick` serving smoke gate (run through
/// `wsel serve-bench --quick`, which re-loads what it just wrote).
pub fn validate_report(json: &Json) -> Result<usize> {
    let cells = json
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("BENCH_serving.json: missing `cells` array"))?;
    if cells.is_empty() {
        anyhow::bail!("BENCH_serving.json: empty `cells`");
    }
    for (i, c) in cells.iter().enumerate() {
        let num = |k: &str| -> Result<f64> {
            c.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("cell {i}: missing numeric `{k}`"))
        };
        let (p50, p95, p99) = (num("p50_us")?, num("p95_us")?, num("p99_us")?);
        if !(p99 >= p95 && p95 >= p50) {
            anyhow::bail!("cell {i}: percentiles not monotone (p50={p50}, p95={p95}, p99={p99})");
        }
        if num("images_per_s")? < 0.0 {
            anyhow::bail!("cell {i}: negative throughput");
        }
    }
    Ok(cells.len())
}

/// Submit `imgs` concurrently through a fresh batcher and return each
/// request's logits in submission order — the bit-identity probe used
/// by tests and the perf stage (results must equal single-image
/// [`ParallelEngine::forward_plain`] regardless of wave packing).
pub fn wave_logits(
    registry: &Arc<SnapshotRegistry>,
    variant: &str,
    imgs: &[Vec<f32>],
    policy: BatchPolicy,
) -> Vec<Result<Vec<f32>, ServeError>> {
    let batcher = MicroBatcher::new(Arc::clone(registry), policy);
    let tickets: Vec<Ticket> = imgs.iter().map(|x| batcher.submit(variant, x)).collect();
    let out = tickets.iter().map(|t| t.wait().result).collect();
    batcher.shutdown();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_produces_valid_report() {
        let cfg = ServeBenchCfg {
            rates: vec![5000.0],
            include_saturated: true,
            requests: 12,
            max_batch: 4,
            max_wait_us: 100,
            seed: 9,
            threads: 2,
        };
        let (json, cells) = run_serve_bench(&cfg).unwrap();
        // 2 variants × (1 rate + saturated) × 2 policies.
        assert_eq!(cells.len(), 8);
        assert_eq!(validate_report(&json).unwrap(), 8);
        for c in &cells {
            assert_eq!(c.ok, c.n, "no errors expected: {c:?}");
        }
        // The sparse variant really is ≥70% empty-block.
        let v = json.get("variants").and_then(Json::as_arr).unwrap();
        let sparse = v
            .iter()
            .find(|x| x.get("name").and_then(Json::as_str) == Some("sparse87"))
            .unwrap();
        assert!(sparse.get("empty_fraction").and_then(Json::as_f64).unwrap() >= 0.70);
    }

    #[test]
    fn validate_rejects_non_monotone_percentiles() {
        let bad = Json::obj(vec![(
            "cells",
            Json::arr([Json::obj(vec![
                ("p50_us", Json::num(10.0)),
                ("p95_us", Json::num(5.0)),
                ("p99_us", Json::num(20.0)),
                ("images_per_s", Json::num(1.0)),
            ])]),
        )]);
        assert!(validate_report(&bad).is_err());
        assert!(validate_report(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn block_mask_hits_structural_grid() {
        let spec = ModelSpec::builtin("lenet5").unwrap();
        let cv = spec.convs()[0];
        let dense = block_structured_mask(cv, 0, 8);
        assert!(dense.iter().all(|&v| v == 1.0));
        let m = block_structured_mask(cv, 7, 8);
        let zeros = m.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0 && zeros < m.len());
    }
}
