//! Named snapshot registry of compiled model variants.
//!
//! A serving process holds a family of compressed variants of the same
//! (or different) models — dense, pruned, weight-set-restricted — and
//! routes each request to one by name.  Compilation
//! ([`Plan::compile`](crate::model::ir::Plan::compile): weight
//! quantization + blocked panel packing) happens **once per install**,
//! then every wave reuses the plan.  Variants live behind `Arc`:
//! [`SnapshotRegistry::install`] replaces the map entry atomically
//! while in-flight waves keep executing on the `Arc` they already
//! resolved, so hot-swap and eviction never interrupt running work.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::model::spec::INPUT_ELEMS;
use crate::model::{ModelSpec, ParallelEngine, Params, QuantConfig};
use crate::selection::CompressionState;
use crate::util::threadpool::PoisonedBatch;
use anyhow::{bail, Context, Result};

/// One servable model variant: a name plus a compiled engine.
pub struct ModelVariant {
    pub name: String,
    pub engine: ParallelEngine,
    /// Test/bench hook: number of upcoming waves that should fail as if
    /// a worker had panicked (see [`Self::inject_wave_faults`]).
    fail_waves: AtomicU64,
}

impl ModelVariant {
    /// Wrap an already-compiled engine.
    pub fn new(name: &str, engine: ParallelEngine) -> Self {
        Self {
            name: name.to_string(),
            engine,
            fail_waves: AtomicU64::new(0),
        }
    }

    /// Compile a variant from params + a [`CompressionState`] using the
    /// same [`QuantConfig`] recipe as the native backend (shared mask
    /// recipe via [`crate::runtime::mask_options`], the state's
    /// restricted weight sets, activation quantization on) — so the
    /// variant a pipeline just compressed is exactly the variant the
    /// registry serves.
    pub fn compile(
        name: &str,
        spec: &ModelSpec,
        params: &[Vec<f32>],
        act_scales: &[f32],
        state: &CompressionState,
        threads: usize,
    ) -> Self {
        let mut wsets = vec![None; spec.n_conv];
        for c in spec.convs() {
            wsets[c.conv_idx] = state.layers[c.conv_idx].wset.clone();
        }
        let qc = QuantConfig {
            act_scales: act_scales.to_vec(),
            quant_on: true,
            masks: crate::runtime::mask_options(spec, params, state),
            wsets,
        };
        Self::new(name, ParallelEngine::new(spec, params, &qc, threads))
    }

    /// Logit width of this variant.
    pub fn n_classes(&self) -> usize {
        self.engine.plan.n_classes
    }

    /// Arm the fault hook: the next `n` waves routed through
    /// [`Self::run_wave`] fail with a synthesized [`PoisonedBatch`]
    /// covering every image, without any worker actually panicking.
    /// This is how tests and benches exercise the "poisoned wave
    /// degrades the wave, not the service" contract deterministically.
    pub fn inject_wave_faults(&self, n: u64) {
        self.fail_waves.fetch_add(n, Ordering::AcqRel);
    }

    /// Atomically consume one armed fault, if any.
    fn take_injected_fault(&self) -> bool {
        loop {
            let cur = self.fail_waves.load(Ordering::Acquire);
            if cur == 0 {
                return false;
            }
            if self
                .fail_waves
                .compare_exchange(cur, cur - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
        }
    }

    /// Execute one wave of independently owned images (the batcher's
    /// unit of work), honoring any armed fault injection.
    pub fn run_wave(&self, imgs: &[&[f32]]) -> Result<Vec<Vec<f32>>, PoisonedBatch> {
        if self.take_injected_fault() {
            return Err(PoisonedBatch {
                poisoned: (0..imgs.len())
                    .map(|i| (i, "injected wave fault (serve fault hook)".to_string()))
                    .collect(),
                n: imgs.len(),
            });
        }
        self.engine.forward_wave(imgs)
    }
}

/// Thread-safe map from variant name to its compiled engine.
#[derive(Default)]
pub struct SnapshotRegistry {
    variants: RwLock<HashMap<String, Arc<ModelVariant>>>,
}

impl SnapshotRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install (or hot-swap) a variant under its name.  Returns the
    /// installed `Arc`.  Waves already holding the previous `Arc` run
    /// to completion on the old plan; waves resolved after this call
    /// see the new one.
    pub fn install(&self, variant: ModelVariant) -> Arc<ModelVariant> {
        let v = Arc::new(variant);
        self.variants
            .write()
            .unwrap()
            .insert(v.name.clone(), Arc::clone(&v));
        v
    }

    /// Resolve a variant by name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelVariant>> {
        self.variants.read().unwrap().get(name).cloned()
    }

    /// Remove a variant by name, returning it if present.  In-flight
    /// waves holding the `Arc` are unaffected; new requests naming it
    /// get [`ServeError::UnknownModel`](super::ServeError::UnknownModel).
    pub fn evict(&self, name: &str) -> Option<Arc<ModelVariant>> {
        self.variants.write().unwrap().remove(name)
    }

    /// Installed variant names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = self.variants.read().unwrap().keys().cloned().collect();
        out.sort();
        out
    }

    pub fn len(&self) -> usize {
        self.variants.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Load a variant from the on-disk artifact layout the runtime
    /// writes (`<artifacts>/<model>/manifest.json` + `params.bin`,
    /// checksummed through [`crate::util::artifact`]) and install it
    /// under `name`.
    ///
    /// * spec: `manifest.json` when present, else
    ///   [`ModelSpec::builtin`]`(model)`;
    /// * params: `params.<tag>.bin` when `params_tag` is given (hard
    ///   error if missing — a named tag is an explicit request), else
    ///   `params.bin` when present, else [`Params::init_train`] (a
    ///   fresh deterministic init, so smoke setups serve without any
    ///   artifacts);
    /// * activation scales: recalibrated through
    ///   [`crate::runtime::calibrate_scales`] (the shared PJRT-free
    ///   recipe), so the served quantization matches what training saw.
    #[allow(clippy::too_many_arguments)]
    pub fn load_artifact(
        &self,
        name: &str,
        artifacts_dir: &Path,
        model: &str,
        params_tag: Option<&str>,
        data_seed: u64,
        calib_batches: usize,
        threads: usize,
    ) -> Result<Arc<ModelVariant>> {
        let dir = artifacts_dir.join(model);
        let manifest = dir.join("manifest.json");
        let spec = if manifest.exists() {
            ModelSpec::from_manifest_file(&manifest)
                .with_context(|| format!("loading {}", manifest.display()))?
        } else {
            ModelSpec::builtin(model)?
        };
        let params = match params_tag {
            Some(tag) => {
                let path = dir.join(format!("params.{tag}.bin"));
                if !path.exists() {
                    bail!("params tag `{tag}` not found at {}", path.display());
                }
                Params::load(&spec, &path)?
            }
            None => {
                let path = dir.join("params.bin");
                if path.exists() {
                    Params::load(&spec, &path)?
                } else {
                    Params::init_train(&spec, spec.seed)
                }
            }
        };
        let scales = crate::runtime::calibrate_scales(
            &spec,
            &params.tensors,
            data_seed,
            calib_batches.max(1),
            threads,
        );
        let qc = QuantConfig::quantized(&spec, scales);
        let engine = ParallelEngine::new(&spec, &params.tensors, &qc, threads);
        Ok(self.install(ModelVariant::new(name, engine)))
    }
}

/// Element count every submitted image must have.
pub const IMG_ELEMS: usize = INPUT_ELEMS;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::tests_support::tiny_spec;

    fn variant(name: &str, seed: u64) -> ModelVariant {
        let spec = tiny_spec();
        let p = Params::random(&spec, seed);
        let qc = QuantConfig::float(&spec);
        ModelVariant::new(name, ParallelEngine::new(&spec, &p.tensors, &qc, 2))
    }

    #[test]
    fn install_get_evict_roundtrip() {
        let reg = SnapshotRegistry::new();
        assert!(reg.is_empty());
        reg.install(variant("a", 1));
        reg.install(variant("b", 2));
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.get("a").is_some());
        assert!(reg.get("missing").is_none());
        assert!(reg.evict("a").is_some());
        assert!(reg.get("a").is_none());
        assert!(reg.evict("a").is_none());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn hot_swap_leaves_old_arc_usable() {
        let reg = SnapshotRegistry::new();
        let old = reg.install(variant("m", 3));
        let img = vec![0.25f32; IMG_ELEMS];
        let before = old.run_wave(&[&img]).unwrap();
        // Swap in a different-params variant under the same name.
        reg.install(variant("m", 4));
        // The held Arc still executes, bit-identically to before.
        let again = old.run_wave(&[&img]).unwrap();
        assert_eq!(
            before[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            again[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // And the registry now resolves to the new engine.
        let new = reg.get("m").unwrap();
        let fresh = new.run_wave(&[&img]).unwrap();
        assert_ne!(
            before[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fresh[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn injected_faults_consume_exactly_n_waves() {
        let v = variant("f", 5);
        let img = vec![0.1f32; IMG_ELEMS];
        v.inject_wave_faults(2);
        let e1 = v.run_wave(&[&img, &img]).unwrap_err();
        assert_eq!(e1.n, 2);
        assert_eq!(e1.poisoned.len(), 2);
        assert!(v.run_wave(&[&img]).is_err());
        // Armed faults exhausted: service healthy again.
        assert!(v.run_wave(&[&img]).is_ok());
    }
}
