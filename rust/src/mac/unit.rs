//! The full MAC cell of the weight-stationary PE (paper §3.1):
//! activation register → Baugh-Wooley multiplier → 22-bit accumulator
//! adder → partial-sum register.
//!
//! The 22-bit accumulator matches the paper: a 16-bit product plus
//! log2(64) bits of headroom for a 64-deep systolic column.  During a
//! tile pass the weight register is stationary, so
//! [`specialize_mac`] const-folds the weight bits into the netlist —
//! giving each weight value its own switching profile.

use crate::gates::netlist::{NetBuilder, Netlist, Sig};
use crate::gates::optimize::const_prop;
use crate::mac::multiplier::baugh_wooley_8x8;

/// Accumulator width (bits), per the paper.
pub const ACC_BITS: usize = 22;
/// Activation operand width (bits).
pub const ACT_BITS: usize = 8;

/// A MAC netlist plus its input layout.
#[derive(Clone, Debug)]
pub struct MacNetlist {
    pub netlist: Netlist,
    /// True if the weight bits are primary inputs (generic MAC); false if
    /// they have been specialized away (weight-stationary MAC).
    pub generic: bool,
}

impl MacNetlist {
    /// Input count expected by the testbench.
    pub fn n_inputs(&self) -> usize {
        if self.generic {
            ACT_BITS + 8 + ACC_BITS
        } else {
            ACT_BITS + ACC_BITS
        }
    }

    /// Pack one (activation, psum_in) step into testbench bit order.
    /// For the generic MAC the caller must insert weight bits separately.
    pub fn pack_step(&self, act: i32, psum_in: i32) -> Vec<bool> {
        assert!(!self.generic, "pack_step is for specialized MACs");
        let mut v = Vec::with_capacity(ACT_BITS + ACC_BITS);
        for i in 0..ACT_BITS {
            v.push((act >> i) & 1 != 0);
        }
        for i in 0..ACC_BITS {
            v.push((psum_in >> i) & 1 != 0);
        }
        v
    }
}

/// Build the generic MAC: inputs `[a0..a7, w0..w7, p0..p21]`, outputs the
/// 22 bits of `psum_out = psum_in + sext22(a*w) mod 2^22`.
pub fn build_mac() -> MacNetlist {
    let mut b = NetBuilder::new();
    let a = b.inputs(ACT_BITS);
    let w = b.inputs(8);
    let p_in = b.inputs(ACC_BITS);

    let prod = baugh_wooley_8x8(&mut b, &a, &w);
    // Sign-extend the 16-bit product to 22 bits.
    let sign = prod[15];
    let mut prod_ext: Vec<Sig> = prod;
    while prod_ext.len() < ACC_BITS {
        prod_ext.push(sign);
    }
    let zero = b.constant(false);
    let psum_out = b.add_words(&p_in, &prod_ext, zero);

    // Sequential loads: the activation register D-pins (driven by the
    // streaming neighbours — modeled as the activation inputs themselves)
    // and the psum register D-pins (the adder outputs).
    let mut ffs: Vec<Sig> = a.clone();
    ffs.extend(psum_out.iter().copied());

    MacNetlist {
        netlist: b.finish(psum_out, ffs),
        generic: true,
    }
}

/// Specialize the generic MAC for a stationary weight value
/// (int8 code in `[-128, 127]`).
pub fn specialize_mac(mac: &MacNetlist, weight: i32) -> MacNetlist {
    assert!(mac.generic);
    let fixed: Vec<(usize, bool)> = (0..8)
        .map(|i| (ACT_BITS + i, (weight >> i) & 1 != 0))
        .collect();
    MacNetlist {
        netlist: const_prop(&mac.netlist, &fixed),
        generic: false,
    }
}

/// Software reference for the MAC step (used by every cross-check).
#[inline]
pub fn mac_ref(act: i32, weight: i32, psum_in: i32) -> i32 {
    let wide = psum_in as i64 + (act as i64 * weight as i64);
    // Wrap to 22-bit two's complement.
    let m = wide & ((1 << ACC_BITS) - 1);
    ((m << (64 - ACC_BITS)) >> (64 - ACC_BITS)) as i32
}

/// Decode a 22-bit little-endian output into a signed value.
pub fn decode_psum(bits: &[bool]) -> i32 {
    let raw: u32 = bits
        .iter()
        .enumerate()
        .map(|(i, &v)| (v as u32) << i)
        .sum();
    ((raw as i32) << (32 - ACC_BITS)) >> (32 - ACC_BITS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::sim::TraceSim;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn generic_mac_matches_ref() {
        let mac = build_mac();
        let mut sim = TraceSim::new(&mac.netlist);
        let mut rng = Xoshiro256::new(11);
        for _ in 0..500 {
            let a = rng.code();
            let w = rng.code();
            let p = (rng.below(1 << ACC_BITS) as i64 - (1 << (ACC_BITS - 1)) as i64) as i32;
            let mut ins = Vec::new();
            for i in 0..8 {
                ins.push((a >> i) & 1 != 0);
            }
            for i in 0..8 {
                ins.push((w >> i) & 1 != 0);
            }
            for i in 0..ACC_BITS {
                ins.push((p >> i) & 1 != 0);
            }
            let out = sim.eval_single(&mac.netlist, &ins);
            assert_eq!(decode_psum(&out), mac_ref(a, w, p), "a={a} w={w} p={p}");
        }
    }

    #[test]
    fn specialized_mac_matches_ref_for_every_weight() {
        let mac = build_mac();
        let mut rng = Xoshiro256::new(13);
        for w in (-127i32..=127).step_by(17) {
            let spec = specialize_mac(&mac, w);
            assert_eq!(spec.n_inputs(), spec.netlist.inputs.len());
            let mut sim = TraceSim::new(&spec.netlist);
            for _ in 0..50 {
                let a = rng.code();
                let p = (rng.below(1 << 20) as i64 - (1 << 19)) as i32;
                let out = sim.eval_single(&spec.netlist, &spec.pack_step(a, p));
                assert_eq!(decode_psum(&out), mac_ref(a, w, p), "a={a} w={w} p={p}");
            }
        }
    }

    #[test]
    fn zero_weight_collapses_multiplier() {
        let mac = build_mac();
        let spec0 = specialize_mac(&mac, 0);
        let spec127 = specialize_mac(&mac, -127);
        // w=0: product is the BW constant, adder folds massively.
        assert!(
            spec0.netlist.gate_count() * 2 < spec127.netlist.gate_count(),
            "w=0 gates {} vs w=-127 gates {}",
            spec0.netlist.gate_count(),
            spec127.netlist.gate_count()
        );
    }

    #[test]
    fn accumulator_wraps_at_22_bits() {
        assert_eq!(mac_ref(0, 0, (1 << 21) - 1), (1 << 21) - 1);
        assert_eq!(mac_ref(1, 1, (1 << 21) - 1), -(1 << 21));
    }
}
