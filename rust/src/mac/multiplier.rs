//! 8×8 signed (two's-complement) Baugh-Wooley array multiplier.
//!
//! Identity used (n = 8, all arithmetic mod 2^16):
//!
//! ```text
//! a·b = Σ_{i,j<7} a_i b_j 2^{i+j}
//!     + a_7 b_7 2^14
//!     + 2^7 Σ_{i<7} ¬(a_i b_7) 2^i
//!     + 2^7 Σ_{j<7} ¬(a_7 b_j) 2^j
//!     + 2^8 + 2^15
//! ```
//!
//! The partial-product rows are reduced with ripple-carry rows (an array
//! multiplier, as in TPU-class PE implementations).  Correctness is
//! pinned *exhaustively* over all 65 536 (a, b) pairs in the test below —
//! the single most important invariant of the energy model.

use crate::gates::netlist::{NetBuilder, Sig};

/// Build the product bits `a*b mod 2^16` (little-endian, 16 signals) from
/// 8-bit little-endian operand signals.
pub fn baugh_wooley_8x8(b: &mut NetBuilder, a_bits: &[Sig], w_bits: &[Sig]) -> Vec<Sig> {
    assert_eq!(a_bits.len(), 8);
    assert_eq!(w_bits.len(), 8);
    let zero = b.constant(false);
    let one = b.constant(true);

    // Row for each j: partial products of b_j against all a_i.
    // rows[j][col] holds the bit of weight 2^(col) contributed by row j,
    // already shifted (col = i + j).
    let mut rows: Vec<Vec<Sig>> = Vec::with_capacity(9);
    for j in 0..8 {
        let mut row = vec![zero; 16];
        for i in 0..8 {
            let pp = if (i == 7) ^ (j == 7) {
                // Complemented cross terms ¬(a_i·b_7), ¬(a_7·b_j).
                b.nand(a_bits[i], w_bits[j])
            } else {
                // Positive terms, including a_7·b_7 at weight 14.
                b.and(a_bits[i], w_bits[j])
            };
            row[i + j] = pp;
        }
        rows.push(row);
    }
    // Correction constants: +2^8 and +2^15.
    let mut konst = vec![zero; 16];
    konst[8] = one;
    konst[15] = one;
    rows.push(konst);

    // Reduce rows with 16-bit ripple adds (wrap-around at 2^16 is exactly
    // the desired modulo arithmetic).
    let mut acc = rows[0].clone();
    for row in &rows[1..] {
        acc = b.add_words(&acc, row, zero);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::netlist::NetBuilder;
    use crate::gates::sim::TraceSim;

    fn build() -> (crate::gates::netlist::Netlist, usize) {
        let mut b = NetBuilder::new();
        let a = b.inputs(8);
        let w = b.inputs(8);
        let p = baugh_wooley_8x8(&mut b, &a, &w);
        let nl = b.finish(p, vec![]);
        let gates = nl.gate_count();
        (nl, gates)
    }

    fn run_mult(
        sim: &mut TraceSim,
        nl: &crate::gates::netlist::Netlist,
        a: i32,
        w: i32,
    ) -> i32 {
        let mut ins = [false; 16];
        for i in 0..8 {
            ins[i] = (a >> i) & 1 != 0;
            ins[8 + i] = (w >> i) & 1 != 0;
        }
        let out = sim.eval_single(nl, &ins);
        let raw: u32 = out
            .iter()
            .enumerate()
            .map(|(i, &v)| (v as u32) << i)
            .sum();
        // Interpret the 16-bit result as signed.
        (raw as i32) << 16 >> 16
    }

    /// EXHAUSTIVE: all 256×256 signed products.
    #[test]
    fn exhaustive_products() {
        let (nl, gates) = build();
        assert!(gates > 100, "suspiciously small multiplier: {gates} gates");
        let mut sim = TraceSim::new(&nl);
        for a in -128i32..=127 {
            for w in -128i32..=127 {
                let got = run_mult(&mut sim, &nl, a, w);
                let expect = ((a * w) << 16) >> 16; // mod 2^16, signed
                assert_eq!(got, expect, "a={a} w={w}");
            }
        }
    }

    /// int8×int8 never overflows 16 bits except -128·-128; our codes are
    /// clamped to [-127, 127] so the product is always exact.
    #[test]
    fn exact_in_code_range() {
        let (nl, _) = build();
        let mut sim = TraceSim::new(&nl);
        for &(a, w) in &[(-127, -127), (127, -127), (-127, 127), (127, 127), (99, -3)] {
            assert_eq!(run_mult(&mut sim, &nl, a, w), a * w);
        }
    }
}
