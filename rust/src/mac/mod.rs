//! MAC-unit netlists: 8×8 signed Baugh-Wooley multiplier, 22-bit
//! accumulator and weight-specialized MAC construction (paper §3.1).

pub mod multiplier;
pub mod unit;

pub use multiplier::baugh_wooley_8x8;
pub use unit::{build_mac, specialize_mac, MacNetlist, ACC_BITS, ACT_BITS};
