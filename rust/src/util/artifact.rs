//! Checksummed, atomically-written on-disk artifacts.
//!
//! Every file this crate persists (golden snapshots, params/checkpoint
//! blobs, `BENCH_*.json` emissions, schedule journals) goes through this
//! module: writes land in a temp file and `rename` into place so a kill
//! mid-write can never leave a half-written artifact under the final
//! name, and every payload is prefixed with a one-line versioned header
//! carrying its CRC-32 and length so truncation and bit-rot are detected
//! at load with a pinpointed error (path + reason) instead of being
//! silently parsed into garbage.
//!
//! Format: an ASCII header line `WSELART1 crc32=xxxxxxxx len=N\n`
//! followed by exactly `N` raw payload bytes (binary-safe — the payload
//! is never inspected).  Files that do not start with the magic are
//! **legacy artifacts** (committed goldens predating this module,
//! `params.bin` written by the Python side): they load as-is, with no
//! integrity claim, so adoption is incremental and cross-tool files keep
//! working.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Version-carrying magic; bump the trailing digit on format changes.
pub const MAGIC: &str = "WSELART1";

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) lookup table, built
/// at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
};

/// CRC-32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_owned();
    s.push(format!(".tmp.{}", std::process::id()));
    PathBuf::from(s)
}

/// Atomically write `payload` to `path` under a checksummed header:
/// the bytes land in a same-directory temp file first and are renamed
/// into place, so readers only ever observe the old artifact or the
/// complete new one.  Parent directories are created as needed.
pub fn write_atomic(path: &Path, payload: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let header = format!("{MAGIC} crc32={:08x} len={}\n", crc32(payload), payload.len());
    let mut bytes = Vec::with_capacity(header.len() + payload.len());
    bytes.extend_from_slice(header.as_bytes());
    bytes.extend_from_slice(payload);
    let tmp = tmp_sibling(path);
    std::fs::write(&tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Load an artifact, verifying header, length, and checksum; returns the
/// raw payload.  Headerless files pass through whole as legacy payloads.
/// Every failure names the path and the precise reason — a corrupt file
/// is never silently consumed.
pub fn load(path: &Path) -> Result<Vec<u8>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading artifact {}", path.display()))?;
    if !bytes.starts_with(MAGIC.as_bytes()) {
        // Legacy artifact written before the versioned header existed
        // (or by the Python side): nothing to verify against.
        return Ok(bytes);
    }
    let nl = bytes
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| anyhow!("{}: artifact header line is unterminated", path.display()))?;
    let header = std::str::from_utf8(&bytes[..nl])
        .map_err(|_| anyhow!("{}: artifact header is not UTF-8", path.display()))?;
    let mut stored_crc: Option<u32> = None;
    let mut stored_len: Option<usize> = None;
    for tok in header.split_whitespace().skip(1) {
        if let Some(v) = tok.strip_prefix("crc32=") {
            stored_crc = u32::from_str_radix(v, 16).ok();
        } else if let Some(v) = tok.strip_prefix("len=") {
            stored_len = v.parse::<usize>().ok();
        }
    }
    let (stored_crc, stored_len) = match (stored_crc, stored_len) {
        (Some(c), Some(l)) => (c, l),
        _ => bail!("{}: malformed artifact header `{header}`", path.display()),
    };
    let payload = &bytes[nl + 1..];
    if payload.len() != stored_len {
        bail!(
            "{}: truncated artifact: header declares {stored_len} payload bytes, file has {}",
            path.display(),
            payload.len()
        );
    }
    let crc = crc32(payload);
    if crc != stored_crc {
        bail!(
            "{}: artifact checksum mismatch (stored {stored_crc:08x}, computed {crc:08x}) — \
             file is corrupt",
            path.display()
        );
    }
    Ok(payload.to_vec())
}

/// [`write_atomic`] for a JSON value (newline-terminated text payload).
pub fn write_json_atomic(path: &Path, json: &crate::util::json::Json) -> Result<()> {
    write_atomic(path, format!("{json}\n").as_bytes())
}

/// [`load`] + parse the payload as JSON.
pub fn load_json(path: &Path) -> Result<crate::util::json::Json> {
    let payload = load(path)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|_| anyhow!("{}: artifact payload is not UTF-8", path.display()))?;
    crate::util::json::Json::parse(text.trim())
        .map_err(|e| anyhow!("{}: artifact JSON does not parse: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wsel_artifact_{tag}_{}", std::process::id()))
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrips_binary_payloads() {
        let path = tmp("roundtrip");
        // Payload containing newlines, 0x00 and 0xFF: header parsing must
        // split only on the first newline.
        let payload = vec![0u8, 10, 255, 87, 10, 10, 0, 1];
        write_atomic(&path, &payload).unwrap();
        assert_eq!(load(&path).unwrap(), payload);
        // Overwrite is atomic and replaces the old content entirely.
        write_atomic(&path, b"second").unwrap();
        assert_eq!(load(&path).unwrap(), b"second");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn legacy_headerless_files_pass_through() {
        let path = tmp("legacy");
        std::fs::write(&path, b"{\"plain\": 1}\n").unwrap();
        assert_eq!(load(&path).unwrap(), b"{\"plain\": 1}\n");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_is_detected_with_path_and_reason() {
        let path = tmp("trunc");
        write_atomic(&path, b"0123456789abcdef").unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = format!("{}", load(&path).unwrap_err());
        assert!(err.contains("truncated"), "unexpected error: {err}");
        assert!(err.contains(&path.display().to_string()), "error lacks path: {err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bit_flip_is_detected_with_path_and_reason() {
        let path = tmp("flip");
        write_atomic(&path, b"0123456789abcdef").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{}", load(&path).unwrap_err());
        assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
        assert!(err.contains(&path.display().to_string()), "error lacks path: {err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn json_roundtrip() {
        use crate::util::json::Json;
        let path = tmp("json");
        let v = Json::obj(vec![("a", Json::num(1.5)), ("b", Json::str("x"))]);
        write_json_atomic(&path, &v).unwrap();
        let back = load_json(&path).unwrap();
        assert_eq!(format!("{back}"), format!("{v}"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_error_names_the_path() {
        let path = tmp("missing_never_written");
        let err = format!("{:?}", load(&path).unwrap_err());
        assert!(err.contains("missing_never_written"), "error lacks path: {err}");
    }
}
