//! Offline-substrate utilities: the crates this repo would normally pull
//! from crates.io (rand, serde_json, clap, a thread pool, a logger) are
//! unavailable in the offline build image, so minimal production-quality
//! equivalents live here (see DESIGN.md §2).

pub mod artifact;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod threadpool;
