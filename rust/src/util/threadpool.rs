//! Scoped work-stealing-free thread pool for data-parallel simulation
//! (per-layer characterization, per-tile power).  The offline image has no
//! rayon/tokio; this covers the fork-join pattern those would provide.
//!
//! Work items are indices `0..n`; workers pull from a shared atomic
//! counter, so load imbalance between items self-schedules.
//!
//! Panic isolation: a panic inside a work item no longer aborts the
//! process.  Each item runs under `catch_unwind`; the batch still visits
//! every index, and the `try_*` entry points return a [`PoisonedBatch`]
//! naming exactly which indices panicked and why.  The infallible
//! `parallel_map` / `parallel_for_with` wrappers keep their historical
//! signatures and re-panic **on the caller's thread** with that same
//! structured message, so even legacy call sites surface the poisoned
//! indices instead of dying inside an unjoinable worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads: `WSEL_THREADS` env override, else the
/// available parallelism (the CI image exposes a single core — the pool
/// degenerates to serial execution with no overhead beyond one atomic).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("WSEL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// One or more work items of a parallel batch panicked.  Every
/// non-poisoned item still ran to completion; this error reports the
/// poisoned ones so the caller can retry, skip, or fail loudly — instead
/// of the whole process aborting.
#[derive(Debug)]
pub struct PoisonedBatch {
    /// `(item index, panic message)` pairs, ascending by index.
    pub poisoned: Vec<(usize, String)>,
    /// Total number of items in the batch.
    pub n: usize,
}

impl std::fmt::Display for PoisonedBatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let idxs: Vec<String> = self.poisoned.iter().map(|(i, _)| i.to_string()).collect();
        write!(
            f,
            "{} of {} parallel work item(s) panicked (poisoned indices [{}]); first: {}",
            self.poisoned.len(),
            self.n,
            idxs.join(", "),
            self.poisoned.first().map(|(_, m)| m.as_str()).unwrap_or("?")
        )
    }
}

impl std::error::Error for PoisonedBatch {}

/// Best-effort human-readable message from a panic payload.
fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f(i)` for every `i in 0..n`, distributing across `threads`
/// workers, and collect results in index order.  Item panics are caught
/// per index: the batch completes and the error lists every poisoned
/// index with its panic message.
pub fn try_parallel_map<T, F>(n: usize, threads: usize, f: F) -> Result<Vec<T>, PoisonedBatch>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let mut poisoned: Vec<(usize, String)> = Vec::new();
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            match catch_unwind(AssertUnwindSafe(|| f(i))) {
                Ok(v) => *slot = Some(v),
                Err(e) => poisoned.push((i, panic_msg(e))),
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let poison_sink: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let next = &next;
                let f = &f;
                let out_ptr = &out_ptr;
                let poison_sink = &poison_sink;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    match catch_unwind(AssertUnwindSafe(|| f(i))) {
                        // SAFETY: each index is claimed by exactly one
                        // worker via the atomic counter, so writes never
                        // alias.
                        Ok(v) => unsafe { *out_ptr.0.add(i) = Some(v) },
                        Err(e) => poison_sink.lock().unwrap().push((i, panic_msg(e))),
                    }
                });
            }
        });
        poisoned = poison_sink.into_inner().unwrap();
        poisoned.sort_by_key(|&(i, _)| i);
    }
    if poisoned.is_empty() {
        Ok(out.into_iter().map(Option::unwrap).collect())
    } else {
        Err(PoisonedBatch { poisoned, n })
    }
}

/// Infallible wrapper around [`try_parallel_map`]: keeps the historical
/// signature; a poisoned batch re-panics on the caller's thread with the
/// structured message naming the poisoned indices.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    match try_parallel_map(n, threads, f) {
        Ok(v) => v,
        Err(e) => panic!("parallel_map: {e}"),
    }
}

/// Run `f(&mut state, i)` for every `i in 0..n` with **worker-local
/// state**: each worker builds one `S` via `init` and threads it through
/// every item it claims, then all worker states are returned (order
/// unspecified — callers must merge with order-insensitive operations,
/// e.g. integer adds).  This is the fork-join shape of the exact
/// tile-power engine: per-thread simulation scratch accumulates toggle
/// counts across work items and is folded once at the end.
///
/// Item panics are caught per index; on any poison the worker states are
/// discarded (a panicking item may have left its state half-updated) and
/// the error lists the poisoned indices.
pub fn try_parallel_for_with<S, I, F>(
    n: usize,
    threads: usize,
    init: I,
    f: F,
) -> Result<Vec<S>, PoisonedBatch>
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        let mut state = init();
        let mut poisoned: Vec<(usize, String)> = Vec::new();
        for i in 0..n {
            if let Err(e) = catch_unwind(AssertUnwindSafe(|| f(&mut state, i))) {
                poisoned.push((i, panic_msg(e)));
            }
        }
        return if poisoned.is_empty() {
            Ok(vec![state])
        } else {
            Err(PoisonedBatch { poisoned, n })
        };
    }
    let next = AtomicUsize::new(0);
    let poison_sink: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());
    let states = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let init = &init;
                let f = &f;
                let poison_sink = &poison_sink;
                scope.spawn(move || {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        if let Err(e) = catch_unwind(AssertUnwindSafe(|| f(&mut state, i))) {
                            poison_sink.lock().unwrap().push((i, panic_msg(e)));
                        }
                    }
                    state
                })
            })
            .collect();
        handles
            .into_iter()
            // Workers catch item panics themselves, so a join failure can
            // only come from harness-level bugs.
            .map(|h| h.join().expect("worker thread died outside an item"))
            .collect::<Vec<S>>()
    });
    let mut poisoned = poison_sink.into_inner().unwrap();
    if poisoned.is_empty() {
        Ok(states)
    } else {
        poisoned.sort_by_key(|&(i, _)| i);
        Err(PoisonedBatch { poisoned, n })
    }
}

/// Infallible wrapper around [`try_parallel_for_with`]: keeps the
/// historical signature; a poisoned batch re-panics on the caller's
/// thread with the structured message naming the poisoned indices.
pub fn parallel_for_with<S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<S>
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    match try_parallel_for_with(n, threads, init, f) {
        Ok(v) => v,
        Err(e) => panic!("parallel_for_with: {e}"),
    }
}

struct SendPtr<T>(*mut T);
// SAFETY: raw pointer shared across scoped threads; disjoint writes only
// (see try_parallel_map).
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn serial_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn for_with_covers_every_item_once() {
        // Each item's index lands in exactly one worker-local sum.
        let states = parallel_for_with(100, 4, || 0u64, |s, i| *s += i as u64);
        assert!(states.len() <= 4 && !states.is_empty());
        assert_eq!(states.iter().sum::<u64>(), 4950);
    }

    #[test]
    fn for_with_serial_preserves_order() {
        let states = parallel_for_with(7, 1, Vec::<usize>::new, |s, i| s.push(i));
        assert_eq!(states.len(), 1);
        assert_eq!(states[0], vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn for_with_empty() {
        let states = parallel_for_with(0, 4, || 1u32, |_s, _i| {});
        assert_eq!(states, vec![1]);
    }

    #[test]
    fn map_poison_reports_every_index_and_batch_completes() {
        let err = try_parallel_map(10, 4, |i| {
            if i == 3 || i == 7 {
                panic!("boom at {i}");
            }
            i * 2
        })
        .unwrap_err();
        let idxs: Vec<usize> = err.poisoned.iter().map(|&(i, _)| i).collect();
        assert_eq!(idxs, vec![3, 7]);
        assert_eq!(err.n, 10);
        assert!(err.poisoned[0].1.contains("boom at 3"), "{:?}", err.poisoned);
        let msg = format!("{err}");
        assert!(msg.contains("poisoned indices [3, 7]"), "{msg}");
    }

    #[test]
    fn map_poison_serial_path() {
        let err = try_parallel_map(4, 1, |i| {
            if i == 1 {
                panic!("serial boom");
            }
            i
        })
        .unwrap_err();
        assert_eq!(err.poisoned.len(), 1);
        assert_eq!(err.poisoned[0].0, 1);
    }

    #[test]
    fn for_with_poison_reports_indices() {
        let err = try_parallel_for_with(
            8,
            3,
            || 0u64,
            |s, i| {
                if i == 5 {
                    panic!("item 5 bad");
                }
                *s += 1;
            },
        )
        .unwrap_err();
        assert_eq!(err.poisoned.len(), 1);
        assert_eq!(err.poisoned[0].0, 5);
        assert!(err.poisoned[0].1.contains("item 5 bad"));
    }

    #[test]
    fn infallible_wrapper_repanics_with_structured_message() {
        let caught = catch_unwind(|| {
            parallel_map(6, 2, |i| {
                if i == 2 {
                    panic!("wrapped");
                }
                i
            })
        })
        .unwrap_err();
        let msg = panic_msg(caught);
        assert!(msg.contains("poisoned indices [2]"), "{msg}");
    }

    #[test]
    fn ok_batches_unaffected_by_catching() {
        assert_eq!(try_parallel_map(5, 2, |i| i + 1).unwrap(), vec![1, 2, 3, 4, 5]);
        let states = try_parallel_for_with(20, 4, || 0u32, |s, _| *s += 1).unwrap();
        assert_eq!(states.iter().sum::<u32>(), 20);
    }
}
