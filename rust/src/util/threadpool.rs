//! Scoped work-stealing-free thread pool for data-parallel simulation
//! (per-layer characterization, per-tile power).  The offline image has no
//! rayon/tokio; this covers the fork-join pattern those would provide.
//!
//! Work items are indices `0..n`; workers pull from a shared atomic
//! counter, so load imbalance between items self-schedules.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads: `WSEL_THREADS` env override, else the
/// available parallelism (the CI image exposes a single core — the pool
/// degenerates to serial execution with no overhead beyond one atomic).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("WSEL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n`, distributing across `threads`
/// workers, and collect results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
        return out.into_iter().map(Option::unwrap).collect();
    }
    let next = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let out_ptr = &out_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index is claimed by exactly one worker via
                // the atomic counter, so writes never alias.
                unsafe { *out_ptr.0.add(i) = Some(v) };
            });
        }
    });
    out.into_iter().map(Option::unwrap).collect()
}

/// Run `f(&mut state, i)` for every `i in 0..n` with **worker-local
/// state**: each worker builds one `S` via `init` and threads it through
/// every item it claims, then all worker states are returned (order
/// unspecified — callers must merge with order-insensitive operations,
/// e.g. integer adds).  This is the fork-join shape of the exact
/// tile-power engine: per-thread simulation scratch accumulates toggle
/// counts across work items and is folded once at the end.
pub fn parallel_for_with<S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<S>
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        let mut state = init();
        for i in 0..n {
            f(&mut state, i);
        }
        return vec![state];
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let init = &init;
                let f = &f;
                scope.spawn(move || {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        f(&mut state, i);
                    }
                    state
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

struct SendPtr<T>(*mut T);
// SAFETY: raw pointer shared across scoped threads; disjoint writes only
// (see parallel_map).
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn serial_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn for_with_covers_every_item_once() {
        // Each item's index lands in exactly one worker-local sum.
        let states = parallel_for_with(100, 4, || 0u64, |s, i| *s += i as u64);
        assert!(states.len() <= 4 && !states.is_empty());
        assert_eq!(states.iter().sum::<u64>(), 4950);
    }

    #[test]
    fn for_with_serial_preserves_order() {
        let states = parallel_for_with(7, 1, Vec::<usize>::new, |s, i| s.push(i));
        assert_eq!(states.len(), 1);
        assert_eq!(states[0], vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn for_with_empty() {
        let states = parallel_for_with(0, 4, || 1u32, |_s, _i| {});
        assert_eq!(states, vec![1]);
    }
}
