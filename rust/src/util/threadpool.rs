//! Scoped work-stealing-free thread pool for data-parallel simulation
//! (per-layer characterization, per-tile power).  The offline image has no
//! rayon/tokio; this covers the fork-join pattern those would provide.
//!
//! Work items are indices `0..n`; workers pull from a shared atomic
//! counter, so load imbalance between items self-schedules.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads: `WSEL_THREADS` env override, else the
/// available parallelism (the CI image exposes a single core — the pool
/// degenerates to serial execution with no overhead beyond one atomic).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("WSEL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n`, distributing across `threads`
/// workers, and collect results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
        return out.into_iter().map(Option::unwrap).collect();
    }
    let next = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let out_ptr = &out_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index is claimed by exactly one worker via
                // the atomic counter, so writes never alias.
                unsafe { *out_ptr.0.add(i) = Some(v) };
            });
        }
    });
    out.into_iter().map(Option::unwrap).collect()
}

struct SendPtr<T>(*mut T);
// SAFETY: raw pointer shared across scoped threads; disjoint writes only
// (see parallel_map).
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn serial_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
