//! Minimal leveled logger with wall-clock-relative timestamps.
//!
//! Level is controlled by `WSEL_LOG` (`error|warn|info|debug`, default
//! `info`).  Output goes to stderr so report tables on stdout stay clean.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let from_env = match std::env::var("WSEL_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the level programmatically (tests, quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

#[doc(hidden)]
pub fn log(l: Level, tag: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) > level() {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    eprintln!("[{secs:9.3}s {tag:5}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, "info", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, "warn", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, "debug", format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering_is_monotone() {
        set_level(Level::Warn);
        assert!((Level::Error as u8) <= (Level::Warn as u8));
        assert!((Level::Debug as u8) > (Level::Warn as u8));
        set_level(Level::Info);
    }
}
