//! Deterministic PRNGs.
//!
//! [`SplitMix64`] is the cross-language workhorse: `python/compile/data.py`
//! implements the identical step function, which is what lets the Rust and
//! Python sides generate bit-identical synthetic datasets
//! (`tests/integration_data.rs` pins a golden vector).
//!
//! [`Xoshiro256`] (xoshiro256**) is the general-purpose generator for
//! sampling, trace synthesis and the property-test harness.

/// SplitMix64 stepper — one `u64` out per step.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// One SplitMix64 step (must match `data.splitmix64` in Python).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Order-sensitive 2-word hash used for random-access sample addressing
/// (must match `data.mix2` in Python).
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    let mut sm = SplitMix64::new(a ^ 0x6A09_E667_F3BC_C909);
    sm.next_u64();
    sm.state ^= b;
    sm.next_u64()
}

/// xoshiro256** — fast, high-quality general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        // Seed the state via SplitMix64 as recommended by the authors.
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough
    /// for simulation sampling; n is tiny relative to 2^64 here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Random `i8` code in `[-127, 127]`.
    #[inline]
    pub fn code(&mut self) -> i32 {
        self.below(255) as i32 - 127
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if the total mass is zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden() {
        // Golden values cross-checked against the Python reference
        // implementation in python/compile/data.py.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_eq!(a, 0xE220_A839_7B1D_CDAF);
        assert_eq!(b, 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn mix2_order_sensitive() {
        assert_ne!(mix2(1, 2), mix2(2, 1));
        assert_eq!(mix2(7, 9), mix2(7, 9));
    }

    #[test]
    fn xoshiro_uniformish() {
        let mut rng = Xoshiro256::new(42);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[rng.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 800 && c < 1200, "bucket count {c} out of range");
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Xoshiro256::new(1);
        let w = [0.0, 0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(rng.weighted(&w), 2);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
